//! The SIMD-backend oracle: `SimdCpuEngine` and the lane-interleaved
//! kernel must be bit-identical to the golden `CpuPbvdDecoder` for
//! every code preset, **both metric widths** (u32 × 8 lanes and the
//! narrow saturating u16 × 16 lanes), **every ACS backend available
//! on the build host** (scalar/portable always; AVX2/NEON per arch),
//! batches {1, 7, 16, 26} (ragged tails for both lane widths), worker
//! counts {1, 2, 8}, and full-range i8 LLRs including -128 (which
//! `frame_stream`'s clamp can produce).
//!
//! Uses the in-tree property driver (`pbvd::testutil::check`) and the
//! shared backend-parametrized conformance harness
//! (`pbvd::testutil::oracle_matrix`).

use pbvd::config::{DecoderConfig, EngineKind};
use pbvd::coordinator::{CpuEngine, DecodeEngine};
use pbvd::rng::Xoshiro256;
use pbvd::simd::{
    AcsBackend, BackendChoice, LaneInterleavedAcs, Metric, MetricWidth, SimdCpuEngine, SimdTuning,
    LANES, LANES_U16,
};
use pbvd::testutil::{
    check, gen_noisy_stream, oracle_matrix, OracleMatrix, PropConfig, BOTH_WIDTHS, SIMD_ONLY,
};
use pbvd::trellis::Trellis;
use pbvd::viterbi::CpuPbvdDecoder;
use std::sync::Arc;

fn cfg(cases: usize) -> PropConfig {
    PropConfig {
        cases,
        base_seed: 0x51D0ED,
    }
}

const WORKER_LADDER: [usize; 3] = [1, 2, 8];
/// Batch sizes: below a u32 lane-group, one short of a group, exactly
/// one u16 lane-group (= two u32 groups), and one u16 group plus a
/// 10-PB ragged tail (= three u32 groups plus a 2-PB tail).
const BATCH_LADDER: [usize; 4] = [1, 7, 16, 26];

/// Full i8 range including -128 (the quantizer clamp can produce it).
fn random_i8_llrs(rng: &mut Xoshiro256, n: usize) -> Vec<i8> {
    (0..n)
        .map(|_| ((rng.next_below(256) as i32) - 128) as i8)
        .collect()
}

#[test]
fn prop_simd_engine_bit_identical_all_presets_batches_workers_widths_backends() {
    // The full conformance matrix through the shared harness: output
    // bit-identity vs golden plus job-count / metric-width / backend
    // attribution invariants, per cell.
    let backends = AcsBackend::available();
    check(
        "simd == cpu across presets/batches/workers/widths/backends",
        cfg(2),
        |rng| {
            for (name, k, _) in pbvd::trellis::PRESETS {
                let t = Trellis::preset(name).unwrap();
                let (block, depth) = (48usize, 6 * *k as usize);
                let per_pb = (block + 2 * depth) * t.r;
                let m = OracleMatrix {
                    trellis: &t,
                    block,
                    depth,
                    q: 8,
                    engines: &SIMD_ONLY,
                    widths: &BOTH_WIDTHS,
                    backends: &backends,
                    batches: &BATCH_LADDER,
                    workers: &WORKER_LADDER,
                };
                oracle_matrix(&m, name, |batch| random_i8_llrs(rng, batch * per_pb))?;
            }
            Ok(())
        },
    );
}

fn check_lockstep_width<M: Metric>(rng: &mut Xoshiro256) -> Result<(), String> {
    let presets = pbvd::trellis::PRESETS;
    let (name, k, _) = presets[rng.next_below(presets.len() as u64) as usize];
    let t = Trellis::preset(name).unwrap();
    let block = 16 + 8 * rng.next_below(6) as usize;
    let depth = 5 * (k as usize) + rng.next_below(10) as usize;
    let reference = CpuPbvdDecoder::new(&t, block, depth);
    let mut kern = LaneInterleavedAcs::<M>::new(&t, block, depth);
    let per_pb = (block + 2 * depth) * t.r;
    let llr8 = random_i8_llrs(rng, M::LANES * per_pb);
    kern.forward(&llr8);
    let mut bits = vec![0u8; block];
    for lane in 0..M::LANES {
        let llr32: Vec<i32> = llr8[lane * per_pb..(lane + 1) * per_pb]
            .iter()
            .map(|&x| x as i32)
            .collect();
        let fwd = reference.forward(&llr32);
        for st in 0..t.n_states {
            let got: u64 = kern.path_metrics()[st * M::LANES + lane].into();
            if got as i64 != fwd.pm[st] {
                return Err(format!(
                    "{name} D={block} L={depth} u{} lane={lane}: path metrics \
                     diverged at state {st}",
                    M::BITS
                ));
            }
        }
        for s0 in [0usize, 1, t.n_states - 1] {
            kern.traceback_into(lane, s0, &mut bits);
            if bits != reference.traceback(&fwd, s0) {
                return Err(format!(
                    "{name} D={block} L={depth} u{} lane={lane} s0={s0}: \
                     traceback diverged",
                    M::BITS
                ));
            }
        }
    }
    Ok(())
}

#[test]
fn prop_lockstep_kernel_matches_golden_forward_and_traceback() {
    check("lane-interleaved kernel == golden model", cfg(6), |rng| {
        check_lockstep_width::<u32>(rng)?;
        check_lockstep_width::<u16>(rng)
    });
}

#[test]
fn prop_simd_stream_matches_golden_under_noise() {
    // End-to-end through the coordinator: framing, zero-copy shared
    // dispatch, lane-group sharding, splicing, reassembly — at both
    // metric widths plus the autotuner.
    check("simd stream == golden stream", cfg(4), |rng| {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let (block, depth) = (64usize, 42usize);
        let n = 3000 + rng.next_below(2000) as usize;
        let (_, llr) = gen_noisy_stream(&t, n, 3.5, rng.next_u64());
        let want = CpuPbvdDecoder::new(&t, block, depth).decode_stream(&llr);
        for (batch, lanes, workers, width) in [
            (LANES, 1usize, 2usize, MetricWidth::W32),
            (13, 2, 4, MetricWidth::W16),
            (LANES_U16, 3, 1, MetricWidth::W16),
            (2 * LANES_U16 + 5, 2, 2, MetricWidth::Auto),
        ] {
            let coord = DecoderConfig::new("ccsds_k7")
                .batch(batch)
                .block(block)
                .depth(depth)
                .workers(workers)
                .lanes(lanes)
                .engine(EngineKind::Simd)
                .width(width)
                .build_coordinator(None)
                .unwrap();
            let (got, stats) = coord.decode_stream(&llr).unwrap();
            if got != want {
                return Err(format!(
                    "B={batch} lanes={lanes} workers={workers} {width:?}: \
                     stream decode diverged"
                ));
            }
            let pw = stats.per_worker.expect("simd engine reports worker stats");
            if pw.workers() != workers {
                return Err(format!("expected {workers} workers, got {}", pw.workers()));
            }
        }
        Ok(())
    });
}

#[test]
fn shared_and_borrowed_entry_points_agree() {
    let t = Trellis::preset("k9").unwrap();
    let (batch, block, depth) = (LANES + 3, 40usize, 54usize);
    let simd = DecoderConfig::new("k9")
        .batch(batch)
        .block(block)
        .depth(depth)
        .workers(3)
        .engine(EngineKind::Simd)
        .build_engine(&t)
        .unwrap();
    let mut rng = Xoshiro256::seeded(0xA5C);
    let llr = random_i8_llrs(&mut rng, batch * (block + 2 * depth) * t.r);
    let (want, _) = simd.decode_batch(&llr).unwrap();
    let shared: Arc<[i8]> = llr.into();
    let (got, timings) = simd.decode_batch_shared(&shared).unwrap();
    assert_eq!(got, want);
    assert_eq!(timings.per_worker.unwrap().total_blocks(), batch as u64);
}

#[test]
fn auto_detection_picks_simd_at_lane_width() {
    let t = Trellis::preset("ccsds_k7").unwrap();
    let auto = |batch: usize, workers: usize| {
        DecoderConfig::new("ccsds_k7")
            .batch(batch)
            .block(64)
            .depth(42)
            .workers(workers)
            .engine(EngineKind::Auto)
            .build_engine(&t)
            .unwrap()
    };
    // batch >= LANES + pooled workers -> lane-interleaved engine
    let eng = auto(LANES, 2);
    assert!(eng.name().starts_with("simd-cpu:"), "{}", eng.name());
    let eng = auto(4 * LANES, 0);
    assert!(eng.name().starts_with("simd-cpu:"), "{}", eng.name());
    // below a lane-group -> scalar pool; 1 worker -> golden engine
    let eng = auto(LANES - 1, 2);
    assert!(eng.name().starts_with("par-cpu:"), "{}", eng.name());
    let eng = auto(4 * LANES, 1);
    assert!(eng.name().starts_with("cpu:"), "{}", eng.name());
}

#[test]
fn cfg_selection_forces_requested_metric_width_and_backend() {
    let t = Trellis::preset("ccsds_k7").unwrap();
    let base = DecoderConfig::new("ccsds_k7")
        .batch(2 * LANES_U16)
        .block(64)
        .depth(42)
        .workers(2)
        .engine(EngineKind::Simd);
    let e16 = base.clone().width(MetricWidth::W16).build_engine(&t).unwrap();
    assert!(e16.name().contains("x16-"), "{}", e16.name());
    let e32 = base.clone().width(MetricWidth::W32).build_engine(&t).unwrap();
    assert!(e32.name().contains("x8-"), "{}", e32.name());
    // a forced backend shows up in the engine name (and the engine
    // really runs it — pinned by the conformance matrix elsewhere)
    let ep = base
        .clone()
        .width(MetricWidth::W32)
        .backend(BackendChoice::Forced(AcsBackend::Portable))
        .build_engine(&t)
        .unwrap();
    assert!(ep.name().ends_with("portable"), "{}", ep.name());
    // both decode a batch identically to the golden engine
    let (batch, block, depth) = (2 * LANES_U16, 64usize, 42usize);
    let mut rng = Xoshiro256::seeded(0xCF6);
    let llr = random_i8_llrs(&mut rng, batch * (block + 2 * depth) * t.r);
    let (want, _) = CpuEngine::new(&t, batch, block, depth).decode_batch(&llr).unwrap();
    assert_eq!(e16.decode_batch(&llr).unwrap().0, want);
    assert_eq!(e32.decode_batch(&llr).unwrap().0, want);
}

#[test]
fn split_pipeline_bit_identical_to_fused_across_presets() {
    // The ACS/traceback split (the SIMD engine's default) must
    // reproduce the fused forward+traceback pool bit-for-bit — every
    // preset, both widths, ragged tails that exercise the full-group /
    // peeled-u32 / scalar-tail job kinds, workers {1, 2, 8} — and the
    // phase attribution must account for every nanosecond of busy time.
    for (name, k, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name).unwrap();
        let depth = 6 * (*k as usize);
        let block = 40usize;
        // one u16 group + peeled u32 group + 3-PB scalar tail (for the
        // u32 width: 3 full groups + the same tail)
        let batch = LANES_U16 + LANES + 3;
        let mut rng = Xoshiro256::seeded(0x5B1D);
        let llr = random_i8_llrs(&mut rng, batch * (block + 2 * depth) * t.r);
        for width in [MetricWidth::W32, MetricWidth::W16] {
            let tuning = SimdTuning {
                width,
                q: 8,
                backend: BackendChoice::Auto,
            };
            let fused = SimdCpuEngine::with_config_fused(&t, batch, block, depth, 2, tuning);
            let (want, want_t) = fused.decode_batch(&llr).unwrap();
            assert_eq!(
                want_t.per_worker.unwrap().total_tb_busy(),
                std::time::Duration::ZERO,
                "{name} {width:?}: fused pool must record no traceback phase"
            );
            for workers in WORKER_LADDER {
                let split = SimdCpuEngine::with_config(&t, batch, block, depth, workers, tuning);
                let (got, tm) = split.decode_batch(&llr).unwrap();
                assert_eq!(got, want, "{name} {width:?} workers={workers}");
                assert_eq!(
                    tm.margins, want_t.margins,
                    "{name} {width:?} workers={workers} margins"
                );
                let pw = tm.per_worker.expect("per-call attribution");
                assert_eq!(
                    pw.total_acs_busy() + pw.total_tb_busy(),
                    pw.total_busy(),
                    "{name} {width:?} workers={workers}: phases must partition busy time"
                );
                assert!(
                    pw.total_tb_busy() > std::time::Duration::ZERO,
                    "{name} {width:?} workers={workers}: traceback phase not attributed"
                );
            }
        }
    }
}

#[test]
fn noiseless_roundtrip_all_presets() {
    // Clean channel: every preset recovers the payload exactly through
    // the lane-interleaved engine in both widths, ragged tail included
    // (B = 13 and B = 19).
    for (name, k, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name).unwrap();
        let depth = 6 * (*k as usize);
        let block = 40usize;
        for (batch, width) in [(13usize, MetricWidth::W32), (19, MetricWidth::W16)] {
            let mut rng = Xoshiro256::seeded(0x0DD7A11);
            let n = 1013usize; // odd tail
            let bits: Vec<u8> = (0..n).map(|_| rng.next_bit()).collect();
            let mut enc = pbvd::encoder::ConvEncoder::new(&t);
            let llr: Vec<i32> = enc
                .encode(&bits)
                .iter()
                .map(|&b| if b == 0 { 16 } else { -16 })
                .collect();
            let coord = DecoderConfig::new(name)
                .batch(batch)
                .block(block)
                .depth(depth)
                .workers(4)
                .lanes(2)
                .engine(EngineKind::Simd)
                .width(width)
                .build_coordinator(None)
                .unwrap();
            let (out, stats) = coord.decode_stream(&llr).unwrap();
            assert_eq!(out, bits, "{name} {width:?}");
            assert_eq!(stats.n_bits, n);
            let pw = stats.per_worker.unwrap();
            assert_eq!(
                pw.total_blocks() as usize,
                n.div_ceil(block).div_ceil(batch) * batch,
                "{name} {width:?}: every decoded PB attributed to exactly one worker"
            );
        }
    }
}
