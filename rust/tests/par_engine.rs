//! The parallel-backend oracle: `ParCpuEngine` must be bit-identical
//! to the golden `CpuEngine` for every code preset, every worker count
//! in {1, 2, 4, 8}, odd tail blocks, and any lane count — under noise.
//!
//! Uses the in-tree property driver (`pbvd::testutil::check`) and the
//! shared backend-parametrized conformance harness
//! (`pbvd::testutil::oracle_matrix_stream` — the same driver the SIMD
//! suites run; `Par` cells collapse the width/backend axes).

use pbvd::coordinator::{DecodeEngine, StreamCoordinator};
use pbvd::par::{ButterflyAcs, ParCpuEngine};
use pbvd::simd::AcsBackend;
use pbvd::testutil::{
    check, gen_noisy_stream, oracle_matrix_stream, random_bits, EngineKind, OracleMatrix,
    PropConfig, BOTH_ENGINES, BOTH_WIDTHS,
};
use pbvd::trellis::Trellis;
use pbvd::viterbi::CpuPbvdDecoder;
use std::sync::Arc;

fn cfg(cases: usize) -> PropConfig {
    PropConfig {
        cases,
        base_seed: 0x9A55ED,
    }
}

const WORKER_LADDER: [usize; 4] = [1, 2, 4, 8];
const PAR_ONLY: [EngineKind; 1] = [EngineKind::Par];

#[test]
fn prop_par_engine_bit_identical_across_worker_counts() {
    check("par == cpu across workers", cfg(12), |rng| {
        let presets = pbvd::trellis::PRESETS;
        let (name, k, _) = presets[rng.next_below(presets.len() as u64) as usize];
        let t = Trellis::preset(name).unwrap();
        let block = 24 + 8 * rng.next_below(6) as usize;
        let depth = 5 * (k as usize) + rng.next_below(12) as usize;
        let batch = 1 + rng.next_below(9) as usize;
        // odd tail: stream length deliberately NOT a multiple of D or B*D
        let n = block * batch + 1 + rng.next_below((2 * block) as u64) as usize;
        let (_, llr) = gen_noisy_stream(&t, n, 4.0, rng.next_u64());
        let m = OracleMatrix {
            trellis: &t,
            block,
            depth,
            q: 8,
            engines: &PAR_ONLY,
            widths: &BOTH_WIDTHS,
            backends: &[],
            batches: &[batch],
            workers: &WORKER_LADDER,
        };
        oracle_matrix_stream(&m, name, 1, &llr)
    });
}

#[test]
fn prop_engine_lane_invariance() {
    // lanes (pipeline concurrency) x workers (shard concurrency) x
    // engine kind must never change the output stream.  The backend
    // axis collapses to the detected one here (full backend coverage
    // is the batch-level matrix's job).
    let detected = [AcsBackend::detect()];
    check("lane x worker x engine invariance", cfg(6), |rng| {
        let t = Trellis::preset("ccsds_k7").unwrap();
        // batch 19 = one full u16 lane-group + 3-PB tail, so the W16
        // axis really runs the 16-lane kernel (batch < 16 would make
        // every W16 cell silently fall back to u32)
        let (batch, block, depth) = (19usize, 64usize, 42usize);
        let n = 2000 + rng.next_below(1500) as usize;
        let (_, llr) = gen_noisy_stream(&t, n, 3.5, rng.next_u64());
        let m = OracleMatrix {
            trellis: &t,
            block,
            depth,
            q: 8,
            engines: &BOTH_ENGINES,
            widths: &BOTH_WIDTHS,
            backends: &detected,
            batches: &[batch],
            workers: &[2, 8],
        };
        for lanes in [1usize, 2, 4] {
            oracle_matrix_stream(&m, "lane-invariance", lanes, &llr)?;
        }
        Ok(())
    });
}

#[test]
fn prop_butterfly_kernel_matches_reference_block_decode() {
    // Kernel-level oracle: decode_block_into == CpuPbvdDecoder::decode_block
    // on noisy i8 LLRs for random geometries and codes.
    check("butterfly kernel == reference", cfg(20), |rng| {
        let presets = pbvd::trellis::PRESETS;
        let (name, k, _) = presets[rng.next_below(presets.len() as u64) as usize];
        let t = Trellis::preset(name).unwrap();
        let block = 16 + 8 * rng.next_below(8) as usize;
        let depth = 5 * (k as usize) + rng.next_below(10) as usize;
        let reference = CpuPbvdDecoder::new(&t, block, depth);
        let mut kern = ButterflyAcs::new(&t, block, depth);
        // full i8 range including -128, which frame_stream can produce
        let llr8: Vec<i8> = (0..kern.total() * t.r)
            .map(|_| ((rng.next_below(256) as i32) - 128) as i8)
            .collect();
        let llr32: Vec<i32> = llr8.iter().map(|&x| x as i32).collect();
        let want = reference.decode_block(&llr32);
        let mut got = vec![0u8; block];
        kern.decode_block_into(&llr8, &mut got);
        if got != want {
            return Err(format!("{name} D={block} L={depth}: kernel diverged"));
        }
        Ok(())
    });
}

#[test]
fn noiseless_roundtrip_all_presets_all_worker_counts() {
    // Clean channel: every preset recovers the payload exactly through
    // the sharded engine at every ladder point.
    for (name, k, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name).unwrap();
        let depth = 6 * (*k as usize);
        let (batch, block) = (3usize, 40usize);
        let mut rng = pbvd::rng::Xoshiro256::seeded(0x0DD7A11);
        let n = 777usize; // odd tail (777 = 19*40 + 17)
        let bits = random_bits(&mut rng, n);
        let mut enc = pbvd::encoder::ConvEncoder::new(&t);
        let llr: Vec<i32> = enc
            .encode(&bits)
            .iter()
            .map(|&b| if b == 0 { 16 } else { -16 })
            .collect();
        for workers in WORKER_LADDER {
            let eng = ParCpuEngine::new(&t, batch, block, depth, workers);
            let coord = StreamCoordinator::new(Arc::new(eng), 2);
            let (out, stats) = coord.decode_stream(&llr).unwrap();
            assert_eq!(out, bits, "{name} workers={workers}");
            assert_eq!(stats.n_bits, n);
            let pw = stats.per_worker.unwrap();
            // every decoded PB is accounted to exactly one worker
            assert_eq!(pw.total_blocks() as usize, n.div_ceil(block).div_ceil(batch) * batch);
        }
    }
}

#[test]
fn split_pipeline_bit_identical_to_fused_across_presets() {
    // The ACS/traceback split (the sharded engine's default) must
    // reproduce the fused forward+traceback pool bit-for-bit — every
    // preset, ragged shard tails, workers {1, 2, 8} — and its phase
    // attribution must account for every nanosecond of busy time.
    for (name, k, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name).unwrap();
        let depth = 6 * (*k as usize);
        let block = 40usize;
        for batch in [1usize, 5] {
            let mut rng = pbvd::rng::Xoshiro256::seeded(0x5B117);
            let llr: Vec<i8> = (0..batch * (block + 2 * depth) * t.r)
                .map(|_| ((rng.next_below(256) as i32) - 128) as i8)
                .collect();
            let fused = ParCpuEngine::with_quantizer_fused(&t, batch, block, depth, 2, 8);
            let (want, want_t) = fused.decode_batch(&llr).unwrap();
            assert_eq!(
                want_t.per_worker.unwrap().total_tb_busy(),
                std::time::Duration::ZERO,
                "{name}: fused pool must record no traceback phase"
            );
            for workers in [1usize, 2, 8] {
                let split = ParCpuEngine::new(&t, batch, block, depth, workers);
                let (got, tm) = split.decode_batch(&llr).unwrap();
                assert_eq!(got, want, "{name} batch={batch} workers={workers}");
                assert_eq!(
                    tm.margins, want_t.margins,
                    "{name} batch={batch} workers={workers} margins"
                );
                let pw = tm.per_worker.expect("per-call attribution");
                assert_eq!(
                    pw.total_acs_busy() + pw.total_tb_busy(),
                    pw.total_busy(),
                    "{name} batch={batch} workers={workers}: phases must partition busy time"
                );
                assert!(
                    pw.total_tb_busy() > std::time::Duration::ZERO,
                    "{name} batch={batch} workers={workers}: traceback phase not attributed"
                );
            }
        }
    }
}

#[test]
fn worker_stats_survive_shared_engine_reuse() {
    // A single engine Arc reused across streams keeps cumulative pool
    // counters; the coordinator still reports correct per-stream deltas.
    let t = Trellis::preset("k5").unwrap();
    let eng = Arc::new(ParCpuEngine::new(&t, 4, 48, 25, 3));
    let (_, llr) = gen_noisy_stream(&t, 3000, 4.0, 99);
    let coord = StreamCoordinator::new(
        Arc::clone(&eng) as Arc<dyn pbvd::coordinator::DecodeEngine>,
        2,
    );
    let (_, s1) = coord.decode_stream(&llr).unwrap();
    let (_, s2) = coord.decode_stream(&llr).unwrap();
    let b1 = s1.per_worker.unwrap().total_blocks();
    let b2 = s2.per_worker.unwrap().total_blocks();
    assert_eq!(b1, b2, "identical streams decode identical block counts");
    // cumulative engine counters cover both streams
    assert_eq!(eng.pool_stats().total_blocks(), b1 + b2);
}
