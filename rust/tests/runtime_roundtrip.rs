//! The authoritative AOT round-trip test: HLO text artifacts produced
//! by `python/compile/aot.py` must load through xla_extension 0.5.1,
//! compile on the PJRT CPU client, execute, and reproduce the CPU
//! golden model bit-for-bit.
//!
//! Requires `make artifacts` (skips with a clear message otherwise).

use pbvd::channel::unpack_bits;
use pbvd::encoder::ConvEncoder;
use pbvd::rng::Xoshiro256;
use pbvd::runtime::{HostTensor, Registry};
use pbvd::trellis::Trellis;
use pbvd::viterbi::CpuPbvdDecoder;

fn registry() -> Option<Registry> {
    if !pbvd::runtime::pjrt_available() {
        eprintln!(
            "SKIP: PJRT runtime unavailable (built against the vendored \
             stub xla crate); see rust/vendor/xla"
        );
        return None;
    }
    match Registry::open_default() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIP: artifacts not built ({e}); run `make artifacts`");
            None
        }
    }
}

/// Noisy quantized batch for the b32_d64_l42 test artifact.
fn make_batch(t: &Trellis, batch: usize, total: usize, seed: u64) -> (Vec<i8>, Vec<Vec<u8>>) {
    let mut rng = Xoshiro256::seeded(seed);
    let r = t.r;
    let mut llr = vec![0i8; batch * total * r];
    let mut payload = Vec::new();
    for b in 0..batch {
        let bits: Vec<u8> = (0..total).map(|_| rng.next_bit()).collect();
        let mut enc = ConvEncoder::new(t);
        let coded = enc.encode(&bits);
        for (i, &c) in coded.iter().enumerate() {
            let clean = if c == 0 { 20i32 } else { -20 };
            let noise = (rng.next_below(13) as i32) - 6;
            llr[b * total * r + i] = (clean + noise).clamp(-127, 127) as i8;
        }
        payload.push(bits);
    }
    (llr, payload)
}

#[test]
fn forward_artifact_matches_cpu_golden() {
    let Some(reg) = registry() else { return };
    let exe = reg
        .load_variant("forward", "ccsds_k7", 32, 64, 42)
        .expect("forward artifact");
    let t = Trellis::preset("ccsds_k7").unwrap();
    let total = 64 + 2 * 42;
    let (llr, _) = make_batch(&t, 32, total, 1);
    let input = HostTensor::from_i8(&[32, total, 2], &llr);
    let out = exe.run(&[input]).expect("execute");
    assert_eq!(out.len(), 2);
    let sp = out[0].to_u32();
    let pm = out[1].to_f32();

    let dec = CpuPbvdDecoder::new(&t, 64, 42);
    let w = t.n_sp_words;
    for b in 0..4 {
        // spot-check 4 PBs against the golden model
        let pb: Vec<i32> = llr[b * total * 2..(b + 1) * total * 2]
            .iter()
            .map(|&x| x as i32)
            .collect();
        let fwd = dec.forward(&pb);
        // the CPU golden model keeps survivors in a D+L ring; compare
        // the retained traceback window stage-by-stage against the
        // kernel's full-length output
        for s in 42..total {
            assert_eq!(
                &sp[b * total * w + s * w..b * total * w + (s + 1) * w],
                &fwd.sp[(s % fwd.ring_stages) * w..(s % fwd.ring_stages + 1) * w],
                "survivor paths differ for PB {b} stage {s}"
            );
        }
        for s in 0..t.n_states {
            let got = pm[b * t.n_states + s] as i64;
            assert_eq!(got, fwd.pm[s], "PM[{s}] differs for PB {b}");
        }
    }
}

#[test]
fn two_kernel_chain_decodes_payload() {
    let Some(reg) = registry() else { return };
    let fwd = reg.load_variant("forward", "ccsds_k7", 32, 64, 42).unwrap();
    let tb = reg
        .load_variant("traceback", "ccsds_k7", 32, 64, 42)
        .unwrap();
    let t = Trellis::preset("ccsds_k7").unwrap();
    let total = 148;
    let (llr, payload) = make_batch(&t, 32, total, 2);
    let input = HostTensor::from_i8(&[32, total, 2], &llr);
    let sp = fwd.run(&[input]).unwrap().remove(0);
    let bits = tb.run(&[sp]).unwrap().remove(0).to_u32();
    let words_per_pb = 64 / 32;
    for b in 0..32 {
        let got = unpack_bits(&bits[b * words_per_pb..(b + 1) * words_per_pb], 64);
        assert_eq!(got[..], payload[b][42..42 + 64], "PB {b}");
    }
}

#[test]
fn fused_equals_two_kernel() {
    let Some(reg) = registry() else { return };
    let fwd = reg.load_variant("forward", "ccsds_k7", 32, 64, 42).unwrap();
    let tb = reg.load_variant("traceback", "ccsds_k7", 32, 64, 42).unwrap();
    let fused = reg.load_variant("fused", "ccsds_k7", 32, 64, 42).unwrap();
    let t = Trellis::preset("ccsds_k7").unwrap();
    let (llr, _) = make_batch(&t, 32, 148, 3);
    let input = HostTensor::from_i8(&[32, 148, 2], &llr);
    let sp = fwd.run(&[input.clone()]).unwrap().remove(0);
    let two = tb.run(&[sp]).unwrap().remove(0).to_u32();
    let one = fused.run(&[input]).unwrap().remove(0).to_u32();
    assert_eq!(one, two);
}

#[test]
fn orig_baseline_same_decisions() {
    let Some(reg) = registry() else { return };
    let fused = reg.load_variant("fused", "ccsds_k7", 32, 64, 42).unwrap();
    let orig = reg.load_variant("orig", "ccsds_k7", 32, 64, 42).unwrap();
    let t = Trellis::preset("ccsds_k7").unwrap();
    let (llr, _) = make_batch(&t, 32, 148, 4);
    let packed = fused
        .run(&[HostTensor::from_i8(&[32, 148, 2], &llr)])
        .unwrap()
        .remove(0)
        .to_u32();
    let f32_data: Vec<f32> = llr.iter().map(|&x| x as f32).collect();
    let per_bit = orig
        .run(&[HostTensor::from_f32(&[32, 148, 2], &f32_data)])
        .unwrap()
        .remove(0)
        .to_i32();
    for b in 0..32 {
        let got = unpack_bits(&packed[b * 2..(b + 1) * 2], 64);
        let want: Vec<u8> = per_bit[b * 64..(b + 1) * 64]
            .iter()
            .map(|&x| x as u8)
            .collect();
        assert_eq!(got, want, "PB {b}");
    }
}

#[test]
fn generality_other_codes_roundtrip() {
    let Some(reg) = registry() else { return };
    for (code, batch, block, depth) in [
        ("k3", 16usize, 32usize, 15usize),
        ("k5", 32, 64, 25),
        ("k9", 16, 64, 45),
        ("r3_k7", 32, 64, 42),
    ] {
        let Ok(fused) = reg.load_variant("fused", code, batch, block, depth) else {
            eprintln!("SKIP {code}: artifact not built");
            continue;
        };
        let t = Trellis::preset(code).unwrap();
        let total = block + 2 * depth;
        let (llr, payload) = make_batch(&t, batch, total, 5);
        let input = HostTensor::from_i8(&[batch, total, t.r], &llr);
        let bits = fused.run(&[input]).unwrap().remove(0).to_u32();
        let wpp = block / 32;
        for b in 0..batch {
            let got = unpack_bits(&bits[b * wpp..(b + 1) * wpp], block);
            assert_eq!(
                got[..],
                payload[b][depth..depth + block],
                "{code} PB {b}"
            );
        }
    }
}

#[test]
fn executable_rejects_wrong_shapes() {
    let Some(reg) = registry() else { return };
    let exe = reg.load_variant("forward", "ccsds_k7", 32, 64, 42).unwrap();
    let bad = HostTensor::from_i8(&[8, 148, 2], &vec![0i8; 8 * 148 * 2]);
    assert!(exe.run(&[bad]).is_err());
    let bad_dtype = HostTensor::from_f32(&[32, 148, 2], &vec![0f32; 32 * 148 * 2]);
    assert!(exe.run(&[bad_dtype]).is_err());
}

#[test]
fn registry_lookup_and_cache() {
    let Some(reg) = registry() else { return };
    assert!(reg.manifest.entries.len() >= 8);
    let a = reg.load("fused_ccsds_k7_b32_d64_l42").unwrap();
    let b = reg.load("fused_ccsds_k7_b32_d64_l42").unwrap();
    // cached: same Arc
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    assert!(reg.load("no_such_artifact").is_err());
}
