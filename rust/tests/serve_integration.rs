//! Loopback integration tests for the `pbvd serve` daemon: concurrent
//! client streams over real TCP against one shared engine, with
//! cross-stream lane-group coalescing, per-stream QoS accounting,
//! slow-reader backpressure, and stall-detector eviction.
//!
//! The acceptance oracle everywhere is bit-identity to the golden
//! `CpuPbvdDecoder` stream decode of the same LLRs — coalescing
//! frames from different clients into one engine batch must be
//! completely invisible in the decoded payloads.

use pbvd::config::DecoderConfig;
use pbvd::serve::{PbvdServer, ServeClient, ServeError};
use pbvd::testutil::gen_noisy_stream;
use pbvd::trellis::Trellis;
use pbvd::viterbi::CpuPbvdDecoder;
use std::net::SocketAddr;
use std::time::{Duration, Instant};

const BLOCK: usize = 32;
const DEPTH: usize = 15;

/// A small, fast daemon on an OS-assigned port.  `workers = 1` makes
/// the config factory pick the golden single-thread engine, so these
/// tests exercise the serving layers, not the SIMD kernels (which have
/// their own conformance matrices).
fn serve(batch: usize, queue: usize, coalesce_us: u64, stall_ms: u64) -> PbvdServer {
    let cfg = DecoderConfig::new("k3")
        .batch(batch)
        .block(BLOCK)
        .depth(DEPTH)
        .workers(1)
        .serve_bind("127.0.0.1:0")
        .stream_queue(queue)
        .coalesce_window_us(coalesce_us)
        .stall_timeout_ms(stall_ms);
    PbvdServer::bind(&cfg, None).expect("bind test daemon")
}

/// One client stream's worth of work: a seeded noisy LLR stream and
/// its golden decode.
fn stream_case(n_bits: usize, seed: u64) -> (Vec<i32>, Vec<u8>) {
    let t = Trellis::preset("k3").unwrap();
    let (_, llr) = gen_noisy_stream(&t, n_bits, 4.0, seed);
    let golden = CpuPbvdDecoder::new(&t, BLOCK, DEPTH).decode_stream(&llr);
    (llr, golden)
}

fn decode_via_daemon(addr: SocketAddr, llr: &[i32], window: usize) -> Vec<u8> {
    let mut client = ServeClient::connect(addr).expect("connect");
    let out = client.decode_stream(llr, window).expect("decode_stream");
    let _ = client.bye();
    out
}

#[test]
fn three_concurrent_streams_coalesce_and_stay_bit_identical() {
    let server = serve(8, 16, 20_000, 10_000);
    let addr = server.local_addr();
    // ragged, deliberately different lengths (tail blocks exercise the
    // partial-frame reassembly per stream)
    let cases: Vec<(Vec<i32>, Vec<u8>)> = [
        (40 * BLOCK + 7, 0xA11CE),
        (37 * BLOCK + 1, 0xB0B),
        (43 * BLOCK + 19, 0xCAFE),
    ]
    .iter()
    .map(|&(n, seed)| stream_case(n, seed))
    .collect();

    let handles: Vec<_> = cases
        .iter()
        .map(|(llr, _)| {
            let llr = llr.clone();
            std::thread::spawn(move || decode_via_daemon(addr, &llr, 8))
        })
        .collect();
    for (h, (_, golden)) in handles.into_iter().zip(&cases) {
        let got = h.join().expect("client thread");
        assert_eq!(&got, golden, "daemon stream diverged from golden");
    }

    // QoS report: at least one dispatched group held frames from >= 2
    // distinct streams, and per-stream counters sum to the totals
    let mut probe = ServeClient::connect(addr).expect("stats probe");
    let stats = probe.stats().expect("stats");
    let totals = stats.get("totals").expect("totals");
    let mixed = totals
        .path("coalesce.groups_mixed")
        .and_then(pbvd::json::Json::as_usize)
        .unwrap_or(0);
    assert!(mixed >= 1, "no cross-stream group was dispatched:\n{stats}");
    let streams = stats
        .get("streams")
        .and_then(pbvd::json::Json::as_obj)
        .expect("streams");
    let num = |j: &pbvd::json::Json, k: &str| j.get(k).and_then(pbvd::json::Json::as_usize).unwrap_or(0) as u64;
    let (mut frames, mut bits, mut busy) = (0u64, 0u64, 0u64);
    for s in streams.values() {
        frames += num(s, "frames");
        bits += num(s, "bits");
        busy += num(s, "busy_ns");
    }
    let expect_frames: u64 = cases
        .iter()
        .map(|(llr, _)| ((llr.len() / 2).div_ceil(BLOCK)) as u64)
        .sum();
    assert_eq!(frames, expect_frames, "per-stream frame counts wrong");
    assert_eq!(num(totals, "frames"), frames, "stream frames != totals");
    assert_eq!(num(totals, "bits"), bits, "stream bits != totals");
    assert_eq!(num(totals, "busy_ns"), busy, "stream busy_ns != totals");
    assert_eq!(server.evictions(), 0, "healthy streams must not be evicted");
}

#[test]
fn slow_reader_is_backpressured_not_evicted_and_peers_run_full_speed() {
    // queue 2: the slow reader can have at most 2 unacked frames, so
    // its trickle cannot hog group slots or daemon memory
    let server = serve(8, 2, 5_000, 10_000);
    let addr = server.local_addr();
    let (fast_llr, fast_golden) = stream_case(60 * BLOCK + 5, 0xFA57);
    let (slow_llr, slow_golden) = stream_case(6 * BLOCK, 0x510);

    let slow = std::thread::spawn(move || {
        let t = Trellis::preset("k3").unwrap();
        let mut client = ServeClient::connect(addr).expect("connect slow");
        let frames = pbvd::coordinator::frame_stream(&slow_llr, t.r, BLOCK, DEPTH, 1);
        let n_bits = slow_llr.len() / t.r;
        let mut out = vec![0u8; n_bits];
        for f in &frames {
            client.submit_frame(&f.llr_i8).expect("submit");
        }
        for _ in 0..frames.len() {
            // a deliberately slow consumer: the daemon must wait for
            // the ack window, never evict (we keep reading, slowly)
            std::thread::sleep(Duration::from_millis(40));
            let (seq, words) = client.recv_result().expect("slow recv");
            let bits = pbvd::channel::unpack_bits(&words, BLOCK);
            let start = seq as usize * BLOCK;
            let take = BLOCK.min(n_bits - start);
            out[start..start + take].copy_from_slice(&bits[..take]);
        }
        out
    });
    let fast = std::thread::spawn(move || decode_via_daemon(addr, &fast_llr, 8));

    assert_eq!(fast.join().unwrap(), fast_golden, "fast stream corrupted");
    assert_eq!(slow.join().unwrap(), slow_golden, "slow stream corrupted");
    assert_eq!(server.evictions(), 0, "a slow-but-live reader was evicted");
}

#[test]
fn wedged_client_is_evicted_without_disturbing_the_other_stream() {
    // short stall so the test runs fast; the healthy client PINGs
    // implicitly by having constant traffic
    let server = serve(8, 8, 2_000, 400);
    let addr = server.local_addr();
    let (llr, golden) = stream_case(80 * BLOCK + 3, 0xD00D);

    // wedge: handshake, submit one valid frame, then go completely
    // silent (no reads, no writes) — the stall detector must kill it
    let t = Trellis::preset("k3").unwrap();
    let (wedge_llr, _) = stream_case(2 * BLOCK, 0x3D);
    let mut wedged = ServeClient::connect(addr).expect("connect wedged");
    let frames = pbvd::coordinator::frame_stream(&wedge_llr, t.r, BLOCK, DEPTH, 1);
    wedged.submit_frame(&frames[0].llr_i8).expect("wedged submit");

    let fast = std::thread::spawn(move || decode_via_daemon(addr, &llr, 8));
    assert_eq!(fast.join().unwrap(), golden, "survivor stream corrupted");

    // wait out the stall window, then confirm the eviction landed
    let t0 = Instant::now();
    while server.evictions() == 0 && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(server.evictions() >= 1, "stall detector never fired");
    // the wedged client's connection is dead: draining it must end in
    // a transport error, never a hang
    let mut saw_dead = false;
    for _ in 0..64 {
        match wedged.recv_result() {
            Err(ServeError::Io(_)) | Err(ServeError::Remote { .. }) => {
                saw_dead = true;
                break;
            }
            Ok(_) => continue,
            Err(e) => panic!("unexpected error draining wedged client: {e:?}"),
        }
    }
    assert!(saw_dead, "wedged client socket still alive after eviction");
}

#[test]
fn protocol_violations_are_typed_and_do_not_kill_the_session_or_daemon() {
    let server = serve(4, 4, 1_000, 10_000);
    let addr = server.local_addr();

    // bad preset bytes in HELLO: a typed refusal
    let err = ServeClient::connect_with(addr, Some("not_a_code")).unwrap_err();
    match &err {
        ServeError::Remote { code, msg } => {
            assert_eq!(code, "bad_hello", "{err}");
            assert!(msg.contains("k3"), "refusal names the served preset: {msg}");
        }
        other => panic!("expected Remote(bad_hello), got {other:?}"),
    }

    // wrong-length SUBMIT: fails that frame, session keeps working
    let (llr, golden) = stream_case(3 * BLOCK, 0xEE);
    let mut client = ServeClient::connect_with(addr, Some("k3")).expect("connect");
    client.submit_frame(&[0i8; 5]).expect("submit short frame");
    let err = client.recv_result().unwrap_err();
    match &err {
        ServeError::Remote { code, .. } => assert_eq!(code, "bad_frame_len", "{err}"),
        other => panic!("expected Remote(bad_frame_len), got {other:?}"),
    }
    // all-erasure SUBMIT (every LLR zero, the puncturing convention
    // for "no information"): a typed frame-scoped refusal, counted as
    // a rejected input — decoding it would only launder garbage bits
    let t = Trellis::preset("k3").unwrap();
    let erased = vec![0i8; (BLOCK + 2 * DEPTH) * t.r];
    client.submit_frame(&erased).expect("submit erased frame");
    let err = client.recv_result().unwrap_err();
    match &err {
        ServeError::Remote { code, msg } => {
            assert_eq!(code, "erased_frame", "{err}");
            assert!(msg.contains("erasure"), "refusal names the cause: {msg}");
        }
        other => panic!("expected Remote(erased_frame), got {other:?}"),
    }
    assert!(
        server.integrity().rejected_inputs() >= 1,
        "the rejected input was not counted"
    );

    let got = client.decode_stream(&llr, 4).expect("session survived");
    assert_eq!(got, golden, "stream after a rejected frame diverged");

    // the daemon as a whole is still healthy for new clients
    let (llr2, golden2) = stream_case(5 * BLOCK + 9, 0xEF);
    assert_eq!(decode_via_daemon(addr, &llr2, 4), golden2);
}

// The advisory load soak that used to live here was promoted into the
// chaos suite (`tests/chaos_serve.rs`,
// `chaos_soak_sustained_load_with_randomized_logged_seed`): same
// sustained concurrent-stream hammering, now under a randomized — but
// logged and replayable — probabilistic fault plan.
