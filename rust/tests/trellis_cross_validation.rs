//! Cross-validation: the Rust trellis implementation must agree with
//! the Python (`python/compile/trellis.py`) export, table for table,
//! for every shipped code — the two independent implementations of the
//! paper's Sec. III-B classification check each other.

use pbvd::json::Json;
use pbvd::runtime::Registry;
use pbvd::trellis::Trellis;

fn registry() -> Option<Registry> {
    match Registry::open_default() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIP: artifacts not built ({e})");
            None
        }
    }
}

#[test]
fn rust_trellis_matches_python_export_all_codes() {
    let Some(reg) = registry() else { return };
    for (name, _, _) in pbvd::trellis::PRESETS {
        let Ok(text) = reg.trellis_json(name) else {
            eprintln!("SKIP {name}: no JSON export");
            continue;
        };
        let t = Trellis::preset(name).unwrap();
        t.validate_against_json(&text)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

#[test]
fn python_export_group_metadata_matches() {
    let Some(reg) = registry() else { return };
    for (name, _, _) in pbvd::trellis::PRESETS {
        let Ok(text) = reg.trellis_json(name) else { continue };
        let j = Json::parse(&text).unwrap();
        let t = Trellis::preset(name).unwrap();
        // group_alpha
        let ga = j.get("group_alpha").and_then(Json::as_i64_vec).unwrap();
        assert_eq!(
            ga.iter().map(|&x| x as u32).collect::<Vec<_>>(),
            t.group_alpha,
            "{name} group_alpha"
        );
        // group label quadruples
        let gl = j.get("group_labels").and_then(Json::as_i64_mat).unwrap();
        for (w, row) in gl.iter().enumerate() {
            let want: Vec<i64> = t.group_labels[w].iter().map(|&x| x as i64).collect();
            assert_eq!(row, &want, "{name} group {w} labels");
        }
        // butterflies per group
        let gb = j.get("group_bflys").and_then(Json::as_i64_mat).unwrap();
        for (w, row) in gb.iter().enumerate() {
            let want: Vec<i64> = t.group_bflys[w].iter().map(|&x| x as i64).collect();
            assert_eq!(row, &want, "{name} group {w} butterflies");
        }
    }
}

#[test]
fn manifest_table2_matches_rust() {
    // aot.py embeds Table II in the manifest `codes` section; check the
    // CCSDS rows against the Rust derivation (and thus the paper).
    let Some(reg) = registry() else { return };
    let text = std::fs::read_to_string(reg.dir.join("manifest.json")).unwrap();
    let j = Json::parse(&text).unwrap();
    let rows = j
        .path("codes.ccsds_k7.table2")
        .and_then(Json::as_arr)
        .expect("manifest table2");
    let t = Trellis::preset("ccsds_k7").unwrap();
    let ours = t.table2();
    assert_eq!(rows.len(), ours.len());
    for (jr, or) in rows.iter().zip(&ours) {
        assert_eq!(
            jr.get("alpha").and_then(Json::as_str).unwrap(),
            or.label_str(0, t.r)
        );
        assert_eq!(
            jr.get("theta").and_then(Json::as_str).unwrap(),
            or.label_str(3, t.r)
        );
        let states = jr.get("states").and_then(Json::as_i64_vec).unwrap();
        assert_eq!(
            states.iter().map(|&x| x as usize).collect::<Vec<_>>(),
            or.states
        );
    }
}

#[test]
fn artifact_shapes_consistent_with_trellis() {
    // Every artifact's declared tensor shapes must follow from its
    // code's trellis dimensions — guards against manifest drift.
    let Some(reg) = registry() else { return };
    for e in &reg.manifest.entries {
        let t = Trellis::preset(&e.code).unwrap();
        match e.variant.as_str() {
            "forward" => {
                assert_eq!(e.inputs[0].shape, vec![e.batch, e.total, t.r]);
                assert_eq!(e.outputs[0].shape, vec![e.batch, e.total, t.n_sp_words]);
                assert_eq!(e.outputs[1].shape, vec![e.batch, t.n_states]);
            }
            "traceback" => {
                assert_eq!(e.inputs[0].shape, vec![e.batch, e.total, t.n_sp_words]);
                assert_eq!(e.outputs[0].shape, vec![e.batch, e.block / 32]);
            }
            "fused" => {
                assert_eq!(e.inputs[0].shape, vec![e.batch, e.total, t.r]);
                assert_eq!(e.outputs[0].shape, vec![e.batch, e.block / 32]);
            }
            "orig" => {
                assert_eq!(e.inputs[0].shape, vec![e.batch, e.total, t.r]);
                assert_eq!(e.outputs[0].shape, vec![e.batch, e.block]);
            }
            other => panic!("unknown variant {other}"),
        }
    }
}
