//! Backend-conformance suite: a deterministic seeded differential-fuzz
//! corpus of adversarial LLR frames plus the cross-ISA tie-break pin.
//!
//! Every ACS backend available on the build host (scalar/portable
//! always; AVX2 on x86_64 and NEON on aarch64 behind
//! `simd-intrinsics`) must decode bit-identically to the golden
//! `CpuEngine` across all 5 code presets, both metric widths and
//! ragged batch tails {1, 7, 9, 15, 17} — driven by the shared
//! `testutil::oracle_matrix` harness.  The corpus is fixed-seed
//! (`rng.rs` Xoshiro256), so a failure replays exactly.
//!
//! The tie-break tests pin the classic cross-ISA divergence: what a
//! `min`/compare-select pair does when both butterfly inputs carry
//! *equal* metrics.  Every backend here must keep the **even
//! predecessor** (survivor bit 0, because the survivor condition is
//! strictly `b < a`), which the crafted equal-metric stages below make
//! directly observable through `LaneInterleavedAcs::decision_mask`.

use pbvd::par::bm_offset;
use pbvd::rng::Xoshiro256;
use pbvd::simd::{AcsBackend, LaneInterleavedAcs, Metric};
use pbvd::testutil::{oracle_matrix, OracleMatrix, BOTH_WIDTHS, SIMD_ONLY};
use pbvd::trellis::Trellis;

/// Ragged batch tails the ISSUE pins: below one u32 group, one short
/// of a u32 group, one past it, one short of a u16 group, one past it.
const TAIL_BATCHES: [usize; 5] = [1, 7, 9, 15, 17];

/// The adversarial frame families of the fuzz corpus.
#[derive(Clone, Copy, Debug)]
enum Pattern {
    /// Every LLR at the i8 minimum — maximal metric growth.
    AllMin,
    /// Alternating -128/+127 — maximal spread churn.
    AlternatingExtremes,
    /// Mostly zeros (every zero stage ties every butterfly) with
    /// random ±extreme bursts — metric ties planted throughout.
    PlantedTies,
    /// Random draws from {-128, 127} only.
    RandomExtremes,
}

const PATTERNS: [Pattern; 4] = [
    Pattern::AllMin,
    Pattern::AlternatingExtremes,
    Pattern::PlantedTies,
    Pattern::RandomExtremes,
];

fn gen_frame(rng: &mut Xoshiro256, pattern: Pattern, n: usize) -> Vec<i8> {
    match pattern {
        Pattern::AllMin => vec![-128i8; n],
        Pattern::AlternatingExtremes => (0..n)
            .map(|i| if i % 2 == 0 { -128i8 } else { 127 })
            .collect(),
        Pattern::PlantedTies => (0..n)
            .map(|_| match rng.next_below(4) {
                0 => {
                    if rng.next_bit() == 0 {
                        -128i8
                    } else {
                        127
                    }
                }
                _ => 0i8,
            })
            .collect(),
        Pattern::RandomExtremes => (0..n)
            .map(|_| if rng.next_bit() == 0 { -128i8 } else { 127 })
            .collect(),
    }
}

#[test]
fn fuzz_corpus_all_backends_bit_identical_to_golden() {
    let backends = AcsBackend::available();
    for (name, k, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name).unwrap();
        let (block, depth) = (32usize, 6 * *k as usize);
        let per_pb = (block + 2 * depth) * t.r;
        for pattern in PATTERNS {
            // fixed seed per (preset, pattern): the corpus is fully
            // deterministic and a failure names its cell exactly
            let mut rng = Xoshiro256::seeded(
                0xC0DE_F0CC ^ ((t.k as u64) << 32) ^ (pattern as u64),
            );
            let m = OracleMatrix {
                trellis: &t,
                block,
                depth,
                q: 8,
                engines: &SIMD_ONLY,
                widths: &BOTH_WIDTHS,
                backends: &backends,
                batches: &TAIL_BATCHES,
                workers: &[2],
            };
            let label = format!("{name} {pattern:?}");
            if let Err(e) =
                oracle_matrix(&m, &label, |batch| gen_frame(&mut rng, pattern, batch * per_pb))
            {
                panic!("{e}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Tie-break pins.
// ---------------------------------------------------------------------------

/// All-zero LLRs make every branch metric equal, so *every* butterfly
/// in *every* stage is an exact tie: each backend's compare-select
/// must keep the even predecessor (survivor bit 0) everywhere.  A
/// backend whose tie-break leaned the other way (e.g. `b <= a`, or a
/// max-based select) would light up immediately.
#[test]
fn all_zero_frames_tie_every_butterfly_to_the_even_predecessor() {
    fn check_width<M: Metric>() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let (block, depth) = (8usize, 12usize);
        let tt = block + 2 * depth;
        let zeros = vec![0i8; M::LANES * tt * t.r];
        for b in AcsBackend::available() {
            let mut kern = LaneInterleavedAcs::<M>::with_config(&t, block, depth, 8, b);
            kern.forward(&zeros);
            // the survivor ring only retains the traceback window
            for s in depth..tt {
                for st in 0..t.n_states {
                    assert_eq!(
                        kern.decision_mask(s, st),
                        0,
                        "{b:?} u{}: stage {s} state {st} tie must keep the even predecessor",
                        M::BITS
                    );
                }
            }
        }
    }
    check_width::<u32>();
    check_width::<u16>();
}

/// A crafted partial-tie stage: LLRs `[c, -c, c, ...]` make the branch
/// metrics of a codeword and its complement equal (`corr = 0` for
/// codewords with balanced taps), so *some* butterflies tie with two
/// genuinely distinct non-zero inputs while others do not.  The
/// crafted stage is planted at index `depth` — the first stage the
/// survivor ring retains — behind a zero-LLR prefix that keeps the
/// planted lanes' metric columns all-zero (every all-zero stage ties
/// every butterfly), so it lands on the same all-zero metrics a
/// stage-0 plant used to.  Two lanes carry the crafted stage, the rest
/// random noise.  Every backend must (a) produce the identical
/// decision mask for every retained stage/state as the scalar
/// reference, and (b) pick the even predecessor at each planted tie.
#[test]
fn crafted_equal_metric_stage_selects_identically_across_backends() {
    fn check_width<M: Metric>(preset: &str) {
        let t = Trellis::preset(preset).unwrap();
        let (block, depth) = (8usize, 6 * t.k as usize);
        let tt = block + 2 * depth;
        let per_pb = tt * t.r;
        let mut rng = Xoshiro256::seeded(0x7E1_B4EA);
        let mut llr: Vec<i8> = (0..M::LANES * per_pb)
            .map(|_| ((rng.next_below(256) as i32) - 128) as i8)
            .collect();
        // lanes 0/1: zero-LLR prefix for stages 0..depth, then the
        // crafted LLRs [12, -12, 12, ...] at stage `depth`
        for lane in 0..2 {
            for i in 0..depth * t.r {
                llr[lane * per_pb + i] = 0;
            }
            for ri in 0..t.r {
                llr[lane * per_pb + depth * t.r + ri] = if ri % 2 == 0 { 12 } else { -12 };
            }
        }
        // scalar-reference branch metrics of the crafted stage for the
        // planted lanes (their pm columns are all-zero entering it, so
        // a butterfly ties iff its two branch metrics are equal)
        let off = bm_offset(t.r, 8) as i64;
        let bm: Vec<i64> = (0..1usize << t.r)
            .map(|c| {
                let mut acc = 0i64;
                for ri in 0..t.r {
                    let y = if ri % 2 == 0 { 12i64 } else { -12 };
                    let bit = ((c >> (t.r - 1 - ri)) & 1) as i64;
                    acc += y * (2 * bit - 1);
                }
                off + acc
            })
            .collect();
        let half = t.n_states / 2;
        let tied_states: Vec<usize> = (0..half)
            .flat_map(|j| {
                let top = (bm[t.cw_top0[j] as usize] == bm[t.cw_top1[j] as usize])
                    .then_some(j);
                let bot = (bm[t.cw_bot0[j] as usize] == bm[t.cw_bot1[j] as usize])
                    .then_some(j + half);
                top.into_iter().chain(bot)
            })
            .collect();
        assert!(
            !tied_states.is_empty(),
            "{preset}: crafted stage must tie at least one butterfly"
        );
        let mut reference =
            LaneInterleavedAcs::<M>::with_config(&t, block, depth, 8, AcsBackend::Scalar);
        reference.forward(&llr);
        for b in AcsBackend::available() {
            let mut kern = LaneInterleavedAcs::<M>::with_config(&t, block, depth, 8, b);
            kern.forward(&llr);
            // (a) decision-word equality with the scalar reference
            // across the retained traceback window
            for s in depth..tt {
                for st in 0..t.n_states {
                    assert_eq!(
                        kern.decision_mask(s, st),
                        reference.decision_mask(s, st),
                        "{preset} {b:?} u{}: stage {s} state {st} mask diverged from scalar",
                        M::BITS
                    );
                }
            }
            // (b) the planted ties keep the even predecessor in the
            // planted lanes
            for &st in &tied_states {
                let mask = kern.decision_mask(depth, st);
                assert_eq!(
                    mask & 0b11,
                    0,
                    "{preset} {b:?} u{}: planted tie at state {st} must keep the even \
                     predecessor in lanes 0/1 (mask {mask:#x})",
                    M::BITS
                );
            }
        }
    }
    for preset in ["k3", "ccsds_k7"] {
        check_width::<u32>(preset);
        check_width::<u16>(preset);
    }
}
