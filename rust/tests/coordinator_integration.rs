//! End-to-end coordinator tests over the real PJRT engines: framing,
//! lanes, reassembly, engine equivalence, BER through the full stack.
//!
//! Requires `make artifacts` (tests skip politely otherwise).

use pbvd::ber::StreamDecoder;
use pbvd::channel::{AwgnChannel, Quantizer};
use pbvd::coordinator::{
    CpuEngine, FusedEngine, OrigEngine, StreamCoordinator, TwoKernelEngine,
};
use pbvd::encoder::ConvEncoder;
use pbvd::rng::Xoshiro256;
use pbvd::runtime::Registry;
use pbvd::trellis::Trellis;
use std::sync::Arc;

fn registry() -> Option<Registry> {
    if !pbvd::runtime::pjrt_available() {
        eprintln!(
            "SKIP: PJRT runtime unavailable (built against the vendored \
             stub xla crate); see rust/vendor/xla"
        );
        return None;
    }
    match Registry::open_default() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIP: artifacts not built ({e})");
            None
        }
    }
}

fn noisy_stream(t: &Trellis, n: usize, ebn0: f64, seed: u64) -> (Vec<u8>, Vec<i32>) {
    let mut rng = Xoshiro256::seeded(seed);
    let bits: Vec<u8> = (0..n).map(|_| rng.next_bit()).collect();
    let mut enc = ConvEncoder::new(t);
    let coded = enc.encode(&bits);
    let mut ch = AwgnChannel::new(ebn0, 1.0 / t.r as f64, &mut rng);
    let soft = ch.transmit(&coded);
    (bits, Quantizer::new(8).quantize(&soft))
}

#[test]
fn pjrt_two_kernel_stream_decode_recovers_payload() {
    let Some(reg) = registry() else { return };
    let t = Trellis::preset("ccsds_k7").unwrap();
    let eng = TwoKernelEngine::from_registry(&reg, "ccsds_k7", 32, 64, 42).unwrap();
    let coord = StreamCoordinator::new(Arc::new(eng), 2);
    let (bits, llr) = noisy_stream(&t, 10_000, 7.0, 1);
    let (out, stats) = coord.decode_stream(&llr).unwrap();
    assert_eq!(out, bits);
    assert_eq!(stats.n_bits, 10_000);
    assert!(stats.phases.k1.as_nanos() > 0);
    assert!(stats.phases.k2.as_nanos() > 0);
    assert!(stats.phases.h2d_bytes > 0);
}

#[test]
fn pjrt_engines_agree_with_cpu_engine() {
    let Some(reg) = registry() else { return };
    let t = Trellis::preset("ccsds_k7").unwrap();
    let (_, llr) = noisy_stream(&t, 6_000, 3.0, 2);

    let cpu = StreamCoordinator::new(Arc::new(CpuEngine::new(&t, 32, 64, 42)), 1);
    let (want, _) = cpu.decode_stream(&llr).unwrap();

    let two = StreamCoordinator::new(
        Arc::new(TwoKernelEngine::from_registry(&reg, "ccsds_k7", 32, 64, 42).unwrap()),
        2,
    );
    let (got2, _) = two.decode_stream(&llr).unwrap();
    assert_eq!(got2, want, "two-kernel != cpu");

    let fused = StreamCoordinator::new(
        Arc::new(FusedEngine::from_registry(&reg, "ccsds_k7", 32, 64, 42).unwrap()),
        2,
    );
    let (got1, _) = fused.decode_stream(&llr).unwrap();
    assert_eq!(got1, want, "fused != cpu");

    let orig = StreamCoordinator::new(
        Arc::new(OrigEngine::from_registry(&reg, "ccsds_k7", 32, 64, 42).unwrap()),
        2,
    );
    let (got0, _) = orig.decode_stream(&llr).unwrap();
    assert_eq!(got0, want, "orig != cpu");
}

#[test]
fn lane_count_does_not_change_output() {
    let Some(reg) = registry() else { return };
    let t = Trellis::preset("ccsds_k7").unwrap();
    let (_, llr) = noisy_stream(&t, 20_000, 4.0, 3);
    let eng: Arc<dyn pbvd::coordinator::DecodeEngine> =
        Arc::new(TwoKernelEngine::from_registry(&reg, "ccsds_k7", 32, 64, 42).unwrap());
    let base = StreamCoordinator::new(Arc::clone(&eng), 1)
        .decode_stream(&llr)
        .unwrap()
        .0;
    for lanes in [2usize, 3, 4, 8] {
        let out = StreamCoordinator::new(Arc::clone(&eng), lanes)
            .decode_stream(&llr)
            .unwrap()
            .0;
        assert_eq!(out, base, "lanes={lanes}");
    }
}

#[test]
fn orig_moves_more_bytes_than_optimized() {
    // The U1/U2 packing claim (Sec. IV-C): the optimized decoder
    // transfers 4x less input and 32x less output per batch.
    let Some(reg) = registry() else { return };
    let t = Trellis::preset("ccsds_k7").unwrap();
    let (_, llr) = noisy_stream(&t, 4_096, 5.0, 4);
    let two = StreamCoordinator::new(
        Arc::new(TwoKernelEngine::from_registry(&reg, "ccsds_k7", 32, 64, 42).unwrap()),
        1,
    );
    let orig = StreamCoordinator::new(
        Arc::new(OrigEngine::from_registry(&reg, "ccsds_k7", 32, 64, 42).unwrap()),
        1,
    );
    let (_, s2) = two.decode_stream(&llr).unwrap();
    let (_, s0) = orig.decode_stream(&llr).unwrap();
    assert_eq!(s0.phases.h2d_bytes, 4 * s2.phases.h2d_bytes, "U1 = 4x");
    assert_eq!(s0.phases.d2h_bytes, 32 * s2.phases.d2h_bytes, "U2 = 32x");
}

#[test]
fn coordinator_ber_through_pjrt_stack() {
    // The full three-layer stack as a BER-harness decoder at one point.
    let Some(reg) = registry() else { return };
    let t = Trellis::preset("ccsds_k7").unwrap();
    let eng = TwoKernelEngine::from_registry(&reg, "ccsds_k7", 32, 64, 42).unwrap();
    let coord = StreamCoordinator::new(Arc::new(eng), 2);
    let cfg = pbvd::ber::BerConfig {
        bits_per_trial: 2048,
        target_errors: 40,
        max_bits: 60_000,
        threads: 2,
        ..Default::default()
    };
    let p = pbvd::ber::measure_ber(&t, &coord, 4.0, &cfg).unwrap();
    let uncoded = pbvd::ber::uncoded_bpsk_ber(4.0);
    assert!(
        p.ber() < uncoded / 10.0,
        "PJRT-stack BER {} must beat uncoded {uncoded}",
        p.ber()
    );
}

#[test]
fn paper_shape_artifact_runs() {
    // The D=512, L=42 paper-scale artifact decodes a real stream.
    let Some(reg) = registry() else { return };
    let t = Trellis::preset("ccsds_k7").unwrap();
    let Ok(eng) = TwoKernelEngine::from_registry(&reg, "ccsds_k7", 64, 512, 42) else {
        eprintln!("SKIP: paper-shape artifact not built");
        return;
    };
    let coord = StreamCoordinator::new(Arc::new(eng), 2);
    let (bits, llr) = noisy_stream(&t, 64 * 512, 6.0, 5);
    let (out, stats) = coord.decode_stream(&llr).unwrap();
    let errors = out.iter().zip(&bits).filter(|(a, b)| a != b).count();
    assert!(errors <= 2, "errors = {errors}");
    assert_eq!(stats.n_batches, 1);
    let _ = coord.rate();
}
