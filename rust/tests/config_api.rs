//! The unified-construction oracle: every frontend-visible property of
//! `DecoderConfig` — parse/display round trips for every enum, env
//! override precedence, JSON serde, and the engine factory (the only
//! construction path since the 0.3-deprecated shims were removed in
//! 0.4).
//!
//! The satellite regression this suite pins: the pre-config
//! `best_available_coordinator` CPU fallback constructed engines at
//! DEFAULT width/backend/q even when the CLI had passed
//! `--metric-width` / `--simd-backend` / `-q` (main.rs routed around
//! it only for `stream`).  With the unified config the fallback *is*
//! the configured path, so the resolved engine name must record the
//! requested backend and width.

use pbvd::config::{DecoderConfig, EngineKind, PjrtVariant, ALL_ENGINE_KINDS};
use pbvd::coordinator::DecodeEngine;
use pbvd::rng::Xoshiro256;
use pbvd::simd::{AcsBackend, BackendChoice, MetricWidth, ALL_BACKENDS, LANES, LANES_U16};
use pbvd::testutil::gen_noisy_stream;
use pbvd::trellis::Trellis;
use pbvd::viterbi::CpuPbvdDecoder;

// ---------------------------------------------------------------------------
// FromStr / Display round trips (the CLI vocabulary lives in the library).
// ---------------------------------------------------------------------------

#[test]
fn every_enum_round_trips_parse_display_parse() {
    for kind in ALL_ENGINE_KINDS {
        let s = kind.to_string();
        assert_eq!(s.parse::<EngineKind>().unwrap(), kind, "EngineKind {s}");
    }
    for w in [MetricWidth::Auto, MetricWidth::W16, MetricWidth::W32] {
        let s = w.to_string();
        assert_eq!(s.parse::<MetricWidth>().unwrap(), w, "MetricWidth {s}");
    }
    for b in ALL_BACKENDS {
        let s = b.to_string();
        assert_eq!(s.parse::<AcsBackend>().unwrap(), b, "AcsBackend {s}");
        let c = BackendChoice::Forced(b);
        assert_eq!(c.to_string().parse::<BackendChoice>().unwrap(), c);
    }
    assert_eq!(
        "auto".parse::<BackendChoice>().unwrap(),
        BackendChoice::Auto
    );
    // the CLI's historical error cases stay errors
    assert!("".parse::<EngineKind>().is_err());
    assert!("8".parse::<MetricWidth>().is_err());
    assert!("sse2".parse::<BackendChoice>().is_err());
}

#[test]
fn env_override_precedence_is_cli_then_env_then_auto() {
    // auto fields pick up the env
    let r = DecoderConfig::default().resolved_with(Some("portable"), Some("32"));
    assert_eq!(r.backend, BackendChoice::Forced(AcsBackend::Portable));
    assert_eq!(r.width, MetricWidth::W32);
    // an explicit CLI request is never overridden by the env
    let cli = DecoderConfig::default()
        .width(MetricWidth::W16)
        .backend(BackendChoice::Forced(AcsBackend::Scalar));
    let r = cli.resolved_with(Some("portable"), Some("32"));
    assert_eq!(r.width, MetricWidth::W16);
    assert_eq!(r.backend, BackendChoice::Forced(AcsBackend::Scalar));
    // garbage env values fall through to auto, silently (the engine
    // still resolves via detection — same policy as PBVD_SIMD_BACKEND
    // before the config existed)
    let r = DecoderConfig::default().resolved_with(Some("quantum"), Some("8.5"));
    assert_eq!(r.backend, BackendChoice::Auto);
    assert_eq!(r.width, MetricWidth::Auto);
}

// ---------------------------------------------------------------------------
// Serde: config -> JSON -> config -> same engine.
// ---------------------------------------------------------------------------

#[test]
fn serde_round_trip_builds_the_same_engine_for_every_preset() {
    for (name, _, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name).unwrap();
        let cfg = DecoderConfig::new(name)
            .batch(LANES)
            .block(32)
            .depth(20)
            .workers(2)
            .engine(EngineKind::Simd)
            .width(MetricWidth::W32)
            .backend(BackendChoice::Forced(AcsBackend::Scalar));
        let json_text = cfg.to_json().to_string_pretty();
        let back = DecoderConfig::from_json(&pbvd::json::Json::parse(&json_text).unwrap()).unwrap();
        assert_eq!(back, cfg, "{name}: serde round trip");
        let a = cfg.build_engine(&t).unwrap();
        let b = back.build_engine(&t).unwrap();
        assert_eq!(a.name(), b.name(), "{name}: round-tripped config builds same engine");
        // and the auto kind round-trips to the same selection too
        let auto = DecoderConfig::new(name).batch(4).block(32).depth(20).workers(1);
        let back =
            DecoderConfig::from_json(&pbvd::json::Json::parse(&auto.to_json().to_string_pretty()).unwrap())
                .unwrap();
        assert_eq!(
            auto.build_engine(&t).unwrap().name(),
            back.build_engine(&t).unwrap().name(),
            "{name}: auto kind"
        );
    }
}

// ---------------------------------------------------------------------------
// The fallback-respects-the-config regression (satellite bugfix).
// ---------------------------------------------------------------------------

#[test]
fn fallback_engine_records_requested_backend_and_width() {
    // No registry => the coordinator falls back to a CPU engine.  The
    // fallback must carry the requested width/backend (the pre-config
    // best_available_coordinator silently dropped them — the engine
    // would have been named e.g. "simd-cpu:b32w2x8-avx2" regardless of
    // the request).
    let cfg = DecoderConfig::new("ccsds_k7")
        .batch(2 * LANES_U16)
        .block(64)
        .depth(42)
        .workers(2)
        .engine(EngineKind::Auto)
        .width(MetricWidth::W16)
        .backend(BackendChoice::Forced(AcsBackend::Portable));
    let coord = cfg.build_coordinator(None).unwrap();
    let name = coord.engine.name();
    assert!(name.starts_with("simd-cpu:"), "{name}");
    assert!(
        name.contains("x16-"),
        "fallback dropped the requested metric width: {name}"
    );
    assert!(
        name.ends_with("portable"),
        "fallback dropped the requested backend: {name}"
    );
    // and with a sub-lane-group batch the scalar pool carries the q
    // (observable as bit-identical decode of a q=4 stream vs golden)
    let q = 4u32;
    let t = Trellis::preset("ccsds_k7").unwrap();
    let par = DecoderConfig::new("ccsds_k7")
        .batch(4)
        .block(64)
        .depth(42)
        .workers(2)
        .engine(EngineKind::Auto)
        .q(q);
    let coord = par.build_coordinator(None).unwrap();
    assert!(coord.engine.name().starts_with("par-cpu:"), "{}", coord.engine.name());
    let mut rng = Xoshiro256::seeded(0xFA11BAC);
    let bits: Vec<u8> = (0..800).map(|_| rng.next_bit()).collect();
    let mut enc = pbvd::encoder::ConvEncoder::new(&t);
    // clean stream inside the q=4 quantizer range
    let llr: Vec<i32> = enc
        .encode(&bits)
        .iter()
        .map(|&b| if b == 0 { 7 } else { -7 })
        .collect();
    let want = CpuPbvdDecoder::new(&t, 64, 42).decode_stream(&llr);
    let (got, _) = coord.decode_stream(&llr).unwrap();
    assert_eq!(got, want, "q=4 fallback pool diverged from golden");
}

// ---------------------------------------------------------------------------
// The serve section resolves through the same single path.
// ---------------------------------------------------------------------------

#[test]
fn serve_section_round_trips_and_resolves_with_cli_env_default_precedence() {
    use pbvd::config::{EnvOverrides, ServeConfig};
    // serde round trip through text, engine + serve fields together
    let cfg = DecoderConfig::new("ccsds_k7")
        .batch(LANES)
        .workers(2)
        .serve_bind("127.0.0.1:7412")
        .max_streams(5)
        .stream_queue(7)
        .coalesce_window_us(900)
        .stall_timeout_ms(4000);
    let text = cfg.to_json().to_string_pretty();
    let back = DecoderConfig::from_json(&pbvd::json::Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, cfg);
    // CLI > env > default, through the one resolution pass
    let env = EnvOverrides {
        serve_bind: Some("0.0.0.0:9999".into()),
        serve_queue_depth: Some("3".into()),
        ..EnvOverrides::default()
    };
    let r = cfg.resolved_env(&env);
    assert_eq!(r.serve.bind_or_default(), "127.0.0.1:7412"); // CLI wins
    assert_eq!(r.serve.queue_depth_or_default(), 7); // CLI wins
    let r = DecoderConfig::default().resolved_env(&env);
    assert_eq!(r.serve.bind_or_default(), "0.0.0.0:9999"); // env fills unset
    assert_eq!(r.serve.queue_depth_or_default(), 3);
    assert_eq!(
        r.serve.max_streams_or_default(),
        ServeConfig::DEFAULT_MAX_STREAMS
    ); // default fills the rest
}

// ---------------------------------------------------------------------------
// Factory end-to-end smoke: every CPU kind decodes a noisy stream
// identically to the golden model.
// ---------------------------------------------------------------------------

#[test]
fn every_cpu_kind_streams_bit_identically_to_golden() {
    let t = Trellis::preset("ccsds_k7").unwrap();
    let (block, depth) = (64usize, 42usize);
    let (_, llr) = gen_noisy_stream(&t, 4000, 3.5, 0xC0F1);
    let want = CpuPbvdDecoder::new(&t, block, depth).decode_stream(&llr);
    for kind in [EngineKind::Auto, EngineKind::Golden, EngineKind::Par, EngineKind::Simd] {
        let coord = DecoderConfig::new("ccsds_k7")
            .batch(LANES_U16 + 3)
            .block(block)
            .depth(depth)
            .workers(2)
            .lanes(2)
            .engine(kind)
            .build_coordinator(None)
            .unwrap();
        let (got, _) = coord.decode_stream(&llr).unwrap();
        assert_eq!(got, want, "{kind} stream decode diverged from golden");
    }
}

// ---------------------------------------------------------------------------
// Adaptive dispatch: with planning off — or on but with no measured
// history for the shape — `Auto` must pin the historical static
// worker policy bit-for-bit (the empty-history fallback).
// ---------------------------------------------------------------------------

#[test]
fn auto_without_measured_history_pins_the_static_worker_policy() {
    let t = Trellis::preset("k3").unwrap();
    let expect = |batch: usize, workers: usize| {
        if workers == 1 {
            "cpu:"
        } else if batch >= LANES {
            "simd-cpu:"
        } else {
            "par-cpu:"
        }
    };
    for batch in [1usize, 7, LANES, 26] {
        for workers in [1usize, 2, 4] {
            // width pinned to W32 so the names carry no calibration
            // nondeterminism and compare exactly
            let base = DecoderConfig::new("k3")
                .batch(batch)
                .block(32)
                .depth(15)
                .workers(workers)
                .width(MetricWidth::W32);
            let static_name = base.clone().build_engine(&t).unwrap().name();
            assert!(
                static_name.starts_with(expect(batch, workers)),
                "static policy itself moved: B={batch} W={workers} -> {static_name}"
            );
            // planning on, but no history at all: same construction
            let cold = base
                .clone()
                .plan_enabled(true)
                .plan_explore_ppm(0)
                .build_engine(&t)
                .unwrap()
                .name();
            assert_eq!(
                cold, static_name,
                "cold planner must pin the static policy (B={batch} W={workers})"
            );
            // planning on with a history measured on a *different*
            // machine: those rows must not steer this host
            let path = std::env::temp_dir().join(format!(
                "pbvd_cfg_alien_hist_{}_{batch}_{workers}.jsonl",
                std::process::id()
            ));
            let mut text = String::new();
            for _ in 0..4 {
                let mut o = pbvd::plan::Observation {
                    preset: "k3".into(),
                    block: 32,
                    depth: 15,
                    batch,
                    engine: "cpu".into(),
                    width: 0,
                    backend: String::new(),
                    workers,
                    q: 8,
                    mbps: 99_999.0,
                    machine: "alien-arch-c1".into(),
                }
                .to_json()
                .to_string();
                o.push('\n');
                text.push_str(&o);
            }
            std::fs::write(&path, text).unwrap();
            let alien = base
                .clone()
                .plan_enabled(true)
                .plan_explore_ppm(0)
                .perf_history(path.display().to_string())
                .build_engine(&t)
                .unwrap()
                .name();
            let _ = std::fs::remove_file(&path);
            assert_eq!(
                alien, static_name,
                "another machine's history steered this host (B={batch} W={workers})"
            );
        }
    }
}

#[test]
fn pjrt_kinds_error_cleanly_without_artifacts_or_registry() {
    for v in [PjrtVariant::Two, PjrtVariant::Fused, PjrtVariant::Orig] {
        let cfg = DecoderConfig::new("ccsds_k7").engine(EngineKind::Pjrt(v));
        let err = cfg.build_coordinator(None).unwrap_err();
        assert!(format!("{err}").contains("artifacts"), "{err}");
    }
}

#[test]
fn validate_matches_the_cli_contract() {
    // unknown presets fail at coordinator construction with the
    // trellis error, not a panic
    assert!(DecoderConfig::new("k11").build_coordinator(None).is_err());
    // q outside the i8 engines' range is a validation error even for
    // the golden engine (the CLI has always rejected it up front)
    let t = Trellis::preset("k3").unwrap();
    let bad = DecoderConfig::new("k3").engine(EngineKind::Golden).q(12);
    assert!(bad.build_engine(&t).is_err());
    assert!(bad.validate().is_err());
    // checked fallback is NOT a validation error: forcing u16 where
    // the batch cannot fill a 16-lane group must build (and resolve
    // to u32), same as before the config existed
    let small = DecoderConfig::new("k3")
        .batch(LANES)
        .block(32)
        .depth(15)
        .workers(2)
        .engine(EngineKind::Simd)
        .width(MetricWidth::W16);
    assert!(small.validate().is_ok());
    let eng = small.build_engine(&t).unwrap();
    assert!(eng.name().contains("x8-"), "checked fallback to u32: {}", eng.name());
}
