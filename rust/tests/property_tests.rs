//! Property-based tests over the decoder invariants (own driver in
//! `pbvd::testutil` — proptest is unavailable offline).

use pbvd::channel::{pack_bits, pack_llrs, unpack_bits, unpack_llrs};
use pbvd::encoder::ConvEncoder;
use pbvd::testutil::{check, random_bits, random_llrs, PropConfig};
use pbvd::trellis::Trellis;
use pbvd::viterbi::{CpuPbvdDecoder, ForwardResult};

fn cfg(cases: usize) -> PropConfig {
    PropConfig {
        cases,
        base_seed: 0xFACE,
    }
}

#[test]
fn prop_noiseless_roundtrip_any_code_any_geometry() {
    check("noiseless roundtrip", cfg(40), |rng| {
        let presets = pbvd::trellis::PRESETS;
        let (name, k, _) = presets[rng.next_below(presets.len() as u64) as usize];
        let t = Trellis::preset(name).unwrap();
        let block = 16 + 8 * rng.next_below(12) as usize;
        let depth = 5 * (k as usize) + rng.next_below(20) as usize;
        let dec = CpuPbvdDecoder::new(&t, block, depth);
        let n = 100 + rng.next_below(900) as usize;
        let bits = random_bits(rng, n);
        let mut enc = ConvEncoder::new(&t);
        let llr: Vec<i32> = enc
            .encode(&bits)
            .iter()
            .map(|&b| if b == 0 { 16 } else { -16 })
            .collect();
        let out = dec.decode_stream(&llr);
        if out != bits {
            return Err(format!(
                "{name} D={block} L={depth} n={n}: decode mismatch"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_traceback_start_state_invariance() {
    // Invariance holds when a codeword was actually transmitted (the
    // Sec. III-A merge argument is about survivor paths re-converging
    // onto the ML path); pure-noise inputs carry no such guarantee.
    check("start-state invariance", cfg(30), |rng| {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 64, 42);
        let bits = random_bits(rng, dec.total());
        let mut enc = ConvEncoder::new(&t);
        let mut llr: Vec<i32> = enc
            .encode(&bits)
            .iter()
            .map(|&b| if b == 0 { 24 } else { -24 })
            .collect();
        for x in llr.iter_mut() {
            *x += rng.next_below(25) as i32 - 12; // mild channel noise
        }
        let fwd: ForwardResult = dec.forward(&llr);
        let base = dec.traceback(&fwd, 0);
        for _ in 0..4 {
            let s0 = rng.next_below(t.n_states as u64) as usize;
            if dec.traceback(&fwd, s0) != base {
                return Err(format!("start state {s0} changed the decode"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_group_and_state_based_forward_identical() {
    check("grouping equivalence", cfg(30), |rng| {
        let presets = pbvd::trellis::PRESETS;
        let (name, _, _) = presets[rng.next_below(presets.len() as u64) as usize];
        let t = Trellis::preset(name).unwrap();
        let dec = CpuPbvdDecoder::new(&t, 32, 20);
        let llr = random_llrs(rng, dec.total() * t.r, 127);
        let a = dec.forward(&llr);
        let b = dec.forward_statebased(&llr);
        if a.sp != b.sp || a.pm != b.pm {
            return Err(format!("{name}: forward variants diverged"));
        }
        Ok(())
    });
}

#[test]
fn prop_llr_packing_roundtrip() {
    check("U1 packing roundtrip", cfg(60), |rng| {
        let q = [2u32, 3, 4, 5, 6, 8, 10, 16][rng.next_below(8) as usize];
        let m = (1i64 << (q - 1)) - 1;
        let n = 1 + rng.next_below(2000) as usize;
        let vals: Vec<i32> = (0..n)
            .map(|_| (rng.next_below((2 * m + 1) as u64) as i64 - m) as i32)
            .collect();
        let packed = pack_llrs(&vals, q);
        let expect_words = n.div_ceil((32 / q) as usize);
        if packed.len() != expect_words {
            return Err(format!("q={q} n={n}: {} words", packed.len()));
        }
        if unpack_llrs(&packed, q, n) != vals {
            return Err(format!("q={q} n={n}: roundtrip mismatch"));
        }
        Ok(())
    });
}

#[test]
fn prop_bit_packing_roundtrip() {
    check("U2 packing roundtrip", cfg(60), |rng| {
        let n = 1 + rng.next_below(5000) as usize;
        let bits = random_bits(rng, n);
        if unpack_bits(&pack_bits(&bits), n) != bits {
            return Err(format!("n={n}: roundtrip mismatch"));
        }
        Ok(())
    });
}

#[test]
fn prop_stream_framing_independent_of_batch() {
    // Decoding must be invariant to how PBs are grouped into batches.
    check("batch-grouping invariance", cfg(20), |rng| {
        let t = Trellis::preset("k5").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 48, 25);
        let n = 300 + rng.next_below(700) as usize;
        let bits = random_bits(rng, n);
        let mut enc = ConvEncoder::new(&t);
        let mut llr: Vec<i32> = enc
            .encode(&bits)
            .iter()
            .map(|&b| if b == 0 { 16 } else { -16 })
            .collect();
        for x in llr.iter_mut() {
            *x += rng.next_below(9) as i32 - 4;
        }
        let want = dec.decode_stream(&llr);
        use pbvd::coordinator::{CpuEngine, StreamCoordinator};
        use std::sync::Arc;
        for batch in [1usize, 2, 5] {
            let eng = CpuEngine::new(&t, batch, 48, 25);
            let coord = StreamCoordinator::new(Arc::new(eng), 2);
            let (got, _) = coord.decode_stream(&llr).unwrap();
            if got != want {
                return Err(format!("batch={batch}: output changed"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pm_normalization_bounded() {
    // After per-stage rescaling, path metrics stay within a provable
    // bound: max PM spread <= 2L_max * max|BM| over merge length.
    check("PM bounded", cfg(20), |rng| {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 64, 42);
        let llr = random_llrs(rng, dec.total() * t.r, 127);
        let fwd = dec.forward(&llr);
        let max_pm = *fwd.pm.iter().max().unwrap();
        let min_pm = *fwd.pm.iter().min().unwrap();
        if min_pm != 0 {
            return Err(format!("min PM {min_pm} != 0 after normalization"));
        }
        // spread bound: K stages to merge any two states, each stage
        // adds at most 2*R*127
        let bound = (t.k as i64 + 2) * 2 * (t.r as i64) * 127;
        if max_pm > bound {
            return Err(format!("PM spread {max_pm} exceeds bound {bound}"));
        }
        Ok(())
    });
}

#[test]
fn prop_error_correction_beats_hard_threshold() {
    // Flip any 2 coded bits at distance >= K apart: decode still exact
    // (d_free = 10 for the CCSDS code; 2 scattered flips are always
    // correctable).
    check("2-flip correction", cfg(30), |rng| {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 64, 42);
        let tt = dec.total();
        let bits = random_bits(rng, tt);
        let mut enc = ConvEncoder::new(&t);
        let mut llr: Vec<i32> = enc
            .encode(&bits)
            .iter()
            .map(|&b| if b == 0 { 8 } else { -8 })
            .collect();
        let n = llr.len();
        let p1 = rng.next_below((n / 2) as u64) as usize;
        let p2 = p1 + 20 + rng.next_below((n - p1 - 21) as u64) as usize;
        llr[p1] = -llr[p1];
        llr[p2] = -llr[p2];
        let out = dec.decode_block(&llr);
        if out[..] != bits[42..42 + 64] {
            return Err(format!("flips at {p1},{p2} broke the decode"));
        }
        Ok(())
    });
}
