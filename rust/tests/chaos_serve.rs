//! Chaos conformance suite: seeded fault plans over real loopback TCP.
//!
//! Every test here runs the daemon with a deterministic
//! [`FaultPlan`](pbvd::serve::FaultPlan) installed and asserts the
//! robustness contract end to end:
//!
//! * the decoded payload of every stream is **bit-identical** to the
//!   golden `CpuPbvdDecoder` decode of the same LLRs — faults may cost
//!   latency, never correctness;
//! * **exact frame accounting** — no frame is lost and none is applied
//!   twice (bit-identity over known payloads is the oracle: a lost
//!   frame leaves zeroed bits, a duplicate would corrupt a reassembled
//!   block);
//! * recovery is **visible**: resumes / replays / degradations / sheds
//!   show up in [`RecoveryStats`] and the STATS document, and the
//!   degraded engine's name is what STATS reports;
//! * a fault plan never turns into a stall-detector eviction of a
//!   healthy client;
//! * with the shadow auditor armed at full rate, silent output
//!   corruption (`flip_llr` / `corrupt_result`) is **detected**, the
//!   diverging backend is **quarantined** down the engine ladder, and
//!   streams decoded afterwards stay bit-identical.
//!
//! The one-shot latch semantics of `seq=`/`job=`/ordinal rules matter
//! throughout: "kill the connection at result seq 5" must not re-kill
//! the replacement connection when seq 5 is replayed after RESUME.

use pbvd::config::{DecoderConfig, EngineKind, RetryPolicy};
use pbvd::serve::{ClientOptions, PbvdServer, ServeClient, ServeError};
use pbvd::testutil::gen_noisy_stream;
use pbvd::trellis::Trellis;
use pbvd::viterbi::CpuPbvdDecoder;
use std::net::SocketAddr;
use std::time::{Duration, Instant};

const BLOCK: usize = 32;
const DEPTH: usize = 15;

/// A chaos daemon on an OS-assigned port: small geometry, long stall
/// window (fault recovery must never depend on eviction), and the
/// given fault spec.
fn chaos_serve(engine: EngineKind, workers: usize, faults: &str, shed: usize) -> PbvdServer {
    let cfg = DecoderConfig::new("k3")
        .batch(8)
        .block(BLOCK)
        .depth(DEPTH)
        .workers(workers)
        .engine(engine)
        .serve_bind("127.0.0.1:0")
        .stream_queue(16)
        .coalesce_window_us(10_000)
        .stall_timeout_ms(10_000)
        .resume_grace_ms(5_000)
        .shed_queue(shed)
        .faults(faults);
    PbvdServer::bind(&cfg, None).expect("bind chaos daemon")
}

/// A client policy tuned for the chaos tests: short deadlines so a
/// swallowed result is noticed fast, quick capped backoff, and a fixed
/// jitter seed so failures replay.
fn chaos_client(addr: SocketAddr, seed: u64) -> ServeClient {
    ServeClient::connect_opts(
        addr,
        ClientOptions {
            preset: Some("k3".into()),
            retry: RetryPolicy {
                io_timeout_ms: 400,
                max_reconnects: 8,
                base_backoff_ms: 10,
                max_backoff_ms: 80,
                jitter_pct: 20,
            },
            seed,
        },
    )
    .expect("connect chaos client")
}

/// One stream's worth of work: a seeded noisy LLR stream and its
/// golden decode.
fn stream_case(n_bits: usize, seed: u64) -> (Vec<i32>, Vec<u8>) {
    let t = Trellis::preset("k3").unwrap();
    let (_, llr) = gen_noisy_stream(&t, n_bits, 4.0, seed);
    let golden = CpuPbvdDecoder::new(&t, BLOCK, DEPTH).decode_stream(&llr);
    (llr, golden)
}

fn decode_resilient(addr: SocketAddr, llr: &[i32], window: usize, seed: u64) -> Vec<u8> {
    let mut client = chaos_client(addr, seed);
    let out = client.decode_stream(llr, window).expect("decode_stream");
    let _ = client.bye();
    out
}

#[test]
fn killed_connection_resumes_and_finishes_bit_identical() {
    // the daemon shoots this stream's connection in the head exactly
    // once, while writing result seq 5; the client must reconnect,
    // RESUME, collect the replayed results, and finish clean
    let server = chaos_serve(EngineKind::Golden, 1, "kill_conn@seq=5", 0);
    let addr = server.local_addr();
    let (llr, golden) = stream_case(30 * BLOCK + 11, 0x1C11);
    let got = decode_resilient(addr, &llr, 6, 0x5EED_0001);
    assert_eq!(got, golden, "resumed stream diverged from golden");

    let rec = server.recovery();
    assert!(rec.resumes() >= 1, "no RESUME was recorded");
    assert!(rec.parked() >= 1, "the lost stream never parked");
    assert!(rec.replayed() >= 1, "nothing was replayed on resume");
    assert_eq!(server.evictions(), 0, "fault recovery must not evict");
    let plan = server.fault_plan().expect("plan installed");
    assert_eq!(plan.injected(), 1, "kill_conn@seq=5 is one-shot");
}

#[test]
fn dropped_result_write_is_replayed_exactly_once() {
    // result seq 2 is swallowed by the "network" (written never, acked
    // never); the client times out waiting for it, resumes, and the
    // replay buffer re-serves it — exactly once, no duplicates
    let server = chaos_serve(EngineKind::Golden, 1, "drop_write@seq=2", 0);
    let addr = server.local_addr();
    let (llr, golden) = stream_case(24 * BLOCK + 3, 0xD20);
    let got = decode_resilient(addr, &llr, 6, 0x5EED_0002);
    assert_eq!(got, golden, "replayed stream diverged from golden");

    let rec = server.recovery();
    assert!(rec.resumes() >= 1, "the timeout never triggered a resume");
    assert!(rec.replayed() >= 1, "the dropped result was not replayed");
    assert_eq!(server.evictions(), 0);
    let plan = server.fault_plan().expect("plan installed");
    assert!(plan.injected() >= 1, "drop_write@seq=2 never fired");
}

#[test]
fn worker_panic_degrades_the_engine_and_streams_never_notice() {
    // a worker thread panics mid-job, permanently closing the par
    // pool; the supervisor retries, then degrades par -> golden at the
    // same geometry — the client sees only correct results
    let server = chaos_serve(EngineKind::Par, 2, "worker_panic@job=1", 0);
    let addr = server.local_addr();
    assert!(
        server.engine_name().starts_with("par-cpu:"),
        "precondition: daemon starts on the par engine, got {}",
        server.engine_name()
    );
    let (llr, golden) = stream_case(32 * BLOCK + 7, 0xBAD);
    let got = decode_resilient(addr, &llr, 6, 0x5EED_0003);
    assert_eq!(got, golden, "degraded decode diverged from golden");

    let rec = server.recovery();
    assert!(rec.retries() >= 1, "the failed group was never retried");
    assert!(rec.degradations() >= 1, "the engine never degraded");
    assert!(
        server.engine_name().starts_with("cpu:"),
        "STATS must show the replacement engine, got {}",
        server.engine_name()
    );
    assert_eq!(server.evictions(), 0);

    // and the daemon keeps serving new streams on the replacement
    let (llr2, golden2) = stream_case(9 * BLOCK + 1, 0xBAD2);
    assert_eq!(decode_resilient(addr, &llr2, 4, 0x5EED_0004), golden2);
}

#[test]
fn overload_shed_is_typed_and_backoff_completes_the_stream() {
    // shed_queue 2 on a daemon whose groups flush on a 30 ms deadline:
    // a burst past 2 pending frames gets a typed retry_after refusal
    let cfg = DecoderConfig::new("k3")
        .batch(4)
        .block(BLOCK)
        .depth(DEPTH)
        .workers(1)
        .serve_bind("127.0.0.1:0")
        .stream_queue(16)
        .coalesce_window_us(30_000)
        .stall_timeout_ms(10_000)
        .shed_queue(2);
    let server = PbvdServer::bind(&cfg, None).expect("bind shed daemon");
    let addr = server.local_addr();

    // raw burst: the refusal must surface as the typed RetryAfter with
    // a usable hint, scoped to the frame (the session survives)
    let t = Trellis::preset("k3").unwrap();
    let (burst_llr, _) = stream_case(8 * BLOCK, 0x05ED);
    let frames = pbvd::coordinator::frame_stream(&burst_llr, t.r, BLOCK, DEPTH, 1);
    let mut client = chaos_client(addr, 0x5EED_0005);
    for f in &frames {
        client.submit_frame(&f.llr_i8).expect("burst submit");
    }
    let mut shed_hint = None;
    for _ in 0..frames.len() {
        match client.recv_result() {
            Ok(_) => {}
            Err(ServeError::RetryAfter { ms }) => {
                shed_hint = Some(ms);
                break;
            }
            Err(e) => panic!("unexpected error during burst: {e:?}"),
        }
    }
    let hint = shed_hint.expect("burst past shed_queue=2 was never shed");
    assert!(hint >= 25, "retry_after hint too small to be useful: {hint}");
    let _ = client.bye();
    drop(client);
    assert!(server.recovery().shed() >= 1, "shed was not counted");

    // the self-healing decode honors the hint and still finishes
    // bit-identical — shed frames are resubmitted, never lost
    let (llr, golden) = stream_case(14 * BLOCK + 5, 0x05ED2);
    let got = decode_resilient(addr, &llr, 8, 0x5EED_0006);
    assert_eq!(got, golden, "shed-then-resubmit stream diverged");
    assert_eq!(server.evictions(), 0, "shedding must not evict");
}

#[test]
fn acceptance_three_streams_survive_kill_drop_and_panic_together() {
    // the ISSUE acceptance plan: one connection killed mid-stream, one
    // result write dropped, one worker panic — under three concurrent
    // streams.  Everything completes bit-identical, the degradation is
    // visible in STATS, and nothing is evicted.
    let server = chaos_serve(
        EngineKind::Par,
        2,
        "kill_conn@seq=5;worker_panic@job=3;drop_write@seq=2",
        0,
    );
    let addr = server.local_addr();
    let cases: Vec<(Vec<i32>, Vec<u8>)> = [
        (33 * BLOCK + 7, 0xACC1),
        (29 * BLOCK + 1, 0xACC2),
        (36 * BLOCK + 19, 0xACC3),
    ]
    .iter()
    .map(|&(n, seed)| stream_case(n, seed))
    .collect();

    let handles: Vec<_> = cases
        .iter()
        .enumerate()
        .map(|(i, (llr, _))| {
            let llr = llr.clone();
            std::thread::spawn(move || decode_resilient(addr, &llr, 6, 0xACC0 + i as u64))
        })
        .collect();
    for (h, (_, golden)) in handles.into_iter().zip(&cases) {
        let got = h.join().expect("chaos client thread");
        assert_eq!(&got, golden, "a stream diverged under the combined plan");
    }

    let rec = server.recovery();
    assert!(rec.resumes() >= 1, "kill/drop never forced a resume");
    assert!(rec.replayed() >= 1, "no replay happened");
    assert!(rec.degradations() >= 1, "the worker panic never degraded");
    assert!(
        server.engine_name().starts_with("cpu:"),
        "degraded engine must be visible, got {}",
        server.engine_name()
    );
    assert_eq!(server.evictions(), 0, "chaos must not look like a stall");

    // every one-shot clause fired exactly once, and STATS carries the
    // plan, the recovery counters, and the parked gauge
    let plan = server.fault_plan().expect("plan installed");
    assert_eq!(plan.injected(), 3, "each one-shot clause fires once");
    let stats = server.stats_json();
    let faults = stats.get("faults").expect("STATS lacks `faults`");
    assert_eq!(
        faults.get("injected").and_then(pbvd::json::Json::as_usize),
        Some(3),
        "{stats}"
    );
    let recovery = stats.get("recovery").expect("STATS lacks `recovery`");
    assert!(
        recovery
            .get("degradations")
            .and_then(pbvd::json::Json::as_usize)
            .unwrap_or(0)
            >= 1,
        "{stats}"
    );
    assert!(
        stats.get("parked_streams").is_some(),
        "STATS lacks the parked_streams gauge:\n{stats}"
    );
}

#[test]
fn expired_resume_grace_is_a_typed_refusal() {
    // a stream parked past its grace window is retired (uncounted);
    // a late RESUME gets the typed bad_resume refusal, and fresh
    // streams are unaffected
    let cfg = DecoderConfig::new("k3")
        .batch(4)
        .block(BLOCK)
        .depth(DEPTH)
        .workers(1)
        .serve_bind("127.0.0.1:0")
        .stream_queue(8)
        .coalesce_window_us(2_000)
        .stall_timeout_ms(10_000)
        .resume_grace_ms(150)
        .faults("kill_conn@seq=1");
    let server = PbvdServer::bind(&cfg, None).expect("bind grace daemon");
    let addr = server.local_addr();

    // a NON-healing client (one reconnect, so the failed resume
    // surfaces instead of being retried into a different error)
    let mut client = ServeClient::connect_opts(
        addr,
        ClientOptions {
            preset: None,
            retry: RetryPolicy {
                io_timeout_ms: 300,
                max_reconnects: 1,
                base_backoff_ms: 400, // sleeps past the 150 ms grace
                max_backoff_ms: 400,
                jitter_pct: 0,
            },
            seed: 0x5EED_0007,
        },
    )
    .expect("connect");
    let (llr, _) = stream_case(6 * BLOCK, 0x9C);
    let err = client.decode_stream(&llr, 4).expect_err("grace must expire");
    match &err {
        ServeError::Remote { code, .. } => assert_eq!(code, "bad_resume", "{err}"),
        ServeError::BadResume(_) => {}
        other => panic!("expected a typed bad_resume refusal, got {other:?}"),
    }
    assert_eq!(server.evictions(), 0, "grace expiry is not an eviction");

    // the daemon is still healthy for new streams
    let (llr2, golden2) = stream_case(5 * BLOCK + 2, 0x9C2);
    assert_eq!(decode_resilient(addr, &llr2, 4, 0x5EED_0008), golden2);
}

/// A chaos daemon with the shadow auditor at full rate (every decoded
/// block re-checked against the golden decoder) and quarantine armed.
fn audited_serve(engine: EngineKind, workers: usize, faults: &str) -> PbvdServer {
    let mut cfg = DecoderConfig::new("k3")
        .batch(8)
        .block(BLOCK)
        .depth(DEPTH)
        .workers(workers)
        .engine(engine)
        .serve_bind("127.0.0.1:0")
        .stream_queue(16)
        .coalesce_window_us(10_000)
        .stall_timeout_ms(10_000)
        .resume_grace_ms(5_000)
        .audit_ppm(1_000_000)
        .audit_seed(0xA11D)
        .audit_quarantine(true);
    if !faults.is_empty() {
        cfg = cfg.faults(faults);
    }
    PbvdServer::bind(&cfg, None).expect("bind audited daemon")
}

/// Wait until the asynchronous audit queue has drained: the audited
/// counter is non-zero and stable across two consecutive reads.
fn wait_audits_settled(server: &PbvdServer) -> u64 {
    let deadline = Instant::now() + Duration::from_secs(10);
    let integ = server.integrity();
    loop {
        let before = integ.audited();
        std::thread::sleep(Duration::from_millis(120));
        if before > 0 && integ.audited() == before {
            return before;
        }
        assert!(Instant::now() < deadline, "audits never settled");
    }
}

#[test]
fn full_rate_audit_on_clean_streams_has_zero_violations() {
    // faults off, auditor at rate 1.0: every block of every group is
    // re-decoded on the golden CPU decoder — zero violations, no
    // quarantine, and the confidence gauge is live in STATS
    let server = audited_serve(EngineKind::Par, 2, "");
    let addr = server.local_addr();
    assert!(server.audit_enabled(), "STATS must advertise the auditor");

    let cases: Vec<(Vec<i32>, Vec<u8>)> = [(18 * BLOCK + 5, 0xC1EA_u64), (21 * BLOCK + 9, 0xC1EB)]
        .iter()
        .map(|&(n, seed)| stream_case(n, seed))
        .collect();
    let handles: Vec<_> = cases
        .iter()
        .enumerate()
        .map(|(i, (llr, _))| {
            let llr = llr.clone();
            std::thread::spawn(move || decode_resilient(addr, &llr, 6, 0xA0D1 + i as u64))
        })
        .collect();
    for (h, (_, golden)) in handles.into_iter().zip(&cases) {
        let got = h.join().expect("audited client thread");
        assert_eq!(&got, golden, "clean stream diverged under audit");
    }

    let audited = wait_audits_settled(&server);
    let integ = server.integrity();
    assert_eq!(integ.violations(), 0, "false positive on clean traffic");
    assert_eq!(integ.margin_mismatches(), 0, "margin mismatch on clean traffic");
    assert_eq!(integ.quarantines(), 0);
    assert!(server.quarantined().is_empty(), "{:?}", server.quarantined());
    assert!(
        server.engine_name().starts_with("par-cpu:"),
        "clean audit must not degrade the engine, got {}",
        server.engine_name()
    );

    let stats = server.stats_json();
    assert_eq!(
        stats.get("audit_enabled").and_then(pbvd::json::Json::as_bool),
        Some(true),
        "{stats}"
    );
    let shown = stats
        .get("integrity")
        .and_then(|i| i.get("audited"))
        .and_then(pbvd::json::Json::as_usize)
        .unwrap_or(0);
    assert!(shown >= audited as usize, "{stats}");
    // every dispatched group reported per-block margins, so the
    // running-minimum confidence gauge must be set
    assert!(
        stats
            .get("totals")
            .and_then(|t| t.get("min_margin"))
            .and_then(pbvd::json::Json::as_usize)
            .is_some(),
        "min_margin gauge unset:\n{stats}"
    );
}

#[test]
fn flipped_dispatch_is_detected_quarantined_and_survivors_bit_identical() {
    // flip_llr=256 corrupts a COPY of the first dispatched group's
    // LLRs on the par backend; the auditor re-decodes the clean
    // original on the golden decoder, so the divergence is charged to
    // the backend and the backend alone.  The ISSUE acceptance plan:
    // detection, quarantine down the ladder, and bit-identical
    // survivors.
    let server = audited_serve(EngineKind::Par, 2, "flip_llr=256@nth=0");
    let addr = server.local_addr();

    // sacrificial stream: its first group decodes from flipped LLRs,
    // so its payload visibly diverges from golden
    let (llr, golden) = stream_case(20 * BLOCK + 3, 0xF11);
    let got = decode_resilient(addr, &llr, 6, 0x5EED_0011);
    assert_ne!(got, golden, "the flipped group never corrupted the stream");
    let plan = server.fault_plan().expect("plan installed");
    assert_eq!(plan.injected(), 1, "flip_llr@nth=0 is one-shot");

    // detection is asynchronous — wait for the audit thread
    let integ = server.integrity();
    let deadline = Instant::now() + Duration::from_secs(10);
    while integ.violations() == 0 {
        assert!(Instant::now() < deadline, "divergence was never detected");
        std::thread::sleep(Duration::from_millis(20));
    }

    // survivors: concurrent fresh streams complete bit-identical while
    // the quarantine takes effect (the one-shot flip is spent, and the
    // ladder drop happens between groups, never inside one)
    let cases: Vec<(Vec<i32>, Vec<u8>)> = [
        (17 * BLOCK + 1, 0xF12_u64),
        (23 * BLOCK + 9, 0xF13),
        (19 * BLOCK + 5, 0xF14),
    ]
    .iter()
    .map(|&(n, seed)| stream_case(n, seed))
    .collect();
    let handles: Vec<_> = cases
        .iter()
        .enumerate()
        .map(|(i, (llr, _))| {
            let llr = llr.clone();
            std::thread::spawn(move || decode_resilient(addr, &llr, 6, 0x5EED_0012 + i as u64))
        })
        .collect();
    for (h, (_, golden)) in handles.into_iter().zip(&cases) {
        let got = h.join().expect("survivor client thread");
        assert_eq!(&got, golden, "a survivor stream diverged after quarantine");
    }

    // the diverging backend is quarantined: forced down the ladder and
    // excluded from rebuilds, visible in the accessors and STATS
    assert!(integ.quarantines() >= 1, "quarantine was never recorded");
    let q = server.quarantined();
    assert_eq!(q.len(), 1, "quarantined list: {q:?}");
    assert!(q[0].starts_with("par-cpu:"), "wrong backend blamed: {q:?}");
    assert!(
        server.engine_name().starts_with("cpu:"),
        "quarantine must force the golden rung, got {}",
        server.engine_name()
    );
    assert_eq!(server.evictions(), 0, "audit chaos must not evict");

    let stats = server.stats_json();
    let shown = stats
        .get("integrity")
        .and_then(|i| i.get("violations"))
        .and_then(pbvd::json::Json::as_usize)
        .unwrap_or(0);
    assert!(shown >= 1, "{stats}");
    match stats.get("quarantined") {
        Some(pbvd::json::Json::Arr(a)) => assert_eq!(a.len(), 1, "{stats}"),
        other => panic!("STATS lacks the quarantined list: {other:?}"),
    }
}

/// Advisory chaos soak, promoted from the PR6 load soak: sustained
/// concurrent streams under a randomized — but logged, and overridable
/// via `PBVD_CHAOS_SEED` — probabilistic fault plan.  Run with
/// `cargo test -q --test chaos_serve -- --ignored --nocapture`
/// (`PBVD_SOAK_SECS` controls the duration, default 60).
#[test]
#[ignore]
fn chaos_soak_sustained_load_with_randomized_logged_seed() {
    let secs: u64 = std::env::var("PBVD_SOAK_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60);
    let seed: u64 = std::env::var("PBVD_CHAOS_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| {
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos() as u64)
                .unwrap_or(0x5EED)
        });
    println!("chaos soak: seed={seed} (rerun with PBVD_CHAOS_SEED={seed})");
    let spec = format!(
        "seed={seed};delay_read=1ms@p=0.02;delay_write=1ms@p=0.02;\
         drop_write@p=0.003;kill_conn@p=0.003;worker_panic@job=20"
    );
    let server = chaos_serve(EngineKind::Par, 2, &spec, 0);
    let addr = server.local_addr();
    let deadline = Instant::now() + Duration::from_secs(secs);

    let workers: Vec<_> = (0..4u64)
        .map(|w| {
            std::thread::spawn(move || {
                let mut rounds = 0u64;
                while Instant::now() < deadline {
                    let n_bits = (16 + (rounds % 24) as usize) * BLOCK + (rounds % 13) as usize;
                    let (llr, golden) = stream_case(n_bits, 0xC4A0 + 101 * w + rounds);
                    let got = decode_resilient(addr, &llr, 6, 0xC4A0 ^ (w << 32) ^ rounds);
                    assert_eq!(got, golden, "soak worker {w} round {rounds} diverged");
                    rounds += 1;
                }
                rounds
            })
        })
        .collect();
    let total_rounds: u64 = workers.into_iter().map(|h| h.join().unwrap()).sum();
    println!("chaos soak: {total_rounds} stream decodes across 4 workers in {secs} s");
    assert!(total_rounds > 0);
    let rec = server.recovery();
    println!(
        "chaos soak recovery: retries={} degradations={} resumes={} replayed={} engine={}",
        rec.retries(),
        rec.degradations(),
        rec.resumes(),
        rec.replayed(),
        server.engine_name()
    );
    println!("{}", server.stats_json().to_string_pretty());
}
