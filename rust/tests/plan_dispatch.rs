//! The adaptive-dispatch gate: the persistent performance history
//! (rotation, corrupt-line tolerance, concurrent append, round-trip),
//! history-steered `Auto` construction, and the tentpole — live
//! mid-stream engine migration in the serve daemon, which must be
//! completely invisible in the decoded payload.
//!
//! The acceptance oracle for every decode is bit-identity to the
//! golden `CpuPbvdDecoder` stream decode of the same LLRs.

use pbvd::config::{DecoderConfig, EngineKind};
use pbvd::json::Json;
use pbvd::plan::{machine_profile, Observation, PerfHistory};
use pbvd::serve::{PbvdServer, ServeClient};
use pbvd::testutil::gen_noisy_stream;
use pbvd::trellis::Trellis;
use pbvd::viterbi::CpuPbvdDecoder;
use std::path::PathBuf;

const BLOCK: usize = 32;
const DEPTH: usize = 15;
const BATCH: usize = 4;
const WORKERS: usize = 2;

/// A pid-unique scratch path so parallel test binaries never collide.
fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("pbvd_plan_{}_{}.jsonl", tag, std::process::id()))
}

/// One observation at the serve tests' batch shape (k3, B=4, D=32,
/// L=15, 2 workers, q=8) for `machine`.
fn obs(engine: &str, mbps: f64, machine: &str) -> Observation {
    Observation {
        preset: "k3".into(),
        block: BLOCK,
        depth: DEPTH,
        batch: BATCH,
        engine: engine.into(),
        width: 0,
        backend: String::new(),
        workers: WORKERS,
        q: 8,
        mbps,
        machine: machine.into(),
    }
}

// ---------------------------------------------------------------------------
// The history store.
// ---------------------------------------------------------------------------

#[test]
fn history_rows_round_trip_through_the_file() {
    let path = temp_path("roundtrip");
    let _ = std::fs::remove_file(&path);
    let machine = machine_profile();
    {
        let h = PerfHistory::open(Some(&path), 1 << 20);
        assert!(h.is_empty());
        h.append(obs("cpu", 11.5, &machine));
        h.append(obs("par", 42.25, &machine));
    }
    let h = PerfHistory::open(Some(&path), 1 << 20);
    assert_eq!(h.len(), 2, "reloaded history lost rows");
    let rows = h.rows();
    assert_eq!(rows[0], obs("cpu", 11.5, &machine), "field-exact round trip");
    assert_eq!(rows[1], obs("par", 42.25, &machine));
    assert_eq!(h.path(), Some(path.as_path()));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupt_and_truncated_lines_are_skipped_not_fatal() {
    let path = temp_path("corrupt");
    let machine = machine_profile();
    let good1 = obs("cpu", 10.0, &machine).to_json().to_string();
    let good2 = obs("par", 20.0, &machine).to_json().to_string();
    // a half-written tail from a killed process, plain garbage, an
    // object missing required fields, and blank lines — all between
    // two valid rows that must survive
    let text = format!(
        "{good1}\n{{\"preset\": \"k3\", \"blo\nnot json at all\n{{}}\n\n{good2}\n{{\"preset\""
    );
    std::fs::write(&path, text).unwrap();
    let h = PerfHistory::open(Some(&path), 1 << 20);
    assert_eq!(h.len(), 2, "corrupt lines must be skipped, valid ones kept");
    assert_eq!(h.rows()[0].engine, "cpu");
    assert_eq!(h.rows()[1].engine, "par");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn rotation_keeps_the_newest_half_under_the_byte_cap() {
    let path = temp_path("rotate");
    let _ = std::fs::remove_file(&path);
    let machine = machine_profile();
    let cap = 4096u64; // the store's floor cap
    let h = PerfHistory::open(Some(&path), cap);
    let total = 60usize;
    for i in 0..total {
        h.append(obs("par", i as f64 + 1.0, &machine));
    }
    let size = std::fs::metadata(&path).unwrap().len();
    assert!(size <= cap, "file never rotated: {size} B > {cap} B cap");
    let reloaded = PerfHistory::open(Some(&path), cap);
    let rows = reloaded.rows();
    assert!(
        rows.len() < total,
        "rotation dropped nothing ({} rows)",
        rows.len()
    );
    assert!(!rows.is_empty());
    // the newest rows are the ones kept, in order
    assert_eq!(rows.last().unwrap().mbps, total as f64);
    let first = rows[0].mbps;
    for (i, r) in rows.iter().enumerate() {
        assert_eq!(r.mbps, first + i as f64, "rotation reordered rows");
    }
    // the live handle's in-memory view drained to match the file
    assert_eq!(h.len(), rows.len(), "in-memory rows diverged from file");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn concurrent_appends_from_two_handles_stay_line_atomic() {
    let path = temp_path("concurrent");
    let _ = std::fs::remove_file(&path);
    let spawn = |base: f64| {
        let path = path.clone();
        std::thread::spawn(move || {
            // a separate handle per thread, like a bench and a daemon
            // sharing one log file
            let h = PerfHistory::open(Some(&path), 1 << 20);
            let machine = machine_profile();
            for i in 0..50 {
                h.append(obs("par", base + i as f64, &machine));
            }
        })
    };
    let a = spawn(1_000.0);
    let b = spawn(2_000.0);
    a.join().unwrap();
    b.join().unwrap();
    // every line parses: single-write appends interleave at line
    // granularity, never mid-row
    let h = PerfHistory::open(Some(&path), 1 << 20);
    assert_eq!(h.len(), 100, "torn or lost lines under concurrent append");
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------------
// History-steered construction.
// ---------------------------------------------------------------------------

#[test]
fn seeded_history_steers_auto_away_from_the_static_policy() {
    let path = temp_path("steer");
    let _ = std::fs::remove_file(&path);
    let machine = machine_profile();
    {
        let h = PerfHistory::open(Some(&path), 1 << 20);
        for _ in 0..3 {
            h.append(obs("cpu", 50_000.0, &machine));
        }
    }
    let t = Trellis::preset("k3").unwrap();
    let base = DecoderConfig::new("k3")
        .batch(BATCH)
        .block(BLOCK)
        .depth(DEPTH)
        .workers(WORKERS);
    // static policy for B=4 (< one lane group), 2 workers: the scalar pool
    let static_name = base.clone().build_engine(&t).unwrap().name();
    assert!(static_name.starts_with("par-cpu:"), "{static_name}");
    // with planning on, the measured history makes the same request
    // construct the golden engine instead
    let planned = base
        .plan_enabled(true)
        .plan_explore_ppm(0)
        .perf_history(path.display().to_string())
        .build_engine(&t)
        .unwrap()
        .name();
    assert!(
        planned.starts_with("cpu:"),
        "history-favored arm not picked: {planned}"
    );
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------------
// The tentpole: live mid-stream engine migration in the serve daemon.
// ---------------------------------------------------------------------------

#[test]
fn live_migration_mid_stream_is_bit_identical_to_golden() {
    let path = temp_path("migrate");
    let _ = std::fs::remove_file(&path);
    let machine = machine_profile();
    {
        // seed a history that makes the dispatcher's runtime re-pick
        // disagree with the engine the daemon was started on: golden
        // hugely fast, the scalar pool terrible
        let h = PerfHistory::open(Some(&path), 1 << 20);
        for _ in 0..5 {
            h.append(obs("cpu", 50_000.0, &machine));
        }
        for _ in 0..5 {
            h.append(obs("par", 0.5, &machine));
        }
    }
    // start explicitly on the scalar pool, re-evaluate after every
    // group, no explore noise
    let cfg = DecoderConfig::new("k3")
        .batch(BATCH)
        .block(BLOCK)
        .depth(DEPTH)
        .workers(WORKERS)
        .engine(EngineKind::Par)
        .plan_enabled(true)
        .perf_history(path.display().to_string())
        .plan_reeval(1)
        .plan_explore_ppm(0)
        .serve_bind("127.0.0.1:0");
    let server = PbvdServer::bind(&cfg, None).expect("bind test daemon");
    assert!(server.plan_enabled());
    let before = server.engine_name();
    assert!(before.starts_with("par-cpu:"), "{before}");

    let t = Trellis::preset("k3").unwrap();
    let n_bits = 12 * BLOCK + 9; // ragged tail, several dispatch groups
    let (_, llr) = gen_noisy_stream(&t, n_bits, 4.0, 0x9A7E);
    let golden = CpuPbvdDecoder::new(&t, BLOCK, DEPTH).decode_stream(&llr);
    let mut client = ServeClient::connect(server.local_addr()).expect("connect");
    let got = client.decode_stream(&llr, 8).expect("decode_stream");
    assert_eq!(
        got, golden,
        "decode diverged across the live engine migration"
    );

    let stats = server.plan_stats();
    assert!(
        stats.migrations() >= 1,
        "no live migration happened (decisions={})",
        stats.decisions()
    );
    let after = server.engine_name();
    assert!(
        after.starts_with("cpu:"),
        "daemon not on the history-favored golden arm: {after} (was {before})"
    );

    // dispatcher provenance is visible to STATS clients
    let sj = client.stats().expect("stats");
    let plan = sj.get("plan").expect("plan section missing from STATS");
    assert_eq!(plan.get("enabled").and_then(Json::as_bool), Some(true));
    assert!(
        plan.get("migrations").and_then(Json::as_usize).unwrap_or(0) >= 1,
        "STATS plan counters missing the migration:\n{sj}"
    );
    assert!(
        plan.get("history_rows").and_then(Json::as_usize).unwrap_or(0) >= 10,
        "STATS plan provenance lost the seeded history:\n{sj}"
    );
    assert_eq!(
        plan.get("engine").and_then(Json::as_str),
        Some(after.as_str())
    );
    let _ = client.bye();
    let _ = std::fs::remove_file(&path);
}

#[test]
fn serve_without_planning_reports_no_plan_section() {
    let cfg = DecoderConfig::new("k3")
        .batch(BATCH)
        .block(BLOCK)
        .depth(DEPTH)
        .workers(1)
        .serve_bind("127.0.0.1:0");
    let server = PbvdServer::bind(&cfg, None).expect("bind test daemon");
    assert!(!server.plan_enabled());
    assert_eq!(server.plan_stats().migrations(), 0);
    let mut probe = ServeClient::connect(server.local_addr()).expect("connect");
    let sj = probe.stats().expect("stats");
    assert!(
        sj.get("plan").is_none(),
        "planner off must keep STATS shape unchanged:\n{sj}"
    );
    let _ = probe.bye();
}
