//! Cross-component decoder tests: encoder -> channel -> quantizer ->
//! decoders (CPU golden + block VA), plus PBVD truncation behaviour.

use pbvd::channel::{AwgnChannel, Quantizer};
use pbvd::encoder::ConvEncoder;
use pbvd::rng::Xoshiro256;
use pbvd::trellis::Trellis;
use pbvd::viterbi::{BlockViterbiDecoder, CpuPbvdDecoder};

fn pipeline_ber(
    t: &Trellis,
    dec: &CpuPbvdDecoder,
    ebn0_db: f64,
    n_bits: usize,
    seed: u64,
) -> f64 {
    let mut rng = Xoshiro256::seeded(seed);
    let bits: Vec<u8> = (0..n_bits).map(|_| rng.next_bit()).collect();
    let mut enc = ConvEncoder::new(t);
    let coded = enc.encode(&bits);
    let mut ch = AwgnChannel::new(ebn0_db, 1.0 / t.r as f64, &mut rng);
    let soft = ch.transmit(&coded);
    let llr = Quantizer::new(8).quantize(&soft);
    let out = dec.decode_stream(&llr);
    let errors = out.iter().zip(&bits).filter(|(a, b)| a != b).count();
    errors as f64 / n_bits as f64
}

#[test]
fn full_pipeline_error_free_at_high_snr() {
    let t = Trellis::preset("ccsds_k7").unwrap();
    let dec = CpuPbvdDecoder::new(&t, 256, 42);
    let ber = pipeline_ber(&t, &dec, 8.0, 50_000, 1);
    assert_eq!(ber, 0.0, "BER at 8 dB must be zero over 50k bits");
}

#[test]
fn full_pipeline_moderate_snr_corrects_heavily() {
    let t = Trellis::preset("ccsds_k7").unwrap();
    let dec = CpuPbvdDecoder::new(&t, 256, 42);
    let ber = pipeline_ber(&t, &dec, 5.0, 100_000, 2);
    // paper Fig. 4: BER ~ 1e-5..1e-6 around 5 dB for L = 42
    assert!(ber < 1e-3, "BER at 5 dB = {ber}");
}

#[test]
fn short_depth_degrades_ber() {
    // Fig. 4's core claim: small L hurts, L >= 42 ~ saturated.
    let t = Trellis::preset("ccsds_k7").unwrap();
    let d_short = CpuPbvdDecoder::new(&t, 256, 7);
    let d_long = CpuPbvdDecoder::new(&t, 256, 42);
    let ber_short = pipeline_ber(&t, &d_short, 4.0, 120_000, 3);
    let ber_long = pipeline_ber(&t, &d_long, 4.0, 120_000, 3);
    assert!(
        ber_short > ber_long * 3.0,
        "L=7 BER {ber_short} should be far worse than L=42 BER {ber_long}"
    );
}

#[test]
fn pbvd_matches_block_va_on_noisy_mid_blocks() {
    // With sufficient depth, PBVD mid-block decisions should almost
    // always match the full-block VA even under noise.
    let t = Trellis::preset("ccsds_k7").unwrap();
    let dec = CpuPbvdDecoder::new(&t, 64, 42);
    let bva = BlockViterbiDecoder::new(&t);
    let mut rng = Xoshiro256::seeded(4);
    let tt = dec.total();
    let mut disagreements = 0usize;
    let trials = 60;
    for _ in 0..trials {
        let bits: Vec<u8> = (0..tt).map(|_| rng.next_bit()).collect();
        let mut enc = ConvEncoder::new(&t);
        let coded = enc.encode(&bits);
        let mut ch = AwgnChannel::new(4.0, 0.5, &mut rng);
        let soft = ch.transmit(&coded);
        let llr = Quantizer::new(8).quantize(&soft);
        let pbvd = dec.decode_block(&llr);
        let va = bva.decode(&llr);
        disagreements += pbvd
            .iter()
            .zip(&va[42..42 + 64])
            .filter(|(a, b)| a != b)
            .count();
    }
    let rate = disagreements as f64 / (trials * 64) as f64;
    assert!(rate < 0.01, "PBVD/VA disagreement rate {rate}");
}

#[test]
fn quantization_8bit_negligible_vs_float() {
    // 8-bit quantization should almost never change decisions (paper
    // uses q=8 for its headline numbers).
    let t = Trellis::preset("ccsds_k7").unwrap();
    let dec = CpuPbvdDecoder::new(&t, 128, 42);
    let mut rng = Xoshiro256::seeded(5);
    let n = 4096;
    let mut diff = 0usize;
    for _ in 0..10 {
        let bits: Vec<u8> = (0..n).map(|_| rng.next_bit()).collect();
        let mut enc = ConvEncoder::new(&t);
        let coded = enc.encode(&bits);
        let mut ch = AwgnChannel::new(3.0, 0.5, &mut rng);
        let soft = ch.transmit(&coded);
        // "float" reference: 14-bit quantization ~ negligible loss
        let fine = Quantizer::new(14).quantize(&soft);
        let coarse = Quantizer::new(8).quantize(&soft);
        let a = dec.decode_stream(&fine);
        let b = dec.decode_stream(&coarse);
        diff += a.iter().zip(&b).filter(|(x, y)| x != y).count();
    }
    let rate = diff as f64 / (10 * n) as f64;
    assert!(rate < 5e-3, "8-bit vs 14-bit decision difference {rate}");
}

#[test]
fn all_presets_full_pipeline() {
    for (name, k, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name).unwrap();
        let depth = 6 * (*k as usize);
        let dec = CpuPbvdDecoder::new(&t, 96, depth);
        let ber = pipeline_ber(&t, &dec, 7.0, 20_000, 6);
        assert_eq!(ber, 0.0, "{name}: BER at 7 dB over 20k bits");
    }
}

#[test]
fn bsc_hard_decision_decoding() {
    // Hard-decision via +-1 LLRs over a BSC: still corrects errors.
    let t = Trellis::preset("ccsds_k7").unwrap();
    let dec = CpuPbvdDecoder::new(&t, 128, 42);
    let mut rng = Xoshiro256::seeded(7);
    let n = 20_000;
    let bits: Vec<u8> = (0..n).map(|_| rng.next_bit()).collect();
    let mut enc = ConvEncoder::new(&t);
    let coded = enc.encode(&bits);
    let mut ch = pbvd::channel::BscChannel::new(0.02, &mut rng);
    let rx = ch.transmit(&coded);
    let llr: Vec<i32> = rx.iter().map(|&b| if b == 0 { 1 } else { -1 }).collect();
    let out = dec.decode_stream(&llr);
    let errors = out.iter().zip(&bits).filter(|(a, b)| a != b).count();
    let ber = errors as f64 / n as f64;
    assert!(ber < 1e-3, "hard-decision BER at p=0.02: {ber}");
}
