//! End-to-end decode-integrity suite (the `make audit-smoke` target):
//! full-rate shadow audits across the CPU engine matrix with zero
//! false positives, bit-identical path-metric margins, transparent
//! factory wrapping, replayable sampling schedules, and typed input
//! hardening.

use pbvd::audit::{AuditedEngine, InputError, ShadowAuditor};
use pbvd::config::{AuditConfig, DecoderConfig, EngineKind};
use pbvd::coordinator::{CpuEngine, DecodeEngine};
use pbvd::encoder::ConvEncoder;
use pbvd::rng::Xoshiro256;
use pbvd::simd::{AcsBackend, BackendChoice, MetricWidth};
use pbvd::trellis::Trellis;
use pbvd::viterbi::CpuPbvdDecoder;
use std::sync::Arc;

const BATCH: usize = 4;
const BLOCK: usize = 32;
const DEPTH: usize = 15;

fn full_rate() -> AuditConfig {
    AuditConfig {
        sample_ppm: Some(1_000_000),
        seed: Some(11),
        quarantine: Some(false),
        low_margin: Some(0),
    }
}

/// Encoded payloads at strong ±8 LLRs, one codeword per batch slot.
fn clean_batch(t: &Trellis, seed: u64) -> Arc<[i8]> {
    let total = BLOCK + 2 * DEPTH;
    let mut rng = Xoshiro256::seeded(seed);
    let mut buf = vec![0i8; BATCH * total * t.r];
    for b in 0..BATCH {
        let bits: Vec<u8> = (0..total).map(|_| rng.next_bit()).collect();
        let coded = ConvEncoder::new(t).encode(&bits);
        for (dst, &c) in buf[b * total * t.r..].iter_mut().zip(&coded) {
            *dst = if c == 0 { 8 } else { -8 };
        }
    }
    buf.into()
}

/// Deterministic pseudo-noisy batch (same generator as the supervisor
/// suite).
fn noisy_batch(t: &Trellis) -> Arc<[i8]> {
    let total = (BLOCK + 2 * DEPTH) * t.r * BATCH;
    (0..total)
        .map(|i| (((i * 37 + 11) % 31) as i8) - 15)
        .collect::<Vec<_>>()
        .into()
}

fn base_cfg() -> DecoderConfig {
    DecoderConfig::new("k3").batch(BATCH).block(BLOCK).depth(DEPTH)
}

/// Every CPU engine realization decodes the same batch under a
/// full-rate auditor: zero violations (no false positives), margins
/// bit-identical to the golden engine — the audit-mode conformance
/// matrix of the oracle harness.
#[test]
fn full_rate_audit_matrix_has_zero_false_positives() {
    let t = Trellis::preset("k3").unwrap();
    let llr = noisy_batch(&t);
    let (want, want_t) = CpuEngine::new(&t, BATCH, BLOCK, DEPTH)
        .decode_batch(&llr)
        .unwrap();
    let mut cfgs = vec![
        base_cfg().engine(EngineKind::Golden),
        base_cfg().engine(EngineKind::Par).workers(2),
    ];
    for &backend in AcsBackend::available().iter() {
        for width in [MetricWidth::W32, MetricWidth::W16] {
            cfgs.push(
                base_cfg()
                    .engine(EngineKind::Simd)
                    .workers(2)
                    .width(width)
                    .backend(BackendChoice::Forced(backend)),
            );
        }
    }
    for cfg in cfgs {
        let inner = cfg.build_engine(&t).unwrap();
        let name = inner.name();
        let auditor = Arc::new(ShadowAuditor::new(&t, BLOCK, DEPTH, &full_rate()));
        let eng = AuditedEngine::new(inner, Arc::clone(&auditor));
        let (got, timings) = eng.decode_batch_shared(&llr).unwrap();
        assert_eq!(got, want, "{name}: words diverged");
        assert_eq!(
            timings.margins, want_t.margins,
            "{name}: margins must be bit-identical to golden"
        );
        auditor.flush();
        assert_eq!(auditor.stats().audited(), BATCH as u64, "{name}");
        assert_eq!(auditor.stats().violations(), 0, "{name}: false positive");
        assert_eq!(auditor.stats().margin_mismatches(), 0, "{name}");
        assert!(auditor.take_quarantine().is_none(), "{name}");
    }
}

/// The factory wraps the engine only when the audit section is on, and
/// the wrapper is observably transparent: same name, same geometry,
/// same bits.
#[test]
fn factory_gates_and_wraps_transparently() {
    let t = Trellis::preset("k3").unwrap();
    let llr = noisy_batch(&t);
    let base = base_cfg().engine(EngineKind::Par).workers(2);
    let plain = base.clone().build_engine(&t).unwrap();
    let audited = base
        .clone()
        .audit_ppm(1_000_000)
        .audit_quarantine(false)
        .build_engine(&t)
        .unwrap();
    assert_eq!(plain.name(), audited.name(), "wrapper must be invisible");
    assert_eq!(plain.batch(), audited.batch());
    assert_eq!(plain.block(), audited.block());
    assert_eq!(plain.depth(), audited.depth());
    let (a, _) = plain.decode_batch_shared(&llr).unwrap();
    let (b, _) = audited.decode_batch_shared(&llr).unwrap();
    assert_eq!(a, b, "audited decode must be bit-identical");
    // an explicit rate of 0 means auditing off — still decodes clean
    let off = base.clone().audit_ppm(0).build_engine(&t).unwrap();
    let (c, _) = off.decode_batch_shared(&llr).unwrap();
    assert_eq!(c, a);
}

/// Input hardening through the factory-built audited engine: typed
/// errors, not panics, and the engine stays usable afterwards.
#[test]
fn audited_engine_rejects_malformed_inputs_with_typed_errors() {
    let t = Trellis::preset("k3").unwrap();
    let eng = base_cfg()
        .engine(EngineKind::Golden)
        .audit_ppm(1_000_000)
        .audit_quarantine(false)
        .build_engine(&t)
        .unwrap();
    let err = eng.decode_batch(&[0i8; 7]).unwrap_err();
    match err.downcast_ref::<InputError>() {
        Some(InputError::BadGeometry { got: 7, .. }) => {}
        other => panic!("expected BadGeometry, got {other:?}"),
    }
    let frame_len = BATCH * (BLOCK + 2 * DEPTH) * t.r;
    let err = eng.decode_batch(&vec![0i8; frame_len]).unwrap_err();
    match err.downcast_ref::<InputError>() {
        Some(InputError::AllErasure { len }) => assert_eq!(*len, frame_len),
        other => panic!("expected AllErasure, got {other:?}"),
    }
    // a rejected input must not poison the engine
    let llr = noisy_batch(&t);
    let (words, _) = eng.decode_batch_shared(&llr).unwrap();
    assert_eq!(words.len(), BATCH * BLOCK.div_ceil(32));
}

/// The sampling schedule is a pure function of (seed, traffic): same
/// seed, same audited blocks; the calibrated rate actually samples.
#[test]
fn sampled_audit_schedule_is_replayable() {
    let t = Trellis::preset("k3").unwrap();
    let llr = clean_batch(&t, 3);
    let run = |seed: u64| {
        let cfg = AuditConfig {
            sample_ppm: Some(400_000),
            seed: Some(seed),
            quarantine: Some(false),
            low_margin: Some(0),
        };
        let auditor = Arc::new(ShadowAuditor::new(&t, BLOCK, DEPTH, &cfg));
        let eng = AuditedEngine::new(
            Arc::new(CpuEngine::new(&t, BATCH, BLOCK, DEPTH)),
            Arc::clone(&auditor),
        );
        for _ in 0..16 {
            eng.decode_batch_shared(&llr).unwrap();
        }
        auditor.flush();
        auditor.stats().audited()
    };
    let a = run(77);
    assert_eq!(a, run(77), "same seed must replay the same schedule");
    // 64 draws at 40%: expect ~26 audited, accept a generous band
    assert!((5..=60).contains(&(a as usize)), "audited = {a}");
}

/// Margin semantics: an all-erasure block has zero confidence, a clean
/// strong-LLR codeword has strictly positive confidence.
#[test]
fn margins_reflect_decode_confidence() {
    let t = Trellis::preset("k3").unwrap();
    let golden = CpuPbvdDecoder::new(&t, BLOCK, DEPTH);
    let n = (BLOCK + 2 * DEPTH) * t.r;
    let (_, m0) = golden.decode_block_with_margin(&vec![0i32; n]);
    assert_eq!(m0, 0, "all-erasure decode must report zero margin");
    let clean = clean_batch(&t, 5);
    let block0: Vec<i32> = clean[..n].iter().map(|&x| x as i32).collect();
    let (_, m) = golden.decode_block_with_margin(&block0);
    assert!(m > 0, "clean codeword must have positive margin, got {m}");
}
