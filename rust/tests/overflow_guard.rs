//! Overflow guards for the narrow-metric u16 kernel: the saturating
//! arithmetic must be *exact* (never actually saturate) for every
//! admissible preset/quantizer combination, pinned at the adversarial
//! edge of the input domain — full frames of i8's most negative value
//! (-128, which `frame_stream`'s clamp can produce), worst-case
//! alternating ±extremes, and random draws from the extreme set only.
//! Plus unit tests that the spread-bound predicate itself rejects a
//! synthetic code that would overflow, and that the engine's checked
//! fallback lands on u32 for it.
//!
//! All bit-identity checks run through the shared
//! `testutil::oracle_matrix` harness, so they automatically cover
//! **both metric widths and every ACS backend available on the build
//! host** (scalar/portable everywhere, AVX2/NEON per arch).

use pbvd::coordinator::DecodeEngine;
use pbvd::rng::Xoshiro256;
use pbvd::simd::{
    metric_spread_bound, u16_metric_admissible, AcsBackend, BackendChoice, MetricWidth,
    SimdCpuEngine, SimdTuning, LANES_U16,
};
use pbvd::testutil::{check, oracle_matrix, OracleMatrix, PropConfig, BOTH_WIDTHS, SIMD_ONLY};
use pbvd::trellis::Trellis;

const WORKER_LADDER: [usize; 3] = [1, 2, 8];

/// Decode one extreme batch through golden / every width / every
/// available backend and demand bit-identity (the acceptance oracle
/// of the u16 mode), via the shared conformance harness.
fn assert_widths_match_golden(
    t: &Trellis,
    batch: usize,
    block: usize,
    depth: usize,
    q: u32,
    llr: &[i8],
    label: &str,
) {
    let backends = AcsBackend::available();
    let m = OracleMatrix {
        trellis: t,
        block,
        depth,
        q,
        engines: &SIMD_ONLY,
        widths: &BOTH_WIDTHS,
        backends: &backends,
        batches: &[batch],
        workers: &WORKER_LADDER,
    };
    if let Err(e) = oracle_matrix(&m, label, |_| llr.to_vec()) {
        panic!("{e}");
    }
}

#[test]
fn all_minus_128_frames_decode_identically_in_every_width() {
    // Every LLR at the i8 minimum: the largest-magnitude branch
    // metrics every stage, the worst case for metric growth between
    // normalizations.
    for (name, k, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name).unwrap();
        let (batch, block, depth) = (LANES_U16 + 3, 40usize, 6 * *k as usize);
        let llr = vec![-128i8; batch * (block + 2 * depth) * t.r];
        assert_widths_match_golden(&t, batch, block, depth, 8, &llr, "all -128");
    }
}

#[test]
fn alternating_extremes_decode_identically_in_every_width() {
    // Alternating -128 / +127 keeps every stage's correlation at its
    // magnitude ceiling while flipping its sign — maximal spread churn.
    for (name, k, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name).unwrap();
        let (batch, block, depth) = (LANES_U16, 40usize, 6 * *k as usize);
        let llr: Vec<i8> = (0..batch * (block + 2 * depth) * t.r)
            .map(|i| if i % 2 == 0 { -128i8 } else { 127 })
            .collect();
        assert_widths_match_golden(&t, batch, block, depth, 8, &llr, "alternating ±extreme");
    }
}

#[test]
fn prop_random_extreme_llrs_decode_identically_in_every_width() {
    // Random draws restricted to {-128, 127}: the hardest population
    // for the saturation bound, across random geometries.
    let backends = AcsBackend::available();
    let cfg = PropConfig {
        cases: 6,
        base_seed: 0x0F10,
    };
    check("u16 == u32 == golden at i8 extremes", cfg, |rng| {
        let presets = pbvd::trellis::PRESETS;
        let (name, k, _) = presets[rng.next_below(presets.len() as u64) as usize];
        let t = Trellis::preset(name).unwrap();
        let block = 24 + 8 * rng.next_below(4) as usize;
        let depth = 6 * (k as usize) + rng.next_below(8) as usize;
        let batch = 1 + rng.next_below(2 * LANES_U16 as u64 + 3) as usize;
        let per_pb = (block + 2 * depth) * t.r;
        let m = OracleMatrix {
            trellis: &t,
            block,
            depth,
            q: 8,
            engines: &SIMD_ONLY,
            widths: &BOTH_WIDTHS,
            backends: &backends,
            batches: &[batch],
            workers: &[2],
        };
        oracle_matrix(&m, name, |batch| {
            (0..batch * per_pb)
                .map(|_| if rng.next_bit() == 0 { -128i8 } else { 127 })
                .collect()
        })
    });
}

#[test]
fn spread_bound_predicate_accepts_presets_and_rejects_synthetic_overflow() {
    // Every built-in preset is admissible at every i8 quantizer width;
    // the bound shrinks monotonically with q.
    for (name, _, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name).unwrap();
        let mut prev = u64::MAX;
        for q in [8u32, 6, 4, 2] {
            assert!(
                u16_metric_admissible(&t, q),
                "{name} must admit u16 at q={q}"
            );
            let b = metric_spread_bound(t.r, t.k, q);
            assert!(b < prev, "{name}: bound must shrink with q");
            prev = b;
        }
    }
    // The synthetic boundary case: K=16 with R=8 at q=8 lands exactly
    // one past u16::MAX (2 * 16 * 8 * 256 = 65536) — rejected.
    assert_eq!(metric_spread_bound(8, 16, 8), u16::MAX as u64 + 1);
    // One quantizer bit less halves the bound back into range.
    assert!(metric_spread_bound(8, 16, 7) <= u16::MAX as u64);
}

#[test]
fn engine_checked_fallback_rejects_inadmissible_u16_request() {
    // A real (synthetic) K=16, R=8 trellis: forcing u16 must fall back
    // to the u32 kernel, and auto must never pick u16.
    let polys: Vec<u64> = vec![
        0o100003, 0o100005, 0o100011, 0o100021, 0o100041, 0o100101, 0o100201, 0o100401,
    ];
    let t = Trellis::build("k16r8", 16, &polys).unwrap();
    assert!(!u16_metric_admissible(&t, 8));
    for width in [MetricWidth::W16, MetricWidth::Auto] {
        let simd = SimdCpuEngine::with_config(
            &t,
            LANES_U16,
            8,
            4,
            1,
            SimdTuning {
                width,
                q: 8,
                backend: BackendChoice::Auto,
            },
        );
        assert_eq!(simd.metric_bits(), 32, "{width:?} must fall back to u32");
        assert_eq!(simd.lane_width(), 8);
        assert!(simd.name().contains("x8-"), "{}", simd.name());
    }
}

#[test]
fn narrow_quantizer_widens_headroom_and_stays_identical() {
    // q = 4 shrinks the BM offset to R * 8; u16 and u32 engines at
    // q = 4 decode a q=4-range extreme stream identically to golden,
    // through every available backend.
    let t = Trellis::preset("r3_k7").unwrap(); // widest preset (R = 3)
    let (batch, block, depth) = (LANES_U16, 32usize, 42usize);
    let mut rng = Xoshiro256::seeded(0x9471);
    let llr: Vec<i8> = (0..batch * (block + 2 * depth) * t.r)
        .map(|_| if rng.next_bit() == 0 { -8i8 } else { 7 })
        .collect();
    assert_widths_match_golden(&t, batch, block, depth, 4, &llr, "q=4 extremes");
    // the q=4 bound for this code is 16x below the q=8 one
    assert_eq!(
        metric_spread_bound(t.r, t.k, 4) * 16,
        metric_spread_bound(t.r, t.k, 8)
    );
}
