//! Bench: paper Table III — kernel/transfer times and throughput of the
//! original vs optimized decoder across the N_t (batch) ladder, with 1
//! and 3 lanes ("CUDA streams"), plus the sharded CPU butterfly-ACS
//! worker ladder (runs everywhere, no artifacts required).
//!
//!     cargo bench --bench table3
//!     PBVD_BENCH_QUICK=1 cargo bench --bench table3   # fast pass
//!
//! Writes `BENCH_table3.json` (CI uploads it per PR) with a `cpu_par`
//! section — ParCpuEngine throughput per worker count — and, when PJRT
//! artifacts are available, a `pjrt` section mirroring the table.

use pbvd::bench::{ms, Bench, BenchReport, Table};
use pbvd::config::{DecoderConfig, EngineKind, PjrtVariant};
use pbvd::coordinator::{DecodeEngine, StreamCoordinator};
use pbvd::json::Json;
use pbvd::runtime::Registry;
use pbvd::testutil::gen_noisy_stream;
use pbvd::trellis::Trellis;
use std::sync::Arc;

fn bench_cfg() -> Bench {
    if std::env::var("PBVD_BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench::default()
    }
}

struct Row {
    n_t: usize,
    orig_tk: f64,
    orig_sk: f64,
    orig_tp1: f64,
    opt_tk1: f64,
    opt_tk2: f64,
    opt_sk: f64,
    opt_tp1: f64,
    opt_tp3: f64,
}

fn measure(
    eng: Arc<dyn DecodeEngine>,
    llr: &[i32],
    lanes: usize,
    bench: &Bench,
) -> (pbvd::coordinator::StreamStats, f64) {
    let coord = StreamCoordinator::new(eng, lanes);
    let mut last = None;
    let stats = bench.run(|| {
        last = Some(coord.decode_stream(llr).expect("decode").1);
    });
    let s = last.unwrap();
    let tp = s.n_bits as f64 / stats.mean.as_secs_f64() / 1e6;
    (s, tp)
}

/// The sharded CPU backend ladder: the golden single-threaded engine
/// as kernel reference, then the scalar pool (`par-cpu`) and the
/// lane-interleaved pool at both metric widths (`simd-u32`,
/// `simd-u16`) at 1/2/4/8 workers.  Speedup is vs the scalar 1-worker
/// pool: par-N isolates thread scaling, simd-u32-N stacks the
/// lockstep-layout kernel gain on top, simd-u16-N the narrow-metric
/// 16-lane gain on top of that.
fn cpu_par_ladder(report: &mut BenchReport, bench: &Bench) -> anyhow::Result<()> {
    let quick = std::env::var("PBVD_BENCH_QUICK").is_ok();
    let (code, batch, block, depth) = ("ccsds_k7", 32usize, 512usize, 42usize);
    let t = Trellis::preset(code)?;
    let n_bits = batch * block * if quick { 2 } else { 6 };
    let (_, llr) = gen_noisy_stream(&t, n_bits, 4.0, 2016);
    println!(
        "CPU butterfly ladder — {code}, B={batch}, D={block}, L={depth}, \
         {n_bits} bits, lanes=1"
    );
    let mut tab = Table::new(&[
        "engine", "workers", "backend", "wall ms", "T/P Mbps", "speedup", "util %", "surv KiB",
    ]);
    // one config carries the whole ladder; its exact resolved form is
    // recorded in the bench JSON so every number is traceable to the
    // realization (kind/width/backend/q/workers) that produced it.
    // The ladder also records every rung into a performance history,
    // which the plan rung below dispatches from.
    let hist_path = std::env::temp_dir().join(format!(
        "pbvd_table3_history_{}.jsonl",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&hist_path);
    let cfg = DecoderConfig::new(code)
        .batch(batch)
        .block(block)
        .depth(depth)
        .lanes(1)
        .q(8)
        .perf_history(hist_path.display().to_string());
    report.scalar("config", cfg.resolved().to_json());
    let rungs = pbvd::bench::worker_ladder(&cfg, &[1, 2, 4, 8], &llr, bench)?;
    for rung in &rungs {
        tab.row(&[
            rung.engine.to_string(),
            rung.workers.to_string(),
            rung.backend.to_string(),
            format!("{:.2}", ms(rung.wall)),
            format!("{:.2}", rung.tp_mbps),
            format!("x{:.2}", rung.speedup),
            rung.utilization
                .map(|u| format!("{:.0}", 100.0 * u))
                .unwrap_or_else(|| "-".into()),
            if rung.survivor_ring_bytes > 0 {
                format!("{:.1}", rung.survivor_ring_bytes as f64 / 1024.0)
            } else {
                "-".into()
            },
        ]);
        let mut row = Json::obj();
        row.set("engine", Json::from(rung.engine));
        row.set("workers", Json::from(rung.workers));
        row.set("tp_mbps", Json::from(rung.tp_mbps));
        row.set("speedup", Json::from(rung.speedup));
        row.set("metric_bits", Json::from(rung.metric_bits as usize));
        row.set("backend", Json::from(rung.backend));
        row.set("survivor_ring_bytes", Json::from(rung.survivor_ring_bytes as usize));
        row.set("survivor_ring_stages", Json::from(rung.survivor_ring_stages as usize));
        row.set("survivor_total_stages", Json::from(rung.survivor_total_stages as usize));
        report.row("cpu_par", row);
    }
    print!("{}", tab.render());
    println!(
        "(speedup = vs scalar pool-1; simd-u32 rows add the lane-interleaved kernel \
         gain, simd-u16 the 16-lane narrow-metric gain; surv KiB = windowed \
         survivor ring per kernel — D+L of the D+2L walked stages retained)\n"
    );

    // width-ladder single-worker comparison scalars for the CI
    // advisory regression check (tools/check_simd_bench.py)
    let tp_of = |eng: &str| {
        rungs
            .iter()
            .find(|r| r.engine == eng && r.workers == 1)
            .map(|r| r.tp_mbps)
    };
    if let (Some(scalar), Some(simd)) = (tp_of("par-cpu"), tp_of("simd-u32")) {
        report.scalar("scalar_w1_mbps", scalar);
        report.scalar("simd_w1_mbps", simd);
        report.scalar("simd_vs_scalar_w1", simd / scalar);
        if simd < scalar {
            println!(
                "ADVISORY: simd-u32 1-worker T/P ({simd:.2} Mbps) below scalar \
                 par-cpu baseline ({scalar:.2} Mbps)"
            );
        }
        if let Some(simd16) = tp_of("simd-u16") {
            report.scalar("simd16_w1_mbps", simd16);
            report.scalar("simd16_vs_simd32_w1", simd16 / simd);
            if simd16 < simd {
                println!(
                    "ADVISORY: simd-u16 1-worker T/P ({simd16:.2} Mbps) below the \
                     u32 lane-interleaved baseline ({simd:.2} Mbps)"
                );
            }
        }
    }

    // plan rung: EngineKind::Auto with adaptive dispatch enabled,
    // picking from the history the ladder just recorded.  The CI
    // advisory (tools/check_simd_bench.py --plan) checks the auto
    // rung lands at or above the best static rung at the same worker
    // count — the dispatcher should never pick a known-slower arm.
    let plan_workers = 8usize;
    let plan_cfg = cfg
        .clone()
        .plan_enabled(true)
        .plan_explore_ppm(0)
        .engine(EngineKind::Auto)
        .workers(plan_workers);
    let plan_engine = plan_cfg.build_engine(&t)?;
    let plan_name = plan_engine.name();
    let (_, plan_tp) = measure(plan_engine, &llr, 1, bench);
    let dsp = plan_cfg.resolved().plan_dispatcher(None);
    report.scalar("plan_auto_mbps", plan_tp);
    report.scalar("plan_workers", plan_workers);
    report.scalar("plan_engine", plan_name.as_str());
    report.scalar("plan_history_rows", dsp.history().len());
    report.scalar("plan_history_path", hist_path.display().to_string());
    report.scalar("plan_machine", dsp.machine());
    println!(
        "plan rung — auto dispatch from {} history rows ({}): {} at {:.2} Mbps\n",
        dsp.history().len(),
        hist_path.display(),
        plan_name,
        plan_tp
    );

    // the lane-width autotuner's pick for this geometry, logged so the
    // bench JSON records which kernel `--metric-width auto` runs (the
    // calibration decode alone — no pool construction needed), plus
    // the ACS backend the auto request resolves to on this host
    let auto_backend = pbvd::simd::BackendChoice::Auto.resolve();
    let pick = pbvd::simd::autotune_metric_width(&t, batch, block, depth, 8, auto_backend);
    let (pick_bits, pick_lanes) = match pick {
        pbvd::simd::MetricWidth::W16 => (16usize, pbvd::simd::LANES_U16),
        _ => (32usize, pbvd::simd::LANES),
    };
    report.scalar("autotune_pick_bits", pick_bits);
    report.scalar("backend", auto_backend.name());
    println!(
        "lane-width autotune pick for B={batch} D={block}: u{pick_bits} ({pick_lanes} lanes, \
         {} backend)\n",
        auto_backend.name()
    );
    Ok(())
}

/// Shadow-audit overhead: the ladder geometry decoded with the
/// auditor off vs armed at full rate (every block re-decoded on the
/// golden model by the background audit thread).  Emits an `audit`
/// row for `tools/check_simd_bench.py --audit-overhead`, which
/// advises when full-rate auditing costs more than its 5% budget.
fn audit_overhead(report: &mut BenchReport, bench: &Bench) -> anyhow::Result<()> {
    let quick = std::env::var("PBVD_BENCH_QUICK").is_ok();
    let (code, batch, block, depth) = ("ccsds_k7", 32usize, 512usize, 42usize);
    let t = Trellis::preset(code)?;
    let n_bits = batch * block * if quick { 2 } else { 6 };
    let (_, llr) = gen_noisy_stream(&t, n_bits, 4.0, 2016);
    let base = DecoderConfig::new(code)
        .batch(batch)
        .block(block)
        .depth(depth)
        .lanes(1)
        .q(8)
        .workers(4);
    let plain = base.clone().build_engine(&t)?;
    let name = plain.name();
    let (_, off) = measure(plain, &llr, 1, bench);
    let audited = base
        .clone()
        .audit_ppm(1_000_000)
        .audit_quarantine(false)
        .build_engine(&t)?;
    let (_, on) = measure(audited, &llr, 1, bench);
    let mut row = Json::obj();
    row.set("engine", Json::from(name.clone()));
    row.set("off_mbps", Json::from(off));
    row.set("on_mbps", Json::from(on));
    row.set("sample_ppm", Json::from(1_000_000usize));
    report.row("audit", row);
    println!(
        "shadow-audit overhead — {name}: {off:.2} Mbps off -> {on:.2} Mbps \
         at full rate ({:+.1}%)\n",
        (off - on) / off * 100.0
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let bench = bench_cfg();
    let mut report = BenchReport::new("table3");
    report.scalar("quick", std::env::var("PBVD_BENCH_QUICK").is_ok());

    // ---- CPU worker-scaling ladder (always runs) ------------------------
    cpu_par_ladder(&mut report, &bench)?;

    // ---- shadow-audit overhead (always runs) ----------------------------
    audit_overhead(&mut report, &bench)?;

    // ---- PJRT Table III (needs artifacts + real xla bindings) -----------
    if !pbvd::runtime::pjrt_available() {
        eprintln!("SKIP table3 PJRT section: PJRT runtime unavailable (stub xla build)");
        let path = report.write()?;
        println!("wrote {}", path.display());
        return Ok(());
    }
    let reg = match Registry::open_default() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("SKIP table3 PJRT section: {e}");
            let path = report.write()?;
            println!("wrote {}", path.display());
            return Ok(());
        }
    };
    let (code, block, depth) = ("ccsds_k7", 512usize, 42usize);
    let t = Trellis::preset(code)?;
    let batches: Vec<usize> = {
        let mut b: Vec<usize> = reg
            .manifest
            .entries
            .iter()
            .filter(|e| e.variant == "forward" && e.code == code
                        && e.block == block && e.depth == depth)
            .map(|e| e.batch)
            .collect();
        b.sort_unstable();
        b
    };
    println!("Table III bench — {code}, D={block}, L={depth}, CPU-PJRT");
    let mut rows = Vec::new();
    let base = DecoderConfig::new(code).block(block).depth(depth);
    for &n_t in &batches {
        // 2 batches worth of stream so lanes can overlap
        let n_bits = 2 * n_t * block;
        let (_, llr) = gen_noisy_stream(&t, n_bits, 4.0, 2016);

        let orig = base
            .clone()
            .batch(n_t)
            .engine(EngineKind::Pjrt(PjrtVariant::Orig))
            .build_engine_with(&t, Some(&reg))?;
        let (so, orig_tp1) = measure(Arc::clone(&orig), &llr, 1, &bench);

        let two = base
            .clone()
            .batch(n_t)
            .engine(EngineKind::Pjrt(PjrtVariant::Two))
            .build_engine_with(&t, Some(&reg))?;
        let (s2, opt_tp1) = measure(Arc::clone(&two), &llr, 1, &bench);
        let (_, opt_tp3) = measure(Arc::clone(&two), &llr, 3, &bench);

        let nb = so.n_batches as u32;
        rows.push(Row {
            n_t,
            orig_tk: ms((so.phases.k1 + so.phases.k2) / nb),
            orig_sk: so.kernel_throughput_mbps(),
            orig_tp1,
            opt_tk1: ms(s2.phases.k1 / nb),
            opt_tk2: ms(s2.phases.k2 / nb),
            opt_sk: s2.kernel_throughput_mbps(),
            opt_tp1,
            opt_tp3,
        });
    }
    let mut tab = Table::new(&[
        "N_t", "orig T_k ms", "orig S_k", "orig T/P(1S)",
        "opt T_k1 ms", "opt T_k2 ms", "opt S_k", "opt T/P(1S)", "opt T/P(3S)",
    ]);
    for r in &rows {
        tab.row(&[
            r.n_t.to_string(),
            format!("{:.2}", r.orig_tk), format!("{:.2}", r.orig_sk),
            format!("{:.2}", r.orig_tp1),
            format!("{:.2}", r.opt_tk1), format!("{:.2}", r.opt_tk2),
            format!("{:.2}", r.opt_sk), format!("{:.2}", r.opt_tp1),
            format!("{:.2}", r.opt_tp3),
        ]);
        let mut jrow = Json::obj();
        jrow.set("n_t", Json::from(r.n_t));
        jrow.set("orig_sk_mbps", Json::from(r.orig_sk));
        jrow.set("opt_sk_mbps", Json::from(r.opt_sk));
        jrow.set("opt_tp1_mbps", Json::from(r.opt_tp1));
        jrow.set("opt_tp3_mbps", Json::from(r.opt_tp3));
        report.row("pjrt", jrow);
    }
    print!("{}", tab.render());

    // Shape summaries (the paper's qualitative claims).
    for r in &rows {
        let orig_total = r.orig_tk;
        let opt_total = r.opt_tk1 + r.opt_tk2;
        println!(
            "N_t={}: optimized kernel time {:.1}% of original; T/P(3S)/T/P(1S) = x{:.2}",
            r.n_t,
            100.0 * opt_total / orig_total,
            r.opt_tp3 / r.opt_tp1
        );
    }
    let path = report.write()?;
    println!("wrote {}", path.display());
    Ok(())
}
