//! Bench: CPU golden-model kernel throughput — forward ACS and
//! traceback per code, the L3-side floor for the perf pass (§Perf) —
//! plus the scalar-vs-lane-interleaved kernel comparison.
//!
//!     cargo bench --bench cpu_kernels
//!
//! Writes `BENCH_cpu_kernels.json` with a `simd` section (scalar vs
//! lane-interleaved Mbps per code); CI's advisory check reads it to
//! flag a SIMD-path regression below the scalar baseline.

use pbvd::bench::{ms, Bench, BenchReport, Table};
use pbvd::json::Json;
use pbvd::rng::Xoshiro256;
use pbvd::simd::{LaneInterleavedAcs, LANES};
use pbvd::testutil::random_llrs;
use pbvd::trellis::Trellis;
use pbvd::viterbi::CpuPbvdDecoder;

fn main() -> anyhow::Result<()> {
    let bench = if std::env::var("PBVD_BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench::default()
    };
    let mut report = BenchReport::new("cpu_kernels");
    report.scalar("quick", std::env::var("PBVD_BENCH_QUICK").is_ok());
    report.scalar("lanes", LANES);
    println!("CPU kernel bench — forward ACS + traceback per parallel block\n");
    let mut tab = Table::new(&[
        "code", "N", "T stages", "fwd ms", "tb ms", "fwd Mbit/s", "stages/us",
    ]);
    for (name, k, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name)?;
        let (block, depth) = (512usize, 6 * *k as usize);
        let dec = CpuPbvdDecoder::new(&t, block, depth);
        let mut rng = Xoshiro256::seeded(17);
        let llr = random_llrs(&mut rng, dec.total() * t.r, 127);
        let s_fwd = bench.run(|| {
            let _ = dec.forward(&llr);
        });
        let fwd = dec.forward(&llr);
        let s_tb = bench.run(|| {
            let _ = dec.traceback(&fwd, 0);
        });
        let stages_per_us =
            dec.total() as f64 / (s_fwd.mean.as_secs_f64() * 1e6);
        tab.row(&[
            name.to_string(),
            t.n_states.to_string(),
            dec.total().to_string(),
            format!("{:.3}", ms(s_fwd.mean)),
            format!("{:.4}", ms(s_tb.mean)),
            format!("{:.2}", block as f64 / s_fwd.mean.as_secs_f64() / 1e6),
            format!("{stages_per_us:.1}"),
        ]);
    }
    print!("{}", tab.render());
    println!("\n(per-PB single-thread numbers; the coordinator parallelizes across PBs.)");

    // ---- butterfly-ACS kernel vs reference forward ----------------------
    println!("\nButterfly-ACS kernel (par.rs: u32 metrics, half BM table, u64 decisions)\n");
    let mut tab = Table::new(&["code", "ref fwd ms", "bfly fwd ms", "speedup"]);
    for (name, k, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name)?;
        let (block, depth) = (512usize, 6 * *k as usize);
        let dec = CpuPbvdDecoder::new(&t, block, depth);
        let mut kern = pbvd::par::ButterflyAcs::new(&t, block, depth);
        let mut rng = Xoshiro256::seeded(18);
        let llr = random_llrs(&mut rng, dec.total() * t.r, 127);
        let llr8: Vec<i8> = llr.iter().map(|&x| x as i8).collect();
        let s_ref = bench.run(|| {
            let _ = dec.forward(&llr);
        });
        let mut bits = vec![0u8; block];
        let s_bf = bench.run(|| {
            kern.decode_block_into(&llr8, &mut bits);
        });
        tab.row(&[
            name.to_string(),
            format!("{:.3}", ms(s_ref.mean)),
            format!("{:.3}", ms(s_bf.mean)),
            format!("x{:.2}", s_ref.mean.as_secs_f64() / s_bf.mean.as_secs_f64()),
        ]);
    }
    print!("{}", tab.render());
    println!("\n(butterfly time includes traceback; ref time is forward only.)");

    // ---- lane-interleaved SIMD kernel vs scalar butterfly ---------------
    println!(
        "\nLane-interleaved ACS (simd.rs: [state][lane] SoA, {LANES} u32 lanes, \
         lane-mask decisions)\n"
    );
    let mut tab = Table::new(&[
        "code", "N", "backend", "scalar ms/PB", "simd ms/PB", "scalar Mbps", "simd Mbps",
        "speedup",
    ]);
    for (name, k, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name)?;
        let (block, depth) = (512usize, 6 * *k as usize);
        let mut scalar = pbvd::par::ButterflyAcs::new(&t, block, depth);
        let mut simd = LaneInterleavedAcs::new(&t, block, depth);
        let per_pb = scalar.total() * t.r;
        let mut rng = Xoshiro256::seeded(19);
        let llr8: Vec<i8> = random_llrs(&mut rng, LANES * per_pb, 127)
            .iter()
            .map(|&x| x as i8)
            .collect();
        // scalar: one PB at a time over the same LANES blocks
        let mut bits = vec![0u8; block];
        let s_scalar = bench.run(|| {
            for lane in 0..LANES {
                scalar.decode_block_into(&llr8[lane * per_pb..(lane + 1) * per_pb], &mut bits);
            }
        });
        // interleaved: all LANES blocks in lockstep
        let mut group_bits = vec![0u8; LANES * block];
        let s_simd = bench.run(|| {
            simd.decode_group_into(&llr8, &mut group_bits);
        });
        let per_pb_scalar = s_scalar.mean / LANES as u32;
        let per_pb_simd = s_simd.mean / LANES as u32;
        let scalar_mbps = block as f64 / per_pb_scalar.as_secs_f64() / 1e6;
        let simd_mbps = block as f64 / per_pb_simd.as_secs_f64() / 1e6;
        let speedup = s_scalar.mean.as_secs_f64() / s_simd.mean.as_secs_f64();
        tab.row(&[
            name.to_string(),
            t.n_states.to_string(),
            simd.backend().to_string(),
            format!("{:.3}", ms(per_pb_scalar)),
            format!("{:.3}", ms(per_pb_simd)),
            format!("{scalar_mbps:.2}"),
            format!("{simd_mbps:.2}"),
            format!("x{speedup:.2}"),
        ]);
        let mut row = Json::obj();
        row.set("code", Json::from(*name));
        row.set("n_states", Json::from(t.n_states));
        row.set("backend", Json::from(simd.backend()));
        row.set("scalar_mbps", Json::from(scalar_mbps));
        row.set("simd_mbps", Json::from(simd_mbps));
        row.set("speedup", Json::from(speedup));
        report.row("simd", row);
    }
    print!("{}", tab.render());
    println!(
        "\n(both decode the same {LANES} PBs, forward + traceback; speedup is the \
         lockstep-layout gain on one core.)"
    );
    let path = report.write()?;
    println!("wrote {}", path.display());
    Ok(())
}
