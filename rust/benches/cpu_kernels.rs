//! Bench: CPU golden-model kernel throughput — forward ACS and
//! traceback per code, the L3-side floor for the perf pass (§Perf).
//!
//!     cargo bench --bench cpu_kernels

use pbvd::bench::{ms, Bench, Table};
use pbvd::rng::Xoshiro256;
use pbvd::testutil::random_llrs;
use pbvd::trellis::Trellis;
use pbvd::viterbi::CpuPbvdDecoder;

fn main() -> anyhow::Result<()> {
    let bench = if std::env::var("PBVD_BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench::default()
    };
    println!("CPU kernel bench — forward ACS + traceback per parallel block\n");
    let mut tab = Table::new(&[
        "code", "N", "T stages", "fwd ms", "tb ms", "fwd Mbit/s", "stages/us",
    ]);
    for (name, k, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name)?;
        let (block, depth) = (512usize, 6 * *k as usize);
        let dec = CpuPbvdDecoder::new(&t, block, depth);
        let mut rng = Xoshiro256::seeded(17);
        let llr = random_llrs(&mut rng, dec.total() * t.r, 127);
        let s_fwd = bench.run(|| {
            let _ = dec.forward(&llr);
        });
        let fwd = dec.forward(&llr);
        let s_tb = bench.run(|| {
            let _ = dec.traceback(&fwd, 0);
        });
        let stages_per_us =
            dec.total() as f64 / (s_fwd.mean.as_secs_f64() * 1e6);
        tab.row(&[
            name.to_string(),
            t.n_states.to_string(),
            dec.total().to_string(),
            format!("{:.3}", ms(s_fwd.mean)),
            format!("{:.4}", ms(s_tb.mean)),
            format!("{:.2}", block as f64 / s_fwd.mean.as_secs_f64() / 1e6),
            format!("{stages_per_us:.1}"),
        ]);
    }
    print!("{}", tab.render());
    println!("\n(per-PB single-thread numbers; the coordinator parallelizes across PBs.)");

    // ---- butterfly-ACS kernel vs reference forward ----------------------
    println!("\nButterfly-ACS kernel (par.rs: u32 metrics, half BM table, u64 decisions)\n");
    let mut tab = Table::new(&["code", "ref fwd ms", "bfly fwd ms", "speedup"]);
    for (name, k, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name)?;
        let (block, depth) = (512usize, 6 * *k as usize);
        let dec = CpuPbvdDecoder::new(&t, block, depth);
        let mut kern = pbvd::par::ButterflyAcs::new(&t, block, depth);
        let mut rng = Xoshiro256::seeded(18);
        let llr = random_llrs(&mut rng, dec.total() * t.r, 127);
        let llr8: Vec<i8> = llr.iter().map(|&x| x as i8).collect();
        let s_ref = bench.run(|| {
            let _ = dec.forward(&llr);
        });
        let mut bits = vec![0u8; block];
        let s_bf = bench.run(|| {
            kern.decode_block_into(&llr8, &mut bits);
        });
        tab.row(&[
            name.to_string(),
            format!("{:.3}", ms(s_ref.mean)),
            format!("{:.3}", ms(s_bf.mean)),
            format!("x{:.2}", s_ref.mean.as_secs_f64() / s_bf.mean.as_secs_f64()),
        ]);
    }
    print!("{}", tab.render());
    println!("\n(butterfly time includes traceback; ref time is forward only.)");
    Ok(())
}
