//! Bench: CPU golden-model kernel throughput — forward ACS and
//! traceback per code, the L3-side floor for the perf pass (§Perf) —
//! plus the scalar-vs-lane-interleaved kernel comparison.
//!
//!     cargo bench --bench cpu_kernels
//!
//! Writes `BENCH_cpu_kernels.json` with a `simd` section (scalar vs
//! u32 vs u16 lane-interleaved Mbps per code, plus each kernel's
//! windowed survivor-ring footprint vs the pre-ring full buffer), a
//! `backends` section (every ACS backend available on this host, per
//! width) and a `split_pool` section (the default ACS/traceback
//! pipelined pool vs a fused forward+traceback pool, with per-phase
//! busy attribution); CI's advisory check reads them to flag the SIMD
//! path regressing below the scalar baseline, the u16 kernel
//! regressing below u32, or the survivor ring losing its window, and
//! to report which backend the numbers came from.

use pbvd::bench::{ms, Bench, BenchReport, Table};
use pbvd::json::Json;
use pbvd::rng::Xoshiro256;
use pbvd::simd::{LaneInterleavedAcs, LANES, LANES_U16};
use pbvd::testutil::random_llrs;
use pbvd::trellis::Trellis;
use pbvd::viterbi::CpuPbvdDecoder;

fn main() -> anyhow::Result<()> {
    let bench = if std::env::var("PBVD_BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench::default()
    };
    let mut report = BenchReport::new("cpu_kernels");
    report.scalar("quick", std::env::var("PBVD_BENCH_QUICK").is_ok());
    report.scalar("lanes", LANES);
    report.scalar("lanes_u16", LANES_U16);
    println!("CPU kernel bench — forward ACS + traceback per parallel block\n");
    let mut tab = Table::new(&[
        "code", "N", "T stages", "fwd ms", "tb ms", "fwd Mbit/s", "stages/us",
    ]);
    for (name, k, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name)?;
        let (block, depth) = (512usize, 6 * *k as usize);
        let dec = CpuPbvdDecoder::new(&t, block, depth);
        let mut rng = Xoshiro256::seeded(17);
        let llr = random_llrs(&mut rng, dec.total() * t.r, 127);
        let s_fwd = bench.run(|| {
            let _ = dec.forward(&llr);
        });
        let fwd = dec.forward(&llr);
        let s_tb = bench.run(|| {
            let _ = dec.traceback(&fwd, 0);
        });
        let stages_per_us =
            dec.total() as f64 / (s_fwd.mean.as_secs_f64() * 1e6);
        tab.row(&[
            name.to_string(),
            t.n_states.to_string(),
            dec.total().to_string(),
            format!("{:.3}", ms(s_fwd.mean)),
            format!("{:.4}", ms(s_tb.mean)),
            format!("{:.2}", block as f64 / s_fwd.mean.as_secs_f64() / 1e6),
            format!("{stages_per_us:.1}"),
        ]);
    }
    print!("{}", tab.render());
    println!("\n(per-PB single-thread numbers; the coordinator parallelizes across PBs.)");

    // ---- butterfly-ACS kernel vs reference forward ----------------------
    println!("\nButterfly-ACS kernel (par.rs: u32 metrics, half BM table, u64 decisions)\n");
    let mut tab = Table::new(&["code", "ref fwd ms", "bfly fwd ms", "speedup"]);
    for (name, k, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name)?;
        let (block, depth) = (512usize, 6 * *k as usize);
        let dec = CpuPbvdDecoder::new(&t, block, depth);
        let mut kern = pbvd::par::ButterflyAcs::new(&t, block, depth);
        let mut rng = Xoshiro256::seeded(18);
        let llr = random_llrs(&mut rng, dec.total() * t.r, 127);
        let llr8: Vec<i8> = llr.iter().map(|&x| x as i8).collect();
        let s_ref = bench.run(|| {
            let _ = dec.forward(&llr);
        });
        let mut bits = vec![0u8; block];
        let s_bf = bench.run(|| {
            kern.decode_block_into(&llr8, &mut bits);
        });
        tab.row(&[
            name.to_string(),
            format!("{:.3}", ms(s_ref.mean)),
            format!("{:.3}", ms(s_bf.mean)),
            format!("x{:.2}", s_ref.mean.as_secs_f64() / s_bf.mean.as_secs_f64()),
        ]);
    }
    print!("{}", tab.render());
    println!("\n(butterfly time includes traceback; ref time is forward only.)");

    // ---- lane-interleaved SIMD kernel vs scalar butterfly ---------------
    // Three kernels over the SAME 16 PBs: the scalar butterfly one PB
    // at a time, the u32 kernel two 8-lane groups, the u16 kernel one
    // 16-lane group (2x ACS lanes per 256-bit vector, saturating adds).
    println!(
        "\nLane-interleaved ACS (simd.rs: [state][lane] SoA, {LANES} u32 or \
         {LANES_U16} u16 lanes, lane-mask decisions)\n"
    );
    let mut tab = Table::new(&[
        "code", "N", "backend", "scalar ms/PB", "u32 ms/PB", "u16 ms/PB", "scalar Mbps",
        "u32 Mbps", "u16 Mbps", "u16/u32",
    ]);
    for (name, k, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name)?;
        let (block, depth) = (512usize, 6 * *k as usize);
        let mut scalar = pbvd::par::ButterflyAcs::new(&t, block, depth);
        let mut simd32 = LaneInterleavedAcs::<u32>::new(&t, block, depth);
        let mut simd16 = LaneInterleavedAcs::<u16>::new(&t, block, depth);
        let per_pb = scalar.total() * t.r;
        let mut rng = Xoshiro256::seeded(19);
        let llr8: Vec<i8> = random_llrs(&mut rng, LANES_U16 * per_pb, 127)
            .iter()
            .map(|&x| x as i8)
            .collect();
        // scalar: one PB at a time over the same 16 blocks
        let mut bits = vec![0u8; block];
        let s_scalar = bench.run(|| {
            for lane in 0..LANES_U16 {
                scalar.decode_block_into(&llr8[lane * per_pb..(lane + 1) * per_pb], &mut bits);
            }
        });
        // u32 interleaved: the 16 blocks as two 8-lane lockstep groups
        let mut group_bits32 = vec![0u8; LANES * block];
        let s_simd32 = bench.run(|| {
            for g in 0..LANES_U16 / LANES {
                simd32.decode_group_into(
                    &llr8[g * LANES * per_pb..(g + 1) * LANES * per_pb],
                    &mut group_bits32,
                );
            }
        });
        // u16 interleaved: all 16 blocks in one lockstep group
        let mut group_bits16 = vec![0u8; LANES_U16 * block];
        let s_simd16 = bench.run(|| {
            simd16.decode_group_into(&llr8, &mut group_bits16);
        });
        let per_pb_scalar = s_scalar.mean / LANES_U16 as u32;
        let per_pb_32 = s_simd32.mean / LANES_U16 as u32;
        let per_pb_16 = s_simd16.mean / LANES_U16 as u32;
        let scalar_mbps = block as f64 / per_pb_scalar.as_secs_f64() / 1e6;
        let simd_mbps = block as f64 / per_pb_32.as_secs_f64() / 1e6;
        let simd16_mbps = block as f64 / per_pb_16.as_secs_f64() / 1e6;
        tab.row(&[
            name.to_string(),
            t.n_states.to_string(),
            simd32.backend().to_string(),
            format!("{:.3}", ms(per_pb_scalar)),
            format!("{:.3}", ms(per_pb_32)),
            format!("{:.3}", ms(per_pb_16)),
            format!("{scalar_mbps:.2}"),
            format!("{simd_mbps:.2}"),
            format!("{simd16_mbps:.2}"),
            format!("x{:.2}", simd16_mbps / simd_mbps),
        ]);
        let mut row = Json::obj();
        row.set("code", Json::from(*name));
        row.set("n_states", Json::from(t.n_states));
        row.set("backend", Json::from(simd32.backend()));
        row.set("scalar_mbps", Json::from(scalar_mbps));
        row.set("simd_mbps", Json::from(simd_mbps));
        row.set("simd16_mbps", Json::from(simd16_mbps));
        row.set("lanes32", Json::from(LANES));
        row.set("lanes16", Json::from(LANES_U16));
        // windowed-survivor-ring footprint per kernel instance: the
        // ring retains D+L of the D+2L walked stages; full = the
        // pre-ring [T][state] layout (CI advises if ring >= full)
        row.set("survivor_ring_bytes", Json::from(simd32.survivor_ring_bytes()));
        row.set("survivor_full_bytes", Json::from(simd32.survivor_full_bytes()));
        row.set("survivor_ring_bytes_u16", Json::from(simd16.survivor_ring_bytes()));
        row.set("survivor_full_bytes_u16", Json::from(simd16.survivor_full_bytes()));
        row.set("survivor_ring_bytes_scalar", Json::from(scalar.survivor_ring_bytes()));
        row.set("survivor_full_bytes_scalar", Json::from(scalar.survivor_full_bytes()));
        report.row("simd", row);
    }
    print!("{}", tab.render());
    println!(
        "\n(all three decode the same {LANES_U16} PBs, forward + traceback; the u32 \
         column is the lockstep-layout gain on one core, the u16 column adds the \
         narrow-metric 16-lane gain.)"
    );

    // ---- ACS/traceback pipelining (split pool vs fused pool) ------------
    // The engines' default worker pools run ACS and traceback as
    // separate queue jobs so one shard's traceback overlaps the next
    // shard's ACS; this section decodes the same batch through the
    // split pool and a fused forward+traceback pool and records the
    // per-phase busy attribution the split pool reports.
    println!(
        "\nACS/traceback split pool vs fused pool (ccsds_k7, decode_batch, \
         per-phase busy attribution)\n"
    );
    let mut tab = Table::new(&[
        "engine", "workers", "fused Mbps", "split Mbps", "split/fused", "acs %", "tb %",
    ]);
    {
        use pbvd::coordinator::DecodeEngine;
        use pbvd::par::ParCpuEngine;
        use pbvd::simd::{SimdCpuEngine, SimdTuning};
        let t = Trellis::preset("ccsds_k7")?;
        let (batch, block, depth) = (LANES_U16, 512usize, 42usize);
        let per_pb = (block + 2 * depth) * t.r;
        let mut rng = Xoshiro256::seeded(21);
        let llr8: Vec<i8> = random_llrs(&mut rng, batch * per_pb, 127)
            .iter()
            .map(|&x| x as i8)
            .collect();
        let batch_bits = (batch * block) as f64;
        for workers in [2usize, 4] {
            for engine in ["par-cpu", "simd"] {
                let (split, fused): (
                    std::sync::Arc<dyn pbvd::coordinator::DecodeEngine>,
                    std::sync::Arc<dyn pbvd::coordinator::DecodeEngine>,
                ) = if engine == "par-cpu" {
                    (
                        std::sync::Arc::new(ParCpuEngine::new(&t, batch, block, depth, workers)),
                        std::sync::Arc::new(ParCpuEngine::with_quantizer_fused(
                            &t, batch, block, depth, workers, 8,
                        )),
                    )
                } else {
                    (
                        std::sync::Arc::new(SimdCpuEngine::with_config(
                            &t, batch, block, depth, workers, SimdTuning::default(),
                        )),
                        std::sync::Arc::new(SimdCpuEngine::with_config_fused(
                            &t, batch, block, depth, workers, SimdTuning::default(),
                        )),
                    )
                };
                let s_fused = bench.run(|| {
                    let _ = fused.decode_batch(&llr8).expect("fused decode");
                });
                let s_split = bench.run(|| {
                    let _ = split.decode_batch(&llr8).expect("split decode");
                });
                let (_, tm) = split.decode_batch(&llr8).expect("split decode");
                let pw = tm.per_worker.expect("split pools attribute per call");
                let busy = pw.total_busy().as_secs_f64().max(1e-12);
                let acs_frac = pw.total_acs_busy().as_secs_f64() / busy;
                let tb_frac = pw.total_tb_busy().as_secs_f64() / busy;
                let fused_mbps = batch_bits / s_fused.mean.as_secs_f64() / 1e6;
                let split_mbps = batch_bits / s_split.mean.as_secs_f64() / 1e6;
                tab.row(&[
                    engine.to_string(),
                    workers.to_string(),
                    format!("{fused_mbps:.2}"),
                    format!("{split_mbps:.2}"),
                    format!("x{:.2}", split_mbps / fused_mbps),
                    format!("{:.1}", 100.0 * acs_frac),
                    format!("{:.1}", 100.0 * tb_frac),
                ]);
                let mut row = Json::obj();
                row.set("engine", Json::from(engine));
                row.set("workers", Json::from(workers));
                row.set("fused_mbps", Json::from(fused_mbps));
                row.set("split_mbps", Json::from(split_mbps));
                row.set("acs_busy_frac", Json::from(acs_frac));
                row.set("tb_busy_frac", Json::from(tb_frac));
                row.set(
                    "survivor_ring_bytes",
                    Json::from(pw.survivor_ring_bytes as usize),
                );
                row.set(
                    "survivor_ring_stages",
                    Json::from(pw.survivor_ring_stages as usize),
                );
                row.set(
                    "survivor_total_stages",
                    Json::from(pw.survivor_total_stages as usize),
                );
                report.row("split_pool", row);
            }
        }
    }
    print!("{}", tab.render());
    println!(
        "\n(both pools decode the same batch bit-identically; acs/tb are the split \
         pool's per-phase busy fractions — a nonzero tb column is the pipelined \
         traceback stage overlapping the next shard's ACS.)"
    );

    // ---- ACS backend ladder (every backend available on this host) ------
    // One code; each available backend decodes the same 16 PBs at both
    // metric widths, so scalar-loop vs lane-chunk-portable vs
    // intrinsics (AVX2 or NEON, arch-depending) is directly visible.
    println!("\nACS backend ladder (simd::backend, ccsds_k7, same 16 PBs per rung)\n");
    let mut tab = Table::new(&["backend", "u32 ms/PB", "u16 ms/PB", "u32 Mbps", "u16 Mbps"]);
    {
        use pbvd::simd::AcsBackend;
        let t = Trellis::preset("ccsds_k7")?;
        let (block, depth) = (512usize, 42usize);
        let per_pb = (block + 2 * depth) * t.r;
        let mut rng = Xoshiro256::seeded(20);
        let llr8: Vec<i8> = random_llrs(&mut rng, LANES_U16 * per_pb, 127)
            .iter()
            .map(|&x| x as i8)
            .collect();
        for b in AcsBackend::available() {
            let mut k32 = LaneInterleavedAcs::<u32>::with_config(&t, block, depth, 8, b);
            let mut k16 = LaneInterleavedAcs::<u16>::with_config(&t, block, depth, 8, b);
            let mut bits32 = vec![0u8; LANES * block];
            let s32 = bench.run(|| {
                for g in 0..LANES_U16 / LANES {
                    k32.decode_group_into(
                        &llr8[g * LANES * per_pb..(g + 1) * LANES * per_pb],
                        &mut bits32,
                    );
                }
            });
            let mut bits16 = vec![0u8; LANES_U16 * block];
            let s16 = bench.run(|| {
                k16.decode_group_into(&llr8, &mut bits16);
            });
            let per_pb_32 = s32.mean / LANES_U16 as u32;
            let per_pb_16 = s16.mean / LANES_U16 as u32;
            let mbps32 = block as f64 / per_pb_32.as_secs_f64() / 1e6;
            let mbps16 = block as f64 / per_pb_16.as_secs_f64() / 1e6;
            tab.row(&[
                b.name().to_string(),
                format!("{:.3}", ms(per_pb_32)),
                format!("{:.3}", ms(per_pb_16)),
                format!("{mbps32:.2}"),
                format!("{mbps16:.2}"),
            ]);
            for (width, mbps) in [(32usize, mbps32), (16usize, mbps16)] {
                let mut row = Json::obj();
                row.set("code", Json::from("ccsds_k7"));
                row.set("backend", Json::from(b.name()));
                row.set("metric_width", Json::from(width));
                row.set("mbps", Json::from(mbps));
                report.row("backends", row);
            }
        }
    }
    print!("{}", tab.render());
    println!("\n(every rung is bit-identical; only the stage-kernel binding differs.)");
    let path = report.write()?;
    println!("wrote {}", path.display());
    Ok(())
}
