//! Ablation A3: lane-count sweep (the CUDA-streams analogue) and fused
//! vs two-kernel execution granularity.
//!
//!     cargo bench --bench ablation_streams

use pbvd::bench::{Bench, Table};
use pbvd::config::{DecoderConfig, EngineKind, PjrtVariant};
use pbvd::coordinator::{DecodeEngine, StreamCoordinator};
use pbvd::runtime::Registry;
use pbvd::testutil::gen_noisy_stream;
use pbvd::trellis::Trellis;
use std::sync::Arc;

fn bench_cfg() -> Bench {
    if std::env::var("PBVD_BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench::default()
    }
}

fn throughput(eng: &Arc<dyn DecodeEngine>, llr: &[i32], lanes: usize, bench: &Bench) -> f64 {
    let coord = StreamCoordinator::new(Arc::clone(eng), lanes);
    let n_bits = llr.len() / 2;
    let stats = bench.run(|| {
        coord.decode_stream(llr).expect("decode");
    });
    n_bits as f64 / stats.mean.as_secs_f64() / 1e6
}

fn main() -> anyhow::Result<()> {
    let bench = bench_cfg();
    let t = Trellis::preset("ccsds_k7")?;
    println!("Ablation A3 — lanes (N_s) sweep and kernel granularity\n");

    let mut engines: Vec<(String, Arc<dyn DecodeEngine>)> = Vec::new();
    let (batch, block, depth) = (64usize, 512usize, 42usize);
    let base = DecoderConfig::new("ccsds_k7").batch(batch).block(block).depth(depth);
    if let Ok(reg) = Registry::open_default() {
        if let Ok(e) = base
            .clone()
            .engine(EngineKind::Pjrt(PjrtVariant::Two))
            .build_engine_with(&t, Some(&reg))
        {
            engines.push(("two-kernel".into(), e));
        }
        if let Ok(e) = base
            .clone()
            .engine(EngineKind::Pjrt(PjrtVariant::Fused))
            .build_engine_with(&t, Some(&reg))
        {
            engines.push(("fused".into(), e));
        }
    }
    engines.push((
        "cpu-golden".into(),
        base.clone().engine(EngineKind::Golden).build_engine(&t)?,
    ));
    engines.push((
        "par-cpu w8".into(),
        base.clone().engine(EngineKind::Par).workers(8).build_engine(&t)?,
    ));

    // 6 batches of work so that multi-lane overlap has material to use
    let n_bits = 6 * batch * block;
    let (_, llr) = gen_noisy_stream(&t, n_bits, 4.0, 5);

    let lanes_list = [1usize, 2, 3, 4, 6, 8];
    let mut headers: Vec<String> = vec!["engine".into()];
    headers.extend(lanes_list.iter().map(|l| format!("{l} lane T/P")));
    let hdr: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut tab = Table::new(&hdr);
    for (name, eng) in &engines {
        let mut cells = vec![name.clone()];
        for &lanes in &lanes_list {
            cells.push(format!("{:.2}", throughput(eng, &llr, lanes, &bench)));
        }
        tab.row(&cells);
    }
    print!("{}", tab.render());
    println!("\nexpected shape: T/P rises with lanes then saturates at core count /");
    println!("XLA-internal parallelism; fused ~ two-kernel (no host roundtrip cost on CPU).");
    Ok(())
}
