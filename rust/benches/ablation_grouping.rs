//! Ablation A1: group-based vs state-based branch-metric computation
//! (the paper's Sec. III-B contribution).
//!
//! Two views:
//!   1. CPU forward kernels (identical output, different BM work):
//!      measures the pure algorithmic saving.
//!   2. PJRT artifacts: `fused` (group-based) vs `orig` (state-based)
//!      end-to-end kernel time.
//!
//!     cargo bench --bench ablation_grouping

use pbvd::bench::{ms, Bench, Table};
use pbvd::coordinator::{DecodeEngine, FusedEngine, OrigEngine, StreamCoordinator};
use pbvd::runtime::Registry;
use pbvd::testutil::{gen_noisy_stream, random_llrs};
use pbvd::rng::Xoshiro256;
use pbvd::trellis::Trellis;
use pbvd::viterbi::CpuPbvdDecoder;
use std::sync::Arc;

fn bench_cfg() -> Bench {
    if std::env::var("PBVD_BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench::default()
    }
}

fn main() -> anyhow::Result<()> {
    let bench = bench_cfg();
    println!("Ablation A1 — group-based vs state-based BM computation\n");

    // ---- CPU view, across codes -----------------------------------------
    let mut tab = Table::new(&[
        "code", "BM ops grp", "BM ops state", "grp ms", "state ms", "speedup",
    ]);
    for (name, _, _) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name)?;
        let dec = CpuPbvdDecoder::new(&t, 256, 6 * t.k as usize);
        let mut rng = Xoshiro256::seeded(11);
        let llr = random_llrs(&mut rng, dec.total() * t.r, 127);
        let s_grp = bench.run(|| {
            let _ = dec.forward(&llr);
        });
        let s_state = bench.run(|| {
            let _ = dec.forward_statebased(&llr);
        });
        let (g, s) = t.bm_ops_per_stage();
        tab.row(&[
            name.to_string(),
            g.to_string(),
            s.to_string(),
            format!("{:.3}", ms(s_grp.mean)),
            format!("{:.3}", ms(s_state.mean)),
            format!("x{:.2}", s_state.mean.as_secs_f64() / s_grp.mean.as_secs_f64()),
        ]);
    }
    print!("{}", tab.render());

    // ---- PJRT view --------------------------------------------------------
    if !pbvd::runtime::pjrt_available() {
        eprintln!("\nSKIP PJRT view: PJRT runtime unavailable (stub xla build)");
        return Ok(());
    }
    let Ok(reg) = Registry::open_default() else {
        eprintln!("\nSKIP PJRT view: artifacts not built");
        return Ok(());
    };
    let t = Trellis::preset("ccsds_k7")?;
    let (batch, block, depth) = (64usize, 512usize, 42usize);
    let (_, llr) = gen_noisy_stream(&t, batch * block, 4.0, 12);
    let mut tab = Table::new(&["engine", "kernel ms/batch", "S_k Mbps"]);
    for (label, eng) in [
        (
            "fused (group-based, i8)",
            Arc::new(FusedEngine::from_registry(&reg, "ccsds_k7", batch, block, depth)?)
                as Arc<dyn DecodeEngine>,
        ),
        (
            "orig (state-based, f32)",
            Arc::new(OrigEngine::from_registry(&reg, "ccsds_k7", batch, block, depth)?),
        ),
    ] {
        let coord = StreamCoordinator::new(eng, 1);
        let mut last = None;
        bench.run(|| {
            last = Some(coord.decode_stream(&llr).expect("decode").1);
        });
        let s = last.unwrap();
        tab.row(&[
            label.into(),
            format!("{:.2}", ms((s.phases.k1 + s.phases.k2) / s.n_batches as u32)),
            format!("{:.2}", s.kernel_throughput_mbps()),
        ]);
    }
    println!();
    print!("{}", tab.render());
    println!("\nexpected shape: group-based <= state-based kernel time (2^(R+2) vs 2^K BMs).");
    Ok(())
}
