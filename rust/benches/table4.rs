//! Bench: paper Table IV — TNDC-normalized comparison with prior GPU
//! works, plus this repo's measured CPU-PJRT throughput for context.
//!
//!     cargo bench --bench table4

use pbvd::bench::{Bench, BenchReport, Table};
use pbvd::config::{DecoderConfig, EngineKind, PjrtVariant};
use pbvd::json::Json;
use pbvd::coordinator::{DecodeEngine, StreamCoordinator};
use pbvd::perfmodel::{tndc, TABLE4_PRIOR, TABLE4_THIS_WORK};
use pbvd::runtime::Registry;
use pbvd::testutil::gen_noisy_stream;
use pbvd::trellis::Trellis;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    println!("Table IV bench — decoding throughput comparison (TNDC)");
    let mut tab = Table::new(&["Work", "Device", "T/P Mbps", "TNDC calc", "TNDC paper", "Speedup"]);
    let best = TABLE4_THIS_WORK[1].paper_tndc;
    for w in TABLE4_PRIOR.iter().chain(TABLE4_THIS_WORK.iter()) {
        tab.row(&[
            w.work.into(),
            w.device.into(),
            format!("{:.1}", w.throughput_mbps),
            format!("{:.3}", tndc(w.throughput_mbps, w.cores, w.clock_mhz)),
            format!("{:.3}", w.paper_tndc),
            format!("x{:.2}", best / w.paper_tndc),
        ]);
    }

    let mut report = BenchReport::new("table4");
    report.scalar("quick", std::env::var("PBVD_BENCH_QUICK").is_ok());
    for w in TABLE4_PRIOR.iter().chain(TABLE4_THIS_WORK.iter()) {
        let mut row = Json::obj();
        row.set("work", Json::from(w.work));
        row.set("tp_mbps", Json::from(w.throughput_mbps));
        row.set("tndc_paper", Json::from(w.paper_tndc));
        report.row("reference", row);
    }

    // This repo's sharded CPU backend (runs everywhere, no artifacts).
    {
        let t = Trellis::preset("ccsds_k7")?;
        let quick = std::env::var("PBVD_BENCH_QUICK").is_ok();
        let bench = if quick { Bench::quick() } else { Bench::default() };
        let (batch, block, depth) = (32usize, 512usize, 42usize);
        let n_bits = batch * block * if quick { 2 } else { 4 };
        let (_, llr) = gen_noisy_stream(&t, n_bits, 4.0, 7);
        let eng = DecoderConfig::new("ccsds_k7")
            .batch(batch)
            .block(block)
            .depth(depth)
            .workers(0)
            .engine(EngineKind::Par)
            .build_engine(&t)?;
        let name = eng.name();
        let coord = StreamCoordinator::new(eng, 2);
        let stats = bench.run(|| {
            coord.decode_stream(&llr).expect("decode");
        });
        let tp = n_bits as f64 / stats.mean.as_secs_f64() / 1e6;
        tab.row(&[
            "this repo (CPU)".into(),
            name,
            format!("{tp:.2}"),
            "-".into(),
            "-".into(),
            "-".into(),
        ]);
        let mut row = Json::obj();
        row.set("engine", Json::from("par-cpu"));
        row.set("tp_mbps", Json::from(tp));
        report.row("measured", row);
    }

    // Our measured numbers (different substrate — reported, not TNDC'd).
    if pbvd::runtime::pjrt_available() {
        if let Ok(reg) = Registry::open_default() {
        let t = Trellis::preset("ccsds_k7")?;
        for (batch, block, depth) in [(256usize, 512usize, 42usize), (64, 512, 42)] {
            let Ok(eng) = DecoderConfig::new("ccsds_k7")
                .batch(batch)
                .block(block)
                .depth(depth)
                .engine(EngineKind::Pjrt(PjrtVariant::Two))
                .build_engine_with(&t, Some(&reg))
            else {
                continue;
            };
            let (_, llr) = gen_noisy_stream(&t, 2 * batch * block, 4.0, 7);
            let bench = if std::env::var("PBVD_BENCH_QUICK").is_ok() {
                Bench::quick()
            } else {
                Bench::default()
            };
            let coord = StreamCoordinator::new(Arc::clone(&eng), 3);
            let stats = bench.run(|| {
                coord.decode_stream(&llr).expect("decode");
            });
            let tp = (2 * batch * block) as f64 / stats.mean.as_secs_f64() / 1e6;
            tab.row(&[
                "this repo".into(),
                format!("CPU-PJRT (N_t={batch})"),
                format!("{tp:.2}"),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
            break;
        }
        }
    } else {
        eprintln!("SKIP table4 PJRT row: PJRT runtime unavailable (stub xla build)");
    }
    print!("{}", tab.render());
    let path = report.write()?;
    println!("wrote {}", path.display());
    println!("\npaper headline: x1.53 vs fastest prior GPU work; our CPU substrate");
    println!("reproduces the *relative* Table III structure, not GPU absolutes.");
    Ok(())
}
