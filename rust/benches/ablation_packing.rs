//! Ablation A2: the U1/U2 packing schemes (Sec. IV-C) — transfer bytes
//! and marshalling time of packed vs unpacked I/O, plus the host-side
//! pack/unpack primitive costs across quantizer widths.
//!
//!     cargo bench --bench ablation_packing

use pbvd::bench::{ms, Bench, Table};
use pbvd::channel::{pack_bits, pack_llrs, u1_bytes, unpack_bits, unpack_llrs};
use pbvd::coordinator::{DecodeEngine, OrigEngine, StreamCoordinator, TwoKernelEngine};
use pbvd::runtime::Registry;
use pbvd::rng::Xoshiro256;
use pbvd::testutil::gen_noisy_stream;
use pbvd::trellis::Trellis;
use std::sync::Arc;

fn bench_cfg() -> Bench {
    if std::env::var("PBVD_BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench::default()
    }
}

fn main() -> anyhow::Result<()> {
    let bench = bench_cfg();
    println!("Ablation A2 — U1/U2 packing\n");

    // ---- primitive pack/unpack cost per q --------------------------------
    let mut rng = Xoshiro256::seeded(3);
    let n = 1_000_000usize;
    let mut tab = Table::new(&["q bits", "U1 B/val", "pack ms/Mval", "unpack ms/Mval"]);
    for q in [4u32, 8, 16] {
        let m = (1i64 << (q - 1)) - 1;
        let vals: Vec<i32> = (0..n)
            .map(|_| (rng.next_below((2 * m + 1) as u64) as i64 - m) as i32)
            .collect();
        let sp = bench.run(|| {
            let _ = pack_llrs(&vals, q);
        });
        let packed = pack_llrs(&vals, q);
        let su = bench.run(|| {
            let _ = unpack_llrs(&packed, q, n);
        });
        tab.row(&[
            q.to_string(),
            format!("{}", u1_bytes(q)),
            format!("{:.2}", ms(sp.mean)),
            format!("{:.2}", ms(su.mean)),
        ]);
    }
    print!("{}", tab.render());

    // bit packing
    let bits: Vec<u8> = (0..n).map(|_| rng.next_bit()).collect();
    let sp = bench.run(|| {
        let _ = pack_bits(&bits);
    });
    let packed = pack_bits(&bits);
    let su = bench.run(|| {
        let _ = unpack_bits(&packed, n);
    });
    println!(
        "U2 bit packing: pack {:.2} ms/Mbit, unpack {:.2} ms/Mbit (32x size cut)\n",
        ms(sp.mean),
        ms(su.mean)
    );

    // ---- decision-word packing: scalar u64 rows vs lane masks ------------
    // The scalar butterfly pokes each survivor bit into a shared u64
    // row (read-modify-write per state); the lane-interleaved kernels
    // emit one lane-mask word per target state — a whole lane-group's
    // decisions in a single store (a byte for 8 u32 lanes, a u16 for
    // 16 u16 lanes).  Forward-pass cost per PB, same LLRs:
    use pbvd::simd::{LaneInterleavedAcs, LANES, LANES_U16};
    let t7 = Trellis::preset("ccsds_k7")?;
    let (d, l) = (512usize, 42usize);
    let mut scalar = pbvd::par::ButterflyAcs::new(&t7, d, l);
    let mut lanes32 = LaneInterleavedAcs::<u32>::new(&t7, d, l);
    let mut lanes16 = LaneInterleavedAcs::<u16>::new(&t7, d, l);
    let per_pb = scalar.total() * t7.r;
    let mut rng2 = Xoshiro256::seeded(11);
    let llr8: Vec<i8> = (0..LANES_U16 * per_pb)
        .map(|_| ((rng2.next_below(255) as i32) - 127) as i8)
        .collect();
    let s_sc = bench.run(|| {
        for lane in 0..LANES_U16 {
            scalar.forward(&llr8[lane * per_pb..(lane + 1) * per_pb]);
        }
    });
    let s_ln = bench.run(|| {
        for g in 0..LANES_U16 / LANES {
            lanes32.forward(&llr8[g * LANES * per_pb..(g + 1) * LANES * per_pb]);
        }
    });
    let s_l16 = bench.run(|| {
        lanes16.forward(&llr8);
    });
    let mut tab = Table::new(&["decision packing", "fwd ms/PB", "bytes/stage"]);
    tab.row(&[
        "per-state u64 bit pokes (scalar)".into(),
        format!("{:.3}", ms(s_sc.mean / LANES_U16 as u32)),
        format!("{}", t7.n_states.div_ceil(64) * 8),
    ]);
    tab.row(&[
        format!("u32 lane-mask bytes x{LANES} blocks ({})", lanes32.backend()),
        format!("{:.3}", ms(s_ln.mean / LANES_U16 as u32)),
        format!("{} (for {LANES} PBs)", t7.n_states),
    ]);
    tab.row(&[
        format!(
            "u16 lane-mask words x{LANES_U16} blocks ({})",
            lanes16.backend()
        ),
        format!("{:.3}", ms(s_l16.mean / LANES_U16 as u32)),
        format!("{} (for {LANES_U16} PBs)", 2 * t7.n_states),
    ]);
    print!("{}", tab.render());
    println!(
        "(same {LANES_U16} PBs; lane masks amortize one store across a lane-group's \
         survivor bits, and u16 metrics double the lanes per 256-bit vector)\n"
    );

    // ---- engine-level transfer accounting ---------------------------------
    if !pbvd::runtime::pjrt_available() {
        eprintln!("SKIP engine view: PJRT runtime unavailable (stub xla build)");
        return Ok(());
    }
    let Ok(reg) = Registry::open_default() else {
        eprintln!("SKIP engine view: artifacts not built");
        return Ok(());
    };
    let t = Trellis::preset("ccsds_k7")?;
    let (batch, block, depth) = (64usize, 512usize, 42usize);
    let (_, llr) = gen_noisy_stream(&t, batch * block, 4.0, 4);
    let mut tab = Table::new(&[
        "engine", "H2D B/batch", "D2H B/batch", "pack ms", "unpack ms",
    ]);
    for (label, eng) in [
        (
            "optimized (i8 in, packed out)",
            Arc::new(TwoKernelEngine::from_registry(&reg, "ccsds_k7", batch, block, depth)?)
                as Arc<dyn DecodeEngine>,
        ),
        (
            "original (f32 in, i32/bit out)",
            Arc::new(OrigEngine::from_registry(&reg, "ccsds_k7", batch, block, depth)?),
        ),
    ] {
        let coord = StreamCoordinator::new(eng, 1);
        let mut last = None;
        bench.run(|| {
            last = Some(coord.decode_stream(&llr).expect("decode").1);
        });
        let s = last.unwrap();
        let nb = s.n_batches;
        tab.row(&[
            label.into(),
            (s.phases.h2d_bytes / nb).to_string(),
            (s.phases.d2h_bytes / nb).to_string(),
            format!("{:.3}", ms(s.phases.pack / nb as u32)),
            format!("{:.3}", ms(s.phases.unpack / nb as u32)),
        ]);
    }
    print!("{}", tab.render());
    println!("\nexpected shape: optimized moves 4x less H2D and 32x less D2H.");
    Ok(())
}
