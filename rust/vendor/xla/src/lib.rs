//! API-compatible stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The container that builds this repository has no network access and
//! no prebuilt `xla_extension` C++ library, so the real bindings cannot
//! be compiled.  This stub exposes the exact API surface that
//! `pbvd::runtime` and the `perf_probe*` examples use, with every
//! runtime entry point returning a descriptive [`Error`].  The effect:
//!
//! * the whole workspace builds and tests offline;
//! * `Registry::load` fails cleanly, so `best_available_coordinator`
//!   and the CLI fall back to the CPU engines;
//! * artifact-gated integration tests skip with a clear message
//!   (`pbvd::runtime::pjrt_available()` reports `false`).
//!
//! To enable real PJRT execution, replace the `xla = { path = ... }`
//! entry in `rust/Cargo.toml` with the actual bindings (same API) — no
//! source change in `pbvd` is required.

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `{e:?}` /
/// `{e}` formatting and `?`-conversion into `anyhow::Error`.
#[derive(Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({:?})", self.0)
    }
}

impl std::error::Error for Error {}

/// Every stub entry point fails with this message.
fn unavailable() -> Error {
    Error(
        "PJRT/XLA native runtime is not available in this build \
         (pbvd was compiled against the vendored stub in \
         rust/vendor/xla). CPU engines are unaffected; to enable PJRT \
         engines, build against the real xla-rs bindings."
            .to_string(),
    )
}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types of the artifact tensors used by this repo.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Marker for element types `Literal::copy_raw_to` accepts.
pub trait NativeType: Copy {}

impl NativeType for i8 {}
impl NativeType for u8 {}
impl NativeType for i16 {}
impl NativeType for u16 {}
impl NativeType for i32 {}
impl NativeType for u32 {}
impl NativeType for i64 {}
impl NativeType for u64 {}
impl NativeType for f32 {}
impl NativeType for f64 {}

/// Host-side tensor literal (never constructible through the stub).
#[derive(Clone, Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _untyped_data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn size_bytes(&self) -> usize {
        0
    }

    pub fn copy_raw_to<T: NativeType>(&self, _dst: &mut [T]) -> Result<()> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }
}

/// Device buffer handle returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Parsed HLO module (text form).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_and_uniformly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("not available"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::S8,
            &[2, 2],
            &[0, 1, 2, 3]
        )
        .is_err());
    }

    #[test]
    fn error_formats_like_the_real_bindings() {
        let e = unavailable();
        assert!(format!("{e:?}").starts_with("XlaError("));
        assert!(!format!("{e}").is_empty());
    }
}
