//! Vendored minimal drop-in replacement for the `anyhow` crate.
//!
//! The build environment for this repository is fully offline (no
//! crates.io registry), so the workspace vendors the tiny subset of
//! `anyhow`'s API that the `pbvd` crate actually uses:
//!
//! * [`Error`] — a string-chain error value (`Send + Sync + 'static`).
//! * [`Result<T>`] — `Result<T, Error>` with a defaulted error type.
//! * [`anyhow!`] / [`bail!`] / [`ensure!`] — format-style constructors.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on results.
//!
//! Semantics match `anyhow` where it matters here: `{e}` prints the
//! top-level message, `{e:#}` prints the full cause chain separated by
//! `": "`, and `?` converts any `std::error::Error` automatically.
//! Downcasting and backtraces are intentionally not supported; if the
//! real crate ever becomes available, deleting this directory and
//! switching `rust/Cargo.toml` to the registry version is a drop-in
//! change.

use std::fmt;

/// A string-chain error: the top-level message plus its causes.
pub struct Error {
    msg: String,
    /// Causes, outermost first (`chain[0]` caused `msg`).
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            chain: Vec::new(),
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        let mut chain = vec![self.msg];
        chain.extend(self.chain);
        Error {
            msg: context.to_string(),
            chain,
        }
    }

    /// The messages of this error and its causes, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.msg.as_str()).chain(self.chain.iter().map(String::as_str))
    }

    /// The root (innermost) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full cause chain, anyhow-style.
            write!(f, "{}", self.msg)?;
            for cause in &self.chain {
                write!(f, ": {cause}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if !self.chain.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain.iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

/// Any standard error converts via `?`, capturing its source chain as
/// strings.  `Error` itself deliberately does NOT implement
/// `std::error::Error`, exactly like the real `anyhow`, so this blanket
/// impl cannot conflict with the reflexive `From<Error> for Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let msg = e.to_string();
        let mut chain = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { msg, chain }
    }
}

/// `Result` with a defaulted `Error` type, as in the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a fallible computation.
pub trait Context<T> {
    /// Wrap the error (if any) with a fixed context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Wrap the error (if any) with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless `$cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Result::<(), std::io::Error>::Err(io_err())
            .context("opening manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "opening manifest");
        assert_eq!(format!("{e:#}"), "opening manifest: missing file");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<i32> {
            let n: i32 = "42".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 42);
        fn bad() -> Result<i32> {
            let n: i32 = "nope".parse()?;
            Ok(n)
        }
        assert!(bad().is_err());
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x == 0 {
                bail!("zero is not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero is not allowed");
        assert_eq!(format!("{}", f(-2).unwrap_err()), "negative input -2");
        let e = anyhow!("code {code}", code = 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn context_chains_compose() {
        let base = anyhow!("root");
        let wrapped = Result::<(), Error>::Err(base)
            .context("mid")
            .with_context(|| format!("outer {}", 1))
            .unwrap_err();
        let msgs: Vec<&str> = wrapped.chain().collect();
        assert_eq!(msgs, vec!["outer 1", "mid", "root"]);
        assert_eq!(wrapped.root_cause(), "root");
        assert_eq!(format!("{wrapped:#}"), "outer 1: mid: root");
    }
}
