//! Latency/throughput metrics: lock-free-ish histogram + windowed
//! rate meter for the coordinator's serving-style reporting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log-scale latency histogram: 2-per-octave buckets from 1 us to
/// ~8.4 s, constant-time record, mergeable, atomic (thread-safe).
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const N_BUCKETS: usize = 48; // 2 per octave * 24 octaves from 1us

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: u64) -> usize {
        if us <= 1 {
            return 0;
        }
        // 2 buckets per octave: index = 2*log2(us) rounded down
        let log2 = 63 - us.leading_zeros() as u64;
        let frac = (us >> (log2.saturating_sub(1))) & 1; // half-octave bit
        ((2 * log2 + frac) as usize).min(N_BUCKETS - 1)
    }

    /// Upper edge of bucket i in microseconds.
    fn bucket_edge(i: usize) -> u64 {
        let octave = i / 2;
        let half = i % 2;
        let base = 1u64 << octave;
        if half == 0 {
            base
        } else {
            base + base / 2
        }
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / n)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Approximate quantile (bucket upper edge), q in [0, 1].
    pub fn quantile(&self, q: f64) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return Duration::from_micros(Self::bucket_edge(i));
            }
        }
        self.max()
    }

    pub fn merge(&self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter().zip(&other.buckets) {
            a.fetch_add(b.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_us
            .fetch_add(other.sum_us.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_us
            .fetch_max(other.max_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.2?} p50={:.2?} p95={:.2?} p99={:.2?} max={:.2?}",
            self.count(),
            self.mean(),
            self.quantile(0.50),
            self.quantile(0.95),
            self.quantile(0.99),
            self.max()
        )
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Monotonic throughput meter: total units over elapsed wall time.
pub struct RateMeter {
    start: std::time::Instant,
    units: AtomicU64,
}

impl RateMeter {
    pub fn new() -> Self {
        Self {
            start: std::time::Instant::now(),
            units: AtomicU64::new(0),
        }
    }

    pub fn add(&self, n: u64) {
        self.units.fetch_add(n, Ordering::Relaxed);
    }

    pub fn rate_per_sec(&self) -> f64 {
        let dt = self.start.elapsed().as_secs_f64();
        if dt == 0.0 {
            0.0
        } else {
            self.units.load(Ordering::Relaxed) as f64 / dt
        }
    }
}

impl Default for RateMeter {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn bucket_monotone() {
        let mut last = 0;
        for us in [1u64, 2, 3, 5, 10, 100, 1000, 10_000, 1_000_000] {
            let b = LatencyHistogram::bucket_of(us);
            assert!(b >= last, "bucket({us}) = {b} < {last}");
            last = b;
        }
    }

    #[test]
    fn quantiles_ordered() {
        let h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99 && p99 <= h.max());
        // log buckets: p50 within a half-octave of 500us
        assert!(p50 >= Duration::from_micros(256) && p50 <= Duration::from_micros(1024));
    }

    #[test]
    fn mean_and_max() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(300));
        assert_eq!(h.mean(), Duration::from_micros(200));
        assert_eq!(h.max(), Duration::from_micros(300));
    }

    #[test]
    fn merge_combines() {
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        a.record(Duration::from_micros(10));
        b.record(Duration::from_micros(1000));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max(), Duration::from_micros(1000));
    }

    #[test]
    fn concurrent_records() {
        let h = Arc::new(LatencyHistogram::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let h = Arc::clone(&h);
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    h.record(Duration::from_micros(i + 1));
                }
            }));
        }
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
        assert!(!h.summary().is_empty());
    }

    #[test]
    fn rate_meter() {
        let m = RateMeter::new();
        m.add(100);
        std::thread::sleep(Duration::from_millis(20));
        let r = m.rate_per_sec();
        assert!(r > 0.0 && r < 100.0 / 0.02 * 2.0);
    }
}
