//! Latency/throughput metrics: lock-free-ish histogram + windowed
//! rate meter for the coordinator's serving-style reporting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log-scale latency histogram: 2-per-octave buckets from 1 us to
/// ~8.4 s, constant-time record, mergeable, atomic (thread-safe).
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const N_BUCKETS: usize = 48; // 2 per octave * 24 octaves from 1us

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: u64) -> usize {
        if us <= 1 {
            return 0;
        }
        // 2 buckets per octave: index = 2*log2(us) rounded down
        let log2 = 63 - us.leading_zeros() as u64;
        let frac = (us >> (log2.saturating_sub(1))) & 1; // half-octave bit
        ((2 * log2 + frac) as usize).min(N_BUCKETS - 1)
    }

    /// Upper edge of bucket i in microseconds.
    fn bucket_edge(i: usize) -> u64 {
        let octave = i / 2;
        let half = i % 2;
        let base = 1u64 << octave;
        if half == 0 {
            base
        } else {
            base + base / 2
        }
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / n)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Approximate quantile (bucket upper edge), q in [0, 1].
    pub fn quantile(&self, q: f64) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return Duration::from_micros(Self::bucket_edge(i));
            }
        }
        self.max()
    }

    pub fn merge(&self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter().zip(&other.buckets) {
            a.fetch_add(b.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_us
            .fetch_add(other.sum_us.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_us
            .fetch_max(other.max_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.2?} p50={:.2?} p95={:.2?} p99={:.2?} max={:.2?}",
            self.count(),
            self.mean(),
            self.quantile(0.50),
            self.quantile(0.95),
            self.quantile(0.99),
            self.max()
        )
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// Per-worker pool accounting (sharded decode backend).
// ---------------------------------------------------------------------------

/// Cumulative per-worker counters for a sharded decode pool
/// (`par::ParCpuEngine`, `simd::SimdCpuEngine`): busy time, jobs and
/// decoded PBs per worker.  A "job" is one shard for the scalar pool
/// and one lane-group for the SIMD pool, so the SIMD engine's
/// attribution is lane-group granular.
/// Atomic, so workers record concurrently with snapshot readers.
pub struct WorkerPoolStats {
    busy_ns: Vec<AtomicU64>,
    /// Busy time spent in the forward-ACS phase per worker (a subset
    /// of `busy_ns`; zero for pools running the fused decode path).
    acs_ns: Vec<AtomicU64>,
    /// Busy time spent in the traceback phase per worker (a subset of
    /// `busy_ns`; zero for fused pools).  `acs_ns[w] + tb_ns[w] ==
    /// busy_ns[w]` on split pools, and one worker's traceback
    /// overlapping another's ACS is exactly what the split buys.
    tb_ns: Vec<AtomicU64>,
    jobs: Vec<AtomicU64>,
    blocks: Vec<AtomicU64>,
    /// Survivor-ring footprint of the pool's kernel, bytes per shard
    /// kernel instance (set once after spawn; 0 = not recorded).
    survivor_ring_bytes: AtomicU64,
    /// Ring capacity in stages (`D + L`; 0 = not recorded).
    survivor_ring_stages: AtomicU64,
    /// Total forward stages per PB (`T = D + 2L`; 0 = not recorded).
    survivor_total_stages: AtomicU64,
    /// Path-metric storage width of the pool's kernel (16 or 32 for
    /// the lane-interleaved SIMD pool — the autotuner's pick — and 0
    /// for scalar pools, where no lane width applies).
    metric_bits: AtomicU64,
    /// ACS backend code of the pool's kernel
    /// ([`AcsBackend::code`](crate::simd::AcsBackend::code) for the
    /// lane-interleaved SIMD pool's resolved backend; 0 for scalar
    /// pools, where no lane backend applies).
    backend: AtomicU64,
}

impl WorkerPoolStats {
    pub fn new(workers: usize) -> Self {
        let mk = |_| AtomicU64::new(0);
        Self {
            busy_ns: (0..workers).map(mk).collect(),
            acs_ns: (0..workers).map(mk).collect(),
            tb_ns: (0..workers).map(mk).collect(),
            jobs: (0..workers).map(mk).collect(),
            blocks: (0..workers).map(mk).collect(),
            metric_bits: AtomicU64::new(0),
            backend: AtomicU64::new(0),
            survivor_ring_bytes: AtomicU64::new(0),
            survivor_ring_stages: AtomicU64::new(0),
            survivor_total_stages: AtomicU64::new(0),
        }
    }

    pub fn workers(&self) -> usize {
        self.busy_ns.len()
    }

    /// Record the pool's path-metric width (the lane-width autotuner's
    /// pick: 16 or 32; 0 = scalar / not applicable).
    pub fn set_metric_bits(&self, bits: u64) {
        self.metric_bits.store(bits, Ordering::Relaxed);
    }

    pub fn metric_bits(&self) -> u64 {
        self.metric_bits.load(Ordering::Relaxed)
    }

    /// Record the pool kernel's ACS backend code
    /// ([`AcsBackend::code`](crate::simd::AcsBackend::code); 0 =
    /// scalar pool / not applicable).
    pub fn set_backend(&self, code: u64) {
        self.backend.store(code, Ordering::Relaxed);
    }

    pub fn backend(&self) -> u64 {
        self.backend.load(Ordering::Relaxed)
    }

    /// Record the survivor-ring footprint of the pool's kernel: bytes
    /// of decision-ring storage per kernel instance, the ring capacity
    /// in stages (`D + L`) and the total stages per PB (`T = D + 2L`).
    pub fn set_survivor_footprint(&self, ring_bytes: u64, ring_stages: u64, total_stages: u64) {
        self.survivor_ring_bytes.store(ring_bytes, Ordering::Relaxed);
        self.survivor_ring_stages.store(ring_stages, Ordering::Relaxed);
        self.survivor_total_stages.store(total_stages, Ordering::Relaxed);
    }

    pub fn survivor_ring_bytes(&self) -> u64 {
        self.survivor_ring_bytes.load(Ordering::Relaxed)
    }

    /// Record one finished shard for `worker` (fused forward +
    /// traceback; split pools use [`record_acs`](Self::record_acs) /
    /// [`record_tb`](Self::record_tb) instead).
    pub fn record(&self, worker: usize, busy: Duration, blocks: u64) {
        self.busy_ns[worker].fetch_add(busy.as_nanos() as u64, Ordering::Relaxed);
        self.jobs[worker].fetch_add(1, Ordering::Relaxed);
        self.blocks[worker].fetch_add(blocks, Ordering::Relaxed);
    }

    /// Record the forward-ACS phase of one shard for `worker` (the
    /// shard's job/block counts are attributed to the ACS worker).
    pub fn record_acs(&self, worker: usize, busy: Duration, blocks: u64) {
        let ns = busy.as_nanos() as u64;
        self.busy_ns[worker].fetch_add(ns, Ordering::Relaxed);
        self.acs_ns[worker].fetch_add(ns, Ordering::Relaxed);
        self.jobs[worker].fetch_add(1, Ordering::Relaxed);
        self.blocks[worker].fetch_add(blocks, Ordering::Relaxed);
    }

    /// Record the traceback phase of one shard for `worker` (possibly
    /// a different worker than the shard's ACS phase — that overlap is
    /// the point of the split).
    pub fn record_tb(&self, worker: usize, busy: Duration) {
        let ns = busy.as_nanos() as u64;
        self.busy_ns[worker].fetch_add(ns, Ordering::Relaxed);
        self.tb_ns[worker].fetch_add(ns, Ordering::Relaxed);
    }

    /// Point-in-time copy of every counter.
    pub fn snapshot(&self) -> WorkerSnapshot {
        let load = |v: &Vec<AtomicU64>| -> Vec<u64> {
            v.iter().map(|x| x.load(Ordering::Relaxed)).collect()
        };
        let load_d = |v: &Vec<AtomicU64>| -> Vec<Duration> {
            v.iter()
                .map(|x| Duration::from_nanos(x.load(Ordering::Relaxed)))
                .collect()
        };
        WorkerSnapshot {
            busy: load_d(&self.busy_ns),
            acs_busy: load_d(&self.acs_ns),
            tb_busy: load_d(&self.tb_ns),
            jobs: load(&self.jobs),
            blocks: load(&self.blocks),
            metric_bits: self.metric_bits(),
            backend: self.backend(),
            survivor_ring_bytes: self.survivor_ring_bytes.load(Ordering::Relaxed),
            survivor_ring_stages: self.survivor_ring_stages.load(Ordering::Relaxed),
            survivor_total_stages: self.survivor_total_stages.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time per-worker counters; two snapshots diff into the
/// per-stream view the coordinator reports in `StreamStats`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WorkerSnapshot {
    /// Busy (decoding) time per worker.
    pub busy: Vec<Duration>,
    /// Forward-ACS phase share of `busy` per worker (split pools;
    /// empty or all-zero on fused pools and default snapshots).
    pub acs_busy: Vec<Duration>,
    /// Traceback phase share of `busy` per worker.  A worker showing
    /// traceback time for shards whose ACS ran elsewhere is the
    /// ACS/traceback overlap the split pipeline buys.
    pub tb_busy: Vec<Duration>,
    /// Jobs completed per worker (shards for `par`, lane-groups for
    /// `simd`).
    pub jobs: Vec<u64>,
    /// Parallel blocks decoded per worker.
    pub blocks: Vec<u64>,
    /// Path-metric storage width of the decode kernel (16/32 for the
    /// SIMD pool — the lane-width autotuner's pick — 0 for scalar).
    pub metric_bits: u64,
    /// ACS backend code of the decode kernel
    /// ([`AcsBackend::code`](crate::simd::AcsBackend::code): the SIMD
    /// pool's resolved scalar/portable/AVX2/NEON pick; 0 for scalar
    /// pools).
    pub backend: u64,
    /// Survivor decision-ring bytes per shard kernel instance (the
    /// depth-windowed footprint; 0 = not recorded).
    pub survivor_ring_bytes: u64,
    /// Ring capacity in stages (`D + L`; 0 = not recorded).
    pub survivor_ring_stages: u64,
    /// Total forward stages per PB (`T = D + 2L`; 0 = not recorded).
    /// `survivor_ring_stages < survivor_total_stages` is the memory
    /// reduction the ring buys over a full-length buffer.
    pub survivor_total_stages: u64,
}

impl WorkerSnapshot {
    pub fn workers(&self) -> usize {
        self.busy.len()
    }

    /// Human name of the recorded ACS backend (`None` when the pool
    /// has no lane backend — scalar pools and default snapshots).
    pub fn backend_name(&self) -> Option<&'static str> {
        crate::simd::AcsBackend::from_code(self.backend).map(|b| b.name())
    }

    pub fn total_busy(&self) -> Duration {
        self.busy.iter().sum()
    }

    /// Total forward-ACS phase time (zero on fused pools).
    pub fn total_acs_busy(&self) -> Duration {
        self.acs_busy.iter().sum()
    }

    /// Total traceback phase time (zero on fused pools).
    pub fn total_tb_busy(&self) -> Duration {
        self.tb_busy.iter().sum()
    }

    pub fn total_jobs(&self) -> u64 {
        self.jobs.iter().sum()
    }

    pub fn total_blocks(&self) -> u64 {
        self.blocks.iter().sum()
    }

    /// Element-wise accumulate `other` into `self`, growing to the
    /// larger worker count (used to sum per-batch attributions into a
    /// per-stream view).
    pub fn merge(&mut self, other: &WorkerSnapshot) {
        let n = self.busy.len().max(other.busy.len());
        self.busy.resize(n, Duration::ZERO);
        self.acs_busy.resize(n, Duration::ZERO);
        self.tb_busy.resize(n, Duration::ZERO);
        self.jobs.resize(n, 0);
        self.blocks.resize(n, 0);
        self.metric_bits = self.metric_bits.max(other.metric_bits);
        self.backend = self.backend.max(other.backend);
        self.survivor_ring_bytes = self.survivor_ring_bytes.max(other.survivor_ring_bytes);
        self.survivor_ring_stages = self.survivor_ring_stages.max(other.survivor_ring_stages);
        self.survivor_total_stages = self.survivor_total_stages.max(other.survivor_total_stages);
        for (i, &b) in other.busy.iter().enumerate() {
            self.busy[i] += b;
        }
        for (i, &b) in other.acs_busy.iter().enumerate() {
            self.acs_busy[i] += b;
        }
        for (i, &b) in other.tb_busy.iter().enumerate() {
            self.tb_busy[i] += b;
        }
        for (i, &j) in other.jobs.iter().enumerate() {
            self.jobs[i] += j;
        }
        for (i, &bl) in other.blocks.iter().enumerate() {
            self.blocks[i] += bl;
        }
    }

    /// Counters accumulated since `earlier` (saturating per worker).
    pub fn delta_since(&self, earlier: &WorkerSnapshot) -> WorkerSnapshot {
        let sub_d = |a: &[Duration], b: &[Duration]| -> Vec<Duration> {
            a.iter()
                .enumerate()
                .map(|(i, &x)| {
                    x.checked_sub(b.get(i).copied().unwrap_or_default())
                        .unwrap_or_default()
                })
                .collect()
        };
        let sub_u = |a: &[u64], b: &[u64]| -> Vec<u64> {
            a.iter()
                .enumerate()
                .map(|(i, &x)| x.saturating_sub(b.get(i).copied().unwrap_or_default()))
                .collect()
        };
        WorkerSnapshot {
            busy: sub_d(&self.busy, &earlier.busy),
            acs_busy: sub_d(&self.acs_busy, &earlier.acs_busy),
            tb_busy: sub_d(&self.tb_busy, &earlier.tb_busy),
            jobs: sub_u(&self.jobs, &earlier.jobs),
            blocks: sub_u(&self.blocks, &earlier.blocks),
            metric_bits: self.metric_bits,
            backend: self.backend,
            survivor_ring_bytes: self.survivor_ring_bytes,
            survivor_ring_stages: self.survivor_ring_stages,
            survivor_total_stages: self.survivor_total_stages,
        }
    }

    /// Pool utilization over a wall-clock interval: total busy time
    /// divided by `workers * wall` (1.0 = every worker always busy).
    pub fn utilization(&self, wall: Duration) -> f64 {
        let denom = self.workers() as f64 * wall.as_secs_f64();
        if denom == 0.0 {
            return 0.0;
        }
        self.total_busy().as_secs_f64() / denom
    }

    /// Load imbalance: busiest worker over mean busy time (1.0 = even).
    pub fn imbalance(&self) -> f64 {
        let n = self.workers();
        if n == 0 {
            return 1.0;
        }
        let total = self.total_busy().as_secs_f64();
        if total == 0.0 {
            return 1.0;
        }
        let max = self
            .busy
            .iter()
            .map(Duration::as_secs_f64)
            .fold(0.0f64, f64::max);
        max / (total / n as f64)
    }

    /// Machine-readable provenance of a pool decode: worker count,
    /// total jobs/blocks, and the recorded metric width + ACS backend
    /// — what `pbvd stream` appends to its resolved-config provenance
    /// line so a measured number is traceable to the kernel that
    /// produced it.
    pub fn to_json(&self) -> crate::json::Json {
        let mut o = crate::json::Json::obj();
        o.set("workers", crate::json::Json::from(self.workers()));
        o.set("jobs", crate::json::Json::from(self.total_jobs() as usize));
        o.set("blocks", crate::json::Json::from(self.total_blocks() as usize));
        o.set(
            "metric_bits",
            crate::json::Json::from(self.metric_bits as usize),
        );
        o.set(
            "backend",
            match self.backend_name() {
                Some(name) => crate::json::Json::from(name),
                None => crate::json::Json::Null,
            },
        );
        o.set(
            "acs_busy_ns",
            crate::json::Json::from(self.total_acs_busy().as_nanos() as usize),
        );
        o.set(
            "tb_busy_ns",
            crate::json::Json::from(self.total_tb_busy().as_nanos() as usize),
        );
        o.set(
            "survivor_ring_bytes",
            crate::json::Json::from(self.survivor_ring_bytes as usize),
        );
        o.set(
            "survivor_ring_stages",
            crate::json::Json::from(self.survivor_ring_stages as usize),
        );
        o.set(
            "survivor_total_stages",
            crate::json::Json::from(self.survivor_total_stages as usize),
        );
        o
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let width = if self.metric_bits > 0 {
            format!(" metric=u{}", self.metric_bits)
        } else {
            String::new()
        };
        let backend = match self.backend_name() {
            Some(name) => format!(" backend={name}"),
            None => String::new(),
        };
        let phases = if self.total_tb_busy() > Duration::ZERO {
            format!(
                " acs={:.2?} tb={:.2?}",
                self.total_acs_busy(),
                self.total_tb_busy()
            )
        } else {
            String::new()
        };
        let ring = if self.survivor_ring_stages > 0 {
            format!(
                " ring={}/{}st",
                self.survivor_ring_stages, self.survivor_total_stages
            )
        } else {
            String::new()
        };
        format!(
            "workers={} jobs={} blocks={} busy={:.2?} imbalance=x{:.2}{width}{backend}{phases}{ring}",
            self.workers(),
            self.total_jobs(),
            self.total_blocks(),
            self.total_busy(),
            self.imbalance()
        )
    }
}

/// Monotonic throughput meter: total units over elapsed wall time.
pub struct RateMeter {
    start: std::time::Instant,
    units: AtomicU64,
}

impl RateMeter {
    pub fn new() -> Self {
        Self {
            start: std::time::Instant::now(),
            units: AtomicU64::new(0),
        }
    }

    pub fn add(&self, n: u64) {
        self.units.fetch_add(n, Ordering::Relaxed);
    }

    pub fn rate_per_sec(&self) -> f64 {
        let dt = self.start.elapsed().as_secs_f64();
        if dt == 0.0 {
            0.0
        } else {
            self.units.load(Ordering::Relaxed) as f64 / dt
        }
    }
}

impl Default for RateMeter {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// Serving QoS (the `pbvd serve` daemon's STATS verb).
// ---------------------------------------------------------------------------

/// Per-stream serving quality-of-service counters: submit→result
/// latency, decoded frames/bits, exact engine busy-time attribution
/// (from [`BatchTimings::per_worker`](crate::coordinator::BatchTimings)
/// shares — the scheduler splits each dispatch's measured busy time
/// over the streams in the coalesced group, so per-stream `busy_ns`
/// sums *exactly* to the pool total), and the decoded-bit rate.
/// Atomic throughout: the scheduler records while STATS readers
/// serialize.
pub struct StreamQos {
    latency: LatencyHistogram,
    frames: AtomicU64,
    bits: AtomicU64,
    busy_ns: AtomicU64,
    rate: RateMeter,
}

impl StreamQos {
    pub fn new() -> Self {
        Self {
            latency: LatencyHistogram::new(),
            frames: AtomicU64::new(0),
            bits: AtomicU64::new(0),
            busy_ns: AtomicU64::new(0),
            rate: RateMeter::new(),
        }
    }

    /// Record one decoded frame: its submit→deliver latency, payload
    /// bits, and this frame's share of the dispatch's exact worker
    /// busy time.
    pub fn record_frame(&self, latency: Duration, bits: u64, busy_ns: u64) {
        self.latency.record(latency);
        self.frames.fetch_add(1, Ordering::Relaxed);
        self.bits.fetch_add(bits, Ordering::Relaxed);
        self.busy_ns.fetch_add(busy_ns, Ordering::Relaxed);
        self.rate.add(bits);
    }

    pub fn frames(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }

    pub fn bits(&self) -> u64 {
        self.bits.load(Ordering::Relaxed)
    }

    pub fn busy_ns(&self) -> u64 {
        self.busy_ns.load(Ordering::Relaxed)
    }

    /// Submit→deliver latency histogram.
    pub fn latency(&self) -> &LatencyHistogram {
        &self.latency
    }

    /// Decoded payload megabits per second since the stream opened.
    pub fn decoded_mbps(&self) -> f64 {
        self.rate.rate_per_sec() / 1e6
    }

    /// The STATS-verb JSON shape of one stream.
    pub fn to_json(&self) -> crate::json::Json {
        use crate::json::Json;
        let mut o = Json::obj();
        o.set("frames", Json::from(self.frames() as usize));
        o.set("bits", Json::from(self.bits() as usize));
        o.set("busy_ns", Json::from(self.busy_ns() as usize));
        o.set(
            "p50_us",
            Json::from(self.latency.quantile(0.50).as_micros() as usize),
        );
        o.set(
            "p99_us",
            Json::from(self.latency.quantile(0.99).as_micros() as usize),
        );
        o.set(
            "mean_us",
            Json::from(self.latency.mean().as_micros() as usize),
        );
        o.set("decoded_mbps", Json::from(self.decoded_mbps()));
        o
    }
}

impl Default for StreamQos {
    fn default() -> Self {
        Self::new()
    }
}

/// Cross-stream coalescing accounting: how full the dispatched lane
/// groups run (the paper's throughput law is batch occupancy) and how
/// often a group actually mixes frames from more than one client
/// stream.  Atomic; shared by the scheduler and STATS readers.
#[derive(Default)]
pub struct CoalesceStats {
    groups: AtomicU64,
    mixed: AtomicU64,
    used_slots: AtomicU64,
    capacity_slots: AtomicU64,
}

impl CoalesceStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one dispatched group: `used` of `capacity` batch slots
    /// filled, drawn from `distinct_streams` client streams.
    pub fn record_group(&self, used: u64, capacity: u64, distinct_streams: u64) {
        self.groups.fetch_add(1, Ordering::Relaxed);
        if distinct_streams >= 2 {
            self.mixed.fetch_add(1, Ordering::Relaxed);
        }
        self.used_slots.fetch_add(used, Ordering::Relaxed);
        self.capacity_slots.fetch_add(capacity, Ordering::Relaxed);
    }

    pub fn groups(&self) -> u64 {
        self.groups.load(Ordering::Relaxed)
    }

    /// Groups whose frames came from at least two distinct streams.
    pub fn mixed_groups(&self) -> u64 {
        self.mixed.load(Ordering::Relaxed)
    }

    /// Mean batch occupancy of every dispatched group (1.0 = every
    /// lane group ran full).
    pub fn fill_ratio(&self) -> f64 {
        let cap = self.capacity_slots.load(Ordering::Relaxed);
        if cap == 0 {
            return 0.0;
        }
        self.used_slots.load(Ordering::Relaxed) as f64 / cap as f64
    }

    pub fn to_json(&self) -> crate::json::Json {
        use crate::json::Json;
        let mut o = Json::obj();
        o.set("groups", Json::from(self.groups() as usize));
        o.set("groups_mixed", Json::from(self.mixed_groups() as usize));
        o.set("fill_ratio", Json::from(self.fill_ratio()));
        o
    }
}

/// Self-healing accounting for the serve path: dispatch retries,
/// supervisor engine degradations, RESUME rebinds, parked sessions,
/// replayed result frames, and overload sheds.  Atomic; shared by the
/// scheduler, the engine supervisor, and STATS readers.
#[derive(Default)]
pub struct RecoveryStats {
    retries: AtomicU64,
    degradations: AtomicU64,
    resumes: AtomicU64,
    parked: AtomicU64,
    replayed: AtomicU64,
    shed: AtomicU64,
}

impl RecoveryStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// A failed group dispatch was retried on the same engine.
    pub fn record_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    /// The supervisor rebuilt the engine one rung down the ladder.
    pub fn record_degradation(&self) {
        self.degradations.fetch_add(1, Ordering::Relaxed);
    }

    /// A RESUME rebound a parked stream to a new connection.
    pub fn record_resume(&self) {
        self.resumes.fetch_add(1, Ordering::Relaxed);
    }

    /// A dying session's stream was parked to await RESUME.
    pub fn record_parked(&self) {
        self.parked.fetch_add(1, Ordering::Relaxed);
    }

    /// Unacked result frames re-sent to a resumed connection.
    pub fn record_replayed(&self, n: u64) {
        self.replayed.fetch_add(n, Ordering::Relaxed);
    }

    /// A submit was refused with `retry_after` because queues were
    /// saturated.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    pub fn degradations(&self) -> u64 {
        self.degradations.load(Ordering::Relaxed)
    }

    pub fn resumes(&self) -> u64 {
        self.resumes.load(Ordering::Relaxed)
    }

    pub fn parked(&self) -> u64 {
        self.parked.load(Ordering::Relaxed)
    }

    pub fn replayed(&self) -> u64 {
        self.replayed.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// True when any recovery machinery has fired at all.
    pub fn any(&self) -> bool {
        self.retries() + self.degradations() + self.resumes() + self.parked() + self.replayed()
            + self.shed()
            > 0
    }

    /// The STATS-verb `recovery` object.
    pub fn to_json(&self) -> crate::json::Json {
        use crate::json::Json;
        let mut o = Json::obj();
        o.set("retries", Json::from(self.retries() as usize));
        o.set("degradations", Json::from(self.degradations() as usize));
        o.set("resumes", Json::from(self.resumes() as usize));
        o.set("parked", Json::from(self.parked() as usize));
        o.set("replayed", Json::from(self.replayed() as usize));
        o.set("shed", Json::from(self.shed() as usize));
        o
    }
}

/// Decode-integrity accounting: shadow-audit coverage, detected
/// divergences, low-confidence blocks, and quarantines.  Atomic;
/// shared by the [`ShadowAuditor`](crate::audit::ShadowAuditor), the
/// engine supervisor, and STATS readers.
#[derive(Default)]
pub struct IntegrityStats {
    audited: AtomicU64,
    violations: AtomicU64,
    margin_mismatches: AtomicU64,
    shed_audits: AtomicU64,
    low_confidence: AtomicU64,
    quarantines: AtomicU64,
    rejected_inputs: AtomicU64,
}

impl IntegrityStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// A sampled block was re-decoded on the golden model.
    pub fn record_audited(&self) {
        self.audited.fetch_add(1, Ordering::Relaxed);
    }

    /// An audited block's decoded words diverged from the golden model.
    pub fn record_violation(&self) {
        self.violations.fetch_add(1, Ordering::Relaxed);
    }

    /// An audited block's words matched but its confidence margin did
    /// not (a metric-path divergence: counted separately because the
    /// payload is still correct).
    pub fn record_margin_mismatch(&self) {
        self.margin_mismatches.fetch_add(1, Ordering::Relaxed);
    }

    /// A sampled block was dropped because the audit queue was full
    /// (the decode path never blocks on auditing).
    pub fn record_shed_audit(&self) {
        self.shed_audits.fetch_add(1, Ordering::Relaxed);
    }

    /// Blocks that decoded with a margin below the configured floor.
    pub fn record_low_confidence(&self, n: u64) {
        self.low_confidence.fetch_add(n, Ordering::Relaxed);
    }

    /// A diverging backend was quarantined (forced down the ladder and
    /// excluded from rebuilds).
    pub fn record_quarantine(&self) {
        self.quarantines.fetch_add(1, Ordering::Relaxed);
    }

    /// A malformed submit (bad geometry / all-erasure frame) was
    /// rejected before reaching an engine.
    pub fn record_rejected_input(&self) {
        self.rejected_inputs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn audited(&self) -> u64 {
        self.audited.load(Ordering::Relaxed)
    }

    pub fn violations(&self) -> u64 {
        self.violations.load(Ordering::Relaxed)
    }

    pub fn margin_mismatches(&self) -> u64 {
        self.margin_mismatches.load(Ordering::Relaxed)
    }

    pub fn shed_audits(&self) -> u64 {
        self.shed_audits.load(Ordering::Relaxed)
    }

    pub fn low_confidence(&self) -> u64 {
        self.low_confidence.load(Ordering::Relaxed)
    }

    pub fn quarantines(&self) -> u64 {
        self.quarantines.load(Ordering::Relaxed)
    }

    pub fn rejected_inputs(&self) -> u64 {
        self.rejected_inputs.load(Ordering::Relaxed)
    }

    /// True when any integrity machinery has fired at all.
    pub fn any(&self) -> bool {
        self.audited()
            + self.violations()
            + self.margin_mismatches()
            + self.shed_audits()
            + self.low_confidence()
            + self.quarantines()
            + self.rejected_inputs()
            > 0
    }

    /// The STATS-verb `integrity` object.
    pub fn to_json(&self) -> crate::json::Json {
        use crate::json::Json;
        let mut o = Json::obj();
        o.set("audited", Json::from(self.audited() as usize));
        o.set("violations", Json::from(self.violations() as usize));
        o.set("margin_mismatches", Json::from(self.margin_mismatches() as usize));
        o.set("shed_audits", Json::from(self.shed_audits() as usize));
        o.set("low_confidence", Json::from(self.low_confidence() as usize));
        o.set("quarantines", Json::from(self.quarantines() as usize));
        o.set("rejected_inputs", Json::from(self.rejected_inputs() as usize));
        o
    }
}

/// Adaptive-dispatch accounting: decisions taken, explore-arm hits,
/// live engine migrations and history-backed width hints.  Atomic;
/// shared by the [`Dispatcher`](crate::plan::Dispatcher), the serve
/// supervisor's planner seam, and STATS readers.
#[derive(Default)]
pub struct PlanStats {
    decisions: AtomicU64,
    explore_hits: AtomicU64,
    migrations: AtomicU64,
    width_hints: AtomicU64,
}

impl PlanStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// The dispatcher picked an arm for a batch shape.
    pub fn record_decision(&self) {
        self.decisions.fetch_add(1, Ordering::Relaxed);
    }

    /// The epsilon-explore draw overrode the best estimate.
    pub fn record_explore_hit(&self) {
        self.explore_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// A live serve engine was swapped to the dispatcher's new pick.
    pub fn record_migration(&self) {
        self.migrations.fetch_add(1, Ordering::Relaxed);
    }

    /// A history-backed width pick replaced a calibration decode.
    pub fn record_width_hint(&self) {
        self.width_hints.fetch_add(1, Ordering::Relaxed);
    }

    pub fn decisions(&self) -> u64 {
        self.decisions.load(Ordering::Relaxed)
    }

    pub fn explore_hits(&self) -> u64 {
        self.explore_hits.load(Ordering::Relaxed)
    }

    pub fn migrations(&self) -> u64 {
        self.migrations.load(Ordering::Relaxed)
    }

    pub fn width_hints(&self) -> u64 {
        self.width_hints.load(Ordering::Relaxed)
    }

    /// True when the planner has made any decision at all.
    pub fn any(&self) -> bool {
        self.decisions() + self.explore_hits() + self.migrations() + self.width_hints() > 0
    }

    /// The STATS-verb `plan` counter object.
    pub fn to_json(&self) -> crate::json::Json {
        use crate::json::Json;
        let mut o = Json::obj();
        o.set("decisions", Json::from(self.decisions() as usize));
        o.set("explore_hits", Json::from(self.explore_hits() as usize));
        o.set("migrations", Json::from(self.migrations() as usize));
        o.set("width_hints", Json::from(self.width_hints() as usize));
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn bucket_monotone() {
        let mut last = 0;
        for us in [1u64, 2, 3, 5, 10, 100, 1000, 10_000, 1_000_000] {
            let b = LatencyHistogram::bucket_of(us);
            assert!(b >= last, "bucket({us}) = {b} < {last}");
            last = b;
        }
    }

    #[test]
    fn quantiles_ordered() {
        let h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99 && p99 <= h.max());
        // log buckets: p50 within a half-octave of 500us
        assert!(p50 >= Duration::from_micros(256) && p50 <= Duration::from_micros(1024));
    }

    #[test]
    fn mean_and_max() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(300));
        assert_eq!(h.mean(), Duration::from_micros(200));
        assert_eq!(h.max(), Duration::from_micros(300));
    }

    #[test]
    fn merge_combines() {
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        a.record(Duration::from_micros(10));
        b.record(Duration::from_micros(1000));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max(), Duration::from_micros(1000));
    }

    #[test]
    fn concurrent_records() {
        let h = Arc::new(LatencyHistogram::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let h = Arc::clone(&h);
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    h.record(Duration::from_micros(i + 1));
                }
            }));
        }
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
        assert!(!h.summary().is_empty());
    }

    #[test]
    fn worker_pool_stats_record_and_diff() {
        let s = WorkerPoolStats::new(3);
        s.record(0, Duration::from_millis(10), 4);
        s.record(1, Duration::from_millis(30), 8);
        let a = s.snapshot();
        s.record(1, Duration::from_millis(20), 2);
        s.record(2, Duration::from_millis(40), 6);
        let b = s.snapshot();
        let d = b.delta_since(&a);
        assert_eq!(d.workers(), 3);
        assert_eq!(d.busy[0], Duration::ZERO);
        assert_eq!(d.busy[1], Duration::from_millis(20));
        assert_eq!(d.busy[2], Duration::from_millis(40));
        assert_eq!(d.total_jobs(), 2);
        assert_eq!(d.total_blocks(), 8);
        assert!(!d.summary().is_empty());
    }

    #[test]
    fn worker_snapshot_utilization_and_imbalance() {
        let snap = WorkerSnapshot {
            busy: vec![Duration::from_millis(50), Duration::from_millis(100)],
            jobs: vec![1, 2],
            blocks: vec![10, 20],
            ..WorkerSnapshot::default()
        };
        // 150ms busy over 2 workers * 100ms wall = 0.75
        let u = snap.utilization(Duration::from_millis(100));
        assert!((u - 0.75).abs() < 1e-9, "utilization {u}");
        // max 100ms / mean 75ms
        let imb = snap.imbalance();
        assert!((imb - 100.0 / 75.0).abs() < 1e-9, "imbalance {imb}");
        // degenerate cases stay finite
        assert_eq!(WorkerSnapshot::default().imbalance(), 1.0);
        assert_eq!(WorkerSnapshot::default().utilization(Duration::ZERO), 0.0);
    }

    #[test]
    fn phase_attribution_travels_through_snapshots() {
        let s = WorkerPoolStats::new(2);
        // worker 0 runs a shard's ACS, worker 1 its traceback
        s.record_acs(0, Duration::from_millis(30), 4);
        s.record_tb(1, Duration::from_millis(10));
        let a = s.snapshot();
        assert_eq!(a.total_busy(), Duration::from_millis(40));
        assert_eq!(a.total_acs_busy(), Duration::from_millis(30));
        assert_eq!(a.total_tb_busy(), Duration::from_millis(10));
        assert_eq!(a.acs_busy[0], Duration::from_millis(30));
        assert_eq!(a.tb_busy[1], Duration::from_millis(10));
        // the shard's job/block counts land on the ACS worker
        assert_eq!(a.total_jobs(), 1);
        assert_eq!(a.total_blocks(), 4);
        assert!(a.summary().contains("acs="));
        // deltas and merges carry phase time
        s.record_tb(0, Duration::from_millis(5));
        let d = s.snapshot().delta_since(&a);
        assert_eq!(d.total_tb_busy(), Duration::from_millis(5));
        assert_eq!(d.total_acs_busy(), Duration::ZERO);
        let mut m = WorkerSnapshot::default();
        m.merge(&a);
        m.merge(&d);
        assert_eq!(m.total_acs_busy(), Duration::from_millis(30));
        assert_eq!(m.total_tb_busy(), Duration::from_millis(15));
        // fused pools show no phase split
        assert!(!WorkerSnapshot::default().summary().contains("acs="));
    }

    #[test]
    fn survivor_footprint_travels_through_snapshots() {
        let s = WorkerPoolStats::new(1);
        assert_eq!(s.survivor_ring_bytes(), 0);
        s.set_survivor_footprint(848, 106, 148);
        let a = s.snapshot();
        assert_eq!(a.survivor_ring_bytes, 848);
        assert_eq!(a.survivor_ring_stages, 106);
        assert_eq!(a.survivor_total_stages, 148);
        assert!(a.survivor_ring_stages < a.survivor_total_stages);
        assert!(a.summary().contains("ring=106/148st"));
        s.record(0, Duration::from_millis(1), 1);
        let d = s.snapshot().delta_since(&a);
        assert_eq!(d.survivor_ring_bytes, 848);
        let mut m = WorkerSnapshot::default();
        m.merge(&a);
        assert_eq!(m.survivor_ring_stages, 106);
        let j = a.to_json();
        let get = |k: &str| j.get(k).and_then(crate::json::Json::as_usize);
        assert_eq!(get("survivor_ring_bytes"), Some(848));
        assert_eq!(get("survivor_ring_stages"), Some(106));
        assert_eq!(get("survivor_total_stages"), Some(148));
        assert_eq!(get("acs_busy_ns"), Some(0));
        assert_eq!(get("tb_busy_ns"), Some(0));
    }

    #[test]
    fn metric_bits_travel_through_snapshots() {
        let s = WorkerPoolStats::new(2);
        assert_eq!(s.metric_bits(), 0);
        s.set_metric_bits(16);
        let a = s.snapshot();
        assert_eq!(a.metric_bits, 16);
        // deltas keep the current width; merges keep the widest
        s.record(0, Duration::from_millis(1), 1);
        let d = s.snapshot().delta_since(&a);
        assert_eq!(d.metric_bits, 16);
        let mut m = WorkerSnapshot::default();
        m.merge(&a);
        assert_eq!(m.metric_bits, 16);
        assert!(a.summary().contains("metric=u16"));
        assert!(!WorkerSnapshot::default().summary().contains("metric="));
    }

    #[test]
    fn backend_code_travels_through_snapshots() {
        use crate::simd::AcsBackend;
        let s = WorkerPoolStats::new(2);
        assert_eq!(s.backend(), 0);
        assert_eq!(s.snapshot().backend_name(), None);
        s.set_backend(AcsBackend::Portable.code());
        let a = s.snapshot();
        assert_eq!(a.backend, AcsBackend::Portable.code());
        assert_eq!(a.backend_name(), Some("portable"));
        // deltas keep the current backend; merges keep the non-zero one
        s.record(0, Duration::from_millis(1), 1);
        let d = s.snapshot().delta_since(&a);
        assert_eq!(d.backend_name(), Some("portable"));
        let mut m = WorkerSnapshot::default();
        m.merge(&a);
        assert_eq!(m.backend_name(), Some("portable"));
        assert!(a.summary().contains("backend=portable"));
        assert!(!WorkerSnapshot::default().summary().contains("backend="));
    }

    #[test]
    fn worker_snapshot_serializes_provenance() {
        use crate::simd::AcsBackend;
        let s = WorkerPoolStats::new(2);
        s.set_metric_bits(16);
        s.set_backend(AcsBackend::Portable.code());
        s.record(0, Duration::from_millis(1), 3);
        s.record(1, Duration::from_millis(2), 5);
        let j = s.snapshot().to_json();
        assert_eq!(j.get("workers").and_then(crate::json::Json::as_usize), Some(2));
        assert_eq!(j.get("jobs").and_then(crate::json::Json::as_usize), Some(2));
        assert_eq!(j.get("blocks").and_then(crate::json::Json::as_usize), Some(8));
        assert_eq!(j.get("metric_bits").and_then(crate::json::Json::as_usize), Some(16));
        assert_eq!(j.get("backend").and_then(crate::json::Json::as_str), Some("portable"));
        // scalar pools record no lane backend
        let j = WorkerSnapshot::default().to_json();
        assert_eq!(j.get("backend"), Some(&crate::json::Json::Null));
    }

    #[test]
    fn worker_pool_concurrent_records() {
        let s = Arc::new(WorkerPoolStats::new(4));
        let mut handles = Vec::new();
        for w in 0..4 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for _ in 0..250 {
                    s.record(w, Duration::from_micros(5), 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = s.snapshot();
        assert_eq!(snap.total_jobs(), 1000);
        assert_eq!(snap.total_blocks(), 1000);
        assert_eq!(snap.total_busy(), Duration::from_micros(5000));
    }

    #[test]
    fn stream_qos_records_and_serializes() {
        let q = StreamQos::new();
        q.record_frame(Duration::from_micros(120), 64, 1_000);
        q.record_frame(Duration::from_micros(480), 64, 3_000);
        assert_eq!(q.frames(), 2);
        assert_eq!(q.bits(), 128);
        assert_eq!(q.busy_ns(), 4_000);
        assert!(q.latency().quantile(0.50) <= q.latency().quantile(0.99));
        let j = q.to_json();
        assert_eq!(j.get("frames").and_then(crate::json::Json::as_usize), Some(2));
        assert_eq!(j.get("bits").and_then(crate::json::Json::as_usize), Some(128));
        assert_eq!(
            j.get("busy_ns").and_then(crate::json::Json::as_usize),
            Some(4_000)
        );
        assert!(j.get("p50_us").is_some() && j.get("p99_us").is_some());
        assert!(j.get("decoded_mbps").and_then(crate::json::Json::as_f64).is_some());
    }

    #[test]
    fn coalesce_stats_fill_and_mixing() {
        let c = CoalesceStats::new();
        assert_eq!(c.fill_ratio(), 0.0);
        c.record_group(16, 16, 3); // full, mixed
        c.record_group(4, 16, 1); // ragged flush, single stream
        assert_eq!(c.groups(), 2);
        assert_eq!(c.mixed_groups(), 1);
        let fill = c.fill_ratio();
        assert!((fill - 20.0 / 32.0).abs() < 1e-9, "fill {fill}");
        let j = c.to_json();
        assert_eq!(j.get("groups").and_then(crate::json::Json::as_usize), Some(2));
        assert_eq!(
            j.get("groups_mixed").and_then(crate::json::Json::as_usize),
            Some(1)
        );
    }

    #[test]
    fn recovery_stats_count_and_serialize() {
        let r = RecoveryStats::new();
        assert!(!r.any());
        r.record_retry();
        r.record_degradation();
        r.record_resume();
        r.record_parked();
        r.record_replayed(3);
        r.record_shed();
        assert!(r.any());
        let j = r.to_json();
        let get = |k: &str| j.get(k).and_then(crate::json::Json::as_usize);
        assert_eq!(get("retries"), Some(1));
        assert_eq!(get("degradations"), Some(1));
        assert_eq!(get("resumes"), Some(1));
        assert_eq!(get("parked"), Some(1));
        assert_eq!(get("replayed"), Some(3));
        assert_eq!(get("shed"), Some(1));
    }

    #[test]
    fn integrity_stats_count_and_serialize() {
        let s = IntegrityStats::new();
        assert!(!s.any());
        s.record_audited();
        s.record_audited();
        s.record_violation();
        s.record_margin_mismatch();
        s.record_shed_audit();
        s.record_low_confidence(4);
        s.record_quarantine();
        s.record_rejected_input();
        assert!(s.any());
        let j = s.to_json();
        let get = |k: &str| j.get(k).and_then(crate::json::Json::as_usize);
        assert_eq!(get("audited"), Some(2));
        assert_eq!(get("violations"), Some(1));
        assert_eq!(get("margin_mismatches"), Some(1));
        assert_eq!(get("shed_audits"), Some(1));
        assert_eq!(get("low_confidence"), Some(4));
        assert_eq!(get("quarantines"), Some(1));
        assert_eq!(get("rejected_inputs"), Some(1));
    }

    #[test]
    fn plan_stats_count_and_serialize() {
        let p = PlanStats::new();
        assert!(!p.any());
        p.record_decision();
        p.record_decision();
        p.record_explore_hit();
        p.record_migration();
        p.record_width_hint();
        assert!(p.any());
        let j = p.to_json();
        let get = |k: &str| j.get(k).and_then(crate::json::Json::as_usize);
        assert_eq!(get("decisions"), Some(2));
        assert_eq!(get("explore_hits"), Some(1));
        assert_eq!(get("migrations"), Some(1));
        assert_eq!(get("width_hints"), Some(1));
    }

    #[test]
    fn rate_meter() {
        let m = RateMeter::new();
        m.add(100);
        std::thread::sleep(Duration::from_millis(20));
        let r = m.rate_per_sec();
        assert!(r > 0.0 && r < 100.0 / 0.02 * 2.0);
    }
}
