//! The per-arch ACS backend seam of the lane-interleaved kernel.
//!
//! PR 2/3 welded the kernel's intrinsics path to one ISA: the only
//! non-portable hook was `Metric::acs_stage_avx2`, selected by a
//! boolean.  The follow-up GPU work on parallel Viterbi decoding
//! (arXiv:2011.09337) shows the `[state][lane]` lockstep layout ports
//! across very different vector ISAs when the stage kernel is
//! expressed ISA-neutrally — the schedule (add, unsigned min, `b < a`
//! survivor mask, running min, subtract-normalize) is fixed; only the
//! register width changes.  This module makes that seam explicit:
//!
//! * [`AcsBackend`] — which stage-kernel implementation runs:
//!   - `Scalar`: the plain per-lane reference loop (always available;
//!     the in-module baseline every other backend is pinned against).
//!   - `Portable`: explicit 128-bit lane-chunk ops (`vadd`/`vmin`/
//!     `vlt_mask` over `Metric::HALF`-lane half-vectors) — the same
//!     schedule as the NEON kernel, written so LLVM autovectorizes it
//!     on any arch.  The default when no intrinsics path applies.
//!   - `Avx2`: 256-bit x86_64 intrinsics (one vector per state row).
//!   - `Neon`: 128-bit aarch64 intrinsics — `vaddq_u32`/`vminq_u32`
//!     (u32) and `vqaddq_u16`/`vminq_u16` (saturating u16) mirror the
//!     AVX2 ops 1:1 on lo/hi half-vectors, masks spliced
//!     `lo | hi << HALF`.
//! * [`BackendChoice`] — the CLI/engine request
//!   (`--simd-backend {auto,scalar,portable,avx2,neon}`), resolved
//!   with a *checked fallback* exactly like `MetricWidth`: a forced
//!   backend that is not available on this host resolves to
//!   [`AcsBackend::detect`], never to an unsound dispatch.  `Auto`
//!   honors the `PBVD_SIMD_BACKEND` env override (how CI forces the
//!   portable path on AVX2 runners).
//!
//! Every backend computes the identical adds, unsigned mins and
//! `b < a` tie-break (equal metrics keep the even predecessor), so
//! decisions are bit-identical; `rust/tests/backend_conformance.rs`
//! and the shared `testutil::oracle_matrix` harness pin this against
//! the golden `CpuEngine` for every backend available on the build
//! host.

use super::{Metric, SelMask, MAX_LANES};
use crate::trellis::Trellis;

/// Largest `Metric::HALF` (lanes per 128-bit half-vector: 8 for u16).
const MAX_HALF: usize = 8;

/// Which ACS stage-kernel implementation a lane-interleaved kernel
/// runs.  See the module docs for what each backend is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AcsBackend {
    /// Plain per-lane reference loop (always available).
    Scalar,
    /// Explicit 128-bit lane-chunk ops, autovectorized (always
    /// available; the default without intrinsics).
    Portable,
    /// 256-bit x86_64 intrinsics (`simd-intrinsics` feature + runtime
    /// AVX2 detection).
    Avx2,
    /// 128-bit aarch64 intrinsics (`simd-intrinsics` feature; NEON is
    /// architecturally mandatory on aarch64 but still
    /// runtime-verified).
    Neon,
}

/// Every backend the seam knows, available or not (the conformance
/// suites filter through [`AcsBackend::is_available`]).
pub const ALL_BACKENDS: [AcsBackend; 4] = [
    AcsBackend::Scalar,
    AcsBackend::Portable,
    AcsBackend::Avx2,
    AcsBackend::Neon,
];

fn avx2_available() -> bool {
    #[cfg(all(target_arch = "x86_64", feature = "simd-intrinsics"))]
    {
        is_x86_feature_detected!("avx2")
    }
    #[cfg(not(all(target_arch = "x86_64", feature = "simd-intrinsics")))]
    {
        false
    }
}

fn neon_available() -> bool {
    #[cfg(all(target_arch = "aarch64", feature = "simd-intrinsics"))]
    {
        std::arch::is_aarch64_feature_detected!("neon")
    }
    #[cfg(not(all(target_arch = "aarch64", feature = "simd-intrinsics")))]
    {
        false
    }
}

impl AcsBackend {
    /// Stable name used in engine names, pool stats, bench JSON and
    /// the CLI (`--simd-backend`).
    pub fn name(self) -> &'static str {
        match self {
            AcsBackend::Scalar => "scalar",
            AcsBackend::Portable => "portable",
            AcsBackend::Avx2 => "avx2",
            AcsBackend::Neon => "neon",
        }
    }

    /// Parse a backend name (the non-`auto` CLI forms).
    pub fn parse(s: &str) -> Option<AcsBackend> {
        ALL_BACKENDS.iter().copied().find(|b| b.name() == s)
    }

    /// Wire code recorded in [`WorkerPoolStats`](crate::metrics::WorkerPoolStats)
    /// / bench JSON (`0` is reserved for "no lane backend" — scalar
    /// pools and PJRT engines).
    pub fn code(self) -> u64 {
        match self {
            AcsBackend::Scalar => 1,
            AcsBackend::Portable => 2,
            AcsBackend::Avx2 => 3,
            AcsBackend::Neon => 4,
        }
    }

    /// Inverse of [`AcsBackend::code`] (`0`/unknown → `None`).
    pub fn from_code(code: u64) -> Option<AcsBackend> {
        ALL_BACKENDS.iter().copied().find(|b| b.code() == code)
    }

    /// Whether this backend can run on this host *as compiled*
    /// (arch + `simd-intrinsics` feature + runtime CPU detection).
    pub fn is_available(self) -> bool {
        match self {
            AcsBackend::Scalar | AcsBackend::Portable => true,
            AcsBackend::Avx2 => avx2_available(),
            AcsBackend::Neon => neon_available(),
        }
    }

    /// Best available backend: the arch's intrinsics path when
    /// compiled in and detected, the portable lane-chunk path
    /// otherwise.
    pub fn detect() -> AcsBackend {
        if avx2_available() {
            AcsBackend::Avx2
        } else if neon_available() {
            AcsBackend::Neon
        } else {
            AcsBackend::Portable
        }
    }

    /// Every backend available on this host, `Scalar` first (the
    /// conformance suites' iteration order).
    pub fn available() -> Vec<AcsBackend> {
        ALL_BACKENDS
            .iter()
            .copied()
            .filter(|b| b.is_available())
            .collect()
    }
}

impl std::fmt::Display for AcsBackend {
    /// The stable [`name`](AcsBackend::name); round-trip stable with
    /// [`FromStr`](std::str::FromStr).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for AcsBackend {
    type Err = crate::config::ConfigError;

    /// Strict parsing of a concrete backend name (no `auto`; that is
    /// [`BackendChoice`]'s vocabulary).
    fn from_str(s: &str) -> Result<AcsBackend, Self::Err> {
        AcsBackend::parse(s).ok_or_else(|| {
            crate::config::ConfigError::new(format!(
                "invalid ACS backend {s:?} (expected scalar, portable, avx2 or neon)"
            ))
        })
    }
}

/// A backend *request* (CLI `--simd-backend`): `Auto` resolves via
/// runtime detection (with the `PBVD_SIMD_BACKEND` env override), a
/// forced backend resolves to itself when available and falls back to
/// [`AcsBackend::detect`] otherwise — the engine never dispatches to a
/// backend the host cannot run, and the resolved pick is visible in
/// the engine name and pool stats.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    Auto,
    Forced(AcsBackend),
}

impl BackendChoice {
    /// Parse the CLI form: `auto` or a backend name.
    pub fn parse(s: &str) -> Option<BackendChoice> {
        if s == "auto" {
            return Some(BackendChoice::Auto);
        }
        AcsBackend::parse(s).map(BackendChoice::Forced)
    }

    /// Resolve against the real environment (see
    /// [`BackendChoice`] for the fallback rules).
    pub fn resolve(self) -> AcsBackend {
        self.resolve_with(std::env::var("PBVD_SIMD_BACKEND").ok().as_deref())
    }

    /// The single rule for interpreting a `PBVD_SIMD_BACKEND`-style
    /// env value: a parseable AND available backend name overrides;
    /// anything else (unset, unknown, unavailable on this host) is
    /// ignored.  Shared by [`resolve`](BackendChoice::resolve) and
    /// `DecoderConfig::resolved_with`, so the engine and the recorded
    /// provenance can never drift apart.
    pub(crate) fn env_override(env: Option<&str>) -> Option<AcsBackend> {
        env.and_then(AcsBackend::parse).filter(|b| b.is_available())
    }

    /// [`resolve`](BackendChoice::resolve) with an explicit env-var
    /// value, so the policy is unit-testable without mutating process
    /// state.
    fn resolve_with(self, env: Option<&str>) -> AcsBackend {
        match self {
            BackendChoice::Forced(b) if b.is_available() => b,
            BackendChoice::Forced(_) => AcsBackend::detect(),
            BackendChoice::Auto => {
                BackendChoice::env_override(env).unwrap_or_else(AcsBackend::detect)
            }
        }
    }
}

impl std::fmt::Display for BackendChoice {
    /// The CLI form: `auto` or the forced backend's name; round-trip
    /// stable with [`FromStr`](std::str::FromStr).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendChoice::Auto => f.write_str("auto"),
            BackendChoice::Forced(b) => f.write_str(b.name()),
        }
    }
}

impl std::str::FromStr for BackendChoice {
    type Err = crate::config::ConfigError;

    /// Strict CLI parsing (`--simd-backend`), with the error message
    /// the CLI used to hand-roll.
    fn from_str(s: &str) -> Result<BackendChoice, Self::Err> {
        BackendChoice::parse(s).ok_or_else(|| {
            crate::config::ConfigError::new(format!(
                "invalid --simd-backend {s:?} (expected auto, scalar, portable, avx2 or neon)"
            ))
        })
    }
}

// ---------------------------------------------------------------------------
// Stage dispatch.
// ---------------------------------------------------------------------------

/// One butterfly ACS stage over lane-interleaved metrics through the
/// selected backend.  `backend` must be available on this host (the
/// engines only store resolved backends); an intrinsics variant that
/// was compiled out falls back to the portable kernel rather than
/// faulting.
#[inline]
pub(crate) fn acs_stage<M: Metric>(
    backend: AcsBackend,
    t: &Trellis,
    pm: &[M],
    new_pm: &mut [M],
    bm: &[M],
    dw_row: &mut [M::Sel],
) {
    match backend {
        AcsBackend::Scalar => acs_stage_scalar(t, pm, new_pm, bm, dw_row),
        AcsBackend::Portable => acs_stage_portable(t, pm, new_pm, bm, dw_row),
        #[cfg(all(target_arch = "x86_64", feature = "simd-intrinsics"))]
        // SAFETY: `Avx2` only resolves after a successful
        // `is_x86_feature_detected!("avx2")`; buffer shapes are fixed
        // at kernel construction.
        AcsBackend::Avx2 => unsafe { M::acs_stage_avx2(t, pm, new_pm, bm, dw_row) },
        #[cfg(all(target_arch = "aarch64", feature = "simd-intrinsics"))]
        // SAFETY: `Neon` only resolves after a successful
        // `is_aarch64_feature_detected!("neon")`; buffer shapes are
        // fixed at kernel construction.
        AcsBackend::Neon => unsafe { M::acs_stage_neon(t, pm, new_pm, bm, dw_row) },
        // Intrinsics variants compiled out on this arch: unreachable
        // through engine resolution, but degrade soundly if hit.
        _ => acs_stage_portable(t, pm, new_pm, bm, dw_row),
    }
}

// ---------------------------------------------------------------------------
// Scalar backend: the plain per-lane reference loop.
// ---------------------------------------------------------------------------

/// One butterfly ACS stage, scalar backend: straight per-lane loops
/// with the trellis label lookups hoisted out (one table read serves a
/// whole lane-group), the decision mask assembled in a register and
/// stored with a single word write.  This is the semantic reference
/// the portable/AVX2/NEON backends are pinned against.
pub(crate) fn acs_stage_scalar<M: Metric>(
    t: &Trellis,
    pm: &[M],
    new_pm: &mut [M],
    bm: &[M],
    dw_row: &mut [M::Sel],
) {
    let l = M::LANES;
    let half = t.n_states / 2;
    let mut minv = [M::MAX; MAX_LANES];
    let (top, bot) = new_pm.split_at_mut(half * l);
    for j in 0..half {
        let pe = &pm[2 * j * l..][..l];
        let po = &pm[(2 * j + 1) * l..][..l];
        let b_t0 = &bm[t.cw_top0[j] as usize * l..][..l];
        let b_t1 = &bm[t.cw_top1[j] as usize * l..][..l];
        let b_b0 = &bm[t.cw_bot0[j] as usize * l..][..l];
        let b_b1 = &bm[t.cw_bot1[j] as usize * l..][..l];
        let out_t = &mut top[j * l..][..l];
        let mut sel_top = 0u32;
        for lane in 0..l {
            let a = pe[lane].add_metric(b_t0[lane]);
            let b = po[lane].add_metric(b_t1[lane]);
            let m = a.min(b);
            sel_top |= ((b < a) as u32) << lane;
            out_t[lane] = m;
            minv[lane] = minv[lane].min(m);
        }
        let out_b = &mut bot[j * l..][..l];
        let mut sel_bot = 0u32;
        for lane in 0..l {
            let a2 = pe[lane].add_metric(b_b0[lane]);
            let b2 = po[lane].add_metric(b_b1[lane]);
            let m2 = a2.min(b2);
            sel_bot |= ((b2 < a2) as u32) << lane;
            out_b[lane] = m2;
            minv[lane] = minv[lane].min(m2);
        }
        dw_row[j] = M::Sel::from_mask(sel_top);
        dw_row[j + half] = M::Sel::from_mask(sel_bot);
    }
    // per-lane min-normalization; lane-contiguous, vectorizes cleanly
    for chunk in new_pm.chunks_exact_mut(l) {
        for lane in 0..l {
            chunk[lane] = chunk[lane].sub_norm(minv[lane]);
        }
    }
}

// ---------------------------------------------------------------------------
// Portable backend: explicit 128-bit lane-chunk ops.
// ---------------------------------------------------------------------------
//
// Each helper models one 128-bit vector instruction over a
// `Metric::HALF`-lane chunk (4 u32 or 8 u16 lanes); the stage kernel
// below composes them in exactly the schedule the NEON kernel issues
// per half-vector, so the two are the same program at different
// binding times — and the shape is what LLVM autovectorizes on any
// arch.

/// `out[i] = a[i] + b[i]` (saturating for u16) — one `vaddq`/`vqaddq`.
#[inline(always)]
fn vadd<M: Metric>(a: &[M], b: &[M], out: &mut [M]) {
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = x.add_metric(y);
    }
}

/// `out[i] = min(a[i], b[i])` — one `vminq`.
#[inline(always)]
fn vmin<M: Metric>(a: &[M], b: &[M], out: &mut [M]) {
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = x.min(y);
    }
}

/// `acc[i] = min(acc[i], v[i])` — the running-minimum `vminq`.
#[inline(always)]
fn vmin_acc<M: Metric>(acc: &mut [M], v: &[M]) {
    for (a, &x) in acc.iter_mut().zip(v) {
        *a = (*a).min(x);
    }
}

/// Per-lane `b < a` collapsed to a chunk bitmask — one `vcltq` plus
/// the mask-collapse (`movemask` / bit-weighted horizontal add).
#[inline(always)]
fn vlt_mask<M: Metric>(b: &[M], a: &[M]) -> u32 {
    let mut mask = 0u32;
    for (i, (&x, &y)) in b.iter().zip(a).enumerate() {
        mask |= ((x < y) as u32) << i;
    }
    mask
}

/// One butterfly ACS stage, portable backend: each state row's
/// `M::LANES` lanes are processed as 128-bit half-vector chunks of
/// `M::HALF` lanes, survivor masks spliced `lo | hi << HALF` — the
/// NEON schedule, ISA-neutral.  Arithmetic (and therefore every
/// decision) is identical to the scalar backend.
pub(crate) fn acs_stage_portable<M: Metric>(
    t: &Trellis,
    pm: &[M],
    new_pm: &mut [M],
    bm: &[M],
    dw_row: &mut [M::Sel],
) {
    let l = M::LANES;
    let h = M::HALF;
    let half = t.n_states / 2;
    let mut minv = [M::MAX; MAX_LANES];
    let mut a = [M::MAX; MAX_HALF];
    let mut b = [M::MAX; MAX_HALF];
    let (top, bot) = new_pm.split_at_mut(half * l);
    for j in 0..half {
        let pe = &pm[2 * j * l..][..l];
        let po = &pm[(2 * j + 1) * l..][..l];
        let b_t0 = &bm[t.cw_top0[j] as usize * l..][..l];
        let b_t1 = &bm[t.cw_top1[j] as usize * l..][..l];
        let b_b0 = &bm[t.cw_bot0[j] as usize * l..][..l];
        let b_b1 = &bm[t.cw_bot1[j] as usize * l..][..l];
        let out_t = &mut top[j * l..][..l];
        let out_b = &mut bot[j * l..][..l];
        let mut sel_top = 0u32;
        let mut sel_bot = 0u32;
        for c in (0..l).step_by(h) {
            // one half-vector worth of lanes [c, c + h)
            vadd(&pe[c..c + h], &b_t0[c..c + h], &mut a[..h]);
            vadd(&po[c..c + h], &b_t1[c..c + h], &mut b[..h]);
            sel_top |= vlt_mask(&b[..h], &a[..h]) << c;
            vmin(&a[..h], &b[..h], &mut out_t[c..c + h]);
            vmin_acc(&mut minv[c..c + h], &out_t[c..c + h]);

            vadd(&pe[c..c + h], &b_b0[c..c + h], &mut a[..h]);
            vadd(&po[c..c + h], &b_b1[c..c + h], &mut b[..h]);
            sel_bot |= vlt_mask(&b[..h], &a[..h]) << c;
            vmin(&a[..h], &b[..h], &mut out_b[c..c + h]);
            vmin_acc(&mut minv[c..c + h], &out_b[c..c + h]);
        }
        dw_row[j] = M::Sel::from_mask(sel_top);
        dw_row[j + half] = M::Sel::from_mask(sel_bot);
    }
    for chunk in new_pm.chunks_exact_mut(l) {
        for lane in 0..l {
            chunk[lane] = chunk[lane].sub_norm(minv[lane]);
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 backend (x86_64, 256-bit vectors).
// ---------------------------------------------------------------------------

#[cfg(all(target_arch = "x86_64", feature = "simd-intrinsics"))]
pub(crate) mod avx2 {
    use crate::trellis::Trellis;
    use core::arch::x86_64::*;

    /// One full ACS stage with AVX2 over u32 metrics: each 256-bit op
    /// covers all 8 lanes of one state.  Arithmetic is identical to
    /// the scalar/portable backends — same u32 adds, same *unsigned*
    /// min, same tie-break (equal metrics keep the even predecessor,
    /// because the survivor bit is `b < a`) — so decisions are
    /// bit-identical.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support
    /// (`is_x86_feature_detected!("avx2")`) and pass `pm`/`new_pm` of
    /// `n_states * 8` u32s and `bm` covering every codeword label.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn acs_stage_u32(
        t: &Trellis,
        pm: &[u32],
        new_pm: &mut [u32],
        bm: &[u32],
        dw_row: &mut [u8],
    ) {
        const L: usize = 8;
        debug_assert_eq!(pm.len(), t.n_states * L);
        debug_assert_eq!(new_pm.len(), t.n_states * L);
        let half = t.n_states / 2;
        let pmp = pm.as_ptr();
        let bmp = bm.as_ptr();
        let np = new_pm.as_mut_ptr();
        let mut minv = _mm256_set1_epi32(-1); // u32::MAX in every lane
        for j in 0..half {
            let pe = _mm256_loadu_si256(pmp.add(2 * j * L) as *const __m256i);
            let po = _mm256_loadu_si256(pmp.add((2 * j + 1) * L) as *const __m256i);
            let bt0 = _mm256_loadu_si256(bmp.add(t.cw_top0[j] as usize * L) as *const __m256i);
            let bt1 = _mm256_loadu_si256(bmp.add(t.cw_top1[j] as usize * L) as *const __m256i);
            let a = _mm256_add_epi32(pe, bt0);
            let b = _mm256_add_epi32(po, bt1);
            let m = _mm256_min_epu32(a, b);
            // survivor bit per lane: (b < a) == !(min == a); movemask
            // collects the 8 lane sign bits into one byte in one op
            let keep_a = _mm256_movemask_ps(_mm256_castsi256_ps(_mm256_cmpeq_epi32(m, a)));
            _mm256_storeu_si256(np.add(j * L) as *mut __m256i, m);
            minv = _mm256_min_epu32(minv, m);
            dw_row[j] = (!keep_a) as u8;

            let bb0 = _mm256_loadu_si256(bmp.add(t.cw_bot0[j] as usize * L) as *const __m256i);
            let bb1 = _mm256_loadu_si256(bmp.add(t.cw_bot1[j] as usize * L) as *const __m256i);
            let a2 = _mm256_add_epi32(pe, bb0);
            let b2 = _mm256_add_epi32(po, bb1);
            let m2 = _mm256_min_epu32(a2, b2);
            let keep_a2 = _mm256_movemask_ps(_mm256_castsi256_ps(_mm256_cmpeq_epi32(m2, a2)));
            _mm256_storeu_si256(np.add((j + half) * L) as *mut __m256i, m2);
            minv = _mm256_min_epu32(minv, m2);
            dw_row[j + half] = (!keep_a2) as u8;
        }
        // per-lane min-normalization
        for st in 0..2 * half {
            let p = np.add(st * L) as *mut __m256i;
            _mm256_storeu_si256(p, _mm256_sub_epi32(_mm256_loadu_si256(p), minv));
        }
    }

    /// Collapse a 16-lane i16 compare result (0xFFFF / 0x0000 per
    /// lane) into one bit per lane: saturate-pack the words to bytes
    /// (`packs` interleaves the two 128-bit halves, so lanes 0-7 land
    /// in bytes 0-7 and lanes 8-15 in bytes 16-23) and movemask the
    /// byte sign bits.
    #[target_feature(enable = "avx2")]
    unsafe fn lane_mask_u16(cmp: __m256i) -> u16 {
        let packed = _mm256_packs_epi16(cmp, cmp);
        let mm = _mm256_movemask_epi8(packed) as u32;
        ((mm & 0x0000_00FF) | ((mm >> 8) & 0x0000_FF00)) as u16
    }

    /// One full ACS stage with AVX2 over u16 metrics: 16 lanes per
    /// 256-bit vector — twice the ACS throughput of the u32 stage.
    /// Uses *saturating* unsigned adds (`_mm256_adds_epu16`), exactly
    /// like `u16::saturating_add` in the scalar/portable backends; the
    /// spread bound guarantees saturation never fires for admissible
    /// configurations, so decisions are bit-identical to the u32 and
    /// golden kernels.  Same unsigned min, same `b < a` tie-break.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support and pass `pm`/`new_pm`
    /// of `n_states * 16` u16s and `bm` covering every codeword label.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn acs_stage_u16(
        t: &Trellis,
        pm: &[u16],
        new_pm: &mut [u16],
        bm: &[u16],
        dw_row: &mut [u16],
    ) {
        const L: usize = 16;
        debug_assert_eq!(pm.len(), t.n_states * L);
        debug_assert_eq!(new_pm.len(), t.n_states * L);
        let half = t.n_states / 2;
        let pmp = pm.as_ptr();
        let bmp = bm.as_ptr();
        let np = new_pm.as_mut_ptr();
        let mut minv = _mm256_set1_epi16(-1); // u16::MAX in every lane
        for j in 0..half {
            let pe = _mm256_loadu_si256(pmp.add(2 * j * L) as *const __m256i);
            let po = _mm256_loadu_si256(pmp.add((2 * j + 1) * L) as *const __m256i);
            let bt0 = _mm256_loadu_si256(bmp.add(t.cw_top0[j] as usize * L) as *const __m256i);
            let bt1 = _mm256_loadu_si256(bmp.add(t.cw_top1[j] as usize * L) as *const __m256i);
            let a = _mm256_adds_epu16(pe, bt0);
            let b = _mm256_adds_epu16(po, bt1);
            let m = _mm256_min_epu16(a, b);
            dw_row[j] = !lane_mask_u16(_mm256_cmpeq_epi16(m, a));
            _mm256_storeu_si256(np.add(j * L) as *mut __m256i, m);
            minv = _mm256_min_epu16(minv, m);

            let bb0 = _mm256_loadu_si256(bmp.add(t.cw_bot0[j] as usize * L) as *const __m256i);
            let bb1 = _mm256_loadu_si256(bmp.add(t.cw_bot1[j] as usize * L) as *const __m256i);
            let a2 = _mm256_adds_epu16(pe, bb0);
            let b2 = _mm256_adds_epu16(po, bb1);
            let m2 = _mm256_min_epu16(a2, b2);
            dw_row[j + half] = !lane_mask_u16(_mm256_cmpeq_epi16(m2, a2));
            _mm256_storeu_si256(np.add((j + half) * L) as *mut __m256i, m2);
            minv = _mm256_min_epu16(minv, m2);
        }
        // per-lane min-normalization (no underflow: every lane >= min)
        for st in 0..2 * half {
            let p = np.add(st * L) as *mut __m256i;
            _mm256_storeu_si256(p, _mm256_sub_epi16(_mm256_loadu_si256(p), minv));
        }
    }
}

// ---------------------------------------------------------------------------
// NEON backend (aarch64, 128-bit half-vectors).
// ---------------------------------------------------------------------------

#[cfg(all(target_arch = "aarch64", feature = "simd-intrinsics"))]
pub(crate) mod neon {
    use crate::trellis::Trellis;
    use core::arch::aarch64::*;

    /// Collapse a `uint32x4_t` compare result (all-ones / all-zero per
    /// lane) into a 4-bit mask: AND with the lane weights (1, 2, 4, 8)
    /// and horizontal-add.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn lane_mask_u32(cmp: uint32x4_t) -> u32 {
        const BITS: [u32; 4] = [1, 2, 4, 8];
        vaddvq_u32(vandq_u32(cmp, vld1q_u32(BITS.as_ptr())))
    }

    /// Collapse a `uint16x8_t` compare result into an 8-bit mask (lane
    /// weights 1..128, horizontal-add).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn lane_mask_u16(cmp: uint16x8_t) -> u32 {
        const BITS: [u16; 8] = [1, 2, 4, 8, 16, 32, 64, 128];
        u32::from(vaddvq_u16(vandq_u16(cmp, vld1q_u16(BITS.as_ptr()))))
    }

    /// One full ACS stage with NEON over u32 metrics: each 8-lane
    /// state row is two `uint32x4_t` half-vectors scheduled exactly
    /// like one AVX2 256-bit vector (lanes 0-3 = lo, 4-7 = hi; masks
    /// splice `lo | hi << 4`).  `vaddq_u32`/`vminq_u32` mirror
    /// `_mm256_add_epi32`/`_mm256_min_epu32` 1:1; the survivor bit is
    /// `b < a` (`vcltq_u32`), the same tie-break (ties keep the even
    /// predecessor) as every other backend, so decisions are
    /// bit-identical.
    ///
    /// # Safety
    /// Caller must have verified NEON support
    /// (`is_aarch64_feature_detected!("neon")`) and pass `pm`/`new_pm`
    /// of `n_states * 8` u32s and `bm` covering every codeword label.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn acs_stage_u32(
        t: &Trellis,
        pm: &[u32],
        new_pm: &mut [u32],
        bm: &[u32],
        dw_row: &mut [u8],
    ) {
        const L: usize = 8;
        const H: usize = 4;
        debug_assert_eq!(pm.len(), t.n_states * L);
        debug_assert_eq!(new_pm.len(), t.n_states * L);
        let half = t.n_states / 2;
        let pmp = pm.as_ptr();
        let bmp = bm.as_ptr();
        let np = new_pm.as_mut_ptr();
        let mut min_lo = vdupq_n_u32(u32::MAX);
        let mut min_hi = vdupq_n_u32(u32::MAX);
        for j in 0..half {
            let pe_lo = vld1q_u32(pmp.add(2 * j * L));
            let pe_hi = vld1q_u32(pmp.add(2 * j * L + H));
            let po_lo = vld1q_u32(pmp.add((2 * j + 1) * L));
            let po_hi = vld1q_u32(pmp.add((2 * j + 1) * L + H));

            let bt0 = bmp.add(t.cw_top0[j] as usize * L);
            let bt1 = bmp.add(t.cw_top1[j] as usize * L);
            let a_lo = vaddq_u32(pe_lo, vld1q_u32(bt0));
            let a_hi = vaddq_u32(pe_hi, vld1q_u32(bt0.add(H)));
            let b_lo = vaddq_u32(po_lo, vld1q_u32(bt1));
            let b_hi = vaddq_u32(po_hi, vld1q_u32(bt1.add(H)));
            let m_lo = vminq_u32(a_lo, b_lo);
            let m_hi = vminq_u32(a_hi, b_hi);
            dw_row[j] = (lane_mask_u32(vcltq_u32(b_lo, a_lo))
                | (lane_mask_u32(vcltq_u32(b_hi, a_hi)) << H)) as u8;
            vst1q_u32(np.add(j * L), m_lo);
            vst1q_u32(np.add(j * L + H), m_hi);
            min_lo = vminq_u32(min_lo, m_lo);
            min_hi = vminq_u32(min_hi, m_hi);

            let bb0 = bmp.add(t.cw_bot0[j] as usize * L);
            let bb1 = bmp.add(t.cw_bot1[j] as usize * L);
            let a2_lo = vaddq_u32(pe_lo, vld1q_u32(bb0));
            let a2_hi = vaddq_u32(pe_hi, vld1q_u32(bb0.add(H)));
            let b2_lo = vaddq_u32(po_lo, vld1q_u32(bb1));
            let b2_hi = vaddq_u32(po_hi, vld1q_u32(bb1.add(H)));
            let m2_lo = vminq_u32(a2_lo, b2_lo);
            let m2_hi = vminq_u32(a2_hi, b2_hi);
            dw_row[j + half] = (lane_mask_u32(vcltq_u32(b2_lo, a2_lo))
                | (lane_mask_u32(vcltq_u32(b2_hi, a2_hi)) << H)) as u8;
            vst1q_u32(np.add((j + half) * L), m2_lo);
            vst1q_u32(np.add((j + half) * L + H), m2_hi);
            min_lo = vminq_u32(min_lo, m2_lo);
            min_hi = vminq_u32(min_hi, m2_hi);
        }
        // per-lane min-normalization
        for st in 0..2 * half {
            let p = np.add(st * L);
            vst1q_u32(p, vsubq_u32(vld1q_u32(p), min_lo));
            vst1q_u32(p.add(H), vsubq_u32(vld1q_u32(p.add(H)), min_hi));
        }
    }

    /// One full ACS stage with NEON over u16 metrics: each 16-lane
    /// state row is two `uint16x8_t` half-vectors.  `vqaddq_u16` is
    /// the exact saturating-add counterpart of `_mm256_adds_epu16` /
    /// `u16::saturating_add` (the spread bound keeps it exact), with
    /// `vminq_u16` mins and the `b < a` (`vcltq_u16`) tie-break —
    /// decisions bit-identical to every other backend.
    ///
    /// # Safety
    /// Caller must have verified NEON support and pass `pm`/`new_pm`
    /// of `n_states * 16` u16s and `bm` covering every codeword label.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn acs_stage_u16(
        t: &Trellis,
        pm: &[u16],
        new_pm: &mut [u16],
        bm: &[u16],
        dw_row: &mut [u16],
    ) {
        const L: usize = 16;
        const H: usize = 8;
        debug_assert_eq!(pm.len(), t.n_states * L);
        debug_assert_eq!(new_pm.len(), t.n_states * L);
        let half = t.n_states / 2;
        let pmp = pm.as_ptr();
        let bmp = bm.as_ptr();
        let np = new_pm.as_mut_ptr();
        let mut min_lo = vdupq_n_u16(u16::MAX);
        let mut min_hi = vdupq_n_u16(u16::MAX);
        for j in 0..half {
            let pe_lo = vld1q_u16(pmp.add(2 * j * L));
            let pe_hi = vld1q_u16(pmp.add(2 * j * L + H));
            let po_lo = vld1q_u16(pmp.add((2 * j + 1) * L));
            let po_hi = vld1q_u16(pmp.add((2 * j + 1) * L + H));

            let bt0 = bmp.add(t.cw_top0[j] as usize * L);
            let bt1 = bmp.add(t.cw_top1[j] as usize * L);
            let a_lo = vqaddq_u16(pe_lo, vld1q_u16(bt0));
            let a_hi = vqaddq_u16(pe_hi, vld1q_u16(bt0.add(H)));
            let b_lo = vqaddq_u16(po_lo, vld1q_u16(bt1));
            let b_hi = vqaddq_u16(po_hi, vld1q_u16(bt1.add(H)));
            let m_lo = vminq_u16(a_lo, b_lo);
            let m_hi = vminq_u16(a_hi, b_hi);
            dw_row[j] = (lane_mask_u16(vcltq_u16(b_lo, a_lo))
                | (lane_mask_u16(vcltq_u16(b_hi, a_hi)) << H)) as u16;
            vst1q_u16(np.add(j * L), m_lo);
            vst1q_u16(np.add(j * L + H), m_hi);
            min_lo = vminq_u16(min_lo, m_lo);
            min_hi = vminq_u16(min_hi, m_hi);

            let bb0 = bmp.add(t.cw_bot0[j] as usize * L);
            let bb1 = bmp.add(t.cw_bot1[j] as usize * L);
            let a2_lo = vqaddq_u16(pe_lo, vld1q_u16(bb0));
            let a2_hi = vqaddq_u16(pe_hi, vld1q_u16(bb0.add(H)));
            let b2_lo = vqaddq_u16(po_lo, vld1q_u16(bb1));
            let b2_hi = vqaddq_u16(po_hi, vld1q_u16(bb1.add(H)));
            let m2_lo = vminq_u16(a2_lo, b2_lo);
            let m2_hi = vminq_u16(a2_hi, b2_hi);
            dw_row[j + half] = (lane_mask_u16(vcltq_u16(b2_lo, a2_lo))
                | (lane_mask_u16(vcltq_u16(b2_hi, a2_hi)) << H)) as u16;
            vst1q_u16(np.add((j + half) * L), m2_lo);
            vst1q_u16(np.add((j + half) * L + H), m2_hi);
            min_lo = vminq_u16(min_lo, m2_lo);
            min_hi = vminq_u16(min_hi, m2_hi);
        }
        // per-lane min-normalization (no underflow: every lane >= min)
        for st in 0..2 * half {
            let p = np.add(st * L);
            vst1q_u16(p, vsubq_u16(vld1q_u16(p), min_lo));
            vst1q_u16(p.add(H), vsubq_u16(vld1q_u16(p.add(H)), min_hi));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_and_codes_round_trip() {
        for b in ALL_BACKENDS {
            assert_eq!(AcsBackend::parse(b.name()), Some(b));
            assert_eq!(AcsBackend::from_code(b.code()), Some(b));
            assert!(b.code() != 0, "0 is reserved for scalar pools");
        }
        assert_eq!(AcsBackend::parse("avx512"), None);
        assert_eq!(AcsBackend::from_code(0), None);
        assert_eq!(AcsBackend::from_code(99), None);
    }

    #[test]
    fn display_from_str_round_trips_every_variant() {
        for b in ALL_BACKENDS {
            assert_eq!(b.to_string().parse::<AcsBackend>().unwrap(), b);
            let c = BackendChoice::Forced(b);
            assert_eq!(c.to_string().parse::<BackendChoice>().unwrap(), c);
        }
        assert_eq!(
            BackendChoice::Auto.to_string().parse::<BackendChoice>().unwrap(),
            BackendChoice::Auto
        );
        assert!("auto".parse::<AcsBackend>().is_err(), "auto is a choice, not a backend");
        assert!("avx512".parse::<BackendChoice>().is_err());
    }

    #[test]
    fn choice_parses_auto_and_backend_names() {
        assert_eq!(BackendChoice::parse("auto"), Some(BackendChoice::Auto));
        assert_eq!(
            BackendChoice::parse("portable"),
            Some(BackendChoice::Forced(AcsBackend::Portable))
        );
        assert_eq!(
            BackendChoice::parse("neon"),
            Some(BackendChoice::Forced(AcsBackend::Neon))
        );
        assert_eq!(BackendChoice::parse("fast"), None);
    }

    #[test]
    fn detection_is_coherent() {
        // whatever detect() picks must itself be available, and the
        // always-portable backends are always listed
        let d = AcsBackend::detect();
        assert!(d.is_available(), "{d:?}");
        let avail = AcsBackend::available();
        assert!(avail.contains(&AcsBackend::Scalar));
        assert!(avail.contains(&AcsBackend::Portable));
        assert!(avail.contains(&d));
    }

    #[test]
    fn forced_unavailable_backend_falls_back_to_detect() {
        // at most one of AVX2/NEON can be available in any one build;
        // the other must fall back
        for b in [AcsBackend::Avx2, AcsBackend::Neon] {
            let resolved = BackendChoice::Forced(b).resolve_with(None);
            if b.is_available() {
                assert_eq!(resolved, b);
            } else {
                assert_eq!(resolved, AcsBackend::detect());
            }
            assert!(resolved.is_available());
        }
        assert_eq!(
            BackendChoice::Forced(AcsBackend::Scalar).resolve_with(None),
            AcsBackend::Scalar
        );
    }

    #[test]
    fn auto_honors_env_override_when_available() {
        let auto = BackendChoice::Auto;
        assert_eq!(auto.resolve_with(Some("scalar")), AcsBackend::Scalar);
        assert_eq!(auto.resolve_with(Some("portable")), AcsBackend::Portable);
        // unknown or unavailable env values fall back to detection
        assert_eq!(auto.resolve_with(Some("bogus")), AcsBackend::detect());
        assert_eq!(auto.resolve_with(None), AcsBackend::detect());
        for name in ["avx2", "neon"] {
            let b = AcsBackend::parse(name).unwrap();
            let want = if b.is_available() { b } else { AcsBackend::detect() };
            assert_eq!(auto.resolve_with(Some(name)), want);
        }
    }
}
