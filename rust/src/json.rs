//! Minimal JSON parser/serializer (serde is unavailable offline —
//! DESIGN.md §3).  Supports the full JSON grammar minus exotic number
//! forms; good enough for `artifacts/manifest.json`, trellis exports
//! and experiment reports.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors -----------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    // ---- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]` style access via a dotted path ("a.b.2").
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = match cur {
                Json::Obj(m) => m.get(part)?,
                Json::Arr(a) => a.get(part.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of integers (for trellis table import).
    pub fn as_i64_vec(&self) -> Option<Vec<i64>> {
        self.as_arr()?.iter().map(|v| v.as_i64()).collect()
    }

    /// Nested array-of-arrays of integers.
    pub fn as_i64_mat(&self) -> Option<Vec<Vec<i64>>> {
        self.as_arr()?.iter().map(|v| v.as_i64_vec()).collect()
    }

    // ---- set ---------------------------------------------------------------

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }

    // ---- parse -------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // ---- serialize ---------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    v.write(out, indent, pretty);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                let n = m.len();
                for (i, (k, v)) in m.iter().enumerate() {
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    Json::Str(k.clone()).write(out, indent, pretty);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                    if i + 1 < n {
                        out.push(',');
                    }
                }
                if pretty && n > 0 {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= 1;
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => {
                    self.pos -= 1;
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => {
                    self.pos -= 1;
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        // surrogate pairs
                        if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone surrogate"));
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c =
                                    self.bump().ok_or_else(|| self.err("bad \\u"))?;
                                low = low * 16
                                    + (c as char)
                                        .to_digit(16)
                                        .ok_or_else(|| self.err("bad hex"))?;
                            }
                            code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                        }
                        s.push(
                            char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy raw bytes
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = start + len;
                    if self.pos > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" 42 ").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.path("a.2.b"), Some(&Json::Null));
        assert_eq!(v.path("a.1").unwrap().as_i64(), Some(2));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"k":"v","n":null},"t":true}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, pretty);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn int_matrix_helpers() {
        let v = Json::parse("[[1,2],[3,4]]").unwrap();
        assert_eq!(v.as_i64_mat().unwrap(), vec![vec![1, 2], vec![3, 4]]);
        assert_eq!(Json::parse("[1,\"x\"]").unwrap().as_i64_vec(), None);
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo — 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — 世界"));
    }
}
