//! The streaming PBVD coordinator — the paper's system contribution
//! (Sec. III-A / Fig. 2) as a Rust orchestrator.
//!
//! A continuous LLR stream is framed into overlapping parallel blocks
//! (biting length 2L between neighbours), gathered into batches of
//! `B = N_t` PBs, and pushed through `N_s` pipeline *lanes* — the
//! CUDA-stream analogue — each running `pack -> K1 -> K2 -> unpack`
//! against the AOT-compiled PJRT executables.  Outputs are reassembled
//! in stream order.
//!
//! Engines (the Table III matrix):
//! * [`TwoKernelEngine`]  — optimized decoder: i8 input, K1 + K2
//!   executables, bit-packed output (paper's "optimized").
//! * [`FusedEngine`]      — K1+K2 in one executable (ablation A3).
//! * [`OrigEngine`]       — "original decoder": f32 input, single
//!   kernel, one i32 per output bit, state-based BM.
//! * [`CpuEngine`]        — the CPU golden model behind the same trait
//!   (used for oracle tests and artifact-free operation).
//! * [`par::ParCpuEngine`](crate::par::ParCpuEngine) — the sharded
//!   multi-threaded butterfly-ACS backend (bit-identical to
//!   `CpuEngine`, `N_w`-way parallel across a batch's PBs).
//! * [`simd::SimdCpuEngine`](crate::simd::SimdCpuEngine) — the
//!   lane-interleaved SIMD backend: a lane-group of PBs (8 at u32
//!   metrics, 16 at u16 — autotuned at construction) advances through
//!   the trellis in lockstep per worker, lane-groups sharded across
//!   the pool (bit-identical to `CpuEngine`; auto-selected when
//!   `batch >= simd::LANES`).
//!
//! All of these are constructed through the unified
//! [`DecoderConfig`](crate::config::DecoderConfig) factory
//! ([`build_engine`](crate::config::DecoderConfig::build_engine) /
//! [`build_coordinator`](crate::config::DecoderConfig::build_coordinator)).
//! The free selection functions that used to live here
//! (`best_available_coordinator`, `cpu_engine_for_workers`,
//! `cpu_engine_for_workers_cfg`) were deprecated in 0.3 and removed
//! in 0.4.

use crate::channel::{pack_bits, unpack_bits};
use crate::pipeline::{run_pipeline, Stage};
use crate::runtime::{Executable, HostTensor, Registry};
use crate::trellis::Trellis;
use crate::viterbi::CpuPbvdDecoder;
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Engine abstraction.
// ---------------------------------------------------------------------------

/// Per-batch phase timings (the Table III columns).
#[derive(Clone, Debug, Default)]
pub struct BatchTimings {
    /// Host-side input marshalling (H2D analogue).
    pub pack: Duration,
    /// Forward kernel (K1) execution.
    pub k1: Duration,
    /// Traceback kernel (K2) execution.
    pub k2: Duration,
    /// Host-side output marshalling (D2H analogue).
    pub unpack: Duration,
    /// Bytes pushed to the device per batch (U1 accounting).
    pub h2d_bytes: usize,
    /// Bytes fetched from the device per batch (U2 accounting).
    pub d2h_bytes: usize,
    /// Exact per-worker attribution of THIS batch's decode, for
    /// engines that shard across a pool.  Carried per call (not a
    /// cumulative-counter delta), so it stays correct when several
    /// streams share one engine concurrently.
    pub per_worker: Option<crate::metrics::WorkerSnapshot>,
    /// Per-PB decode-confidence margins of THIS batch, in batch
    /// order: the runner-up final path metric of each block (the
    /// winner is 0 after min-normalization — see
    /// [`ForwardResult::margin`](crate::viterbi::ForwardResult::margin)).
    /// Bit-identical across every CPU engine/width/backend; empty for
    /// the PJRT engines, which do not surface metrics yet.
    pub margins: Vec<u32>,
}

impl BatchTimings {
    pub fn total(&self) -> Duration {
        self.pack + self.k1 + self.k2 + self.unpack
    }

    /// Accumulate another batch's phase timings and attribution.
    /// `margins` are deliberately NOT concatenated: batches complete
    /// out of order under pipelining, so per-block margins must be
    /// reassembled in stream order by the caller (the coordinator
    /// keys them by `Frame::first_block`), never by summation order.
    pub fn add(&mut self, o: &BatchTimings) {
        self.pack += o.pack;
        self.k1 += o.k1;
        self.k2 += o.k2;
        self.unpack += o.unpack;
        self.h2d_bytes += o.h2d_bytes;
        self.d2h_bytes += o.d2h_bytes;
        if let Some(ow) = &o.per_worker {
            match &mut self.per_worker {
                Some(w) => w.merge(ow),
                None => self.per_worker = Some(ow.clone()),
            }
        }
    }
}

/// A batch decoder: `B` parallel blocks of `T = D + 2L` stages each.
pub trait DecodeEngine: Send + Sync {
    /// Decode one batch.  `llr_i8` is `[B, T, R]` row-major quantized
    /// LLRs.  Returns bit-packed decoded payload `[B, D/32]` u32.
    fn decode_batch(&self, llr_i8: &[i8]) -> Result<(Vec<u32>, BatchTimings)>;

    /// Decode one batch from a shared buffer.  Engines that shard work
    /// across a thread pool (`par`, `simd`) override this to hand the
    /// buffer to their workers as `Arc` clones — zero input copies per
    /// batch.  The default delegates to [`decode_batch`]
    /// (one borrow, still no copy for single-threaded engines).
    ///
    /// [`decode_batch`]: DecodeEngine::decode_batch
    fn decode_batch_shared(&self, llr_i8: &Arc<[i8]>) -> Result<(Vec<u32>, BatchTimings)> {
        self.decode_batch(llr_i8)
    }
    fn batch(&self) -> usize;
    fn block(&self) -> usize;
    fn depth(&self) -> usize;
    fn r(&self) -> usize;
    fn name(&self) -> String;

    fn total(&self) -> usize {
        self.block() + 2 * self.depth()
    }

    /// Cumulative engine-lifetime per-worker pool counters, when the
    /// engine shards work across a thread pool (`par::ParCpuEngine`);
    /// `None` for single-threaded and PJRT engines.  Per-stream
    /// attribution travels in `BatchTimings::per_worker` instead.
    fn worker_snapshot(&self) -> Option<crate::metrics::WorkerSnapshot> {
        None
    }

    /// Install (or clear, with `None`) a fault-injection plan on the
    /// engine's execution seams (see
    /// [`serve::faults`](crate::serve::faults)).  Default: no seams,
    /// ignore — only the pool-backed engines (`par`, `simd`) forward
    /// the plan to their worker loops.
    fn install_fault_plan(&self, _plan: Option<Arc<crate::serve::faults::FaultPlan>>) {}
}

// ---------------------------------------------------------------------------
// PJRT engines.
// ---------------------------------------------------------------------------

/// Optimized two-kernel decoder (paper K1 + K2, i8 in, packed bits out).
pub struct TwoKernelEngine {
    fwd: Arc<Executable>,
    tb: Arc<Executable>,
    r: usize,
}

impl TwoKernelEngine {
    pub fn from_registry(
        reg: &Registry,
        code: &str,
        batch: usize,
        block: usize,
        depth: usize,
    ) -> Result<TwoKernelEngine> {
        let fwd = reg.load_variant("forward", code, batch, block, depth)?;
        let tb = reg.load_variant("traceback", code, batch, block, depth)?;
        let r = fwd.meta.inputs[0].shape[2];
        Ok(TwoKernelEngine { fwd, tb, r })
    }
}

impl DecodeEngine for TwoKernelEngine {
    fn decode_batch(&self, llr_i8: &[i8]) -> Result<(Vec<u32>, BatchTimings)> {
        let mut t = BatchTimings::default();
        let in_spec = &self.fwd.meta.inputs[0];
        if llr_i8.len() != in_spec.numel() {
            bail!(
                "batch size mismatch: got {} LLRs, artifact wants {}",
                llr_i8.len(),
                in_spec.numel()
            );
        }
        let t0 = Instant::now();
        let input = HostTensor::from_i8(&in_spec.shape, llr_i8);
        t.pack = t0.elapsed();
        t.h2d_bytes = input.bytes.len();

        let t0 = Instant::now();
        let fwd_out = self.fwd.run(&[input])?;
        t.k1 = t0.elapsed();

        let t0 = Instant::now();
        // sp tensor feeds K2 directly; pm is a diagnostic output
        let tb_out = self.tb.run(&[fwd_out[0].clone()])?;
        t.k2 = t0.elapsed();

        let t0 = Instant::now();
        let bits = tb_out[0].to_u32();
        t.unpack = t0.elapsed();
        t.d2h_bytes = tb_out[0].bytes.len();
        Ok((bits, t))
    }

    fn batch(&self) -> usize {
        self.fwd.meta.batch
    }
    fn block(&self) -> usize {
        self.fwd.meta.block
    }
    fn depth(&self) -> usize {
        self.fwd.meta.depth
    }
    fn r(&self) -> usize {
        self.r
    }
    fn name(&self) -> String {
        format!("pjrt-2k:{}", self.fwd.meta.name)
    }
}

/// Fused single-executable decoder (ablation A3).
pub struct FusedEngine {
    exe: Arc<Executable>,
    r: usize,
}

impl FusedEngine {
    pub fn from_registry(
        reg: &Registry,
        code: &str,
        batch: usize,
        block: usize,
        depth: usize,
    ) -> Result<FusedEngine> {
        let exe = reg.load_variant("fused", code, batch, block, depth)?;
        let r = exe.meta.inputs[0].shape[2];
        Ok(FusedEngine { exe, r })
    }
}

impl DecodeEngine for FusedEngine {
    fn decode_batch(&self, llr_i8: &[i8]) -> Result<(Vec<u32>, BatchTimings)> {
        let mut t = BatchTimings::default();
        let in_spec = &self.exe.meta.inputs[0];
        if llr_i8.len() != in_spec.numel() {
            bail!("batch size mismatch");
        }
        let t0 = Instant::now();
        let input = HostTensor::from_i8(&in_spec.shape, llr_i8);
        t.pack = t0.elapsed();
        t.h2d_bytes = input.bytes.len();
        let t0 = Instant::now();
        let out = self.exe.run(&[input])?;
        t.k1 = t0.elapsed();
        let t0 = Instant::now();
        let bits = out[0].to_u32();
        t.unpack = t0.elapsed();
        t.d2h_bytes = out[0].bytes.len();
        Ok((bits, t))
    }

    fn batch(&self) -> usize {
        self.exe.meta.batch
    }
    fn block(&self) -> usize {
        self.exe.meta.block
    }
    fn depth(&self) -> usize {
        self.exe.meta.depth
    }
    fn r(&self) -> usize {
        self.r
    }
    fn name(&self) -> String {
        format!("pjrt-fused:{}", self.exe.meta.name)
    }
}

/// The paper's "original decoder" baseline: one kernel, f32 soft input
/// (4x H2D bytes), state-based BM, one i32 per decoded bit (32x D2H).
pub struct OrigEngine {
    exe: Arc<Executable>,
    r: usize,
}

impl OrigEngine {
    pub fn from_registry(
        reg: &Registry,
        code: &str,
        batch: usize,
        block: usize,
        depth: usize,
    ) -> Result<OrigEngine> {
        let exe = reg.load_variant("orig", code, batch, block, depth)?;
        let r = exe.meta.inputs[0].shape[2];
        Ok(OrigEngine { exe, r })
    }
}

impl DecodeEngine for OrigEngine {
    fn decode_batch(&self, llr_i8: &[i8]) -> Result<(Vec<u32>, BatchTimings)> {
        let mut t = BatchTimings::default();
        let in_spec = &self.exe.meta.inputs[0];
        if llr_i8.len() != in_spec.numel() {
            bail!("batch size mismatch");
        }
        // "unpacked" H2D: full f32 soft values
        let t0 = Instant::now();
        let f32_data: Vec<f32> = llr_i8.iter().map(|&x| x as f32).collect();
        let input = HostTensor::from_f32(&in_spec.shape, &f32_data);
        t.pack = t0.elapsed();
        t.h2d_bytes = input.bytes.len();
        let t0 = Instant::now();
        let out = self.exe.run(&[input])?;
        t.k1 = t0.elapsed();
        // "unpacked" D2H: one i32 per bit, packed on the host afterwards
        let t0 = Instant::now();
        let per_bit = out[0].to_i32();
        let bytes: Vec<u8> = per_bit.iter().map(|&b| b as u8).collect();
        let packed = pack_bits(&bytes);
        t.unpack = t0.elapsed();
        t.d2h_bytes = out[0].bytes.len();
        Ok((packed, t))
    }

    fn batch(&self) -> usize {
        self.exe.meta.batch
    }
    fn block(&self) -> usize {
        self.exe.meta.block
    }
    fn depth(&self) -> usize {
        self.exe.meta.depth
    }
    fn r(&self) -> usize {
        self.r
    }
    fn name(&self) -> String {
        format!("pjrt-orig:{}", self.exe.meta.name)
    }
}

/// CPU golden engine (no artifacts required).
pub struct CpuEngine {
    dec: CpuPbvdDecoder,
    batch: usize,
}

impl CpuEngine {
    pub fn new(trellis: &Trellis, batch: usize, block: usize, depth: usize) -> CpuEngine {
        CpuEngine {
            dec: CpuPbvdDecoder::new(trellis, block, depth),
            batch,
        }
    }
}

impl DecodeEngine for CpuEngine {
    fn decode_batch(&self, llr_i8: &[i8]) -> Result<(Vec<u32>, BatchTimings)> {
        let mut t = BatchTimings::default();
        let r = self.dec.trellis().r;
        let tt = self.dec.total();
        let per_pb = tt * r;
        if llr_i8.len() != self.batch * per_pb {
            bail!("batch size mismatch");
        }
        let t0 = Instant::now();
        let words_per_pb = self.dec.block.div_ceil(32);
        let mut out = Vec::with_capacity(self.batch * words_per_pb);
        let mut pb = vec![0i32; per_pb];
        for b in 0..self.batch {
            for (dst, &src) in pb.iter_mut().zip(&llr_i8[b * per_pb..(b + 1) * per_pb]) {
                *dst = src as i32;
            }
            let (bits, margin) = self.dec.decode_block_with_margin(&pb);
            t.margins.push(margin);
            out.extend(pack_bits(&bits));
        }
        t.k1 = t0.elapsed();
        Ok((out, t))
    }

    fn batch(&self) -> usize {
        self.batch
    }
    fn block(&self) -> usize {
        self.dec.block
    }
    fn depth(&self) -> usize {
        self.dec.depth
    }
    fn r(&self) -> usize {
        self.dec.trellis().r
    }
    fn name(&self) -> String {
        format!("cpu:b{}", self.batch)
    }
}

// ---------------------------------------------------------------------------
// Stream framing.
// ---------------------------------------------------------------------------

/// One batch of PBs cut from the stream, ready for an engine.
#[derive(Clone, Debug)]
pub struct Frame {
    /// Index of the first decode block covered by this batch.
    pub first_block: usize,
    /// How many of the batch's B block slots carry real payload.
    pub used_blocks: usize,
    /// `[B, T, R]` quantized LLRs (zero-padded at stream edges/tail).
    /// Shared so sharding engines dispatch it to workers without
    /// copying (`DecodeEngine::decode_batch_shared`).
    pub llr_i8: Arc<[i8]>,
}

/// Frame a quantized LLR stream into PB batches for an engine geometry.
///
/// Saturating i32 -> i8 conversion is applied (the quantizer already
/// bounds values for q <= 8; wider quantizers saturate here).
pub fn frame_stream(
    llr: &[i32],
    r: usize,
    block: usize,
    depth: usize,
    batch: usize,
) -> Vec<Frame> {
    let n_bits = llr.len() / r;
    assert_eq!(llr.len(), n_bits * r, "LLR stream not a multiple of R");
    let total = block + 2 * depth;
    let per_pb = total * r;
    let n_blocks = n_bits.div_ceil(block).max(1);
    let n_batches = n_blocks.div_ceil(batch);
    // §Perf: saturate-convert the whole stream to i8 ONCE, then each PB
    // is a single slice copy (neighbouring PBs overlap by 2L stages, so
    // per-PB conversion would redo ~2L*R casts per block boundary).
    let stream_i8: Vec<i8> = llr.iter().map(|&x| x.clamp(-128, 127) as i8).collect();
    let mut frames = Vec::with_capacity(n_batches);
    for bi in 0..n_batches {
        let first_block = bi * batch;
        let used = batch.min(n_blocks - first_block);
        // build the batch in place inside the Arc (zero-filled once =
        // the edge/tail padding), so no engine ever copies it again:
        // single-threaded engines borrow it, sharding engines clone
        // the Arc out to their workers
        let mut shared: Arc<[i8]> = std::iter::repeat(0i8).take(batch * per_pb).collect();
        let buf = Arc::get_mut(&mut shared).expect("freshly built Arc is unique");
        for slot in 0..used {
            let blk = first_block + slot;
            let begin = blk as isize * block as isize - depth as isize;
            let end = begin + total as isize;
            // clip [begin, end) to the stream, memcpy the interior
            let s0 = begin.max(0) as usize;
            let s1 = (end.min(n_bits as isize)).max(0) as usize;
            if s1 > s0 {
                let dst_off = slot * per_pb + (s0 as isize - begin) as usize * r;
                buf[dst_off..dst_off + (s1 - s0) * r]
                    .copy_from_slice(&stream_i8[s0 * r..s1 * r]);
            }
        }
        frames.push(Frame {
            first_block,
            used_blocks: used,
            llr_i8: shared,
        });
    }
    frames
}

// ---------------------------------------------------------------------------
// The coordinator.
// ---------------------------------------------------------------------------

/// Aggregate statistics of one stream decode.
#[derive(Clone, Debug, Default)]
pub struct StreamStats {
    pub n_bits: usize,
    pub n_batches: usize,
    pub lanes: usize,
    pub wall: Duration,
    /// Sums across batches (overlapped wall time is `wall`).
    pub phases: BatchTimings,
    /// Per-worker busy/job counters accumulated during this stream,
    /// when the engine runs a sharded worker pool.
    pub per_worker: Option<crate::metrics::WorkerSnapshot>,
    /// Per-block decode-confidence margins in STREAM order (block 0
    /// first), reassembled from out-of-order batch completions and
    /// truncated to real payload blocks.  Empty when the engine does
    /// not surface margins (PJRT backends).
    pub margins: Vec<u32>,
}

impl StreamStats {
    /// End-to-end decoded throughput (info bits / wall second).
    pub fn throughput_mbps(&self) -> f64 {
        self.n_bits as f64 / self.wall.as_secs_f64() / 1e6
    }

    /// Smallest per-block confidence margin of the stream, or `None`
    /// when the engine surfaced no margins.
    pub fn min_margin(&self) -> Option<u32> {
        self.margins.iter().copied().min()
    }

    /// How many blocks decoded with a margin strictly below `floor`.
    pub fn low_confidence_blocks(&self, floor: u32) -> usize {
        self.margins.iter().filter(|&&m| m < floor).count()
    }

    /// Kernel throughput S_k = decoded bits / summed kernel time.
    pub fn kernel_throughput_mbps(&self) -> f64 {
        let k = (self.phases.k1 + self.phases.k2).as_secs_f64();
        if k == 0.0 {
            0.0
        } else {
            self.n_bits as f64 / k / 1e6
        }
    }
}

/// Streaming decoder: framing + lanes + reassembly over any engine.
pub struct StreamCoordinator {
    pub engine: Arc<dyn DecodeEngine>,
    /// Pipeline lanes (the paper's N_s CUDA streams). 1 = synchronous.
    pub lanes: usize,
    /// Bounded input-queue capacity (backpressure depth).
    pub queue_cap: usize,
    /// Per-batch end-to-end latency distribution (serving-style metric).
    pub batch_latency: Arc<crate::metrics::LatencyHistogram>,
    /// Adaptive-dispatch recorder: when planning is enabled the
    /// config factory attaches the dispatcher and this stream's batch
    /// shape here, and every decoded batch feeds one throughput
    /// observation back into the performance history.
    pub plan: Option<(Arc<crate::plan::Dispatcher>, crate::plan::BatchShape)>,
}

impl StreamCoordinator {
    pub fn new(engine: Arc<dyn DecodeEngine>, lanes: usize) -> StreamCoordinator {
        StreamCoordinator {
            engine,
            lanes: lanes.max(1),
            queue_cap: 2 * lanes.max(1),
            batch_latency: Arc::new(crate::metrics::LatencyHistogram::new()),
            plan: None,
        }
    }

    /// Decode a quantized LLR stream (`n_bits * R` values) into
    /// `n_bits` bits plus pipeline statistics.
    pub fn decode_stream(&self, llr: &[i32]) -> Result<(Vec<u8>, StreamStats)> {
        let eng = &self.engine;
        let (r, d, l, b) = (eng.r(), eng.block(), eng.depth(), eng.batch());
        let n_bits = llr.len() / r;
        let frames = frame_stream(llr, r, d, l, b);
        let n_batches = frames.len();
        let words_per_pb = d.div_ceil(32);

        type Item = (Frame, Option<Result<(Vec<u32>, BatchTimings)>>);
        let engine = Arc::clone(eng);
        let hist = Arc::clone(&self.batch_latency);
        let stage = Stage::new("decode", move |(frame, _): Item| {
            let t0 = Instant::now();
            // shared dispatch: sharding engines fan the Arc out to
            // their workers, so a batch costs zero input copies.  A
            // panicking engine is caught here and surfaced as a typed
            // batch error — letting it unwind would kill the pipeline
            // lane thread and silently drop every batch it still held.
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                engine.decode_batch_shared(&frame.llr_i8)
            }))
            .unwrap_or_else(|p| {
                let msg = p
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".into());
                Err(anyhow::anyhow!("decode stage panicked: {msg}"))
            });
            hist.record(t0.elapsed());
            (frame, Some(res))
        });

        let items: Vec<Item> = frames.into_iter().map(|f| (f, None)).collect();
        let t0 = Instant::now();
        let results = run_pipeline(items, vec![stage], self.lanes, self.queue_cap);
        let wall = t0.elapsed();
        if results.len() != n_batches {
            bail!(
                "pipeline returned {} of {n_batches} batches (a lane died mid-stream)",
                results.len()
            );
        }

        let mut out = vec![0u8; n_bits];
        let mut phases = BatchTimings::default();
        // the engine name (hence arm + backend) is fixed for the whole
        // stream, so classify once and observe per batch below
        let plan_obs = self.plan.as_ref().and_then(|(dsp, shape)| {
            let name = eng.name();
            crate::plan::Arm::for_engine_name(&name).map(|arm| {
                let backend = crate::plan::backend_of_engine_name(&name).to_string();
                (dsp, shape, arm, backend)
            })
        });
        // (first_block, per-PB margins) per batch; batches complete out
        // of order under pipelining, so stream order is restored below.
        let mut margin_parts: Vec<(usize, Vec<u32>)> = Vec::with_capacity(n_batches);
        for (_idx, (frame, res)) in results {
            // a missing stage result is a pipeline bug, not a decode
            // error — but it must fail the stream, not panic it
            let Some(res) = res else {
                bail!("pipeline returned a batch whose decode stage never ran");
            };
            let (words, mut t) = res?;
            if !t.margins.is_empty() {
                t.margins.truncate(frame.used_blocks);
                margin_parts.push((frame.first_block, std::mem::take(&mut t.margins)));
            }
            phases.add(&t);
            if let Some((dsp, shape, arm, backend)) = &plan_obs {
                let secs = t.total().as_secs_f64();
                if secs > 0.0 {
                    let mbps = (frame.used_blocks * d) as f64 / secs / 1e6;
                    dsp.observe(shape, *arm, backend, mbps);
                }
            }
            for slot in 0..frame.used_blocks {
                let blk = frame.first_block + slot;
                let bits = unpack_bits(
                    &words[slot * words_per_pb..(slot + 1) * words_per_pb],
                    d,
                );
                let start = blk * d;
                if start >= n_bits {
                    continue;
                }
                let take = d.min(n_bits - start);
                out[start..start + take].copy_from_slice(&bits[..take]);
            }
        }
        // per-stream worker attribution = sum of this stream's own
        // batch attributions (exact even when engines are shared)
        let per_worker = phases.per_worker.take();
        margin_parts.sort_unstable_by_key(|(first_block, _)| *first_block);
        let margins: Vec<u32> = margin_parts.into_iter().flat_map(|(_, m)| m).collect();
        Ok((
            out,
            StreamStats {
                n_bits,
                n_batches,
                lanes: self.lanes,
                wall,
                phases,
                per_worker,
                margins,
            },
        ))
    }
}

impl StreamDecoderForBer for StreamCoordinator {}

/// Marker trait so the coordinator plugs into the BER harness.
pub trait StreamDecoderForBer {}

impl crate::ber::StreamDecoder for StreamCoordinator {
    fn decode_stream(&self, llr: &[i32]) -> Result<Vec<u8>> {
        Ok(StreamCoordinator::decode_stream(self, llr)?.0)
    }
    fn rate(&self) -> f64 {
        1.0 / self.engine.r() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoder::ConvEncoder;
    use crate::rng::Xoshiro256;

    fn clean_llrs(t: &Trellis, bits: &[u8], amp: i32) -> Vec<i32> {
        let mut e = ConvEncoder::new(t);
        e.encode(bits)
            .iter()
            .map(|&b| if b == 0 { amp } else { -amp })
            .collect()
    }

    #[test]
    fn framing_covers_stream_exactly() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let mut rng = Xoshiro256::seeded(31);
        let n = 1000usize;
        let bits: Vec<u8> = (0..n).map(|_| rng.next_bit()).collect();
        let llr = clean_llrs(&t, &bits, 8);
        let frames = frame_stream(&llr, 2, 64, 42, 4);
        // 1000 bits / 64 = 15.6 -> 16 blocks -> 4 batches of 4
        assert_eq!(frames.len(), 4);
        assert_eq!(frames[0].used_blocks, 4);
        assert_eq!(frames[3].used_blocks, 4);
        assert_eq!(frames[0].llr_i8.len(), 4 * (64 + 84) * 2);
        // interior samples match the quantized stream
        let total = 64 + 2 * 42;
        let f1 = &frames[1]; // blocks 4..8, block 4 starts at bit 256
        let begin = 4 * 64 - 42;
        for s in 0..total {
            let src = begin + s;
            assert_eq!(
                f1.llr_i8[s * 2] as i32,
                llr[src * 2],
                "stage {s}"
            );
        }
    }

    #[test]
    fn framing_zero_pads_edges() {
        let llr = vec![5i32; 2 * 100];
        let frames = frame_stream(&llr, 2, 64, 42, 2);
        let f0 = &frames[0];
        // first 42 stages of PB 0 precede the stream -> zeros
        for s in 0..42 {
            assert_eq!(f0.llr_i8[s * 2], 0);
            assert_eq!(f0.llr_i8[s * 2 + 1], 0);
        }
        assert_eq!(f0.llr_i8[42 * 2], 5);
    }

    #[test]
    fn cpu_engine_stream_matches_reference_decoder() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let mut rng = Xoshiro256::seeded(32);
        let n = 700usize;
        let bits: Vec<u8> = (0..n).map(|_| rng.next_bit()).collect();
        let mut llr = clean_llrs(&t, &bits, 8);
        for x in llr.iter_mut() {
            *x += (rng.next_below(7) as i32) - 3;
        }
        let reference = CpuPbvdDecoder::new(&t, 64, 42).decode_stream(&llr);
        for lanes in [1usize, 2, 4] {
            let eng = CpuEngine::new(&t, 4, 64, 42);
            let coord = StreamCoordinator::new(Arc::new(eng), lanes);
            let (out, stats) = coord.decode_stream(&llr).unwrap();
            assert_eq!(out, reference, "lanes={lanes}");
            assert_eq!(stats.n_bits, n);
            assert!(stats.wall > Duration::ZERO);
        }
    }

    #[test]
    fn cpu_engine_recovers_clean_payload() {
        let t = Trellis::preset("k3").unwrap();
        let mut rng = Xoshiro256::seeded(33);
        let n = 500usize;
        let bits: Vec<u8> = (0..n).map(|_| rng.next_bit()).collect();
        let llr = clean_llrs(&t, &bits, 8);
        let eng = CpuEngine::new(&t, 8, 32, 15);
        let coord = StreamCoordinator::new(Arc::new(eng), 3);
        let (out, _) = coord.decode_stream(&llr).unwrap();
        assert_eq!(out, bits);
    }

    #[test]
    fn par_engine_stream_matches_reference_decoder() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let mut rng = Xoshiro256::seeded(35);
        let n = 900usize;
        let bits: Vec<u8> = (0..n).map(|_| rng.next_bit()).collect();
        let mut llr = clean_llrs(&t, &bits, 8);
        for x in llr.iter_mut() {
            *x += (rng.next_below(7) as i32) - 3;
        }
        let reference = CpuPbvdDecoder::new(&t, 64, 42).decode_stream(&llr);
        for (lanes, workers) in [(1usize, 2usize), (2, 4), (3, 1)] {
            let eng = crate::par::ParCpuEngine::new(&t, 4, 64, 42, workers);
            let coord = StreamCoordinator::new(Arc::new(eng), lanes);
            let (out, stats) = coord.decode_stream(&llr).unwrap();
            assert_eq!(out, reference, "lanes={lanes} workers={workers}");
            let pw = stats.per_worker.expect("par engine reports worker stats");
            assert_eq!(pw.workers(), workers);
            assert!(pw.total_blocks() > 0);
        }
    }

    /// Engine that panics on one batch — drives the decode_stream
    /// seam where a panicking stage used to kill the pipeline lane
    /// (and the `expect("stage ran")` un-wound the whole stream).
    struct PanickingEngine {
        inner: CpuEngine,
        calls: std::sync::atomic::AtomicUsize,
        panic_at: usize,
    }

    impl DecodeEngine for PanickingEngine {
        fn decode_batch(&self, llr_i8: &[i8]) -> Result<(Vec<u32>, BatchTimings)> {
            let n = self
                .calls
                .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            if n == self.panic_at {
                panic!("injected engine panic (batch {n})");
            }
            self.inner.decode_batch(llr_i8)
        }
        fn batch(&self) -> usize {
            self.inner.batch()
        }
        fn block(&self) -> usize {
            self.inner.block()
        }
        fn depth(&self) -> usize {
            self.inner.depth()
        }
        fn r(&self) -> usize {
            self.inner.r()
        }
        fn name(&self) -> String {
            "panicking-cpu".into()
        }
    }

    #[test]
    fn panicking_stage_fails_stream_with_typed_error() {
        let t = Trellis::preset("k3").unwrap();
        let mut rng = Xoshiro256::seeded(37);
        let bits: Vec<u8> = (0..256).map(|_| rng.next_bit()).collect();
        let llr = clean_llrs(&t, &bits, 8);
        // both the synchronous lane path and the threaded pipeline
        for lanes in [1usize, 2] {
            let eng = PanickingEngine {
                inner: CpuEngine::new(&t, 2, 32, 15),
                calls: std::sync::atomic::AtomicUsize::new(0),
                panic_at: 1,
            };
            let coord = StreamCoordinator::new(Arc::new(eng), lanes);
            let err = coord.decode_stream(&llr).expect_err("stream must fail");
            assert!(
                err.to_string().contains("panicked"),
                "lanes={lanes}: unexpected error {err}"
            );
        }
        // an engine that never reaches its panic batch still decodes
        let eng = PanickingEngine {
            inner: CpuEngine::new(&t, 2, 32, 15),
            calls: std::sync::atomic::AtomicUsize::new(0),
            panic_at: usize::MAX,
        };
        let coord = StreamCoordinator::new(Arc::new(eng), 2);
        let (out, _) = coord.decode_stream(&llr).unwrap();
        assert_eq!(out, bits);
    }

    #[test]
    fn config_auto_policy_selects_and_decodes_identically() {
        // the selection coverage the removed shim test used to pin,
        // expressed through the one remaining construction path
        let t = Trellis::preset("k3").unwrap();
        let base = crate::config::DecoderConfig::new("k3").block(32).depth(15).lanes(1);
        let c1 = base.clone().batch(4).workers(1).build_coordinator(None).unwrap();
        assert!(c1.engine.name().starts_with("cpu:"));
        let c3 = base.clone().batch(4).workers(3).build_coordinator(None).unwrap();
        assert!(c3.engine.name().starts_with("par-cpu:"), "{}", c3.engine.name());
        assert!(c3.engine.name().contains("w3"), "{}", c3.engine.name());
        let c0 = base.clone().batch(4).workers(0).build_coordinator(None).unwrap();
        assert!(c0.engine.name().starts_with("par-cpu:"));
        let cs = base
            .clone()
            .batch(crate::simd::LANES)
            .workers(2)
            .build_coordinator(None)
            .unwrap();
        assert!(cs.engine.name().starts_with("simd-cpu:"), "{}", cs.engine.name());
        // all four decode a clean stream identically, with bit-identical
        // per-block confidence margins (the shared-helper invariant)
        let mut rng = Xoshiro256::seeded(36);
        let bits: Vec<u8> = (0..400).map(|_| rng.next_bit()).collect();
        let llr = clean_llrs(&t, &bits, 8);
        let mut golden_margins: Option<Vec<u32>> = None;
        for c in [&c1, &c3, &c0, &cs] {
            let (out, stats) = c.decode_stream(&llr).unwrap();
            assert_eq!(out, bits);
            assert_eq!(stats.margins.len(), 400usize.div_ceil(32), "{}", c.engine.name());
            assert!(stats.min_margin().unwrap() > 0, "{}", c.engine.name());
            match &golden_margins {
                None => golden_margins = Some(stats.margins),
                Some(g) => assert_eq!(&stats.margins, g, "{}", c.engine.name()),
            }
        }
    }

    #[test]
    fn stats_accumulate() {
        let t = Trellis::preset("k3").unwrap();
        let mut rng = Xoshiro256::seeded(34);
        let bits: Vec<u8> = (0..256).map(|_| rng.next_bit()).collect();
        let llr = clean_llrs(&t, &bits, 8);
        let eng = CpuEngine::new(&t, 2, 32, 15);
        let coord = StreamCoordinator::new(Arc::new(eng), 1);
        let (_, stats) = coord.decode_stream(&llr).unwrap();
        assert_eq!(stats.n_batches, 4); // 8 blocks / 2 per batch
        assert!(stats.phases.k1 > Duration::ZERO);
        assert!(stats.throughput_mbps() > 0.0);
        // one margin per payload block, in stream order, all confident
        assert_eq!(stats.margins.len(), 8);
        assert!(stats.min_margin().unwrap() > 0);
        assert_eq!(stats.low_confidence_blocks(u32::MAX), 8);
        assert_eq!(stats.low_confidence_blocks(1), 0);
    }
}
