//! Sharded multi-threaded CPU decode backend — the serving-scale path.
//!
//! The paper's throughput comes from decoding many parallel blocks
//! (PBs) at once; the original [`CpuEngine`](crate::coordinator::CpuEngine)
//! decodes a batch's PBs sequentially on the calling thread, so the
//! coordinator's lanes all serialize on one ACS kernel.  This module
//! adds:
//!
//! * [`ButterflyAcs`] — a branchless radix-2 butterfly ACS kernel:
//!   flattened state-major `u32` path-metric buffers, a half-size
//!   branch-metric table (Sec. III trellis symmetry: `BM(~c) = -BM(c)`,
//!   so one correlation serves a complementary codeword pair), and
//!   packed `u64` decision words whose buffers are allocated once and
//!   reused across stages and blocks.
//! * [`ParCpuEngine`] — a [`DecodeEngine`](crate::coordinator::DecodeEngine)
//!   that shards each batch's PBs across a persistent
//!   [`WorkerPool`](crate::pool::WorkerPool) of `N_w` worker threads
//!   (std threads + channels only; no external dependencies), each
//!   running its own `ButterflyAcs` scratch.  Each call returns its
//!   exact per-worker attribution in `BatchTimings::per_worker`
//!   (summed per stream into `StreamStats::per_worker`), and
//!   cumulative pool counters feed
//!   [`WorkerPoolStats`](crate::metrics::WorkerPoolStats).  Construct
//!   it through
//!   [`DecoderConfig::build_engine`](crate::config::DecoderConfig::build_engine)
//!   ([`EngineKind::Par`](crate::config::EngineKind::Par)); the
//!   inherent constructors below are the factory's implementation
//!   layer.
//!
//! Decisions are **bit-identical** to
//! [`CpuPbvdDecoder`](crate::viterbi::CpuPbvdDecoder): the kernel
//! applies a uniform per-stage shift of [`bm_offset`]`(R, q)` =
//! `R * 2^(q-1)` to every branch metric (so `u32` arithmetic never
//! underflows, even at the q-bit quantizer's most negative output —
//! i8's -128 for the default q = 8), which cancels in every
//! compare-select and in the per-stage min-normalization.  Narrower
//! quantizers shrink the offset proportionally, which is what buys the
//! u16 headroom in the lane-interleaved kernel
//! ([`simd`](crate::simd)).  The property tests in
//! `rust/tests/par_engine.rs` pin the equivalence across codes, worker
//! counts and odd stream tails.

use crate::channel::pack_bits;
use crate::coordinator::{BatchTimings, DecodeEngine};
use crate::metrics::WorkerSnapshot;
use crate::pool::{DecodeShard, WorkerPool};
use crate::trellis::Trellis;
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::Instant;

// ---------------------------------------------------------------------------
// Butterfly ACS kernel.
// ---------------------------------------------------------------------------

/// Gray-code walk over the lower half of a `2^R`-entry codeword table:
/// yields `(codeword, llr_index, bit_now_set)` per step, visiting every
/// codeword in `1..2^(R-1)` exactly once with a single bit flip between
/// consecutive steps.  Both the scalar and the lane-interleaved BM
/// fills ([`fill_bm`], `simd::fill_bm_lanes`) walk this sequence so a
/// table entry costs one add/sub instead of an R-iteration inner loop.
///
/// Conventions match the correlation `BM[c] = Σ_r y_r (2 c_r − 1)`
/// with codeword bit `r-1-p` (MSB-first) feeding LLR index `p`:
/// flipping bit position `p` (LSB-based) to 1 adds `2 * llr[r-1-p]`,
/// clearing it subtracts.
#[inline]
pub(crate) fn gray_walk(r: usize) -> impl Iterator<Item = (usize, usize, bool)> {
    let mut g = 0usize;
    (1..1usize << (r - 1)).map(move |i| {
        let p = i.trailing_zeros() as usize;
        g ^= 1 << p;
        (g, r - 1 - p, (g >> p) & 1 == 1)
    })
}

/// The uniform per-stage branch-metric shift for an `R`-filter code
/// fed by a `q`-bit quantizer: `R * 2^(q-1)`, the largest correlation
/// magnitude a stage can produce (the quantizer emits values in
/// `[-2^(q-1), 2^(q-1) - 1]`; `frame_stream`'s saturating clamp can
/// hit the lower edge).  A uniform shift cannot change any
/// compare-select decision and cancels in the min-normalization; its
/// only job is keeping unsigned metric arithmetic above zero.  Smaller
/// `q` shrinks the shift — and with it the worst-case metric spread,
/// which is what admits u16 storage in the lane-interleaved kernel
/// (see `simd::metric_spread_bound`).
#[inline]
pub fn bm_offset(r: usize, q: u32) -> i32 {
    (r as i32) * (1i32 << (q - 1))
}

/// Branch-metric table fill for one stage of i8 LLRs, exploiting the
/// antipodal symmetry `corr(~c) = -corr(c)`: only the lower half of the
/// 2^R table is correlated, the upper half is derived by reflection.
/// The lower half itself is walked in Gray-code order ([`gray_walk`]),
/// so each entry is one add/sub off its predecessor instead of an
/// R-term correlation from scratch.
/// Every entry is shifted by `off` = [`bm_offset`]`(R, q) >= |corr|`,
/// making the table non-negative; a uniform per-stage shift cannot
/// change any compare-select decision and cancels in the
/// min-normalization.
#[inline]
fn fill_bm(bm: &mut [u32], llr_s: &[i8], r: usize, off: i32) {
    debug_assert!(
        llr_s.iter().take(r).all(|&y| {
            let b = off / r as i32; // 2^(q-1)
            (-b..b).contains(&(y as i32))
        }),
        "LLR outside the q-bit range the BM offset was built for"
    );
    let mask = bm.len() - 1;
    // codeword 0 (all bits clear): corr = -Σ llr
    let mut acc: i32 = -llr_s.iter().take(r).map(|&y| y as i32).sum::<i32>();
    bm[0] = (off + acc) as u32;
    bm[mask] = (off - acc) as u32;
    for (g, ri, set) in gray_walk(r) {
        let delta = 2 * (llr_s[ri] as i32);
        acc += if set { delta } else { -delta };
        bm[g] = (off + acc) as u32;
        bm[mask ^ g] = (off - acc) as u32;
    }
}

/// The branchless butterfly forward/traceback kernel with reusable
/// scratch.  One instance per worker thread; geometry is fixed at
/// construction (`block` = D payload bits, `depth` = L, T = D + 2L).
pub struct ButterflyAcs {
    trellis: Trellis,
    pub block: usize,
    pub depth: usize,
    /// u64 decision words per stage: bit `s % 64` of word `s / 64` is
    /// the survivor input of state `s`.
    n_dw: usize,
    /// Survivor-ring capacity in stages (`D + L`): decision rows live
    /// at `s % ring`, so the forward pass overwrites the first `L`
    /// warm-up stages — which Algorithm-1 traceback never reads — with
    /// the last `L`.  The retained window `L..T` spans exactly `D + L`
    /// stages and maps bijectively onto the ring rows.
    ring: usize,
    /// Uniform per-stage BM shift ([`bm_offset`] of the quantizer
    /// width this kernel was built for).
    bm_off: i32,
    // flattened state-major scratch, reused across stages and blocks
    pm: Vec<u32>,
    new_pm: Vec<u32>,
    bm: Vec<u32>,
    dw: Vec<u64>,
}

impl ButterflyAcs {
    /// Kernel for the default 8-bit quantizer (i8 full range).
    pub fn new(trellis: &Trellis, block: usize, depth: usize) -> ButterflyAcs {
        ButterflyAcs::with_quantizer(trellis, block, depth, 8)
    }

    /// Kernel for a `q`-bit quantizer (`2 <= q <= 8`; the engine input
    /// is i8, so wider quantizers must saturate upstream).  The BM
    /// shift shrinks to `R * 2^(q-1)`; feeding LLRs outside the q-bit
    /// range is a caller bug (debug-asserted in the fill).
    pub fn with_quantizer(
        trellis: &Trellis,
        block: usize,
        depth: usize,
        q: u32,
    ) -> ButterflyAcs {
        assert!(block > 0 && depth > 0);
        assert!((2..=8).contains(&q), "q={q} out of range for i8 input");
        let n = trellis.n_states;
        let n_dw = n.div_ceil(64);
        let ring = block + depth;
        ButterflyAcs {
            trellis: trellis.clone(),
            block,
            depth,
            n_dw,
            ring,
            bm_off: bm_offset(trellis.r, q),
            pm: vec![0u32; n],
            new_pm: vec![0u32; n],
            bm: vec![0u32; 1 << trellis.r],
            dw: vec![0u64; ring * n_dw],
        }
    }

    /// Stages per parallel block (T = D + 2L).
    pub fn total(&self) -> usize {
        self.block + 2 * self.depth
    }

    /// Survivor-ring capacity in stages (`D + L < T`).
    pub fn ring_stages(&self) -> usize {
        self.ring
    }

    /// u64 decision words per retained forward pass (`ring_stages *
    /// n_dw`), i.e. the length of [`decision_ring`](Self::decision_ring).
    pub fn ring_len(&self) -> usize {
        self.ring * self.n_dw
    }

    /// Bytes of survivor storage this kernel retains per PB with the
    /// depth-windowed ring.
    pub fn survivor_ring_bytes(&self) -> usize {
        self.ring_len() * std::mem::size_of::<u64>()
    }

    /// Bytes a full-length `[T][n_dw]` decision buffer would cost
    /// (the pre-ring layout; kept for the bench report's before/after).
    pub fn survivor_full_bytes(&self) -> usize {
        self.total() * self.n_dw * std::mem::size_of::<u64>()
    }

    /// The packed decision ring of the last forward pass (row `s %
    /// ring_stages` holds stage `s`; only stages `L..T` are retained).
    pub fn decision_ring(&self) -> &[u64] {
        &self.dw
    }

    pub fn trellis(&self) -> &Trellis {
        &self.trellis
    }

    /// Final normalized path metrics of the last forward pass
    /// (min = 0; bit-identical to `CpuPbvdDecoder::forward`'s `pm`).
    pub fn path_metrics(&self) -> &[u32] {
        &self.pm
    }

    /// Confidence margin of the last decoded PB: the runner-up final
    /// path metric (the winner is 0 after min-normalization).  The
    /// traceback never touches `pm`, so this stays valid after
    /// [`decode_block_into`](Self::decode_block_into); bit-identical
    /// to [`ForwardResult::margin`](crate::viterbi::ForwardResult::margin).
    pub fn margin(&self) -> u32 {
        crate::viterbi::second_min_margin(self.pm.iter().copied())
    }

    /// Group-based branchless forward pass over one PB of i8 LLRs
    /// (stage-major `[T][R]` flat).  Fills the decision-word buffer.
    pub fn forward(&mut self, llr: &[i8]) {
        let r = self.trellis.r;
        let tt = self.total();
        assert_eq!(llr.len(), tt * r, "LLR length != T * R");
        let half = self.trellis.n_states / 2;
        let n_dw = self.n_dw;
        let ring = self.ring;
        let off = self.bm_off;
        let Self {
            trellis,
            pm,
            new_pm,
            bm,
            dw,
            ..
        } = &mut *self;
        pm.fill(0);
        for s in 0..tt {
            fill_bm(bm.as_mut_slice(), &llr[s * r..(s + 1) * r], r, off);
            // ring slot: OR-packed rows must be cleared on reuse
            let slot = s % ring;
            let dw_row = &mut dw[slot * n_dw..(slot + 1) * n_dw];
            dw_row.fill(0);
            let mut min_pm = u32::MAX;
            for j in 0..half {
                let pe = pm[2 * j];
                let po = pm[2 * j + 1];
                // one table read per butterfly label; both radix-2
                // outputs (targets j and j + N/2) computed together
                let a = pe + bm[trellis.cw_top0[j] as usize];
                let b = po + bm[trellis.cw_top1[j] as usize];
                let a2 = pe + bm[trellis.cw_bot0[j] as usize];
                let b2 = po + bm[trellis.cw_bot1[j] as usize];
                let sel_top = (b < a) as u64;
                let sel_bot = (b2 < a2) as u64;
                let m_top = a.min(b);
                let m_bot = a2.min(b2);
                new_pm[j] = m_top;
                new_pm[j + half] = m_bot;
                min_pm = min_pm.min(m_top).min(m_bot);
                dw_row[j >> 6] |= sel_top << (j & 63);
                dw_row[(j + half) >> 6] |= sel_bot << ((j + half) & 63);
            }
            for x in new_pm.iter_mut() {
                *x -= min_pm;
            }
            std::mem::swap(pm, new_pm);
        }
    }

    /// Algorithm-1 traceback over this kernel's own decision ring;
    /// writes the D payload bits into `out`.  `start_state` is
    /// arbitrary (the merge phase absorbs it, Sec. III-A).
    pub fn traceback_into(&self, start_state: usize, out: &mut [u8]) {
        let dw = &self.dw;
        self.traceback_from(dw, start_state, out);
    }

    /// Algorithm-1 traceback over a detached decision ring (a
    /// [`decision_ring`](Self::decision_ring) copy of matching
    /// geometry) — the per-lane traceback phase of the split ACS /
    /// traceback pipeline runs this on whichever worker picked the
    /// job up.
    pub fn traceback_from(&self, dw: &[u64], start_state: usize, out: &mut [u8]) {
        let (d, l) = (self.block, self.depth);
        let tt = self.total();
        assert_eq!(out.len(), d, "output buffer != D bits");
        assert_eq!(dw.len(), self.ring_len(), "decision ring length");
        let v = self.trellis.v;
        let mask = (1usize << (v - 1)) - 1;
        let n_dw = self.n_dw;
        let ring = self.ring;
        let mut state = start_state;
        for s in (l..tt).rev() {
            if s <= d + l - 1 {
                out[s - l] = ((state >> (v - 1)) & 1) as u8;
            }
            let slot = s % ring;
            let row = &dw[slot * n_dw..(slot + 1) * n_dw];
            let bit = ((row[state >> 6] >> (state & 63)) & 1) as usize;
            state = 2 * (state & mask) + bit;
        }
    }

    /// Decode one PB (`[T][R]` i8 LLRs) into `out` (`block` bits),
    /// reusing every scratch buffer.
    pub fn decode_block_into(&mut self, llr: &[i8], out: &mut [u8]) {
        self.forward(llr);
        self.traceback_into(0, out);
    }
}

// ---------------------------------------------------------------------------
// The sharded engine.
// ---------------------------------------------------------------------------

/// Per-worker state of the scalar pool: one reusable kernel plus the
/// traceback bit scratch.
struct ParWorker {
    kern: ButterflyAcs,
    bits: Vec<u8>,
}

/// The ACS phase's detached survivor artifact for a scalar shard:
/// `n_pbs` consecutive decision-ring copies (each `ring_len` u64
/// words).  Handing the rings off is what lets the traceback phase run
/// on whichever worker frees up first while the ACS worker's kernel
/// immediately starts the next shard's forward pass.
struct ParAcsArtifact {
    rings: Vec<u64>,
}

impl ParWorker {
    /// Fused reference path (forward + traceback on one worker) — kept
    /// for the split-vs-fused equivalence tests and benches.
    fn decode(&mut self, n_pbs: usize, llr: &[i8]) -> (Vec<u32>, Vec<u32>) {
        let per_pb = self.kern.total() * self.kern.trellis().r;
        let wpp = self.kern.block.div_ceil(32);
        let mut words = Vec::with_capacity(n_pbs * wpp);
        let mut margins = Vec::with_capacity(n_pbs);
        for p in 0..n_pbs {
            self.kern
                .decode_block_into(&llr[p * per_pb..(p + 1) * per_pb], &mut self.bits);
            // read the margin before the next PB overwrites the metrics
            margins.push(self.kern.margin());
            words.extend(pack_bits(&self.bits));
        }
        (words, margins)
    }

    /// Forward-ACS phase of a shard: run every PB's forward pass,
    /// capture each margin before the next pass overwrites the path
    /// metrics, and copy out the decision rings as the traceback
    /// phase's artifact.
    fn acs(&mut self, n_pbs: usize, llr: &[i8]) -> (ParAcsArtifact, Vec<u32>) {
        let per_pb = self.kern.total() * self.kern.trellis().r;
        let ring_len = self.kern.ring_len();
        let mut rings = Vec::with_capacity(n_pbs * ring_len);
        let mut margins = Vec::with_capacity(n_pbs);
        for p in 0..n_pbs {
            self.kern.forward(&llr[p * per_pb..(p + 1) * per_pb]);
            margins.push(self.kern.margin());
            rings.extend_from_slice(self.kern.decision_ring());
        }
        (ParAcsArtifact { rings }, margins)
    }

    /// Traceback phase of a shard, over the ACS phase's detached rings
    /// (bit-identical to the fused path: same rings, same walk).
    fn tb(&mut self, n_pbs: usize, art: ParAcsArtifact) -> Vec<u32> {
        let ring_len = self.kern.ring_len();
        let wpp = self.kern.block.div_ceil(32);
        let mut words = Vec::with_capacity(n_pbs * wpp);
        for p in 0..n_pbs {
            self.kern.traceback_from(
                &art.rings[p * ring_len..(p + 1) * ring_len],
                0,
                &mut self.bits,
            );
            words.extend(pack_bits(&self.bits));
        }
        words
    }
}

/// Sharded multi-threaded CPU engine: a persistent `N_w`-worker
/// [`WorkerPool`] behind the [`DecodeEngine`] trait.  Each
/// `decode_batch` call splits the batch's PBs into at most `N_w`
/// contiguous shards, decodes them concurrently on the pool, and
/// splices the bit-packed outputs back in batch order.  Multiple
/// coordinator lanes may call `decode_batch` concurrently; shards
/// carry their own reply channels so calls never interleave results.
pub struct ParCpuEngine {
    trellis: Trellis,
    batch: usize,
    block: usize,
    depth: usize,
    pool: WorkerPool,
}

impl ParCpuEngine {
    /// Build a pool of `workers` decode workers; `0` means one per
    /// available core (the 0-means-auto policy lives in
    /// `pool::resolve_workers`, shared with
    /// [`SimdCpuEngine`](crate::simd::SimdCpuEngine)).
    pub fn new(
        trellis: &Trellis,
        batch: usize,
        block: usize,
        depth: usize,
        workers: usize,
    ) -> ParCpuEngine {
        ParCpuEngine::with_quantizer(trellis, batch, block, depth, workers, 8)
    }

    /// Pool whose kernels carry the `q`-bit quantizer's BM offset
    /// (`R * 2^(q-1)`); the LLR stream must come from a matching
    /// (or narrower) quantizer.
    pub fn with_quantizer(
        trellis: &Trellis,
        batch: usize,
        block: usize,
        depth: usize,
        workers: usize,
        q: u32,
    ) -> ParCpuEngine {
        ParCpuEngine::with_quantizer_mode(trellis, batch, block, depth, workers, q, true)
    }

    /// Fused forward+traceback pool (each shard decoded end-to-end on
    /// one worker) — the reference the split pipeline's equivalence
    /// tests and benches compare against.
    pub fn with_quantizer_fused(
        trellis: &Trellis,
        batch: usize,
        block: usize,
        depth: usize,
        workers: usize,
        q: u32,
    ) -> ParCpuEngine {
        ParCpuEngine::with_quantizer_mode(trellis, batch, block, depth, workers, q, false)
    }

    fn with_quantizer_mode(
        trellis: &Trellis,
        batch: usize,
        block: usize,
        depth: usize,
        workers: usize,
        q: u32,
        split: bool,
    ) -> ParCpuEngine {
        assert!(batch > 0 && block > 0 && depth > 0);
        // fail fast on the constructing thread — the same assert inside
        // the worker factory would panic on the worker threads instead
        assert!((2..=8).contains(&q), "q={q} out of range for i8 input");
        let t = trellis.clone();
        let make = move |_wid: usize| ParWorker {
            kern: ButterflyAcs::with_quantizer(&t, block, depth, q),
            bits: vec![0u8; block],
        };
        let pool = if split {
            WorkerPool::spawn_split(
                "pbvd-acs",
                workers,
                0, // scalar kernel: no lane width to record
                0, // ... and no lane backend either
                make,
                ParWorker::acs,
                ParWorker::tb,
            )
        } else {
            WorkerPool::spawn("pbvd-acs", workers, 0, 0, make, ParWorker::decode)
        };
        // survivor footprint of one kernel instance (every worker's
        // kernel shares the geometry)
        let n_dw = trellis.n_states.div_ceil(64);
        pool.set_survivor_footprint(
            ((block + depth) * n_dw * std::mem::size_of::<u64>()) as u64,
            (block + depth) as u64,
            (block + 2 * depth) as u64,
        );
        ParCpuEngine {
            trellis: trellis.clone(),
            batch,
            block,
            depth,
            pool,
        }
    }

    /// Pool sized to the machine (one worker per available core).
    pub fn with_auto_workers(
        trellis: &Trellis,
        batch: usize,
        block: usize,
        depth: usize,
    ) -> ParCpuEngine {
        ParCpuEngine::new(trellis, batch, block, depth, 0)
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Cumulative pool counters (engine lifetime; diff two snapshots
    /// for a per-stream view).
    pub fn pool_stats(&self) -> WorkerSnapshot {
        self.pool.snapshot()
    }

    /// Shard-dispatch core shared by both [`DecodeEngine`] entry
    /// points: the batch buffer is handed to workers as `Arc` clones,
    /// never copied here.
    fn dispatch(&self, llr_i8: &Arc<[i8]>) -> Result<(Vec<u32>, BatchTimings)> {
        let r = self.trellis.r;
        let per_pb = (self.block + 2 * self.depth) * r;
        if llr_i8.len() != self.batch * per_pb {
            bail!(
                "batch size mismatch: got {} LLRs, engine wants {}",
                llr_i8.len(),
                self.batch * per_pb
            );
        }
        // shard the batch's PBs into <= N_w contiguous, near-even runs
        let shards = self.pool.workers().min(self.batch).max(1);
        let base = self.batch / shards;
        let extra = self.batch % shards;
        let mut plan = Vec::with_capacity(shards);
        let mut off = 0usize; // in PBs
        for seq in 0..shards {
            let n_pbs = base + usize::from(seq < extra);
            plan.push(DecodeShard {
                n_pbs,
                lo: off * per_pb,
                hi: (off + n_pbs) * per_pb,
            });
            off += n_pbs;
        }
        self.pool.dispatch(llr_i8, &plan)
    }
}

impl DecodeEngine for ParCpuEngine {
    fn decode_batch(&self, llr_i8: &[i8]) -> Result<(Vec<u32>, BatchTimings)> {
        // Borrowed entry point: one copy to get a shareable allocation.
        // Streaming callers go through `decode_batch_shared` and skip it.
        let t0 = Instant::now();
        let shared: Arc<[i8]> = Arc::from(llr_i8);
        let copy = t0.elapsed();
        let (words, mut t) = self.dispatch(&shared)?;
        t.pack += copy;
        Ok((words, t))
    }

    fn decode_batch_shared(&self, llr_i8: &Arc<[i8]>) -> Result<(Vec<u32>, BatchTimings)> {
        self.dispatch(llr_i8)
    }

    fn batch(&self) -> usize {
        self.batch
    }
    fn block(&self) -> usize {
        self.block
    }
    fn depth(&self) -> usize {
        self.depth
    }
    fn r(&self) -> usize {
        self.trellis.r
    }
    fn name(&self) -> String {
        format!("par-cpu:b{}w{}", self.batch, self.pool.workers())
    }
    fn worker_snapshot(&self) -> Option<WorkerSnapshot> {
        Some(self.pool.snapshot())
    }
    fn install_fault_plan(&self, plan: Option<Arc<crate::serve::faults::FaultPlan>>) {
        self.pool.install_fault_plan(plan);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CpuEngine;
    use crate::rng::Xoshiro256;
    use crate::viterbi::CpuPbvdDecoder;

    fn random_i8_llrs(rng: &mut Xoshiro256, n: usize) -> Vec<i8> {
        // full i8 range including -128 (frame_stream clamps to -128)
        (0..n)
            .map(|_| ((rng.next_below(256) as i32) - 128) as i8)
            .collect()
    }

    #[test]
    fn butterfly_forward_matches_reference_metrics_and_bits() {
        for (name, k, _) in crate::trellis::PRESETS {
            let t = Trellis::preset(name).unwrap();
            let (block, depth) = (48usize, 6 * *k as usize);
            let reference = CpuPbvdDecoder::new(&t, block, depth);
            let mut kern = ButterflyAcs::new(&t, block, depth);
            let mut rng = Xoshiro256::seeded(0xB1F);
            for _ in 0..5 {
                let llr8 = random_i8_llrs(&mut rng, kern.total() * t.r);
                let llr32: Vec<i32> = llr8.iter().map(|&x| x as i32).collect();
                let fwd = reference.forward(&llr32);
                kern.forward(&llr8);
                // normalized path metrics agree exactly (offset cancels)
                let got: Vec<i64> = kern.path_metrics().iter().map(|&x| x as i64).collect();
                assert_eq!(got, fwd.pm, "{name}: path metrics diverged");
                // traceback bits agree from every start state
                let mut bits = vec![0u8; block];
                for s0 in [0usize, 1, t.n_states - 1] {
                    kern.traceback_into(s0, &mut bits);
                    assert_eq!(bits, reference.traceback(&fwd, s0), "{name} s0={s0}");
                }
            }
        }
    }

    #[test]
    fn butterfly_ring_is_depth_windowed_and_detachable() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        // depth < block and depth >= block (ring wraps more than once)
        for (block, depth) in [(48usize, 42usize), (8, 42)] {
            let reference = CpuPbvdDecoder::new(&t, block, depth);
            let mut kern = ButterflyAcs::new(&t, block, depth);
            assert_eq!(kern.ring_stages(), block + depth);
            assert!(kern.ring_stages() < kern.total());
            assert_eq!(kern.decision_ring().len(), kern.ring_len());
            assert!(kern.survivor_ring_bytes() < kern.survivor_full_bytes());
            let mut rng = Xoshiro256::seeded(0x41B6);
            let llr8 = random_i8_llrs(&mut rng, kern.total() * t.r);
            let llr32: Vec<i32> = llr8.iter().map(|&x| x as i32).collect();
            let fwd = reference.forward(&llr32);
            kern.forward(&llr8);
            // a detached ring copy tracebacks identically to the live
            // kernel and to golden, from several start states
            let detached = kern.decision_ring().to_vec();
            let mut live = vec![0u8; block];
            let mut from = vec![0u8; block];
            for s0 in [0usize, 1, t.n_states - 1] {
                kern.traceback_into(s0, &mut live);
                kern.traceback_from(&detached, s0, &mut from);
                assert_eq!(live, from, "D={block} L={depth} s0={s0}");
                assert_eq!(live, reference.traceback(&fwd, s0), "D={block} L={depth} s0={s0}");
            }
        }
    }

    #[test]
    fn split_engine_matches_fused_engine() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let (batch, block, depth) = (13usize, 48usize, 42usize);
        let mut rng = Xoshiro256::seeded(0x5917);
        let llr = random_i8_llrs(&mut rng, batch * (block + 2 * depth) * t.r);
        let fused = ParCpuEngine::with_quantizer_fused(&t, batch, block, depth, 2, 8);
        let (want, want_t) = fused.decode_batch(&llr).unwrap();
        for workers in [1usize, 2, 8] {
            let split = ParCpuEngine::new(&t, batch, block, depth, workers);
            let (got, tm) = split.decode_batch(&llr).unwrap();
            assert_eq!(got, want, "workers={workers}");
            assert_eq!(tm.margins, want_t.margins, "workers={workers}");
            let pw = tm.per_worker.expect("per-call attribution");
            // phase attribution: all busy time is ACS + traceback
            assert_eq!(pw.total_acs_busy() + pw.total_tb_busy(), pw.total_busy());
            assert!(pw.total_tb_busy() > std::time::Duration::ZERO);
            assert_eq!(pw.total_blocks(), batch as u64);
            // survivor footprint travels with the attribution
            assert_eq!(pw.survivor_ring_stages, (block + depth) as u64);
            assert_eq!(pw.survivor_total_stages, (block + 2 * depth) as u64);
            assert!(pw.survivor_ring_bytes > 0);
        }
        // the fused pool records no phase split
        let pw = want_t.per_worker.unwrap();
        assert_eq!(pw.total_tb_busy(), std::time::Duration::ZERO);
    }

    #[test]
    fn quantizer_aware_kernel_matches_reference_at_narrow_q() {
        // q = 4: LLRs in [-8, 7], BM shift shrinks to R * 8 — decisions
        // and normalized metrics still match the (offset-free) golden
        // model exactly.
        for q in [4u32, 6] {
            let m = 1i32 << (q - 1);
            let t = Trellis::preset("ccsds_k7").unwrap();
            let (block, depth) = (40usize, 42usize);
            let reference = CpuPbvdDecoder::new(&t, block, depth);
            let mut kern = ButterflyAcs::with_quantizer(&t, block, depth, q);
            let mut rng = Xoshiro256::seeded(0x9_0000 + q as u64);
            let llr8: Vec<i8> = (0..kern.total() * t.r)
                .map(|_| ((rng.next_below(2 * m as u64) as i32) - m) as i8)
                .collect();
            let llr32: Vec<i32> = llr8.iter().map(|&x| x as i32).collect();
            let fwd = reference.forward(&llr32);
            kern.forward(&llr8);
            let got: Vec<i64> = kern.path_metrics().iter().map(|&x| x as i64).collect();
            assert_eq!(got, fwd.pm, "q={q}: path metrics diverged");
            let mut bits = vec![0u8; block];
            kern.traceback_into(0, &mut bits);
            assert_eq!(bits, reference.traceback(&fwd, 0), "q={q}");
        }
    }

    #[test]
    fn butterfly_margin_matches_golden_margin() {
        for (name, k, _) in crate::trellis::PRESETS {
            let t = Trellis::preset(name).unwrap();
            let (block, depth) = (48usize, 6 * *k as usize);
            let reference = CpuPbvdDecoder::new(&t, block, depth);
            let mut kern = ButterflyAcs::new(&t, block, depth);
            let mut rng = Xoshiro256::seeded(0x3A6);
            let mut bits = vec![0u8; block];
            for _ in 0..3 {
                let llr8 = random_i8_llrs(&mut rng, kern.total() * t.r);
                let llr32: Vec<i32> = llr8.iter().map(|&x| x as i32).collect();
                let want = reference.forward(&llr32).margin();
                // margin must survive a full decode (traceback included)
                kern.decode_block_into(&llr8, &mut bits);
                assert_eq!(kern.margin(), want, "{name}: margin diverged");
            }
        }
    }

    #[test]
    fn bm_table_symmetry_trick_is_exact() {
        let mut rng = Xoshiro256::seeded(7);
        for r in [2usize, 3] {
            let llr8 = random_i8_llrs(&mut rng, r);
            let mut bm = vec![0u32; 1 << r];
            fill_bm(&mut bm, &llr8, r, bm_offset(r, 8));
            let off = (r as i64) * 128;
            for (c, &entry) in bm.iter().enumerate() {
                let mut acc = 0i64;
                for (ri, &y) in llr8.iter().enumerate() {
                    let bit = ((c >> (r - 1 - ri)) & 1) as i64;
                    acc += (y as i64) * (2 * bit - 1);
                }
                assert_eq!(entry as i64, off + acc, "r={r} c={c}");
            }
        }
    }

    #[test]
    fn bm_offset_scales_with_quantizer_width() {
        assert_eq!(bm_offset(2, 8), 2 * 128);
        assert_eq!(bm_offset(2, 4), 2 * 8);
        assert_eq!(bm_offset(3, 5), 3 * 16);
        // q = 4 table stays non-negative at the quantizer extremes
        let mut bm = vec![0u32; 4];
        fill_bm(&mut bm, &[-8i8, -8], 2, bm_offset(2, 4));
        assert!(bm.iter().all(|&x| x <= 2 * 16), "{bm:?}");
    }

    #[test]
    fn par_engine_matches_cpu_engine_batch() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let (batch, block, depth) = (13usize, 64usize, 42usize);
        let cpu = CpuEngine::new(&t, batch, block, depth);
        let mut rng = Xoshiro256::seeded(0xACE);
        let llr = random_i8_llrs(&mut rng, batch * (block + 2 * depth) * t.r);
        let (want, _) = cpu.decode_batch(&llr).unwrap();
        for workers in [1usize, 2, 4, 8] {
            let par = ParCpuEngine::new(&t, batch, block, depth, workers);
            let (got, timings) = par.decode_batch(&llr).unwrap();
            assert_eq!(got, want, "workers={workers}");
            assert!(timings.k1.as_nanos() > 0);
            let pw = timings.per_worker.expect("per-call attribution");
            assert_eq!(pw.total_blocks(), batch as u64, "workers={workers}");
            assert_eq!(pw.workers(), workers, "workers={workers}");
        }
    }

    #[test]
    fn par_engine_rejects_bad_batch_and_reports_stats() {
        let t = Trellis::preset("k5").unwrap();
        let par = ParCpuEngine::new(&t, 4, 32, 20, 3);
        assert!(par.decode_batch(&[0i8; 7]).is_err());
        let llr = vec![1i8; 4 * (32 + 40) * t.r];
        let before = par.pool_stats();
        par.decode_batch(&llr).unwrap();
        let delta = par.pool_stats().delta_since(&before);
        assert_eq!(delta.total_blocks(), 4);
        // 4 PBs over min(3 workers, 4 PBs) shards
        assert_eq!(delta.total_jobs(), 3);
        // scalar pool: no lane width recorded
        assert_eq!(delta.metric_bits, 0);
        assert_eq!(par.worker_snapshot().unwrap().workers(), 3);
        assert_eq!(par.workers(), 3);
        assert!(par.name().contains("w3"));
    }

    #[test]
    fn shared_entry_point_matches_borrowed_and_attributes_blocks() {
        let t = Trellis::preset("k5").unwrap();
        let par = ParCpuEngine::new(&t, 5, 32, 20, 2);
        let mut rng = Xoshiro256::seeded(0x5EED);
        let llr = random_i8_llrs(&mut rng, 5 * (32 + 40) * t.r);
        let (want, _) = par.decode_batch(&llr).unwrap();
        let shared: Arc<[i8]> = llr.into();
        let (got, timings) = par.decode_batch_shared(&shared).unwrap();
        assert_eq!(got, want);
        assert_eq!(timings.per_worker.unwrap().total_blocks(), 5);
    }

    #[test]
    fn par_engine_concurrent_callers_do_not_interleave() {
        let t = Trellis::preset("k3").unwrap();
        let (batch, block, depth) = (8usize, 32usize, 15usize);
        let par = Arc::new(ParCpuEngine::new(&t, batch, block, depth, 4));
        let cpu = CpuEngine::new(&t, batch, block, depth);
        let mut rng = Xoshiro256::seeded(0xCAFE);
        let streams: Vec<Vec<i8>> = (0..6)
            .map(|_| random_i8_llrs(&mut rng, batch * (block + 2 * depth) * t.r))
            .collect();
        let wants: Vec<Vec<u32>> = streams
            .iter()
            .map(|s| cpu.decode_batch(s).unwrap().0)
            .collect();
        let mut handles = Vec::new();
        for (s, w) in streams.into_iter().zip(wants.into_iter()) {
            let eng = Arc::clone(&par);
            handles.push(std::thread::spawn(move || {
                for _ in 0..3 {
                    let (got, _) = eng.decode_batch(&s).unwrap();
                    assert_eq!(got, w);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn pool_shuts_down_cleanly_on_drop() {
        let t = Trellis::preset("k3").unwrap();
        let par = ParCpuEngine::new(&t, 2, 32, 15, 2);
        let llr = vec![0i8; 2 * (32 + 30) * t.r];
        par.decode_batch(&llr).unwrap();
        drop(par); // joins workers; must not hang or panic
    }
}
