//! Monte-Carlo BER harness (Fig. 4): multi-threaded trials of
//! encode -> BPSK -> AWGN -> quantize -> decode -> count bit errors.
//!
//! Generic over the decoder (CPU PBVD, classic VA, or the PJRT-backed
//! coordinator) through the [`StreamDecoder`] trait.

use crate::channel::{AwgnChannel, Quantizer};
use crate::encoder::ConvEncoder;
use crate::rng::Xoshiro256;
use crate::trellis::Trellis;
use crate::viterbi::{BlockViterbiDecoder, CpuPbvdDecoder};
use anyhow::Result;
use std::sync::mpsc;
use std::thread;

/// Anything that can decode a quantized LLR stream into bits.
pub trait StreamDecoder: Send + Sync {
    /// llr: stage-major `n_bits * R` quantized values -> `n_bits` bits.
    /// Fallible so engine-backed decoders (coordinator, PJRT) propagate
    /// decode failures as typed errors instead of panicking mid-harness.
    fn decode_stream(&self, llr: &[i32]) -> Result<Vec<u8>>;
    fn rate(&self) -> f64;
}

impl StreamDecoder for CpuPbvdDecoder {
    fn decode_stream(&self, llr: &[i32]) -> Result<Vec<u8>> {
        Ok(CpuPbvdDecoder::decode_stream(self, llr))
    }
    fn rate(&self) -> f64 {
        1.0 / self.trellis().r as f64
    }
}

/// Adapter: the classic block VA as a stream decoder (decodes the whole
/// stream as one block — the truncation-free reference of Fig. 4).
pub struct BlockVaStream {
    pub dec: BlockViterbiDecoder,
    pub r: usize,
}

impl StreamDecoder for BlockVaStream {
    fn decode_stream(&self, llr: &[i32]) -> Result<Vec<u8>> {
        let n = llr.len() / self.r;
        let mut bits = self.dec.decode(llr);
        bits.truncate(n);
        Ok(bits)
    }
    fn rate(&self) -> f64 {
        1.0 / self.r as f64
    }
}

/// One (Eb/N0, decoder) measurement.
#[derive(Clone, Copy, Debug)]
pub struct BerPoint {
    pub ebn0_db: f64,
    pub bits: u64,
    pub errors: u64,
}

impl BerPoint {
    pub fn ber(&self) -> f64 {
        if self.bits == 0 {
            0.0
        } else {
            self.errors as f64 / self.bits as f64
        }
    }
}

/// Configuration of a BER run.
#[derive(Clone, Copy, Debug)]
pub struct BerConfig {
    /// Information bits per trial stream.
    pub bits_per_trial: usize,
    /// Stop after this many bit errors (confidence) ...
    pub target_errors: u64,
    /// ... or this many bits, whichever first.
    pub max_bits: u64,
    /// Quantizer resolution (paper: 8-bit).
    pub q: u32,
    /// Worker threads.
    pub threads: usize,
    pub seed: u64,
}

impl Default for BerConfig {
    fn default() -> Self {
        Self {
            bits_per_trial: 8192,
            target_errors: 200,
            max_bits: 20_000_000,
            q: 8,
            threads: 8,
            seed: 0xBE2,
        }
    }
}

/// Measure BER at one Eb/N0 point.
///
/// A decode failure on any worker thread aborts the measurement and is
/// propagated to the caller (remaining workers finish their in-flight
/// trial and exit on their own).
pub fn measure_ber<D: StreamDecoder>(
    trellis: &Trellis,
    decoder: &D,
    ebn0_db: f64,
    cfg: &BerConfig,
) -> Result<BerPoint> {
    let threads = cfg.threads.max(1);
    let (tx, rx) = mpsc::channel::<Result<(u64, u64)>>();
    let mut master = Xoshiro256::seeded(cfg.seed ^ (ebn0_db.to_bits()));
    thread::scope(|scope| {
        for _ in 0..threads {
            let mut rng = master.split();
            let tx = tx.clone();
            let t = trellis;
            let d = decoder;
            let cfg = *cfg;
            scope.spawn(move || {
                let per_thread_bits = cfg.max_bits / threads as u64;
                let per_thread_errs = cfg.target_errors.div_ceil(threads as u64);
                let mut bits_done = 0u64;
                let mut errs = 0u64;
                let quant = Quantizer::new(cfg.q);
                let mut enc = ConvEncoder::new(t);
                let mut ch = AwgnChannel::new(ebn0_db, d.rate(), &mut rng);
                while bits_done < per_thread_bits && errs < per_thread_errs {
                    let payload: Vec<u8> =
                        (0..cfg.bits_per_trial).map(|_| rng.next_bit()).collect();
                    enc.reset();
                    let coded = enc.encode(&payload);
                    let soft = ch.transmit(&coded);
                    let llr = quant.quantize(&soft);
                    let dec = match d.decode_stream(&llr) {
                        Ok(bits) => bits,
                        Err(e) => {
                            let _ = tx.send(Err(e));
                            return;
                        }
                    };
                    errs += dec
                        .iter()
                        .zip(payload.iter())
                        .filter(|(a, b)| a != b)
                        .count() as u64;
                    bits_done += cfg.bits_per_trial as u64;
                }
                let _ = tx.send(Ok((bits_done, errs)));
            });
        }
        drop(tx);
        let mut total_bits = 0u64;
        let mut total_errs = 0u64;
        let mut failure = None;
        for res in rx {
            match res {
                Ok((b, e)) => {
                    total_bits += b;
                    total_errs += e;
                }
                Err(e) => failure = Some(e),
            }
        }
        match failure {
            Some(e) => Err(e),
            None => Ok(BerPoint {
                ebn0_db,
                bits: total_bits,
                errors: total_errs,
            }),
        }
    })
}

/// Sweep a list of Eb/N0 points.
pub fn sweep<D: StreamDecoder>(
    trellis: &Trellis,
    decoder: &D,
    ebn0_list: &[f64],
    cfg: &BerConfig,
) -> Result<Vec<BerPoint>> {
    ebn0_list
        .iter()
        .map(|&e| measure_ber(trellis, decoder, e, cfg))
        .collect()
}

/// Uncoded BPSK BER (theory): Q(sqrt(2 Eb/N0)) — the Fig. 4 baseline.
pub fn uncoded_bpsk_ber(ebn0_db: f64) -> f64 {
    let ebn0 = 10f64.powf(ebn0_db / 10.0);
    q_function((2.0 * ebn0).sqrt())
}

/// Gaussian tail Q(x) via erfc.
pub fn q_function(x: f64) -> f64 {
    0.5 * erfc(x / std::f64::consts::SQRT_2)
}

/// erfc via the Abramowitz–Stegun 7.1.26-style rational approximation
/// (max abs error ~1.5e-7 — plenty for BER plotting).
pub fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        return 2.0 - erfc(-x);
    }
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = t
        * (0.254829592
            + t * (-0.284496736
                + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    poly * (-x * x).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erfc_reference_points() {
        assert!((erfc(0.0) - 1.0).abs() < 1e-7);
        assert!((erfc(1.0) - 0.157_299_2).abs() < 1e-6);
        assert!((erfc(2.0) - 0.004_677_73).abs() < 1e-6);
        assert!((erfc(-1.0) - (2.0 - 0.157_299_2)).abs() < 1e-6);
    }

    #[test]
    fn uncoded_ber_reference() {
        // Eb/N0 = 0 dB -> Q(sqrt 2) ~ 0.0786; 9.6 dB -> ~1e-5
        assert!((uncoded_bpsk_ber(0.0) - 0.0786).abs() < 1e-3);
        let b96 = uncoded_bpsk_ber(9.6);
        assert!(b96 > 0.5e-5 && b96 < 2e-5, "{b96}");
    }

    #[test]
    fn coded_beats_uncoded_at_4db() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 128, 42);
        let cfg = BerConfig {
            bits_per_trial: 4096,
            target_errors: 50,
            max_bits: 400_000,
            threads: 4,
            ..Default::default()
        };
        let p = measure_ber(&t, &dec, 4.0, &cfg).unwrap();
        let coded = p.ber();
        let uncoded = uncoded_bpsk_ber(4.0); // ~1.25e-2
        assert!(
            coded < uncoded / 10.0,
            "coded {coded} must be well below uncoded {uncoded}"
        );
    }

    #[test]
    fn ber_decreases_with_snr() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 128, 42);
        let cfg = BerConfig {
            bits_per_trial: 4096,
            target_errors: 100,
            max_bits: 200_000,
            threads: 4,
            ..Default::default()
        };
        let pts = sweep(&t, &dec, &[0.0, 2.0, 4.0], &cfg).unwrap();
        assert!(pts[0].ber() > pts[1].ber());
        assert!(pts[1].ber() > pts[2].ber());
    }

    #[test]
    fn deterministic_given_seed() {
        let t = Trellis::preset("k3").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 64, 15);
        let cfg = BerConfig {
            bits_per_trial: 1024,
            target_errors: 30,
            max_bits: 50_000,
            threads: 2,
            ..Default::default()
        };
        let a = measure_ber(&t, &dec, 2.0, &cfg).unwrap();
        let b = measure_ber(&t, &dec, 2.0, &cfg).unwrap();
        assert_eq!(a.errors, b.errors);
        assert_eq!(a.bits, b.bits);
    }
}
