//! Minimal command-line argument parser (clap is unavailable offline —
//! DESIGN.md §3).  Subcommand + `--flag`, `--key value` / `--key=value`
//! options with typed accessors, defaults and usage generation.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declarative option spec for usage/help output.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    UnknownOption(String),
    MissingValue(String),
    BadValue(String, String, String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownOption(n) => write!(f, "unknown option --{n}"),
            CliError::MissingValue(n) => write!(f, "option --{n} requires a value"),
            CliError::BadValue(n, v, why) => {
                write!(f, "invalid value for --{n}: {v:?} ({why})")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse `argv[1..]`.  The first non-option token becomes the
    /// subcommand; later non-option tokens are positional.  `specs`
    /// defines legal options (strict parsing).
    pub fn parse(argv: &[String], specs: &[OptSpec]) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| CliError::UnknownOption(key.clone()))?;
                if spec.is_flag {
                    out.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(key.clone()))?,
                    };
                    out.options.insert(key, val);
                }
            } else if out.command.is_none() {
                out.command = Some(tok.clone());
            } else {
                out.positional.push(tok.clone());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e: std::num::ParseIntError| {
                CliError::BadValue(name.into(), v.into(), e.to_string())
            }),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e: std::num::ParseFloatError| {
                CliError::BadValue(name.into(), v.into(), e.to_string())
            }),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e: std::num::ParseIntError| {
                CliError::BadValue(name.into(), v.into(), e.to_string())
            }),
        }
    }

    /// Comma-separated list of usizes, e.g. `--lanes 1,2,4`.
    pub fn usize_list_or(
        &self,
        name: &str,
        default: &[usize],
    ) -> Result<Vec<usize>, CliError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|e: std::num::ParseIntError| {
                        CliError::BadValue(name.into(), v.into(), e.to_string())
                    })
                })
                .collect(),
        }
    }

    /// Comma-separated f64 list, e.g. `--ebn0 0,1,2,3`.
    pub fn f64_list_or(
        &self,
        name: &str,
        default: &[f64],
    ) -> Result<Vec<f64>, CliError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|e: std::num::ParseFloatError| {
                        CliError::BadValue(name.into(), v.into(), e.to_string())
                    })
                })
                .collect(),
        }
    }
}

/// Render a usage block from specs.
pub fn usage(prog: &str, commands: &[(&str, &str)], specs: &[OptSpec]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "usage: {prog} <command> [options]\n");
    let _ = writeln!(s, "commands:");
    for (name, help) in commands {
        let _ = writeln!(s, "  {name:<12} {help}");
    }
    let _ = writeln!(s, "\noptions:");
    for o in specs {
        let d = o
            .default
            .map(|d| format!(" (default {d})"))
            .unwrap_or_default();
        let _ = writeln!(s, "  --{:<14} {}{}", o.name, o.help, d);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "code", help: "", default: Some("ccsds_k7"), is_flag: false },
            OptSpec { name: "lanes", help: "", default: None, is_flag: false },
            OptSpec { name: "ebn0", help: "", default: None, is_flag: false },
            OptSpec { name: "verbose", help: "", default: None, is_flag: true },
        ]
    }

    fn parse(toks: &[&str]) -> Result<Args, CliError> {
        let v: Vec<String> = toks.iter().map(|s| s.to_string()).collect();
        Args::parse(&v, &specs())
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["decode", "--code", "k5", "--verbose", "input.bin"]).unwrap();
        assert_eq!(a.command.as_deref(), Some("decode"));
        assert_eq!(a.get("code"), Some("k5"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["input.bin"]);
    }

    #[test]
    fn equals_form() {
        let a = parse(&["ber", "--ebn0=1.5,2.5"]).unwrap();
        assert_eq!(a.f64_list_or("ebn0", &[]).unwrap(), vec![1.5, 2.5]);
    }

    #[test]
    fn defaults_and_typed() {
        let a = parse(&["x"]).unwrap();
        assert_eq!(a.str_or("code", "ccsds_k7"), "ccsds_k7");
        assert_eq!(a.usize_or("lanes", 3).unwrap(), 3);
        let b = parse(&["x", "--lanes", "8"]).unwrap();
        assert_eq!(b.usize_or("lanes", 3).unwrap(), 8);
    }

    #[test]
    fn errors() {
        assert!(matches!(parse(&["x", "--nope"]), Err(CliError::UnknownOption(_))));
        assert!(matches!(parse(&["x", "--lanes"]), Err(CliError::MissingValue(_))));
        let a = parse(&["x", "--lanes", "abc"]).unwrap();
        assert!(matches!(a.usize_or("lanes", 1), Err(CliError::BadValue(..))));
    }

    #[test]
    fn usize_list() {
        let a = parse(&["x", "--lanes", "1, 2,4"]).unwrap();
        assert_eq!(a.usize_list_or("lanes", &[]).unwrap(), vec![1, 2, 4]);
    }
}
