//! Streaming convolutional encoder — the transmit-side substrate.
//!
//! Encodes an unbounded bit stream through an (R,1,K) code, emitting R
//! coded bits per input bit.  Supports zero-termination (tail bits) for
//! block transmission and carries state across calls for stream use.

use crate::trellis::Trellis;

/// Stateful streaming encoder.
#[derive(Clone, Debug)]
pub struct ConvEncoder {
    next_state: Vec<[u32; 2]>,
    output: Vec<[u32; 2]>,
    r: usize,
    v: u32,
    state: u32,
}

impl ConvEncoder {
    pub fn new(trellis: &Trellis) -> Self {
        Self {
            next_state: trellis.next_state.clone(),
            output: trellis.output.clone(),
            r: trellis.r,
            v: trellis.v,
            state: 0,
        }
    }

    /// Current encoder state (the v memory bits).
    pub fn state(&self) -> u32 {
        self.state
    }

    /// Reset to the all-zero state.
    pub fn reset(&mut self) {
        self.state = 0;
    }

    /// Encode one input bit; returns the R coded bits (MSB-first order
    /// of the generator list) as a small vec of 0/1 bytes.
    #[inline]
    pub fn push(&mut self, bit: u8) -> Codeword {
        debug_assert!(bit <= 1);
        let cw = self.output[self.state as usize][bit as usize];
        self.state = self.next_state[self.state as usize][bit as usize];
        Codeword { cw, r: self.r }
    }

    /// Encode a slice of bits; returns a flat coded-bit vec of length
    /// `bits.len() * R` (stage-major, filter order within a stage).
    pub fn encode(&mut self, bits: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(bits.len() * self.r);
        for &b in bits {
            let cw = self.push(b);
            for r in 0..self.r {
                out.push(cw.bit(r));
            }
        }
        out
    }

    /// Append `v` zero tail bits, driving the encoder back to state 0.
    /// Returns the coded tail (length `v * R`).
    pub fn terminate(&mut self) -> Vec<u8> {
        let tail = vec![0u8; self.v as usize];
        let coded = self.encode(&tail);
        debug_assert_eq!(self.state, 0);
        coded
    }
}

/// One stage's coded output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Codeword {
    cw: u32,
    r: usize,
}

impl Codeword {
    /// Bit of filter `r` (0-indexed; filter 0 = MSB of the codeword int).
    #[inline]
    pub fn bit(&self, r: usize) -> u8 {
        ((self.cw >> (self.r - 1 - r)) & 1) as u8
    }

    pub fn as_int(&self) -> u32 {
        self.cw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trellis::Trellis;

    #[test]
    fn textbook_vector_k3() {
        let t = Trellis::preset("k3").unwrap();
        let mut e = ConvEncoder::new(&t);
        let coded = e.encode(&[1, 0, 1, 1]);
        assert_eq!(coded, vec![1, 1, 1, 0, 0, 0, 0, 1]);
    }

    #[test]
    fn stream_equals_block() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let bits: Vec<u8> = (0..257).map(|i| ((i * 7) % 3 == 0) as u8).collect();
        let mut block = ConvEncoder::new(&t);
        let all = block.encode(&bits);
        let mut stream = ConvEncoder::new(&t);
        let mut chunked = Vec::new();
        for chunk in bits.chunks(13) {
            chunked.extend(stream.encode(chunk));
        }
        assert_eq!(all, chunked);
        assert_eq!(block.state(), stream.state());
    }

    #[test]
    fn termination_returns_to_zero() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let mut e = ConvEncoder::new(&t);
        e.encode(&[1, 1, 0, 1, 0, 0, 1, 1, 1]);
        assert_ne!(e.state(), 0);
        let tail = e.terminate();
        assert_eq!(e.state(), 0);
        assert_eq!(tail.len(), (t.v as usize) * t.r);
    }

    #[test]
    fn rate_one_third() {
        let t = Trellis::preset("r3_k7").unwrap();
        let mut e = ConvEncoder::new(&t);
        let coded = e.encode(&[1, 0, 1]);
        assert_eq!(coded.len(), 9);
    }

    #[test]
    fn output_matches_trellis_tables() {
        let t = Trellis::preset("k5").unwrap();
        let mut e = ConvEncoder::new(&t);
        let mut state = 0usize;
        let mut rng = crate::rng::Xoshiro256::seeded(21);
        for _ in 0..500 {
            let b = rng.next_bit();
            let expect = t.output[state][b as usize];
            let got = e.push(b);
            assert_eq!(got.as_int(), expect);
            state = t.next_state[state][b as usize] as usize;
            assert_eq!(e.state() as usize, state);
        }
    }
}
