//! Blocking client for the `pbvd serve` daemon.
//!
//! [`ServeClient`] speaks the [`protocol`](crate::serve::protocol)
//! wire format over one TCP connection = one stream.  It is what the
//! integration tests drive the daemon with, and doubles as the
//! reference implementation for clients in other languages: connect,
//! HELLO, read the geometry from HELLO_ACK, then pipeline SUBMITs
//! against a bounded outstanding window and reassemble RESULTs.
//!
//! The window matters: the daemon acknowledges a frame against the
//! stream's backpressure budget only when its result has been written
//! back, so a client that submits unboundedly ahead of its reads would
//! deadlock itself once the server-side window fills.  `decode_stream`
//! keeps at most `window` frames outstanding — at least 2 keeps the
//! wire busy while a group decodes.

use std::collections::{HashMap, VecDeque};
use std::net::{TcpStream, ToSocketAddrs};

use crate::channel::unpack_bits;
use crate::coordinator::frame_stream;
use crate::json::Json;
use crate::serve::protocol::{
    read_message, wire_to_words, write_message, ServeError, Verb,
};

/// The daemon's geometry, from HELLO_ACK.  Frames submitted on this
/// connection must be exactly `frame_bytes` long; results carry
/// `result_bytes` (= `4 * ceil(block/32)`) packed-bit bytes.
#[derive(Clone, Debug)]
pub struct ServerInfo {
    pub engine: String,
    pub preset: String,
    pub batch: usize,
    pub block: usize,
    pub depth: usize,
    pub r: usize,
    pub q: u32,
    pub frame_bytes: usize,
    pub result_bytes: usize,
}

impl ServerInfo {
    fn from_json(j: &Json) -> Result<ServerInfo, ServeError> {
        let get = |k: &str| {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| ServeError::BadHello(format!("HELLO_ACK missing {k}")))
        };
        let s = |k: &str| {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| ServeError::BadHello(format!("HELLO_ACK missing {k}")))
        };
        Ok(ServerInfo {
            engine: s("engine")?,
            preset: s("preset")?,
            batch: get("batch")?,
            block: get("block")?,
            depth: get("depth")?,
            r: get("r")?,
            q: get("q")? as u32,
            frame_bytes: get("frame_bytes")?,
            result_bytes: get("result_bytes")?,
        })
    }
}

/// One connection to a `pbvd serve` daemon (one stream).
pub struct ServeClient {
    sock: TcpStream,
    info: ServerInfo,
    next_seq: u32,
    /// Results that arrived while waiting for a control reply.
    pending: VecDeque<(u32, Result<Vec<u32>, ServeError>)>,
}

impl ServeClient {
    /// Connect and complete the HELLO handshake.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<ServeClient, ServeError> {
        Self::connect_with(addr, None)
    }

    /// Connect, asserting the daemon serves `preset` (the daemon
    /// refuses the HELLO with a typed `bad_hello` error otherwise).
    pub fn connect_with(
        addr: impl ToSocketAddrs,
        preset: Option<&str>,
    ) -> Result<ServeClient, ServeError> {
        let sock = TcpStream::connect(addr).map_err(|e| ServeError::Io(e.to_string()))?;
        let _ = sock.set_nodelay(true);
        let mut client = ServeClient {
            sock,
            info: ServerInfo {
                engine: String::new(),
                preset: String::new(),
                batch: 0,
                block: 0,
                depth: 0,
                r: 0,
                q: 0,
                frame_bytes: 0,
                result_bytes: 0,
            },
            next_seq: 0,
            pending: VecDeque::new(),
        };
        let payload = match preset {
            Some(p) => {
                let mut o = Json::obj();
                o.set("preset", Json::from(p));
                o.to_string().into_bytes()
            }
            None => Vec::new(),
        };
        write_message(&mut client.sock, Verb::Hello, 0, &payload)?;
        loop {
            let msg = read_message(&mut client.sock)?;
            match msg.verb {
                Verb::Heartbeat | Verb::Pong => continue,
                Verb::HelloAck => {
                    let text = String::from_utf8_lossy(&msg.payload).into_owned();
                    let json = Json::parse(&text)
                        .map_err(|e| ServeError::BadHello(format!("unparseable HELLO_ACK: {e}")))?;
                    client.info = ServerInfo::from_json(&json)?;
                    return Ok(client);
                }
                Verb::Error => return Err(ServeError::from_wire(&msg.payload)),
                other => return Err(ServeError::UnknownVerb(other as u8)),
            }
        }
    }

    /// The daemon's geometry.
    pub fn info(&self) -> &ServerInfo {
        &self.info
    }

    /// Submit one frame (`frame_bytes` i8 LLRs); returns its sequence
    /// number.  Does not wait for the result.
    pub fn submit_frame(&mut self, llr: &[i8]) -> Result<u32, ServeError> {
        let seq = self.next_seq;
        self.next_seq = self.next_seq.wrapping_add(1);
        let bytes: Vec<u8> = llr.iter().map(|&v| v as u8).collect();
        write_message(&mut self.sock, Verb::Submit, seq, &bytes)?;
        Ok(seq)
    }

    /// Wait for the next frame result: `(seq, packed words)` on
    /// success, or the frame's typed error.  Skips heartbeats.
    pub fn recv_result(&mut self) -> Result<(u32, Vec<u32>), ServeError> {
        if let Some((seq, res)) = self.pending.pop_front() {
            return res.map(|words| (seq, words));
        }
        loop {
            let msg = read_message(&mut self.sock)?;
            match msg.verb {
                Verb::Heartbeat | Verb::Pong => continue,
                Verb::Result => {
                    let words = wire_to_words(&msg.payload).ok_or_else(|| {
                        ServeError::Io("RESULT payload not a whole number of words".into())
                    })?;
                    return Ok((msg.seq, words));
                }
                Verb::Error => return Err(ServeError::from_wire(&msg.payload)),
                other => return Err(ServeError::UnknownVerb(other as u8)),
            }
        }
    }

    /// Fetch the daemon's QoS report (the STATS verb).  Results that
    /// arrive while waiting are buffered for `recv_result`.
    pub fn stats(&mut self) -> Result<Json, ServeError> {
        let seq = self.next_seq;
        self.next_seq = self.next_seq.wrapping_add(1);
        write_message(&mut self.sock, Verb::Stats, seq, &[])?;
        loop {
            let msg = read_message(&mut self.sock)?;
            match msg.verb {
                Verb::Heartbeat | Verb::Pong => continue,
                Verb::Result => {
                    let words = wire_to_words(&msg.payload).ok_or_else(|| {
                        ServeError::Io("RESULT payload not a whole number of words".into())
                    });
                    self.pending.push_back((msg.seq, words));
                }
                Verb::Error => self
                    .pending
                    .push_back((msg.seq, Err(ServeError::from_wire(&msg.payload)))),
                Verb::StatsReply => {
                    let text = String::from_utf8_lossy(&msg.payload).into_owned();
                    return Json::parse(&text)
                        .map_err(|e| ServeError::Io(format!("unparseable STATS_REPLY: {e}")));
                }
                other => return Err(ServeError::UnknownVerb(other as u8)),
            }
        }
    }

    /// Keepalive round trip (refreshes the daemon's stall clock for
    /// this stream).
    pub fn ping(&mut self) -> Result<(), ServeError> {
        let seq = self.next_seq;
        self.next_seq = self.next_seq.wrapping_add(1);
        write_message(&mut self.sock, Verb::Ping, seq, &[])?;
        loop {
            let msg = read_message(&mut self.sock)?;
            match msg.verb {
                Verb::Heartbeat => continue,
                Verb::Pong => return Ok(()),
                Verb::Result => {
                    let words = wire_to_words(&msg.payload).ok_or_else(|| {
                        ServeError::Io("RESULT payload not a whole number of words".into())
                    });
                    self.pending.push_back((msg.seq, words));
                }
                Verb::Error => self
                    .pending
                    .push_back((msg.seq, Err(ServeError::from_wire(&msg.payload)))),
                other => return Err(ServeError::UnknownVerb(other as u8)),
            }
        }
    }

    /// Graceful close.
    pub fn bye(&mut self) -> Result<(), ServeError> {
        write_message(&mut self.sock, Verb::Bye, self.next_seq, &[])
    }

    /// Decode a whole quantized LLR stream (`n_bits * R` values)
    /// through the daemon: frame per PB, pipeline with at most
    /// `window` frames outstanding, reassemble in block order.
    /// Bit-identical to `StreamCoordinator::decode_stream` on the
    /// same engine geometry.
    pub fn decode_stream(&mut self, llr: &[i32], window: usize) -> Result<Vec<u8>, ServeError> {
        let (r, block, depth) = (self.info.r, self.info.block, self.info.depth);
        let n_bits = llr.len() / r;
        // batch=1 framing: one PB per frame, first_block == index
        let frames = frame_stream(llr, r, block, depth, 1);
        let window = window.max(1);
        let mut seq_to_block: HashMap<u32, usize> = HashMap::new();
        let mut out = vec![0u8; n_bits];
        let mut next = 0usize;
        let mut outstanding = 0usize;
        let mut done = 0usize;
        while done < frames.len() {
            while next < frames.len() && outstanding < window {
                let seq = self.submit_frame(&frames[next].llr_i8)?;
                seq_to_block.insert(seq, next);
                next += 1;
                outstanding += 1;
            }
            let (seq, words) = self.recv_result()?;
            outstanding -= 1;
            done += 1;
            let blk = *seq_to_block
                .get(&seq)
                .ok_or_else(|| ServeError::Io(format!("unexpected result seq {seq}")))?;
            let bits = unpack_bits(&words, block);
            let start = blk * block;
            if start < n_bits {
                let take = block.min(n_bits - start);
                out[start..start + take].copy_from_slice(&bits[..take]);
            }
        }
        Ok(out)
    }
}
