//! Blocking, self-healing client for the `pbvd serve` daemon.
//!
//! [`ServeClient`] speaks the [`protocol`](crate::serve::protocol)
//! wire format over one TCP connection = one stream.  It is what the
//! integration and chaos tests drive the daemon with, and doubles as
//! the reference implementation for clients in other languages:
//! connect, HELLO, read the geometry (and resume `token`) from
//! HELLO_ACK, then pipeline SUBMITs against a bounded outstanding
//! window and reassemble RESULTs.
//!
//! The window matters: the daemon acknowledges a frame against the
//! stream's backpressure budget only when its result has been written
//! back, so a client that submits unboundedly ahead of its reads would
//! deadlock itself once the server-side window fills.  `decode_stream`
//! keeps at most `window` frames outstanding — at least 2 keeps the
//! wire busy while a group decodes.
//!
//! # Timeouts, reconnect, resume
//!
//! Every socket operation runs under the
//! [`RetryPolicy`](crate::config::RetryPolicy) deadline
//! (`io_timeout_ms`), so a dead server surfaces as the typed
//! [`ServeError::Timeout`] instead of blocking forever.  Server
//! HEARTBEAT frames prove the daemon is alive but deliberately do
//! **not** extend the deadline while a *result* is awaited — a daemon
//! that heartbeats without ever producing the next result still times
//! out.
//!
//! When a connection dies mid-stream (`Io`/`Timeout`), `decode_stream`
//! heals itself: it reconnects under the policy's capped exponential
//! backoff (± jitter), sends RESUME `{token, next_needed}` where
//! `next_needed` is the lowest result seq it has not yet applied, lets
//! the daemon replay every missing result exactly once, and resubmits
//! — under fresh seqs — only the frames the daemon never accepted
//! (seq ≥ the `next_expected` in the resume ack).  Duplicate replays
//! (an ack racing the crash) are dropped by the outstanding-map gate,
//! so the reassembled stream is bit-identical with no frame lost or
//! applied twice.  Overload sheds ([`ServeError::RetryAfter`]) are
//! honored per frame: sleep the hinted backoff, then resubmit.

use std::collections::{HashMap, VecDeque};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use crate::channel::unpack_bits;
use crate::config::RetryPolicy;
use crate::coordinator::frame_stream;
use crate::json::Json;
use crate::rng::Xoshiro256;
use crate::serve::protocol::{
    read_message, wire_to_words, write_message, ServeError, Verb,
};

/// Client-side connection policy: preset assertion plus the
/// [`RetryPolicy`] governing socket deadlines, reconnect attempts, and
/// backoff.  `seed` makes the backoff jitter deterministic (chaos
/// tests log it; fixed default otherwise).
#[derive(Clone, Debug)]
pub struct ClientOptions {
    /// Assert the daemon serves this preset (HELLO is refused with a
    /// typed error otherwise).
    pub preset: Option<String>,
    /// Deadlines and reconnect/backoff policy.
    pub retry: RetryPolicy,
    /// Seed for the backoff jitter PRNG.
    pub seed: u64,
}

impl Default for ClientOptions {
    fn default() -> ClientOptions {
        ClientOptions {
            preset: None,
            retry: RetryPolicy::default(),
            seed: 0xC11E_0001,
        }
    }
}

/// The daemon's geometry, from HELLO_ACK.  Frames submitted on this
/// connection must be exactly `frame_bytes` long; results carry
/// `result_bytes` (= `4 * ceil(block/32)`) packed-bit bytes.
#[derive(Clone, Debug)]
pub struct ServerInfo {
    pub engine: String,
    pub preset: String,
    pub batch: usize,
    pub block: usize,
    pub depth: usize,
    pub r: usize,
    pub q: u32,
    pub frame_bytes: usize,
    pub result_bytes: usize,
}

impl ServerInfo {
    fn from_json(j: &Json) -> Result<ServerInfo, ServeError> {
        let get = |k: &str| {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| ServeError::BadHello(format!("HELLO_ACK missing {k}")))
        };
        let s = |k: &str| {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| ServeError::BadHello(format!("HELLO_ACK missing {k}")))
        };
        Ok(ServerInfo {
            engine: s("engine")?,
            preset: s("preset")?,
            batch: get("batch")?,
            block: get("block")?,
            depth: get("depth")?,
            r: get("r")?,
            q: get("q")? as u32,
            frame_bytes: get("frame_bytes")?,
            result_bytes: get("result_bytes")?,
        })
    }

}

/// Resolve and dial, honoring the policy's connect/read/write
/// deadlines.
fn dial(addrs: &[SocketAddr], retry: &RetryPolicy) -> Result<TcpStream, ServeError> {
    let mut last: Option<ServeError> = None;
    for a in addrs {
        let conn = match retry.io_timeout() {
            Some(t) => TcpStream::connect_timeout(a, t),
            None => TcpStream::connect(a),
        };
        match conn {
            Ok(sock) => {
                let _ = sock.set_nodelay(true);
                let _ = sock.set_read_timeout(retry.io_timeout());
                let _ = sock.set_write_timeout(retry.io_timeout());
                return Ok(sock);
            }
            Err(e) => last = Some(ServeError::from_io(&e)),
        }
    }
    Err(last.unwrap_or_else(|| ServeError::Io("address resolved to nothing".into())))
}

/// Progress-deadline check for the noise-skipping read loops:
/// heartbeats prove liveness but do not extend the wait for the
/// message actually awaited.
fn still_waiting(deadline: Option<Instant>) -> Result<(), ServeError> {
    match deadline {
        Some(d) if Instant::now() >= d => Err(ServeError::Timeout),
        _ => Ok(()),
    }
}

/// One connection to a `pbvd serve` daemon (one stream).
pub struct ServeClient {
    sock: TcpStream,
    addrs: Vec<SocketAddr>,
    opts: ClientOptions,
    info: ServerInfo,
    /// Resume token from HELLO_ACK (`None` when the daemon has resume
    /// disabled — the client then cannot heal a dead connection).
    token: Option<u64>,
    rng: Xoshiro256,
    next_seq: u32,
    /// Results that arrived while waiting for a control reply.
    pending: VecDeque<(u32, Result<Vec<u32>, ServeError>)>,
}

impl ServeClient {
    /// Connect and complete the HELLO handshake under the default
    /// [`ClientOptions`].
    pub fn connect(addr: impl ToSocketAddrs) -> Result<ServeClient, ServeError> {
        Self::connect_opts(addr, ClientOptions::default())
    }

    /// Connect, asserting the daemon serves `preset` (the daemon
    /// refuses the HELLO with a typed `bad_hello` error otherwise).
    pub fn connect_with(
        addr: impl ToSocketAddrs,
        preset: Option<&str>,
    ) -> Result<ServeClient, ServeError> {
        Self::connect_opts(
            addr,
            ClientOptions {
                preset: preset.map(str::to_string),
                ..ClientOptions::default()
            },
        )
    }

    /// Connect with an explicit policy (deadlines, reconnects,
    /// backoff, jitter seed).
    pub fn connect_opts(
        addr: impl ToSocketAddrs,
        opts: ClientOptions,
    ) -> Result<ServeClient, ServeError> {
        let addrs: Vec<SocketAddr> = addr
            .to_socket_addrs()
            .map_err(|e| ServeError::from_io(&e))?
            .collect();
        let mut sock = dial(&addrs, &opts.retry)?;
        let payload = match &opts.preset {
            Some(p) => {
                let mut o = Json::obj();
                o.set("preset", Json::from(p.as_str()));
                o.to_string().into_bytes()
            }
            None => Vec::new(),
        };
        write_message(&mut sock, Verb::Hello, 0, &payload)?;
        let deadline = opts.retry.io_timeout().map(|t| Instant::now() + t);
        loop {
            let msg = read_message(&mut sock)?;
            match msg.verb {
                Verb::Heartbeat | Verb::Pong => still_waiting(deadline)?,
                Verb::HelloAck => {
                    let (info, token) = parse_hello_ack(&msg.payload)?;
                    let rng = Xoshiro256::seeded(opts.seed);
                    return Ok(ServeClient {
                        sock,
                        addrs,
                        opts,
                        info,
                        token,
                        rng,
                        next_seq: 0,
                        pending: VecDeque::new(),
                    });
                }
                Verb::Error => return Err(ServeError::from_wire(&msg.payload)),
                other => return Err(ServeError::UnknownVerb(other as u8)),
            }
        }
    }

    /// The daemon's geometry.
    pub fn info(&self) -> &ServerInfo {
        &self.info
    }

    /// The stream's resume token (16 hex digits), when the daemon
    /// issued one.
    pub fn resume_token(&self) -> Option<String> {
        self.token.map(|t| format!("{t:016x}"))
    }

    /// Submit one frame (`frame_bytes` i8 LLRs); returns its sequence
    /// number.  Does not wait for the result.
    pub fn submit_frame(&mut self, llr: &[i8]) -> Result<u32, ServeError> {
        let seq = self.next_seq;
        self.next_seq = self.next_seq.wrapping_add(1);
        let bytes: Vec<u8> = llr.iter().map(|&v| v as u8).collect();
        write_message(&mut self.sock, Verb::Submit, seq, &bytes)?;
        Ok(seq)
    }

    /// Wait for the next frame outcome: `(seq, result)`.  Skips
    /// heartbeats without letting them extend the progress deadline;
    /// a transport failure (or deadline expiry) is the outer `Err`.
    fn recv_any(&mut self) -> Result<(u32, Result<Vec<u32>, ServeError>), ServeError> {
        if let Some(item) = self.pending.pop_front() {
            return Ok(item);
        }
        let deadline = self.opts.retry.io_timeout().map(|t| Instant::now() + t);
        loop {
            let msg = read_message(&mut self.sock)?;
            match msg.verb {
                Verb::Heartbeat | Verb::Pong => still_waiting(deadline)?,
                Verb::Result => {
                    let res = wire_to_words(&msg.payload).ok_or_else(|| {
                        ServeError::Io("RESULT payload not a whole number of words".into())
                    });
                    return Ok((msg.seq, res));
                }
                Verb::Error => return Ok((msg.seq, Err(ServeError::from_wire(&msg.payload)))),
                other => return Err(ServeError::UnknownVerb(other as u8)),
            }
        }
    }

    /// Wait for the next frame result: `(seq, packed words)` on
    /// success, or the frame's typed error.  Skips heartbeats; returns
    /// [`ServeError::Timeout`] once the policy deadline passes without
    /// a result.
    pub fn recv_result(&mut self) -> Result<(u32, Vec<u32>), ServeError> {
        let (seq, res) = self.recv_any()?;
        res.map(|words| (seq, words))
    }

    /// Fetch the daemon's QoS report (the STATS verb).  Results that
    /// arrive while waiting are buffered for `recv_result`.
    pub fn stats(&mut self) -> Result<Json, ServeError> {
        let seq = self.next_seq;
        self.next_seq = self.next_seq.wrapping_add(1);
        write_message(&mut self.sock, Verb::Stats, seq, &[])?;
        let deadline = self.opts.retry.io_timeout().map(|t| Instant::now() + t);
        loop {
            let msg = read_message(&mut self.sock)?;
            match msg.verb {
                Verb::Heartbeat | Verb::Pong => still_waiting(deadline)?,
                Verb::Result => {
                    let words = wire_to_words(&msg.payload).ok_or_else(|| {
                        ServeError::Io("RESULT payload not a whole number of words".into())
                    });
                    self.pending.push_back((msg.seq, words));
                }
                Verb::Error => self
                    .pending
                    .push_back((msg.seq, Err(ServeError::from_wire(&msg.payload)))),
                Verb::StatsReply => {
                    let text = String::from_utf8_lossy(&msg.payload).into_owned();
                    return Json::parse(&text)
                        .map_err(|e| ServeError::Io(format!("unparseable STATS_REPLY: {e}")));
                }
                other => return Err(ServeError::UnknownVerb(other as u8)),
            }
        }
    }

    /// Keepalive round trip (refreshes the daemon's stall clock for
    /// this stream).
    pub fn ping(&mut self) -> Result<(), ServeError> {
        let seq = self.next_seq;
        self.next_seq = self.next_seq.wrapping_add(1);
        write_message(&mut self.sock, Verb::Ping, seq, &[])?;
        let deadline = self.opts.retry.io_timeout().map(|t| Instant::now() + t);
        loop {
            let msg = read_message(&mut self.sock)?;
            match msg.verb {
                Verb::Heartbeat => still_waiting(deadline)?,
                Verb::Pong => return Ok(()),
                Verb::Result => {
                    let words = wire_to_words(&msg.payload).ok_or_else(|| {
                        ServeError::Io("RESULT payload not a whole number of words".into())
                    });
                    self.pending.push_back((msg.seq, words));
                }
                Verb::Error => self
                    .pending
                    .push_back((msg.seq, Err(ServeError::from_wire(&msg.payload)))),
                other => return Err(ServeError::UnknownVerb(other as u8)),
            }
        }
    }

    /// Graceful close.
    pub fn bye(&mut self) -> Result<(), ServeError> {
        write_message(&mut self.sock, Verb::Bye, self.next_seq, &[])
    }

    // ---- reconnect / resume ------------------------------------------------

    /// One RESUME attempt on a fresh connection.  On success the new
    /// socket replaces the dead one and the daemon's `next_expected`
    /// (the seq the client must resubmit from) is returned.
    fn try_resume(&mut self, token: u64, next_needed: u32) -> Result<u32, ServeError> {
        let mut sock = dial(&self.addrs, &self.opts.retry)?;
        let mut o = Json::obj();
        o.set("token", Json::from(format!("{token:016x}")));
        o.set("next_needed", Json::from(next_needed as usize));
        write_message(&mut sock, Verb::Resume, 0, o.to_string().as_bytes())?;
        let deadline = self.opts.retry.io_timeout().map(|t| Instant::now() + t);
        loop {
            let msg = read_message(&mut sock)?;
            match msg.verb {
                Verb::Heartbeat | Verb::Pong => still_waiting(deadline)?,
                Verb::HelloAck => {
                    let text = String::from_utf8_lossy(&msg.payload).into_owned();
                    let json = Json::parse(&text)
                        .map_err(|e| ServeError::BadResume(format!("unparseable ack: {e}")))?;
                    if json.get("resumed").and_then(Json::as_bool) != Some(true) {
                        return Err(ServeError::BadResume(
                            "ack does not confirm the resume".into(),
                        ));
                    }
                    let next_expected = json
                        .get("next_expected")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| {
                            ServeError::BadResume("ack lacks next_expected".into())
                        })? as u32;
                    self.info = ServerInfo::from_json(&json)?;
                    self.sock = sock;
                    return Ok(next_expected);
                }
                Verb::Error => return Err(ServeError::from_wire(&msg.payload)),
                other => return Err(ServeError::UnknownVerb(other as u8)),
            }
        }
    }

    /// Reconnect under capped exponential backoff (± jitter) and
    /// RESUME the stream.  Transport failures are retried up to
    /// `max_reconnects`; a definitive server refusal (a typed remote
    /// error, e.g. `bad_resume` after the grace window) is not.
    fn reconnect_and_resume(&mut self, next_needed: u32) -> Result<u32, ServeError> {
        let token = self.token.ok_or_else(|| {
            ServeError::BadResume("daemon issued no resume token (resume disabled)".into())
        })?;
        let attempts = self.opts.retry.max_reconnects.max(1);
        let mut last = ServeError::Timeout;
        for attempt in 0..attempts {
            std::thread::sleep(self.opts.retry.backoff(attempt, &mut self.rng));
            match self.try_resume(token, next_needed) {
                Ok(next_expected) => return Ok(next_expected),
                Err(e @ (ServeError::Remote { .. } | ServeError::BadResume(_))) => {
                    return Err(e)
                }
                Err(e) => last = e,
            }
        }
        Err(last)
    }

    /// True for the transport failures `decode_stream` heals over.
    fn recoverable(e: &ServeError) -> bool {
        matches!(e, ServeError::Io(_) | ServeError::Timeout)
    }

    // ---- streaming ---------------------------------------------------------

    /// Decode a whole quantized LLR stream (`n_bits * R` values)
    /// through the daemon: frame per PB, pipeline with at most
    /// `window` frames outstanding, reassemble in block order.
    /// Bit-identical to `StreamCoordinator::decode_stream` on the
    /// same engine geometry — including across connection loss, which
    /// is healed by reconnect + RESUME (see the [module docs](self)).
    pub fn decode_stream(&mut self, llr: &[i32], window: usize) -> Result<Vec<u8>, ServeError> {
        let (r, block, depth) = (self.info.r, self.info.block, self.info.depth);
        let n_bits = llr.len() / r;
        // batch=1 framing: one PB per frame, first_block == index
        let frames = frame_stream(llr, r, block, depth, 1);
        let window = window.max(1);
        let mut out = vec![0u8; n_bits];
        // outstanding: submitted, result not yet applied (the dedup
        // gate — a replayed duplicate misses the map and is dropped)
        let mut outstanding: HashMap<u32, usize> = HashMap::new();
        // blocks owed a (re)submission, ahead of fresh frames
        let mut redo: VecDeque<usize> = VecDeque::new();
        let mut next = 0usize;
        let mut done = 0usize;
        while done < frames.len() {
            // fill the window: resubmissions first, then fresh frames
            while outstanding.len() < window {
                let blk = match redo.pop_front() {
                    Some(b) => b,
                    None if next < frames.len() => {
                        next += 1;
                        next - 1
                    }
                    None => break,
                };
                match self.submit_frame(&frames[blk].llr_i8) {
                    Ok(seq) => {
                        outstanding.insert(seq, blk);
                    }
                    Err(e) if Self::recoverable(&e) => {
                        redo.push_front(blk);
                        self.heal(&mut outstanding, &mut redo)?;
                    }
                    Err(e) => return Err(e),
                }
            }
            let (seq, res) = match self.recv_any() {
                Ok(item) => item,
                Err(e) if Self::recoverable(&e) => {
                    self.heal(&mut outstanding, &mut redo)?;
                    continue;
                }
                Err(e) => return Err(e),
            };
            match res {
                Ok(words) => {
                    // the gate: only a still-outstanding seq applies
                    if let Some(blk) = outstanding.remove(&seq) {
                        done += 1;
                        let bits = unpack_bits(&words, block);
                        let start = blk * block;
                        if start < n_bits {
                            let take = block.min(n_bits - start);
                            out[start..start + take].copy_from_slice(&bits[..take]);
                        }
                    }
                }
                Err(ServeError::RetryAfter { ms }) => {
                    // overload shed: honor the hint, then resubmit
                    if let Some(blk) = outstanding.remove(&seq) {
                        std::thread::sleep(Duration::from_millis(ms));
                        redo.push_back(blk);
                    }
                }
                Err(e) => return Err(e),
            }
        }
        Ok(out)
    }

    /// Recover `decode_stream` after a transport failure: reconnect +
    /// RESUME, then move every frame the daemon never accepted (seq ≥
    /// the resume ack's `next_expected`) back onto the redo queue;
    /// results below it replay over the new connection.
    fn heal(
        &mut self,
        outstanding: &mut HashMap<u32, usize>,
        redo: &mut VecDeque<usize>,
    ) -> Result<(), ServeError> {
        let next_needed = outstanding
            .keys()
            .copied()
            .min()
            .unwrap_or(self.next_seq);
        let next_expected = self.reconnect_and_resume(next_needed)?;
        let mut lost: Vec<u32> = outstanding
            .keys()
            .copied()
            .filter(|&s| s >= next_expected)
            .collect();
        lost.sort_unstable();
        for seq in lost {
            if let Some(blk) = outstanding.remove(&seq) {
                redo.push_back(blk);
            }
        }
        Ok(())
    }
}

/// HELLO_ACK payload → geometry + optional resume token.
fn parse_hello_ack(payload: &[u8]) -> Result<(ServerInfo, Option<u64>), ServeError> {
    let text = String::from_utf8_lossy(payload).into_owned();
    let json = Json::parse(&text)
        .map_err(|e| ServeError::BadHello(format!("unparseable HELLO_ACK: {e}")))?;
    let info = ServerInfo::from_json(&json)?;
    let token = json
        .get("token")
        .and_then(Json::as_str)
        .and_then(|s| u64::from_str_radix(s, 16).ok())
        .filter(|&t| t != 0);
    Ok((info, token))
}
