//! Deterministic, seeded fault injection for the serve path.
//!
//! A [`FaultPlan`] is parsed from a compact spec string (the
//! `PBVD_FAULTS` env var, `--faults` CLI flag, or
//! [`DecoderConfig::faults`](crate::config::DecoderConfig::faults))
//! and consulted at four seams:
//!
//! | site     | hook                        | injected by                                   |
//! |----------|-----------------------------|-----------------------------------------------|
//! | read     | [`FaultPlan::on_read`]      | session reader, before each message read      |
//! | write    | [`FaultPlan::on_write`]     | session writer, before each RESULT frame      |
//! | dispatch | [`FaultPlan::on_dispatch`]  | scheduler / supervisor, per coalesced group   |
//! | worker   | [`FaultPlan::on_worker_job`]| pool worker, before executing each job        |
//!
//! # Spec grammar
//!
//! A spec is `;`-separated clauses, each `action[=arg]@selector`
//! (plus the special clause `seed=N`):
//!
//! ```text
//! drop_write@seq=7;delay_read=20ms@p=0.1;worker_panic@job=3;dispatch_err@group=2
//! ```
//!
//! Actions and the selectors they accept:
//!
//! | action              | site     | selectors        | effect                                   |
//! |---------------------|----------|------------------|------------------------------------------|
//! | `drop_write`        | write    | `seq` `nth` `p`  | skip writing (and acking) that result    |
//! | `kill_conn`         | write    | `seq` `nth` `p`  | shut the connection down instead of writing |
//! | `delay_read=DUR`    | read     | `nth` `p`        | sleep before the read                    |
//! | `delay_write=DUR`   | write    | `seq` `nth` `p`  | sleep before the write                   |
//! | `worker_panic`      | worker   | `job` `nth` `p`  | panic inside the worker thread           |
//! | `dispatch_err`      | dispatch | `group` `nth` `p`| fail the group with an engine error      |
//! | `flip_llr=N`        | dispatch | `nth` `p`        | flip N input LLR bytes before dispatch   |
//! | `corrupt_result`    | dispatch | `nth` `p`        | flip the decoded words after the decode  |
//!
//! `flip_llr` and `corrupt_result` are *payload-corruption* faults for
//! exercising the decode-integrity layer ([`audit`](crate::audit)):
//! `flip_llr` corrupts the copy of the group handed to the engine (the
//! auditor re-decodes the clean original, so the divergence is
//! detectable), while `corrupt_result` flips the words an otherwise
//! clean decode produced (guaranteed detection for every audited
//! block).
//!
//! Selectors:
//!
//! * `seq=N` — the result frame with sequence number `N` (write site
//!   only, where the seq is known).
//! * `nth=N` — the N-th consultation of that site, counted from 0
//!   across the whole daemon.  `job=N` and `group=N` are the same
//!   ordinal selector spelled for their site (and are validated to
//!   only appear on `worker_panic` / `dispatch_err` respectively).
//! * `p=F` — fire with probability `F` (`0 < F <= 1`) on every
//!   consultation, drawn from the plan's seeded [`Xoshiro256`] stream
//!   so a given `seed=N` replays the identical fault sequence.
//!
//! `seq`/`nth`/`job`/`group` rules are **one-shot**: an atomic latch
//! guarantees they fire at most once, so "kill the connection at seq
//! 5" does not re-kill the replacement connection when seq 5 is
//! replayed after RESUME.  `p=` rules have no latch.
//!
//! Durations (`DUR`) take `us`/`ms`/`s` suffixes; a bare integer is
//! milliseconds.
//!
//! # Zero cost when absent
//!
//! Every injection site holds an `Option<Arc<FaultPlan>>` (or an
//! armed-flag cell, see `pool::FaultCell`), so production runs with no
//! plan configured pay one `None` check — no locks, no atomics on the
//! data path.
//!
//! [`Xoshiro256`]: crate::rng::Xoshiro256

use crate::json::Json;
use crate::rng::Xoshiro256;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Default RNG seed for `p=` selectors when the spec has no `seed=N`
/// clause.
pub const DEFAULT_FAULT_SEED: u64 = 0x5EED;

/// A spec-string parse failure: which clause was malformed and why.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultParseError(String);

impl std::fmt::Display for FaultParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "bad fault spec: {}", self.0)
    }
}

impl std::error::Error for FaultParseError {}

fn err(msg: impl Into<String>) -> FaultParseError {
    FaultParseError(msg.into())
}

/// What a fault clause does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Action {
    DropWrite,
    KillConn,
    DelayRead,
    DelayWrite,
    WorkerPanic,
    DispatchErr,
    FlipLlr,
    CorruptResult,
}

/// Which injection seam an action applies to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Site {
    Read,
    Write,
    Dispatch,
    Worker,
}

impl Action {
    fn site(self) -> Site {
        match self {
            Action::DelayRead => Site::Read,
            Action::DropWrite | Action::KillConn | Action::DelayWrite => Site::Write,
            Action::DispatchErr | Action::FlipLlr | Action::CorruptResult => Site::Dispatch,
            Action::WorkerPanic => Site::Worker,
        }
    }

    fn name(self) -> &'static str {
        match self {
            Action::DropWrite => "drop_write",
            Action::KillConn => "kill_conn",
            Action::DelayRead => "delay_read",
            Action::DelayWrite => "delay_write",
            Action::WorkerPanic => "worker_panic",
            Action::DispatchErr => "dispatch_err",
            Action::FlipLlr => "flip_llr",
            Action::CorruptResult => "corrupt_result",
        }
    }
}

/// When a fault clause fires.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Selector {
    /// The result frame with this sequence number (write site only).
    Seq(u32),
    /// The n-th consultation of the action's site, counted from 0.
    Nth(u64),
    /// Each consultation independently, with this probability.
    Prob(f64),
}

struct Rule {
    action: Action,
    delay: Option<Duration>,
    /// Integer argument of `flip_llr=N` (how many LLR bytes to flip).
    arg: Option<u64>,
    sel: Selector,
    /// One-shot latch for `Seq`/`Nth` rules; `Prob` rules never latch.
    fired: AtomicBool,
}

/// What [`FaultPlan::on_write`] injects for one result frame.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WriteFault {
    /// Sleep this long before writing.
    pub delay: Option<Duration>,
    /// Skip the write entirely (the result must stay unacked so the
    /// replay buffer can re-deliver it).
    pub drop: bool,
    /// Shut the connection down instead of writing.
    pub kill: bool,
}

impl WriteFault {
    /// True when no write-site fault fired.
    pub fn is_clean(&self) -> bool {
        self.delay.is_none() && !self.drop && !self.kill
    }
}

/// A parsed, seeded fault plan: the shared oracle every injection seam
/// consults.  Thread-safe; sites share one plan via `Arc`.
pub struct FaultPlan {
    spec: String,
    seed: u64,
    rules: Vec<Rule>,
    rng: Mutex<Xoshiro256>,
    reads: AtomicU64,
    writes: AtomicU64,
    groups: AtomicU64,
    jobs: AtomicU64,
    flips: AtomicU64,
    corrupts: AtomicU64,
    injected: AtomicU64,
}

impl FaultPlan {
    /// Parse a spec string (see the [module docs](self) for the
    /// grammar).  An empty / whitespace-only spec yields an empty plan
    /// whose hooks all no-op.
    pub fn parse(spec: &str) -> Result<FaultPlan, FaultParseError> {
        let mut seed = DEFAULT_FAULT_SEED;
        let mut rules = Vec::new();
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if let Some(v) = clause.strip_prefix("seed=") {
                seed = v
                    .trim()
                    .parse()
                    .map_err(|_| err(format!("seed `{v}` is not a u64")))?;
                continue;
            }
            let (head, sel_str) = clause
                .split_once('@')
                .ok_or_else(|| err(format!("clause `{clause}` is missing its `@selector`")))?;
            let (name, arg) = match head.split_once('=') {
                Some((n, a)) => (n.trim(), Some(a.trim())),
                None => (head.trim(), None),
            };
            let action = match name {
                "drop_write" => Action::DropWrite,
                "kill_conn" => Action::KillConn,
                "delay_read" => Action::DelayRead,
                "delay_write" => Action::DelayWrite,
                "worker_panic" => Action::WorkerPanic,
                "dispatch_err" => Action::DispatchErr,
                "flip_llr" => Action::FlipLlr,
                "corrupt_result" => Action::CorruptResult,
                other => return Err(err(format!("unknown action `{other}`"))),
            };
            let (delay, int_arg) = match action {
                Action::DelayRead | Action::DelayWrite => {
                    let a = arg.ok_or_else(|| {
                        err(format!("`{name}` needs a duration, e.g. `{name}=20ms`"))
                    })?;
                    (Some(parse_duration(a)?), None)
                }
                Action::FlipLlr => {
                    let a = arg.ok_or_else(|| {
                        err(format!("`{name}` needs a flip count, e.g. `{name}=32`"))
                    })?;
                    let n: u64 = a
                        .parse()
                        .map_err(|_| err(format!("flip count `{a}` is not a u64")))?;
                    if n == 0 {
                        return Err(err("flip_llr count must be at least 1"));
                    }
                    (None, Some(n))
                }
                _ => {
                    if let Some(a) = arg {
                        return Err(err(format!("`{name}` takes no argument (got `{a}`)")));
                    }
                    (None, None)
                }
            };
            let sel = parse_selector(sel_str.trim(), action)?;
            rules.push(Rule {
                action,
                delay,
                arg: int_arg,
                sel,
                fired: AtomicBool::new(false),
            });
        }
        Ok(FaultPlan {
            spec: spec.trim().to_string(),
            seed,
            rules,
            rng: Mutex::new(Xoshiro256::seeded(seed)),
            reads: AtomicU64::new(0),
            writes: AtomicU64::new(0),
            groups: AtomicU64::new(0),
            jobs: AtomicU64::new(0),
            flips: AtomicU64::new(0),
            corrupts: AtomicU64::new(0),
            injected: AtomicU64::new(0),
        })
    }

    /// True when the plan has no fault clauses (a `seed=` clause alone
    /// still counts as empty).
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// The original spec string (trimmed).
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// The RNG seed driving `p=` selectors.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Total faults fired so far, across every site.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Did `rule` fire for this consultation?  Ordinal and seq rules
    /// latch atomically so they fire at most once.
    fn fires(&self, rule: &Rule, ordinal: u64, seq: Option<u32>) -> bool {
        let hit = match rule.sel {
            Selector::Seq(s) => {
                seq == Some(s) && !rule.fired.swap(true, Ordering::Relaxed)
            }
            Selector::Nth(n) => ordinal == n && !rule.fired.swap(true, Ordering::Relaxed),
            Selector::Prob(p) => {
                let mut rng = self.rng.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                rng.next_f64() < p
            }
        };
        if hit {
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Read-site hook: returns a delay to sleep before the next
    /// message read, if a `delay_read` clause fires.
    pub fn on_read(&self) -> Option<Duration> {
        let ordinal = self.reads.fetch_add(1, Ordering::Relaxed);
        let mut delay = None;
        for r in &self.rules {
            if r.action.site() == Site::Read && self.fires(r, ordinal, None) {
                delay = r.delay;
            }
        }
        delay
    }

    /// Write-site hook for the result frame `seq`: which write faults
    /// (delay / drop / kill) fire for it.
    pub fn on_write(&self, seq: u32) -> WriteFault {
        let ordinal = self.writes.fetch_add(1, Ordering::Relaxed);
        let mut f = WriteFault::default();
        for r in &self.rules {
            if r.action.site() != Site::Write || !self.fires(r, ordinal, Some(seq)) {
                continue;
            }
            match r.action {
                Action::DropWrite => f.drop = true,
                Action::KillConn => f.kill = true,
                Action::DelayWrite => f.delay = r.delay,
                _ => {}
            }
        }
        f
    }

    /// Dispatch-site hook: returns `Some(error message)` when a
    /// `dispatch_err` clause fails this coalesced group.
    pub fn on_dispatch(&self) -> Option<String> {
        let ordinal = self.groups.fetch_add(1, Ordering::Relaxed);
        for r in &self.rules {
            if r.action == Action::DispatchErr && self.fires(r, ordinal, None) {
                return Some(format!("injected dispatch fault (group {ordinal})"));
            }
        }
        None
    }

    /// Input-corruption hook, consulted per coalesced group before
    /// dispatch: when a `flip_llr` clause fires, how many LLR bytes of
    /// the *dispatch copy* to flip.  The seam must corrupt a copy, not
    /// the original buffer — the shadow auditor re-decodes the clean
    /// input, which is what makes the corruption detectable.
    pub fn on_flip_llr(&self) -> Option<u32> {
        let ordinal = self.flips.fetch_add(1, Ordering::Relaxed);
        for r in &self.rules {
            if r.action == Action::FlipLlr && self.fires(r, ordinal, None) {
                return Some(r.arg.unwrap_or(1).min(u32::MAX as u64) as u32);
            }
        }
        None
    }

    /// Result-corruption hook, consulted per successfully decoded
    /// group: true when a `corrupt_result` clause says the decoded
    /// words should be flipped before they are sliced into per-stream
    /// results.
    pub fn on_corrupt_result(&self) -> bool {
        let ordinal = self.corrupts.fetch_add(1, Ordering::Relaxed);
        self.rules
            .iter()
            .any(|r| r.action == Action::CorruptResult && self.fires(r, ordinal, None))
    }

    /// Worker-site hook: true when a `worker_panic` clause says this
    /// job's worker thread should panic.
    pub fn on_worker_job(&self) -> bool {
        let ordinal = self.jobs.fetch_add(1, Ordering::Relaxed);
        self.rules
            .iter()
            .any(|r| r.action == Action::WorkerPanic && self.fires(r, ordinal, None))
    }

    /// STATS-verb shape: the spec, seed, faults fired, and per-site
    /// consultation counts.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("spec", Json::from(self.spec.as_str()));
        o.set("seed", Json::from(self.seed as i64));
        o.set("injected", Json::from(self.injected() as usize));
        o.set("reads", Json::from(self.reads.load(Ordering::Relaxed) as usize));
        o.set("writes", Json::from(self.writes.load(Ordering::Relaxed) as usize));
        o.set("groups", Json::from(self.groups.load(Ordering::Relaxed) as usize));
        o.set("jobs", Json::from(self.jobs.load(Ordering::Relaxed) as usize));
        o.set("flips", Json::from(self.flips.load(Ordering::Relaxed) as usize));
        o.set("corrupts", Json::from(self.corrupts.load(Ordering::Relaxed) as usize));
        o
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} (seed {})", self.spec, self.seed)
    }
}

impl std::fmt::Debug for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultPlan")
            .field("spec", &self.spec)
            .field("seed", &self.seed)
            .field("rules", &self.rules.len())
            .field("injected", &self.injected())
            .finish()
    }
}

fn parse_selector(s: &str, action: Action) -> Result<Selector, FaultParseError> {
    let (key, val) = s
        .split_once('=')
        .ok_or_else(|| err(format!("selector `{s}` is not `key=value`")))?;
    let (key, val) = (key.trim(), val.trim());
    let ordinal = |what: &str| -> Result<Selector, FaultParseError> {
        val.parse::<u64>()
            .map(Selector::Nth)
            .map_err(|_| err(format!("{what} `{val}` is not a u64")))
    };
    match key {
        "seq" => {
            if action.site() != Site::Write {
                return Err(err(format!(
                    "`seq=` only selects write-site actions, not `{}`",
                    action.name()
                )));
            }
            val.parse::<u32>()
                .map(Selector::Seq)
                .map_err(|_| err(format!("seq `{val}` is not a u32")))
        }
        "nth" => ordinal("nth"),
        "job" => {
            if action != Action::WorkerPanic {
                return Err(err(format!(
                    "`job=` only selects `worker_panic`, not `{}`",
                    action.name()
                )));
            }
            ordinal("job")
        }
        "group" => {
            if action != Action::DispatchErr {
                return Err(err(format!(
                    "`group=` only selects `dispatch_err`, not `{}`",
                    action.name()
                )));
            }
            ordinal("group")
        }
        "p" => {
            let p: f64 = val
                .parse()
                .map_err(|_| err(format!("p `{val}` is not a float")))?;
            if !(p > 0.0 && p <= 1.0) {
                return Err(err(format!("p must be in (0, 1], got {p}")));
            }
            Ok(Selector::Prob(p))
        }
        other => Err(err(format!("unknown selector `{other}`"))),
    }
}

/// `20ms` / `150us` / `2s` / bare integer (= ms) to a [`Duration`].
fn parse_duration(s: &str) -> Result<Duration, FaultParseError> {
    let (num, mul_us) = if let Some(n) = s.strip_suffix("ms") {
        (n, 1_000u64)
    } else if let Some(n) = s.strip_suffix("us") {
        (n, 1)
    } else if let Some(n) = s.strip_suffix('s') {
        (n, 1_000_000)
    } else {
        (s, 1_000)
    };
    let v: u64 = num
        .trim()
        .parse()
        .map_err(|_| err(format!("duration `{s}` is not an integer with us/ms/s suffix")))?;
    Ok(Duration::from_micros(v.saturating_mul(mul_us)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issue_example_spec_parses() {
        let p = FaultPlan::parse(
            "drop_write@seq=7;delay_read=20ms@p=0.1;worker_panic@job=3;dispatch_err@group=2",
        )
        .unwrap();
        assert!(!p.is_empty());
        assert_eq!(p.rules.len(), 4);
        assert_eq!(p.seed(), DEFAULT_FAULT_SEED);
        assert_eq!(p.injected(), 0);
    }

    #[test]
    fn empty_spec_is_empty_and_inert() {
        let p = FaultPlan::parse("   ").unwrap();
        assert!(p.is_empty());
        assert_eq!(p.on_read(), None);
        assert!(p.on_write(0).is_clean());
        assert_eq!(p.on_dispatch(), None);
        assert!(!p.on_worker_job());
        assert_eq!(p.injected(), 0);
    }

    #[test]
    fn seed_clause_reseeds() {
        let p = FaultPlan::parse("seed=42").unwrap();
        assert!(p.is_empty());
        assert_eq!(p.seed(), 42);
    }

    #[test]
    fn malformed_specs_are_typed_errors() {
        for bad in [
            "drop_write",                 // missing selector
            "explode@seq=1",              // unknown action
            "drop_write@when=now",        // unknown selector
            "worker_panic@seq=3",         // seq only selects write-site
            "drop_write@job=1",           // job only selects worker_panic
            "delay_read@nth=0",           // delay needs a duration
            "delay_read=fast@nth=0",      // bad duration
            "drop_write=7@nth=0",         // no-arg action with arg
            "delay_write=5ms@p=1.5",      // p out of range
            "dispatch_err@nth=x",         // bad ordinal
            "seed=banana",                // bad seed
            "kill_conn@group=0",          // group only selects dispatch_err
            "flip_llr@nth=0",             // flip_llr needs a count
            "flip_llr=0@nth=0",           // zero flips is meaningless
            "flip_llr=x@nth=0",           // bad count
            "flip_llr=8@seq=1",           // seq only selects write-site
            "flip_llr=8@group=0",         // group only selects dispatch_err
            "corrupt_result=3@nth=0",     // no-arg action with arg
            "corrupt_result@job=0",       // job only selects worker_panic
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn seq_rule_is_one_shot() {
        let p = FaultPlan::parse("drop_write@seq=3").unwrap();
        assert!(p.on_write(1).is_clean());
        assert!(p.on_write(3).drop, "seq=3 must fire");
        assert!(p.on_write(3).is_clean(), "seq rules latch after firing");
        assert_eq!(p.injected(), 1);
    }

    #[test]
    fn nth_counts_per_site_from_zero() {
        let p = FaultPlan::parse("delay_read=5ms@nth=2").unwrap();
        assert_eq!(p.on_read(), None);
        assert_eq!(p.on_read(), None);
        assert_eq!(p.on_read(), Some(Duration::from_millis(5)));
        assert_eq!(p.on_read(), None);
        // write-site consultations don't advance the read ordinal
        let p = FaultPlan::parse("drop_write@nth=1").unwrap();
        assert_eq!(p.on_read(), None);
        assert!(p.on_write(9).is_clean());
        assert!(p.on_write(9).drop);
    }

    #[test]
    fn write_faults_compose() {
        let p =
            FaultPlan::parse("drop_write@seq=1;delay_write=5ms@seq=1;kill_conn@seq=2").unwrap();
        let f = p.on_write(1);
        assert!(f.drop && f.delay == Some(Duration::from_millis(5)) && !f.kill);
        let f = p.on_write(2);
        assert!(f.kill && !f.drop);
    }

    #[test]
    fn worker_and_dispatch_ordinals() {
        let p = FaultPlan::parse("worker_panic@job=1;dispatch_err@group=0").unwrap();
        assert!(!p.on_worker_job());
        assert!(p.on_worker_job());
        assert!(!p.on_worker_job(), "job rules latch");
        let msg = p.on_dispatch().expect("group=0 fires first");
        assert!(msg.contains("injected"), "{msg}");
        assert_eq!(p.on_dispatch(), None);
        assert_eq!(p.injected(), 2);
    }

    #[test]
    fn corruption_hooks_fire_and_latch() {
        let p = FaultPlan::parse("flip_llr=32@nth=1;corrupt_result@nth=0").unwrap();
        assert_eq!(p.on_flip_llr(), None);
        assert_eq!(p.on_flip_llr(), Some(32));
        assert_eq!(p.on_flip_llr(), None, "nth rules latch");
        assert!(p.on_corrupt_result());
        assert!(!p.on_corrupt_result());
        assert_eq!(p.injected(), 2);
        let j = p.to_json();
        assert_eq!(j.get("flips").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("corrupts").and_then(Json::as_usize), Some(2));
        // the corruption ordinals are independent of dispatch_err's
        let p = FaultPlan::parse("dispatch_err@group=0;flip_llr=1@nth=0").unwrap();
        assert_eq!(p.on_flip_llr(), Some(1));
        assert!(p.on_dispatch().is_some());
    }

    #[test]
    fn probabilistic_rules_replay_identically_for_a_seed() {
        let run = |spec: &str| -> Vec<bool> {
            let p = FaultPlan::parse(spec).unwrap();
            (0..64).map(|_| p.on_worker_job()).collect()
        };
        let a = run("seed=99;worker_panic@p=0.5");
        let b = run("seed=99;worker_panic@p=0.5");
        assert_eq!(a, b, "same seed must replay the same fault sequence");
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x), "p=0.5 mixes");
        let c = run("seed=100;worker_panic@p=0.5");
        assert_ne!(a, c, "different seed, different sequence");
    }

    #[test]
    fn durations_parse_all_suffixes() {
        assert_eq!(parse_duration("20ms").unwrap(), Duration::from_millis(20));
        assert_eq!(parse_duration("150us").unwrap(), Duration::from_micros(150));
        assert_eq!(parse_duration("2s").unwrap(), Duration::from_secs(2));
        assert_eq!(parse_duration("7").unwrap(), Duration::from_millis(7));
        assert!(parse_duration("1.5ms").is_err());
    }

    #[test]
    fn json_shape_counts_sites() {
        let p = FaultPlan::parse("seed=7;drop_write@seq=0").unwrap();
        let _ = p.on_write(0);
        let _ = p.on_read();
        let j = p.to_json();
        assert_eq!(j.get("seed").and_then(Json::as_i64), Some(7));
        assert_eq!(j.get("injected").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("writes").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("reads").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("jobs").and_then(Json::as_usize), Some(0));
    }
}
