//! The daemon: accept loop, per-client sessions, and the stall
//! detector.
//!
//! [`PbvdServer::bind`] builds one shared engine through the
//! [`DecoderConfig`](crate::config::DecoderConfig) factory (the same
//! single construction path every frontend uses), wraps it in a
//! [`Scheduler`], and listens on the configured address.  Each
//! accepted client gets a *reader* thread (blocking
//! [`read_message`](crate::serve::protocol::read_message) loop — the
//! socket, not a poll timeout, is the interruption point, so framing
//! can never desynchronize) and a *writer* thread draining a channel
//! of results and control replies; the writer emits HEARTBEAT frames
//! when idle so clients can tell a busy daemon from a dead one.
//!
//! Liveness is tracked per session as "milliseconds since the last
//! inbound message or completed result write".  A monitor thread
//! evicts any session that exceeds the configured stall timeout:
//! its stream is retired in the scheduler (dropping queued frames and
//! unblocking anything waiting on it) and its socket is shut down,
//! which unblocks the blocked reader/writer.  Other streams never
//! stall on a wedged peer — their groups keep dispatching, at worst
//! slightly emptier.  Idle clients that want to stay connected past
//! the stall timeout must PING.

use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::DecoderConfig;
use crate::json::Json;
use crate::runtime::Registry;
use crate::serve::protocol::{
    read_message, words_to_wire, write_message, Message, ServeError, Verb, PROTO_VERSION,
};
use crate::serve::scheduler::Scheduler;

/// What the writer thread is asked to put on the wire.
enum WriterMsg {
    /// A decoded frame (or its typed failure); acked to the scheduler
    /// once the bytes are out, which is what opens the backpressure
    /// window.
    Result {
        seq: u32,
        res: Result<Vec<u32>, ServeError>,
    },
    /// A control reply (HELLO_ACK, STATS_REPLY, PONG, ERROR).
    Control {
        verb: Verb,
        seq: u32,
        payload: Vec<u8>,
    },
}

/// Per-session state shared between the reader, writer, and monitor.
struct Session {
    /// Socket handle the monitor uses to break a wedged session's
    /// blocking reads/writes (`shutdown(Both)`).
    tcp: TcpStream,
    /// Scheduler stream id; 0 until HELLO completes.
    stream: AtomicU64,
    /// Liveness clock: ms since server start of the last inbound
    /// message or completed result write.
    last_ms: AtomicU64,
    done: AtomicBool,
    evicted: AtomicBool,
}

/// Server-wide state every service thread shares.
struct ServerCtx {
    scheduler: Arc<Scheduler>,
    sessions: Mutex<Vec<Arc<Session>>>,
    active: AtomicUsize,
    epoch: Instant,
    stall: Duration,
    max_streams: usize,
    preset: String,
    q: u32,
}

fn now_ms(epoch: Instant) -> u64 {
    epoch.elapsed().as_millis() as u64
}

/// The `pbvd serve` daemon.  See the module docs for the thread
/// layout; construction is [`PbvdServer::bind`], teardown is
/// [`PbvdServer::shutdown`] (also run on drop).
pub struct PbvdServer {
    ctx: Arc<ServerCtx>,
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    monitor: Option<JoinHandle<()>>,
}

impl PbvdServer {
    /// Validate `cfg`, build the shared engine through the config
    /// factory (PJRT via `reg` when available, CPU policy otherwise),
    /// and start listening on the resolved `serve` address
    /// (`cfg.serve_bind(..)` / `PBVD_SERVE_BIND` / the default; bind
    /// port 0 to let the OS pick — see [`PbvdServer::local_addr`]).
    pub fn bind(cfg: &DecoderConfig, reg: Option<&Registry>) -> Result<PbvdServer> {
        cfg.validate()?;
        let rc = cfg.resolved();
        let coord = rc.build_coordinator(reg)?;
        let scheduler = Arc::new(Scheduler::new(
            coord.engine,
            rc.serve.queue_depth_or_default(),
            rc.serve.coalesce_window(),
        ));
        let bind_addr = rc.serve.bind_or_default().to_string();
        let listener = TcpListener::bind(&bind_addr)
            .with_context(|| format!("pbvd serve: cannot bind {bind_addr}"))?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let stop = Arc::new(AtomicBool::new(false));
        let ctx = Arc::new(ServerCtx {
            scheduler,
            sessions: Mutex::new(Vec::new()),
            active: AtomicUsize::new(0),
            epoch: Instant::now(),
            stall: rc.serve.stall_timeout(),
            max_streams: rc.serve.max_streams_or_default(),
            preset: rc.preset.clone(),
            q: rc.q,
        });

        let accept = {
            let ctx = Arc::clone(&ctx);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("pbvd-accept".into())
                .spawn(move || accept_loop(&listener, &stop, &ctx))?
        };
        let monitor = {
            let ctx = Arc::clone(&ctx);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("pbvd-monitor".into())
                .spawn(move || monitor_loop(&stop, &ctx))?
        };

        Ok(PbvdServer {
            ctx,
            local_addr,
            stop,
            accept: Some(accept),
            monitor: Some(monitor),
        })
    }

    /// The bound address (with the OS-assigned port when the config
    /// asked for port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Name of the shared engine every stream decodes through.
    pub fn engine_name(&self) -> String {
        self.ctx.scheduler.engine().name()
    }

    /// Live sessions right now.
    pub fn active_sessions(&self) -> usize {
        self.ctx.active.load(Ordering::SeqCst)
    }

    /// Forced evictions so far (the stall detector's kill count).
    pub fn evictions(&self) -> u64 {
        self.ctx.scheduler.evictions()
    }

    /// The QoS report (same JSON the STATS verb returns).
    pub fn stats_json(&self) -> Json {
        self.ctx.scheduler.stats_json()
    }

    /// Stop accepting, shut down every session socket, and join the
    /// service threads.  Idempotent; also run on drop.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.ctx.scheduler.shutdown();
        {
            let sessions = self.ctx.sessions.lock().unwrap();
            for s in sessions.iter() {
                let _ = s.tcp.shutdown(Shutdown::Both);
            }
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.monitor.take() {
            let _ = h.join();
        }
        // give detached session threads a moment to drain out
        let t0 = Instant::now();
        while self.ctx.active.load(Ordering::SeqCst) > 0 && t0.elapsed() < Duration::from_secs(2)
        {
            std::thread::sleep(Duration::from_millis(5));
        }
    }
}

impl Drop for PbvdServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: &TcpListener, stop: &Arc<AtomicBool>, ctx: &Arc<ServerCtx>) {
    let mut next_session = 0u64;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((sock, _peer)) => {
                let _ = sock.set_nodelay(true);
                if ctx.active.load(Ordering::SeqCst) >= ctx.max_streams {
                    // admission refused over the wire, then dropped —
                    // existing streams are unaffected
                    let mut w = &sock;
                    let err = ServeError::ServerFull {
                        max: ctx.max_streams,
                    };
                    let _ = write_message(&mut w, Verb::Error, 0, &err.to_wire());
                    continue;
                }
                next_session += 1;
                spawn_session(sock, next_session, ctx);
            }
            // non-blocking accept: poll the stop flag between retries
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

fn spawn_session(sock: TcpStream, session_no: u64, ctx: &Arc<ServerCtx>) {
    let (Ok(monitor_handle), Ok(write_half)) = (sock.try_clone(), sock.try_clone()) else {
        return; // clone failed: drop the connection, daemon unaffected
    };
    let session = Arc::new(Session {
        tcp: monitor_handle,
        stream: AtomicU64::new(0),
        last_ms: AtomicU64::new(now_ms(ctx.epoch)),
        done: AtomicBool::new(false),
        evicted: AtomicBool::new(false),
    });
    let (tx, rx) = mpsc::channel::<WriterMsg>();

    let writer = {
        let ctx = Arc::clone(ctx);
        let session = Arc::clone(&session);
        // heartbeat fast enough that a healthy-but-quiet wire shows
        // life well inside the stall window
        let heartbeat = (ctx.stall / 4).clamp(Duration::from_millis(50), Duration::from_secs(2));
        std::thread::Builder::new()
            .name(format!("pbvd-wr-{session_no}"))
            .spawn(move || writer_loop(write_half, &rx, &ctx, &session, heartbeat))
    };
    if writer.is_err() {
        return;
    }

    ctx.active.fetch_add(1, Ordering::SeqCst);
    ctx.sessions.lock().unwrap().push(Arc::clone(&session));
    let reader = {
        let ctx = Arc::clone(ctx);
        std::thread::Builder::new()
            .name(format!("pbvd-rd-{session_no}"))
            .spawn(move || reader_main(sock, &ctx, &session, &tx))
    };
    if reader.is_err() {
        // roll the admission back; the writer exits via tx drop
        ctx.active.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Reader entry: run the session, then tear the stream down exactly
/// once regardless of how it ended.
fn reader_main(
    mut sock: TcpStream,
    ctx: &Arc<ServerCtx>,
    session: &Arc<Session>,
    tx: &mpsc::Sender<WriterMsg>,
) {
    let end = session_loop(&mut sock, ctx, session, tx);
    if let Err(e) = end {
        // best-effort: tell the client why before the socket dies
        let _ = tx.send(WriterMsg::Control {
            verb: Verb::Error,
            seq: 0,
            payload: e.to_wire(),
        });
        std::thread::sleep(Duration::from_millis(20));
    }
    let sid = session.stream.load(Ordering::SeqCst);
    if sid != 0 {
        // no-op if the monitor already evicted us (counted there)
        ctx.scheduler.retire(sid, "connection closed", false);
    }
    let _ = sock.shutdown(Shutdown::Both);
    session.done.store(true, Ordering::SeqCst);
    ctx.active.fetch_sub(1, Ordering::SeqCst);
}

/// The per-client protocol state machine.  `Ok(())` is a graceful BYE
/// or EOF; `Err` is a protocol violation worth reporting back.
fn session_loop(
    sock: &mut TcpStream,
    ctx: &ServerCtx,
    session: &Session,
    tx: &mpsc::Sender<WriterMsg>,
) -> Result<(), ServeError> {
    let touch = || {
        session.last_ms.store(now_ms(ctx.epoch), Ordering::SeqCst);
    };

    // HELLO must come first; it is the one message allowed before the
    // stream exists in the scheduler.
    let hello = match read_message(sock) {
        Ok(m) => m,
        Err(ServeError::Io(_)) => return Ok(()), // connect-and-close probe
        Err(e) => return Err(e),
    };
    touch();
    if hello.verb != Verb::Hello {
        return Err(ServeError::BadHello(format!(
            "first message must be HELLO, got {:?}",
            hello.verb
        )));
    }
    check_hello_payload(&hello, &ctx.preset)?;

    let sid = {
        let tx = tx.clone();
        ctx.scheduler.register(Box::new(move |seq, res| {
            let _ = tx.send(WriterMsg::Result { seq, res });
        }))
    };
    session.stream.store(sid, Ordering::SeqCst);

    let engine = ctx.scheduler.engine();
    let mut ack = Json::obj();
    ack.set("proto", Json::from(PROTO_VERSION as usize));
    ack.set("engine", Json::from(engine.name()));
    ack.set("preset", Json::from(ctx.preset.as_str()));
    ack.set("batch", Json::from(engine.batch()));
    ack.set("block", Json::from(engine.block()));
    ack.set("depth", Json::from(engine.depth()));
    ack.set("r", Json::from(engine.r()));
    ack.set("q", Json::from(ctx.q as usize));
    ack.set("frame_bytes", Json::from(ctx.scheduler.frame_len()));
    ack.set("result_bytes", Json::from(4 * ctx.scheduler.words_per_pb()));
    let _ = tx.send(WriterMsg::Control {
        verb: Verb::HelloAck,
        seq: hello.seq,
        payload: ack.to_string().into_bytes(),
    });

    loop {
        let msg = match read_message(sock) {
            Ok(m) => m,
            // socket closed / reset / shut down by the monitor
            Err(ServeError::Io(_)) => return Ok(()),
            Err(e) => return Err(e),
        };
        touch();
        match msg.verb {
            Verb::Submit => {
                let llr: Vec<i8> = msg.payload.iter().map(|&b| b as i8).collect();
                match ctx.scheduler.submit(sid, msg.seq, llr) {
                    Ok(()) => {}
                    // a malformed frame fails that frame, not the session
                    Err(e @ ServeError::BadFrameLen { .. }) => {
                        let _ = tx.send(WriterMsg::Control {
                            verb: Verb::Error,
                            seq: msg.seq,
                            payload: e.to_wire(),
                        });
                    }
                    Err(e) => return Err(e),
                }
            }
            Verb::Stats => {
                let _ = tx.send(WriterMsg::Control {
                    verb: Verb::StatsReply,
                    seq: msg.seq,
                    payload: ctx.scheduler.stats_json().to_string().into_bytes(),
                });
            }
            Verb::Ping => {
                let _ = tx.send(WriterMsg::Control {
                    verb: Verb::Pong,
                    seq: msg.seq,
                    payload: Vec::new(),
                });
            }
            Verb::Bye => return Ok(()),
            Verb::Hello => return Err(ServeError::BadHello("duplicate HELLO".into())),
            other => return Err(ServeError::UnknownVerb(other as u8)),
        }
    }
}

/// HELLO payload: empty, or JSON whose optional `preset` must name the
/// code this daemon serves (the "bad preset bytes" path — a typed
/// refusal, not a panic).
fn check_hello_payload(hello: &Message, preset: &str) -> Result<(), ServeError> {
    if hello.payload.is_empty() {
        return Ok(());
    }
    let text = std::str::from_utf8(&hello.payload)
        .map_err(|_| ServeError::BadHello("payload is not UTF-8".into()))?;
    let json =
        Json::parse(text).map_err(|e| ServeError::BadHello(format!("payload is not JSON: {e}")))?;
    if let Some(want) = json.get("preset").and_then(Json::as_str) {
        if want != preset {
            return Err(ServeError::BadHello(format!(
                "this daemon serves preset {preset:?}, not {want:?}"
            )));
        }
    }
    Ok(())
}

fn writer_loop(
    mut sock: TcpStream,
    rx: &mpsc::Receiver<WriterMsg>,
    ctx: &ServerCtx,
    session: &Session,
    heartbeat: Duration,
) {
    loop {
        match rx.recv_timeout(heartbeat) {
            Ok(WriterMsg::Result { seq, res }) => {
                let wrote = match res {
                    Ok(words) => {
                        write_message(&mut sock, Verb::Result, seq, &words_to_wire(&words))
                    }
                    Err(e) => write_message(&mut sock, Verb::Error, seq, &e.to_wire()),
                };
                // the ack is what opens the backpressure window: a
                // client that stops reading blocks this write, runs
                // its window dry, and stalls only itself
                let sid = session.stream.load(Ordering::SeqCst);
                if sid != 0 {
                    ctx.scheduler.ack(sid);
                }
                if wrote.is_err() {
                    return;
                }
                session.last_ms.store(now_ms(ctx.epoch), Ordering::SeqCst);
            }
            Ok(WriterMsg::Control { verb, seq, payload }) => {
                if write_message(&mut sock, verb, seq, &payload).is_err() {
                    return;
                }
                session.last_ms.store(now_ms(ctx.epoch), Ordering::SeqCst);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // deliberately does NOT touch last_ms: heartbeats
                // prove the daemon is alive, not the client
                if write_message(&mut sock, Verb::Heartbeat, 0, &[]).is_err() {
                    return;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        }
    }
}

fn monitor_loop(stop: &Arc<AtomicBool>, ctx: &Arc<ServerCtx>) {
    let stall_ms = ctx.stall.as_millis() as u64;
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(50));
        let now = now_ms(ctx.epoch);
        let mut sessions = ctx.sessions.lock().unwrap();
        sessions.retain(|s| !s.done.load(Ordering::SeqCst));
        for s in sessions.iter() {
            let idle = now.saturating_sub(s.last_ms.load(Ordering::SeqCst));
            if idle > stall_ms && !s.evicted.swap(true, Ordering::SeqCst) {
                let sid = s.stream.load(Ordering::SeqCst);
                if sid != 0 {
                    ctx.scheduler
                        .retire(sid, &format!("stalled: no activity for {idle} ms"), true);
                }
                // breaks the session's blocking read/write; the reader
                // then runs its normal teardown
                let _ = s.tcp.shutdown(Shutdown::Both);
            }
        }
    }
}
