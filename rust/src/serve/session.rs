//! The daemon: accept loop, per-client sessions, the stall detector,
//! and the resume registry.
//!
//! [`PbvdServer::bind`] builds one shared engine through the
//! [`DecoderConfig`](crate::config::DecoderConfig) factory (the same
//! single construction path every frontend uses), wraps it in the
//! self-healing [`EngineSupervisor`] and a [`Scheduler`], and listens
//! on the configured address.  Each accepted client gets a *reader*
//! thread (blocking
//! [`read_message`](crate::serve::protocol::read_message) loop — the
//! socket, not a poll timeout, is the interruption point, so framing
//! can never desynchronize) and a *writer* thread draining a channel
//! of results and control replies; the writer emits HEARTBEAT frames
//! when idle so clients can tell a busy daemon from a dead one.
//!
//! Liveness is tracked per session as "milliseconds since the last
//! inbound message or completed result write".  A monitor thread
//! evicts any session that exceeds the configured stall timeout:
//! its stream is retired in the scheduler (dropping queued frames and
//! unblocking anything waiting on it) and its socket is shut down,
//! which unblocks the blocked reader/writer.  Other streams never
//! stall on a wedged peer — their groups keep dispatching, at worst
//! slightly emptier.  Idle clients that want to stay connected past
//! the stall timeout must PING.
//!
//! # Reconnect / resume (protocol v2)
//!
//! Every HELLO_ACK carries a per-stream resume `token` (when resume is
//! enabled).  A connection that dies *without* BYE leaves its stream
//! **parked**: queued frames keep decoding into the scheduler's replay
//! buffer while the token sits in the resume registry with a grace
//! deadline ([`crate::config::ServeConfig::resume_grace_ms`]).  A
//! replacement connection opens with RESUME `{token, next_needed}`
//! instead of HELLO; the daemon rebinds the stream (bumping its
//! binding generation so the dead connection's reader/writer become
//! inert), replays every result the client is missing exactly once,
//! and answers with `resumed: true` plus `next_expected` — the seq
//! from which the client must resubmit.  Parked streams whose grace
//! expires are retired (uncounted — the stall detector's eviction
//! counter stays a pure wedge signal).
//!
//! An installed fault plan
//! ([`crate::config::ServeConfig::faults`]) is consulted at the
//! read seam (delays), the write seam (delay / drop / kill per RESULT
//! frame), the supervisor's dispatch seam, and the worker pool's job
//! seam — see [`crate::serve::faults`].

use std::collections::HashMap;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::audit::ShadowAuditor;
use crate::config::DecoderConfig;
use crate::json::Json;
use crate::metrics::{IntegrityStats, PlanStats, RecoveryStats};
use crate::plan::Dispatcher;
use crate::rng::SplitMix64;
use crate::runtime::Registry;
use crate::serve::faults::FaultPlan;
use crate::serve::protocol::{
    read_message, words_to_wire, write_message, Message, ServeError, Verb, PROTO_VERSION,
};
use crate::serve::scheduler::{Scheduler, SchedulerOptions};
use crate::serve::supervisor::EngineSupervisor;

/// What the writer thread is asked to put on the wire.
enum WriterMsg {
    /// A decoded frame (or its typed failure); acked to the scheduler
    /// once the bytes are out, which is what opens the backpressure
    /// window (an un-acked result stays replayable for a resume).
    Result {
        seq: u32,
        res: Result<Vec<u32>, ServeError>,
    },
    /// A control reply (HELLO_ACK, STATS_REPLY, PONG, ERROR).
    Control {
        verb: Verb,
        seq: u32,
        payload: Vec<u8>,
    },
}

/// How a session's protocol loop ended.
enum SessionEnd {
    /// BYE, clean EOF probe, or an orderly close — the stream retires.
    Graceful,
    /// The connection died under the stream (socket error, parked by a
    /// superseded binding) — the stream parks for a resume.
    Lost,
}

/// Per-session state shared between the reader, writer, and monitor.
struct Session {
    /// Socket handle the monitor uses to break a wedged session's
    /// blocking reads/writes (`shutdown(Both)`).
    tcp: TcpStream,
    /// Scheduler stream id; 0 until HELLO/RESUME completes.
    stream: AtomicU64,
    /// Binding generation this connection holds on its stream (set by
    /// HELLO registration or RESUME rebinding); scheduler calls carry
    /// it so a superseded connection is inert.
    binding: AtomicU64,
    /// Resume token (0 until HELLO/RESUME completes, or when resume is
    /// disabled).
    token: AtomicU64,
    /// Liveness clock: ms since server start of the last inbound
    /// message or completed result write.
    last_ms: AtomicU64,
    done: AtomicBool,
    evicted: AtomicBool,
}

/// Resume-registry entry: which stream a token names, and — once the
/// connection died — when it parked (the grace clock).
struct TokenEntry {
    sid: u64,
    parked_since_ms: Option<u64>,
}

/// Server-wide state every service thread shares.
struct ServerCtx {
    scheduler: Arc<Scheduler>,
    /// The supervisor behind the scheduler's engine, kept for the
    /// quarantine report in STATS.
    supervisor: Arc<EngineSupervisor>,
    /// The shadow auditor (held so it outlives the server; counters
    /// land in the shared [`IntegrityStats`]).  `None` when auditing
    /// is off.
    auditor: Option<Arc<ShadowAuditor>>,
    /// The adaptive dispatcher installed on the supervisor (`None`
    /// when planning is off); kept for the STATS plan report.
    planner: Option<Arc<Dispatcher>>,
    sessions: Mutex<Vec<Arc<Session>>>,
    /// Resume registry: token → stream (+ park clock).  Lock order:
    /// `tokens` before the scheduler's state lock, never the reverse.
    tokens: Mutex<HashMap<u64, TokenEntry>>,
    token_rng: Mutex<SplitMix64>,
    faults: Option<Arc<FaultPlan>>,
    recovery: Arc<RecoveryStats>,
    /// `None` = resume disabled (no tokens issued, RESUME refused).
    resume_grace: Option<Duration>,
    active: AtomicUsize,
    epoch: Instant,
    stall: Duration,
    max_streams: usize,
    preset: String,
    q: u32,
}

fn now_ms(epoch: Instant) -> u64 {
    epoch.elapsed().as_millis() as u64
}

fn lock_sessions(ctx: &ServerCtx) -> std::sync::MutexGuard<'_, Vec<Arc<Session>>> {
    ctx.sessions.lock().unwrap_or_else(PoisonError::into_inner)
}

fn lock_tokens(ctx: &ServerCtx) -> std::sync::MutexGuard<'_, HashMap<u64, TokenEntry>> {
    ctx.tokens.lock().unwrap_or_else(PoisonError::into_inner)
}

/// The `pbvd serve` daemon.  See the module docs for the thread
/// layout; construction is [`PbvdServer::bind`], teardown is
/// [`PbvdServer::shutdown`] (also run on drop).
pub struct PbvdServer {
    ctx: Arc<ServerCtx>,
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    monitor: Option<JoinHandle<()>>,
}

impl PbvdServer {
    /// Validate `cfg`, build the shared engine through the config
    /// factory (PJRT via `reg` when available, CPU policy otherwise),
    /// wrap it in the [`EngineSupervisor`], and start listening on the
    /// resolved `serve` address (`cfg.serve_bind(..)` /
    /// `PBVD_SERVE_BIND` / the default; bind port 0 to let the OS pick
    /// — see [`PbvdServer::local_addr`]).
    pub fn bind(cfg: &DecoderConfig, reg: Option<&Registry>) -> Result<PbvdServer> {
        cfg.validate()?;
        let rc = cfg.resolved();
        let trellis = rc.trellis()?;
        // The daemon owns the audit layer at the supervisor seam (one
        // shared auditor observing every group, feeding quarantine), so
        // the engine the supervisor runs — and every rebuilt rung —
        // must NOT be factory-wrapped in its own AuditedEngine.
        let mut engine_cfg = rc.clone();
        engine_cfg.audit = Default::default();
        let engine = engine_cfg.build_engine_with(&trellis, reg)?;
        let recovery = Arc::new(RecoveryStats::new());
        let auditor = if !rc.audit.is_unset() && rc.audit.sample_ppm_or_default() > 0 {
            Some(Arc::new(ShadowAuditor::new(
                &trellis,
                engine.block(),
                engine.depth(),
                &rc.audit,
            )))
        } else {
            None
        };
        let integrity = auditor
            .as_ref()
            .map(|a| Arc::clone(a.stats()))
            .unwrap_or_else(|| Arc::new(IntegrityStats::default()));
        let faults = match rc.serve.fault_spec() {
            Some(spec) => Some(Arc::new(
                FaultPlan::parse(spec).map_err(|e| anyhow::anyhow!("{e}"))?,
            )),
            None => None,
        };
        let plan_shape = rc.batch_shape(&trellis);
        let supervisor = Arc::new(EngineSupervisor::new(
            engine,
            engine_cfg,
            trellis,
            Arc::clone(&recovery),
        ));
        if let Some(aud) = &auditor {
            supervisor.install_auditor(Arc::clone(aud));
        }
        // adaptive dispatch: the supervisor observes every group into
        // the history and migrates the live engine on the re-eval
        // cadence; the handle stays here for the STATS plan report
        let planner = if rc.plan.enabled_or_default() {
            let dsp = Arc::new(rc.plan_dispatcher(None));
            supervisor.install_planner(Arc::clone(&dsp), plan_shape);
            Some(dsp)
        } else {
            None
        };
        // the plan reaches every seam from here: the supervisor keeps
        // the dispatch hook and pushes the worker hook into the pool
        // (re-installing it on any degraded replacement engine)
        if faults.is_some() {
            use crate::coordinator::DecodeEngine;
            supervisor.install_fault_plan(faults.clone());
        }
        let scheduler = Arc::new(Scheduler::with_options(
            Arc::clone(&supervisor) as Arc<dyn crate::coordinator::DecodeEngine>,
            rc.serve.queue_depth_or_default(),
            rc.serve.coalesce_window(),
            SchedulerOptions {
                shed_queue: rc.serve.shed_queue_or_default(),
                // dispatch faults are the supervisor's seam here; a
                // scheduler-level plan would double-count groups
                faults: None,
                recovery: Some(Arc::clone(&recovery)),
                integrity: Some(Arc::clone(&integrity)),
            },
        ));
        let bind_addr = rc.serve.bind_or_default().to_string();
        let listener = TcpListener::bind(&bind_addr)
            .with_context(|| format!("pbvd serve: cannot bind {bind_addr}"))?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let stop = Arc::new(AtomicBool::new(false));
        let ctx = Arc::new(ServerCtx {
            scheduler,
            supervisor,
            auditor,
            planner,
            sessions: Mutex::new(Vec::new()),
            tokens: Mutex::new(HashMap::new()),
            token_rng: Mutex::new(SplitMix64::new(0x7B5D_70C0_FFEE_D00D)),
            faults,
            recovery,
            resume_grace: rc.serve.resume_grace(),
            active: AtomicUsize::new(0),
            epoch: Instant::now(),
            stall: rc.serve.stall_timeout(),
            max_streams: rc.serve.max_streams_or_default(),
            preset: rc.preset.clone(),
            q: rc.q,
        });

        let accept = {
            let ctx = Arc::clone(&ctx);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("pbvd-accept".into())
                .spawn(move || accept_loop(&listener, &stop, &ctx))?
        };
        let monitor = {
            let ctx = Arc::clone(&ctx);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("pbvd-monitor".into())
                .spawn(move || monitor_loop(&stop, &ctx))?
        };

        Ok(PbvdServer {
            ctx,
            local_addr,
            stop,
            accept: Some(accept),
            monitor: Some(monitor),
        })
    }

    /// The bound address (with the OS-assigned port when the config
    /// asked for port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Name of the engine every stream currently decodes through
    /// (after a degradation, the supervisor's replacement).
    pub fn engine_name(&self) -> String {
        self.ctx.scheduler.engine().name()
    }

    /// Live sessions right now.
    pub fn active_sessions(&self) -> usize {
        self.ctx.active.load(Ordering::SeqCst)
    }

    /// Forced evictions so far (the stall detector's kill count).
    pub fn evictions(&self) -> u64 {
        self.ctx.scheduler.evictions()
    }

    /// Shared recovery counters (retries, degradations, resumes,
    /// parks, replays, sheds).
    pub fn recovery(&self) -> Arc<RecoveryStats> {
        Arc::clone(&self.ctx.recovery)
    }

    /// The active fault plan, when one was configured.
    pub fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        self.ctx.faults.clone()
    }

    /// Shared integrity counters (audits, violations, margin
    /// mismatches, rejected inputs; the shadow auditor's set when
    /// auditing is on).
    pub fn integrity(&self) -> Arc<IntegrityStats> {
        Arc::clone(self.ctx.scheduler.integrity())
    }

    /// Whether a shadow auditor is sampling decodes.
    pub fn audit_enabled(&self) -> bool {
        self.ctx.auditor.is_some()
    }

    /// Engine names the supervisor quarantined after an audit caught
    /// them diverging (excluded from rebuilds until restart).
    pub fn quarantined(&self) -> Vec<String> {
        self.ctx.supervisor.quarantined()
    }

    /// Whether the adaptive dispatcher is planning this daemon's
    /// engine (observing groups, migrating on its cadence).
    pub fn plan_enabled(&self) -> bool {
        self.ctx.planner.is_some()
    }

    /// Planner counters (decisions, explore hits, migrations, width
    /// hints); a zeroed set when planning is off.
    pub fn plan_stats(&self) -> Arc<PlanStats> {
        self.ctx
            .planner
            .as_ref()
            .map(|p| Arc::clone(p.stats()))
            .unwrap_or_default()
    }

    /// Streams currently parked awaiting a RESUME.
    pub fn parked_streams(&self) -> usize {
        lock_tokens(&self.ctx)
            .values()
            .filter(|e| e.parked_since_ms.is_some())
            .count()
    }

    /// The QoS report (same JSON the STATS verb returns).
    pub fn stats_json(&self) -> Json {
        server_stats(&self.ctx)
    }

    /// Stop accepting, shut down every session socket, and join the
    /// service threads.  Idempotent; also run on drop.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.ctx.scheduler.shutdown();
        {
            let sessions = lock_sessions(&self.ctx);
            for s in sessions.iter() {
                let _ = s.tcp.shutdown(Shutdown::Both);
            }
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.monitor.take() {
            let _ = h.join();
        }
        // give detached session threads a moment to drain out
        let t0 = Instant::now();
        while self.ctx.active.load(Ordering::SeqCst) > 0 && t0.elapsed() < Duration::from_secs(2)
        {
            std::thread::sleep(Duration::from_millis(5));
        }
    }
}

impl Drop for PbvdServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The STATS document: the scheduler's QoS report plus the fault plan,
/// the current parked-stream gauge, the quarantine report, and — when
/// planning is on — the adaptive-dispatch report (counters, history
/// provenance, the live engine).
fn server_stats(ctx: &ServerCtx) -> Json {
    let mut out = ctx.scheduler.stats_json();
    if let Some(p) = &ctx.faults {
        out.set("faults", p.to_json());
    }
    let parked_now = lock_tokens(ctx)
        .values()
        .filter(|e| e.parked_since_ms.is_some())
        .count();
    out.set("parked_streams", Json::from(parked_now));
    out.set("audit_enabled", Json::from(ctx.auditor.is_some()));
    out.set(
        "quarantined",
        Json::Arr(
            ctx.supervisor
                .quarantined()
                .into_iter()
                .map(Json::from)
                .collect(),
        ),
    );
    if let Some(p) = &ctx.planner {
        let mut plan = p.stats().to_json();
        plan.set("enabled", Json::from(true));
        plan.set("machine", Json::from(p.machine()));
        plan.set("history_rows", Json::from(p.history().len()));
        if let Some(path) = p.history().path() {
            plan.set("history_path", Json::from(path.display().to_string()));
        }
        plan.set("engine", Json::from(ctx.scheduler.engine().name()));
        out.set("plan", plan);
    }
    out
}

fn accept_loop(listener: &TcpListener, stop: &Arc<AtomicBool>, ctx: &Arc<ServerCtx>) {
    let mut next_session = 0u64;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((sock, _peer)) => {
                let _ = sock.set_nodelay(true);
                if ctx.active.load(Ordering::SeqCst) >= ctx.max_streams {
                    // admission refused over the wire, then dropped —
                    // existing streams are unaffected
                    let mut w = &sock;
                    let err = ServeError::ServerFull {
                        max: ctx.max_streams,
                    };
                    let _ = write_message(&mut w, Verb::Error, 0, &err.to_wire());
                    continue;
                }
                next_session += 1;
                spawn_session(sock, next_session, ctx);
            }
            // non-blocking accept: poll the stop flag between retries
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

fn spawn_session(sock: TcpStream, session_no: u64, ctx: &Arc<ServerCtx>) {
    let (Ok(monitor_handle), Ok(write_half)) = (sock.try_clone(), sock.try_clone()) else {
        return; // clone failed: drop the connection, daemon unaffected
    };
    let session = Arc::new(Session {
        tcp: monitor_handle,
        stream: AtomicU64::new(0),
        binding: AtomicU64::new(0),
        token: AtomicU64::new(0),
        last_ms: AtomicU64::new(now_ms(ctx.epoch)),
        done: AtomicBool::new(false),
        evicted: AtomicBool::new(false),
    });
    let (tx, rx) = mpsc::channel::<WriterMsg>();

    let writer = {
        let ctx = Arc::clone(ctx);
        let session = Arc::clone(&session);
        // heartbeat fast enough that a healthy-but-quiet wire shows
        // life well inside the stall window
        let heartbeat = (ctx.stall / 4).clamp(Duration::from_millis(50), Duration::from_secs(2));
        std::thread::Builder::new()
            .name(format!("pbvd-wr-{session_no}"))
            .spawn(move || writer_loop(write_half, &rx, &ctx, &session, heartbeat))
    };
    if writer.is_err() {
        return;
    }

    ctx.active.fetch_add(1, Ordering::SeqCst);
    lock_sessions(ctx).push(Arc::clone(&session));
    let reader = {
        let ctx = Arc::clone(ctx);
        std::thread::Builder::new()
            .name(format!("pbvd-rd-{session_no}"))
            .spawn(move || reader_main(sock, &ctx, &session, &tx))
    };
    if reader.is_err() {
        // roll the admission back; the writer exits via tx drop
        ctx.active.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Park this session's stream for a later RESUME.  Returns whether the
/// stream is now held by the resume registry (false = resume disabled,
/// no stream, or the binding was superseded — the caller retires).
fn park_session(ctx: &ServerCtx, session: &Session) -> bool {
    if ctx.resume_grace.is_none() {
        return false;
    }
    let sid = session.stream.load(Ordering::SeqCst);
    let token = session.token.load(Ordering::SeqCst);
    if sid == 0 || token == 0 {
        return false;
    }
    // lock order: tokens, then the scheduler's state (inside park)
    let mut reg = lock_tokens(ctx);
    if !ctx
        .scheduler
        .park(sid, session.binding.load(Ordering::SeqCst))
    {
        return false;
    }
    if let Some(entry) = reg.get_mut(&token) {
        entry.parked_since_ms = Some(now_ms(ctx.epoch));
    }
    true
}

/// Reader entry: run the session, then either park its stream for a
/// resume or tear it down exactly once, regardless of how it ended.
fn reader_main(
    mut sock: TcpStream,
    ctx: &Arc<ServerCtx>,
    session: &Arc<Session>,
    tx: &mpsc::Sender<WriterMsg>,
) {
    let end = session_loop(&mut sock, ctx, session, tx);
    if let Err(e) = &end {
        // best-effort: tell the client why before the socket dies
        let _ = tx.send(WriterMsg::Control {
            verb: Verb::Error,
            seq: 0,
            payload: e.to_wire(),
        });
        std::thread::sleep(Duration::from_millis(20));
    }
    let sid = session.stream.load(Ordering::SeqCst);
    let parked = matches!(end, Ok(SessionEnd::Lost)) && park_session(ctx, session);
    if sid != 0 && !parked {
        // no-op if the monitor already evicted us (counted there) or
        // a RESUME rebound the stream to a newer connection (release
        // is binding-guarded, so the resumed stream survives us)
        let binding = session.binding.load(Ordering::SeqCst);
        if ctx.scheduler.release(sid, binding, "connection closed", false) {
            lock_tokens(ctx).remove(&session.token.load(Ordering::SeqCst));
        }
    }
    let _ = sock.shutdown(Shutdown::Both);
    session.done.store(true, Ordering::SeqCst);
    ctx.active.fetch_sub(1, Ordering::SeqCst);
}

/// Geometry/identity document behind HELLO_ACK (and the RESUME ack).
fn hello_ack_json(ctx: &ServerCtx, token: Option<u64>) -> Json {
    let engine = ctx.scheduler.engine();
    let mut ack = Json::obj();
    ack.set("proto", Json::from(PROTO_VERSION as usize));
    ack.set("engine", Json::from(engine.name()));
    ack.set("preset", Json::from(ctx.preset.as_str()));
    ack.set("batch", Json::from(engine.batch()));
    ack.set("block", Json::from(engine.block()));
    ack.set("depth", Json::from(engine.depth()));
    ack.set("r", Json::from(engine.r()));
    ack.set("q", Json::from(ctx.q as usize));
    ack.set("frame_bytes", Json::from(ctx.scheduler.frame_len()));
    ack.set("result_bytes", Json::from(4 * ctx.scheduler.words_per_pb()));
    if let Some(t) = token {
        ack.set("token", Json::from(format!("{t:016x}")));
    }
    ack
}

/// The per-client protocol state machine.  `Ok(Graceful)` is a BYE or
/// clean EOF before HELLO; `Ok(Lost)` is a connection that died under
/// a live stream (parked for resume by the caller); `Err` is a
/// protocol violation worth reporting back.
fn session_loop(
    sock: &mut TcpStream,
    ctx: &ServerCtx,
    session: &Session,
    tx: &mpsc::Sender<WriterMsg>,
) -> Result<SessionEnd, ServeError> {
    let touch = || {
        session.last_ms.store(now_ms(ctx.epoch), Ordering::SeqCst);
    };
    let read_faulted = |sock: &mut TcpStream| {
        // read-site fault seam: an injected delay before the read
        if let Some(p) = &ctx.faults {
            if let Some(d) = p.on_read() {
                std::thread::sleep(d);
            }
        }
        read_message(sock)
    };

    // HELLO (or RESUME on a replacement connection) must come first;
    // it is the one message allowed before the stream is bound.
    let first = match read_faulted(sock) {
        Ok(m) => m,
        Err(ServeError::Io(_)) => return Ok(SessionEnd::Graceful), // connect-and-close probe
        Err(e) => return Err(e),
    };
    touch();
    let (sid, binding) = match first.verb {
        Verb::Hello => {
            check_hello_payload(&first, &ctx.preset)?;
            let sid = {
                let tx = tx.clone();
                ctx.scheduler.register(Box::new(move |seq, res| {
                    let _ = tx.send(WriterMsg::Result { seq, res });
                }))
            };
            let token = match ctx.resume_grace {
                Some(_) => {
                    let mut reg = lock_tokens(ctx);
                    let mut rng = ctx
                        .token_rng
                        .lock()
                        .unwrap_or_else(PoisonError::into_inner);
                    let token = loop {
                        let t = rng.next_u64();
                        if t != 0 && !reg.contains_key(&t) {
                            break t;
                        }
                    };
                    reg.insert(
                        token,
                        TokenEntry {
                            sid,
                            parked_since_ms: None,
                        },
                    );
                    Some(token)
                }
                None => None,
            };
            session.stream.store(sid, Ordering::SeqCst);
            session.binding.store(0, Ordering::SeqCst);
            session.token.store(token.unwrap_or(0), Ordering::SeqCst);
            let _ = tx.send(WriterMsg::Control {
                verb: Verb::HelloAck,
                seq: first.seq,
                payload: hello_ack_json(ctx, token).to_string().into_bytes(),
            });
            (sid, 0)
        }
        Verb::Resume => {
            if ctx.resume_grace.is_none() {
                return Err(ServeError::BadResume(
                    "resume is disabled on this daemon".into(),
                ));
            }
            let (token, next_needed) = parse_resume_payload(&first)?;
            // the registry lock is held across the rebind so the grace
            // sweeper cannot retire the stream under a live RESUME
            let (sid, binding, next_expected) = {
                let mut reg = lock_tokens(ctx);
                let entry = reg.get_mut(&token).ok_or_else(|| {
                    ServeError::BadResume("unknown or expired resume token".into())
                })?;
                let deliver = {
                    let tx = tx.clone();
                    Box::new(move |seq, res| {
                        let _ = tx.send(WriterMsg::Result { seq, res });
                    })
                };
                let (binding, next_expected) =
                    ctx.scheduler.rebind(entry.sid, next_needed, deliver)?;
                entry.parked_since_ms = None;
                (entry.sid, binding, next_expected)
            };
            session.stream.store(sid, Ordering::SeqCst);
            session.binding.store(binding, Ordering::SeqCst);
            session.token.store(token, Ordering::SeqCst);
            let mut ack = hello_ack_json(ctx, Some(token));
            ack.set("resumed", Json::from(true));
            ack.set("next_expected", Json::from(next_expected as usize));
            let _ = tx.send(WriterMsg::Control {
                verb: Verb::HelloAck,
                seq: first.seq,
                payload: ack.to_string().into_bytes(),
            });
            (sid, binding)
        }
        other => {
            return Err(ServeError::BadHello(format!(
                "first message must be HELLO or RESUME, got {other:?}"
            )))
        }
    };

    loop {
        let msg = match read_faulted(sock) {
            Ok(m) => m,
            // socket closed / reset / shut down by the monitor: the
            // stream may still be resumable — park, don't retire
            Err(ServeError::Io(_)) => return Ok(SessionEnd::Lost),
            Err(e) => return Err(e),
        };
        touch();
        match msg.verb {
            Verb::Submit => {
                let llr: Vec<i8> = msg.payload.iter().map(|&b| b as i8).collect();
                match ctx.scheduler.submit(sid, binding, msg.seq, llr) {
                    Ok(()) => {}
                    // a malformed frame (or an overload shed) fails
                    // that frame, not the session
                    Err(
                        e @ (ServeError::BadFrameLen { .. }
                        | ServeError::ErasedFrame { .. }
                        | ServeError::RetryAfter { .. }),
                    ) => {
                        let _ = tx.send(WriterMsg::Control {
                            verb: Verb::Error,
                            seq: msg.seq,
                            payload: e.to_wire(),
                        });
                    }
                    // the stream was parked under us (writer saw the
                    // connection die first): this connection is done
                    Err(ServeError::Io(_)) => return Ok(SessionEnd::Lost),
                    Err(e) => return Err(e),
                }
            }
            Verb::Stats => {
                let _ = tx.send(WriterMsg::Control {
                    verb: Verb::StatsReply,
                    seq: msg.seq,
                    payload: server_stats(ctx).to_string().into_bytes(),
                });
            }
            Verb::Ping => {
                let _ = tx.send(WriterMsg::Control {
                    verb: Verb::Pong,
                    seq: msg.seq,
                    payload: Vec::new(),
                });
            }
            Verb::Bye => return Ok(SessionEnd::Graceful),
            Verb::Hello => return Err(ServeError::BadHello("duplicate HELLO".into())),
            Verb::Resume => {
                return Err(ServeError::BadResume(
                    "RESUME must be the first message on a connection".into(),
                ))
            }
            other => return Err(ServeError::UnknownVerb(other as u8)),
        }
    }
}

/// HELLO payload: empty, or JSON whose optional `preset` must name the
/// code this daemon serves (the "bad preset bytes" path — a typed
/// refusal, not a panic).
fn check_hello_payload(hello: &Message, preset: &str) -> Result<(), ServeError> {
    if hello.payload.is_empty() {
        return Ok(());
    }
    let text = std::str::from_utf8(&hello.payload)
        .map_err(|_| ServeError::BadHello("payload is not UTF-8".into()))?;
    let json =
        Json::parse(text).map_err(|e| ServeError::BadHello(format!("payload is not JSON: {e}")))?;
    if let Some(want) = json.get("preset").and_then(Json::as_str) {
        if want != preset {
            return Err(ServeError::BadHello(format!(
                "this daemon serves preset {preset:?}, not {want:?}"
            )));
        }
    }
    Ok(())
}

/// RESUME payload: JSON `{token: "<16 hex digits>", next_needed: N}`.
fn parse_resume_payload(msg: &Message) -> Result<(u64, u32), ServeError> {
    let text = std::str::from_utf8(&msg.payload)
        .map_err(|_| ServeError::BadResume("payload is not UTF-8".into()))?;
    let json = Json::parse(text)
        .map_err(|e| ServeError::BadResume(format!("payload is not JSON: {e}")))?;
    let token_str = json
        .get("token")
        .and_then(Json::as_str)
        .ok_or_else(|| ServeError::BadResume("payload lacks a `token` string".into()))?;
    let token = u64::from_str_radix(token_str, 16)
        .map_err(|_| ServeError::BadResume(format!("token {token_str:?} is not hex")))?;
    if token == 0 {
        return Err(ServeError::BadResume("token 0 is never issued".into()));
    }
    let next_needed = json
        .get("next_needed")
        .and_then(Json::as_usize)
        .ok_or_else(|| ServeError::BadResume("payload lacks a numeric `next_needed`".into()))?;
    let next_needed = u32::try_from(next_needed)
        .map_err(|_| ServeError::BadResume("next_needed exceeds u32".into()))?;
    Ok((token, next_needed))
}

fn writer_loop(
    mut sock: TcpStream,
    rx: &mpsc::Receiver<WriterMsg>,
    ctx: &ServerCtx,
    session: &Session,
    heartbeat: Duration,
) {
    loop {
        match rx.recv_timeout(heartbeat) {
            Ok(WriterMsg::Result { seq, res }) => {
                // write-site fault seam, per RESULT frame
                if let Some(p) = &ctx.faults {
                    let f = p.on_write(seq);
                    if let Some(d) = f.delay {
                        std::thread::sleep(d);
                    }
                    if f.kill {
                        // simulate the connection dying mid-stream:
                        // the blocked reader sees Io and parks
                        let _ = sock.shutdown(Shutdown::Both);
                        return;
                    }
                    if f.drop {
                        // swallowed by the network: no write, **no
                        // ack** — the result stays in the replay
                        // buffer until a resume re-serves it
                        continue;
                    }
                }
                let wrote = match res {
                    Ok(words) => {
                        write_message(&mut sock, Verb::Result, seq, &words_to_wire(&words))
                    }
                    Err(e) => write_message(&mut sock, Verb::Error, seq, &e.to_wire()),
                };
                if wrote.is_err() {
                    // NOT acked: the frame is still owed to the client
                    // and replays on resume
                    return;
                }
                // the ack is what opens the backpressure window: a
                // client that stops reading blocks this write, runs
                // its window dry, and stalls only itself
                let sid = session.stream.load(Ordering::SeqCst);
                if sid != 0 {
                    ctx.scheduler
                        .ack(sid, session.binding.load(Ordering::SeqCst), seq);
                }
                session.last_ms.store(now_ms(ctx.epoch), Ordering::SeqCst);
            }
            Ok(WriterMsg::Control { verb, seq, payload }) => {
                if write_message(&mut sock, verb, seq, &payload).is_err() {
                    return;
                }
                session.last_ms.store(now_ms(ctx.epoch), Ordering::SeqCst);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // deliberately does NOT touch last_ms: heartbeats
                // prove the daemon is alive, not the client
                if write_message(&mut sock, Verb::Heartbeat, 0, &[]).is_err() {
                    return;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        }
    }
}

fn monitor_loop(stop: &Arc<AtomicBool>, ctx: &Arc<ServerCtx>) {
    let stall_ms = ctx.stall.as_millis() as u64;
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(50));
        let now = now_ms(ctx.epoch);
        {
            let mut sessions = lock_sessions(ctx);
            sessions.retain(|s| !s.done.load(Ordering::SeqCst));
            for s in sessions.iter() {
                let idle = now.saturating_sub(s.last_ms.load(Ordering::SeqCst));
                if idle > stall_ms && !s.evicted.swap(true, Ordering::SeqCst) {
                    let sid = s.stream.load(Ordering::SeqCst);
                    if sid != 0 {
                        // binding-guarded: a session whose stream was
                        // rebound away must not evict the resume
                        let binding = s.binding.load(Ordering::SeqCst);
                        if ctx.scheduler.release(
                            sid,
                            binding,
                            &format!("stalled: no activity for {idle} ms"),
                            true,
                        ) {
                            lock_tokens(ctx).remove(&s.token.load(Ordering::SeqCst));
                        }
                    }
                    // breaks the session's blocking read/write; the
                    // reader then runs its normal teardown
                    let _ = s.tcp.shutdown(Shutdown::Both);
                }
            }
        }
        // sweep the resume registry: parked streams whose grace
        // expired retire (uncounted — not a stall eviction)
        if let Some(grace) = ctx.resume_grace {
            let grace_ms = grace.as_millis() as u64;
            let mut reg = lock_tokens(ctx);
            reg.retain(|_, entry| match entry.parked_since_ms {
                Some(t) if now.saturating_sub(t) > grace_ms => {
                    ctx.scheduler
                        .retire(entry.sid, "resume grace expired", false);
                    false
                }
                _ => true,
            });
        }
    }
}
