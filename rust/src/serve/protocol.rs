//! The `pbvd serve` wire format and the typed serving error surface.
//!
//! Every message is a fixed 12-byte header followed by a
//! length-prefixed payload:
//!
//! | offset | size | field                              |
//! |--------|------|------------------------------------|
//! | 0      | 2    | magic `"PV"`                       |
//! | 2      | 1    | protocol version ([`PROTO_VERSION`]) |
//! | 3      | 1    | verb ([`Verb`])                    |
//! | 4      | 4    | sequence number (u32 LE)           |
//! | 8      | 4    | payload length (u32 LE)            |
//!
//! Client → server verbs: `HELLO` (optional JSON), `SUBMIT` (exactly
//! one frame of `T*R` i8 LLR bytes), `STATS`, `PING`, `BYE`, and —
//! since protocol version 2 — `RESUME` (JSON `{token, next_needed}`:
//! rebind a parked stream to this connection and replay unacked
//! results).  Server → client: `HELLO_ACK` (JSON geometry, including
//! the stream's resume `token`), `RESULT` (bit-packed payload words,
//! LE), `STATS_REPLY` (JSON), `PONG`, `ERROR` (JSON `{code, msg}`,
//! plus `retry_after_ms` for overload sheds), `HEARTBEAT`.  The
//! payload length is validated against [`MAX_PAYLOAD`] *before* any
//! allocation, so a hostile header cannot OOM the daemon.
//!
//! [`ServeError`] is the complete failure surface a client can reach:
//! every variant is a value the session layer reports over the wire
//! (or the scheduler returns to a caller) — never a `panic!` that
//! would take the shared daemon down with it.

use std::fmt;
use std::io::{self, Read, Write};

/// Message magic: `"PV"`.
pub const MAGIC: [u8; 2] = *b"PV";
/// Wire-format version carried in every header (2 added `RESUME` and
/// the `token` field in `HELLO_ACK`).
pub const PROTO_VERSION: u8 = 2;
/// Hard payload cap, checked before allocation (largest legitimate
/// payload is one SUBMIT frame of `T*R` bytes — far below this).
pub const MAX_PAYLOAD: usize = 1 << 22;
/// Header size in bytes.
pub const HEADER_LEN: usize = 12;

/// Message verbs.  `0x0x` = client → server, `0x8x` = server → client.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Verb {
    /// Open a stream; payload empty or a JSON request (`preset` must
    /// match the daemon's code if present).
    Hello = 0x01,
    /// One frame of `T*R` quantized i8 LLRs.
    Submit = 0x02,
    /// Request the daemon's QoS report.
    Stats = 0x03,
    /// Keepalive probe.
    Ping = 0x04,
    /// Graceful close.
    Bye = 0x05,
    /// Rebind a parked stream to this connection; payload = JSON
    /// `{token, next_needed}` where `token` is the hex stream token
    /// from HELLO_ACK and `next_needed` the lowest result seq the
    /// client is still missing.  Sent *instead of* HELLO on a
    /// replacement connection.
    Resume = 0x06,
    /// HELLO accepted; payload = JSON engine/geometry description
    /// (plus the stream's resume `token`; a RESUME reply sets
    /// `resumed: true` and `next_expected`).
    HelloAck = 0x81,
    /// Decoded frame; seq echoes the SUBMIT, payload = `ceil(D/32)`
    /// little-endian u32 words of bit-packed payload.
    Result = 0x82,
    /// Payload = the JSON QoS report.
    StatsReply = 0x83,
    /// PING reply.
    Pong = 0x84,
    /// Payload = JSON `{code, msg}`; seq echoes the offending message
    /// when the error is frame-scoped.
    Error = 0x85,
    /// Idle-writer keepalive so clients can tell "slow" from "dead".
    Heartbeat = 0x86,
}

impl Verb {
    pub fn from_u8(b: u8) -> Option<Verb> {
        Some(match b {
            0x01 => Verb::Hello,
            0x02 => Verb::Submit,
            0x03 => Verb::Stats,
            0x04 => Verb::Ping,
            0x05 => Verb::Bye,
            0x06 => Verb::Resume,
            0x81 => Verb::HelloAck,
            0x82 => Verb::Result,
            0x83 => Verb::StatsReply,
            0x84 => Verb::Pong,
            0x85 => Verb::Error,
            0x86 => Verb::Heartbeat,
            _ => return None,
        })
    }

    /// Verbs a client may send (everything else on an inbound socket
    /// is a protocol violation).
    pub fn is_client_verb(self) -> bool {
        matches!(
            self,
            Verb::Hello | Verb::Submit | Verb::Stats | Verb::Ping | Verb::Bye | Verb::Resume
        )
    }
}

/// One decoded wire message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Message {
    pub verb: Verb,
    pub seq: u32,
    pub payload: Vec<u8>,
}

/// The typed failure surface of the serving layer.  Everything a
/// client can provoke — malformed bytes, oversize payloads, wrong
/// geometry, admission refusal, eviction, an engine dispatch failure
/// after a worker panic — is one of these values; the daemon reports
/// it (over the wire where possible) and keeps running.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Header did not start with [`MAGIC`].
    BadMagic([u8; 2]),
    /// Header carried an unsupported protocol version.
    Version { got: u8, want: u8 },
    /// Header verb byte is not a [`Verb`] (or not valid in this
    /// direction).
    UnknownVerb(u8),
    /// Declared payload length exceeds [`MAX_PAYLOAD`] (checked before
    /// allocation).
    Oversize { len: usize, max: usize },
    /// SUBMIT payload is not exactly one frame (`T*R` bytes).
    BadFrameLen { got: usize, want: usize },
    /// SUBMIT payload is all-erasure (every LLR zero, the
    /// [puncturing](crate::puncture) convention): the frame carries no
    /// channel information, so decoding it would deliver noise as if
    /// it were data.  Frame-scoped — the stream keeps going.
    ErasedFrame { len: usize },
    /// HELLO payload was not valid UTF-8/JSON, or requested a preset
    /// this daemon does not serve.
    BadHello(String),
    /// Admission refused: the daemon is at its concurrent-stream
    /// limit.
    ServerFull { max: usize },
    /// The stall detector (or an operator) evicted this stream.
    Evicted { reason: String },
    /// The shared engine failed to decode a dispatched group (e.g.
    /// the pool reported a worker panic).  The daemon survives; the
    /// affected frames are reported failed.
    Engine(String),
    /// The daemon is shutting down.
    Shutdown,
    /// Transport error.
    Io(String),
    /// A socket deadline expired (client-side read/write/connect
    /// timeouts; distinguishes a dead peer from a slow one).
    Timeout,
    /// Admission refused under overload: the daemon shed this submit;
    /// retry after roughly `ms` milliseconds.  Round-trips typed
    /// through ERROR payloads so the client's backoff can honor it.
    RetryAfter { ms: u64 },
    /// RESUME named a token the daemon does not hold parked (expired
    /// grace window, wrong daemon, or the stream was never parked).
    BadResume(String),
    /// An error reported by the peer over the wire (client side).
    Remote { code: String, msg: String },
}

impl ServeError {
    /// Stable short code, carried in ERROR payloads.
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::BadMagic(_) => "bad_magic",
            ServeError::Version { .. } => "bad_version",
            ServeError::UnknownVerb(_) => "unknown_verb",
            ServeError::Oversize { .. } => "oversize",
            ServeError::BadFrameLen { .. } => "bad_frame_len",
            ServeError::ErasedFrame { .. } => "erased_frame",
            ServeError::BadHello(_) => "bad_hello",
            ServeError::ServerFull { .. } => "server_full",
            ServeError::Evicted { .. } => "evicted",
            ServeError::Engine(_) => "engine",
            ServeError::Shutdown => "shutdown",
            ServeError::Io(_) => "io",
            ServeError::Timeout => "timeout",
            ServeError::RetryAfter { .. } => "retry_after",
            ServeError::BadResume(_) => "bad_resume",
            ServeError::Remote { .. } => "remote",
        }
    }

    /// The JSON `{code, msg}` body of an ERROR message
    /// (`retry_after_ms` added for [`ServeError::RetryAfter`]).
    pub fn to_json(&self) -> crate::json::Json {
        let mut o = crate::json::Json::obj();
        o.set("code", crate::json::Json::from(self.code()));
        o.set("msg", crate::json::Json::from(self.to_string()));
        if let ServeError::RetryAfter { ms } = self {
            o.set("retry_after_ms", crate::json::Json::from(*ms as usize));
        }
        o
    }

    /// Serialized ERROR payload bytes.
    pub fn to_wire(&self) -> Vec<u8> {
        self.to_json().to_string().into_bytes()
    }

    /// Reconstruct a peer-reported error from an ERROR payload
    /// (client side).  `retry_after` refusals come back typed (the
    /// client's backoff honors the hint); everything else degrades to
    /// a generic [`ServeError::Remote`], and unparseable payloads to
    /// one with code `unknown`.
    pub fn from_wire(payload: &[u8]) -> ServeError {
        let parsed = std::str::from_utf8(payload)
            .ok()
            .and_then(|s| crate::json::Json::parse(s).ok());
        if let Some(j) = &parsed {
            if j.get("code").and_then(crate::json::Json::as_str) == Some("retry_after") {
                let ms = j
                    .get("retry_after_ms")
                    .and_then(crate::json::Json::as_usize)
                    .unwrap_or(100) as u64;
                return ServeError::RetryAfter { ms };
            }
        }
        match parsed {
            Some(j) => ServeError::Remote {
                code: j
                    .get("code")
                    .and_then(crate::json::Json::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
                msg: j
                    .get("msg")
                    .and_then(crate::json::Json::as_str)
                    .unwrap_or_default()
                    .to_string(),
            },
            None => ServeError::Remote {
                code: "unknown".to_string(),
                msg: String::from_utf8_lossy(payload).into_owned(),
            },
        }
    }

    /// Map a transport error: expired socket deadlines become the
    /// typed [`ServeError::Timeout`], everything else
    /// [`ServeError::Io`].
    pub(crate) fn from_io(e: &io::Error) -> ServeError {
        match e.kind() {
            io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock => ServeError::Timeout,
            k => ServeError::Io(format!("{}: {e}", kind_name(k))),
        }
    }
}

fn kind_name(k: io::ErrorKind) -> &'static str {
    match k {
        io::ErrorKind::UnexpectedEof => "eof",
        io::ErrorKind::ConnectionReset => "reset",
        io::ErrorKind::BrokenPipe => "pipe",
        _ => "io",
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::BadMagic(m) => {
                write!(f, "bad message magic {m:02x?} (expected \"PV\")")
            }
            ServeError::Version { got, want } => {
                write!(f, "unsupported protocol version {got} (this daemon speaks {want})")
            }
            ServeError::UnknownVerb(v) => write!(f, "unknown or misdirected verb 0x{v:02x}"),
            ServeError::Oversize { len, max } => {
                write!(f, "declared payload of {len} bytes exceeds the {max}-byte cap")
            }
            ServeError::BadFrameLen { got, want } => write!(
                f,
                "SUBMIT payload of {got} bytes is not one frame ({want} bytes = T*R LLRs)"
            ),
            ServeError::ErasedFrame { len } => write!(
                f,
                "all-erasure SUBMIT frame ({len} LLRs, every one zero): nothing to decode"
            ),
            ServeError::BadHello(msg) => write!(f, "bad HELLO: {msg}"),
            ServeError::ServerFull { max } => {
                write!(f, "server full: already serving {max} streams")
            }
            ServeError::Evicted { reason } => write!(f, "stream evicted: {reason}"),
            ServeError::Engine(msg) => write!(f, "engine dispatch failed: {msg}"),
            ServeError::Shutdown => write!(f, "daemon shutting down"),
            ServeError::Io(msg) => write!(f, "transport error: {msg}"),
            ServeError::Timeout => write!(f, "socket deadline expired"),
            ServeError::RetryAfter { ms } => {
                write!(f, "overloaded: shed this submit, retry after ~{ms} ms")
            }
            ServeError::BadResume(msg) => write!(f, "cannot resume: {msg}"),
            ServeError::Remote { code, msg } => write!(f, "peer error [{code}]: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Read one message.  Blocks until a full message arrives; transport
/// failures (including a socket shut down by the stall detector)
/// surface as [`ServeError::Io`].
pub fn read_message(r: &mut impl Read) -> Result<Message, ServeError> {
    let mut hdr = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr).map_err(|e| ServeError::from_io(&e))?;
    if hdr[0..2] != MAGIC {
        return Err(ServeError::BadMagic([hdr[0], hdr[1]]));
    }
    if hdr[2] != PROTO_VERSION {
        return Err(ServeError::Version {
            got: hdr[2],
            want: PROTO_VERSION,
        });
    }
    let verb = Verb::from_u8(hdr[3]).ok_or(ServeError::UnknownVerb(hdr[3]))?;
    let seq = u32::from_le_bytes([hdr[4], hdr[5], hdr[6], hdr[7]]);
    let len = u32::from_le_bytes([hdr[8], hdr[9], hdr[10], hdr[11]]) as usize;
    if len > MAX_PAYLOAD {
        return Err(ServeError::Oversize {
            len,
            max: MAX_PAYLOAD,
        });
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .map_err(|e| ServeError::from_io(&e))?;
    Ok(Message { verb, seq, payload })
}

/// Write one message (header + payload) and flush.
pub fn write_message(
    w: &mut impl Write,
    verb: Verb,
    seq: u32,
    payload: &[u8],
) -> Result<(), ServeError> {
    debug_assert!(payload.len() <= MAX_PAYLOAD);
    let mut hdr = [0u8; HEADER_LEN];
    hdr[0..2].copy_from_slice(&MAGIC);
    hdr[2] = PROTO_VERSION;
    hdr[3] = verb as u8;
    hdr[4..8].copy_from_slice(&seq.to_le_bytes());
    hdr[8..12].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&hdr).map_err(|e| ServeError::from_io(&e))?;
    w.write_all(payload).map_err(|e| ServeError::from_io(&e))?;
    w.flush().map_err(|e| ServeError::from_io(&e))?;
    Ok(())
}

/// RESULT payload encoding: bit-packed words, little-endian.
pub fn words_to_wire(words: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 * words.len());
    for w in words {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out
}

/// Inverse of [`words_to_wire`]; `None` when the payload is not a
/// whole number of words.
pub fn wire_to_words(payload: &[u8]) -> Option<Vec<u32>> {
    if payload.len() % 4 != 0 {
        return None;
    }
    Some(
        payload
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn round_trip(verb: Verb, seq: u32, payload: &[u8]) -> Message {
        let mut buf = Vec::new();
        write_message(&mut buf, verb, seq, payload).unwrap();
        assert_eq!(buf.len(), HEADER_LEN + payload.len());
        read_message(&mut Cursor::new(buf)).unwrap()
    }

    #[test]
    fn messages_round_trip_every_verb() {
        for verb in [
            Verb::Hello,
            Verb::Submit,
            Verb::Stats,
            Verb::Ping,
            Verb::Bye,
            Verb::Resume,
            Verb::HelloAck,
            Verb::Result,
            Verb::StatsReply,
            Verb::Pong,
            Verb::Error,
            Verb::Heartbeat,
        ] {
            let m = round_trip(verb, 0xDEAD_BEEF, b"payload");
            assert_eq!(m.verb, verb);
            assert_eq!(m.seq, 0xDEAD_BEEF);
            assert_eq!(m.payload, b"payload");
            assert_eq!(Verb::from_u8(verb as u8), Some(verb));
        }
        assert_eq!(round_trip(Verb::Ping, 0, &[]).payload, Vec::<u8>::new());
        assert!(Verb::Hello.is_client_verb());
        assert!(Verb::Resume.is_client_verb());
        assert!(!Verb::Result.is_client_verb());
    }

    #[test]
    fn bad_magic_is_a_typed_error() {
        let mut buf = Vec::new();
        write_message(&mut buf, Verb::Ping, 1, &[]).unwrap();
        buf[0] = b'X';
        let err = read_message(&mut Cursor::new(buf)).unwrap_err();
        assert_eq!(err, ServeError::BadMagic([b'X', b'V']));
        assert_eq!(err.code(), "bad_magic");
        assert!(err.to_string().contains("PV"));
    }

    #[test]
    fn wrong_version_is_a_typed_error() {
        let mut buf = Vec::new();
        write_message(&mut buf, Verb::Ping, 1, &[]).unwrap();
        buf[2] = 9;
        let err = read_message(&mut Cursor::new(buf)).unwrap_err();
        assert_eq!(
            err,
            ServeError::Version {
                got: 9,
                want: PROTO_VERSION
            }
        );
        assert_eq!(err.code(), "bad_version");
    }

    #[test]
    fn unknown_verb_is_a_typed_error() {
        let mut buf = Vec::new();
        write_message(&mut buf, Verb::Ping, 1, &[]).unwrap();
        buf[3] = 0x7F;
        let err = read_message(&mut Cursor::new(buf)).unwrap_err();
        assert_eq!(err, ServeError::UnknownVerb(0x7F));
        assert_eq!(err.code(), "unknown_verb");
    }

    #[test]
    fn oversize_declaration_is_rejected_before_allocation() {
        // a hostile header declaring a huge payload must be refused
        // from the 12 header bytes alone — no buffer is allocated, no
        // payload bytes are read
        let mut buf = Vec::new();
        write_message(&mut buf, Verb::Submit, 1, &[]).unwrap();
        buf[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_message(&mut Cursor::new(buf)).unwrap_err();
        assert_eq!(
            err,
            ServeError::Oversize {
                len: u32::MAX as usize,
                max: MAX_PAYLOAD
            }
        );
        assert_eq!(err.code(), "oversize");
    }

    #[test]
    fn truncated_messages_are_io_errors() {
        // header cut short
        let mut buf = Vec::new();
        write_message(&mut buf, Verb::Ping, 1, &[]).unwrap();
        buf.truncate(5);
        let err = read_message(&mut Cursor::new(buf)).unwrap_err();
        assert_eq!(err.code(), "io");
        // payload cut short
        let mut buf = Vec::new();
        write_message(&mut buf, Verb::Submit, 1, &[0u8; 64]).unwrap();
        buf.truncate(HEADER_LEN + 10);
        let err = read_message(&mut Cursor::new(buf)).unwrap_err();
        assert!(matches!(err, ServeError::Io(_)), "{err:?}");
    }

    #[test]
    fn error_payloads_round_trip_code_and_message() {
        let errs = [
            ServeError::BadMagic([0, 1]),
            ServeError::Version { got: 2, want: 1 },
            ServeError::UnknownVerb(0xEE),
            ServeError::Oversize { len: 9, max: 1 },
            ServeError::BadFrameLen { got: 3, want: 296 },
            ServeError::ErasedFrame { len: 296 },
            ServeError::BadHello("not json".into()),
            ServeError::ServerFull { max: 4 },
            ServeError::Evicted {
                reason: "stalled".into(),
            },
            ServeError::Engine("worker exited".into()),
            ServeError::Shutdown,
            ServeError::Io("eof".into()),
            ServeError::Timeout,
            ServeError::BadResume("unknown token".into()),
            ServeError::Remote {
                code: "engine".into(),
                msg: "x".into(),
            },
        ];
        let mut codes = std::collections::BTreeSet::new();
        for e in &errs {
            assert!(!e.to_string().is_empty());
            assert!(codes.insert(e.code()), "duplicate code {}", e.code());
            let back = ServeError::from_wire(&e.to_wire());
            match back {
                ServeError::Remote { code, msg } => {
                    assert_eq!(code, e.code());
                    assert_eq!(msg, e.to_string());
                }
                other => panic!("expected Remote, got {other:?}"),
            }
        }
        assert!(codes.insert(ServeError::RetryAfter { ms: 1 }.code()));
        // garbage ERROR payloads degrade, never panic
        let back = ServeError::from_wire(&[0xFF, 0xFE]);
        assert!(matches!(back, ServeError::Remote { .. }));
    }

    #[test]
    fn retry_after_round_trips_typed() {
        let e = ServeError::RetryAfter { ms: 250 };
        assert_eq!(e.code(), "retry_after");
        let back = ServeError::from_wire(&e.to_wire());
        assert_eq!(back, e, "retry_after must come back typed, not Remote");
        // a retry_after payload missing the hint still comes back typed
        let back = ServeError::from_wire(br#"{"code":"retry_after","msg":"x"}"#);
        assert!(matches!(back, ServeError::RetryAfter { .. }), "{back:?}");
    }

    #[test]
    fn socket_deadline_maps_to_typed_timeout() {
        let e = io::Error::new(io::ErrorKind::TimedOut, "read timed out");
        assert_eq!(ServeError::from_io(&e), ServeError::Timeout);
        let e = io::Error::new(io::ErrorKind::WouldBlock, "would block");
        assert_eq!(ServeError::from_io(&e), ServeError::Timeout);
        let e = io::Error::new(io::ErrorKind::BrokenPipe, "pipe");
        assert!(matches!(ServeError::from_io(&e), ServeError::Io(_)));
    }

    #[test]
    fn result_words_round_trip() {
        let words = vec![0u32, 1, 0xFFFF_FFFF, 0x1234_5678];
        assert_eq!(wire_to_words(&words_to_wire(&words)).unwrap(), words);
        assert_eq!(wire_to_words(&[1, 2, 3]), None);
    }
}
