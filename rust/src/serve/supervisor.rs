//! The engine supervisor: graceful degradation for the shared serve
//! engine.
//!
//! The daemon decodes every client stream on one shared engine, so a
//! single engine failure — a worker panic that permanently closes a
//! pool's job queue, a dispatch error — would otherwise turn *every*
//! subsequent group into an error.  [`EngineSupervisor`] wraps the
//! engine and makes group dispatch self-healing:
//!
//! 1. **Retry** — a failed group is retried once on the current
//!    engine (transient faults, e.g. an injected `dispatch_err`,
//!    recover here).
//! 2. **Degrade** — if the retry also fails, the supervisor rebuilds
//!    the engine one rung down the ladder `simd → par → golden` at the
//!    *same* geometry/width/backend/q via the existing
//!    [`DecoderConfig`] factory, and decodes the group there.  The
//!    golden engine is single-threaded with no pool to kill, so the
//!    ladder always terminates in an engine that cannot fail this way.
//!
//! Every retry and degradation is counted in
//! [`RecoveryStats`](crate::metrics::RecoveryStats) and the currently
//! active engine's name shows up in STATS — a degraded daemon is
//! visible, not silent.
//!
//! 3. **Quarantine** — when a [`ShadowAuditor`](crate::audit) is
//!    installed, the supervisor polls its quarantine latch before each
//!    dispatch: a backend whose decodes diverged from the golden
//!    re-decode is forced one rung down the same ladder and — because
//!    the ladder only ever shrinks — excluded from rebuilds until the
//!    process restarts.  Quarantined engine names stay visible in
//!    STATS via [`quarantined`](EngineSupervisor::quarantined).
//!
//! 4. **Migrate** — with adaptive dispatch enabled ([`crate::plan`]),
//!    an installed [`Dispatcher`](crate::plan::Dispatcher) observes
//!    every decoded group's throughput and re-picks the best arm on
//!    its cadence; a changed pick swaps the live engine mid-stream,
//!    bit-identically (see
//!    [`install_planner`](EngineSupervisor::install_planner)).
//!
//! The supervisor also hosts the payload-corruption fault seams
//! (`flip_llr` corrupts a *dispatch copy* of the group; the auditor
//! always observes the clean original, and `corrupt_result` flips the
//! words of a successful decode), so integrity detection is testable
//! end-to-end.
//!
//! The supervisor implements [`DecodeEngine`] itself, so the scheduler
//! needs no knowledge of it; `PbvdServer` simply wraps the factory's
//! engine before handing it over.

use crate::audit::{IntegrityViolation, ShadowAuditor};
use crate::config::{DecoderConfig, EngineKind};
use crate::coordinator::{BatchTimings, DecodeEngine};
use crate::metrics::RecoveryStats;
use crate::plan::{backend_of_engine_name, Arm, BatchShape, Dispatcher};
use crate::serve::faults::FaultPlan;
use crate::trellis::Trellis;
use anyhow::{anyhow, Result};
use std::sync::{Arc, Mutex, PoisonError};

struct Slot {
    engine: Arc<dyn DecodeEngine>,
    /// Remaining downgrade rungs, strictly below the current engine.
    ladder: Vec<EngineKind>,
}

/// Rungs strictly below an engine, inferred from its (stable) name
/// prefix; non-CPU engines get the full CPU ladder.
fn ladder_below(name: &str) -> &'static [EngineKind] {
    static ALL: [EngineKind; 3] = [EngineKind::Simd, EngineKind::Par, EngineKind::Golden];
    let skip = if name.starts_with("simd-cpu:") {
        1
    } else if name.starts_with("par-cpu:") {
        2
    } else if name.starts_with("cpu:") {
        3
    } else {
        0
    };
    &ALL[skip..]
}

/// Self-healing wrapper around the daemon's shared engine (see the
/// [module docs](self)).
pub struct EngineSupervisor {
    cfg: DecoderConfig,
    trellis: Trellis,
    slot: Mutex<Slot>,
    recovery: Arc<RecoveryStats>,
    faults: Mutex<Option<Arc<FaultPlan>>>,
    auditor: Mutex<Option<Arc<ShadowAuditor>>>,
    /// Engine names abandoned by quarantine, for STATS.
    quarantined: Mutex<Vec<String>>,
    /// Adaptive dispatcher + the daemon's batch shape (see
    /// [`install_planner`](EngineSupervisor::install_planner)).
    planner: Mutex<Option<(Arc<Dispatcher>, BatchShape)>>,
}

impl EngineSupervisor {
    /// Wrap `engine`, remembering the (resolved) `cfg` it was built
    /// from so degraded replacements keep its geometry, metric width,
    /// backend, and quantizer.
    pub fn new(
        engine: Arc<dyn DecodeEngine>,
        cfg: DecoderConfig,
        trellis: Trellis,
        recovery: Arc<RecoveryStats>,
    ) -> EngineSupervisor {
        let ladder = ladder_below(&engine.name()).to_vec();
        EngineSupervisor {
            cfg,
            trellis,
            slot: Mutex::new(Slot { engine, ladder }),
            recovery,
            faults: Mutex::new(None),
            auditor: Mutex::new(None),
            quarantined: Mutex::new(Vec::new()),
            planner: Mutex::new(None),
        }
    }

    /// Install the adaptive dispatcher: every successfully decoded
    /// group feeds one throughput observation into the performance
    /// history, and every `reeval_batches`-th group re-picks the best
    /// arm for `shape` — a changed pick migrates the live engine
    /// in-place.  The swap is invisible in the decoded bits (every
    /// CPU arm is proven bit-identical by `testutil::oracle_matrix`),
    /// so a mid-stream migration only changes throughput.
    pub fn install_planner(&self, dispatcher: Arc<Dispatcher>, shape: BatchShape) {
        *self
            .planner
            .lock()
            .unwrap_or_else(PoisonError::into_inner) = Some((dispatcher, shape));
    }

    fn planner_ref(&self) -> Option<(Arc<Dispatcher>, BatchShape)> {
        self.planner
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .clone()
    }

    /// Install the shadow auditor: every successfully decoded group is
    /// observed (clean input, final words, margins), and the auditor's
    /// quarantine latch is polled before each dispatch.
    pub fn install_auditor(&self, auditor: Arc<ShadowAuditor>) {
        *self
            .auditor
            .lock()
            .unwrap_or_else(PoisonError::into_inner) = Some(auditor);
    }

    fn auditor_ref(&self) -> Option<Arc<ShadowAuditor>> {
        self.auditor
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .clone()
    }

    /// Engine names quarantined so far (excluded from rebuilds until
    /// restart).
    pub fn quarantined(&self) -> Vec<String> {
        self.quarantined
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .clone()
    }

    /// The currently active engine (post-degradation, this is the
    /// replacement).
    pub fn engine(&self) -> Arc<dyn DecodeEngine> {
        Arc::clone(&self.lock_slot().engine)
    }

    /// Shared recovery counters (retries / degradations recorded
    /// here; the serve layers record the rest).
    pub fn recovery(&self) -> Arc<RecoveryStats> {
        Arc::clone(&self.recovery)
    }

    fn lock_slot(&self) -> std::sync::MutexGuard<'_, Slot> {
        // a panic while holding the lock leaves plain data; recover it
        self.slot.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        self.faults
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .clone()
    }

    /// Rebuild one rung down; returns the replacement engine, or
    /// `None` when the ladder is exhausted.
    fn degrade(&self) -> Option<Result<Arc<dyn DecodeEngine>>> {
        let mut slot = self.lock_slot();
        if slot.ladder.is_empty() {
            return None;
        }
        let kind = slot.ladder.remove(0);
        let built = self
            .cfg
            .clone()
            .engine(kind)
            .build_engine(&self.trellis)
            .map_err(|e| anyhow!("supervisor rebuild ({kind}) failed: {e}"));
        Some(match built {
            Ok(engine) => {
                engine.install_fault_plan(self.fault_plan());
                slot.engine = Arc::clone(&engine);
                self.recovery.record_degradation();
                Ok(engine)
            }
            Err(e) => Err(e),
        })
    }

    /// Demote the backend an [`IntegrityViolation`] blames: record its
    /// name, count the quarantine, and — if it is still the active
    /// engine — force one rung down the ladder.  `degrade` pops rungs
    /// and never climbs back, so a quarantined backend is structurally
    /// excluded from rebuilds until the process restarts.
    fn quarantine(&self, v: &IntegrityViolation) {
        {
            let mut q = self
                .quarantined
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            if !q.contains(&v.engine) {
                q.push(v.engine.clone());
            }
        }
        if let Some(aud) = self.auditor_ref() {
            aud.stats().record_quarantine();
        }
        if self.engine().name() == v.engine {
            // ladder exhausted (golden diverged — only possible under
            // result-corruption faults) leaves the engine in place;
            // the quarantine is still counted and named in STATS
            let _ = self.degrade();
        }
    }

    /// The supervised decode: quarantine poll → attempt → retry →
    /// degrade down the ladder (see the [module docs](self)), then
    /// hand the result to the shadow auditor.
    fn decode_group(&self, llr: &Arc<[i8]>) -> Result<(Vec<u32>, BatchTimings)> {
        // quarantine latch: a backend the audit thread caught
        // diverging is demoted before it decodes anything else
        if let Some(aud) = self.auditor_ref() {
            if let Some(v) = aud.take_quarantine() {
                self.quarantine(&v);
            }
        }
        let plan = self.fault_plan();
        // flip_llr fault seam: corrupt a *dispatch copy* only — the
        // auditor observes the clean original below, so the divergence
        // is attributed to the backend, exactly like real bad silicon
        let dispatch: Arc<[i8]> = match plan.as_ref().and_then(|p| p.on_flip_llr()) {
            Some(n) => flipped_copy(llr, n),
            None => Arc::clone(llr),
        };
        let (mut words, timings, by) = self.dispatch_group(&dispatch)?;
        // adaptive dispatch: feed the measured group back into the
        // history, and on the re-evaluation cadence re-pick the arm
        // (a changed pick migrates the live engine — see `reeval`)
        if let Some((dsp, shape)) = self.planner_ref() {
            if let Some(arm) = Arm::for_engine_name(&by) {
                let secs = timings.total().as_secs_f64();
                if secs > 0.0 {
                    let bits = self.batch() * self.block();
                    dsp.observe(&shape, arm, backend_of_engine_name(&by), bits as f64 / secs / 1e6);
                }
            }
            if dsp.should_reeval() {
                self.reeval(&dsp, &shape);
            }
        }
        // corrupt_result fault seam: flip the decoded words of a
        // *successful* decode — clean input, corrupt output, so a
        // full-rate auditor detects every injected corruption
        if plan.as_ref().is_some_and(|p| p.on_corrupt_result()) {
            for w in &mut words {
                *w = !*w;
            }
        }
        if let Some(aud) = self.auditor_ref() {
            aud.observe_batch(&by, llr, &words, &timings.margins, self.batch());
        }
        Ok((words, timings))
    }

    /// Runtime re-evaluation: re-pick the arm for the daemon's shape
    /// and, when the pick differs from the live engine (and its arm is
    /// not quarantined), rebuild at the same geometry and swap the
    /// slot in-place.  The replacement's downgrade ladder is recomputed
    /// below it, minus any quarantined kinds — quarantine only ever
    /// shrinks the ladder, migration never resurrects a demoted arm.
    fn reeval(&self, dsp: &Dispatcher, shape: &BatchShape) {
        let decision = dsp.pick(shape);
        if Arm::for_engine_name(&self.engine().name()) == Some(decision.arm) {
            return;
        }
        let quarantined = self.quarantined();
        let q_arms: Vec<Arm> = quarantined
            .iter()
            .filter_map(|q| Arm::for_engine_name(q))
            .collect();
        if q_arms.contains(&decision.arm) {
            return;
        }
        let built = self
            .cfg
            .clone()
            .engine(decision.arm.kind())
            .width(decision.arm.width())
            .build_engine(&self.trellis);
        // a failed rebuild is not an error path: the current engine
        // keeps decoding and the next cadence re-picks
        let Ok(engine) = built else { return };
        engine.install_fault_plan(self.fault_plan());
        let mut ladder = ladder_below(&engine.name()).to_vec();
        ladder.retain(|k| !q_arms.iter().any(|a| a.kind() == *k));
        let mut slot = self.lock_slot();
        slot.engine = engine;
        slot.ladder = ladder;
        dsp.stats().record_migration();
    }

    /// attempt → retry → degrade; returns the words, timings, and the
    /// name of the engine that actually produced the decode.
    fn dispatch_group(&self, llr: &Arc<[i8]>) -> Result<(Vec<u32>, BatchTimings, String)> {
        let engine = self.engine();
        // dispatch fault seam: an injected fault counts as the first
        // attempt's failure, so it exercises the real retry machinery
        let first = match self.fault_plan().and_then(|p| p.on_dispatch()) {
            Some(msg) => Err(anyhow!(msg)),
            None => engine.decode_batch_shared(llr),
        };
        let mut err = match first {
            Ok((w, t)) => return Ok((w, t, engine.name())),
            Err(e) => e,
        };
        // one retry on the current engine
        self.recovery.record_retry();
        match engine.decode_batch_shared(llr) {
            Ok((w, t)) => return Ok((w, t, engine.name())),
            Err(e) => err = e,
        }
        // then rebuild down the ladder until a rung decodes the group
        while let Some(built) = self.degrade() {
            let attempt = built.and_then(|engine| {
                engine
                    .decode_batch_shared(llr)
                    .map(|(w, t)| (w, t, engine.name()))
            });
            match attempt {
                Ok(r) => return Ok(r),
                Err(e) => err = e,
            }
        }
        Err(err)
    }
}

/// A copy of `llr` with `n` evenly spaced samples saturate-flipped to
/// the strongly wrong sign (the `flip_llr` fault payload).
fn flipped_copy(llr: &Arc<[i8]>, n: u32) -> Arc<[i8]> {
    let mut c = llr.to_vec();
    if !c.is_empty() {
        let len = c.len();
        let step = (len / (n as usize).max(1)).max(1);
        for i in 0..(n as usize).min(len) {
            let pos = (i * step) % len;
            c[pos] = if c[pos] >= 0 { -16 } else { 16 };
        }
    }
    c.into()
}

impl DecodeEngine for EngineSupervisor {
    fn decode_batch(&self, llr_i8: &[i8]) -> Result<(Vec<u32>, BatchTimings)> {
        let shared: Arc<[i8]> = Arc::from(llr_i8);
        self.decode_group(&shared)
    }

    fn decode_batch_shared(&self, llr_i8: &Arc<[i8]>) -> Result<(Vec<u32>, BatchTimings)> {
        self.decode_group(llr_i8)
    }

    fn batch(&self) -> usize {
        self.engine().batch()
    }
    fn block(&self) -> usize {
        self.engine().block()
    }
    fn depth(&self) -> usize {
        self.engine().depth()
    }
    fn r(&self) -> usize {
        self.engine().r()
    }
    /// The *current* engine's name — after a degradation this is the
    /// replacement, so STATS shows what is actually decoding.
    fn name(&self) -> String {
        self.engine().name()
    }
    fn worker_snapshot(&self) -> Option<crate::metrics::WorkerSnapshot> {
        self.engine().worker_snapshot()
    }
    fn install_fault_plan(&self, plan: Option<Arc<FaultPlan>>) {
        *self
            .faults
            .lock()
            .unwrap_or_else(PoisonError::into_inner) = plan.clone();
        self.engine().install_fault_plan(plan);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CpuEngine;

    const BATCH: usize = 4;
    const BLOCK: usize = 32;
    const DEPTH: usize = 15;

    fn cfg(kind: EngineKind, workers: usize) -> DecoderConfig {
        DecoderConfig::new("k3")
            .batch(BATCH)
            .block(BLOCK)
            .depth(DEPTH)
            .workers(workers)
            .engine(kind)
    }

    fn supervised(kind: EngineKind, workers: usize) -> (EngineSupervisor, Vec<u32>, Arc<[i8]>) {
        let c = cfg(kind, workers);
        let t = c.trellis().unwrap();
        let engine = c.build_engine(&t).unwrap();
        // deterministic pseudo-noisy batch input
        let total = (BLOCK + 2 * DEPTH) * t.r * BATCH;
        let llr: Arc<[i8]> = (0..total)
            .map(|i| (((i * 37 + 11) % 31) as i8) - 15)
            .collect::<Vec<_>>()
            .into();
        let (golden, _) = CpuEngine::new(&t, BATCH, BLOCK, DEPTH)
            .decode_batch(&llr)
            .unwrap();
        let sup = EngineSupervisor::new(engine, c, t, Arc::new(RecoveryStats::new()));
        (sup, golden, llr)
    }

    #[test]
    fn clean_engine_passes_through_untouched() {
        let (sup, golden, llr) = supervised(EngineKind::Par, 2);
        assert!(sup.name().starts_with("par-cpu:"), "{}", sup.name());
        let (words, _) = sup.decode_batch_shared(&llr).unwrap();
        assert_eq!(words, golden);
        assert_eq!(sup.recovery().retries(), 0);
        assert_eq!(sup.recovery().degradations(), 0);
    }

    #[test]
    fn injected_dispatch_fault_recovers_via_one_retry() {
        let (sup, golden, llr) = supervised(EngineKind::Par, 2);
        sup.install_fault_plan(Some(Arc::new(
            FaultPlan::parse("dispatch_err@group=0").unwrap(),
        )));
        let (words, _) = sup.decode_batch_shared(&llr).unwrap();
        assert_eq!(words, golden, "retried group must be bit-identical");
        assert_eq!(sup.recovery().retries(), 1);
        assert_eq!(sup.recovery().degradations(), 0);
        assert!(sup.name().starts_with("par-cpu:"), "no downgrade needed");
    }

    #[test]
    fn worker_panic_degrades_par_to_golden_bit_identically() {
        let (sup, golden, llr) = supervised(EngineKind::Par, 2);
        sup.install_fault_plan(Some(Arc::new(
            FaultPlan::parse("worker_panic@job=0").unwrap(),
        )));
        // attempt 1: injected panic kills the pool; retry: pool is
        // closed; degrade: par -> golden, which decodes the group
        let (words, _) = sup.decode_batch_shared(&llr).unwrap();
        assert_eq!(words, golden, "degraded decode must be bit-identical");
        assert_eq!(sup.recovery().retries(), 1);
        assert_eq!(sup.recovery().degradations(), 1);
        assert!(sup.name().starts_with("cpu:"), "STATS shows the replacement: {}", sup.name());
        // and the daemon keeps decoding on the replacement
        let (words, _) = sup.decode_batch_shared(&llr).unwrap();
        assert_eq!(words, golden);
    }

    #[test]
    fn golden_engine_has_no_ladder_left() {
        let (sup, _, _) = supervised(EngineKind::Golden, 1);
        assert!(sup.lock_slot().ladder.is_empty());
        // a simd engine still has two rungs below it
        let (sup, _, _) = supervised(EngineKind::Simd, 2);
        assert_eq!(
            sup.lock_slot().ladder,
            vec![EngineKind::Par, EngineKind::Golden]
        );
    }

    /// A full-rate (every block), quarantine-enabled auditor installed
    /// on the supervisor.
    fn full_rate_auditor(sup: &EngineSupervisor) -> Arc<ShadowAuditor> {
        let acfg = crate::config::AuditConfig {
            sample_ppm: Some(1_000_000),
            seed: Some(7),
            quarantine: Some(true),
            low_margin: None,
        };
        let t = Trellis::preset("k3").unwrap();
        let aud = Arc::new(ShadowAuditor::new(&t, BLOCK, DEPTH, &acfg));
        sup.install_auditor(Arc::clone(&aud));
        aud
    }

    #[test]
    fn clean_decode_under_full_rate_audit_has_zero_violations() {
        let (sup, golden, llr) = supervised(EngineKind::Par, 2);
        let aud = full_rate_auditor(&sup);
        let (words, t) = sup.decode_batch_shared(&llr).unwrap();
        assert_eq!(words, golden);
        assert_eq!(t.margins.len(), BATCH, "margins ride along per PB");
        aud.flush();
        assert_eq!(aud.stats().audited(), BATCH as u64);
        assert_eq!(aud.stats().violations(), 0, "no false positives");
        assert!(sup.quarantined().is_empty());
    }

    #[test]
    fn corrupt_result_fault_is_detected_and_backend_quarantined() {
        let (sup, golden, llr) = supervised(EngineKind::Par, 2);
        let aud = full_rate_auditor(&sup);
        sup.install_fault_plan(Some(Arc::new(
            FaultPlan::parse("corrupt_result@nth=0").unwrap(),
        )));
        // group 0: the decode succeeds, then the words are flipped —
        // clean input + corrupt output is detected with certainty
        let (corrupted, _) = sup.decode_batch_shared(&llr).unwrap();
        assert_ne!(corrupted, golden);
        aud.flush();
        assert!(aud.stats().violations() >= 1, "auditor caught the corruption");
        let v = &aud.violations()[0];
        assert!(v.engine.starts_with("par-cpu:"), "provenance: {v}");
        // the next dispatch polls the latch: par-cpu is quarantined
        // and the group decodes on the golden rung, bit-identically
        let (words, _) = sup.decode_batch_shared(&llr).unwrap();
        assert_eq!(words, golden, "post-quarantine decode is clean");
        assert!(sup.name().starts_with("cpu:"), "{}", sup.name());
        assert_eq!(aud.stats().quarantines(), 1);
        let q = sup.quarantined();
        assert_eq!(q.len(), 1, "{q:?}");
        assert!(q[0].starts_with("par-cpu:"), "{q:?}");
        assert_eq!(sup.recovery().retries(), 0, "quarantine is not a retry");
    }

    #[test]
    fn flip_llr_fault_diverges_from_clean_input_and_is_detected() {
        let (sup, golden, llr) = supervised(EngineKind::Par, 2);
        let aud = full_rate_auditor(&sup);
        // flip a dense run of samples so the decode genuinely diverges
        sup.install_fault_plan(Some(Arc::new(
            FaultPlan::parse("flip_llr=256@nth=0").unwrap(),
        )));
        let (words, _) = sup.decode_batch_shared(&llr).unwrap();
        assert_ne!(words, golden, "corrupted dispatch copy changes the decode");
        aud.flush();
        // the auditor re-decoded the CLEAN original, so the divergence
        // is attributed to the backend
        assert!(aud.stats().violations() >= 1);
        // an un-faulted group on the same plan decodes clean again
        sup.install_fault_plan(None);
        let before = aud.stats().violations();
        let (words, _) = sup.decode_batch_shared(&llr).unwrap();
        aud.flush();
        // quarantine fired, so this decode ran on a lower rung — still
        // bit-identical to golden, with no new violations
        assert_eq!(words, golden);
        assert_eq!(aud.stats().violations(), before);
    }

    #[test]
    fn geometry_delegates_to_the_current_engine() {
        let (sup, _, _) = supervised(EngineKind::Par, 2);
        assert_eq!(sup.batch(), BATCH);
        assert_eq!(sup.block(), BLOCK);
        assert_eq!(sup.depth(), DEPTH);
        assert_eq!(sup.r(), 2);
        assert!(sup.worker_snapshot().is_some());
    }
}
