//! Cross-stream lane-group coalescing scheduler.
//!
//! The daemon's throughput case rests on one observation: the engine's
//! cost per dispatch is nearly flat in batch occupancy, so frames from
//! *different* client streams should share one lane group whenever
//! possible.  The scheduler keeps one bounded FIFO per registered
//! stream (the bound is the backpressure contract — a producer that
//! outruns the engine blocks in [`Scheduler::submit`], it does not OOM
//! the daemon), and a single batcher thread that drafts frames
//! round-robin across streams into `batch`-slot groups:
//!
//! * a group dispatches **immediately** once `batch` frames are
//!   pending across all streams, and
//! * a **flush deadline** (`coalesce` past the oldest pending frame's
//!   enqueue time) dispatches a partial group so a trickle stream is
//!   never stalled waiting for traffic that may not come.
//!
//! Dispatch is one group at a time to the shared engine, which keeps
//! per-stream FIFO ordering without any reordering buffer.  QoS
//! attribution is exact: each group's busy time (from
//! `BatchTimings::per_worker` when the engine shards across a pool,
//! else the phase total) is split across the group's frames so
//! per-stream `busy_ns` sums to the pool total.
//!
//! # Robustness (PR 7)
//!
//! Three mechanisms make the scheduler survive a lost connection, an
//! overload, and a fault plan without losing or duplicating a frame:
//!
//! * **Park / rebind.**  A stream whose connection died is *parked*
//!   ([`Scheduler::park`]), not retired: its queued frames keep
//!   decoding and every undelivered (or delivered-but-unacked) result
//!   accumulates in a per-stream **replay buffer**.  A replacement
//!   connection rebinds ([`Scheduler::rebind`]) with the client's
//!   `next_needed` seq; the buffer is pruned below it and replayed
//!   above it, exactly once.  Each rebind bumps the stream's
//!   **binding generation** — `submit`/`ack`/`park` from the
//!   superseded connection carry the old generation and are ignored,
//!   so a zombie reader or writer cannot corrupt the window.
//! * **Shedding.**  With a configured shed bound, `submit` refuses
//!   new frames while the *global* pending count is saturated,
//!   returning the typed [`ServeError::RetryAfter`] hint instead of
//!   blocking — overload degrades into client backoff, not into a
//!   convoy.
//! * **Fault seam.**  An installed [`FaultPlan`] is consulted once
//!   per coalesced group before dispatch (`dispatch_err` clauses fail
//!   the group through the exact error path a real engine failure
//!   takes).  The serve daemon installs its plan on the
//!   [`EngineSupervisor`](crate::serve::supervisor::EngineSupervisor)
//!   instead, which retries and degrades before the scheduler ever
//!   sees an error; the scheduler-level seam serves bare-scheduler
//!   deployments and tests.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::DecodeEngine;
use crate::metrics::{CoalesceStats, IntegrityStats, RecoveryStats, StreamQos};
use crate::serve::faults::FaultPlan;
use crate::serve::protocol::ServeError;

/// Result-delivery callback for one stream.  Called by the batcher
/// thread with the scheduler lock held — it must hand the result off
/// (e.g. into a channel) and **must not call back into the scheduler**.
pub type Deliver = Box<dyn Fn(u32, Result<Vec<u32>, ServeError>) + Send>;

/// Tuning knobs beyond the original `(queue_depth, coalesce)` pair;
/// [`Default`] reproduces the pre-robustness scheduler exactly.
#[derive(Default)]
pub struct SchedulerOptions {
    /// Global pending-frame bound above which [`Scheduler::submit`]
    /// sheds with [`ServeError::RetryAfter`] instead of blocking
    /// (`0` = never shed).
    pub shed_queue: usize,
    /// Fault plan consulted at the group-dispatch seam (bare-scheduler
    /// deployments; the daemon installs its plan on the supervisor).
    pub faults: Option<Arc<FaultPlan>>,
    /// Shared recovery counters; a fresh set is created when absent.
    pub recovery: Option<Arc<RecoveryStats>>,
    /// Shared integrity counters (rejected inputs recorded here; the
    /// daemon shares the shadow auditor's set so STATS shows one
    /// coherent integrity view).  A fresh set is created when absent.
    pub integrity: Option<Arc<IntegrityStats>>,
}

struct Pending {
    seq: u32,
    llr: Vec<i8>,
    enqueued: Instant,
}

struct StreamEntry {
    queue: VecDeque<Pending>,
    /// Frames submitted but not yet acknowledged by the consumer
    /// ([`Scheduler::ack`]); this — not the queue length — is the
    /// backpressure window, so a slow *reader* exerts backpressure
    /// just like a fast writer.
    in_flight: usize,
    /// Binding generation: bumped by every [`Scheduler::rebind`] so
    /// calls from a superseded connection are ignored.
    binding: u64,
    /// Parked by [`Scheduler::park`]: the connection is gone but the
    /// stream is resumable — decode continues into `replay`.
    parked: bool,
    /// The next SUBMIT seq this stream expects (highest seen + 1);
    /// reported to a resuming client so it knows what to resubmit.
    next_expected: u32,
    /// Results delivered (or decoded while parked) but not yet acked
    /// by a successful socket write, in seq order.  Bounded by the
    /// unacked window.
    replay: VecDeque<(u32, Result<Vec<u32>, ServeError>)>,
    /// The last `queue_depth` *acked* results, kept so a resume can
    /// re-serve frames that were written to a socket the peer never
    /// drained (the TCP-buffer race).  Bounded ring.
    acked_tail: VecDeque<(u32, Result<Vec<u32>, ServeError>)>,
    evicted: Option<String>,
    deliver: Option<Deliver>,
    qos: Arc<StreamQos>,
}

struct State {
    streams: BTreeMap<u64, StreamEntry>,
    next_id: u64,
    pending_total: usize,
    shutdown: bool,
}

struct Shared {
    engine: Arc<dyn DecodeEngine>,
    /// Bytes per SUBMIT frame: `T * R` (`T = D + 2L`).
    frame_len: usize,
    /// Result words per frame: `ceil(D / 32)`.
    words_per_pb: usize,
    /// Payload bits per frame (`D`).
    bits_per_frame: u64,
    batch: usize,
    queue_depth: usize,
    coalesce: Duration,
    shed_queue: usize,
    faults: Option<Arc<FaultPlan>>,
    recovery: Arc<RecoveryStats>,
    integrity: Arc<IntegrityStats>,
    /// Smallest final path-metric margin any dispatched group reported
    /// (`u64::MAX` until the first margin-reporting decode) — the
    /// fleet-level confidence floor surfaced in STATS.
    min_margin: AtomicU64,
    state: Mutex<State>,
    /// Signals the batcher: work arrived or shutdown.
    work_cv: Condvar,
    /// Signals blocked submitters: in-flight window opened or stream
    /// state changed.
    space_cv: Condvar,
    coalesce_stats: CoalesceStats,
    evictions: AtomicU64,
}

/// The scheduler lock outlives any panic that poisons it (all guarded
/// data is plain bookkeeping), so every acquisition recovers instead
/// of propagating the poison — a panicking deliver callback must not
/// wedge the daemon.
fn lock_state(sh: &Shared) -> MutexGuard<'_, State> {
    sh.state.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Admission control + cross-stream batching in front of one shared
/// [`DecodeEngine`].  See the module docs for the dispatch policy.
pub struct Scheduler {
    shared: Arc<Shared>,
    batcher: Option<JoinHandle<()>>,
}

struct Slot {
    stream: u64,
    seq: u32,
    enqueued: Instant,
    llr: Vec<i8>,
}

impl Scheduler {
    /// Wrap `engine` with a coalescing scheduler.  `queue_depth` is
    /// the per-stream unacknowledged-frame bound (min 1); `coalesce`
    /// is the flush deadline for partial groups (zero = dispatch
    /// whatever is pending as soon as the batcher wakes).
    pub fn new(engine: Arc<dyn DecodeEngine>, queue_depth: usize, coalesce: Duration) -> Scheduler {
        Scheduler::with_options(engine, queue_depth, coalesce, SchedulerOptions::default())
    }

    /// [`Scheduler::new`] plus the robustness knobs (shed bound, fault
    /// plan, shared recovery counters).
    pub fn with_options(
        engine: Arc<dyn DecodeEngine>,
        queue_depth: usize,
        coalesce: Duration,
        opts: SchedulerOptions,
    ) -> Scheduler {
        let shared = Arc::new(Shared {
            frame_len: engine.total() * engine.r(),
            words_per_pb: engine.block().div_ceil(32),
            bits_per_frame: engine.block() as u64,
            batch: engine.batch(),
            queue_depth: queue_depth.max(1),
            coalesce,
            shed_queue: opts.shed_queue,
            faults: opts.faults,
            recovery: opts.recovery.unwrap_or_else(|| Arc::new(RecoveryStats::new())),
            integrity: opts
                .integrity
                .unwrap_or_else(|| Arc::new(IntegrityStats::default())),
            min_margin: AtomicU64::new(u64::MAX),
            engine,
            state: Mutex::new(State {
                streams: BTreeMap::new(),
                next_id: 1,
                pending_total: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            coalesce_stats: CoalesceStats::new(),
            evictions: AtomicU64::new(0),
        });
        let batcher = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("pbvd-batcher".into())
                .spawn(move || batcher_loop(&shared))
                .expect("spawn batcher thread")
        };
        Scheduler {
            shared,
            batcher: Some(batcher),
        }
    }

    /// Register a stream; `deliver` receives each frame's result (or
    /// typed error) in submission order.  The stream starts at binding
    /// generation 0 (bumped by every [`Scheduler::rebind`]).
    pub fn register(&self, deliver: Deliver) -> u64 {
        let mut st = lock_state(&self.shared);
        let id = st.next_id;
        st.next_id += 1;
        st.streams.insert(
            id,
            StreamEntry {
                queue: VecDeque::new(),
                in_flight: 0,
                binding: 0,
                parked: false,
                next_expected: 0,
                replay: VecDeque::new(),
                acked_tail: VecDeque::new(),
                evicted: None,
                deliver: Some(deliver),
                qos: Arc::new(StreamQos::new()),
            },
        );
        id
    }

    /// Enqueue one frame (`T*R` i8 LLR values) on behalf of binding
    /// generation `binding`.  Blocks while the stream's unacknowledged
    /// window is full; returns the typed error if the stream was
    /// evicted or rebound (the wait is interrupted), sheds with
    /// [`ServeError::RetryAfter`] when the global pending bound is
    /// saturated, and fails with [`ServeError::Shutdown`] on teardown.
    pub fn submit(
        &self,
        stream: u64,
        binding: u64,
        seq: u32,
        llr: Vec<i8>,
    ) -> Result<(), ServeError> {
        let sh = &self.shared;
        if llr.len() != sh.frame_len {
            return Err(ServeError::BadFrameLen {
                got: llr.len(),
                want: sh.frame_len,
            });
        }
        // input hardening: an all-erasure frame (every LLR zero, the
        // puncturing convention) has no channel information — decoding
        // it would deliver noise as data, so refuse it frame-scoped
        if crate::audit::is_all_erasure(&llr) {
            sh.integrity.record_rejected_input();
            return Err(ServeError::ErasedFrame { len: llr.len() });
        }
        let mut st = lock_state(sh);
        loop {
            if st.shutdown {
                return Err(ServeError::Shutdown);
            }
            let entry = st.streams.get(&stream).ok_or_else(|| ServeError::Evicted {
                reason: "unknown stream".into(),
            })?;
            if let Some(reason) = &entry.evicted {
                return Err(ServeError::Evicted {
                    reason: reason.clone(),
                });
            }
            if entry.binding != binding {
                return Err(ServeError::Evicted {
                    reason: "stream rebound by a newer connection".into(),
                });
            }
            if entry.parked {
                // the connection this submit arrived on is gone; its
                // reader must stop, the frames live on for the resume
                return Err(ServeError::Io("stream parked: connection lost".into()));
            }
            if sh.shed_queue > 0 && st.pending_total >= sh.shed_queue {
                let ms = ((st.pending_total / sh.batch.max(1)) as u64 * 10).clamp(25, 1000);
                sh.recovery.record_shed();
                return Err(ServeError::RetryAfter { ms });
            }
            if entry.in_flight < sh.queue_depth {
                break;
            }
            st = sh.space_cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        let s = &mut *st;
        let entry = match s.streams.get_mut(&stream) {
            Some(e) => e,
            // unreachable (checked in the loop above, lock still held),
            // but a typed error beats a panic in the serve path
            None => {
                return Err(ServeError::Evicted {
                    reason: "unknown stream".into(),
                })
            }
        };
        entry.in_flight += 1;
        entry.next_expected = entry.next_expected.max(seq.wrapping_add(1));
        entry.queue.push_back(Pending {
            seq,
            llr,
            enqueued: Instant::now(),
        });
        s.pending_total += 1;
        sh.work_cv.notify_one();
        Ok(())
    }

    /// Consumer acknowledgment from binding generation `binding`: the
    /// result for `seq` was written to the client socket, opening one
    /// slot in the backpressure window.  The result moves from the
    /// replay buffer into the bounded acked tail (so a resume can
    /// still re-serve it); acks from a superseded binding are ignored.
    pub fn ack(&self, stream: u64, binding: u64, seq: u32) {
        let mut st = lock_state(&self.shared);
        if let Some(entry) = st.streams.get_mut(&stream) {
            if entry.binding != binding {
                return; // stale writer from before a rebind
            }
            if let Some(pos) = entry.replay.iter().position(|(s, _)| *s == seq) {
                if let Some(done) = entry.replay.remove(pos) {
                    entry.acked_tail.push_back(done);
                    while entry.acked_tail.len() > self.shared.queue_depth {
                        entry.acked_tail.pop_front();
                    }
                }
                entry.in_flight = entry.in_flight.saturating_sub(1);
            }
        }
        drop(st);
        self.shared.space_cv.notify_all();
    }

    /// Park a stream whose connection died: keep its queued frames
    /// decoding (results accumulate in the replay buffer) and await a
    /// [`Scheduler::rebind`] within the resume grace window.  Returns
    /// `false` when the stream is unknown, evicted, already parked, or
    /// `binding` was superseded (a zombie connection cannot park the
    /// replacement).
    pub fn park(&self, stream: u64, binding: u64) -> bool {
        let mut st = lock_state(&self.shared);
        let parked = match st.streams.get_mut(&stream) {
            Some(e) if e.evicted.is_none() && !e.parked && e.binding == binding => {
                e.parked = true;
                e.deliver = None;
                true
            }
            _ => false,
        };
        drop(st);
        if parked {
            self.shared.recovery.record_parked();
            // a reader blocked in submit must observe `parked` and bail
            self.shared.space_cv.notify_all();
        }
        parked
    }

    /// Rebind a (typically parked) stream to a replacement connection.
    /// `next_needed` is the lowest result seq the client is still
    /// missing: buffered results below it are retired, everything at
    /// or above it is replayed through `deliver` exactly once, in seq
    /// order.  Returns the new binding generation plus the next SUBMIT
    /// seq the stream expects (the client resubmits from there).
    pub fn rebind(
        &self,
        stream: u64,
        next_needed: u32,
        deliver: Deliver,
    ) -> Result<(u64, u32), ServeError> {
        let mut st = lock_state(&self.shared);
        let entry = st
            .streams
            .get_mut(&stream)
            .ok_or_else(|| ServeError::BadResume("unknown stream".into()))?;
        if let Some(reason) = &entry.evicted {
            return Err(ServeError::BadResume(format!("stream evicted: {reason}")));
        }
        // a result older than both buffers is unrecoverable — refuse
        // loudly rather than resume with a silent gap
        let oldest_held = entry
            .acked_tail
            .front()
            .or_else(|| entry.replay.front())
            .map(|(s, _)| *s);
        if let Some(oldest) = oldest_held {
            if next_needed < oldest {
                return Err(ServeError::BadResume(format!(
                    "resume horizon exceeded: need {next_needed}, oldest held {oldest}"
                )));
            }
        }
        entry.binding += 1;
        entry.parked = false;
        // acked results the client did receive are done for good
        entry.acked_tail.retain(|(s, _)| *s >= next_needed);
        // un-acked results the client received are acked after the fact
        while let Some((s, _)) = entry.replay.front() {
            if *s >= next_needed {
                break;
            }
            entry.replay.pop_front();
            entry.in_flight = entry.in_flight.saturating_sub(1);
        }
        // replay what's left: acked-but-undrained first (older seqs),
        // then the un-acked window — both already in seq order
        let mut replayed = 0u64;
        for (s, r) in entry.acked_tail.iter().chain(entry.replay.iter()) {
            deliver(*s, r.clone());
            replayed += 1;
        }
        // re-served acked results re-enter the un-acked window so the
        // new writer's acks balance the books
        while let Some(back) = entry.acked_tail.pop_back() {
            entry.in_flight += 1;
            entry.replay.push_front(back);
        }
        entry.deliver = Some(deliver);
        let out = (entry.binding, entry.next_expected);
        drop(st);
        self.shared.recovery.record_resume();
        self.shared.recovery.record_replayed(replayed);
        self.shared.space_cv.notify_all();
        Ok(out)
    }

    /// Retire a stream: drop its pending frames, stop delivering, and
    /// unblock anything waiting on it.  `counted` marks this as a
    /// forced eviction (stall detector) rather than a graceful close.
    /// The entry stays behind, marked, so STATS keeps its totals.
    pub fn retire(&self, stream: u64, reason: &str, counted: bool) {
        self.end_stream(stream, None, reason, counted);
    }

    /// [`Scheduler::retire`] guarded by binding generation: a session
    /// whose stream was rebound to a newer connection must not tear
    /// the resumed stream down.  Returns whether `binding` still owns
    /// the stream (the caller then owns token cleanup too).
    pub fn release(&self, stream: u64, binding: u64, reason: &str, counted: bool) -> bool {
        self.end_stream(stream, Some(binding), reason, counted)
    }

    fn end_stream(
        &self,
        stream: u64,
        binding: Option<u64>,
        reason: &str,
        counted: bool,
    ) -> bool {
        let mut st = lock_state(&self.shared);
        let s = &mut *st;
        let mut newly = false;
        let mut owned = false;
        if let Some(entry) = s.streams.get_mut(&stream) {
            if let Some(b) = binding {
                if entry.binding != b {
                    return false; // superseded by a rebind
                }
            }
            owned = true;
            if entry.evicted.is_none() {
                newly = true;
                s.pending_total -= entry.queue.len();
                entry.queue.clear();
                entry.replay.clear();
                entry.acked_tail.clear();
                entry.in_flight = 0;
                entry.parked = false;
                entry.deliver = None;
                entry.evicted = Some(reason.to_string());
            }
        }
        drop(st);
        if newly && counted {
            self.shared.evictions.fetch_add(1, Ordering::Relaxed);
        }
        self.shared.space_cv.notify_all();
        self.shared.work_cv.notify_all();
        owned
    }

    /// The stream's live QoS counters (present even after eviction).
    pub fn qos(&self, stream: u64) -> Option<Arc<StreamQos>> {
        let st = lock_state(&self.shared);
        st.streams.get(&stream).map(|e| Arc::clone(&e.qos))
    }

    /// Forced evictions so far (stall detector and peers).
    pub fn evictions(&self) -> u64 {
        self.shared.evictions.load(Ordering::Relaxed)
    }

    /// Coalescing counters (groups, mixed groups, fill ratio).
    pub fn coalesce_stats(&self) -> &CoalesceStats {
        &self.shared.coalesce_stats
    }

    /// Shared recovery counters (resumes, parks, replays, sheds, plus
    /// the supervisor's retries/degradations when the counters are
    /// shared via [`SchedulerOptions::recovery`]).
    pub fn recovery(&self) -> &Arc<RecoveryStats> {
        &self.shared.recovery
    }

    /// Shared integrity counters (audits, violations, rejected
    /// inputs; shared with the shadow auditor via
    /// [`SchedulerOptions::integrity`]).
    pub fn integrity(&self) -> &Arc<IntegrityStats> {
        &self.shared.integrity
    }

    /// The shared engine (geometry + name for HELLO_ACK).
    pub fn engine(&self) -> &Arc<dyn DecodeEngine> {
        &self.shared.engine
    }

    /// Bytes per SUBMIT frame (`T * R`).
    pub fn frame_len(&self) -> usize {
        self.shared.frame_len
    }

    /// Result words per frame (`ceil(D / 32)`).
    pub fn words_per_pb(&self) -> usize {
        self.shared.words_per_pb
    }

    /// The full QoS report behind the STATS verb: per-stream counters
    /// plus totals that sum exactly over the streams, the recovery
    /// counters, and the active fault plan (when any).
    pub fn stats_json(&self) -> crate::json::Json {
        use crate::json::Json;
        let st = lock_state(&self.shared);
        let mut streams = Json::obj();
        let (mut frames, mut bits, mut busy) = (0u64, 0u64, 0u64);
        for (id, e) in &st.streams {
            frames += e.qos.frames();
            bits += e.qos.bits();
            busy += e.qos.busy_ns();
            let mut o = e.qos.to_json();
            o.set("pending", Json::from(e.queue.len()));
            o.set("in_flight", Json::from(e.in_flight));
            o.set("queue_depth", Json::from(self.shared.queue_depth));
            o.set("evicted", Json::from(e.evicted.is_some()));
            o.set("parked", Json::from(e.parked));
            o.set("binding", Json::from(e.binding as usize));
            o.set("replay", Json::from(e.replay.len()));
            o.set("next_expected", Json::from(e.next_expected as usize));
            streams.set(&id.to_string(), o);
        }
        drop(st);
        let mut totals = Json::obj();
        totals.set("frames", Json::from(frames as usize));
        totals.set("bits", Json::from(bits as usize));
        totals.set("busy_ns", Json::from(busy as usize));
        totals.set("evictions", Json::from(self.evictions() as usize));
        totals.set("coalesce", self.shared.coalesce_stats.to_json());
        totals.set(
            "pool",
            match self.shared.engine.worker_snapshot() {
                Some(s) => s.to_json(),
                None => Json::Null,
            },
        );
        match self.shared.min_margin.load(Ordering::Relaxed) {
            u64::MAX => totals.set("min_margin", Json::Null),
            m => totals.set("min_margin", Json::from(m as usize)),
        }
        let mut out = Json::obj();
        out.set("engine", Json::from(self.shared.engine.name()));
        out.set("batch", Json::from(self.shared.batch));
        out.set("streams", streams);
        out.set("totals", totals);
        out.set("recovery", self.shared.recovery.to_json());
        out.set("integrity", self.shared.integrity.to_json());
        out.set(
            "faults",
            match &self.shared.faults {
                Some(p) => p.to_json(),
                None => Json::Null,
            },
        );
        out
    }

    /// Stop the batcher and fail any blocked submitters.  Idempotent;
    /// also run by `Drop`.
    pub fn shutdown(&self) {
        let mut st = lock_state(&self.shared);
        st.shutdown = true;
        drop(st);
        self.shared.work_cv.notify_all();
        self.shared.space_cv.notify_all();
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

fn batcher_loop(sh: &Shared) {
    loop {
        let mut st = lock_state(sh);
        while st.pending_total == 0 && !st.shutdown {
            st = sh.work_cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        if st.shutdown {
            return;
        }
        // Coalesce: hold for a full group, but never past the oldest
        // frame's flush deadline.
        while st.pending_total > 0 && st.pending_total < sh.batch && !st.shutdown {
            let oldest = st
                .streams
                .values()
                .filter_map(|e| e.queue.front().map(|p| p.enqueued))
                .min();
            let Some(oldest) = oldest else { break };
            let wait = (oldest + sh.coalesce).saturating_duration_since(Instant::now());
            if wait.is_zero() {
                break;
            }
            let (g, _) = sh
                .work_cv
                .wait_timeout(st, wait)
                .unwrap_or_else(PoisonError::into_inner);
            st = g;
        }
        if st.shutdown {
            return;
        }
        if st.pending_total == 0 {
            continue; // drained by an eviction while we coalesced
        }

        // Draft round-robin, one frame per stream per pass, so no
        // stream can monopolize a group.
        let s = &mut *st;
        let order: Vec<u64> = s
            .streams
            .iter()
            .filter(|(_, e)| !e.queue.is_empty())
            .map(|(id, _)| *id)
            .collect();
        let mut slots: Vec<Slot> = Vec::with_capacity(sh.batch);
        'draft: loop {
            let mut took = false;
            for id in &order {
                let Some(entry) = s.streams.get_mut(id) else {
                    continue; // drafted id raced a removal; skip it
                };
                if let Some(p) = entry.queue.pop_front() {
                    took = true;
                    slots.push(Slot {
                        stream: *id,
                        seq: p.seq,
                        enqueued: p.enqueued,
                        llr: p.llr,
                    });
                    if slots.len() == sh.batch {
                        break 'draft;
                    }
                }
            }
            if !took {
                break;
            }
        }
        s.pending_total -= slots.len();
        drop(st);

        let used = slots.len();
        let distinct = slots.iter().map(|x| x.stream).collect::<BTreeSet<_>>().len();
        sh.coalesce_stats
            .record_group(used as u64, sh.batch as u64, distinct as u64);

        // Assemble the group buffer (zero-padded tail lanes decode to
        // garbage we never deliver) and dispatch shared, same as the
        // stream coordinator's zero-copy path.
        let mut buf: Arc<[i8]> = std::iter::repeat(0i8)
            .take(sh.batch * sh.frame_len)
            .collect();
        if let Some(dst) = Arc::get_mut(&mut buf) {
            for (i, slot) in slots.iter().enumerate() {
                dst[i * sh.frame_len..(i + 1) * sh.frame_len].copy_from_slice(&slot.llr);
            }
        }
        // the bare-scheduler fault seam (the daemon's seam lives in
        // the supervisor; see the module docs)
        let outcome = match sh.faults.as_ref().and_then(|p| p.on_dispatch()) {
            Some(msg) => Err(anyhow::anyhow!(msg)),
            None => sh.engine.decode_batch_shared(&buf),
        };
        let now = Instant::now();

        match outcome {
            Ok((words, timings)) => {
                // Decode confidence: fold the real (non-padding) slots'
                // path-metric margins into the fleet-level floor.  CPU
                // engines report one margin per PB; PJRT groups leave
                // the vector empty and skip this.
                if let Some(&m) = timings.margins.iter().take(used).min() {
                    sh.min_margin.fetch_min(u64::from(m), Ordering::Relaxed);
                }
                // Exact attribution: pool busy time when the engine
                // shards work, else the single-thread phase total;
                // split so per-frame shares sum to the group total.
                let busy_ns = timings
                    .per_worker
                    .as_ref()
                    .map(|w| w.total_busy().as_nanos() as u64)
                    .unwrap_or_else(|| timings.total().as_nanos() as u64);
                let base = busy_ns / used as u64;
                let extra = (busy_ns % used as u64) as usize;
                let wpp = sh.words_per_pb;
                let mut st = lock_state(sh);
                for (i, slot) in slots.iter().enumerate() {
                    let Some(entry) = st.streams.get_mut(&slot.stream) else {
                        continue;
                    };
                    if entry.evicted.is_some() {
                        continue;
                    }
                    entry.qos.record_frame(
                        now.saturating_duration_since(slot.enqueued),
                        sh.bits_per_frame,
                        base + u64::from(i < extra),
                    );
                    let result = Ok(words[i * wpp..(i + 1) * wpp].to_vec());
                    // buffer first, deliver second: a result is
                    // replayable until a successful write acks it
                    entry.replay.push_back((slot.seq, result.clone()));
                    if let Some(deliver) = &entry.deliver {
                        deliver(slot.seq, result);
                    }
                }
            }
            Err(e) => {
                // A dispatch failure (e.g. the pool reporting a worker
                // panic) fails the affected frames, not the daemon.
                let msg = format!("{e:#}");
                let mut st = lock_state(sh);
                for slot in &slots {
                    let Some(entry) = st.streams.get_mut(&slot.stream) else {
                        continue;
                    };
                    if entry.evicted.is_some() {
                        continue;
                    }
                    let result = Err(ServeError::Engine(msg.clone()));
                    entry.replay.push_back((slot.seq, result.clone()));
                    if let Some(deliver) = &entry.deliver {
                        deliver(slot.seq, result);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatchTimings, CpuEngine};
    use crate::testutil::gen_noisy_stream;
    use crate::trellis::Trellis;
    use crate::channel::unpack_bits;
    use crate::viterbi::CpuPbvdDecoder;
    use std::sync::mpsc;

    const BLOCK: usize = 32;
    const DEPTH: usize = 15;

    fn engine(batch: usize) -> Arc<dyn DecodeEngine> {
        let t = Trellis::preset("k3").unwrap();
        Arc::new(CpuEngine::new(&t, batch, BLOCK, DEPTH))
    }

    /// Per-PB frames (batch=1 framing) of a noisy stream, as owned
    /// vectors, plus the golden decode of the same stream.
    fn frames_and_golden(n_bits: usize, seed: u64) -> (Vec<Vec<i8>>, Vec<u8>) {
        let t = Trellis::preset("k3").unwrap();
        let (_, llr) = gen_noisy_stream(&t, n_bits, 4.0, seed);
        let frames: Vec<Vec<i8>> = crate::coordinator::frame_stream(&llr, t.r, BLOCK, DEPTH, 1)
            .into_iter()
            .map(|f| f.llr_i8.to_vec())
            .collect();
        let golden = CpuPbvdDecoder::new(&t, BLOCK, DEPTH).decode_stream(&llr);
        (frames, golden)
    }

    fn channel_deliver() -> (Deliver, mpsc::Receiver<(u32, Result<Vec<u32>, ServeError>)>) {
        let (tx, rx) = mpsc::channel();
        (
            Box::new(move |seq, res| {
                let _ = tx.send((seq, res));
            }),
            rx,
        )
    }

    /// Drive one stream's frames through the scheduler (acking as
    /// results come back) and reassemble its payload bits.
    fn run_stream(
        sched: &Scheduler,
        id: u64,
        frames: &[Vec<i8>],
        rx: &mpsc::Receiver<(u32, Result<Vec<u32>, ServeError>)>,
        n_bits: usize,
    ) -> Vec<u8> {
        for (i, f) in frames.iter().enumerate() {
            sched.submit(id, 0, i as u32, f.clone()).unwrap();
        }
        let mut out = vec![0u8; n_bits];
        for _ in 0..frames.len() {
            let (seq, res) = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            sched.ack(id, 0, seq);
            let words = res.unwrap();
            let bits = unpack_bits(&words, BLOCK);
            let start = seq as usize * BLOCK;
            let take = BLOCK.min(n_bits - start);
            out[start..start + take].copy_from_slice(&bits[..take]);
        }
        out
    }

    #[test]
    fn coalesces_two_streams_into_one_mixed_group_bit_identically() {
        let sched = Scheduler::new(engine(8), 32, Duration::from_millis(100));
        let n_bits = 5 * BLOCK;
        let (fa, ga) = frames_and_golden(n_bits, 0xA);
        let (fb, gb) = frames_and_golden(n_bits, 0xB);
        let (da, rxa) = channel_deliver();
        let (db, rxb) = channel_deliver();
        let ia = sched.register(da);
        let ib = sched.register(db);
        // submit everything before the first flush deadline: 10
        // pending frames over two streams against an 8-slot group
        for (i, f) in fa.iter().enumerate() {
            sched.submit(ia, 0, i as u32, f.clone()).unwrap();
        }
        for (i, f) in fb.iter().enumerate() {
            sched.submit(ib, 0, i as u32, f.clone()).unwrap();
        }
        let collect = |id: u64, rx: &mpsc::Receiver<(u32, Result<Vec<u32>, ServeError>)>| {
            let mut out = vec![0u8; n_bits];
            for _ in 0..5 {
                let (seq, res) = rx.recv_timeout(Duration::from_secs(10)).unwrap();
                sched.ack(id, 0, seq);
                let bits = unpack_bits(&res.unwrap(), BLOCK);
                let start = seq as usize * BLOCK;
                let take = BLOCK.min(n_bits - start);
                out[start..start + take].copy_from_slice(&bits[..take]);
            }
            out
        };
        assert_eq!(collect(ia, &rxa), ga, "stream A diverged from golden");
        assert_eq!(collect(ib, &rxb), gb, "stream B diverged from golden");
        let cs = sched.coalesce_stats();
        assert!(cs.mixed_groups() >= 1, "no group mixed the two streams");
        // per-stream totals sum to the report totals
        let stats = sched.stats_json();
        let totals = stats.get("totals").unwrap();
        let sum: u64 = [ia, ib]
            .iter()
            .map(|id| sched.qos(*id).unwrap().frames())
            .sum();
        assert_eq!(sum, 10);
        assert_eq!(
            totals.get("frames").and_then(crate::json::Json::as_usize),
            Some(10)
        );
    }

    #[test]
    fn flush_deadline_dispatches_a_partial_group() {
        let sched = Scheduler::new(engine(8), 32, Duration::from_millis(20));
        let n_bits = 3 * BLOCK; // 3 frames < the 8-slot group
        let (frames, golden) = frames_and_golden(n_bits, 0xC);
        let (d, rx) = channel_deliver();
        let id = sched.register(d);
        let got = run_stream(&sched, id, &frames, &rx, n_bits);
        assert_eq!(got, golden);
        let cs = sched.coalesce_stats();
        assert!(cs.groups() >= 1);
        assert!(cs.fill_ratio() < 1.0, "partial group must lower fill");
        assert_eq!(cs.mixed_groups(), 0);
    }

    #[test]
    fn unacked_window_blocks_submit_until_ack() {
        let sched = Arc::new(Scheduler::new(engine(4), 2, Duration::ZERO));
        let (frames, _) = frames_and_golden(3 * BLOCK, 0xD);
        let (d, rx) = channel_deliver();
        let id = sched.register(d);
        sched.submit(id, 0, 0, frames[0].clone()).unwrap();
        sched.submit(id, 0, 1, frames[1].clone()).unwrap();
        // window full (2 unacked): the third submit must block even
        // after the first two were dispatched and delivered
        let (done_tx, done_rx) = mpsc::channel();
        let s2 = Arc::clone(&sched);
        let f2 = frames[2].clone();
        let h = std::thread::spawn(move || {
            let r = s2.submit(id, 0, 2, f2);
            done_tx.send(()).unwrap();
            r
        });
        assert!(
            done_rx.recv_timeout(Duration::from_millis(200)).is_err(),
            "submit must block while the window is full"
        );
        let (seq, _) = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        sched.ack(id, 0, seq);
        done_rx
            .recv_timeout(Duration::from_secs(5))
            .expect("ack must unblock the submitter");
        h.join().unwrap().unwrap();
    }

    #[test]
    fn eviction_interrupts_a_blocked_submit_and_counts() {
        let sched = Arc::new(Scheduler::new(engine(4), 1, Duration::from_millis(5)));
        let (frames, _) = frames_and_golden(2 * BLOCK, 0xE);
        let (d, _rx) = channel_deliver();
        let id = sched.register(d);
        sched.submit(id, 0, 0, frames[0].clone()).unwrap();
        let s2 = Arc::clone(&sched);
        let f1 = frames[1].clone();
        let h = std::thread::spawn(move || s2.submit(id, 0, 1, f1));
        std::thread::sleep(Duration::from_millis(50));
        sched.retire(id, "stalled for test", true);
        let err = h.join().unwrap().unwrap_err();
        assert!(matches!(err, ServeError::Evicted { .. }), "{err:?}");
        assert_eq!(sched.evictions(), 1);
        // double retire stays counted once
        sched.retire(id, "again", true);
        assert_eq!(sched.evictions(), 1);
        // and a later submit fails fast with the original reason
        let err = sched.submit(id, 0, 2, frames[0].clone()).unwrap_err();
        assert!(err.to_string().contains("stalled for test"), "{err}");
    }

    #[test]
    fn wrong_frame_length_is_rejected_up_front() {
        let sched = Scheduler::new(engine(4), 4, Duration::ZERO);
        let (d, _rx) = channel_deliver();
        let id = sched.register(d);
        let err = sched.submit(id, 0, 0, vec![0i8; 3]).unwrap_err();
        assert_eq!(
            err,
            ServeError::BadFrameLen {
                got: 3,
                want: sched.frame_len()
            }
        );
    }

    /// An engine whose dispatch always fails — the shape of the pool's
    /// "decode worker exited before replying" error path.
    struct FailingEngine {
        inner: Arc<dyn DecodeEngine>,
    }
    impl DecodeEngine for FailingEngine {
        fn decode_batch(&self, _llr: &[i8]) -> anyhow::Result<(Vec<u32>, BatchTimings)> {
            anyhow::bail!("decode worker exited before replying")
        }
        fn batch(&self) -> usize {
            self.inner.batch()
        }
        fn block(&self) -> usize {
            self.inner.block()
        }
        fn depth(&self) -> usize {
            self.inner.depth()
        }
        fn r(&self) -> usize {
            self.inner.r()
        }
        fn name(&self) -> String {
            "failing".into()
        }
    }

    #[test]
    fn engine_failure_is_delivered_typed_and_the_scheduler_survives() {
        let sched = Scheduler::new(
            Arc::new(FailingEngine { inner: engine(4) }),
            4,
            Duration::ZERO,
        );
        let (frames, _) = frames_and_golden(2 * BLOCK, 0xF);
        let (d, rx) = channel_deliver();
        let id = sched.register(d);
        for round in 0..2u32 {
            sched
                .submit(id, 0, round, frames[round as usize].clone())
                .unwrap();
            let (seq, res) = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            sched.ack(id, 0, seq);
            assert_eq!(seq, round);
            let err = res.unwrap_err();
            assert!(matches!(err, ServeError::Engine(_)), "{err:?}");
            assert!(err.to_string().contains("worker exited"), "{err}");
        }
        // failures do not pollute QoS frame counts
        assert_eq!(sched.qos(id).unwrap().frames(), 0);
    }

    #[test]
    fn shutdown_fails_blocked_submitters_and_drop_joins() {
        let sched = Arc::new(Scheduler::new(engine(4), 1, Duration::from_secs(5)));
        let (frames, _) = frames_and_golden(2 * BLOCK, 0x10);
        let (d, _rx) = channel_deliver();
        let id = sched.register(d);
        sched.submit(id, 0, 0, frames[0].clone()).unwrap();
        let s2 = Arc::clone(&sched);
        let f1 = frames[1].clone();
        let h = std::thread::spawn(move || s2.submit(id, 0, 1, f1));
        std::thread::sleep(Duration::from_millis(30));
        sched.shutdown();
        assert_eq!(h.join().unwrap().unwrap_err(), ServeError::Shutdown);
    }

    #[test]
    fn park_and_rebind_replays_the_unacked_window_exactly_once() {
        let sched = Scheduler::new(engine(4), 8, Duration::ZERO);
        let n_bits = 4 * BLOCK;
        let (frames, golden) = frames_and_golden(n_bits, 0x11);
        let (d, rx) = channel_deliver();
        let id = sched.register(d);
        for (i, f) in frames.iter().enumerate() {
            sched.submit(id, 0, i as u32, f.clone()).unwrap();
        }
        // the dead connection wrote (and acked) seq 0, received 1-3
        // but never wrote them, then died
        let mut first = None;
        for _ in 0..frames.len() {
            let (seq, res) = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            if seq == 0 {
                sched.ack(id, 0, 0);
                first = Some(res.unwrap());
            }
        }
        assert!(sched.park(id, 0), "live stream at binding 0 must park");
        assert!(!sched.park(id, 0), "double park is refused");
        // zombie submits from the old binding die typed
        let err = sched.submit(id, 0, 9, frames[0].clone()).unwrap_err();
        assert!(matches!(err, ServeError::Io(_)), "{err:?}");

        // replacement connection: client has seq 0, needs 1
        let (d2, rx2) = channel_deliver();
        let (binding, next_expected) = sched.rebind(id, 1, d2).unwrap();
        assert_eq!(binding, 1);
        assert_eq!(next_expected, 4, "all four frames were accepted");
        // a stale park / release from the superseded binding must be
        // ignored — the zombie connection cannot kill the resume
        assert!(!sched.park(id, 0), "stale binding cannot park the resume");
        assert!(
            !sched.release(id, 0, "zombie teardown", false),
            "stale binding cannot retire the resume"
        );

        // seqs 1..=3 replay, in order, exactly once
        let mut words = vec![first.expect("seq 0 was received pre-park")];
        for want in 1..4u32 {
            let (seq, res) = rx2.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(seq, want, "replay must preserve seq order");
            sched.ack(id, binding, seq);
            words.push(res.unwrap());
        }
        assert!(
            rx2.recv_timeout(Duration::from_millis(100)).is_err(),
            "nothing may be replayed twice"
        );
        // stale acks from the old writer are ignored (no underflow)
        sched.ack(id, 0, 2);

        let mut out = vec![0u8; n_bits];
        for (seq, w) in words.iter().enumerate() {
            let bits = unpack_bits(w, BLOCK);
            out[seq * BLOCK..(seq + 1) * BLOCK].copy_from_slice(&bits[..BLOCK]);
        }
        assert_eq!(out, golden, "resumed stream diverged from golden");
        let rec = sched.recovery();
        assert_eq!(rec.parked(), 1);
        assert_eq!(rec.resumes(), 1);
        assert_eq!(rec.replayed(), 3);
    }

    #[test]
    fn rebind_reserves_recently_acked_results_for_undrained_sockets() {
        // the TCP-buffer race: the server wrote + acked seq 0 but the
        // peer never drained it; resume with next_needed=0 re-serves
        // it from the acked tail
        let sched = Scheduler::new(engine(4), 4, Duration::ZERO);
        let (frames, _) = frames_and_golden(BLOCK, 0x12);
        let (d, rx) = channel_deliver();
        let id = sched.register(d);
        sched.submit(id, 0, 0, frames[0].clone()).unwrap();
        let (_, res) = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        let expect = res.unwrap();
        sched.ack(id, 0, 0);
        assert!(sched.park(id, 0));
        let (d2, rx2) = channel_deliver();
        let (binding, _) = sched.rebind(id, 0, d2).unwrap();
        let (seq, res) = rx2.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(seq, 0);
        assert_eq!(res.unwrap(), expect);
        sched.ack(id, binding, 0);
        // and a resume naming an unknown stream is a typed refusal
        let unknown = sched.rebind(99, 0, channel_deliver().0).unwrap_err();
        assert!(matches!(unknown, ServeError::BadResume(_)), "{unknown:?}");
    }

    #[test]
    fn saturated_scheduler_sheds_with_a_typed_retry_hint() {
        // batch 4 + a long coalesce hold frames pending; shed_queue=2
        // refuses the third submit instead of blocking
        let sched = Scheduler::with_options(
            engine(4),
            8,
            Duration::from_secs(5),
            SchedulerOptions {
                shed_queue: 2,
                ..SchedulerOptions::default()
            },
        );
        let (frames, _) = frames_and_golden(3 * BLOCK, 0x13);
        let (d, _rx) = channel_deliver();
        let id = sched.register(d);
        sched.submit(id, 0, 0, frames[0].clone()).unwrap();
        sched.submit(id, 0, 1, frames[1].clone()).unwrap();
        let err = sched.submit(id, 0, 2, frames[2].clone()).unwrap_err();
        let ServeError::RetryAfter { ms } = err else {
            panic!("want RetryAfter, got {err:?}");
        };
        assert!((25..=1000).contains(&ms), "hint out of range: {ms}");
        assert_eq!(sched.recovery().shed(), 1);
        let j = sched.stats_json();
        assert_eq!(
            j.path(&["recovery", "shed"]).and_then(crate::json::Json::as_usize),
            Some(1)
        );
    }

    #[test]
    fn dispatch_fault_seam_fails_one_group_then_recovers() {
        let sched = Scheduler::with_options(
            engine(1),
            4,
            Duration::ZERO,
            SchedulerOptions {
                faults: Some(Arc::new(
                    FaultPlan::parse("dispatch_err@group=0").unwrap(),
                )),
                ..SchedulerOptions::default()
            },
        );
        let (frames, _) = frames_and_golden(2 * BLOCK, 0x14);
        let (d, rx) = channel_deliver();
        let id = sched.register(d);
        sched.submit(id, 0, 0, frames[0].clone()).unwrap();
        let (seq, res) = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        sched.ack(id, 0, seq);
        let err = res.unwrap_err();
        assert!(err.to_string().contains("injected"), "{err}");
        // the fault latched; the next group decodes normally
        sched.submit(id, 0, 1, frames[1].clone()).unwrap();
        let (_, res) = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(res.is_ok(), "{res:?}");
        let j = sched.stats_json();
        assert_eq!(
            j.path(&["faults", "injected"]).and_then(crate::json::Json::as_usize),
            Some(1)
        );
    }
}
