//! `pbvd` — the PBVD coordinator CLI (leader entrypoint).
//!
//! Commands map 1:1 to the paper's experiments (DESIGN.md §4):
//! `table1`/`table2` print the structural tables, `fig4` runs the BER
//! sweep, `table3` measures kernel/transfer timing and throughput for
//! the original vs optimized decoder, `table4` produces the TNDC
//! comparison, and `stream` is an end-to-end SDR-style demo.

use anyhow::{anyhow, bail, Result};
use pbvd::bench::{ms, Bench, Table};
use pbvd::ber::{measure_ber, uncoded_bpsk_ber, BerConfig};
use pbvd::channel::{AwgnChannel, Quantizer};
use pbvd::cli::{usage, Args, OptSpec};
use pbvd::config::{DecoderConfig, EngineKind, PjrtVariant};
use pbvd::coordinator::{DecodeEngine, StreamCoordinator};
use pbvd::encoder::ConvEncoder;
use pbvd::perfmodel::{
    pcie_bandwidth_bytes, tndc, ThroughputModel, TABLE4_PRIOR, TABLE4_THIS_WORK,
};
use pbvd::rng::Xoshiro256;
use pbvd::runtime::Registry;
use pbvd::serve::PbvdServer;
use pbvd::trellis::Trellis;
use pbvd::viterbi::CpuPbvdDecoder;
use std::sync::Arc;

const COMMANDS: &[(&str, &str)] = &[
    ("info", "artifact registry + platform summary"),
    ("table1", "thread-geometry table (paper Table I)"),
    ("table2", "state classification table (paper Table II)"),
    ("fig4", "BER vs Eb/N0 for several L (paper Fig. 4)"),
    ("table3", "timing + throughput, original vs optimized (Table III)"),
    ("table4", "TNDC comparison with prior works (Table IV)"),
    ("stream", "end-to-end stream decode demo with stats"),
    ("serve", "multi-stream decode daemon (cross-stream lane-group coalescing)"),
    ("scale", "worker-scaling ladder for the sharded CPU backend"),
    ("plan", "adaptive-dispatch planner: history provenance + per-arm estimates"),
    ("ber", "single BER sweep for one decoder config"),
    ("model", "eq. (7) analytic throughput projection"),
];

fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "code", help: "code preset", default: Some("ccsds_k7"), is_flag: false },
        OptSpec { name: "engine", help: "auto | cpu | par | simd | two | fused | orig", default: Some("auto"), is_flag: false },
        OptSpec { name: "metric-width", help: "SIMD path-metric width: auto (calibrated) | 16 | 32", default: Some("auto"), is_flag: false },
        OptSpec { name: "simd-backend", help: "SIMD ACS backend: auto | scalar | portable | avx2 | neon (checked fallback)", default: Some("auto"), is_flag: false },
        OptSpec { name: "workers", help: "CPU decode workers for par/simd engines (0 = all cores); list for scale", default: Some("0"), is_flag: false },
        OptSpec { name: "batch", help: "PBs per executable call (N_t)", default: Some("32"), is_flag: false },
        OptSpec { name: "block", help: "decode block D", default: Some("64"), is_flag: false },
        OptSpec { name: "depth", help: "decoding depth L", default: Some("42"), is_flag: false },
        OptSpec { name: "lanes", help: "pipeline lanes (N_s streams)", default: Some("3"), is_flag: false },
        OptSpec { name: "bits", help: "payload bits for stream/ber", default: Some("200000"), is_flag: false },
        OptSpec { name: "ebn0", help: "Eb/N0 list in dB (comma)", default: Some("0,1,2,3,4,5,6"), is_flag: false },
        OptSpec { name: "depths", help: "L list for fig4 (comma)", default: Some("7,14,21,28,42,63"), is_flag: false },
        OptSpec { name: "errors", help: "target error count per BER point", default: Some("100"), is_flag: false },
        OptSpec { name: "max-bits", help: "max bits per BER point", default: Some("2000000"), is_flag: false },
        OptSpec { name: "threads", help: "BER worker threads", default: Some("8"), is_flag: false },
        OptSpec { name: "seed", help: "RNG seed", default: Some("2016"), is_flag: false },
        OptSpec { name: "nbl", help: "threadblock count for table1", default: Some("64"), is_flag: false },
        OptSpec { name: "q", help: "quantizer bits", default: Some("8"), is_flag: false },
        OptSpec { name: "bind", help: "serve: listen address (host:port, 0 port = OS-assigned)", default: None, is_flag: false },
        OptSpec { name: "max-streams", help: "serve: concurrent client stream cap", default: None, is_flag: false },
        OptSpec { name: "stream-queue", help: "serve: per-stream unacked-frame bound (backpressure)", default: None, is_flag: false },
        OptSpec { name: "coalesce-us", help: "serve: partial-group flush deadline in microseconds", default: None, is_flag: false },
        OptSpec { name: "stall-ms", help: "serve: evict a client after this much inactivity", default: None, is_flag: false },
        OptSpec { name: "faults", help: "serve: deterministic fault-injection spec (e.g. drop_write@seq=7;worker_panic@job=3)", default: None, is_flag: false },
        OptSpec { name: "shed-queue", help: "serve: shed submits once total pending frames reach N (0 = off)", default: None, is_flag: false },
        OptSpec { name: "resume-grace-ms", help: "serve: hold lost streams for RESUME this long (0 = resume off)", default: None, is_flag: false },
        OptSpec { name: "audit-ppm", help: "shadow-audit sample rate, blocks per million (0 = off; default 10000 when audit is on)", default: None, is_flag: false },
        OptSpec { name: "audit-seed", help: "shadow-audit sampling seed (replayable)", default: None, is_flag: false },
        OptSpec { name: "audit-quarantine", help: "quarantine a backend the audit catches diverging: true | false", default: None, is_flag: false },
        OptSpec { name: "audit-low-margin", help: "count decodes whose path-metric margin is below this floor", default: None, is_flag: false },
        OptSpec { name: "duration", help: "serve: run for N seconds then exit (0 = forever)", default: Some("0"), is_flag: false },
        OptSpec { name: "plan", help: "enable adaptive engine dispatch (history-driven Auto policy)", default: None, is_flag: true },
        OptSpec { name: "perf-history", help: "performance-history JSONL path (also PBVD_PERF_HISTORY)", default: None, is_flag: false },
        OptSpec { name: "plan-reeval", help: "re-evaluate the dispatch every N groups (0 = never migrate)", default: None, is_flag: false },
        OptSpec { name: "plan-explore-ppm", help: "epsilon-explore rate, picks per million (0 = off)", default: None, is_flag: false },
        OptSpec { name: "quick", help: "reduced iteration counts", default: None, is_flag: true },
        OptSpec { name: "cpu-only", help: "skip PJRT engines", default: None, is_flag: true },
    ]
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &specs()).map_err(|e| anyhow!("{e}"))?;
    match args.command.as_deref() {
        Some("info") => cmd_info(&args),
        Some("table1") => cmd_table1(&args),
        Some("table2") => cmd_table2(&args),
        Some("fig4") => cmd_fig4(&args),
        Some("table3") => cmd_table3(&args),
        Some("table4") => cmd_table4(&args),
        Some("stream") => cmd_stream(&args),
        Some("serve") => cmd_serve(&args),
        Some("scale") => cmd_scale(&args),
        Some("plan") => cmd_plan(&args),
        Some("ber") => cmd_ber(&args),
        Some("model") => cmd_model(&args),
        Some(other) => bail!("unknown command {other:?}\n{}", usage("pbvd", COMMANDS, &specs())),
        None => {
            print!("{}", usage("pbvd", COMMANDS, &specs()));
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------------
// Configuration construction (the one CLI -> DecoderConfig mapping).
// ---------------------------------------------------------------------------

/// Map the CLI arguments onto a validated [`DecoderConfig`] —
/// everything except `--workers`, which the `scale` command treats as
/// a comma-separated ladder instead of a single count.  All option
/// parsing is the library's `FromStr` impls; env overrides
/// (`PBVD_SIMD_BACKEND`, `PBVD_METRIC_WIDTH`) are applied by the
/// factory with CLI > env > auto precedence.
fn base_config(args: &Args) -> Result<DecoderConfig> {
    let mut cfg = DecoderConfig::new(&args.str_or("code", "ccsds_k7"))
        .batch(args.usize_or("batch", 32)?)
        .block(args.usize_or("block", 64)?)
        .depth(args.usize_or("depth", 42)?)
        .lanes(args.usize_or("lanes", 3)?)
        .engine(args.str_or("engine", "auto").parse()?)
        .width(args.str_or("metric-width", "auto").parse()?)
        .backend(args.str_or("simd-backend", "auto").parse()?)
        .q(u32::try_from(args.usize_or("q", 8)?)
            .map_err(|_| anyhow!("--q out of range for u32"))?);
    // serve section: only explicitly-passed flags count as CLI values,
    // so unset fields still pick up PBVD_SERVE_* env (then defaults)
    // inside the factory's single resolution pass
    if let Some(bind) = args.get("bind") {
        cfg = cfg.serve_bind(bind);
    }
    if args.get("max-streams").is_some() {
        cfg = cfg.max_streams(args.usize_or("max-streams", 0)?);
    }
    if args.get("stream-queue").is_some() {
        cfg = cfg.stream_queue(args.usize_or("stream-queue", 0)?);
    }
    if args.get("coalesce-us").is_some() {
        cfg = cfg.coalesce_window_us(args.u64_or("coalesce-us", 0)?);
    }
    if args.get("stall-ms").is_some() {
        cfg = cfg.stall_timeout_ms(args.u64_or("stall-ms", 0)?);
    }
    if let Some(spec) = args.get("faults") {
        cfg = cfg.faults(spec);
    }
    if args.get("shed-queue").is_some() {
        cfg = cfg.shed_queue(args.usize_or("shed-queue", 0)?);
    }
    if args.get("resume-grace-ms").is_some() {
        cfg = cfg.resume_grace_ms(args.u64_or("resume-grace-ms", 0)?);
    }
    // audit section: same explicit-only rule (unset falls through to
    // PBVD_AUDIT_* env, then the defaults)
    if args.get("audit-ppm").is_some() {
        cfg = cfg.audit_ppm(u32::try_from(args.usize_or("audit-ppm", 0)?)
            .map_err(|_| anyhow!("--audit-ppm out of range for u32"))?);
    }
    if args.get("audit-seed").is_some() {
        cfg = cfg.audit_seed(args.u64_or("audit-seed", 0)?);
    }
    if let Some(v) = args.get("audit-quarantine") {
        cfg = cfg.audit_quarantine(match v {
            "1" | "true" | "on" => true,
            "0" | "false" | "off" => false,
            other => return Err(anyhow!("--audit-quarantine: expected true/false, got {other}")),
        });
    }
    if args.get("audit-low-margin").is_some() {
        cfg = cfg.audit_low_margin(u32::try_from(args.usize_or("audit-low-margin", 0)?)
            .map_err(|_| anyhow!("--audit-low-margin out of range for u32"))?);
    }
    // plan section: same explicit-only rule (unset falls through to
    // PBVD_PLAN / PBVD_PERF_HISTORY / ... env, then the defaults)
    if args.flag("plan") {
        cfg = cfg.plan_enabled(true);
    }
    if let Some(p) = args.get("perf-history") {
        cfg = cfg.perf_history(p);
    }
    if args.get("plan-reeval").is_some() {
        cfg = cfg.plan_reeval(args.usize_or("plan-reeval", 0)?);
    }
    if args.get("plan-explore-ppm").is_some() {
        cfg = cfg.plan_explore_ppm(u32::try_from(args.usize_or("plan-explore-ppm", 0)?)
            .map_err(|_| anyhow!("--plan-explore-ppm out of range for u32"))?);
    }
    cfg.validate()?;
    Ok(cfg)
}

/// [`base_config`] plus the scalar `--workers` count.
fn decoder_config(args: &Args) -> Result<DecoderConfig> {
    Ok(base_config(args)?.workers(args.usize_or("workers", 0)?))
}

fn open_registry() -> Option<Registry> {
    Registry::open_default().ok()
}

// ---------------------------------------------------------------------------
// Commands.
// ---------------------------------------------------------------------------

fn cmd_info(_args: &Args) -> Result<()> {
    println!("pbvd — Parallel Block-based Viterbi Decoder (Peng et al. 2016)");
    println!("three-layer stack: Pallas kernels -> JAX decode graphs -> rust coordinator\n");
    match open_registry() {
        Some(reg) => {
            println!("artifacts: {} ({} entries)", reg.dir.display(), reg.manifest.entries.len());
            let mut tab = Table::new(&["name", "variant", "code", "B", "D", "L"]);
            for e in &reg.manifest.entries {
                tab.row(&[
                    e.name.clone(), e.variant.clone(), e.code.clone(),
                    e.batch.to_string(), e.block.to_string(), e.depth.to_string(),
                ]);
            }
            print!("{}", tab.render());
        }
        None => println!("artifacts: NOT BUILT (run `make artifacts`)"),
    }
    println!("\ncodes:");
    for (name, k, polys) in pbvd::trellis::PRESETS {
        let t = Trellis::preset(name)?;
        let octal: Vec<String> = polys.iter().map(|p| format!("{p:o}")).collect();
        println!(
            "  {name:<10} K={k} R={} N={} N_c={} polys=[{}]",
            t.r, t.n_states, t.n_groups, octal.join(",")
        );
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let code = args.str_or("code", "ccsds_k7");
    let nbl = args.usize_or("nbl", 64)?;
    let t = Trellis::preset(&code)?;
    let g = t.table1(nbl);
    println!("Table I — thread dimensions & parallelism ({code}, N_bl = {nbl})\n");
    let mut tab = Table::new(&["Kernel", "BlockDim", "ThreadDim", "Inter-frame", "Intra-frame"]);
    tab.row(&["K1".into(), g.k1_block_dim.to_string(), g.k1_thread_dim.to_string(),
              g.inter_frame.to_string(), g.k1_intra_frame.to_string()]);
    tab.row(&["K2".into(), g.k2_block_dim.to_string(), g.k2_thread_dim.to_string(),
              g.inter_frame.to_string(), g.k2_intra_frame.to_string()]);
    print!("{}", tab.render());
    println!("\nRust-coordinator mapping: one PJRT batch = {} PBs; lanes model N_s streams.",
             g.n_parallel_blocks);
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let code = args.str_or("code", "ccsds_k7");
    let t = Trellis::preset(&code)?;
    println!(
        "Table II — classification of states for {code} (K={}, R={}, N={}, N_c={})\n",
        t.k, t.r, t.n_states, t.n_groups
    );
    let mut tab = Table::new(&["Group", "alpha", "beta", "gamma", "theta", "Index of states"]);
    for row in t.table2() {
        let states = row.states.iter().map(usize::to_string).collect::<Vec<_>>().join(", ");
        tab.row(&[
            row.group.to_string(),
            row.label_str(0, t.r), row.label_str(1, t.r),
            row.label_str(2, t.r), row.label_str(3, t.r),
            states,
        ]);
    }
    print!("{}", tab.render());
    let (grouped, statebased) = t.bm_ops_per_stage();
    println!("\nBM computations per stage: group-based 2^(R+2) = {grouped}, state-based 2^K = {statebased}");
    Ok(())
}

fn cmd_fig4(args: &Args) -> Result<()> {
    let code = args.str_or("code", "ccsds_k7");
    let t = Trellis::preset(&code)?;
    let depths = args.usize_list_or("depths", &[7, 14, 21, 28, 42, 63])?;
    let ebn0 = args.f64_list_or("ebn0", &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0])?;
    let quick = args.flag("quick");
    let cfg = BerConfig {
        bits_per_trial: 8192,
        target_errors: if quick { 40 } else { args.u64_or("errors", 100)? },
        max_bits: if quick { 300_000 } else { args.u64_or("max-bits", 2_000_000)? },
        q: args.usize_or("q", 8)? as u32,
        threads: args.usize_or("threads", 8)?,
        seed: args.u64_or("seed", 2016)?,
    };
    // paper: D = 512 fixed ("a less important factor"); CPU default 256
    let block = args.usize_or("block", 256)?;
    println!("Fig. 4 — BER of {code}, D={block}, {}-bit quantization", cfg.q);
    println!("(decoder: CPU PBVD golden model; identical decisions to the kernels)\n");
    let mut headers: Vec<String> = vec!["Eb/N0 dB".into(), "uncoded".into()];
    headers.extend(depths.iter().map(|l| format!("L={l}")));
    let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut tab = Table::new(&hdr_refs);
    let decs: Vec<CpuPbvdDecoder> = depths
        .iter()
        .map(|&l| CpuPbvdDecoder::new(&t, block, l))
        .collect();
    for &e in &ebn0 {
        let mut cells = vec![format!("{e:.1}"), format!("{:.2e}", uncoded_bpsk_ber(e))];
        for dec in &decs {
            let p = measure_ber(&t, dec, e, &cfg)?;
            cells.push(format!("{:.2e}", p.ber()));
        }
        tab.row(&cells);
        print!("{}", tab.render().lines().last().unwrap());
        println!();
    }
    println!("\n{}", tab.render());
    println!("expected shape: larger L -> lower BER, saturating near L = 42 (6K).");
    Ok(())
}

fn cmd_table3(args: &Args) -> Result<()> {
    let reg = open_registry()
        .ok_or_else(|| anyhow!("table3 needs artifacts; run `make artifacts`"))?;
    let code = args.str_or("code", "ccsds_k7");
    let block = args.usize_or("block", 512)?;
    let depth = args.usize_or("depth", 42)?;
    let quick = args.flag("quick");
    let t = Trellis::preset(&code)?;
    // batch ladder = the N_t sweep (scaled to CPU sizes)
    let batches: Vec<usize> = reg
        .manifest
        .entries
        .iter()
        .filter(|e| e.variant == "forward" && e.code == code && e.block == block && e.depth == depth)
        .map(|e| e.batch)
        .collect();
    if batches.is_empty() {
        bail!("no artifacts for {code} D={block} L={depth}");
    }
    println!("Table III — time consumption and throughput ({code}, D={block}, L={depth})");
    println!("(CPU-PJRT testbed; paper columns, ms and Mbps; 1S/3S = 1 or 3 lanes)\n");
    let mut tab = Table::new(&[
        "N_t", "orig T_k", "orig S_k", "orig T/P(1S)",
        "opt T_k1", "opt T_k2", "opt S_k", "opt T/P(1S)", "opt T/P(3S)",
    ]);
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let mut rng = Xoshiro256::seeded(args.u64_or("seed", 2016)?);
    let base = DecoderConfig::new(&code).block(block).depth(depth);
    for &batch in &batches {
        let n_bits = batch * block * if quick { 1 } else { 3 };
        let (_, llr) = gen_stream(&t, n_bits, 4.0, 8, &mut rng);
        // original decoder, 1 lane
        let orig = base
            .clone()
            .batch(batch)
            .engine(EngineKind::Pjrt(PjrtVariant::Orig))
            .build_engine_with(&t, Some(&reg))?;
        let (o_tk, o_sk, o_tp1, _) = measure_engine(&orig, &llr, 1, &bench)?;
        // optimized decoder
        let two = base
            .clone()
            .batch(batch)
            .engine(EngineKind::Pjrt(PjrtVariant::Two))
            .build_engine_with(&t, Some(&reg))?;
        let (t_k12, o2_sk, tp1, phases) = measure_engine(&two, &llr, 1, &bench)?;
        let (_, _, tp3, _) = measure_engine(&two, &llr, 3, &bench)?;
        let _ = t_k12;
        tab.row(&[
            batch.to_string(),
            format!("{:.2}", ms(o_tk)),
            format!("{o_sk:.1}"),
            format!("{o_tp1:.1}"),
            format!("{:.2}", ms(phases.0)),
            format!("{:.2}", ms(phases.1)),
            format!("{o2_sk:.1}"),
            format!("{tp1:.1}"),
            format!("{tp3:.1}"),
        ]);
    }
    print!("{}", tab.render());
    println!("\nshape checks: opt kernel time < orig; opt T/P > orig T/P; 3S >= 1S.");
    Ok(())
}

/// Time one engine over a stream; returns (kernel time per batch,
/// S_k Mbps, T/P Mbps, (k1, k2) per batch).
fn measure_engine(
    eng: &Arc<dyn DecodeEngine>,
    llr: &[i32],
    lanes: usize,
    bench: &Bench,
) -> Result<(std::time::Duration, f64, f64, (std::time::Duration, std::time::Duration))> {
    let coord = StreamCoordinator::new(Arc::clone(eng), lanes);
    let mut last: Option<pbvd::coordinator::StreamStats> = None;
    let stats = bench.run(|| {
        let (_, s) = coord.decode_stream(llr).expect("decode");
        last = Some(s);
    });
    let s = last.unwrap();
    let per_batch = |d: std::time::Duration| d / (s.n_batches as u32);
    let kernel = per_batch(s.phases.k1 + s.phases.k2);
    let sk = s.kernel_throughput_mbps();
    let tp = s.n_bits as f64 / stats.mean.as_secs_f64() / 1e6;
    Ok((kernel, sk, tp, (per_batch(s.phases.k1), per_batch(s.phases.k2))))
}

fn gen_stream(
    t: &Trellis,
    n_bits: usize,
    ebn0: f64,
    q: u32,
    rng: &mut Xoshiro256,
) -> (Vec<u8>, Vec<i32>) {
    let bits: Vec<u8> = (0..n_bits).map(|_| rng.next_bit()).collect();
    let mut enc = ConvEncoder::new(t);
    let coded = enc.encode(&bits);
    let mut ch = AwgnChannel::new(ebn0, 1.0 / t.r as f64, rng);
    let soft = ch.transmit(&coded);
    (bits, Quantizer::new(q).quantize(&soft))
}

fn cmd_table4(args: &Args) -> Result<()> {
    println!("Table IV — decoding throughput comparison (TNDC-normalized)\n");
    let mut tab = Table::new(&["Work", "Device", "T/P (Mbps)", "TNDC", "Speedup vs best"]);
    let best_tndc = TABLE4_THIS_WORK[1].paper_tndc;
    for w in TABLE4_PRIOR.iter().chain(TABLE4_THIS_WORK.iter()) {
        let t = tndc(w.throughput_mbps, w.cores, w.clock_mhz);
        tab.row(&[
            w.work.into(), w.device.into(),
            format!("{:.1}", w.throughput_mbps),
            format!("{t:.3} (paper {:.3})", w.paper_tndc),
            format!("x{:.2}", best_tndc / w.paper_tndc),
        ]);
    }
    // our measured row (CPU testbed)
    if let Some(reg) = open_registry() {
        let cfg = DecoderConfig::new(&args.str_or("code", "ccsds_k7"))
            .batch(args.usize_or("batch", 256)?)
            .block(args.usize_or("block", 512)?)
            .depth(args.usize_or("depth", 42)?)
            .engine(EngineKind::Pjrt(PjrtVariant::Two));
        let t = cfg.trellis()?;
        if let Ok(eng) = cfg.build_engine_with(&t, Some(&reg)) {
            let mut rng = Xoshiro256::seeded(7);
            let (_, llr) = gen_stream(&t, 256 * 512, 4.0, 8, &mut rng);
            let bench = if args.flag("quick") { Bench::quick() } else { Bench::default() };
            let (_, _, tp, _) = measure_engine(&eng, &llr, 3, &bench)?;
            let ncpu = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            tab.row(&[
                "this repo".into(),
                format!("CPU-PJRT x{ncpu}"),
                format!("{tp:.2}"),
                "n/a (different substrate)".into(),
                "-".into(),
            ]);
        }
    }
    print!("{}", tab.render());
    println!("\npaper headline: x1.53 TNDC speedup vs the fastest prior GPU work [10].");
    Ok(())
}

fn cmd_stream(args: &Args) -> Result<()> {
    let cfg = decoder_config(args)?;
    // --cpu-only skips the PJRT engines: the PJRT kinds are refused
    // and EngineKind::Auto resolves to the CPU worker policy — at the
    // SAME width/backend/q the CLI requested (the unified config makes
    // it impossible for a fallback path to drop those axes again)
    let reg = if args.flag("cpu-only") {
        if let EngineKind::Pjrt(_) = cfg.engine {
            bail!("--cpu-only excludes the PJRT engines (--engine {})", cfg.engine);
        }
        None
    } else {
        open_registry()
    };
    let t = cfg.trellis()?;
    let q = cfg.q;
    let lanes = cfg.lanes;
    let n_bits = args.usize_or("bits", 200_000)?;
    let ebn0 = args.f64_list_or("ebn0", &[4.0])?[0];
    let mut rng = Xoshiro256::seeded(args.u64_or("seed", 2016)?);
    let coord = cfg.build_coordinator(reg.as_ref())?;
    println!("stream demo: {} bits through {} (lanes={lanes}, Eb/N0={ebn0} dB, q={q})",
             n_bits, coord.engine.name());
    if let Some((dsp, _)) = &coord.plan {
        println!(
            "plan:       adaptive dispatch on — machine {}, {} history rows{}",
            dsp.machine(),
            dsp.history().len(),
            dsp.history()
                .path()
                .map(|p| format!(" from {}", p.display()))
                .unwrap_or_default()
        );
    }
    let (bits, llr) = gen_stream(&t, n_bits, ebn0, q, &mut rng);
    let (out, stats) = coord.decode_stream(&llr)?;
    let errors = out.iter().zip(&bits).filter(|(a, b)| a != b).count();
    println!("\ndecoded {} bits in {:.1} ms over {} batches", stats.n_bits,
             ms(stats.wall), stats.n_batches);
    println!("bit errors: {errors} (BER {:.2e})", errors as f64 / n_bits as f64);
    println!("throughput: {:.2} Mbps end-to-end, {:.2} Mbps kernel (S_k)",
             stats.throughput_mbps(), stats.kernel_throughput_mbps());
    println!("phase sums: pack {:.1} ms | K1 {:.1} ms | K2 {:.1} ms | unpack {:.1} ms",
             ms(stats.phases.pack), ms(stats.phases.k1), ms(stats.phases.k2),
             ms(stats.phases.unpack));
    println!("transfer:   H2D {} B, D2H {} B per stream", stats.phases.h2d_bytes,
             stats.phases.d2h_bytes);
    if let Some(pw) = &stats.per_worker {
        println!("pool:       {} (utilization {:.0}%)",
                 pw.summary(), 100.0 * pw.utilization(stats.wall));
    }
    // provenance: the exact resolved configuration plus the pool's
    // recorded width/backend, machine-readable
    let mut prov = cfg.resolved().to_json();
    if let Some(pw) = &stats.per_worker {
        prov.set("pool", pw.to_json());
    }
    if let Some((dsp, _)) = &coord.plan {
        let mut pj = dsp.stats().to_json();
        pj.set("machine", pbvd::json::Json::from(dsp.machine()));
        pj.set("history_rows", pbvd::json::Json::from(dsp.history().len()));
        if let Some(p) = dsp.history().path() {
            pj.set("history_path", pbvd::json::Json::from(p.display().to_string()));
        }
        prov.set("plan_runtime", pj);
    }
    println!("provenance: {prov}");
    Ok(())
}

/// `pbvd serve`: run the decode daemon until `--duration` elapses (or
/// forever), reporting QoS totals every 10 s.
fn cmd_serve(args: &Args) -> Result<()> {
    use std::time::{Duration, Instant};
    let cfg = decoder_config(args)?;
    let reg = if args.flag("cpu-only") {
        if let EngineKind::Pjrt(_) = cfg.engine {
            bail!("--cpu-only excludes the PJRT engines (--engine {})", cfg.engine);
        }
        None
    } else {
        open_registry()
    };
    let duration = args.u64_or("duration", 0)?;
    let server = PbvdServer::bind(&cfg, reg.as_ref())?;
    let rc = cfg.resolved();
    println!(
        "pbvd serve: listening on {} (engine {})",
        server.local_addr(),
        server.engine_name()
    );
    println!(
        "            max {} streams, {} unacked frames/stream, coalesce {} us, stall {} ms",
        rc.serve.max_streams_or_default(),
        rc.serve.queue_depth_or_default(),
        rc.serve.coalesce_window().as_micros(),
        rc.serve.stall_timeout().as_millis()
    );
    match rc.serve.resume_grace() {
        Some(grace) => println!(
            "            resume grace {} ms, shed queue {}",
            grace.as_millis(),
            match rc.serve.shed_queue_or_default() {
                0 => "off".to_string(),
                n => n.to_string(),
            }
        ),
        None => println!("            resume disabled"),
    }
    if server.plan_enabled() {
        println!(
            "            adaptive dispatch on: reeval every {} groups, explore {} ppm",
            rc.plan.reeval_batches_or_default(),
            rc.plan.explore_ppm_or_default()
        );
    }
    if let Some(plan) = server.fault_plan() {
        println!(
            "            FAULT INJECTION ACTIVE: {:?} (seed {:#x})",
            plan.spec(),
            plan.seed()
        );
    }
    let t0 = Instant::now();
    let mut last_report = Instant::now();
    loop {
        std::thread::sleep(Duration::from_millis(200));
        if duration > 0 && t0.elapsed() >= Duration::from_secs(duration) {
            break;
        }
        if last_report.elapsed() >= Duration::from_secs(10) {
            last_report = Instant::now();
            let stats = server.stats_json();
            let totals = stats.get("totals");
            let num = |k: &str| {
                totals
                    .and_then(|t| t.get(k))
                    .and_then(pbvd::json::Json::as_usize)
                    .unwrap_or(0)
            };
            let fill = totals
                .and_then(|t| t.path("coalesce.fill_ratio"))
                .and_then(pbvd::json::Json::as_f64)
                .unwrap_or(0.0);
            println!(
                "stats: sessions={} frames={} bits={} evictions={} group_fill={:.2}",
                server.active_sessions(),
                num("frames"),
                num("bits"),
                server.evictions(),
                fill
            );
            let rec = server.recovery();
            if rec.any() || server.parked_streams() > 0 {
                println!(
                    "recovery: engine={} retries={} degradations={} resumes={} parked={} replayed={} shed={}",
                    server.engine_name(),
                    rec.retries(),
                    rec.degradations(),
                    rec.resumes(),
                    server.parked_streams(),
                    rec.replayed(),
                    rec.shed()
                );
            }
            if server.plan_enabled() {
                let ps = server.plan_stats();
                println!(
                    "plan: engine={} decisions={} explore_hits={} migrations={} width_hints={}",
                    server.engine_name(),
                    ps.decisions(),
                    ps.explore_hits(),
                    ps.migrations(),
                    ps.width_hints()
                );
            }
            let integ = server.integrity();
            if integ.any() {
                let q = server.quarantined();
                println!(
                    "integrity: audited={} violations={} margin_mismatches={} low_confidence={} \
                     shed_audits={} rejected_inputs={} quarantined=[{}]",
                    integ.audited(),
                    integ.violations(),
                    integ.margin_mismatches(),
                    integ.low_confidence(),
                    integ.shed_audits(),
                    integ.rejected_inputs(),
                    q.join(",")
                );
            }
        }
    }
    println!("final QoS report:\n{}", server.stats_json().to_string_pretty());
    Ok(())
}

fn cmd_scale(args: &Args) -> Result<()> {
    let cfg = base_config(args)?;
    let quick = args.flag("quick");
    let n_bits = args.usize_or("bits", if quick { 50_000 } else { 200_000 })?;
    let ladder = args.usize_list_or("workers", &[1, 2, 4, 8])?;
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let t = cfg.trellis()?;
    let mut rng = Xoshiro256::seeded(args.u64_or("seed", 2016)?);
    let (_, llr) = gen_stream(&t, n_bits, 4.0, cfg.q, &mut rng);
    println!(
        "worker-scaling ladder — {}, B={}, D={}, L={}, lanes={}, q={}, {n_bits} bits \
         ({} cores available)\n",
        cfg.preset, cfg.batch, cfg.block, cfg.depth, cfg.lanes, cfg.q,
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    let mut tab = Table::new(&[
        "engine", "workers", "backend", "wall ms", "T/P Mbps", "speedup", "util %", "imbalance",
        "surv KiB",
    ]);
    for rung in pbvd::bench::worker_ladder(&cfg, &ladder, &llr, &bench)? {
        tab.row(&[
            rung.engine.to_string(),
            rung.workers.to_string(),
            rung.backend.to_string(),
            format!("{:.2}", ms(rung.wall)),
            format!("{:.2}", rung.tp_mbps),
            format!("x{:.2}", rung.speedup),
            rung.utilization.map(|u| format!("{:.0}", 100.0 * u)).unwrap_or_else(|| "-".into()),
            rung.imbalance.map(|i| format!("x{i:.2}")).unwrap_or_else(|| "-".into()),
            if rung.survivor_ring_bytes > 0 {
                format!("{:.1}", rung.survivor_ring_bytes as f64 / 1024.0)
            } else {
                "-".into()
            },
        ]);
    }
    print!("{}", tab.render());
    println!("\n(speedup is vs the 1-worker scalar pool — par-cpu rows isolate thread");
    println!(" scaling, simd-u32 rows add the lane-interleaved kernel gain, simd-u16");
    println!(" rows the narrow-metric 16-lane kernel on top, and the cpu-golden row");
    println!(" shows the butterfly-kernel gain over the reference.)");
    let rcfg = cfg.resolved();
    if rcfg.plan.enabled_or_default() || rcfg.plan.history_path_opt().is_some() {
        let dsp = rcfg.plan_dispatcher(None);
        println!(
            "\nplan: {} — machine {}, {} history rows{}",
            if rcfg.plan.enabled_or_default() {
                "adaptive dispatch on"
            } else {
                "recording history only (planning off)"
            },
            dsp.machine(),
            dsp.history().len(),
            dsp.history()
                .path()
                .map(|p| format!(" at {}", p.display()))
                .unwrap_or_default()
        );
    }
    Ok(())
}

/// `pbvd plan`: inspect the adaptive-dispatch planner for this
/// configuration — history provenance, the per-arm estimates (measured
/// EMA or eq.-(7) prior), and the pick the factory would make.
fn cmd_plan(args: &Args) -> Result<()> {
    let cfg = decoder_config(args)?;
    let rc = cfg.resolved();
    let t = rc.trellis()?;
    let dsp = rc.plan_dispatcher(None);
    let shape = rc.batch_shape(&t);
    println!(
        "adaptive dispatch — {} B={} D={} L={} workers={} q={}",
        rc.preset, rc.batch, rc.block, rc.depth, shape.workers, rc.q
    );
    println!("machine profile: {}", dsp.machine());
    match dsp.history().path() {
        Some(p) => println!("history: {} ({} rows loaded)", p.display(), dsp.history().len()),
        None => println!("history: none (set --perf-history or PBVD_PERF_HISTORY to persist)"),
    }
    println!(
        "planning: {} — reeval every {} groups, explore {} ppm\n",
        if rc.plan.enabled_or_default() {
            "ENABLED"
        } else {
            "disabled (Auto uses the static worker policy)"
        },
        rc.plan.reeval_batches_or_default(),
        rc.plan.explore_ppm_or_default()
    );
    let mut tab = Table::new(&["arm", "kind", "width", "samples", "est Mbps", "source"]);
    for arm in shape.arms() {
        let n = dsp.samples(&shape, arm);
        tab.row(&[
            arm.tag().into(),
            arm.kind().to_string(),
            match arm.metric_bits() {
                0 => "-".into(),
                b => format!("u{b}"),
            },
            n.to_string(),
            format!("{:.2}", dsp.estimate(&shape, arm)),
            if n == 0 { "eq.(7) prior" } else { "measured EMA" }.into(),
        ]);
    }
    print!("{}", tab.render());
    let d = dsp.pick(&shape);
    println!(
        "\npick: {} (est {:.2} Mbps{})",
        d.arm,
        d.est_mbps,
        if d.explored { ", explore draw" } else { "" }
    );
    Ok(())
}

fn cmd_ber(args: &Args) -> Result<()> {
    let code = args.str_or("code", "ccsds_k7");
    let t = Trellis::preset(&code)?;
    let dec = CpuPbvdDecoder::new(
        &t, args.usize_or("block", 256)?, args.usize_or("depth", 42)?,
    );
    let ebn0 = args.f64_list_or("ebn0", &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0])?;
    let cfg = BerConfig {
        target_errors: args.u64_or("errors", 100)?,
        max_bits: args.u64_or("max-bits", 2_000_000)?,
        q: args.usize_or("q", 8)? as u32,
        threads: args.usize_or("threads", 8)?,
        seed: args.u64_or("seed", 2016)?,
        ..Default::default()
    };
    let mut tab = Table::new(&["Eb/N0 dB", "bits", "errors", "BER", "uncoded"]);
    for &e in &ebn0 {
        let p = measure_ber(&t, &dec, e, &cfg)?;
        tab.row(&[
            format!("{e:.1}"), p.bits.to_string(), p.errors.to_string(),
            format!("{:.2e}", p.ber()), format!("{:.2e}", uncoded_bpsk_ber(e)),
        ]);
    }
    print!("{}", tab.render());
    Ok(())
}

fn cmd_model(args: &Args) -> Result<()> {
    let q = args.usize_or("q", 8)? as u32;
    let r = Trellis::preset(&args.str_or("code", "ccsds_k7"))?.r;
    let block = args.usize_or("block", 512)?;
    let depth = args.usize_or("depth", 42)?;
    println!("eq. (7) throughput projection (PCI-E model, paper units)\n");
    let mut tab = Table::new(&["config", "U1 B/stage", "U2 B/bit", "S_k Mbps", "N_s", "T/P Mbps"]);
    for (name, u1, u2) in [
        ("original (f32 in, i32 out)", 4.0 * r as f64, 4.0),
        ("optimized (packed)", pbvd::channel::u1_bytes(q) * r as f64, 1.0 / 8.0),
    ] {
        for (sk, ns) in [(370.0, 1usize), (640.0, 1), (640.0, 3), (2100.0, 3)] {
            let m = ThroughputModel {
                block, depth,
                u1_bytes_per_stage: u1,
                u2_bytes_per_bit: u2,
                bus_bytes_per_s: pcie_bandwidth_bytes(2),
                kernel_bits_per_s: sk * 1e6,
                streams: ns,
            };
            tab.row(&[
                name.into(), format!("{u1}"), format!("{u2:.3}"),
                format!("{sk}"), ns.to_string(),
                format!("{:.1}", m.decode_throughput(4096) / 1e6),
            ]);
        }
    }
    print!("{}", tab.render());
    println!("\n(S_k values bracket the paper's measured kernel throughputs.)");
    Ok(())
}
