//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute
//! from the decode hot path.
//!
//! Mirrors /opt/xla-example/load_hlo: `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `client.compile` -> `execute`.
//! HLO *text* is the interchange format (jax >= 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects in proto form).
//!
//! The [`Registry`] reads `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) and exposes typed, cached executables.

use crate::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

// ---------------------------------------------------------------------------
// Manifest model.
// ---------------------------------------------------------------------------

/// Element type of an artifact tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    I8,
    I32,
    U32,
    F32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "int8" | "i8" | "s8" => DType::I8,
            "int32" | "i32" | "s32" => DType::I32,
            "uint32" | "u32" => DType::U32,
            "float32" | "f32" => DType::F32,
            other => bail!("unsupported dtype {other:?}"),
        })
    }

    pub fn element(self) -> xla::ElementType {
        match self {
            DType::I8 => xla::ElementType::S8,
            DType::I32 => xla::ElementType::S32,
            DType::U32 => xla::ElementType::U32,
            DType::F32 => xla::ElementType::F32,
        }
    }

    pub fn size(self) -> usize {
        match self {
            DType::I8 => 1,
            _ => 4,
        }
    }
}

/// Shape + dtype of one artifact input/output.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.numel() * self.dtype.size()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_i64_vec)
            .ok_or_else(|| anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|&x| x as usize)
            .collect();
        let dtype = DType::parse(
            j.get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("tensor spec missing dtype"))?,
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One artifact (a compiled decode variant at fixed shapes).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub variant: String,
    pub code: String,
    pub batch: usize,
    pub block: usize,
    pub depth: usize,
    pub total: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub entries: Vec<ArtifactMeta>,
    /// code name -> trellis json file
    pub trellis_files: HashMap<String, String>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let mut entries = Vec::new();
        for e in j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?
        {
            let s = |k: &str| -> Result<String> {
                Ok(e.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry missing {k}"))?
                    .to_string())
            };
            let n = |k: &str| -> Result<usize> {
                e.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("entry missing {k}"))
            };
            let inputs = e
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("entry missing inputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("entry missing outputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            entries.push(ArtifactMeta {
                name: s("name")?,
                file: s("file")?,
                variant: s("variant")?,
                code: s("code")?,
                batch: n("batch")?,
                block: n("block")?,
                depth: n("depth")?,
                total: n("total")?,
                inputs,
                outputs,
            });
        }
        let mut trellis_files = HashMap::new();
        if let Some(codes) = j.get("codes").and_then(Json::as_obj) {
            for (code, info) in codes {
                if let Some(f) = info.get("file").and_then(Json::as_str) {
                    trellis_files.insert(code.clone(), f.to_string());
                }
            }
        }
        Ok(Manifest {
            entries,
            trellis_files,
        })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Find by (variant, code, batch, block, depth).
    pub fn lookup(
        &self,
        variant: &str,
        code: &str,
        batch: usize,
        block: usize,
        depth: usize,
    ) -> Option<&ArtifactMeta> {
        self.entries.iter().find(|e| {
            e.variant == variant
                && e.code == code
                && e.batch == batch
                && e.block == block
                && e.depth == depth
        })
    }
}

// ---------------------------------------------------------------------------
// Host tensors.
// ---------------------------------------------------------------------------

/// A host-side tensor matched to a `TensorSpec` (raw bytes + dtype).
#[derive(Clone, Debug)]
pub struct HostTensor {
    pub spec: TensorSpec,
    pub bytes: Vec<u8>,
}

impl HostTensor {
    pub fn from_i8(shape: &[usize], data: &[i8]) -> HostTensor {
        let spec = TensorSpec {
            shape: shape.to_vec(),
            dtype: DType::I8,
        };
        assert_eq!(spec.numel(), data.len());
        HostTensor {
            spec,
            bytes: data.iter().map(|&x| x as u8).collect(),
        }
    }

    pub fn from_f32(shape: &[usize], data: &[f32]) -> HostTensor {
        let spec = TensorSpec {
            shape: shape.to_vec(),
            dtype: DType::F32,
        };
        assert_eq!(spec.numel(), data.len());
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for x in data {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        HostTensor { spec, bytes }
    }

    pub fn from_u32(shape: &[usize], data: &[u32]) -> HostTensor {
        let spec = TensorSpec {
            shape: shape.to_vec(),
            dtype: DType::U32,
        };
        assert_eq!(spec.numel(), data.len());
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for x in data {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        HostTensor { spec, bytes }
    }

    pub fn to_u32(&self) -> Vec<u32> {
        assert!(matches!(self.spec.dtype, DType::U32 | DType::I32));
        self.bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    pub fn to_i32(&self) -> Vec<i32> {
        assert!(matches!(self.spec.dtype, DType::U32 | DType::I32));
        self.bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    pub fn to_f32(&self) -> Vec<f32> {
        assert_eq!(self.spec.dtype, DType::F32);
        self.bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        xla::Literal::create_from_shape_and_untyped_data(
            self.spec.dtype.element(),
            &self.spec.shape,
            &self.bytes,
        )
        .map_err(|e| anyhow!("literal creation failed: {e:?}"))
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
        let n = lit.size_bytes();
        if n != spec.byte_len() {
            bail!(
                "output size mismatch: literal {n} B, spec {} B",
                spec.byte_len()
            );
        }
        // copy_raw_to enforces the literal's element type; dispatch on it.
        let bytes = match spec.dtype {
            DType::I8 => {
                let mut v = vec![0i8; spec.numel()];
                lit.copy_raw_to::<i8>(&mut v)
                    .map_err(|e| anyhow!("literal read failed: {e:?}"))?;
                v.iter().map(|&x| x as u8).collect()
            }
            DType::U32 => {
                let mut v = vec![0u32; spec.numel()];
                lit.copy_raw_to::<u32>(&mut v)
                    .map_err(|e| anyhow!("literal read failed: {e:?}"))?;
                let mut b = Vec::with_capacity(n);
                for x in v {
                    b.extend_from_slice(&x.to_le_bytes());
                }
                b
            }
            DType::I32 => {
                let mut v = vec![0i32; spec.numel()];
                lit.copy_raw_to::<i32>(&mut v)
                    .map_err(|e| anyhow!("literal read failed: {e:?}"))?;
                let mut b = Vec::with_capacity(n);
                for x in v {
                    b.extend_from_slice(&x.to_le_bytes());
                }
                b
            }
            DType::F32 => {
                let mut v = vec![0f32; spec.numel()];
                lit.copy_raw_to::<f32>(&mut v)
                    .map_err(|e| anyhow!("literal read failed: {e:?}"))?;
                let mut b = Vec::with_capacity(n);
                for x in v {
                    b.extend_from_slice(&x.to_le_bytes());
                }
                b
            }
        };
        Ok(HostTensor {
            spec: spec.clone(),
            bytes,
        })
    }
}

// ---------------------------------------------------------------------------
// Executables.
// ---------------------------------------------------------------------------

/// Thread-shareable compiled executable.
///
/// SAFETY: `PjRtLoadedExecutable` wraps a C++ PJRT executable whose
/// `Execute` is documented thread-safe (PJRT clients/executables are
/// concurrently usable; the CPU plugin serializes internally where
/// needed).  The wrapper holds no Rust-side mutable state.
struct SharedExec(xla::PjRtLoadedExecutable);
unsafe impl Send for SharedExec {}
unsafe impl Sync for SharedExec {}

/// A loaded artifact ready to run.
pub struct Executable {
    pub meta: ArtifactMeta,
    exec: SharedExec,
}

impl Executable {
    /// Execute on host tensors; returns host tensors (decomposed from
    /// the jax `return_tuple=True` 1..n-tuple).
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "artifact {} expects {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&self.meta.inputs).enumerate() {
            if t.spec.shape != spec.shape || t.spec.dtype != spec.dtype {
                bail!(
                    "artifact {} input {i}: expected {:?}{:?}, got {:?}{:?}",
                    self.meta.name,
                    spec.dtype,
                    spec.shape,
                    t.spec.dtype,
                    t.spec.shape
                );
            }
        }
        let literals = inputs
            .iter()
            .map(HostTensor::to_literal)
            .collect::<Result<Vec<_>>>()?;
        let result = self
            .exec
            .0
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute failed: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch failed: {e:?}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("tuple decompose failed: {e:?}"))?;
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "artifact {}: {} outputs declared, {} returned",
                self.meta.name,
                self.meta.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(&self.meta.outputs)
            .map(|(lit, spec)| HostTensor::from_literal(lit, spec))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Registry.
// ---------------------------------------------------------------------------

/// Thread-shareable PJRT client wrapper.
///
/// SAFETY: the Rust wrapper holds an `Rc` purely for drop bookkeeping;
/// the underlying C++ `PjRtClient` is documented thread-safe (it is the
/// same object JAX shares across Python threads).  We never mutate the
/// Rust-side state after construction and the process-wide singleton
/// below guarantees the `Rc` count is only touched at init.
struct SharedClient(xla::PjRtClient);
unsafe impl Send for SharedClient {}
unsafe impl Sync for SharedClient {}

/// Process-wide PJRT CPU client (PJRT clients are heavyweight; one per
/// process is the intended usage).
fn client() -> Result<&'static xla::PjRtClient> {
    static CLIENT: OnceLock<Option<SharedClient>> = OnceLock::new();
    CLIENT
        .get_or_init(|| xla::PjRtClient::cpu().ok().map(SharedClient))
        .as_ref()
        .map(|c| &c.0)
        .ok_or_else(|| anyhow!("PJRT CPU client init failed"))
}

/// True when a PJRT CPU client can be constructed — i.e. the crate was
/// built against the real `xla` bindings rather than the vendored stub
/// (`rust/vendor/xla`).  Artifact-gated tests and benches use this to
/// skip with a clear message instead of failing mid-run.
pub fn pjrt_available() -> bool {
    client().is_ok()
}

/// Artifact registry: manifest + lazily compiled executable cache.
pub struct Registry {
    pub dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Registry {
    /// Open the registry at `dir` (reads `manifest.json`).
    pub fn open(dir: &Path) -> Result<Registry> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {}", mpath.display()))?;
        Ok(Registry {
            dir: dir.to_path_buf(),
            manifest: Manifest::parse(&text)?,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Open the default artifacts directory.
    pub fn open_default() -> Result<Registry> {
        Registry::open(&crate::artifacts_dir())
    }

    /// Load (compile-once, cache) an artifact by name.
    ///
    /// The cache mutex is taken poison-tolerant
    /// ([`relock`](crate::pipeline::relock)): a thread that panicked
    /// between lookup and insert leaves the map in a consistent state
    /// (worst case a missing entry, recompiled on the next call), so
    /// poisoning must not cascade the panic into every later load.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = crate::pipeline::relock(&self.cache).get(name) {
            return Ok(Arc::clone(e));
        }
        let meta = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?
            .clone();
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("HLO parse failed for {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client()?
            .compile(&comp)
            .map_err(|e| anyhow!("PJRT compile failed for {name}: {e:?}"))?;
        let executable = Arc::new(Executable {
            meta,
            exec: SharedExec(exe),
        });
        crate::pipeline::relock(&self.cache).insert(name.to_string(), Arc::clone(&executable));
        Ok(executable)
    }

    /// Load by (variant, code, batch, block, depth).
    pub fn load_variant(
        &self,
        variant: &str,
        code: &str,
        batch: usize,
        block: usize,
        depth: usize,
    ) -> Result<Arc<Executable>> {
        let meta = self
            .manifest
            .lookup(variant, code, batch, block, depth)
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for variant={variant} code={code} \
                     B={batch} D={block} L={depth}; run `make artifacts`"
                )
            })?;
        let name = meta.name.clone();
        self.load(&name)
    }

    /// Read the trellis JSON export for a code.
    pub fn trellis_json(&self, code: &str) -> Result<String> {
        let file = self
            .manifest
            .trellis_files
            .get(code)
            .ok_or_else(|| anyhow!("no trellis export for code {code:?}"))?;
        Ok(std::fs::read_to_string(self.dir.join(file))?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
      "version": 1,
      "entries": [
        {"name": "fwd_x", "file": "fwd_x.hlo.txt", "variant": "forward",
         "code": "ccsds_k7", "batch": 32, "block": 64, "depth": 42,
         "total": 148, "tile_b": 8,
         "inputs": [{"shape": [32, 148, 2], "dtype": "int8"}],
         "outputs": [{"shape": [32, 148, 4], "dtype": "u32"},
                      {"shape": [32, 64], "dtype": "f32"}]}
      ],
      "codes": {"ccsds_k7": {"file": "trellis_ccsds_k7.json"}}
    }"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(MANIFEST).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.find("fwd_x").unwrap();
        assert_eq!(e.batch, 32);
        assert_eq!(e.inputs[0].dtype, DType::I8);
        assert_eq!(e.inputs[0].numel(), 32 * 148 * 2);
        assert_eq!(e.outputs[1].dtype, DType::F32);
        assert!(m.lookup("forward", "ccsds_k7", 32, 64, 42).is_some());
        assert!(m.lookup("forward", "ccsds_k7", 32, 64, 43).is_none());
        assert_eq!(
            m.trellis_files.get("ccsds_k7").unwrap(),
            "trellis_ccsds_k7.json"
        );
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("int8").unwrap(), DType::I8);
        assert_eq!(DType::parse("uint32").unwrap(), DType::U32);
        assert_eq!(DType::parse("u32").unwrap(), DType::U32);
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert!(DType::parse("float64").is_err());
    }

    #[test]
    fn host_tensor_roundtrip() {
        let t = HostTensor::from_u32(&[2, 2], &[1, 2, 3, 4]);
        assert_eq!(t.to_u32(), vec![1, 2, 3, 4]);
        assert_eq!(t.spec.byte_len(), 16);
        let f = HostTensor::from_f32(&[3], &[1.0, -2.5, 3.25]);
        assert_eq!(f.to_f32(), vec![1.0, -2.5, 3.25]);
        let i = HostTensor::from_i8(&[4], &[-1, 2, -3, 4]);
        assert_eq!(i.bytes.len(), 4);
        assert_eq!(i.bytes[0], 0xFF);
    }
}
