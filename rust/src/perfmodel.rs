//! Analytic performance models: the paper's throughput equation (7),
//! the PCI-E bus model, TNDC normalization and the Table IV
//! prior-work comparison constants.

/// Parameters of the eq.-(7) decoding-throughput model.
#[derive(Clone, Copy, Debug)]
pub struct ThroughputModel {
    /// Decoded payload bits per PB (D).
    pub block: usize,
    /// Decoding depth (L); PB length is D + 2L.
    pub depth: usize,
    /// Bytes per stored input symbol *vector* per stage (U1·R in the
    /// paper's units: 4R for f32, 4R/⌊32/q⌋ packed).
    pub u1_bytes_per_stage: f64,
    /// Bytes per stored decoded bit (U2: 4 unpacked i32, 1/8 packed).
    pub u2_bytes_per_bit: f64,
    /// Bus bandwidth in bytes/s (PCI-E model or measured host<->PJRT).
    pub bus_bytes_per_s: f64,
    /// Kernel throughput S_k in decoded bits/s.
    pub kernel_bits_per_s: f64,
    /// Number of overlapped streams/lanes (N_s).
    pub streams: usize,
}

impl ThroughputModel {
    /// H2D time for one batch of `n_t` PBs (seconds).
    pub fn t_h2d(&self, n_t: usize) -> f64 {
        ((self.block + 2 * self.depth) * n_t) as f64 * self.u1_bytes_per_stage
            / self.bus_bytes_per_s
    }

    /// D2H time for one batch (seconds).
    pub fn t_d2h(&self, n_t: usize) -> f64 {
        (self.block * n_t) as f64 * self.u2_bytes_per_bit / self.bus_bytes_per_s
    }

    /// Kernel time for one batch (seconds).
    pub fn t_kernel(&self, n_t: usize) -> f64 {
        (self.block * n_t) as f64 / self.kernel_bits_per_s
    }

    /// eq. (7): overall decoding throughput in bits/s with `N_s`
    /// streams — first H2D and last D2H are exposed, the rest overlaps.
    pub fn decode_throughput(&self, n_t: usize) -> f64 {
        let ns = self.streams.max(1) as f64;
        let total_bits = (self.block * n_t) as f64 * ns;
        let time =
            self.t_h2d(n_t) + ns * self.t_kernel(n_t) + self.t_d2h(n_t);
        total_bits / time
    }

    /// The closed form of eq. (7) (bits/s); equal to
    /// `decode_throughput` up to rounding — kept for unit-testing the
    /// algebra.
    pub fn decode_throughput_closed_form(&self) -> f64 {
        let ns = self.streams.max(1) as f64;
        let d = self.block as f64;
        let l = self.depth as f64;
        let u1 = self.u1_bytes_per_stage;
        let u2 = self.u2_bytes_per_bit;
        let b = self.bus_bytes_per_s;
        let sk = self.kernel_bits_per_s;
        b * ns / ((1.0 + 2.0 * l / d) * u1 + ns * b / sk + u2)
    }
}

/// Throughput under Normalized Decoding Cost [14]: decoded Mbps divided
/// by (cores × clock-GHz) of the device — the paper's cross-device
/// fairness metric (Table IV).
pub fn tndc(throughput_mbps: f64, cores: u32, clock_mhz: f64) -> f64 {
    throughput_mbps / (cores as f64 * clock_mhz / 1000.0)
}

/// A prior-work row of Table IV.
#[derive(Clone, Copy, Debug)]
pub struct PriorWork {
    pub work: &'static str,
    pub device: &'static str,
    pub throughput_mbps: f64,
    pub cores: u32,
    pub clock_mhz: f64,
    /// TNDC as printed in the paper (for cross-checking our formula).
    pub paper_tndc: f64,
}

/// Table IV constants (prior GPU decoders, K = 7, rate 1/2).
pub const TABLE4_PRIOR: &[PriorWork] = &[
    PriorWork { work: "[6]",  device: "GTX275",      throughput_mbps: 28.7,  cores: 240,  clock_mhz: 1404.0, paper_tndc: 0.085 },
    PriorWork { work: "[7]",  device: "8800GTX",     throughput_mbps: 29.4,  cores: 128,  clock_mhz: 1350.0, paper_tndc: 0.170 },
    PriorWork { work: "[8]",  device: "GTX580",      throughput_mbps: 67.1,  cores: 512,  clock_mhz: 1544.0, paper_tndc: 0.085 },
    PriorWork { work: "[9]",  device: "9800GTX",     throughput_mbps: 90.8,  cores: 128,  clock_mhz: 1688.0, paper_tndc: 0.420 },
    PriorWork { work: "[11]", device: "HD7970",      throughput_mbps: 391.5, cores: 2048, clock_mhz: 925.0,  paper_tndc: 0.207 },
    PriorWork { work: "[10]", device: "Tesla C2050", throughput_mbps: 240.9, cores: 448,  clock_mhz: 1150.0, paper_tndc: 0.468 },
    PriorWork { work: "[10]", device: "GTX580",      throughput_mbps: 404.7, cores: 512,  clock_mhz: 1544.0, paper_tndc: 0.512 },
];

/// This-work rows as reported in the paper.
pub const TABLE4_THIS_WORK: &[PriorWork] = &[
    PriorWork { work: "paper", device: "GTX580", throughput_mbps: 598.3,  cores: 512,  clock_mhz: 1544.0, paper_tndc: 0.757 },
    PriorWork { work: "paper", device: "GTX980", throughput_mbps: 1802.5, cores: 2048, clock_mhz: 1126.0, paper_tndc: 0.782 },
];

/// PCI-E bus generations (bytes/s effective for a x16 link).
pub fn pcie_bandwidth_bytes(gen: u32) -> f64 {
    match gen {
        2 => 8.0e9,  // PCI-E 2.0 x16 ~ 8 GB/s
        3 => 15.75e9,
        4 => 31.5e9,
        _ => 8.0e9,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ThroughputModel {
        ThroughputModel {
            block: 512,
            depth: 42,
            u1_bytes_per_stage: 2.0, // q=8, R=2 packed
            u2_bytes_per_bit: 1.0 / 8.0,
            bus_bytes_per_s: 8.0e9,
            kernel_bits_per_s: 600.0e6,
            streams: 3,
        }
    }

    #[test]
    fn closed_form_matches_expanded() {
        let m = model();
        for n_t in [2048usize, 4096, 10240] {
            let a = m.decode_throughput(n_t);
            let b = m.decode_throughput_closed_form();
            assert!(
                (a - b).abs() / b < 1e-12,
                "n_t={n_t}: {a} vs {b}"
            );
        }
    }

    #[test]
    fn more_streams_more_throughput() {
        let mut m = model();
        m.streams = 1;
        let one = m.decode_throughput(4096);
        m.streams = 3;
        let three = m.decode_throughput(4096);
        assert!(three > one);
        // but bounded by kernel throughput
        assert!(three < m.kernel_bits_per_s);
    }

    #[test]
    fn packing_improves_throughput() {
        let mut m = model();
        let packed = m.decode_throughput(4096);
        m.u1_bytes_per_stage = 8.0; // f32, R = 2
        m.u2_bytes_per_bit = 4.0;   // i32 per bit
        let unpacked = m.decode_throughput(4096);
        assert!(packed > unpacked * 1.2, "{packed} vs {unpacked}");
    }

    #[test]
    fn tndc_reproduces_paper_values() {
        // Our TNDC formula must reproduce the paper's printed values to
        // ~10% for its own rows (paper rounds aggressively).
        for w in TABLE4_THIS_WORK {
            let got = tndc(w.throughput_mbps, w.cores, w.clock_mhz);
            let rel = (got - w.paper_tndc).abs() / w.paper_tndc;
            assert!(rel < 0.1, "{}: got {got}, paper {}", w.device, w.paper_tndc);
        }
        // GTX580 row of [10]
        let w = &TABLE4_PRIOR[6];
        let got = tndc(w.throughput_mbps, w.cores, w.clock_mhz);
        assert!((got - w.paper_tndc).abs() / w.paper_tndc < 0.1);
    }

    #[test]
    fn paper_speedup_ratios() {
        // The ~1.5x headline: this work GTX580 TNDC vs [10] GTX580 TNDC.
        let ours = TABLE4_THIS_WORK[1].paper_tndc;
        let best_prior = TABLE4_PRIOR
            .iter()
            .map(|w| w.paper_tndc)
            .fold(0.0f64, f64::max);
        let speedup = ours / best_prior;
        assert!((1.4..1.7).contains(&speedup), "speedup {speedup}");
    }

    #[test]
    fn kernel_bound_dominates_at_high_bus() {
        let mut m = model();
        m.bus_bytes_per_s = 1e15; // infinite bus
        let tp = m.decode_throughput(4096);
        // With a free bus, eq.(7) -> S_k
        assert!((tp - m.kernel_bits_per_s).abs() / m.kernel_bits_per_s < 0.01);
    }
}
