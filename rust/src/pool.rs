//! The generic sharded decode worker pool — one implementation of the
//! job/spawn/dispatch/recv/attribution/splice/Drop machinery that
//! [`par::ParCpuEngine`](crate::par::ParCpuEngine) and
//! [`simd::SimdCpuEngine`](crate::simd::SimdCpuEngine) previously
//! duplicated nearly line for line.
//!
//! A [`WorkerPool`] owns `N_w` persistent worker threads.  Each worker
//! builds its own kernel state once (via the engine-supplied factory —
//! scratch buffers, trellis tables, lane-interleaved or scalar ACS
//! kernels) and then drains jobs through the engine-supplied handler,
//! which turns one job's LLR slice into bit-packed payload words.  The
//! pool carries everything engine-independent:
//!
//! * job envelopes over a shared `Arc<[i8]>` batch buffer (zero input
//!   copies on the `decode_batch_shared` path),
//! * bounded-queue dispatch with per-call reply channels (concurrent
//!   callers never interleave results),
//! * exact per-call worker attribution ([`BatchTimings::per_worker`])
//!   plus cumulative [`WorkerPoolStats`] counters,
//! * batch-order splicing of the shard outputs, and
//! * clean shutdown (close + join) on `Drop`.
//!
//! Engines stay thin: they validate geometry, cut a batch into a
//! [`DecodeShard`] plan (contiguous PB runs for the scalar pool,
//! lane-groups plus a ragged tail for the SIMD pool) and call
//! [`WorkerPool::dispatch`].

use crate::coordinator::BatchTimings;
use crate::metrics::{WorkerPoolStats, WorkerSnapshot};
use crate::pipeline::BoundedQueue;
use crate::serve::faults::FaultPlan;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex, PoisonError};
use std::thread;
use std::time::{Duration, Instant};

/// Worker-count resolution shared by every sharded pool: `0` = one
/// worker per available core, otherwise exactly `n`.
pub(crate) fn resolve_workers(n: usize) -> usize {
    if n == 0 {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        n
    }
}

/// One shard of a batch's decode plan: `n_pbs` parallel blocks whose
/// LLRs occupy `[lo, hi)` of the shared batch buffer.
#[derive(Clone, Copy, Debug)]
pub struct DecodeShard {
    pub n_pbs: usize,
    pub lo: usize,
    pub hi: usize,
}

/// One queued job: a [`DecodeShard`] plus the shared batch buffer and
/// the dispatching call's reply channel.
struct Job {
    seq: usize,
    n_pbs: usize,
    llr: Arc<[i8]>,
    lo: usize,
    hi: usize,
    reply: mpsc::Sender<JobReply>,
}

struct JobReply {
    seq: usize,
    /// Which worker decoded this shard, and for how long — the exact
    /// per-call attribution that feeds `BatchTimings::per_worker`.
    wid: usize,
    busy: Duration,
    n_pbs: usize,
    /// Bit-packed decoded payload, `n_pbs * ceil(D/32)` words.
    words: Vec<u32>,
    /// Per-PB confidence margins, `n_pbs` values (runner-up final
    /// path metric; see `viterbi::ForwardResult::margin`).
    margins: Vec<u32>,
}

/// Holder for an optional [`FaultPlan`], designed so the worker hot
/// loop pays exactly one relaxed atomic load per job while no plan is
/// installed (the production case) and only takes the mutex once
/// armed.
pub(crate) struct FaultCell {
    armed: AtomicBool,
    plan: Mutex<Option<Arc<FaultPlan>>>,
}

impl FaultCell {
    fn new() -> FaultCell {
        FaultCell {
            armed: AtomicBool::new(false),
            plan: Mutex::new(None),
        }
    }

    fn install(&self, plan: Option<Arc<FaultPlan>>) {
        let mut g = self.plan.lock().unwrap_or_else(PoisonError::into_inner);
        self.armed.store(plan.is_some(), Ordering::Release);
        *g = plan;
    }

    fn get(&self) -> Option<Arc<FaultPlan>> {
        if !self.armed.load(Ordering::Acquire) {
            return None;
        }
        self.plan
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .clone()
    }
}

/// A persistent pool of decode workers parameterized by a per-worker
/// kernel-state factory and a job handler (see the module docs).
pub struct WorkerPool {
    workers: usize,
    jobs: Arc<BoundedQueue<Job>>,
    stats: Arc<WorkerPoolStats>,
    faults: Arc<FaultCell>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` decode workers (`0` = one per available core).
    ///
    /// `make_state` runs once on each worker thread to build its
    /// private kernel state (so the state itself need not be `Send`);
    /// `handle_job` decodes one shard — `(state, n_pbs, llr_slice)` —
    /// into bit-packed payload words plus one confidence margin per
    /// PB.  `metric_bits` and `backend`
    /// are recorded in the pool's [`WorkerPoolStats`] (path-metric
    /// storage width and [`AcsBackend::code`](crate::simd::AcsBackend::code)
    /// for SIMD pools; `0`/`0` for scalar pools).
    pub fn spawn<S, F, H>(
        thread_prefix: &str,
        workers: usize,
        metric_bits: u64,
        backend: u64,
        make_state: F,
        handle_job: H,
    ) -> WorkerPool
    where
        S: 'static,
        F: Fn(usize) -> S + Send + Sync + 'static,
        H: Fn(&mut S, usize, &[i8]) -> (Vec<u32>, Vec<u32>) + Send + Sync + 'static,
    {
        let workers = resolve_workers(workers);
        let jobs: Arc<BoundedQueue<Job>> = BoundedQueue::new(workers * 4);
        let stats = Arc::new(WorkerPoolStats::new(workers));
        stats.set_metric_bits(metric_bits);
        stats.set_backend(backend);
        let faults = Arc::new(FaultCell::new());
        let make_state = Arc::new(make_state);
        let handle_job = Arc::new(handle_job);
        let mut handles = Vec::with_capacity(workers);
        for wid in 0..workers {
            let q = Arc::clone(&jobs);
            let st = Arc::clone(&stats);
            let fc = Arc::clone(&faults);
            let mk = Arc::clone(&make_state);
            let hd = Arc::clone(&handle_job);
            handles.push(
                thread::Builder::new()
                    .name(format!("{thread_prefix}-{wid}"))
                    .spawn(move || {
                        // If this worker panics (state factory or job
                        // handler), fail the pool fast: close the queue
                        // and drop any queued jobs so their reply
                        // senders die and blocked dispatchers get
                        // "worker exited" instead of hanging forever.
                        struct FailPoolOnPanic(Arc<BoundedQueue<Job>>);
                        impl Drop for FailPoolOnPanic {
                            fn drop(&mut self) {
                                if thread::panicking() {
                                    self.0.close();
                                    while self.0.pop().is_some() {}
                                }
                            }
                        }
                        let _guard = FailPoolOnPanic(Arc::clone(&q));
                        let mut state = (*mk)(wid);
                        while let Some(job) = q.pop() {
                            // fault seam: one relaxed load when unarmed
                            if let Some(plan) = fc.get() {
                                if plan.on_worker_job() {
                                    panic!("injected worker panic (fault plan)");
                                }
                            }
                            let t0 = Instant::now();
                            let (words, margins) =
                                (*hd)(&mut state, job.n_pbs, &job.llr[job.lo..job.hi]);
                            let busy = t0.elapsed();
                            st.record(wid, busy, job.n_pbs as u64);
                            // receiver may be gone if the caller bailed;
                            // the job is then moot
                            let _ = job.reply.send(JobReply {
                                seq: job.seq,
                                wid,
                                busy,
                                n_pbs: job.n_pbs,
                                words,
                                margins,
                            });
                        }
                    })
                    .expect("spawn decode worker"),
            );
        }
        WorkerPool {
            workers,
            jobs,
            stats,
            faults,
            handles,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Install (or clear, with `None`) a fault-injection plan on the
    /// worker job loop (see [`serve::faults`](crate::serve::faults)).
    /// With no plan installed the loop pays one relaxed atomic load
    /// per job.
    pub fn install_fault_plan(&self, plan: Option<Arc<FaultPlan>>) {
        self.faults.install(plan);
    }

    /// Cumulative pool counters (pool lifetime; diff two snapshots for
    /// a per-stream view).
    pub fn snapshot(&self) -> WorkerSnapshot {
        self.stats.snapshot()
    }

    /// Path-metric width recorded at spawn (`0` for scalar pools).
    pub fn metric_bits(&self) -> u64 {
        self.stats.metric_bits()
    }

    /// ACS backend code recorded at spawn (`0` for scalar pools).
    pub fn backend(&self) -> u64 {
        self.stats.backend()
    }

    /// Dispatch one batch's shard plan over the shared buffer, wait
    /// for every reply, and splice the bit-packed outputs back in plan
    /// order.  The buffer reaches workers as `Arc` clones — never
    /// copied here.  Timings: `pack` = dispatch, `k1` = decode wall,
    /// `unpack` = splice; `per_worker` carries this call's exact
    /// attribution.
    pub fn dispatch(
        &self,
        llr: &Arc<[i8]>,
        plan: &[DecodeShard],
    ) -> Result<(Vec<u32>, BatchTimings)> {
        let mut t = BatchTimings::default();
        let n_jobs = plan.len();
        let (tx, rx) = mpsc::channel::<JobReply>();

        let t0 = Instant::now();
        for (seq, s) in plan.iter().enumerate() {
            let job = Job {
                seq,
                n_pbs: s.n_pbs,
                llr: Arc::clone(llr),
                lo: s.lo,
                hi: s.hi,
                reply: tx.clone(),
            };
            if self.jobs.push(job).is_err() {
                bail!("decode pool already shut down");
            }
        }
        drop(tx);
        t.pack = t0.elapsed(); // dispatch only: zero input copies

        // wall time of the sharded decode (the batch's kernel phase)
        let t0 = Instant::now();
        let mut parts: Vec<Option<(Vec<u32>, Vec<u32>)>> = vec![None; n_jobs];
        let mut pool = WorkerSnapshot {
            busy: vec![Duration::ZERO; self.workers],
            jobs: vec![0; self.workers],
            blocks: vec![0; self.workers],
            metric_bits: self.stats.metric_bits(),
            backend: self.stats.backend(),
        };
        for _ in 0..n_jobs {
            match rx.recv() {
                Ok(res) => {
                    pool.busy[res.wid] += res.busy;
                    pool.jobs[res.wid] += 1;
                    pool.blocks[res.wid] += res.n_pbs as u64;
                    parts[res.seq] = Some((res.words, res.margins));
                }
                Err(_) => bail!("decode worker exited before replying"),
            }
        }
        t.k1 = t0.elapsed();
        t.per_worker = Some(pool);

        // splice shards back into batch order (words and margins alike)
        let t0 = Instant::now();
        let total: usize = parts
            .iter()
            .map(|p| p.as_ref().map_or(0, |(w, _)| w.len()))
            .sum();
        let mut out = Vec::with_capacity(total);
        for p in parts {
            let (words, margins) = p.expect("every shard replies exactly once");
            out.extend(words);
            t.margins.extend(margins);
        }
        t.unpack = t0.elapsed();
        Ok((out, t))
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.jobs.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A toy handler: each "PB" is one byte; decoding negates it into
    /// a word (margin = the byte itself), so splice order, margin
    /// order and attribution are all observable.
    fn toy_pool(workers: usize) -> WorkerPool {
        WorkerPool::spawn(
            "pbvd-test",
            workers,
            0,
            0,
            |_wid| 0u64, // per-worker state: decoded-job counter
            |count, n_pbs, llr| {
                *count += 1;
                assert_eq!(llr.len(), n_pbs);
                (
                    llr.iter().map(|&x| (-(x as i32)) as u32).collect(),
                    llr.iter().map(|&x| x as u32).collect(),
                )
            },
        )
    }

    #[test]
    fn dispatch_splices_in_plan_order_and_attributes() {
        let pool = toy_pool(3);
        assert_eq!(pool.workers(), 3);
        let llr: Arc<[i8]> = (0..10i8).collect::<Vec<_>>().into();
        let plan = [
            DecodeShard { n_pbs: 4, lo: 0, hi: 4 },
            DecodeShard { n_pbs: 3, lo: 4, hi: 7 },
            DecodeShard { n_pbs: 3, lo: 7, hi: 10 },
        ];
        let (words, t) = pool.dispatch(&llr, &plan).unwrap();
        let want: Vec<u32> = (0..10i32).map(|x| (-x) as u32).collect();
        assert_eq!(words, want);
        // margins splice back in the same plan order as the words
        let want_margins: Vec<u32> = (0..10u32).collect();
        assert_eq!(t.margins, want_margins);
        let pw = t.per_worker.expect("per-call attribution");
        assert_eq!(pw.total_jobs(), 3);
        assert_eq!(pw.total_blocks(), 10);
        assert_eq!(pool.snapshot().total_blocks(), 10);
    }

    #[test]
    fn resolve_workers_policy() {
        assert_eq!(resolve_workers(3), 3);
        assert!(resolve_workers(0) >= 1);
    }

    #[test]
    fn metric_bits_and_backend_recorded() {
        let code = crate::simd::AcsBackend::Portable.code();
        let pool = WorkerPool::spawn("pbvd-test16", 1, 16, code, |_| (), |_, _, _| {
            (Vec::new(), Vec::new())
        });
        assert_eq!(pool.metric_bits(), 16);
        assert_eq!(pool.snapshot().metric_bits, 16);
        assert_eq!(pool.backend(), code);
        assert_eq!(pool.snapshot().backend_name(), Some("portable"));
    }

    #[test]
    fn panicking_worker_fails_dispatch_instead_of_hanging() {
        // A worker panic (factory or handler) must surface as a
        // dispatch error, not a forever-blocked rx.recv().
        let pool = WorkerPool::spawn(
            "pbvd-panic",
            1,
            0,
            0,
            |_| (),
            |_: &mut (), _, _| -> (Vec<u32>, Vec<u32>) { panic!("worker down") },
        );
        let llr: Arc<[i8]> = vec![0i8; 2].into();
        let plan = [
            DecodeShard { n_pbs: 1, lo: 0, hi: 1 },
            DecodeShard { n_pbs: 1, lo: 1, hi: 2 },
        ];
        assert!(pool.dispatch(&llr, &plan).is_err());
    }

    #[test]
    fn installed_fault_plan_panics_the_selected_job() {
        let pool = toy_pool(1);
        let llr: Arc<[i8]> = vec![0i8; 1].into();
        let plan = [DecodeShard { n_pbs: 1, lo: 0, hi: 1 }];
        // job 0 decodes cleanly, job 1 is selected by the plan
        pool.install_fault_plan(Some(Arc::new(
            FaultPlan::parse("worker_panic@job=1").unwrap(),
        )));
        assert!(pool.dispatch(&llr, &plan).is_ok(), "job 0 unaffected");
        assert!(
            pool.dispatch(&llr, &plan).is_err(),
            "job 1 must fail via the injected panic"
        );
        // clearing the plan disarms the seam (the pool itself stays
        // failed after the panic — that is the supervisor's problem)
        pool.install_fault_plan(None);
        assert!(pool.dispatch(&llr, &plan).is_err(), "pool is closed");
    }

    #[test]
    fn empty_fault_plan_is_inert() {
        let pool = toy_pool(1);
        pool.install_fault_plan(Some(Arc::new(FaultPlan::parse("").unwrap())));
        let llr: Arc<[i8]> = vec![0i8; 1].into();
        let plan = [DecodeShard { n_pbs: 1, lo: 0, hi: 1 }];
        for _ in 0..4 {
            pool.dispatch(&llr, &plan).unwrap();
        }
    }

    #[test]
    fn drop_joins_workers_cleanly() {
        let pool = toy_pool(2);
        let llr: Arc<[i8]> = vec![1i8; 4].into();
        let plan = [DecodeShard { n_pbs: 4, lo: 0, hi: 4 }];
        pool.dispatch(&llr, &plan).unwrap();
        drop(pool); // close + join; must not hang or panic
    }
}
