//! The generic sharded decode worker pool — one implementation of the
//! job/spawn/dispatch/recv/attribution/splice/Drop machinery that
//! [`par::ParCpuEngine`](crate::par::ParCpuEngine) and
//! [`simd::SimdCpuEngine`](crate::simd::SimdCpuEngine) previously
//! duplicated nearly line for line.
//!
//! A [`WorkerPool`] owns `N_w` persistent worker threads.  Each worker
//! builds its own kernel state once (via the engine-supplied factory —
//! scratch buffers, trellis tables, lane-interleaved or scalar ACS
//! kernels) and then drains jobs through the engine-supplied handler,
//! which turns one job's LLR slice into bit-packed payload words.  The
//! pool carries everything engine-independent:
//!
//! * job envelopes over a shared `Arc<[i8]>` batch buffer (zero input
//!   copies on the `decode_batch_shared` path),
//! * bounded-queue dispatch with per-call reply channels (concurrent
//!   callers never interleave results),
//! * exact per-call worker attribution ([`BatchTimings::per_worker`])
//!   plus cumulative [`WorkerPoolStats`] counters,
//! * batch-order splicing of the shard outputs, and
//! * clean shutdown (close + join) on `Drop`.
//!
//! Engines stay thin: they validate geometry, cut a batch into a
//! [`DecodeShard`] plan (contiguous PB runs for the scalar pool,
//! lane-groups plus a ragged tail for the SIMD pool) and call
//! [`WorkerPool::dispatch`].

use crate::coordinator::BatchTimings;
use crate::metrics::{WorkerPoolStats, WorkerSnapshot};
use crate::pipeline::BoundedQueue;
use crate::serve::faults::FaultPlan;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex, PoisonError};
use std::thread;
use std::time::{Duration, Instant};

/// Worker-count resolution shared by every sharded pool: `0` = one
/// worker per available core, otherwise exactly `n`.
pub(crate) fn resolve_workers(n: usize) -> usize {
    if n == 0 {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        n
    }
}

/// One shard of a batch's decode plan: `n_pbs` parallel blocks whose
/// LLRs occupy `[lo, hi)` of the shared batch buffer.
#[derive(Clone, Copy, Debug)]
pub struct DecodeShard {
    pub n_pbs: usize,
    pub lo: usize,
    pub hi: usize,
}

/// One queued job: a [`DecodeShard`] plus the shared batch buffer and
/// the dispatching call's reply channel.
struct Job {
    seq: usize,
    n_pbs: usize,
    llr: Arc<[i8]>,
    lo: usize,
    hi: usize,
    reply: mpsc::Sender<JobReply>,
}

/// The traceback continuation of a split shard: the ACS phase's
/// detached survivor artifact (decision rings for the scalar pool,
/// lane-mask rings for the SIMD pool) plus everything needed to build
/// the final reply.  Pushed to the *back* of the shared work queue so
/// whichever worker frees up first runs it — one shard's traceback
/// overlapping another shard's ACS is the split's whole point.
struct TbJob<A> {
    seq: usize,
    n_pbs: usize,
    artifact: A,
    /// Margins captured at the end of the ACS phase, before the next
    /// shard's forward pass overwrites the kernel's path metrics.
    margins: Vec<u32>,
    acs_wid: usize,
    acs_busy: Duration,
    reply: mpsc::Sender<JobReply>,
}

/// A unit of queued work: a shard's forward-ACS phase, or the
/// traceback continuation it spawns (split pools only — fused pools
/// never enqueue `Tb`).
enum Work<A> {
    Acs(Job),
    Tb(TbJob<A>),
}

struct JobReply {
    seq: usize,
    /// Which worker ran this shard's (fused decode or) ACS phase, and
    /// for how long — the exact per-call attribution that feeds
    /// `BatchTimings::per_worker`.
    wid: usize,
    busy: Duration,
    /// Split pools: which worker ran the traceback phase and for how
    /// long (`None` on the fused path).  May differ from `wid` — that
    /// cross-worker handoff is the measured ACS/traceback overlap.
    tb: Option<(usize, Duration)>,
    n_pbs: usize,
    /// Bit-packed decoded payload, `n_pbs * ceil(D/32)` words.
    words: Vec<u32>,
    /// Per-PB confidence margins, `n_pbs` values (runner-up final
    /// path metric; see `viterbi::ForwardResult::margin`).
    margins: Vec<u32>,
}

/// Type-erased handle to the pool's work queue: `dispatch` only ever
/// pushes ACS jobs and closes the queue, so the artifact type `A` —
/// fixed inside [`WorkerPool::spawn_split`] — never escapes into the
/// (non-generic) [`WorkerPool`] struct.
trait JobSink: Send + Sync {
    fn push_job(&self, job: Job) -> Result<(), ()>;
    fn close_sink(&self);
}

impl<A: Send + 'static> JobSink for BoundedQueue<Work<A>> {
    fn push_job(&self, job: Job) -> Result<(), ()> {
        self.push(Work::Acs(job)).map_err(|_| ())
    }
    fn close_sink(&self) {
        self.close();
    }
}

/// Holder for an optional [`FaultPlan`], designed so the worker hot
/// loop pays exactly one relaxed atomic load per job while no plan is
/// installed (the production case) and only takes the mutex once
/// armed.
pub(crate) struct FaultCell {
    armed: AtomicBool,
    plan: Mutex<Option<Arc<FaultPlan>>>,
}

impl FaultCell {
    fn new() -> FaultCell {
        FaultCell {
            armed: AtomicBool::new(false),
            plan: Mutex::new(None),
        }
    }

    fn install(&self, plan: Option<Arc<FaultPlan>>) {
        let mut g = self.plan.lock().unwrap_or_else(PoisonError::into_inner);
        self.armed.store(plan.is_some(), Ordering::Release);
        *g = plan;
    }

    fn get(&self) -> Option<Arc<FaultPlan>> {
        if !self.armed.load(Ordering::Acquire) {
            return None;
        }
        self.plan
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .clone()
    }
}

/// If a worker panics (state factory or job handler), fail the pool
/// fast: close the queue and drop any queued work so reply senders die
/// and blocked dispatchers get "worker exited" instead of hanging
/// forever.
struct FailPoolOnPanic<A: Send + 'static>(Arc<BoundedQueue<Work<A>>>);

impl<A: Send + 'static> Drop for FailPoolOnPanic<A> {
    fn drop(&mut self) {
        if thread::panicking() {
            self.0.close();
            while self.0.pop().is_some() {}
        }
    }
}

/// A persistent pool of decode workers parameterized by a per-worker
/// kernel-state factory and a job handler (see the module docs).
pub struct WorkerPool {
    workers: usize,
    jobs: Arc<dyn JobSink>,
    stats: Arc<WorkerPoolStats>,
    faults: Arc<FaultCell>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` decode workers (`0` = one per available core).
    ///
    /// `make_state` runs once on each worker thread to build its
    /// private kernel state (so the state itself need not be `Send`);
    /// `handle_job` decodes one shard — `(state, n_pbs, llr_slice)` —
    /// into bit-packed payload words plus one confidence margin per
    /// PB.  `metric_bits` and `backend`
    /// are recorded in the pool's [`WorkerPoolStats`] (path-metric
    /// storage width and [`AcsBackend::code`](crate::simd::AcsBackend::code)
    /// for SIMD pools; `0`/`0` for scalar pools).
    pub fn spawn<S, F, H>(
        thread_prefix: &str,
        workers: usize,
        metric_bits: u64,
        backend: u64,
        make_state: F,
        handle_job: H,
    ) -> WorkerPool
    where
        S: 'static,
        F: Fn(usize) -> S + Send + Sync + 'static,
        H: Fn(&mut S, usize, &[i8]) -> (Vec<u32>, Vec<u32>) + Send + Sync + 'static,
    {
        let workers = resolve_workers(workers);
        let jobs: Arc<BoundedQueue<Work<()>>> = BoundedQueue::new(workers * 4);
        let stats = Arc::new(WorkerPoolStats::new(workers));
        stats.set_metric_bits(metric_bits);
        stats.set_backend(backend);
        let faults = Arc::new(FaultCell::new());
        let make_state = Arc::new(make_state);
        let handle_job = Arc::new(handle_job);
        let mut handles = Vec::with_capacity(workers);
        for wid in 0..workers {
            let q = Arc::clone(&jobs);
            let st = Arc::clone(&stats);
            let fc = Arc::clone(&faults);
            let mk = Arc::clone(&make_state);
            let hd = Arc::clone(&handle_job);
            handles.push(
                thread::Builder::new()
                    .name(format!("{thread_prefix}-{wid}"))
                    .spawn(move || {
                        let _guard = FailPoolOnPanic(Arc::clone(&q));
                        let mut state = (*mk)(wid);
                        while let Some(work) = q.pop() {
                            let Work::Acs(job) = work else {
                                unreachable!("fused pool never enqueues traceback jobs");
                            };
                            // fault seam: one relaxed load when unarmed
                            if let Some(plan) = fc.get() {
                                if plan.on_worker_job() {
                                    panic!("injected worker panic (fault plan)");
                                }
                            }
                            let t0 = Instant::now();
                            let (words, margins) =
                                (*hd)(&mut state, job.n_pbs, &job.llr[job.lo..job.hi]);
                            let busy = t0.elapsed();
                            st.record(wid, busy, job.n_pbs as u64);
                            // receiver may be gone if the caller bailed;
                            // the job is then moot
                            let _ = job.reply.send(JobReply {
                                seq: job.seq,
                                wid,
                                busy,
                                tb: None,
                                n_pbs: job.n_pbs,
                                words,
                                margins,
                            });
                        }
                    })
                    .expect("spawn decode worker"),
            );
        }
        WorkerPool {
            workers,
            jobs,
            stats,
            faults,
            handles,
        }
    }

    /// Spawn a pool whose shards run as two pipelined phases: a
    /// forward-ACS phase producing a detached survivor artifact (plus
    /// the per-PB margins, captured before the next forward pass
    /// overwrites the kernel's path metrics) and a traceback phase
    /// turning that artifact into bit-packed payload words.
    ///
    /// The traceback continuation goes to the *back* of the shared
    /// work queue, capacity-exempt ([`BoundedQueue::push_unbounded`] —
    /// a bounded push from inside a consumer could deadlock with every
    /// worker blocked pushing while dispatchers hold the remaining
    /// capacity).  Whichever worker frees up first pops it, so one
    /// shard's traceback overlaps the next shard's ACS; the fault seam
    /// fires on the ACS phase only, keeping job indexing identical to
    /// the fused pool's.
    pub fn spawn_split<S, A, F, HA, HT>(
        thread_prefix: &str,
        workers: usize,
        metric_bits: u64,
        backend: u64,
        make_state: F,
        acs_phase: HA,
        tb_phase: HT,
    ) -> WorkerPool
    where
        S: 'static,
        A: Send + 'static,
        F: Fn(usize) -> S + Send + Sync + 'static,
        HA: Fn(&mut S, usize, &[i8]) -> (A, Vec<u32>) + Send + Sync + 'static,
        HT: Fn(&mut S, usize, A) -> Vec<u32> + Send + Sync + 'static,
    {
        let workers = resolve_workers(workers);
        let jobs: Arc<BoundedQueue<Work<A>>> = BoundedQueue::new(workers * 4);
        let stats = Arc::new(WorkerPoolStats::new(workers));
        stats.set_metric_bits(metric_bits);
        stats.set_backend(backend);
        let faults = Arc::new(FaultCell::new());
        let make_state = Arc::new(make_state);
        let acs_phase = Arc::new(acs_phase);
        let tb_phase = Arc::new(tb_phase);
        let mut handles = Vec::with_capacity(workers);
        for wid in 0..workers {
            let q = Arc::clone(&jobs);
            let st = Arc::clone(&stats);
            let fc = Arc::clone(&faults);
            let mk = Arc::clone(&make_state);
            let ha = Arc::clone(&acs_phase);
            let ht = Arc::clone(&tb_phase);
            handles.push(
                thread::Builder::new()
                    .name(format!("{thread_prefix}-{wid}"))
                    .spawn(move || {
                        let _guard = FailPoolOnPanic(Arc::clone(&q));
                        let mut state = (*mk)(wid);
                        while let Some(work) = q.pop() {
                            match work {
                                Work::Acs(job) => {
                                    // fault seam: ACS phase only, so
                                    // `job=N` plans keep the fused
                                    // pool's job indexing
                                    if let Some(plan) = fc.get() {
                                        if plan.on_worker_job() {
                                            panic!("injected worker panic (fault plan)");
                                        }
                                    }
                                    let t0 = Instant::now();
                                    let (artifact, margins) =
                                        (*ha)(&mut state, job.n_pbs, &job.llr[job.lo..job.hi]);
                                    let busy = t0.elapsed();
                                    st.record_acs(wid, busy, job.n_pbs as u64);
                                    // queue closed => the TbJob (and its
                                    // reply sender) drops, and the
                                    // dispatcher sees "worker exited"
                                    let _ = q.push_unbounded(Work::Tb(TbJob {
                                        seq: job.seq,
                                        n_pbs: job.n_pbs,
                                        artifact,
                                        margins,
                                        acs_wid: wid,
                                        acs_busy: busy,
                                        reply: job.reply,
                                    }));
                                }
                                Work::Tb(tb) => {
                                    let t0 = Instant::now();
                                    let words = (*ht)(&mut state, tb.n_pbs, tb.artifact);
                                    let busy = t0.elapsed();
                                    st.record_tb(wid, busy);
                                    let _ = tb.reply.send(JobReply {
                                        seq: tb.seq,
                                        wid: tb.acs_wid,
                                        busy: tb.acs_busy,
                                        tb: Some((wid, busy)),
                                        n_pbs: tb.n_pbs,
                                        words,
                                        margins: tb.margins,
                                    });
                                }
                            }
                        }
                    })
                    .expect("spawn decode worker"),
            );
        }
        WorkerPool {
            workers,
            jobs,
            stats,
            faults,
            handles,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Install (or clear, with `None`) a fault-injection plan on the
    /// worker job loop (see [`serve::faults`](crate::serve::faults)).
    /// With no plan installed the loop pays one relaxed atomic load
    /// per job.
    pub fn install_fault_plan(&self, plan: Option<Arc<FaultPlan>>) {
        self.faults.install(plan);
    }

    /// Cumulative pool counters (pool lifetime; diff two snapshots for
    /// a per-stream view).
    pub fn snapshot(&self) -> WorkerSnapshot {
        self.stats.snapshot()
    }

    /// Path-metric width recorded at spawn (`0` for scalar pools).
    pub fn metric_bits(&self) -> u64 {
        self.stats.metric_bits()
    }

    /// Record the survivor-ring footprint of this pool's kernel (set
    /// once by the engine after spawn; travels through every
    /// [`WorkerSnapshot`]).
    pub fn set_survivor_footprint(&self, ring_bytes: u64, ring_stages: u64, total_stages: u64) {
        self.stats
            .set_survivor_footprint(ring_bytes, ring_stages, total_stages);
    }

    /// ACS backend code recorded at spawn (`0` for scalar pools).
    pub fn backend(&self) -> u64 {
        self.stats.backend()
    }

    /// Dispatch one batch's shard plan over the shared buffer, wait
    /// for every reply, and splice the bit-packed outputs back in plan
    /// order.  The buffer reaches workers as `Arc` clones — never
    /// copied here.  Timings: `pack` = dispatch, `k1` = decode wall,
    /// `unpack` = splice; `per_worker` carries this call's exact
    /// attribution.
    pub fn dispatch(
        &self,
        llr: &Arc<[i8]>,
        plan: &[DecodeShard],
    ) -> Result<(Vec<u32>, BatchTimings)> {
        let mut t = BatchTimings::default();
        let n_jobs = plan.len();
        let (tx, rx) = mpsc::channel::<JobReply>();

        let t0 = Instant::now();
        for (seq, s) in plan.iter().enumerate() {
            let job = Job {
                seq,
                n_pbs: s.n_pbs,
                llr: Arc::clone(llr),
                lo: s.lo,
                hi: s.hi,
                reply: tx.clone(),
            };
            if self.jobs.push_job(job).is_err() {
                bail!("decode pool already shut down");
            }
        }
        drop(tx);
        t.pack = t0.elapsed(); // dispatch only: zero input copies

        // wall time of the sharded decode (the batch's kernel phase)
        let t0 = Instant::now();
        let mut parts: Vec<Option<(Vec<u32>, Vec<u32>)>> = vec![None; n_jobs];
        let snap = self.stats.snapshot();
        let mut pool = WorkerSnapshot {
            busy: vec![Duration::ZERO; self.workers],
            acs_busy: vec![Duration::ZERO; self.workers],
            tb_busy: vec![Duration::ZERO; self.workers],
            jobs: vec![0; self.workers],
            blocks: vec![0; self.workers],
            metric_bits: snap.metric_bits,
            backend: snap.backend,
            survivor_ring_bytes: snap.survivor_ring_bytes,
            survivor_ring_stages: snap.survivor_ring_stages,
            survivor_total_stages: snap.survivor_total_stages,
        };
        for _ in 0..n_jobs {
            match rx.recv() {
                Ok(res) => {
                    pool.busy[res.wid] += res.busy;
                    pool.jobs[res.wid] += 1;
                    pool.blocks[res.wid] += res.n_pbs as u64;
                    if let Some((tb_wid, tb_busy)) = res.tb {
                        // split reply: `busy` was the ACS phase; add
                        // the traceback phase where it actually ran
                        pool.acs_busy[res.wid] += res.busy;
                        pool.busy[tb_wid] += tb_busy;
                        pool.tb_busy[tb_wid] += tb_busy;
                    }
                    parts[res.seq] = Some((res.words, res.margins));
                }
                Err(_) => bail!("decode worker exited before replying"),
            }
        }
        t.k1 = t0.elapsed();
        t.per_worker = Some(pool);

        // splice shards back into batch order (words and margins alike)
        let t0 = Instant::now();
        let total: usize = parts
            .iter()
            .map(|p| p.as_ref().map_or(0, |(w, _)| w.len()))
            .sum();
        let mut out = Vec::with_capacity(total);
        for p in parts {
            let (words, margins) = p.expect("every shard replies exactly once");
            out.extend(words);
            t.margins.extend(margins);
        }
        t.unpack = t0.elapsed();
        Ok((out, t))
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.jobs.close_sink();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A toy handler: each "PB" is one byte; decoding negates it into
    /// a word (margin = the byte itself), so splice order, margin
    /// order and attribution are all observable.
    fn toy_pool(workers: usize) -> WorkerPool {
        WorkerPool::spawn(
            "pbvd-test",
            workers,
            0,
            0,
            |_wid| 0u64, // per-worker state: decoded-job counter
            |count, n_pbs, llr| {
                *count += 1;
                assert_eq!(llr.len(), n_pbs);
                (
                    llr.iter().map(|&x| (-(x as i32)) as u32).collect(),
                    llr.iter().map(|&x| x as u32).collect(),
                )
            },
        )
    }

    #[test]
    fn dispatch_splices_in_plan_order_and_attributes() {
        let pool = toy_pool(3);
        assert_eq!(pool.workers(), 3);
        let llr: Arc<[i8]> = (0..10i8).collect::<Vec<_>>().into();
        let plan = [
            DecodeShard { n_pbs: 4, lo: 0, hi: 4 },
            DecodeShard { n_pbs: 3, lo: 4, hi: 7 },
            DecodeShard { n_pbs: 3, lo: 7, hi: 10 },
        ];
        let (words, t) = pool.dispatch(&llr, &plan).unwrap();
        let want: Vec<u32> = (0..10i32).map(|x| (-x) as u32).collect();
        assert_eq!(words, want);
        // margins splice back in the same plan order as the words
        let want_margins: Vec<u32> = (0..10u32).collect();
        assert_eq!(t.margins, want_margins);
        let pw = t.per_worker.expect("per-call attribution");
        assert_eq!(pw.total_jobs(), 3);
        assert_eq!(pw.total_blocks(), 10);
        assert_eq!(pool.snapshot().total_blocks(), 10);
    }

    /// The split twin of [`toy_pool`]: the ACS phase hands the bytes
    /// over as the artifact (margins = the bytes), the traceback phase
    /// negates them into words — same observable output as the fused
    /// toy, but run as two queued phases.
    fn toy_split_pool(workers: usize) -> WorkerPool {
        WorkerPool::spawn_split(
            "pbvd-test-split",
            workers,
            0,
            0,
            |_wid| (),
            |_: &mut (), n_pbs, llr: &[i8]| {
                assert_eq!(llr.len(), n_pbs);
                (llr.to_vec(), llr.iter().map(|&x| x as u32).collect())
            },
            |_: &mut (), n_pbs, artifact: Vec<i8>| {
                assert_eq!(artifact.len(), n_pbs);
                artifact.iter().map(|&x| (-(x as i32)) as u32).collect()
            },
        )
    }

    #[test]
    fn split_dispatch_matches_fused_and_attributes_phases() {
        let llr: Arc<[i8]> = (0..10i8).collect::<Vec<_>>().into();
        let plan = [
            DecodeShard { n_pbs: 4, lo: 0, hi: 4 },
            DecodeShard { n_pbs: 3, lo: 4, hi: 7 },
            DecodeShard { n_pbs: 3, lo: 7, hi: 10 },
        ];
        let (want_words, want_t) = toy_pool(2).dispatch(&llr, &plan).unwrap();
        for workers in [1usize, 2, 4] {
            let pool = toy_split_pool(workers);
            let (words, t) = pool.dispatch(&llr, &plan).unwrap();
            assert_eq!(words, want_words, "workers={workers}");
            assert_eq!(t.margins, want_t.margins, "workers={workers}");
            let pw = t.per_worker.expect("per-call attribution");
            assert_eq!(pw.total_jobs(), 3);
            assert_eq!(pw.total_blocks(), 10);
            // every nanosecond of busy time is attributed to a phase
            assert_eq!(
                pw.total_acs_busy() + pw.total_tb_busy(),
                pw.total_busy(),
                "workers={workers}"
            );
            // cumulative stats agree with the per-call view
            let snap = pool.snapshot();
            assert_eq!(snap.total_acs_busy() + snap.total_tb_busy(), snap.total_busy());
            assert_eq!(snap.total_jobs(), 3);
        }
    }

    #[test]
    fn split_survivor_footprint_reaches_per_call_attribution() {
        let pool = toy_split_pool(1);
        pool.set_survivor_footprint(848, 106, 148);
        let llr: Arc<[i8]> = vec![1i8; 2].into();
        let plan = [DecodeShard { n_pbs: 2, lo: 0, hi: 2 }];
        let (_, t) = pool.dispatch(&llr, &plan).unwrap();
        let pw = t.per_worker.unwrap();
        assert_eq!(pw.survivor_ring_bytes, 848);
        assert_eq!(pw.survivor_ring_stages, 106);
        assert_eq!(pw.survivor_total_stages, 148);
    }

    #[test]
    fn split_panicking_traceback_fails_dispatch_instead_of_hanging() {
        let pool = WorkerPool::spawn_split(
            "pbvd-tb-panic",
            1,
            0,
            0,
            |_| (),
            |_: &mut (), _, llr: &[i8]| (llr.to_vec(), Vec::new()),
            |_: &mut (), _, _: Vec<i8>| -> Vec<u32> { panic!("traceback down") },
        );
        let llr: Arc<[i8]> = vec![0i8; 2].into();
        let plan = [
            DecodeShard { n_pbs: 1, lo: 0, hi: 1 },
            DecodeShard { n_pbs: 1, lo: 1, hi: 2 },
        ];
        assert!(pool.dispatch(&llr, &plan).is_err());
    }

    #[test]
    fn split_fault_plan_keeps_fused_job_indexing() {
        // the fault seam fires on the ACS phase only, so `job=1`
        // selects the second *shard*, exactly as on the fused pool
        let pool = toy_split_pool(1);
        let llr: Arc<[i8]> = vec![0i8; 1].into();
        let plan = [DecodeShard { n_pbs: 1, lo: 0, hi: 1 }];
        pool.install_fault_plan(Some(Arc::new(
            FaultPlan::parse("worker_panic@job=1").unwrap(),
        )));
        assert!(pool.dispatch(&llr, &plan).is_ok(), "job 0 unaffected");
        assert!(
            pool.dispatch(&llr, &plan).is_err(),
            "job 1 must fail via the injected panic"
        );
    }

    #[test]
    fn resolve_workers_policy() {
        assert_eq!(resolve_workers(3), 3);
        assert!(resolve_workers(0) >= 1);
    }

    #[test]
    fn metric_bits_and_backend_recorded() {
        let code = crate::simd::AcsBackend::Portable.code();
        let pool = WorkerPool::spawn("pbvd-test16", 1, 16, code, |_| (), |_, _, _| {
            (Vec::new(), Vec::new())
        });
        assert_eq!(pool.metric_bits(), 16);
        assert_eq!(pool.snapshot().metric_bits, 16);
        assert_eq!(pool.backend(), code);
        assert_eq!(pool.snapshot().backend_name(), Some("portable"));
    }

    #[test]
    fn panicking_worker_fails_dispatch_instead_of_hanging() {
        // A worker panic (factory or handler) must surface as a
        // dispatch error, not a forever-blocked rx.recv().
        let pool = WorkerPool::spawn(
            "pbvd-panic",
            1,
            0,
            0,
            |_| (),
            |_: &mut (), _, _| -> (Vec<u32>, Vec<u32>) { panic!("worker down") },
        );
        let llr: Arc<[i8]> = vec![0i8; 2].into();
        let plan = [
            DecodeShard { n_pbs: 1, lo: 0, hi: 1 },
            DecodeShard { n_pbs: 1, lo: 1, hi: 2 },
        ];
        assert!(pool.dispatch(&llr, &plan).is_err());
    }

    #[test]
    fn installed_fault_plan_panics_the_selected_job() {
        let pool = toy_pool(1);
        let llr: Arc<[i8]> = vec![0i8; 1].into();
        let plan = [DecodeShard { n_pbs: 1, lo: 0, hi: 1 }];
        // job 0 decodes cleanly, job 1 is selected by the plan
        pool.install_fault_plan(Some(Arc::new(
            FaultPlan::parse("worker_panic@job=1").unwrap(),
        )));
        assert!(pool.dispatch(&llr, &plan).is_ok(), "job 0 unaffected");
        assert!(
            pool.dispatch(&llr, &plan).is_err(),
            "job 1 must fail via the injected panic"
        );
        // clearing the plan disarms the seam (the pool itself stays
        // failed after the panic — that is the supervisor's problem)
        pool.install_fault_plan(None);
        assert!(pool.dispatch(&llr, &plan).is_err(), "pool is closed");
    }

    #[test]
    fn empty_fault_plan_is_inert() {
        let pool = toy_pool(1);
        pool.install_fault_plan(Some(Arc::new(FaultPlan::parse("").unwrap())));
        let llr: Arc<[i8]> = vec![0i8; 1].into();
        let plan = [DecodeShard { n_pbs: 1, lo: 0, hi: 1 }];
        for _ in 0..4 {
            pool.dispatch(&llr, &plan).unwrap();
        }
    }

    #[test]
    fn drop_joins_workers_cleanly() {
        let pool = toy_pool(2);
        let llr: Arc<[i8]> = vec![1i8; 4].into();
        let plan = [DecodeShard { n_pbs: 4, lo: 0, hi: 4 }];
        pool.dispatch(&llr, &plan).unwrap();
        drop(pool); // close + join; must not hang or panic
    }
}
