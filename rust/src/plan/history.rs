//! The persistent performance history: a capped JSONL log of measured
//! decode throughput per (machine, engine arm, batch shape).
//!
//! One [`Observation`] per line, appended with a single `write` call
//! so concurrent appenders (two pools, a bench and a daemon) interleave
//! at line granularity.  When the file outgrows its byte cap the
//! newest half is kept (rewrite to a temp file + atomic rename).  The
//! loader skips corrupt or truncated lines instead of failing — a
//! half-written tail from a killed process must never poison the
//! planner.

use crate::json::Json;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, PoisonError};

/// Default byte cap for the on-disk log (1 MiB ≈ 4–5k observations).
pub const DEFAULT_MAX_BYTES: u64 = 1 << 20;

/// The machine profile segmenting EMA estimates: ISA architecture plus
/// core count.  Throughput measured on one machine must not steer
/// dispatch on a different one sharing the history file.
pub fn machine_profile() -> String {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    format!("{}-c{}", std::env::consts::ARCH, cores)
}

/// One measured decode: the full dispatch coordinate and the observed
/// throughput in decoded Mbps.
#[derive(Clone, Debug, PartialEq)]
pub struct Observation {
    /// Code preset (`k3`, `ccsds_k7`, ...).
    pub preset: String,
    /// Decode block D.
    pub block: usize,
    /// Decoding depth L.
    pub depth: usize,
    /// PBs per engine call (N_t).
    pub batch: usize,
    /// Arm tag: `cpu`, `par`, `simd-u32` or `simd-u16`.
    pub engine: String,
    /// Path-metric width actually run (16/32; 0 for non-SIMD arms).
    pub width: u32,
    /// Resolved ACS backend name (`scalar`, `portable`, `avx2`,
    /// `neon`; empty for non-SIMD arms).
    pub backend: String,
    /// Pool worker count the decode ran with.
    pub workers: usize,
    /// Quantizer bit width.
    pub q: u32,
    /// Measured decoded throughput, Mbps.
    pub mbps: f64,
    /// [`machine_profile`] of the measuring host.
    pub machine: String,
}

impl Observation {
    /// One JSONL row (serialized compact, one line).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("preset", Json::from(self.preset.as_str()));
        o.set("block", Json::from(self.block));
        o.set("depth", Json::from(self.depth));
        o.set("batch", Json::from(self.batch));
        o.set("engine", Json::from(self.engine.as_str()));
        o.set("width", Json::from(self.width as usize));
        o.set("backend", Json::from(self.backend.as_str()));
        o.set("workers", Json::from(self.workers));
        o.set("q", Json::from(self.q as usize));
        o.set("mbps", Json::from(self.mbps));
        o.set("machine", Json::from(self.machine.as_str()));
        o
    }

    /// Strict parse of one row; `None` on any missing or mistyped
    /// field (the loader's corrupt-line tolerance).
    pub fn from_json(j: &Json) -> Option<Observation> {
        Some(Observation {
            preset: j.get("preset")?.as_str()?.to_string(),
            block: j.get("block")?.as_usize()?,
            depth: j.get("depth")?.as_usize()?,
            batch: j.get("batch")?.as_usize()?,
            engine: j.get("engine")?.as_str()?.to_string(),
            width: u32::try_from(j.get("width")?.as_usize()?).ok()?,
            backend: j.get("backend")?.as_str()?.to_string(),
            workers: j.get("workers")?.as_usize()?,
            q: u32::try_from(j.get("q")?.as_usize()?).ok()?,
            mbps: j.get("mbps")?.as_f64()?,
            machine: j.get("machine")?.as_str()?.to_string(),
        })
    }
}

/// The capped observation log.  `path = None` keeps the history
/// in-memory only (planning still works within the process; nothing
/// persists).
pub struct PerfHistory {
    path: Option<PathBuf>,
    max_bytes: u64,
    rows: Mutex<Vec<Observation>>,
}

impl PerfHistory {
    /// Open (or start) a history.  An existing file is loaded
    /// tolerantly: unparseable or truncated lines are skipped, never
    /// errors — see the module docs.
    pub fn open(path: Option<&Path>, max_bytes: u64) -> PerfHistory {
        let mut rows = Vec::new();
        if let Some(p) = path {
            if let Ok(text) = std::fs::read_to_string(p) {
                for line in text.lines() {
                    let line = line.trim();
                    if line.is_empty() {
                        continue;
                    }
                    if let Some(o) = Json::parse(line)
                        .ok()
                        .as_ref()
                        .and_then(Observation::from_json)
                    {
                        rows.push(o);
                    }
                }
            }
        }
        PerfHistory {
            path: path.map(Path::to_path_buf),
            max_bytes: max_bytes.max(4096),
            rows: Mutex::new(rows),
        }
    }

    /// A process-local history with no backing file.
    pub fn in_memory() -> PerfHistory {
        PerfHistory::open(None, DEFAULT_MAX_BYTES)
    }

    fn lock_rows(&self) -> std::sync::MutexGuard<'_, Vec<Observation>> {
        self.rows.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Append one observation: in-memory immediately, and — when
    /// file-backed — one whole line in a single `write_all`, then a
    /// rotation check against the byte cap.
    pub fn append(&self, obs: Observation) {
        let mut line = obs.to_json().to_string();
        line.push('\n');
        self.lock_rows().push(obs);
        if let Some(p) = &self.path {
            let appended = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(p)
                .and_then(|mut f| f.write_all(line.as_bytes()));
            if appended.is_ok() {
                self.rotate_if_oversize(p);
            }
        }
    }

    /// Rotate on size: keep the newest half of the file's valid lines,
    /// written to a temp file and renamed over the log so concurrent
    /// readers always see a complete file.
    fn rotate_if_oversize(&self, p: &Path) {
        let size = std::fs::metadata(p).map(|m| m.len()).unwrap_or(0);
        if size <= self.max_bytes {
            return;
        }
        let Ok(text) = std::fs::read_to_string(p) else {
            return;
        };
        let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
        if lines.is_empty() {
            return;
        }
        let keep = (lines.len() / 2).max(1);
        let mut out = String::with_capacity(text.len() / 2 + 1);
        for l in &lines[lines.len() - keep..] {
            out.push_str(l);
            out.push('\n');
        }
        let tmp = p.with_extension("rotate.tmp");
        if std::fs::write(&tmp, out).is_ok() {
            let _ = std::fs::rename(&tmp, p);
        }
        let mut rows = self.lock_rows();
        let n = rows.len();
        if n > keep {
            rows.drain(0..n - keep);
        }
    }

    /// Every loaded + appended observation, oldest first.
    pub fn rows(&self) -> Vec<Observation> {
        self.lock_rows().clone()
    }

    pub fn len(&self) -> usize {
        self.lock_rows().len()
    }

    pub fn is_empty(&self) -> bool {
        self.lock_rows().is_empty()
    }

    /// The backing file, when persistent.
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(engine: &str, mbps: f64) -> Observation {
        Observation {
            preset: "k3".into(),
            block: 32,
            depth: 15,
            batch: 8,
            engine: engine.into(),
            width: if engine == "simd-u16" { 16 } else { 0 },
            backend: if engine.starts_with("simd") {
                "scalar".into()
            } else {
                String::new()
            },
            workers: 2,
            q: 8,
            mbps,
            machine: machine_profile(),
        }
    }

    #[test]
    fn observation_round_trips_every_field() {
        let o = obs("simd-u16", 123.456);
        let line = o.to_json().to_string();
        let back = Observation::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, o);
        // a missing field is a rejected row, not a default
        let mut j = o.to_json();
        j = match j {
            Json::Obj(mut m) => {
                m.remove("mbps");
                Json::Obj(m)
            }
            other => other,
        };
        assert!(Observation::from_json(&j).is_none());
    }

    #[test]
    fn in_memory_history_accumulates() {
        let h = PerfHistory::in_memory();
        assert!(h.is_empty());
        assert!(h.path().is_none());
        h.append(obs("cpu", 10.0));
        h.append(obs("par", 20.0));
        assert_eq!(h.len(), 2);
        assert_eq!(h.rows()[1].engine, "par");
    }
}
