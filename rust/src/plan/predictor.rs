//! Per-(machine, config-key) throughput estimates: an EMA over
//! measured observations with an eq.-(7) analytic prior for unseen
//! cells, plus the epsilon-explore arm that keeps cold backends
//! measured.

use crate::perfmodel::ThroughputModel;
use crate::plan::dispatcher::{Arm, BatchShape};
use crate::plan::history::PerfHistory;
use crate::rng::SplitMix64;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, PoisonError};

/// EMA smoothing factor: new observations move the estimate 30% of
/// the way — fast enough to track thermal / load drift, slow enough
/// that one noisy batch cannot flip a dispatch decision.
pub const EMA_ALPHA: f64 = 0.3;

/// Analytic per-core scalar ACS kernel throughput (bits/s) feeding
/// the eq.-(7) prior.  Only the *relative* order between arms matters
/// for dispatch; the first real observation replaces it.
const PRIOR_SCALAR_KERNEL_BITS_PER_S: f64 = 30.0e6;

#[derive(Clone, Copy, Debug)]
struct Ema {
    mbps: f64,
    samples: u64,
}

/// The dispatch key of one EMA cell: every shape coordinate plus the
/// arm tag, so `(machine, key)` uniquely names a measured throughput.
fn cell_key(shape: &BatchShape, arm: Arm) -> String {
    format!(
        "{}:D{}:L{}:B{}:W{}:q{}:{}",
        shape.preset, shape.block, shape.depth, shape.batch, shape.workers, shape.q,
        arm.tag()
    )
}

/// EMA throughput model for one machine profile (see module docs).
pub struct Predictor {
    machine: String,
    ema: Mutex<HashMap<String, Ema>>,
    explore_ppm: u32,
    draws: AtomicU64,
    seed: u64,
}

impl Predictor {
    /// Fold a history's rows (oldest first, matching `machine` only)
    /// into EMA cells.
    pub fn from_history(history: &PerfHistory, machine: &str, explore_ppm: u32) -> Predictor {
        let p = Predictor {
            machine: machine.to_string(),
            ema: Mutex::new(HashMap::new()),
            explore_ppm: explore_ppm.min(1_000_000),
            draws: AtomicU64::new(0),
            seed: 0x5EED_D15B,
        };
        for o in history.rows() {
            if o.machine != machine {
                continue;
            }
            let Some(arm) = Arm::from_tag(&o.engine) else {
                continue;
            };
            let shape = BatchShape {
                preset: o.preset.clone(),
                block: o.block,
                depth: o.depth,
                batch: o.batch,
                workers: o.workers,
                q: o.q,
                r: 2, // the prior's R is irrelevant here: this cell is measured
                simd_ok: true,
                u16_ok: true,
            };
            p.observe(&shape, arm, o.mbps);
        }
        p
    }

    /// The machine profile this predictor segments by.
    pub fn machine(&self) -> &str {
        &self.machine
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, HashMap<String, Ema>> {
        self.ema.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Fold one measured throughput into the cell's EMA.
    pub fn observe(&self, shape: &BatchShape, arm: Arm, mbps: f64) {
        if !mbps.is_finite() || mbps <= 0.0 {
            return;
        }
        let key = cell_key(shape, arm);
        let mut map = self.lock();
        match map.get_mut(&key) {
            Some(e) => {
                e.mbps += EMA_ALPHA * (mbps - e.mbps);
                e.samples += 1;
            }
            None => {
                map.insert(key, Ema { mbps, samples: 1 });
            }
        }
    }

    /// How many observations this cell has folded in (0 = prior only).
    pub fn samples(&self, shape: &BatchShape, arm: Arm) -> u64 {
        self.lock()
            .get(&cell_key(shape, arm))
            .map(|e| e.samples)
            .unwrap_or(0)
    }

    /// Estimated throughput (Mbps) for an arm: the EMA when measured,
    /// the eq.-(7) prior otherwise.
    pub fn estimate(&self, shape: &BatchShape, arm: Arm) -> f64 {
        if let Some(e) = self.lock().get(&cell_key(shape, arm)) {
            return e.mbps;
        }
        prior_mbps(shape, arm)
    }

    /// The epsilon-explore draw: with probability `explore_ppm` per
    /// million picks, return the *coldest* candidate (fewest samples;
    /// ties break toward the earliest arm) so unmeasured backends
    /// still get observations.  Deterministic: a counter-seeded
    /// `SplitMix64`, so a replayed decision sequence explores
    /// identically.
    pub fn maybe_explore(&self, shape: &BatchShape, arms: &[Arm]) -> Option<Arm> {
        if self.explore_ppm == 0 || arms.len() < 2 {
            return None;
        }
        let n = self.draws.fetch_add(1, Ordering::Relaxed);
        let roll = SplitMix64::new(self.seed ^ n).next_u64() % 1_000_000;
        if roll >= self.explore_ppm as u64 {
            return None;
        }
        arms.iter().copied().min_by_key(|a| self.samples(shape, *a))
    }
}

/// The analytic prior: eq. (7) with the kernel term scaled by each
/// arm's parallelism (workers × lanes) and a small coordination
/// discount for the pool-backed arms.  The prior only ranks arms
/// *relative to each other* for cold cells; a shape with no measured
/// arm at all never reaches it — the factory pins the static `Auto`
/// policy instead (see `DecoderConfig::plan_resolved_kind_width`).
pub fn prior_mbps(shape: &BatchShape, arm: Arm) -> f64 {
    let speedup = match arm {
        Arm::Golden => 1.0,
        // scalar pool: one PB per worker, 10% coordination discount
        Arm::Par => 0.9 * shape.workers.min(shape.batch).max(1) as f64,
        // lane-interleaved: workers × one lane-group in lockstep
        Arm::SimdW32 => {
            let groups = (shape.batch / crate::simd::LANES).max(1);
            0.95 * (shape.workers.min(groups).max(1) * 6) as f64
        }
        Arm::SimdW16 => {
            let groups = (shape.batch / crate::simd::LANES_U16).max(1);
            0.95 * (shape.workers.min(groups).max(1) * 10) as f64
        }
    };
    let m = ThroughputModel {
        block: shape.block,
        depth: shape.depth,
        // one i8 per symbol per stage; packed single-bit output
        u1_bytes_per_stage: shape.r.max(1) as f64,
        u2_bytes_per_bit: 1.0 / 8.0,
        // host memory bus stands in for PCI-E on the CPU arms
        bus_bytes_per_s: 16.0e9,
        kernel_bits_per_s: PRIOR_SCALAR_KERNEL_BITS_PER_S * speedup,
        streams: 1,
    };
    m.decode_throughput(shape.batch.max(1)) / 1e6
}
