//! The dispatcher: enumerates candidate engine arms for a batch
//! shape, picks the best predictor estimate (or an explore arm),
//! records observations back into the history, and answers the
//! re-evaluation cadence the serve supervisor migrates on.

use crate::config::EngineKind;
use crate::metrics::PlanStats;
use crate::plan::history::{machine_profile, Observation, PerfHistory};
use crate::plan::predictor::Predictor;
use crate::simd::MetricWidth;
use crate::trellis::Trellis;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// One dispatchable engine arm.  Ordered simplest-first: estimate
/// ties resolve toward the arm with the least machinery.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arm {
    /// Single-threaded golden CPU engine.
    Golden,
    /// Scalar butterfly-ACS worker pool.
    Par,
    /// Lane-interleaved SIMD pool at u32 metrics (8 lanes).
    SimdW32,
    /// Lane-interleaved SIMD pool at u16 metrics (16 lanes).
    SimdW16,
}

impl Arm {
    /// The history-row tag (`engine` column).
    pub fn tag(self) -> &'static str {
        match self {
            Arm::Golden => "cpu",
            Arm::Par => "par",
            Arm::SimdW32 => "simd-u32",
            Arm::SimdW16 => "simd-u16",
        }
    }

    /// Inverse of [`tag`](Arm::tag) (history-row parse).
    pub fn from_tag(s: &str) -> Option<Arm> {
        match s {
            "cpu" => Some(Arm::Golden),
            "par" => Some(Arm::Par),
            "simd-u32" => Some(Arm::SimdW32),
            "simd-u16" => Some(Arm::SimdW16),
            _ => None,
        }
    }

    /// The factory kind that builds this arm.
    pub fn kind(self) -> EngineKind {
        match self {
            Arm::Golden => EngineKind::Golden,
            Arm::Par => EngineKind::Par,
            Arm::SimdW32 | Arm::SimdW16 => EngineKind::Simd,
        }
    }

    /// The width request the factory should pin for this arm
    /// (`Auto` for the non-SIMD arms, where width is meaningless).
    pub fn width(self) -> MetricWidth {
        match self {
            Arm::SimdW32 => MetricWidth::W32,
            Arm::SimdW16 => MetricWidth::W16,
            _ => MetricWidth::Auto,
        }
    }

    /// The metric storage width for the history row (0 = non-SIMD).
    pub fn metric_bits(self) -> u32 {
        match self {
            Arm::SimdW16 => 16,
            Arm::SimdW32 => 32,
            _ => 0,
        }
    }

    /// Classify a built engine by its (stable) name: `cpu:` /
    /// `par-cpu:` / `simd-cpu:bBwWx{8,16}-backend`.  `None` for PJRT
    /// engines, which the planner does not dispatch between.
    pub fn for_engine_name(name: &str) -> Option<Arm> {
        if name.starts_with("cpu:") {
            Some(Arm::Golden)
        } else if name.starts_with("par-cpu:") {
            Some(Arm::Par)
        } else if name.starts_with("simd-cpu:") {
            Some(if name.contains("x16-") {
                Arm::SimdW16
            } else {
                Arm::SimdW32
            })
        } else {
            None
        }
    }
}

/// The resolved ACS backend encoded in a SIMD engine name
/// (`simd-cpu:bBwWxN-backend`); empty for every other engine, whose
/// history rows carry no backend column.
pub fn backend_of_engine_name(name: &str) -> &str {
    if !name.starts_with("simd-cpu:") {
        return "";
    }
    name.rsplit('-').next().unwrap_or("")
}

impl std::fmt::Display for Arm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.tag())
    }
}

/// The dispatch coordinate of one batch: geometry, pool size and the
/// SIMD eligibility the arm enumeration gates on.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchShape {
    pub preset: String,
    pub block: usize,
    pub depth: usize,
    pub batch: usize,
    /// Resolved pool worker count (a `workers = 0` request is one per
    /// core, resolved here so history rows are comparable).
    pub workers: usize,
    pub q: u32,
    /// Symbols per stage (code rate denominator), for the prior.
    pub r: usize,
    /// Whether the batch fills at least one 8-lane group.
    pub simd_ok: bool,
    /// Whether the u16 kernel is exact and fills a 16-lane group.
    pub u16_ok: bool,
}

impl BatchShape {
    /// Build the shape for an engine geometry against its trellis.
    pub fn new(
        preset: &str,
        t: &Trellis,
        batch: usize,
        block: usize,
        depth: usize,
        workers: usize,
        q: u32,
    ) -> BatchShape {
        let workers = if workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            workers
        };
        BatchShape {
            preset: preset.to_string(),
            block,
            depth,
            batch,
            workers,
            q,
            r: t.r,
            simd_ok: batch >= crate::simd::LANES,
            u16_ok: crate::simd::u16_width_eligible(t, batch, q),
        }
    }

    /// Candidate arms for this shape, simplest-first (the dispatch
    /// tie-break order).
    pub fn arms(&self) -> Vec<Arm> {
        let mut v = vec![Arm::Golden, Arm::Par];
        if self.simd_ok {
            v.push(Arm::SimdW32);
        }
        if self.u16_ok {
            v.push(Arm::SimdW16);
        }
        v
    }
}

/// One dispatch decision.
#[derive(Clone, Copy, Debug)]
pub struct Decision {
    pub arm: Arm,
    /// True when the epsilon-explore draw overrode the best estimate.
    pub explored: bool,
    /// The predictor's estimate for the chosen arm, Mbps.
    pub est_mbps: f64,
}

/// The performance-history dispatcher (see module docs).  Shared
/// between construction-time picks and the serve supervisor's
/// runtime re-evaluation; all state is internally synchronized.
pub struct Dispatcher {
    history: Arc<PerfHistory>,
    predictor: Predictor,
    stats: Arc<PlanStats>,
    reeval_batches: u64,
    groups: AtomicU64,
}

impl Dispatcher {
    /// Build from an opened history: folds its rows (for this
    /// machine's profile) into the predictor.
    pub fn new(
        history: Arc<PerfHistory>,
        explore_ppm: u32,
        reeval_batches: usize,
        stats: Arc<PlanStats>,
    ) -> Dispatcher {
        let machine = machine_profile();
        let predictor = Predictor::from_history(&history, &machine, explore_ppm);
        Dispatcher {
            history,
            predictor,
            stats,
            reeval_batches: reeval_batches as u64,
            groups: AtomicU64::new(0),
        }
    }

    pub fn history(&self) -> &Arc<PerfHistory> {
        &self.history
    }

    pub fn stats(&self) -> &Arc<PlanStats> {
        &self.stats
    }

    /// The machine profile decisions are segmented by.
    pub fn machine(&self) -> &str {
        self.predictor.machine()
    }

    /// Pick the arm for a shape: the explore draw first, otherwise
    /// the best estimate (ties toward the simplest arm).
    pub fn pick(&self, shape: &BatchShape) -> Decision {
        let arms = shape.arms();
        self.stats.record_decision();
        if let Some(arm) = self.predictor.maybe_explore(shape, &arms) {
            self.stats.record_explore_hit();
            return Decision {
                arm,
                explored: true,
                est_mbps: self.predictor.estimate(shape, arm),
            };
        }
        let mut best = arms[0];
        let mut best_est = self.predictor.estimate(shape, best);
        for &arm in &arms[1..] {
            let est = self.predictor.estimate(shape, arm);
            if est > best_est {
                best = arm;
                best_est = est;
            }
        }
        Decision {
            arm: best,
            explored: false,
            est_mbps: best_est,
        }
    }

    /// Observation count behind an arm's estimate (0 = prior only).
    pub fn samples(&self, shape: &BatchShape, arm: Arm) -> u64 {
        self.predictor.samples(shape, arm)
    }

    /// The estimate for one arm: measured EMA, or the eq.-(7)
    /// analytic prior when the cell is cold.
    pub fn estimate(&self, shape: &BatchShape, arm: Arm) -> f64 {
        self.predictor.estimate(shape, arm)
    }

    /// Fold one measured batch back in: EMA update plus a history
    /// row.  `backend` is the resolved ACS backend for SIMD arms
    /// (empty otherwise); non-finite or zero throughputs are dropped.
    pub fn observe(&self, shape: &BatchShape, arm: Arm, backend: &str, mbps: f64) {
        if !mbps.is_finite() || mbps <= 0.0 {
            return;
        }
        self.predictor.observe(shape, arm, mbps);
        self.history.append(Observation {
            preset: shape.preset.clone(),
            block: shape.block,
            depth: shape.depth,
            batch: shape.batch,
            engine: arm.tag().to_string(),
            width: arm.metric_bits(),
            backend: backend.to_string(),
            workers: shape.workers,
            q: shape.q,
            mbps,
            machine: self.machine().to_string(),
        });
    }

    /// Count one dispatched group; true every `reeval_batches`-th
    /// group (the runtime re-evaluation cadence).
    pub fn should_reeval(&self) -> bool {
        let n = self.groups.fetch_add(1, Ordering::Relaxed) + 1;
        self.reeval_batches > 0 && n % self.reeval_batches == 0
    }

    /// A width pick from *measured* history, replacing the
    /// construction-time `autotune_metric_width` calibration decode:
    /// `Some` only when both SIMD widths have at least one
    /// observation for this shape (or when eligibility alone already
    /// forces u32).  `None` means "no history — calibrate".
    pub fn width_hint(&self, shape: &BatchShape) -> Option<MetricWidth> {
        if !shape.u16_ok {
            return Some(MetricWidth::W32);
        }
        let s16 = self.predictor.samples(shape, Arm::SimdW16);
        let s32 = self.predictor.samples(shape, Arm::SimdW32);
        if s16 == 0 || s32 == 0 {
            return None;
        }
        self.stats.record_width_hint();
        let e16 = self.predictor.estimate(shape, Arm::SimdW16);
        let e32 = self.predictor.estimate(shape, Arm::SimdW32);
        Some(if e16 >= e32 {
            MetricWidth::W16
        } else {
            MetricWidth::W32
        })
    }
}
