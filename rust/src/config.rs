//! The unified decoder-construction path: one typed [`DecoderConfig`]
//! describes *any* realization of the paper's decoder — golden CPU,
//! scalar butterfly pool, lane-interleaved SIMD (either metric width,
//! any ACS backend), or the PJRT two-kernel/fused/original engines —
//! and one factory pair ([`DecoderConfig::build_engine`] /
//! [`DecoderConfig::build_coordinator`]) turns it into a running
//! engine or stream coordinator.
//!
//! Four PRs of growth had scattered construction across a zoo of
//! positional-argument constructors (`new` / `with_quantizer` /
//! `with_options` / `with_config` variants of up to 8 parameters), a
//! hand-rolled selection match in the CLI, and per-call-site argument
//! parsing.  Each new execution axis (metric width in PR 3, ACS
//! backend in PR 4) meant widening every signature.  This module
//! collapses all of that into a single carrier so the next axes the
//! ROADMAP names — the PJRT/Pallas K1 kernel, u8 metrics, pool
//! work-stealing — land as **one enum variant plus one match arm**:
//!
//! * [`DecoderConfig`] — builder-style struct: code/geometry
//!   (`preset`, `batch`, `block`, `depth`) plus execution (`workers`,
//!   [`EngineKind`], [`MetricWidth`], [`BackendChoice`], quantizer
//!   `q`, pipeline `lanes`).
//! * [`EngineKind`] — which realization to build.  `Auto` reproduces
//!   the historical best-available policy: PJRT two-kernel when
//!   artifacts exist, otherwise the CPU worker policy (1 worker =
//!   golden engine, a batch of at least one lane-group = SIMD pool,
//!   anything else = scalar pool).
//! * Every execution enum implements [`FromStr`] and
//!   [`Display`](fmt::Display) (round-trip stable), so CLI parsing,
//!   JSON serde and log output share one vocabulary.
//! * [`DecoderConfig::resolved`] applies the environment overrides
//!   (`PBVD_SIMD_BACKEND`, `PBVD_METRIC_WIDTH`, and the daemon's
//!   `PBVD_SERVE_*` family) in exactly one place, with
//!   CLI > env > default precedence: an explicitly requested value
//!   is never overridden by the environment.
//! * [`ServeConfig`] — the `pbvd serve` daemon section (bind address,
//!   admission limit, per-stream queue depth, coalesce window, stall
//!   timeout), carried as an optional sub-object so one config file
//!   describes both the decode realization and how it is served.
//! * [`DecoderConfig::validate`] enforces the same bounds the engines
//!   assert (positive geometry, `q` in `2..=8` for the i8 engines);
//!   width/backend requests are *never* invalid — inadmissible
//!   combinations degrade through the engines' checked fallbacks,
//!   exactly as before, and the resolved pick stays visible in the
//!   engine name and pool stats.
//! * [`DecoderConfig::to_json`] / [`DecoderConfig::from_json`] — the
//!   exact resolved configuration is serializable, so bench reports
//!   (`BENCH_*.json`) and stream provenance record which realization
//!   produced a number.
//!
//! As of 0.4 this module is the *only* construction path: the
//! pre-config free functions (`coordinator::cpu_engine_for_workers`,
//! `coordinator::cpu_engine_for_workers_cfg`,
//! `coordinator::best_available_coordinator`) and
//! `SimdCpuEngine::with_options`, deprecated in 0.3, have been
//! removed.  Every in-tree call site — CLI, daemon, coordinator
//! fallback, benches, tests, examples — goes through this module.
//!
//! ```no_run
//! use pbvd::config::{DecoderConfig, EngineKind};
//! use pbvd::coordinator::DecodeEngine; // for engine.name()
//!
//! let cfg = DecoderConfig::new("ccsds_k7")
//!     .batch(32)
//!     .block(64)
//!     .depth(42)
//!     .workers(0) // 0 = one decode worker per core
//!     .lanes(3)
//!     .engine(EngineKind::Auto);
//! let coord = cfg.build_coordinator(None).unwrap();
//! let llr = vec![0i32; 2 * 10_000];
//! let (bits, stats) = coord.decode_stream(&llr).unwrap();
//! assert_eq!(bits.len(), 10_000);
//! println!("{} -> {:.2} Mbps", coord.engine.name(), stats.throughput_mbps());
//! ```

use crate::coordinator::{
    CpuEngine, DecodeEngine, FusedEngine, OrigEngine, StreamCoordinator, TwoKernelEngine,
};
use crate::json::Json;
use crate::par::ParCpuEngine;
use crate::runtime::Registry;
use crate::simd::{BackendChoice, MetricWidth, SimdCpuEngine, SimdTuning};
use crate::trellis::Trellis;
use anyhow::{anyhow, Result};
use std::fmt;
use std::str::FromStr;
use std::sync::Arc;

// ---------------------------------------------------------------------------
// Errors.
// ---------------------------------------------------------------------------

/// Validation / parse error of the decoder-configuration layer.  One
/// concrete `std::error::Error` type shared by [`DecoderConfig`] and
/// the execution enums' [`FromStr`] impls, so `?` lifts it into
/// `anyhow::Result` everywhere.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConfigError {
    msg: String,
}

impl ConfigError {
    pub(crate) fn new(msg: impl Into<String>) -> ConfigError {
        ConfigError { msg: msg.into() }
    }
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for ConfigError {}

// ---------------------------------------------------------------------------
// The serve section.
// ---------------------------------------------------------------------------

/// Client-side self-healing knobs: socket deadlines and the capped
/// exponential backoff the [`ServeClient`](crate::serve::ServeClient)
/// uses between reconnect attempts (and when honoring a
/// `retry_after` shed).  All-integer fields so the carrying
/// [`ServeConfig`] stays `Eq`/hashable-by-value.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Per-operation socket deadline in milliseconds (`0` = no
    /// deadline — the pre-0.5 block-forever behavior).
    pub io_timeout_ms: u64,
    /// Reconnect attempts before a dead connection is reported to the
    /// caller.
    pub max_reconnects: u32,
    /// First backoff delay in milliseconds.
    pub base_backoff_ms: u64,
    /// Backoff cap in milliseconds.
    pub max_backoff_ms: u64,
    /// Jitter as a percentage of the computed delay (`20` = ±20 %),
    /// decorrelating a thundering herd of resuming clients.
    pub jitter_pct: u32,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            io_timeout_ms: 5_000,
            max_reconnects: 5,
            base_backoff_ms: 25,
            max_backoff_ms: 2_000,
            jitter_pct: 20,
        }
    }
}

impl RetryPolicy {
    /// The per-operation socket deadline (`None` when disabled).
    pub fn io_timeout(&self) -> Option<std::time::Duration> {
        (self.io_timeout_ms > 0).then(|| std::time::Duration::from_millis(self.io_timeout_ms))
    }

    /// Backoff before reconnect attempt `attempt` (counted from 0):
    /// capped exponential `base * 2^attempt`, ± `jitter_pct` % drawn
    /// from `rng`.
    pub fn backoff(&self, attempt: u32, rng: &mut crate::rng::Xoshiro256) -> std::time::Duration {
        let exp = self
            .base_backoff_ms
            .saturating_mul(1u64 << attempt.min(20))
            .min(self.max_backoff_ms)
            .max(1);
        let span = exp * u64::from(self.jitter_pct) / 100;
        let ms = if span > 0 {
            // uniform in [exp - span, exp + span]
            exp - span + rng.next_below(2 * span + 1)
        } else {
            exp
        };
        std::time::Duration::from_millis(ms.max(1))
    }

    fn validate(&self) -> Result<(), ConfigError> {
        if self.jitter_pct > 100 {
            return Err(ConfigError::new(format!(
                "retry jitter_pct must be <= 100, got {}",
                self.jitter_pct
            )));
        }
        if self.max_backoff_ms < self.base_backoff_ms {
            return Err(ConfigError::new(format!(
                "retry max_backoff_ms ({}) must be >= base_backoff_ms ({})",
                self.max_backoff_ms, self.base_backoff_ms
            )));
        }
        Ok(())
    }
}

/// The `pbvd serve` daemon section of a [`DecoderConfig`]: how the
/// shared engine is exposed to concurrent client streams.
///
/// Every field is optional — `None` means "not set here", which lets
/// the single [`DecoderConfig::resolved`] pass apply the
/// `PBVD_SERVE_*` environment overrides with the same
/// **CLI > env > default** precedence the engine knobs use.  The
/// `*_or_default` accessors collapse a (possibly resolved) field to
/// the effective value the daemon runs with.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ServeConfig {
    /// Listen address (`host:port`); default `127.0.0.1:7410`.  Env:
    /// `PBVD_SERVE_BIND`.
    pub bind: Option<String>,
    /// Admission limit: concurrent client streams beyond this are
    /// refused at HELLO; default 64.  Env: `PBVD_SERVE_MAX_STREAMS`.
    pub max_streams: Option<usize>,
    /// Bounded per-stream queue depth (frames submitted but not yet
    /// acknowledged) — the backpressure knob; default 32.  Env:
    /// `PBVD_SERVE_QUEUE_DEPTH`.
    pub queue_depth: Option<usize>,
    /// Coalesce window in microseconds: how long the scheduler holds a
    /// partial lane group open for frames from *other* streams before
    /// flushing it ragged; default 500.  Env: `PBVD_SERVE_COALESCE_US`.
    pub coalesce_window_us: Option<u64>,
    /// Stall timeout in milliseconds: a session with no inbound
    /// traffic and no delivered results for this long is evicted;
    /// default 10 000.  Env: `PBVD_SERVE_STALL_MS`.
    pub stall_timeout_ms: Option<u64>,
    /// Fault-injection spec (see
    /// [`serve::faults`](crate::serve::faults) for the grammar);
    /// `None`/empty = no injection, and the seams are zero-cost.  Env:
    /// `PBVD_FAULTS`.
    pub faults: Option<String>,
    /// Overload shedding: refuse a SUBMIT with a typed `retry_after`
    /// when the scheduler's total pending frames reach this bound
    /// (`0`/`None` = shedding disabled — backpressure blocks instead).
    /// Env: `PBVD_SERVE_SHED_QUEUE`.
    pub shed_queue: Option<usize>,
    /// How long (ms) a dead connection's stream stays parked awaiting
    /// RESUME before it is retired; default 3 000, `0` = resume
    /// disabled.  Env: `PBVD_SERVE_RESUME_GRACE_MS`.
    pub resume_grace_ms: Option<u64>,
    /// Client-side retry/backoff policy (no env; set via builder or
    /// config file).
    pub retry: Option<RetryPolicy>,
}

impl ServeConfig {
    /// Default listen address.
    pub const DEFAULT_BIND: &'static str = "127.0.0.1:7410";
    /// Default admission limit.
    pub const DEFAULT_MAX_STREAMS: usize = 64;
    /// Default per-stream queue depth.
    pub const DEFAULT_QUEUE_DEPTH: usize = 32;
    /// Default coalesce window (µs).
    pub const DEFAULT_COALESCE_US: u64 = 500;
    /// Default stall timeout (ms).
    pub const DEFAULT_STALL_MS: u64 = 10_000;
    /// Default RESUME grace window (ms).
    pub const DEFAULT_RESUME_GRACE_MS: u64 = 3_000;

    /// Effective listen address.
    pub fn bind_or_default(&self) -> &str {
        self.bind.as_deref().unwrap_or(Self::DEFAULT_BIND)
    }
    /// Effective admission limit.
    pub fn max_streams_or_default(&self) -> usize {
        self.max_streams.unwrap_or(Self::DEFAULT_MAX_STREAMS)
    }
    /// Effective per-stream queue depth.
    pub fn queue_depth_or_default(&self) -> usize {
        self.queue_depth.unwrap_or(Self::DEFAULT_QUEUE_DEPTH)
    }
    /// Effective coalesce window.
    pub fn coalesce_window(&self) -> std::time::Duration {
        std::time::Duration::from_micros(
            self.coalesce_window_us.unwrap_or(Self::DEFAULT_COALESCE_US),
        )
    }
    /// Effective stall timeout.
    pub fn stall_timeout(&self) -> std::time::Duration {
        std::time::Duration::from_millis(self.stall_timeout_ms.unwrap_or(Self::DEFAULT_STALL_MS))
    }
    /// Effective fault spec (`None` when unset or empty — no
    /// injection).
    pub fn fault_spec(&self) -> Option<&str> {
        self.faults.as_deref().map(str::trim).filter(|s| !s.is_empty())
    }
    /// Effective shed bound (`0` = shedding disabled).
    pub fn shed_queue_or_default(&self) -> usize {
        self.shed_queue.unwrap_or(0)
    }
    /// Effective RESUME grace window (`None` = resume disabled).
    pub fn resume_grace(&self) -> Option<std::time::Duration> {
        let ms = self
            .resume_grace_ms
            .unwrap_or(Self::DEFAULT_RESUME_GRACE_MS);
        (ms > 0).then(|| std::time::Duration::from_millis(ms))
    }
    /// Effective client retry/backoff policy.
    pub fn retry_or_default(&self) -> RetryPolicy {
        self.retry.clone().unwrap_or_default()
    }

    pub(crate) fn is_unset(&self) -> bool {
        *self == ServeConfig::default()
    }

    fn validate(&self) -> Result<(), ConfigError> {
        if let Some(b) = &self.bind {
            if b.is_empty() {
                return Err(ConfigError::new("serve bind address must be non-empty"));
            }
        }
        if self.max_streams == Some(0) {
            return Err(ConfigError::new("serve max_streams must be at least 1"));
        }
        if self.queue_depth == Some(0) {
            return Err(ConfigError::new("serve queue_depth must be at least 1"));
        }
        if let Some(spec) = self.fault_spec() {
            crate::serve::faults::FaultPlan::parse(spec)
                .map_err(|e| ConfigError::new(e.to_string()))?;
        }
        if let Some(r) = &self.retry {
            r.validate()?;
        }
        Ok(())
    }
}

/// The online decode-integrity section of a [`DecoderConfig`]: shadow
/// auditing, backend quarantine, and the low-confidence margin floor
/// (see [`audit`](crate::audit)).
///
/// Every field is optional with the same semantics as [`ServeConfig`]:
/// `None` means "not set here", `PBVD_AUDIT_*` environment variables
/// fill unset fields in the single [`DecoderConfig::resolved`] pass,
/// and the whole section being unset means the integrity layer is off
/// and the decode path is untouched.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AuditConfig {
    /// Shadow-audit sampling rate in parts per million of decoded
    /// blocks (`1_000_000` = audit every block, `0` = auditing off);
    /// default 10 000 (1%).  Env: `PBVD_AUDIT_PPM`.
    pub sample_ppm: Option<u32>,
    /// Seed of the deterministic block sampler — same seed, same
    /// traffic, same audited blocks (replayable like a fault plan);
    /// default `0xA0D17`.  Env: `PBVD_AUDIT_SEED`.
    pub seed: Option<u64>,
    /// Whether a detected divergence quarantines the backend (forces
    /// the supervisor down the ladder and excludes the backend from
    /// rebuilds until restart); default true.  Env:
    /// `PBVD_AUDIT_QUARANTINE` (`0`/`false` disables).
    pub quarantine: Option<bool>,
    /// Confidence floor: blocks whose path-metric margin is strictly
    /// below this count as low-confidence in stats (`0` = disabled);
    /// default 0.  Env: `PBVD_AUDIT_LOW_MARGIN`.
    pub low_margin: Option<u32>,
}

impl AuditConfig {
    /// Default sampling rate (parts per million): 1% of blocks.
    pub const DEFAULT_SAMPLE_PPM: u32 = 10_000;
    /// Default sampler seed.
    pub const DEFAULT_SEED: u64 = 0xA0D17;
    /// Default low-confidence margin floor (disabled).
    pub const DEFAULT_LOW_MARGIN: u32 = 0;

    /// Effective sampling rate (ppm of decoded blocks).
    pub fn sample_ppm_or_default(&self) -> u32 {
        self.sample_ppm.unwrap_or(Self::DEFAULT_SAMPLE_PPM)
    }
    /// Effective sampler seed.
    pub fn seed_or_default(&self) -> u64 {
        self.seed.unwrap_or(Self::DEFAULT_SEED)
    }
    /// Effective quarantine policy.
    pub fn quarantine_or_default(&self) -> bool {
        self.quarantine.unwrap_or(true)
    }
    /// Effective low-confidence margin floor (`0` = disabled).
    pub fn low_margin_or_default(&self) -> u32 {
        self.low_margin.unwrap_or(Self::DEFAULT_LOW_MARGIN)
    }

    /// True when no field was set anywhere (CLI, builder, file or
    /// env): the integrity layer stays off and engines are built bare.
    pub fn is_unset(&self) -> bool {
        *self == AuditConfig::default()
    }

    fn validate(&self) -> Result<(), ConfigError> {
        if let Some(ppm) = self.sample_ppm {
            if ppm > 1_000_000 {
                return Err(ConfigError::new(format!(
                    "audit sample_ppm {ppm} out of range (0..=1000000)"
                )));
            }
        }
        Ok(())
    }
}

/// The adaptive-dispatch section of a [`DecoderConfig`]: the
/// persistent performance history, the predictor's explore rate, and
/// the runtime re-evaluation cadence (see [`plan`](crate::plan)).
///
/// Every field is optional with the same semantics as [`ServeConfig`]:
/// `None` means "not set here", `PBVD_PLAN*` / `PBVD_PERF_HISTORY`
/// environment variables fill unset fields in the single
/// [`DecoderConfig::resolved`] pass, and with planning disabled
/// (the default) `EngineKind::Auto` keeps the historical static
/// policy bit-for-bit.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PlanConfig {
    /// Whether the performance-history dispatcher drives
    /// `EngineKind::Auto` (and serve-engine migration); default
    /// false.  Env: `PBVD_PLAN` (`1`/`true` enables).
    pub enabled: Option<bool>,
    /// Path of the JSONL observation log; unset keeps the history
    /// in-memory only.  Env: `PBVD_PERF_HISTORY`.
    pub history_path: Option<String>,
    /// Re-evaluate the dispatch (and possibly migrate a live serve
    /// engine) every this many decoded groups; `0` disables runtime
    /// re-evaluation; default 64.  Env: `PBVD_PLAN_REEVAL`.
    pub reeval_batches: Option<usize>,
    /// Epsilon-explore rate in parts per million of decisions
    /// (`0` = never explore); default 20 000 (2%).  Env:
    /// `PBVD_PLAN_EXPLORE_PPM`.
    pub explore_ppm: Option<u32>,
    /// Byte cap of the on-disk history before rotation keeps the
    /// newest half; default 1 MiB.  Env: `PBVD_PLAN_HISTORY_MAX`.
    pub history_max_bytes: Option<u64>,
}

impl PlanConfig {
    /// Default runtime re-evaluation cadence (decoded groups).
    pub const DEFAULT_REEVAL_BATCHES: usize = 64;
    /// Default explore rate (parts per million of decisions): 2%.
    pub const DEFAULT_EXPLORE_PPM: u32 = 20_000;

    /// Effective planning switch.
    pub fn enabled_or_default(&self) -> bool {
        self.enabled.unwrap_or(false)
    }
    /// Effective history file path (`None` = in-memory only).
    pub fn history_path_opt(&self) -> Option<&str> {
        self.history_path.as_deref().filter(|s| !s.is_empty())
    }
    /// Effective re-evaluation cadence (`0` = construction-time only).
    pub fn reeval_batches_or_default(&self) -> usize {
        self.reeval_batches.unwrap_or(Self::DEFAULT_REEVAL_BATCHES)
    }
    /// Effective explore rate (ppm of decisions).
    pub fn explore_ppm_or_default(&self) -> u32 {
        self.explore_ppm.unwrap_or(Self::DEFAULT_EXPLORE_PPM)
    }
    /// Effective history byte cap.
    pub fn history_max_bytes_or_default(&self) -> u64 {
        self.history_max_bytes
            .unwrap_or(crate::plan::history::DEFAULT_MAX_BYTES)
    }

    /// True when no field was set anywhere (CLI, builder, file or
    /// env): the planner stays off and `Auto` is the static policy.
    pub fn is_unset(&self) -> bool {
        *self == PlanConfig::default()
    }

    fn validate(&self) -> Result<(), ConfigError> {
        if let Some(ppm) = self.explore_ppm {
            if ppm > 1_000_000 {
                return Err(ConfigError::new(format!(
                    "plan explore_ppm {ppm} out of range (0..=1000000)"
                )));
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Environment overrides.
// ---------------------------------------------------------------------------

/// The full set of `PBVD_*` environment overrides, captured as plain
/// values so the resolution policy
/// ([`DecoderConfig::resolved_env`]) is unit-testable without
/// mutating process state.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EnvOverrides {
    /// `PBVD_SIMD_BACKEND`
    pub simd_backend: Option<String>,
    /// `PBVD_METRIC_WIDTH`
    pub metric_width: Option<String>,
    /// `PBVD_SERVE_BIND`
    pub serve_bind: Option<String>,
    /// `PBVD_SERVE_MAX_STREAMS`
    pub serve_max_streams: Option<String>,
    /// `PBVD_SERVE_QUEUE_DEPTH`
    pub serve_queue_depth: Option<String>,
    /// `PBVD_SERVE_COALESCE_US`
    pub serve_coalesce_us: Option<String>,
    /// `PBVD_SERVE_STALL_MS`
    pub serve_stall_ms: Option<String>,
    /// `PBVD_FAULTS`
    pub faults: Option<String>,
    /// `PBVD_SERVE_SHED_QUEUE`
    pub serve_shed_queue: Option<String>,
    /// `PBVD_SERVE_RESUME_GRACE_MS`
    pub serve_resume_grace_ms: Option<String>,
    /// `PBVD_AUDIT_PPM`
    pub audit_ppm: Option<String>,
    /// `PBVD_AUDIT_SEED`
    pub audit_seed: Option<String>,
    /// `PBVD_AUDIT_QUARANTINE`
    pub audit_quarantine: Option<String>,
    /// `PBVD_AUDIT_LOW_MARGIN`
    pub audit_low_margin: Option<String>,
    /// `PBVD_PLAN`
    pub plan_enabled: Option<String>,
    /// `PBVD_PERF_HISTORY`
    pub perf_history: Option<String>,
    /// `PBVD_PLAN_REEVAL`
    pub plan_reeval: Option<String>,
    /// `PBVD_PLAN_EXPLORE_PPM`
    pub plan_explore_ppm: Option<String>,
    /// `PBVD_PLAN_HISTORY_MAX`
    pub plan_history_max: Option<String>,
}

impl EnvOverrides {
    /// Snapshot the overrides from the process environment.
    pub fn from_process() -> EnvOverrides {
        let var = |k: &str| std::env::var(k).ok();
        EnvOverrides {
            simd_backend: var("PBVD_SIMD_BACKEND"),
            metric_width: var("PBVD_METRIC_WIDTH"),
            serve_bind: var("PBVD_SERVE_BIND"),
            serve_max_streams: var("PBVD_SERVE_MAX_STREAMS"),
            serve_queue_depth: var("PBVD_SERVE_QUEUE_DEPTH"),
            serve_coalesce_us: var("PBVD_SERVE_COALESCE_US"),
            serve_stall_ms: var("PBVD_SERVE_STALL_MS"),
            faults: var("PBVD_FAULTS"),
            serve_shed_queue: var("PBVD_SERVE_SHED_QUEUE"),
            serve_resume_grace_ms: var("PBVD_SERVE_RESUME_GRACE_MS"),
            audit_ppm: var("PBVD_AUDIT_PPM"),
            audit_seed: var("PBVD_AUDIT_SEED"),
            audit_quarantine: var("PBVD_AUDIT_QUARANTINE"),
            audit_low_margin: var("PBVD_AUDIT_LOW_MARGIN"),
            plan_enabled: var("PBVD_PLAN"),
            perf_history: var("PBVD_PERF_HISTORY"),
            plan_reeval: var("PBVD_PLAN_REEVAL"),
            plan_explore_ppm: var("PBVD_PLAN_EXPLORE_PPM"),
            plan_history_max: var("PBVD_PLAN_HISTORY_MAX"),
        }
    }
}

/// A positive number from an env string, or `None` — invalid values
/// fall through to the default silently, the same policy
/// `PBVD_METRIC_WIDTH` has always had.
fn env_pos<T: FromStr + PartialEq + Default>(v: &Option<String>) -> Option<T> {
    v.as_deref()
        .and_then(|s| s.parse::<T>().ok())
        .filter(|n| *n != T::default())
}

// ---------------------------------------------------------------------------
// Engine selection.
// ---------------------------------------------------------------------------

/// Which PJRT executable variant a [`EngineKind::Pjrt`] engine loads
/// (the paper's Table III columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PjrtVariant {
    /// Optimized two-kernel decoder (K1 + K2, i8 in, packed bits out).
    Two,
    /// K1+K2 fused into one executable (ablation A3).
    Fused,
    /// The "original decoder" baseline (f32 in, one i32 per bit out).
    Orig,
}

/// Which decoder realization [`DecoderConfig::build_engine`] builds.
///
/// `Auto` is the historical best-available policy in one place: a
/// PJRT [`TwoKernelEngine`] when a registry with matching artifacts is
/// supplied, otherwise the CPU worker policy — `workers == 1` builds
/// the single-threaded golden [`CpuEngine`], a batch holding at least
/// one full lane-group ([`crate::simd::LANES`]) builds the
/// lane-interleaved [`SimdCpuEngine`], anything else the scalar
/// [`ParCpuEngine`].  All CPU choices are bit-identical.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// PJRT if available, else the CPU worker policy.
    Auto,
    /// Single-threaded golden [`CpuEngine`] (CLI name `cpu`).
    Golden,
    /// Sharded scalar butterfly pool ([`ParCpuEngine`]).
    Par,
    /// Lane-interleaved SIMD pool ([`SimdCpuEngine`]).
    Simd,
    /// A PJRT engine built from AOT artifacts (CLI names `two`,
    /// `fused`, `orig`).
    Pjrt(PjrtVariant),
}

impl fmt::Display for EngineKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            EngineKind::Auto => "auto",
            EngineKind::Golden => "cpu",
            EngineKind::Par => "par",
            EngineKind::Simd => "simd",
            EngineKind::Pjrt(PjrtVariant::Two) => "two",
            EngineKind::Pjrt(PjrtVariant::Fused) => "fused",
            EngineKind::Pjrt(PjrtVariant::Orig) => "orig",
        })
    }
}

impl FromStr for EngineKind {
    type Err = ConfigError;

    /// Parse the CLI vocabulary (`--engine`): `auto`, `cpu` (alias
    /// `golden`), `par`, `simd`, `two` (alias `pjrt`), `fused`,
    /// `orig`.
    fn from_str(s: &str) -> Result<EngineKind, ConfigError> {
        Ok(match s {
            "auto" => EngineKind::Auto,
            "cpu" | "golden" => EngineKind::Golden,
            "par" => EngineKind::Par,
            "simd" => EngineKind::Simd,
            "two" | "pjrt" => EngineKind::Pjrt(PjrtVariant::Two),
            "fused" => EngineKind::Pjrt(PjrtVariant::Fused),
            "orig" => EngineKind::Pjrt(PjrtVariant::Orig),
            other => {
                return Err(ConfigError::new(format!(
                    "invalid engine {other:?} (expected auto, cpu, par, simd, two, \
                     fused or orig)"
                )))
            }
        })
    }
}

/// Every [`EngineKind`] variant, in CLI-vocabulary order — drives the
/// round-trip tests and keeps "add a variant" a one-line diff here.
pub const ALL_ENGINE_KINDS: [EngineKind; 7] = [
    EngineKind::Auto,
    EngineKind::Golden,
    EngineKind::Par,
    EngineKind::Simd,
    EngineKind::Pjrt(PjrtVariant::Two),
    EngineKind::Pjrt(PjrtVariant::Fused),
    EngineKind::Pjrt(PjrtVariant::Orig),
];

// ---------------------------------------------------------------------------
// The configuration carrier.
// ---------------------------------------------------------------------------

/// One typed description of a decoder realization — code/geometry plus
/// execution — and the single construction path for every engine and
/// frontend (see the [module docs](crate::config)).
#[derive(Clone, Debug, PartialEq)]
pub struct DecoderConfig {
    /// Code preset name ([`Trellis::preset`]); purely informative when
    /// an explicit [`Trellis`] is passed to
    /// [`build_engine`](DecoderConfig::build_engine).
    pub preset: String,
    /// Parallel blocks per engine call (the paper's N_t).
    pub batch: usize,
    /// Decode block length D (payload bits per PB).
    pub block: usize,
    /// Decoding depth L (biting length is 2L).
    pub depth: usize,
    /// Decode workers for the sharded CPU pools (`0` = one per core;
    /// ignored by the golden and PJRT engines).
    pub workers: usize,
    /// Pipeline lanes of the stream coordinator (the paper's N_s
    /// CUDA-stream analogue; clamped to at least 1).
    pub lanes: usize,
    /// Which realization to build.
    pub engine: EngineKind,
    /// Path-metric width request of the SIMD engine (checked fallback
    /// to u32 when u16 is inadmissible).
    pub width: MetricWidth,
    /// ACS stage-kernel backend request of the SIMD engine (checked
    /// fallback to the detected backend).
    pub backend: BackendChoice,
    /// Quantizer bit width the LLR stream was quantized with (sets the
    /// pool kernels' branch-metric offset; `2..=8` for the i8 decode
    /// engines).
    pub q: u32,
    /// The `pbvd serve` daemon section (ignored by the one-shot
    /// frontends).
    pub serve: ServeConfig,
    /// The online decode-integrity section: shadow auditing, backend
    /// quarantine, low-confidence accounting.  Unset = layer off.
    pub audit: AuditConfig,
    /// The adaptive-dispatch section: performance history, predictor
    /// explore rate, runtime re-evaluation.  Unset = planner off and
    /// `Auto` keeps the static policy.
    pub plan: PlanConfig,
}

impl Default for DecoderConfig {
    /// The CLI defaults: CCSDS (2,1,7), B=32, D=64, L=42, auto
    /// workers, 3 lanes, auto engine/width/backend, q=8.
    fn default() -> DecoderConfig {
        DecoderConfig {
            preset: "ccsds_k7".to_string(),
            batch: 32,
            block: 64,
            depth: 42,
            workers: 0,
            lanes: 3,
            engine: EngineKind::Auto,
            width: MetricWidth::Auto,
            backend: BackendChoice::Auto,
            q: 8,
            serve: ServeConfig::default(),
            audit: AuditConfig::default(),
            plan: PlanConfig::default(),
        }
    }
}

impl DecoderConfig {
    /// Start from the defaults with a code preset.
    pub fn new(preset: &str) -> DecoderConfig {
        DecoderConfig {
            preset: preset.to_string(),
            ..DecoderConfig::default()
        }
    }

    // ---- builder ----------------------------------------------------------

    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }
    pub fn block(mut self, block: usize) -> Self {
        self.block = block;
        self
    }
    pub fn depth(mut self, depth: usize) -> Self {
        self.depth = depth;
        self
    }
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }
    pub fn lanes(mut self, lanes: usize) -> Self {
        self.lanes = lanes;
        self
    }
    pub fn engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }
    pub fn width(mut self, width: MetricWidth) -> Self {
        self.width = width;
        self
    }
    pub fn backend(mut self, backend: BackendChoice) -> Self {
        self.backend = backend;
        self
    }
    pub fn q(mut self, q: u32) -> Self {
        self.q = q;
        self
    }

    // ---- serve-section builder --------------------------------------------

    /// Daemon listen address (`host:port`).
    pub fn serve_bind(mut self, bind: impl Into<String>) -> Self {
        self.serve.bind = Some(bind.into());
        self
    }
    /// Daemon admission limit (concurrent client streams).
    pub fn max_streams(mut self, n: usize) -> Self {
        self.serve.max_streams = Some(n);
        self
    }
    /// Daemon per-stream queue depth (backpressure bound).
    pub fn stream_queue(mut self, n: usize) -> Self {
        self.serve.queue_depth = Some(n);
        self
    }
    /// Daemon coalesce window in microseconds.
    pub fn coalesce_window_us(mut self, us: u64) -> Self {
        self.serve.coalesce_window_us = Some(us);
        self
    }
    /// Daemon stall timeout in milliseconds.
    pub fn stall_timeout_ms(mut self, ms: u64) -> Self {
        self.serve.stall_timeout_ms = Some(ms);
        self
    }
    /// Fault-injection spec (see [`serve::faults`](crate::serve::faults)).
    pub fn faults(mut self, spec: impl Into<String>) -> Self {
        self.serve.faults = Some(spec.into());
        self
    }
    /// Overload-shed bound on total pending frames (`0` = disabled).
    pub fn shed_queue(mut self, n: usize) -> Self {
        self.serve.shed_queue = Some(n);
        self
    }
    /// RESUME grace window in milliseconds (`0` = resume disabled).
    pub fn resume_grace_ms(mut self, ms: u64) -> Self {
        self.serve.resume_grace_ms = Some(ms);
        self
    }
    /// Client retry/backoff policy.
    pub fn retry(mut self, policy: RetryPolicy) -> Self {
        self.serve.retry = Some(policy);
        self
    }

    // ---- audit-section builder --------------------------------------------

    /// Shadow-audit sampling rate in ppm of decoded blocks
    /// (`1_000_000` = every block, `0` = off).
    pub fn audit_ppm(mut self, ppm: u32) -> Self {
        self.audit.sample_ppm = Some(ppm);
        self
    }
    /// Deterministic audit-sampler seed.
    pub fn audit_seed(mut self, seed: u64) -> Self {
        self.audit.seed = Some(seed);
        self
    }
    /// Quarantine a backend on detected divergence.
    pub fn audit_quarantine(mut self, on: bool) -> Self {
        self.audit.quarantine = Some(on);
        self
    }
    /// Low-confidence margin floor (`0` = disabled).
    pub fn audit_low_margin(mut self, floor: u32) -> Self {
        self.audit.low_margin = Some(floor);
        self
    }

    // ---- plan-section builder ---------------------------------------------

    /// Enable (or disable) the performance-history dispatcher.
    pub fn plan_enabled(mut self, on: bool) -> Self {
        self.plan.enabled = Some(on);
        self
    }
    /// Path of the persistent JSONL performance history.
    pub fn perf_history(mut self, path: impl Into<String>) -> Self {
        self.plan.history_path = Some(path.into());
        self
    }
    /// Runtime re-evaluation cadence in decoded groups (`0` =
    /// construction-time dispatch only).
    pub fn plan_reeval(mut self, groups: usize) -> Self {
        self.plan.reeval_batches = Some(groups);
        self
    }
    /// Epsilon-explore rate in ppm of dispatch decisions (`0` = off).
    pub fn plan_explore_ppm(mut self, ppm: u32) -> Self {
        self.plan.explore_ppm = Some(ppm);
        self
    }
    /// History byte cap before rotation keeps the newest half.
    pub fn plan_history_max_bytes(mut self, bytes: u64) -> Self {
        self.plan.history_max_bytes = Some(bytes);
        self
    }

    // ---- validation -------------------------------------------------------

    /// Check the bounds the engines would otherwise assert: positive
    /// geometry and `q` within the i8 engines' `2..=8` range.  Width
    /// and backend requests are never invalid — inadmissible
    /// combinations resolve through the engines' *checked fallbacks*
    /// (u16 -> u32 when the spread bound fails or the batch cannot
    /// fill a 16-lane group; an unavailable backend -> the detected
    /// one), identical to the pre-config behavior.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.batch == 0 || self.block == 0 || self.depth == 0 {
            return Err(ConfigError::new(format!(
                "decoder geometry must be positive (batch={}, block={}, depth={})",
                self.batch, self.block, self.depth
            )));
        }
        if !(2..=8).contains(&self.q) {
            return Err(ConfigError::new(format!(
                "--q {} out of range for the i8 decode engines (2..=8)",
                self.q
            )));
        }
        self.serve.validate()?;
        self.audit.validate()?;
        self.plan.validate()?;
        Ok(())
    }

    // ---- environment-override resolution ----------------------------------

    /// Apply the environment overrides in one place, with
    /// **CLI > env > default** precedence: a field left unset (`Auto`
    /// for the engine knobs, `None` in the serve section) picks up its
    /// `PBVD_*` variable when set to a valid (and, for backends,
    /// available) value; an explicitly requested value is never
    /// overridden.  Returns the resolved copy;
    /// [`build_engine`](DecoderConfig::build_engine) calls this
    /// internally, so callers only need it to *record* the resolved
    /// configuration (e.g. [`to_json`](DecoderConfig::to_json)).
    pub fn resolved(&self) -> DecoderConfig {
        self.resolved_env(&EnvOverrides::from_process())
    }

    /// [`resolved`](DecoderConfig::resolved) restricted to the two
    /// engine-knob variables — the historical entry point, kept for
    /// callers (and tests) that only exercise backend/width
    /// precedence.
    pub fn resolved_with(
        &self,
        env_backend: Option<&str>,
        env_width: Option<&str>,
    ) -> DecoderConfig {
        self.resolved_env(&EnvOverrides {
            simd_backend: env_backend.map(str::to_string),
            metric_width: env_width.map(str::to_string),
            ..EnvOverrides::default()
        })
    }

    /// [`resolved`](DecoderConfig::resolved) with an explicit
    /// [`EnvOverrides`] snapshot, so the full precedence policy —
    /// engine knobs *and* serve section — is unit-testable without
    /// mutating process state.  Invalid values fall through to the
    /// default silently (the `PBVD_METRIC_WIDTH` policy).
    pub fn resolved_env(&self, env: &EnvOverrides) -> DecoderConfig {
        let mut c = self.clone();
        if c.width == MetricWidth::Auto {
            if let Some(w) = env
                .metric_width
                .as_deref()
                .and_then(|s| s.parse::<MetricWidth>().ok())
            {
                c.width = w;
            }
        }
        if c.backend == BackendChoice::Auto {
            // the one env-interpretation rule, shared with
            // `BackendChoice::resolve` so the recorded provenance and
            // the kernel's actual resolution can never drift apart
            if let Some(b) = BackendChoice::env_override(env.simd_backend.as_deref()) {
                c.backend = BackendChoice::Forced(b);
            }
        }
        if c.serve.bind.is_none() {
            if let Some(b) = env.serve_bind.as_deref().filter(|s| !s.is_empty()) {
                c.serve.bind = Some(b.to_string());
            }
        }
        if c.serve.max_streams.is_none() {
            c.serve.max_streams = env_pos::<usize>(&env.serve_max_streams);
        }
        if c.serve.queue_depth.is_none() {
            c.serve.queue_depth = env_pos::<usize>(&env.serve_queue_depth);
        }
        if c.serve.coalesce_window_us.is_none() {
            c.serve.coalesce_window_us = env
                .serve_coalesce_us
                .as_deref()
                .and_then(|s| s.parse::<u64>().ok());
        }
        if c.serve.stall_timeout_ms.is_none() {
            c.serve.stall_timeout_ms = env_pos::<u64>(&env.serve_stall_ms);
        }
        if c.serve.faults.is_none() {
            if let Some(f) = env.faults.as_deref().filter(|s| !s.trim().is_empty()) {
                c.serve.faults = Some(f.to_string());
            }
        }
        if c.serve.shed_queue.is_none() {
            c.serve.shed_queue = env_pos::<usize>(&env.serve_shed_queue);
        }
        if c.serve.resume_grace_ms.is_none() {
            // plain parse: an explicit 0 means "resume disabled",
            // which is distinct from unset (the 3 s default)
            c.serve.resume_grace_ms = env
                .serve_resume_grace_ms
                .as_deref()
                .and_then(|s| s.parse::<u64>().ok());
        }
        if c.audit.sample_ppm.is_none() {
            // plain parse: an explicit 0 means "auditing off", which
            // is distinct from unset (the whole layer stays off)
            c.audit.sample_ppm = env
                .audit_ppm
                .as_deref()
                .and_then(|s| s.parse::<u32>().ok())
                .filter(|&ppm| ppm <= 1_000_000);
        }
        if c.audit.seed.is_none() {
            c.audit.seed = env.audit_seed.as_deref().and_then(|s| s.parse::<u64>().ok());
        }
        if c.audit.quarantine.is_none() {
            c.audit.quarantine = env.audit_quarantine.as_deref().and_then(|s| match s {
                "1" | "true" | "on" => Some(true),
                "0" | "false" | "off" => Some(false),
                _ => None,
            });
        }
        if c.audit.low_margin.is_none() {
            c.audit.low_margin = env
                .audit_low_margin
                .as_deref()
                .and_then(|s| s.parse::<u32>().ok());
        }
        if c.plan.enabled.is_none() {
            c.plan.enabled = env.plan_enabled.as_deref().and_then(|s| match s {
                "1" | "true" | "on" => Some(true),
                "0" | "false" | "off" => Some(false),
                _ => None,
            });
        }
        if c.plan.history_path.is_none() {
            if let Some(p) = env.perf_history.as_deref().filter(|s| !s.trim().is_empty()) {
                c.plan.history_path = Some(p.to_string());
            }
        }
        if c.plan.reeval_batches.is_none() {
            // plain parse: an explicit 0 means "construction-time
            // dispatch only", which is distinct from unset (64)
            c.plan.reeval_batches = env
                .plan_reeval
                .as_deref()
                .and_then(|s| s.parse::<usize>().ok());
        }
        if c.plan.explore_ppm.is_none() {
            // plain parse: an explicit 0 means "never explore"
            c.plan.explore_ppm = env
                .plan_explore_ppm
                .as_deref()
                .and_then(|s| s.parse::<u32>().ok())
                .filter(|&ppm| ppm <= 1_000_000);
        }
        if c.plan.history_max_bytes.is_none() {
            c.plan.history_max_bytes = env_pos::<u64>(&env.plan_history_max);
        }
        c
    }

    // ---- JSON serde -------------------------------------------------------

    /// Serialize every field (enums via their [`Display`](fmt::Display)
    /// forms).  Recorded in `BENCH_*.json` reports and the `stream`
    /// command's provenance line, so a measured number is always
    /// traceable to the exact realization that produced it.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("preset", Json::from(self.preset.clone()));
        o.set("batch", Json::from(self.batch));
        o.set("block", Json::from(self.block));
        o.set("depth", Json::from(self.depth));
        o.set("workers", Json::from(self.workers));
        o.set("lanes", Json::from(self.lanes));
        o.set("engine", Json::from(self.engine.to_string()));
        o.set("metric_width", Json::from(self.width.to_string()));
        o.set("simd_backend", Json::from(self.backend.to_string()));
        o.set("q", Json::from(self.q as usize));
        if !self.serve.is_unset() {
            let mut s = Json::obj();
            if let Some(b) = &self.serve.bind {
                s.set("bind", Json::from(b.clone()));
            }
            if let Some(n) = self.serve.max_streams {
                s.set("max_streams", Json::from(n));
            }
            if let Some(n) = self.serve.queue_depth {
                s.set("queue_depth", Json::from(n));
            }
            if let Some(us) = self.serve.coalesce_window_us {
                s.set("coalesce_window_us", Json::from(us as usize));
            }
            if let Some(ms) = self.serve.stall_timeout_ms {
                s.set("stall_timeout_ms", Json::from(ms as usize));
            }
            if let Some(f) = &self.serve.faults {
                s.set("faults", Json::from(f.clone()));
            }
            if let Some(n) = self.serve.shed_queue {
                s.set("shed_queue", Json::from(n));
            }
            if let Some(ms) = self.serve.resume_grace_ms {
                s.set("resume_grace_ms", Json::from(ms as usize));
            }
            if let Some(r) = &self.serve.retry {
                let mut rj = Json::obj();
                rj.set("io_timeout_ms", Json::from(r.io_timeout_ms as usize));
                rj.set("max_reconnects", Json::from(r.max_reconnects as usize));
                rj.set("base_backoff_ms", Json::from(r.base_backoff_ms as usize));
                rj.set("max_backoff_ms", Json::from(r.max_backoff_ms as usize));
                rj.set("jitter_pct", Json::from(r.jitter_pct as usize));
                s.set("retry", rj);
            }
            o.set("serve", s);
        }
        if !self.audit.is_unset() {
            let mut a = Json::obj();
            if let Some(ppm) = self.audit.sample_ppm {
                a.set("sample_ppm", Json::from(ppm as usize));
            }
            if let Some(seed) = self.audit.seed {
                a.set("seed", Json::from(seed as usize));
            }
            if let Some(q) = self.audit.quarantine {
                a.set("quarantine", Json::from(q));
            }
            if let Some(m) = self.audit.low_margin {
                a.set("low_margin", Json::from(m as usize));
            }
            o.set("audit", a);
        }
        if !self.plan.is_unset() {
            let mut p = Json::obj();
            if let Some(on) = self.plan.enabled {
                p.set("enabled", Json::from(on));
            }
            if let Some(path) = &self.plan.history_path {
                p.set("history_path", Json::from(path.clone()));
            }
            if let Some(n) = self.plan.reeval_batches {
                p.set("reeval_batches", Json::from(n));
            }
            if let Some(ppm) = self.plan.explore_ppm {
                p.set("explore_ppm", Json::from(ppm as usize));
            }
            if let Some(b) = self.plan.history_max_bytes {
                p.set("history_max_bytes", Json::from(b as usize));
            }
            o.set("plan", p);
        }
        o
    }

    /// Inverse of [`to_json`](DecoderConfig::to_json): absent keys
    /// keep their defaults (forward compatible), present keys must
    /// parse.
    pub fn from_json(j: &Json) -> Result<DecoderConfig, ConfigError> {
        let mut c = DecoderConfig::default();
        if let Some(p) = j.get("preset").and_then(Json::as_str) {
            c.preset = p.to_string();
        }
        let num = |key: &str, dflt: usize| -> Result<usize, ConfigError> {
            match j.get(key) {
                None => Ok(dflt),
                Some(v) => v
                    .as_usize()
                    .ok_or_else(|| ConfigError::new(format!("config key {key:?} must be a non-negative integer"))),
            }
        };
        c.batch = num("batch", c.batch)?;
        c.block = num("block", c.block)?;
        c.depth = num("depth", c.depth)?;
        c.workers = num("workers", c.workers)?;
        c.lanes = num("lanes", c.lanes)?;
        c.q = u32::try_from(num("q", c.q as usize)?)
            .map_err(|_| ConfigError::new("config key \"q\" out of range for u32"))?;
        if let Some(v) = j.get("engine") {
            let s = v
                .as_str()
                .ok_or_else(|| ConfigError::new("config key \"engine\" must be a string"))?;
            c.engine = s.parse()?;
        }
        if let Some(v) = j.get("metric_width") {
            let s = v
                .as_str()
                .ok_or_else(|| ConfigError::new("config key \"metric_width\" must be a string"))?;
            c.width = s.parse()?;
        }
        if let Some(v) = j.get("simd_backend") {
            let s = v
                .as_str()
                .ok_or_else(|| ConfigError::new("config key \"simd_backend\" must be a string"))?;
            c.backend = s.parse()?;
        }
        if let Some(sv) = j.get("serve") {
            if sv.as_obj().is_none() {
                return Err(ConfigError::new("config key \"serve\" must be an object"));
            }
            if let Some(b) = sv.get("bind") {
                c.serve.bind = Some(
                    b.as_str()
                        .ok_or_else(|| {
                            ConfigError::new("config key \"serve.bind\" must be a string")
                        })?
                        .to_string(),
                );
            }
            let snum = |key: &str| -> Result<Option<usize>, ConfigError> {
                match sv.get(key) {
                    None => Ok(None),
                    Some(v) => v.as_usize().map(Some).ok_or_else(|| {
                        ConfigError::new(format!(
                            "config key \"serve.{key}\" must be a non-negative integer"
                        ))
                    }),
                }
            };
            c.serve.max_streams = snum("max_streams")?;
            c.serve.queue_depth = snum("queue_depth")?;
            c.serve.coalesce_window_us = snum("coalesce_window_us")?.map(|n| n as u64);
            c.serve.stall_timeout_ms = snum("stall_timeout_ms")?.map(|n| n as u64);
            if let Some(f) = sv.get("faults") {
                c.serve.faults = Some(
                    f.as_str()
                        .ok_or_else(|| {
                            ConfigError::new("config key \"serve.faults\" must be a string")
                        })?
                        .to_string(),
                );
            }
            c.serve.shed_queue = snum("shed_queue")?;
            c.serve.resume_grace_ms = snum("resume_grace_ms")?.map(|n| n as u64);
            if let Some(rv) = sv.get("retry") {
                if rv.as_obj().is_none() {
                    return Err(ConfigError::new("config key \"serve.retry\" must be an object"));
                }
                let rnum = |key: &str, dflt: usize| -> Result<usize, ConfigError> {
                    match rv.get(key) {
                        None => Ok(dflt),
                        Some(v) => v.as_usize().ok_or_else(|| {
                            ConfigError::new(format!(
                                "config key \"serve.retry.{key}\" must be a non-negative integer"
                            ))
                        }),
                    }
                };
                let d = RetryPolicy::default();
                c.serve.retry = Some(RetryPolicy {
                    io_timeout_ms: rnum("io_timeout_ms", d.io_timeout_ms as usize)? as u64,
                    max_reconnects: rnum("max_reconnects", d.max_reconnects as usize)? as u32,
                    base_backoff_ms: rnum("base_backoff_ms", d.base_backoff_ms as usize)? as u64,
                    max_backoff_ms: rnum("max_backoff_ms", d.max_backoff_ms as usize)? as u64,
                    jitter_pct: rnum("jitter_pct", d.jitter_pct as usize)? as u32,
                });
            }
        }
        if let Some(av) = j.get("audit") {
            if av.as_obj().is_none() {
                return Err(ConfigError::new("config key \"audit\" must be an object"));
            }
            let anum = |key: &str| -> Result<Option<usize>, ConfigError> {
                match av.get(key) {
                    None => Ok(None),
                    Some(v) => v.as_usize().map(Some).ok_or_else(|| {
                        ConfigError::new(format!(
                            "config key \"audit.{key}\" must be a non-negative integer"
                        ))
                    }),
                }
            };
            c.audit.sample_ppm = anum("sample_ppm")?.map(|n| n as u32);
            c.audit.seed = anum("seed")?.map(|n| n as u64);
            if let Some(q) = av.get("quarantine") {
                c.audit.quarantine = Some(q.as_bool().ok_or_else(|| {
                    ConfigError::new("config key \"audit.quarantine\" must be a boolean")
                })?);
            }
            c.audit.low_margin = anum("low_margin")?.map(|n| n as u32);
        }
        if let Some(pv) = j.get("plan") {
            if pv.as_obj().is_none() {
                return Err(ConfigError::new("config key \"plan\" must be an object"));
            }
            let pnum = |key: &str| -> Result<Option<usize>, ConfigError> {
                match pv.get(key) {
                    None => Ok(None),
                    Some(v) => v.as_usize().map(Some).ok_or_else(|| {
                        ConfigError::new(format!(
                            "config key \"plan.{key}\" must be a non-negative integer"
                        ))
                    }),
                }
            };
            if let Some(on) = pv.get("enabled") {
                c.plan.enabled = Some(on.as_bool().ok_or_else(|| {
                    ConfigError::new("config key \"plan.enabled\" must be a boolean")
                })?);
            }
            if let Some(p) = pv.get("history_path") {
                c.plan.history_path = Some(
                    p.as_str()
                        .ok_or_else(|| {
                            ConfigError::new("config key \"plan.history_path\" must be a string")
                        })?
                        .to_string(),
                );
            }
            c.plan.reeval_batches = pnum("reeval_batches")?;
            c.plan.explore_ppm = pnum("explore_ppm")?.map(|n| n as u32);
            c.plan.history_max_bytes = pnum("history_max_bytes")?.map(|n| n as u64);
        }
        Ok(c)
    }

    // ---- the factory ------------------------------------------------------

    /// Resolve the configured code preset into its [`Trellis`].
    pub fn trellis(&self) -> Result<Trellis> {
        Trellis::preset(&self.preset)
    }

    /// Open this configuration's performance history (the shared
    /// construction path for the factory, the serve daemon and the
    /// benches — `plan.history_path` / `PBVD_PERF_HISTORY`).
    pub fn plan_history(&self) -> Arc<crate::plan::PerfHistory> {
        let path = self.plan.history_path_opt().map(std::path::PathBuf::from);
        Arc::new(crate::plan::PerfHistory::open(
            path.as_deref(),
            self.plan.history_max_bytes_or_default(),
        ))
    }

    /// Build a dispatcher over this configuration's history, counting
    /// into `stats` (a fresh counter set when `None`).
    pub fn plan_dispatcher(
        &self,
        stats: Option<Arc<crate::metrics::PlanStats>>,
    ) -> crate::plan::Dispatcher {
        crate::plan::Dispatcher::new(
            self.plan_history(),
            self.plan.explore_ppm_or_default(),
            self.plan.reeval_batches_or_default(),
            stats.unwrap_or_default(),
        )
    }

    /// The dispatch coordinate of this configuration against its
    /// trellis (resolves `workers = 0` and the SIMD eligibility).
    pub fn batch_shape(&self, t: &Trellis) -> crate::plan::BatchShape {
        crate::plan::BatchShape::new(
            &self.preset,
            t,
            self.batch,
            self.block,
            self.depth,
            self.workers,
            self.q,
        )
    }

    /// Resolve `Auto` (and, with planning on, an `Auto` width) into
    /// the concrete CPU kind and width to construct with.
    ///
    /// With planning disabled this is *exactly* the historical static
    /// worker policy — 1 worker = the golden engine, a batch of at
    /// least one lane-group = the SIMD pool, otherwise the scalar
    /// pool — and the width passes through untouched (the pinned
    /// fallback; see tests/config_api.rs).  With planning enabled the
    /// dispatcher picks the arm from measured history; when *no* arm
    /// of this shape has an observation yet (empty history, a history
    /// from a different machine, or a never-measured geometry) the
    /// pick falls back to the same static policy — cold planning is
    /// bit-for-bit the historical behavior.  A measured width hint
    /// replaces the `autotune_metric_width` calibration decode when
    /// both widths have observations.
    fn plan_resolved_kind_width(&self, t: &Trellis) -> (EngineKind, MetricWidth) {
        let static_kind = match self.engine {
            EngineKind::Auto => match self.workers {
                1 => EngineKind::Golden,
                _ if self.batch >= crate::simd::LANES => EngineKind::Simd,
                _ => EngineKind::Par,
            },
            k => k,
        };
        if !self.plan.enabled_or_default() {
            return (static_kind, self.width);
        }
        let dispatcher = self.plan_dispatcher(None);
        let shape = self.batch_shape(t);
        if self.engine == EngineKind::Auto {
            let measured = shape
                .arms()
                .iter()
                .any(|&a| dispatcher.samples(&shape, a) > 0);
            if !measured {
                return (static_kind, self.width);
            }
            let d = dispatcher.pick(&shape);
            let width = match d.arm.width() {
                MetricWidth::Auto => self.width,
                w => w, // SIMD arm carries its width: no calibration decode
            };
            return (d.arm.kind(), width);
        }
        // explicit engine request: the kind is the user's, but an
        // `Auto` width still prefers a measured hint over calibration
        let mut width = self.width;
        if static_kind == EngineKind::Simd && width == MetricWidth::Auto {
            if let Some(w) = dispatcher.width_hint(&shape) {
                width = w;
            }
        }
        (static_kind, width)
    }

    /// The CPU engine family for an already-resolved configuration
    /// (`Auto` here means "no PJRT available": the worker policy).
    fn cpu_engine(&self, t: &Trellis) -> Arc<dyn DecodeEngine> {
        // Auto maps onto a concrete kind first (static worker policy,
        // or the plan dispatcher when enabled — see
        // `plan_resolved_kind_width`), so each engine is constructed
        // in exactly one place below — at THIS config's
        // width/backend/q (the pre-config fallback silently dropped
        // them; see tests/config_api.rs).
        let (kind, width) = self.plan_resolved_kind_width(t);
        match kind {
            EngineKind::Golden => Arc::new(CpuEngine::new(t, self.batch, self.block, self.depth)),
            EngineKind::Par => Arc::new(ParCpuEngine::with_quantizer(
                t,
                self.batch,
                self.block,
                self.depth,
                self.workers,
                self.q,
            )),
            EngineKind::Simd => Arc::new(SimdCpuEngine::with_config(
                t,
                self.batch,
                self.block,
                self.depth,
                self.workers,
                SimdTuning {
                    width,
                    q: self.q,
                    backend: self.backend,
                },
            )),
            EngineKind::Auto | EngineKind::Pjrt(_) => {
                unreachable!("resolved above / handled by build_engine_with")
            }
        }
    }

    /// Build the configured engine against an explicit trellis (which
    /// may be a synthetic [`Trellis::build`] code — `preset` is not
    /// re-resolved).  Equivalent to
    /// [`build_engine_with`](DecoderConfig::build_engine_with) without
    /// an artifact registry: PJRT kinds error, `Auto` resolves to the
    /// CPU worker policy.
    pub fn build_engine(&self, trellis: &Trellis) -> Result<Arc<dyn DecodeEngine>> {
        self.build_engine_with(trellis, None)
    }

    /// Build the configured engine, consulting `reg` for the PJRT
    /// kinds (and for `Auto`, which prefers the two-kernel PJRT
    /// engine when its artifacts load and falls back to the CPU
    /// worker policy otherwise — at this configuration's
    /// width/backend/q, never at defaults).
    pub fn build_engine_with(
        &self,
        trellis: &Trellis,
        reg: Option<&Registry>,
    ) -> Result<Arc<dyn DecodeEngine>> {
        self.validate()?;
        let c = self.resolved();
        let eng: Arc<dyn DecodeEngine> = match c.engine {
            EngineKind::Pjrt(variant) => {
                let reg = reg.ok_or_else(|| {
                    anyhow!(
                        "engine {} needs PJRT artifacts (run `make artifacts`)",
                        c.engine
                    )
                })?;
                match variant {
                    PjrtVariant::Two => Arc::new(TwoKernelEngine::from_registry(
                        reg, &trellis.name, c.batch, c.block, c.depth,
                    )?) as Arc<dyn DecodeEngine>,
                    PjrtVariant::Fused => Arc::new(FusedEngine::from_registry(
                        reg, &trellis.name, c.batch, c.block, c.depth,
                    )?),
                    PjrtVariant::Orig => Arc::new(OrigEngine::from_registry(
                        reg, &trellis.name, c.batch, c.block, c.depth,
                    )?),
                }
            }
            EngineKind::Auto => {
                let pjrt = reg.and_then(|reg| {
                    TwoKernelEngine::from_registry(reg, &trellis.name, c.batch, c.block, c.depth)
                        .ok()
                });
                match pjrt {
                    Some(eng) => Arc::new(eng),
                    None => c.cpu_engine(trellis),
                }
            }
            _ => c.cpu_engine(trellis),
        };
        // the integrity layer is strictly opt-in: engines stay bare
        // (zero overhead, zero new threads) unless the audit section
        // was set somewhere (CLI, builder, file or PBVD_AUDIT_* env)
        if c.audit.is_unset() || c.audit.sample_ppm_or_default() == 0 {
            return Ok(eng);
        }
        let auditor = std::sync::Arc::new(crate::audit::ShadowAuditor::new(
            trellis,
            eng.block(),
            eng.depth(),
            &c.audit,
        ));
        Ok(Arc::new(crate::audit::AuditedEngine::new(eng, auditor)))
    }

    /// Build a [`StreamCoordinator`] for this configuration: resolve
    /// the preset, build the engine
    /// ([`build_engine_with`](DecoderConfig::build_engine_with)), wrap
    /// it in `lanes` pipeline lanes.
    pub fn build_coordinator(&self, reg: Option<&Registry>) -> Result<StreamCoordinator> {
        let t = self.trellis()?;
        let mut coord = StreamCoordinator::new(self.build_engine_with(&t, reg)?, self.lanes);
        // with planning on, every decoded batch feeds one throughput
        // observation back into the history (see StreamCoordinator)
        let c = self.resolved();
        if c.plan.enabled_or_default() {
            coord.plan = Some((Arc::new(c.plan_dispatcher(None)), c.batch_shape(&t)));
        }
        Ok(coord)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::AcsBackend;

    #[test]
    fn engine_kind_round_trips_through_display_and_aliases() {
        for kind in ALL_ENGINE_KINDS {
            let s = kind.to_string();
            assert_eq!(s.parse::<EngineKind>().unwrap(), kind, "{s}");
        }
        // aliases map onto canonical variants
        assert_eq!("golden".parse::<EngineKind>().unwrap(), EngineKind::Golden);
        assert_eq!(
            "pjrt".parse::<EngineKind>().unwrap(),
            EngineKind::Pjrt(PjrtVariant::Two)
        );
        assert!("warp".parse::<EngineKind>().is_err());
    }

    #[test]
    fn builder_sets_every_field() {
        let cfg = DecoderConfig::new("k5")
            .batch(16)
            .block(48)
            .depth(30)
            .workers(4)
            .lanes(2)
            .engine(EngineKind::Simd)
            .width(MetricWidth::W16)
            .backend(BackendChoice::Forced(AcsBackend::Scalar))
            .q(6);
        assert_eq!(cfg.preset, "k5");
        assert_eq!(
            (cfg.batch, cfg.block, cfg.depth, cfg.workers, cfg.lanes),
            (16, 48, 30, 4, 2)
        );
        assert_eq!(cfg.engine, EngineKind::Simd);
        assert_eq!(cfg.width, MetricWidth::W16);
        assert_eq!(cfg.backend, BackendChoice::Forced(AcsBackend::Scalar));
        assert_eq!(cfg.q, 6);
    }

    #[test]
    fn validate_rejects_out_of_range_q_and_zero_geometry() {
        assert!(DecoderConfig::default().validate().is_ok());
        assert!(DecoderConfig::default().q(1).validate().is_err());
        assert!(DecoderConfig::default().q(9).validate().is_err());
        assert!(DecoderConfig::default().q(2).validate().is_ok());
        assert!(DecoderConfig::default().batch(0).validate().is_err());
        assert!(DecoderConfig::default().block(0).validate().is_err());
        assert!(DecoderConfig::default().depth(0).validate().is_err());
    }

    #[test]
    fn env_overrides_fill_auto_but_never_explicit_requests() {
        let auto = DecoderConfig::default();
        // env fills Auto fields
        let r = auto.resolved_with(Some("scalar"), Some("16"));
        assert_eq!(r.backend, BackendChoice::Forced(AcsBackend::Scalar));
        assert_eq!(r.width, MetricWidth::W16);
        // CLI wins over env
        let forced = DecoderConfig::default()
            .width(MetricWidth::W32)
            .backend(BackendChoice::Forced(AcsBackend::Portable));
        let r = forced.resolved_with(Some("scalar"), Some("16"));
        assert_eq!(r.backend, BackendChoice::Forced(AcsBackend::Portable));
        assert_eq!(r.width, MetricWidth::W32);
        // bogus env values are ignored, not errors
        let r = auto.resolved_with(Some("fast"), Some("64"));
        assert_eq!(r.backend, BackendChoice::Auto);
        assert_eq!(r.width, MetricWidth::Auto);
        // unavailable env backends are ignored (checked fallback)
        let unavailable = [AcsBackend::Avx2, AcsBackend::Neon]
            .into_iter()
            .find(|b| !b.is_available());
        if let Some(missing) = unavailable {
            let r = auto.resolved_with(Some(missing.name()), None);
            assert_eq!(r.backend, BackendChoice::Auto);
        }
        // no env: untouched
        assert_eq!(auto.resolved_with(None, None), auto);
    }

    #[test]
    fn json_round_trips_every_field() {
        let cfg = DecoderConfig::new("r3_k7")
            .batch(19)
            .block(40)
            .depth(21)
            .workers(3)
            .lanes(2)
            .engine(EngineKind::Pjrt(PjrtVariant::Fused))
            .width(MetricWidth::W16)
            .backend(BackendChoice::Forced(AcsBackend::Portable))
            .q(4);
        let j = cfg.to_json();
        let back = DecoderConfig::from_json(&j).unwrap();
        assert_eq!(back, cfg);
        // through text too (what lands in BENCH_*.json)
        let reparsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(DecoderConfig::from_json(&reparsed).unwrap(), cfg);
        // absent keys keep defaults; bad values error
        assert_eq!(
            DecoderConfig::from_json(&Json::obj()).unwrap(),
            DecoderConfig::default()
        );
        let bad = Json::parse(r#"{"engine": "warp"}"#).unwrap();
        assert!(DecoderConfig::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"batch": -3}"#).unwrap();
        assert!(DecoderConfig::from_json(&bad).is_err());
        // q beyond u32 must error, not silently wrap into range
        let bad = Json::parse(r#"{"q": 4294967300}"#).unwrap();
        assert!(DecoderConfig::from_json(&bad).is_err());
    }

    #[test]
    fn serve_builder_accessors_and_defaults() {
        let cfg = DecoderConfig::default();
        assert!(cfg.serve.is_unset());
        assert_eq!(cfg.serve.bind_or_default(), ServeConfig::DEFAULT_BIND);
        assert_eq!(cfg.serve.max_streams_or_default(), 64);
        assert_eq!(cfg.serve.queue_depth_or_default(), 32);
        assert_eq!(
            cfg.serve.coalesce_window(),
            std::time::Duration::from_micros(500)
        );
        assert_eq!(
            cfg.serve.stall_timeout(),
            std::time::Duration::from_millis(10_000)
        );
        let cfg = cfg
            .serve_bind("0.0.0.0:9000")
            .max_streams(8)
            .stream_queue(4)
            .coalesce_window_us(250)
            .stall_timeout_ms(1500);
        assert_eq!(cfg.serve.bind_or_default(), "0.0.0.0:9000");
        assert_eq!(cfg.serve.max_streams_or_default(), 8);
        assert_eq!(cfg.serve.queue_depth_or_default(), 4);
        assert_eq!(
            cfg.serve.coalesce_window(),
            std::time::Duration::from_micros(250)
        );
        assert_eq!(
            cfg.serve.stall_timeout(),
            std::time::Duration::from_millis(1500)
        );
    }

    #[test]
    fn serve_validate_bounds() {
        assert!(DecoderConfig::default().max_streams(0).validate().is_err());
        assert!(DecoderConfig::default().stream_queue(0).validate().is_err());
        assert!(DecoderConfig::default().serve_bind("").validate().is_err());
        // a zero coalesce window is a valid request: flush immediately
        assert!(DecoderConfig::default()
            .coalesce_window_us(0)
            .validate()
            .is_ok());
        assert!(DecoderConfig::default()
            .serve_bind("127.0.0.1:0")
            .max_streams(1)
            .stream_queue(1)
            .validate()
            .is_ok());
    }

    #[test]
    fn serve_env_overrides_fill_unset_but_never_explicit() {
        let env = EnvOverrides {
            serve_bind: Some("10.0.0.1:7500".into()),
            serve_max_streams: Some("16".into()),
            serve_queue_depth: Some("5".into()),
            serve_coalesce_us: Some("0".into()),
            serve_stall_ms: Some("2500".into()),
            ..EnvOverrides::default()
        };
        // env fills unset serve fields (coalesce 0 = flush immediately
        // is a meaningful override and is honored)
        let r = DecoderConfig::default().resolved_env(&env);
        assert_eq!(r.serve.bind_or_default(), "10.0.0.1:7500");
        assert_eq!(r.serve.max_streams_or_default(), 16);
        assert_eq!(r.serve.queue_depth_or_default(), 5);
        assert_eq!(r.serve.coalesce_window_us, Some(0));
        assert_eq!(r.serve.stall_timeout_ms, Some(2500));
        // CLI wins over env
        let cli = DecoderConfig::default()
            .serve_bind("127.0.0.1:7411")
            .max_streams(2)
            .stream_queue(3)
            .coalesce_window_us(100)
            .stall_timeout_ms(50);
        let r = cli.clone().resolved_env(&env);
        assert_eq!(r.serve, cli.serve);
        // invalid or degenerate env values fall through to default
        // silently (the PBVD_METRIC_WIDTH policy): garbage numbers,
        // zero limits, an empty bind
        let bad = EnvOverrides {
            serve_bind: Some("".into()),
            serve_max_streams: Some("lots".into()),
            serve_queue_depth: Some("0".into()),
            serve_coalesce_us: Some("-3".into()),
            serve_stall_ms: Some("0".into()),
            ..EnvOverrides::default()
        };
        let r = DecoderConfig::default().resolved_env(&bad);
        assert!(r.serve.is_unset());
        // and the engine knobs still resolve through the same pass
        let env = EnvOverrides {
            simd_backend: Some("scalar".into()),
            metric_width: Some("16".into()),
            ..EnvOverrides::default()
        };
        let r = DecoderConfig::default().resolved_env(&env);
        assert_eq!(r.backend, BackendChoice::Forced(AcsBackend::Scalar));
        assert_eq!(r.width, MetricWidth::W16);
    }

    #[test]
    fn serve_json_round_trips_and_stays_absent_when_unset() {
        // an unset serve section is not serialized (BENCH_*.json
        // provenance keeps its pre-0.4 shape)
        let j = DecoderConfig::default().to_json();
        assert!(j.get("serve").is_none());
        // set fields round-trip exactly
        let cfg = DecoderConfig::new("k5")
            .serve_bind("0.0.0.0:7410")
            .max_streams(10)
            .stream_queue(6)
            .coalesce_window_us(750)
            .stall_timeout_ms(3000);
        let back =
            DecoderConfig::from_json(&Json::parse(&cfg.to_json().to_string_pretty()).unwrap())
                .unwrap();
        assert_eq!(back, cfg);
        // a partially-set section leaves the rest None
        let cfg = DecoderConfig::new("k5").max_streams(3);
        let back = DecoderConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.serve.max_streams, Some(3));
        assert_eq!(back.serve.bind, None);
        // bad types error
        let bad = Json::parse(r#"{"serve": 7}"#).unwrap();
        assert!(DecoderConfig::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"serve": {"queue_depth": "deep"}}"#).unwrap();
        assert!(DecoderConfig::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"serve": {"bind": 9}}"#).unwrap();
        assert!(DecoderConfig::from_json(&bad).is_err());
    }

    #[test]
    fn robustness_fields_round_trip_builder_env_and_json() {
        // builder + accessors
        let cfg = DecoderConfig::default()
            .faults("drop_write@seq=1")
            .shed_queue(12)
            .resume_grace_ms(0)
            .retry(RetryPolicy {
                io_timeout_ms: 100,
                ..RetryPolicy::default()
            });
        assert_eq!(cfg.serve.fault_spec(), Some("drop_write@seq=1"));
        assert_eq!(cfg.serve.shed_queue_or_default(), 12);
        assert_eq!(cfg.serve.resume_grace(), None, "0 disables resume");
        assert_eq!(cfg.serve.retry_or_default().io_timeout_ms, 100);
        // defaults
        let d = ServeConfig::default();
        assert_eq!(d.fault_spec(), None);
        assert_eq!(d.shed_queue_or_default(), 0);
        assert_eq!(
            d.resume_grace(),
            Some(std::time::Duration::from_millis(
                ServeConfig::DEFAULT_RESUME_GRACE_MS
            ))
        );
        assert_eq!(d.retry_or_default(), RetryPolicy::default());
        // validation: malformed fault specs and retry bounds are
        // config errors
        assert!(DecoderConfig::default().faults("explode@now").validate().is_err());
        assert!(DecoderConfig::default()
            .faults("drop_write@seq=1")
            .validate()
            .is_ok());
        assert!(DecoderConfig::default()
            .retry(RetryPolicy {
                jitter_pct: 150,
                ..RetryPolicy::default()
            })
            .validate()
            .is_err());
        assert!(DecoderConfig::default()
            .retry(RetryPolicy {
                base_backoff_ms: 10,
                max_backoff_ms: 5,
                ..RetryPolicy::default()
            })
            .validate()
            .is_err());
        // env fills unset, never explicit
        let env = EnvOverrides {
            faults: Some("worker_panic@job=0".into()),
            serve_shed_queue: Some("9".into()),
            serve_resume_grace_ms: Some("0".into()),
            ..EnvOverrides::default()
        };
        let r = DecoderConfig::default().resolved_env(&env);
        assert_eq!(r.serve.fault_spec(), Some("worker_panic@job=0"));
        assert_eq!(r.serve.shed_queue_or_default(), 9);
        assert_eq!(r.serve.resume_grace(), None, "explicit env 0 disables resume");
        let r = cfg.clone().resolved_env(&env);
        assert_eq!(r.serve.fault_spec(), Some("drop_write@seq=1"));
        assert_eq!(r.serve.shed_queue_or_default(), 12);
        // JSON round-trip including the retry object
        let cfg = DecoderConfig::new("k5")
            .faults("seed=3;dispatch_err@group=0")
            .shed_queue(5)
            .resume_grace_ms(1200)
            .retry(RetryPolicy::default());
        let back =
            DecoderConfig::from_json(&Json::parse(&cfg.to_json().to_string_pretty()).unwrap())
                .unwrap();
        assert_eq!(back, cfg);
        let bad = Json::parse(r#"{"serve": {"retry": 4}}"#).unwrap();
        assert!(DecoderConfig::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"serve": {"faults": 7}}"#).unwrap();
        assert!(DecoderConfig::from_json(&bad).is_err());
    }

    #[test]
    fn audit_fields_round_trip_builder_env_and_json() {
        // builder + accessors
        let cfg = DecoderConfig::default()
            .audit_ppm(250_000)
            .audit_seed(7)
            .audit_quarantine(false)
            .audit_low_margin(3);
        assert!(!cfg.audit.is_unset());
        assert_eq!(cfg.audit.sample_ppm_or_default(), 250_000);
        assert_eq!(cfg.audit.seed_or_default(), 7);
        assert!(!cfg.audit.quarantine_or_default());
        assert_eq!(cfg.audit.low_margin_or_default(), 3);
        // defaults
        let d = AuditConfig::default();
        assert!(d.is_unset());
        assert_eq!(d.sample_ppm_or_default(), AuditConfig::DEFAULT_SAMPLE_PPM);
        assert_eq!(d.seed_or_default(), AuditConfig::DEFAULT_SEED);
        assert!(d.quarantine_or_default());
        assert_eq!(d.low_margin_or_default(), AuditConfig::DEFAULT_LOW_MARGIN);
        // validation: a rate above one-in-one is a config error
        assert!(DecoderConfig::default().audit_ppm(1_000_001).validate().is_err());
        assert!(DecoderConfig::default().audit_ppm(1_000_000).validate().is_ok());
        // env fills unset, never explicit
        let env = EnvOverrides {
            audit_ppm: Some("5000".into()),
            audit_seed: Some("99".into()),
            audit_quarantine: Some("off".into()),
            audit_low_margin: Some("2".into()),
            ..EnvOverrides::default()
        };
        let r = DecoderConfig::default().resolved_env(&env);
        assert_eq!(r.audit.sample_ppm, Some(5000));
        assert_eq!(r.audit.seed, Some(99));
        assert_eq!(r.audit.quarantine, Some(false));
        assert_eq!(r.audit.low_margin, Some(2));
        let r = cfg.clone().resolved_env(&env);
        assert_eq!(r.audit, cfg.audit, "CLI wins over env");
        // garbage and out-of-range env values fall through silently
        let bad = EnvOverrides {
            audit_ppm: Some("2000000".into()),
            audit_seed: Some("lots".into()),
            audit_quarantine: Some("maybe".into()),
            audit_low_margin: Some("-1".into()),
            ..EnvOverrides::default()
        };
        let r = DecoderConfig::default().resolved_env(&bad);
        assert!(r.audit.is_unset());
        // explicit env 0 = auditing off, distinct from unset
        let env = EnvOverrides {
            audit_ppm: Some("0".into()),
            ..EnvOverrides::default()
        };
        let r = DecoderConfig::default().resolved_env(&env);
        assert_eq!(r.audit.sample_ppm, Some(0));
        // JSON: absent when unset (pins the provenance shape), exact
        // round-trip when set
        assert!(DecoderConfig::default().to_json().get("audit").is_none());
        let back =
            DecoderConfig::from_json(&Json::parse(&cfg.to_json().to_string_pretty()).unwrap())
                .unwrap();
        assert_eq!(back, cfg);
        // bad types error
        let bad = Json::parse(r#"{"audit": 7}"#).unwrap();
        assert!(DecoderConfig::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"audit": {"sample_ppm": "many"}}"#).unwrap();
        assert!(DecoderConfig::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"audit": {"quarantine": 3}}"#).unwrap();
        assert!(DecoderConfig::from_json(&bad).is_err());
    }

    #[test]
    fn plan_fields_round_trip_builder_env_and_json() {
        // builder + accessors
        let cfg = DecoderConfig::default()
            .plan_enabled(true)
            .perf_history("/tmp/hist.jsonl")
            .plan_reeval(16)
            .plan_explore_ppm(1_000)
            .plan_history_max_bytes(65_536);
        assert!(!cfg.plan.is_unset());
        assert!(cfg.plan.enabled_or_default());
        assert_eq!(cfg.plan.history_path_opt(), Some("/tmp/hist.jsonl"));
        assert_eq!(cfg.plan.reeval_batches_or_default(), 16);
        assert_eq!(cfg.plan.explore_ppm_or_default(), 1_000);
        assert_eq!(cfg.plan.history_max_bytes_or_default(), 65_536);
        // defaults: planner off, in-memory history
        let d = PlanConfig::default();
        assert!(d.is_unset());
        assert!(!d.enabled_or_default());
        assert_eq!(d.history_path_opt(), None);
        assert_eq!(d.reeval_batches_or_default(), PlanConfig::DEFAULT_REEVAL_BATCHES);
        assert_eq!(d.explore_ppm_or_default(), PlanConfig::DEFAULT_EXPLORE_PPM);
        assert_eq!(
            d.history_max_bytes_or_default(),
            crate::plan::history::DEFAULT_MAX_BYTES
        );
        // validation: an explore rate above one-in-one is a config error
        assert!(DecoderConfig::default().plan_explore_ppm(1_000_001).validate().is_err());
        assert!(DecoderConfig::default().plan_explore_ppm(1_000_000).validate().is_ok());
        // env fills unset, never explicit
        let env = EnvOverrides {
            plan_enabled: Some("on".into()),
            perf_history: Some("/var/pbvd/hist.jsonl".into()),
            plan_reeval: Some("0".into()),
            plan_explore_ppm: Some("0".into()),
            plan_history_max: Some("4096".into()),
            ..EnvOverrides::default()
        };
        let r = DecoderConfig::default().resolved_env(&env);
        assert_eq!(r.plan.enabled, Some(true));
        assert_eq!(r.plan.history_path.as_deref(), Some("/var/pbvd/hist.jsonl"));
        // explicit env 0s are distinct from unset: construction-time
        // dispatch only, never explore
        assert_eq!(r.plan.reeval_batches, Some(0));
        assert_eq!(r.plan.explore_ppm, Some(0));
        assert_eq!(r.plan.history_max_bytes, Some(4096));
        let r = cfg.clone().resolved_env(&env);
        assert_eq!(r.plan, cfg.plan, "CLI wins over env");
        // garbage and out-of-range env values fall through silently
        let bad = EnvOverrides {
            plan_enabled: Some("maybe".into()),
            perf_history: Some("   ".into()),
            plan_reeval: Some("often".into()),
            plan_explore_ppm: Some("2000000".into()),
            plan_history_max: Some("-1".into()),
            ..EnvOverrides::default()
        };
        let r = DecoderConfig::default().resolved_env(&bad);
        assert!(r.plan.is_unset());
        // JSON: absent when unset (pins the provenance shape), exact
        // round-trip when set
        assert!(DecoderConfig::default().to_json().get("plan").is_none());
        let back =
            DecoderConfig::from_json(&Json::parse(&cfg.to_json().to_string_pretty()).unwrap())
                .unwrap();
        assert_eq!(back, cfg);
        // bad types error
        let bad = Json::parse(r#"{"plan": 7}"#).unwrap();
        assert!(DecoderConfig::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"plan": {"enabled": "yes"}}"#).unwrap();
        assert!(DecoderConfig::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"plan": {"reeval_batches": "often"}}"#).unwrap();
        assert!(DecoderConfig::from_json(&bad).is_err());
    }

    #[test]
    fn retry_backoff_is_capped_exponential_with_jitter() {
        let p = RetryPolicy {
            io_timeout_ms: 0,
            max_reconnects: 3,
            base_backoff_ms: 100,
            max_backoff_ms: 400,
            jitter_pct: 0,
        };
        assert_eq!(p.io_timeout(), None, "0 disables the deadline");
        let mut rng = crate::rng::Xoshiro256::seeded(1);
        let ms = |n: u64| std::time::Duration::from_millis(n);
        assert_eq!(p.backoff(0, &mut rng), ms(100));
        assert_eq!(p.backoff(1, &mut rng), ms(200));
        assert_eq!(p.backoff(2, &mut rng), ms(400));
        assert_eq!(p.backoff(9, &mut rng), ms(400), "capped at max_backoff");
        let p = RetryPolicy {
            jitter_pct: 20,
            ..p
        };
        for a in 0u32..8 {
            let d = p.backoff(a, &mut rng).as_millis() as u64;
            let exp = (100u64 << a.min(20)).min(400);
            assert!(
                d >= exp - exp / 5 && d <= exp + exp / 5,
                "attempt {a}: {d} outside ±20% of {exp}"
            );
        }
        assert_eq!(
            RetryPolicy::default().io_timeout(),
            Some(std::time::Duration::from_millis(5_000))
        );
    }

    #[test]
    fn pjrt_kind_without_registry_is_a_clean_error() {
        let t = Trellis::preset("k3").unwrap();
        for v in [PjrtVariant::Two, PjrtVariant::Fused, PjrtVariant::Orig] {
            let cfg = DecoderConfig::new("k3").engine(EngineKind::Pjrt(v));
            let err = cfg.build_engine(&t).unwrap_err();
            assert!(format!("{err}").contains("artifacts"), "{err}");
        }
    }

    #[test]
    fn auto_worker_policy_matches_the_historical_selection() {
        let t = Trellis::preset("k3").unwrap();
        let base = DecoderConfig::new("k3").block(32).depth(15);
        // workers = 1 -> golden
        let e = base.clone().batch(4).workers(1).build_engine(&t).unwrap();
        assert!(e.name().starts_with("cpu:"), "{}", e.name());
        // batch below a lane-group -> scalar pool
        let e = base.clone().batch(4).workers(3).build_engine(&t).unwrap();
        assert!(e.name().starts_with("par-cpu:"), "{}", e.name());
        assert!(e.name().contains("w3"), "{}", e.name());
        // batch >= LANES -> lane-interleaved pool
        let e = base
            .clone()
            .batch(crate::simd::LANES)
            .workers(2)
            .build_engine(&t)
            .unwrap();
        assert!(e.name().starts_with("simd-cpu:"), "{}", e.name());
    }

    #[test]
    fn explicit_kinds_build_their_engines() {
        let t = Trellis::preset("k5").unwrap();
        let base = DecoderConfig::new("k5").batch(16).block(32).depth(20).workers(2);
        let g = base.clone().engine(EngineKind::Golden).build_engine(&t).unwrap();
        assert!(g.name().starts_with("cpu:"), "{}", g.name());
        let p = base.clone().engine(EngineKind::Par).build_engine(&t).unwrap();
        assert!(p.name().starts_with("par-cpu:"), "{}", p.name());
        let s = base
            .clone()
            .engine(EngineKind::Simd)
            .width(MetricWidth::W32)
            .backend(BackendChoice::Forced(AcsBackend::Scalar))
            .build_engine(&t)
            .unwrap();
        assert!(s.name().starts_with("simd-cpu:"), "{}", s.name());
        assert!(s.name().ends_with("scalar"), "{}", s.name());
    }

    #[test]
    fn build_coordinator_resolves_preset_and_carries_lanes() {
        let cfg = DecoderConfig::new("k3").batch(4).block(32).depth(15).workers(1).lanes(2);
        let coord = cfg.build_coordinator(None).unwrap();
        assert_eq!(coord.lanes, 2);
        assert!(coord.engine.name().starts_with("cpu:"));
        assert!(DecoderConfig::new("no_such_code")
            .build_coordinator(None)
            .is_err());
    }
}
