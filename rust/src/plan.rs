//! Adaptive engine dispatch: persistent performance history, a
//! throughput predictor, and a dispatcher that makes
//! [`EngineKind::Auto`](crate::config::EngineKind::Auto) *measured*
//! instead of static.
//!
//! The paper's throughput model (eq. 7, [`crate::perfmodel`]) shows
//! the optimal engine/parallelism point depends on batch depth, block
//! geometry and the bus/kernel balance — a single static policy plus a
//! one-shot calibration decode cannot track it as the backend matrix
//! widens.  This module replaces both with three layers:
//!
//! * [`history`] — a capped, rotate-on-size JSONL log of observation
//!   rows `(preset, block, depth, batch, engine, width, backend,
//!   workers, q) → mbps`, appended from every measured batch (engines,
//!   benches and the serve daemon all feed it).  The path comes from
//!   `PBVD_PERF_HISTORY` / [`DecoderConfig`](crate::config::DecoderConfig);
//!   the loader tolerates corrupt or truncated lines.
//! * [`predictor`] — per-(machine-profile, config-key) EMA throughput
//!   estimates, falling back to an eq.-(7)
//!   [`ThroughputModel`](crate::perfmodel::ThroughputModel) analytic
//!   prior for unseen cells, with an epsilon-explore arm so cold
//!   backends still get measured.
//! * [`dispatcher`] — enumerates the candidate arms
//!   (golden / par / simd-u32 / simd-u16) for a batch shape, picks the
//!   best estimate at construction, re-evaluates every N batches at
//!   runtime, and — wired through the serve
//!   [`EngineSupervisor`](crate::serve::supervisor::EngineSupervisor) —
//!   migrates a live stream between engines mid-flight with
//!   bit-identical output (every CPU arm is proven bit-identical by
//!   `testutil::oracle_matrix`, so a swap between groups is invisible
//!   in the decoded bits).
//!
//! With planning disabled (the default) and no history file,
//! `EngineKind::Auto` reproduces the historical static policy exactly
//! — pinned by `tests/config_api.rs`.

pub mod dispatcher;
pub mod history;
pub mod predictor;

pub use dispatcher::{backend_of_engine_name, Arm, BatchShape, Decision, Dispatcher};
pub use history::{machine_profile, Observation, PerfHistory};
pub use predictor::Predictor;
