//! # pbvd — Parallel Block-based Viterbi Decoder
//!
//! A reproduction of *"A Gb/s Parallel Block-based Viterbi Decoder for
//! Convolutional Codes on GPU"* (Peng, Liu, Hou, Zhao — 2016) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 1** (`python/compile/kernels/`) — the forward-ACS (K1) and
//!   traceback (K2) Pallas kernels, AOT-lowered to HLO text.
//! * **Layer 2** (`python/compile/model.py`) — the batched decode graphs
//!   composed from the kernels.
//! * **Layer 3** (this crate) — the streaming coordinator: PB framing,
//!   batching, multi-lane (CUDA-stream analogue) pipelining, PJRT
//!   execution of the AOT artifacts, reassembly, plus every substrate
//!   the paper depends on (encoder, channel, quantizer, packing, CPU
//!   reference decoders, BER harness, throughput model).
//!
//! Python never runs on the decode path: `make artifacts` produces
//! `artifacts/*.hlo.txt` once; the `pbvd` binary is self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use pbvd::trellis::Trellis;
//! use pbvd::viterbi::CpuPbvdDecoder;
//! use pbvd::channel::{bpsk_modulate, AwgnChannel, Quantizer};
//! use pbvd::encoder::ConvEncoder;
//! use pbvd::rng::Xoshiro256;
//!
//! let trellis = Trellis::preset("ccsds_k7").unwrap();
//! let mut enc = ConvEncoder::new(&trellis);
//! let bits: Vec<u8> = (0..1000).map(|i| (i % 3 == 0) as u8).collect();
//! let coded = enc.encode(&bits);
//! let mut rng = Xoshiro256::seeded(42);
//! let mut ch = AwgnChannel::new(3.0, 0.5, &mut rng);
//! let soft = ch.transmit(&coded);
//! let llr = Quantizer::new(8).quantize(&soft);
//! let dec = CpuPbvdDecoder::new(&trellis, 512, 42);
//! let decoded = dec.decode_stream(&llr);
//! ```

pub mod ber;
pub mod bench;
pub mod channel;
pub mod cli;
pub mod coordinator;
pub mod encoder;
pub mod json;
pub mod metrics;
pub mod perfmodel;
pub mod puncture;
pub mod pipeline;
pub mod rng;
pub mod runtime;
pub mod testutil;
pub mod trellis;
pub mod viterbi;

/// Repo-relative default artifact directory.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$PBVD_ARTIFACTS` or `artifacts/`
/// relative to the current dir or the crate root.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("PBVD_ARTIFACTS") {
        return p.into();
    }
    let cwd = std::path::PathBuf::from(ARTIFACTS_DIR);
    if cwd.exists() {
        return cwd;
    }
    // fall back to the crate root (useful under `cargo test` from anywhere)
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(ARTIFACTS_DIR)
}
