//! # pbvd — Parallel Block-based Viterbi Decoder
//!
//! A reproduction of *"A Gb/s Parallel Block-based Viterbi Decoder for
//! Convolutional Codes on GPU"* (Peng, Liu, Hou, Zhao — 2016) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 1** (`python/compile/kernels/`) — the forward-ACS (K1) and
//!   traceback (K2) Pallas kernels, AOT-lowered to HLO text.
//! * **Layer 2** (`python/compile/model.py`) — the batched decode graphs
//!   composed from the kernels.
//! * **Layer 3** (this crate) — the streaming coordinator: PB framing,
//!   batching, multi-lane (CUDA-stream analogue) pipelining, PJRT
//!   execution of the AOT artifacts, reassembly, plus every substrate
//!   the paper depends on (encoder, channel, quantizer, packing, CPU
//!   reference decoders, BER harness, throughput model).
//!
//! Python never runs on the decode path: `make artifacts` produces
//! `artifacts/*.hlo.txt` once; the `pbvd` binary is self-contained.
//!
//! ## Workspace layout
//!
//! The repository is a Cargo workspace rooted one level above this
//! crate: `rust/` (this crate, `pbvd`), `rust/vendor/` (offline shims
//! for `anyhow` and the `xla` PJRT bindings), `examples/` (repo-root
//! example binaries, wired in via explicit `[[example]]` paths),
//! `python/` (the Pallas/JAX kernel layers) and `artifacts/` (AOT HLO
//! exports).  `cargo build --release && cargo test -q` works from the
//! repo root or from `rust/`; `make ci` runs the full local CI sweep.
//!
//! ## Quick start
//!
//! Every decoder realization — golden CPU, scalar pool,
//! lane-interleaved SIMD, the PJRT engines — is described by one typed
//! [`config::DecoderConfig`] and built by its factory
//! ([`build_engine`](config::DecoderConfig::build_engine) /
//! [`build_coordinator`](config::DecoderConfig::build_coordinator)):
//!
//! ```no_run
//! use pbvd::channel::{AwgnChannel, Quantizer};
//! use pbvd::config::{DecoderConfig, EngineKind};
//! use pbvd::coordinator::DecodeEngine; // for engine.name()
//! use pbvd::encoder::ConvEncoder;
//! use pbvd::rng::Xoshiro256;
//! use pbvd::trellis::Trellis;
//!
//! // transmit side: encode, add noise, quantize to 8-bit LLRs
//! let trellis = Trellis::preset("ccsds_k7").unwrap();
//! let mut enc = ConvEncoder::new(&trellis);
//! let bits: Vec<u8> = (0..1000).map(|i| (i % 3 == 0) as u8).collect();
//! let coded = enc.encode(&bits);
//! let mut rng = Xoshiro256::seeded(42);
//! let mut ch = AwgnChannel::new(3.0, 0.5, &mut rng);
//! let llr = Quantizer::new(8).quantize(&ch.transmit(&coded));
//!
//! // receive side: one config, one construction path
//! let cfg = DecoderConfig::new("ccsds_k7")
//!     .batch(32)      // PBs per engine call (N_t)
//!     .block(64)      // decode block D
//!     .depth(42)      // decoding depth L
//!     .workers(0)     // CPU pools: one decode worker per core
//!     .lanes(3)       // pipeline lanes (N_s streams)
//!     .engine(EngineKind::Auto); // PJRT if artifacts exist, else CPU
//! let coord = cfg.build_coordinator(None).unwrap();
//! let (decoded, stats) = coord.decode_stream(&llr).unwrap();
//! assert_eq!(decoded, bits);
//! println!("{}: {:.2} Mbps", coord.engine.name(), stats.throughput_mbps());
//! ```
//!
//! ## Multi-threaded + SIMD decoding
//!
//! The serving-scale path shards each batch's parallel blocks across a
//! persistent worker pool ([`pool::WorkerPool`], shared by both
//! sharded engines).  [`par::ParCpuEngine`]
//! ([`EngineKind::Par`](config::EngineKind::Par)) runs the scalar
//! butterfly-ACS kernel per worker, bit-identical to the golden model
//! above.  When a batch holds at least one full lane-group
//! ([`simd::LANES`] = 8 PBs), the lane-interleaved
//! [`simd::SimdCpuEngine`]
//! ([`EngineKind::Simd`](config::EngineKind::Simd)) steps a whole
//! lane-group through the trellis in lockstep per worker
//! (`[state][lane]` SoA metrics, one lane-mask decision word per
//! state, with a per-arch ACS backend seam — [`simd::backend`]:
//! scalar / portable lane-chunk / AVX2 / NEON behind the
//! `simd-intrinsics` feature, runtime-detected and forceable via the
//! config's `backend` field or CLI `--simd-backend`) — still
//! bit-identical.  The path-metric width is autotuned at engine
//! construction: u16 × 16 lanes when the saturation spread bound
//! admits it (2x ACS throughput per 256-bit vector), u32 × 8 lanes
//! otherwise — forceable with the config's `width` field or CLI
//! `--metric-width {auto,16,32}`.  From the CLI:
//! `pbvd stream --engine simd --workers 8`, or `pbvd scale` for the
//! worker-scaling ladder.  Programmatically:
//!
//! ```no_run
//! use pbvd::config::{DecoderConfig, EngineKind};
//! use pbvd::simd::{BackendChoice, MetricWidth};
//!
//! // 16-lane u16 SIMD pool, 8 workers, forced portable ACS backend
//! let cfg = DecoderConfig::new("ccsds_k7")
//!     .batch(32)
//!     .block(64)
//!     .depth(42)
//!     .workers(8)
//!     .engine(EngineKind::Simd)
//!     .width(MetricWidth::W16)
//!     .backend("portable".parse::<BackendChoice>().unwrap());
//! let coord = cfg.build_coordinator(None).unwrap();
//! let llr = vec![0i32; 2 * 10_000];
//! let (bits, stats) = coord.decode_stream(&llr).unwrap();
//! assert_eq!(bits.len(), 10_000);
//! println!("{}", stats.per_worker.unwrap().summary());
//! ```
//!
//! As of 0.4 the config factory is the *only* construction path: the
//! 0.3-deprecated free functions (`cpu_engine_for_workers`,
//! `best_available_coordinator`, ...) are gone.  The same factory also
//! backs the [`serve`] daemon — `pbvd serve` exposes one shared engine
//! to many TCP client streams, coalescing their frames into full lane
//! groups (see the `serve` module docs).

pub mod audit;
pub mod ber;
pub mod bench;
pub mod channel;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod encoder;
pub mod json;
pub mod metrics;
pub mod par;
pub mod perfmodel;
pub mod plan;
pub mod pool;
pub mod puncture;
pub mod pipeline;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod simd;
pub mod testutil;
pub mod trellis;
pub mod viterbi;

/// Repo-relative default artifact directory.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory, trying in order: `$PBVD_ARTIFACTS`,
/// `artifacts/` under the current directory, under the crate root
/// (`rust/`), and under the workspace root (one level up — `make
/// artifacts` writes there, and `cargo` may be invoked from either).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("PBVD_ARTIFACTS") {
        return p.into();
    }
    let cwd = std::path::PathBuf::from(ARTIFACTS_DIR);
    if cwd.exists() {
        return cwd;
    }
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let crate_local = manifest.join(ARTIFACTS_DIR);
    if crate_local.exists() {
        return crate_local;
    }
    let workspace = manifest.join("..").join(ARTIFACTS_DIR);
    if workspace.exists() {
        return workspace;
    }
    cwd
}
