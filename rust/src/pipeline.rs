//! Thread-pool + bounded-channel pipeline substrate (tokio is not
//! available offline — DESIGN.md §3).
//!
//! The coordinator models the paper's CUDA streams as pipeline *lanes*:
//! each lane runs `pack -> K1 -> K2 -> unpack` for a batch while other
//! lanes are in different stages, overlapping host work with PJRT
//! execution exactly as async H2D/kernel/D2H copies overlap on a GPU.
//!
//! Building blocks:
//! * [`BoundedQueue`] — MPMC blocking queue with capacity (backpressure).
//! * [`WorkerPool`] — fixed threads draining a closure queue.
//! * [`run_pipeline`] — generic staged pipeline over an input iterator.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread;

/// Poison-tolerant lock: a panicking pipeline job must not make every
/// later queue operation panic too (the supervisor retries the batch;
/// the queue state itself is a plain `VecDeque` + flags, always valid).
/// Shared crate-wide so every non-test mutex under contention with
/// possibly-panicking holders (the PJRT executable cache, the fault
/// cell) uses the same policy instead of `.lock().unwrap()`.
pub(crate) fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

// ---------------------------------------------------------------------------
// Bounded MPMC queue.
// ---------------------------------------------------------------------------

struct QueueInner<T> {
    buf: VecDeque<T>,
    closed: bool,
}

/// A blocking bounded queue.  `push` blocks when full (backpressure);
/// `pop` blocks when empty and returns `None` once closed and drained.
pub struct BoundedQueue<T> {
    inner: Mutex<QueueInner<T>>,
    cap: usize,
    not_full: Condvar,
    not_empty: Condvar,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Arc<Self> {
        assert!(cap > 0);
        Arc::new(Self {
            inner: Mutex::new(QueueInner {
                buf: VecDeque::with_capacity(cap),
                closed: false,
            }),
            cap,
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        })
    }

    /// Blocking push.  Returns `Err(item)` if the queue was closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut g = relock(&self.inner);
        loop {
            if g.closed {
                return Err(item);
            }
            if g.buf.len() < self.cap {
                g.buf.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Non-blocking push that ignores the capacity bound.  Returns
    /// `Err(item)` only if the queue was closed.
    ///
    /// For *continuation* jobs enqueued by the queue's own consumers:
    /// a worker that pushed with the blocking, bounded [`push`] while
    /// every other worker was also blocked pushing would deadlock —
    /// nobody is left to pop.  Capacity-exempt continuations keep the
    /// pipeline moving; backpressure still applies at the producer
    /// boundary where `push` is used.
    pub fn push_unbounded(&self, item: T) -> Result<(), T> {
        let mut g = relock(&self.inner);
        if g.closed {
            return Err(item);
        }
        g.buf.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop.  `None` once the queue is closed and empty.
    pub fn pop(&self) -> Option<T> {
        let mut g = relock(&self.inner);
        loop {
            if let Some(item) = g.buf.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Close: pending pops drain remaining items then observe the end.
    pub fn close(&self) {
        let mut g = relock(&self.inner);
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        relock(&self.inner).buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// Worker pool.
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool.  `scope`-less: jobs must be `'static`.
pub struct WorkerPool {
    queue: Arc<BoundedQueue<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    in_flight: Arc<(Mutex<usize>, Condvar)>,
}

impl WorkerPool {
    pub fn new(threads: usize) -> Self {
        let queue: Arc<BoundedQueue<Job>> = BoundedQueue::new(threads.max(1) * 4);
        let in_flight = Arc::new((Mutex::new(0usize), Condvar::new()));
        let handles = (0..threads.max(1))
            .map(|_| {
                let q = Arc::clone(&queue);
                let inf = Arc::clone(&in_flight);
                thread::spawn(move || {
                    while let Some(job) = q.pop() {
                        // a panicking job must still decrement in_flight,
                        // or wait_idle() deadlocks on the leaked count
                        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                        let (lock, cv) = &*inf;
                        let mut n = relock(lock);
                        *n -= 1;
                        cv.notify_all();
                    }
                })
            })
            .collect();
        Self {
            queue,
            handles,
            in_flight,
        }
    }

    /// Submit a job (blocks if the internal queue is full).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.in_flight;
            *relock(lock) += 1;
        }
        if self.queue.push(Box::new(f)).is_err() {
            panic!("worker pool already shut down");
        }
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.in_flight;
        let mut n = relock(lock);
        while *n > 0 {
            n = cv.wait(n).unwrap_or_else(PoisonError::into_inner);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Staged pipeline.
// ---------------------------------------------------------------------------

/// A pipeline stage: transforms items of type `T` in place-ish fashion
/// (T -> T) with a stage name for metrics.
pub struct Stage<T> {
    pub name: &'static str,
    pub f: Box<dyn Fn(T) -> T + Send + Sync>,
}

impl<T> Stage<T> {
    pub fn new(name: &'static str, f: impl Fn(T) -> T + Send + Sync + 'static) -> Self {
        Self { name, f: Box::new(f) }
    }
}

/// Run `items` through `stages` with `lanes` concurrent lanes and a
/// per-stage-queue capacity of `queue_cap`.  Order is *not* preserved
/// across lanes; each output carries its input index so callers can
/// reassemble.  Returns outputs in completion order.
///
/// `lanes == 1` degenerates to synchronous execution (the paper's
/// 1-stream mode); `lanes >= 2` overlaps stages across items (3-stream
/// mode of Table III).
pub fn run_pipeline<T: Send + 'static>(
    items: Vec<T>,
    stages: Vec<Stage<T>>,
    lanes: usize,
    queue_cap: usize,
) -> Vec<(usize, T)> {
    assert!(!stages.is_empty());
    let lanes = lanes.max(1);
    if lanes == 1 {
        // synchronous reference path
        return items
            .into_iter()
            .enumerate()
            .map(|(i, mut x)| {
                for s in &stages {
                    x = (s.f)(x);
                }
                (i, x)
            })
            .collect();
    }

    let n = items.len();
    let input: Arc<BoundedQueue<(usize, T)>> = BoundedQueue::new(queue_cap.max(1));
    let output: Arc<BoundedQueue<(usize, T)>> = BoundedQueue::new(n.max(1));
    let stages = Arc::new(stages);
    let done = Arc::new(AtomicUsize::new(0));

    let mut handles = Vec::new();
    for _ in 0..lanes {
        let inq = Arc::clone(&input);
        let outq = Arc::clone(&output);
        let st = Arc::clone(&stages);
        let done = Arc::clone(&done);
        handles.push(thread::spawn(move || {
            while let Some((i, mut x)) = inq.pop() {
                for s in st.iter() {
                    x = (s.f)(x);
                }
                done.fetch_add(1, Ordering::Relaxed);
                if outq.push((i, x)).is_err() {
                    break;
                }
            }
        }));
    }

    for (i, x) in items.into_iter().enumerate() {
        if input.push((i, x)).is_err() {
            break;
        }
    }
    input.close();
    for h in handles {
        let _ = h.join();
    }
    output.close();
    let mut out = Vec::with_capacity(n);
    while let Some(pair) = output.pop() {
        out.push(pair);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::time::Duration;

    #[test]
    fn queue_fifo() {
        let q = BoundedQueue::new(4);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        q.close();
        assert_eq!(q.pop(), Some(0));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn queue_backpressure_blocks_until_pop() {
        let q = BoundedQueue::new(1);
        q.push(1).unwrap();
        let q2 = Arc::clone(&q);
        let pushed = Arc::new(AtomicBool::new(false));
        let p2 = Arc::clone(&pushed);
        let h = thread::spawn(move || {
            q2.push(2).unwrap();
            p2.store(true, Ordering::SeqCst);
        });
        thread::sleep(Duration::from_millis(30));
        assert!(!pushed.load(Ordering::SeqCst), "push must block when full");
        assert_eq!(q.pop(), Some(1));
        h.join().unwrap();
        assert!(pushed.load(Ordering::SeqCst));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn push_unbounded_ignores_capacity_but_not_close() {
        let q = BoundedQueue::new(1);
        q.push(1).unwrap();
        // a bounded push would block here; the unbounded one must not
        q.push_unbounded(2).unwrap();
        q.push_unbounded(3).unwrap();
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        q.close();
        assert_eq!(q.push_unbounded(4), Err(4));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn queue_close_unblocks_producers() {
        let q: Arc<BoundedQueue<i32>> = BoundedQueue::new(1);
        q.push(1).unwrap();
        let q2 = Arc::clone(&q);
        let h = thread::spawn(move || q2.push(2));
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_err());
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = WorkerPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        pool.submit(|| panic!("job down"));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // wait_idle must neither panic (lock poison) nor hang (leaked
        // in_flight count from the panicking job)
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn pipeline_sync_equals_parallel() {
        let items: Vec<u64> = (0..50).collect();
        let mk = || {
            vec![
                Stage::new("double", |x: u64| x * 2),
                Stage::new("inc", |x: u64| x + 1),
            ]
        };
        let mut sync: Vec<(usize, u64)> = run_pipeline(items.clone(), mk(), 1, 4);
        let mut par = run_pipeline(items, mk(), 4, 4);
        sync.sort_by_key(|&(i, _)| i);
        par.sort_by_key(|&(i, _)| i);
        assert_eq!(sync, par);
        assert_eq!(sync[10].1, 21);
    }

    #[test]
    fn pipeline_overlap_speedup() {
        // Sleep-based stage: 4 lanes must be measurably faster than 1.
        let items: Vec<()> = vec![(); 12];
        let mk = || {
            vec![Stage::new("sleep", |x: ()| {
                thread::sleep(Duration::from_millis(10));
                x
            })]
        };
        let t0 = std::time::Instant::now();
        run_pipeline(items.clone(), mk(), 1, 4);
        let sync_t = t0.elapsed();
        let t0 = std::time::Instant::now();
        run_pipeline(items, mk(), 4, 4);
        let par_t = t0.elapsed();
        assert!(
            par_t < sync_t * 2 / 3,
            "parallel {par_t:?} not faster than sync {sync_t:?}"
        );
    }
}
