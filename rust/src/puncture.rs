//! Puncturing / depuncturing — the SDR rate-matching substrate.
//!
//! The paper positions the PBVD as the Viterbi unit of an SDR stack
//! (Sec. I, Sec. VI); every deployed standard (802.11, LTE, DVB) runs
//! the mother rate-1/2 or 1/3 code through *puncturing* to reach
//! higher rates.  The decoder needs no change: punctured positions are
//! depunctured to **erasures** (LLR 0), which contribute nothing to
//! any branch metric — exactly the correlation-form BM's neutral
//! element — so the same AOT kernels decode every derived rate.

use anyhow::{bail, Result};

/// A puncturing pattern over an (R,1,K) mother code: a period-`p`
/// boolean matrix, `keep[stage % p][r]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PuncturePattern {
    pub name: String,
    /// keep[i][r]: transmit output r of stage (t mod period) == i?
    pub keep: Vec<Vec<bool>>,
    pub r: usize,
}

impl PuncturePattern {
    pub fn new(name: &str, keep: Vec<Vec<bool>>) -> Result<Self> {
        if keep.is_empty() {
            bail!("empty puncture pattern");
        }
        let r = keep[0].len();
        if r == 0 || keep.iter().any(|row| row.len() != r) {
            bail!("ragged puncture pattern");
        }
        if keep.iter().any(|row| row.iter().all(|&k| !k)) {
            bail!("pattern drops an entire stage (undecodable)");
        }
        Ok(Self {
            name: name.to_string(),
            keep,
            r,
        })
    }

    /// Standard patterns for rate-1/2 mother codes (802.11/LTE style).
    pub fn preset(name: &str) -> Result<Self> {
        let t = true;
        let f = false;
        match name {
            // no puncturing
            "r1/2" => Self::new("r1/2", vec![vec![t, t]]),
            // rate 2/3: period 2, drop second output every other stage
            "r2/3" => Self::new("r2/3", vec![vec![t, t], vec![t, f]]),
            // rate 3/4: period 3 (802.11a pattern)
            "r3/4" => Self::new(
                "r3/4",
                vec![vec![t, t], vec![t, f], vec![f, t]],
            ),
            // rate 5/6 (802.11n)
            "r5/6" => Self::new(
                "r5/6",
                vec![vec![t, t], vec![t, f], vec![f, t], vec![t, f], vec![f, t]],
            ),
            other => bail!("unknown puncture preset {other:?}"),
        }
    }

    pub fn period(&self) -> usize {
        self.keep.len()
    }

    /// Transmitted bits per period / mother-coded bits per period.
    pub fn rate_factor(&self) -> f64 {
        let kept: usize = self
            .keep
            .iter()
            .map(|row| row.iter().filter(|&&k| k).count())
            .sum();
        kept as f64 / (self.period() * self.r) as f64
    }

    /// Effective code rate for a rate-1/R mother code.
    pub fn effective_rate(&self) -> f64 {
        (1.0 / self.r as f64) / self.rate_factor()
    }

    /// Puncture a mother-coded bit stream (stage-major, R per stage).
    pub fn puncture<T: Copy>(&self, coded: &[T]) -> Vec<T> {
        assert_eq!(coded.len() % self.r, 0);
        let mut out = Vec::with_capacity(
            (coded.len() as f64 * self.rate_factor()).ceil() as usize,
        );
        for (stage, chunk) in coded.chunks(self.r).enumerate() {
            let row = &self.keep[stage % self.period()];
            for (r, &v) in chunk.iter().enumerate() {
                if row[r] {
                    out.push(v);
                }
            }
        }
        out
    }

    /// Depuncture received LLRs back to the mother-code lattice,
    /// inserting erasures (0) at punctured positions.  `n_stages` is
    /// the mother-code stage count to reconstruct.
    pub fn depuncture(&self, llr: &[i32], n_stages: usize) -> Result<Vec<i32>> {
        let mut out = vec![0i32; n_stages * self.r];
        let mut src = 0usize;
        for stage in 0..n_stages {
            let row = &self.keep[stage % self.period()];
            for r in 0..self.r {
                if row[r] {
                    if src >= llr.len() {
                        bail!(
                            "punctured stream too short: need more than {} values",
                            llr.len()
                        );
                    }
                    out[stage * self.r + r] = llr[src];
                    src += 1;
                }
            }
        }
        if src != llr.len() {
            bail!("punctured stream has {} leftover values", llr.len() - src);
        }
        Ok(out)
    }

    /// Number of transmitted values for `n_stages` mother stages.
    pub fn tx_len(&self, n_stages: usize) -> usize {
        (0..n_stages)
            .map(|s| {
                self.keep[s % self.period()]
                    .iter()
                    .filter(|&&k| k)
                    .count()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{AwgnChannel, Quantizer};
    use crate::encoder::ConvEncoder;
    use crate::rng::Xoshiro256;
    use crate::trellis::Trellis;
    use crate::viterbi::CpuPbvdDecoder;

    #[test]
    fn preset_rates() {
        assert!((PuncturePattern::preset("r1/2").unwrap().effective_rate() - 0.5).abs() < 1e-12);
        assert!((PuncturePattern::preset("r2/3").unwrap().effective_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!((PuncturePattern::preset("r3/4").unwrap().effective_rate() - 0.75).abs() < 1e-12);
        assert!((PuncturePattern::preset("r5/6").unwrap().effective_rate() - 5.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn puncture_depuncture_roundtrip() {
        let p = PuncturePattern::preset("r3/4").unwrap();
        let n_stages = 100;
        let coded: Vec<i32> = (0..n_stages * 2).map(|i| i as i32 + 1).collect();
        let tx = p.puncture(&coded);
        assert_eq!(tx.len(), p.tx_len(n_stages));
        let rx = p.depuncture(&tx, n_stages).unwrap();
        // kept positions recovered, punctured are erasures
        let mut k = 0usize;
        for stage in 0..n_stages {
            for r in 0..2 {
                let kept = p.keep[stage % p.period()][r];
                if kept {
                    assert_eq!(rx[stage * 2 + r], tx[k]);
                    k += 1;
                } else {
                    assert_eq!(rx[stage * 2 + r], 0);
                }
            }
        }
    }

    #[test]
    fn depuncture_length_checks() {
        let p = PuncturePattern::preset("r2/3").unwrap();
        assert_eq!(p.tx_len(2), 3); // stage 0 keeps 2, stage 1 keeps 1
        let ok = p.depuncture(&[1, 2, 3], 2).unwrap();
        assert_eq!(ok, vec![1, 2, 3, 0]); // punctured slot -> erasure
        assert!(p.depuncture(&[1, 2, 3, 4], 2).is_err()); // one too many
        assert!(p.depuncture(&[1, 2], 2).is_err()); // too short
    }

    #[test]
    fn rejects_degenerate_patterns() {
        assert!(PuncturePattern::new("bad", vec![]).is_err());
        assert!(PuncturePattern::new("bad", vec![vec![true], vec![true, false]]).is_err());
        assert!(PuncturePattern::new("bad", vec![vec![false, false]]).is_err());
    }

    /// End-to-end: punctured rates decode through the SAME decoder.
    #[test]
    fn punctured_decode_end_to_end() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 128, 42);
        let mut rng = Xoshiro256::seeded(77);
        for preset in ["r1/2", "r2/3", "r3/4"] {
            let p = PuncturePattern::preset(preset).unwrap();
            let n = 10_000usize;
            let bits: Vec<u8> = (0..n).map(|_| rng.next_bit()).collect();
            let mut enc = ConvEncoder::new(&t);
            let coded = enc.encode(&bits);
            let tx_bits = p.puncture(&coded);
            // higher effective rate -> less redundancy; use generous SNR
            let mut ch = AwgnChannel::new(7.0, p.effective_rate(), &mut rng);
            let soft = ch.transmit(&tx_bits);
            let rx = Quantizer::new(8).quantize(&soft);
            let llr = p.depuncture(&rx, n).unwrap();
            let out = dec.decode_stream(&llr);
            let errors = out.iter().zip(&bits).filter(|(a, b)| a != b).count();
            assert!(
                errors < n / 1000,
                "{preset}: {errors} errors at 7 dB"
            );
        }
    }

    /// BER ordering: more puncturing -> worse BER at equal Eb/N0.
    #[test]
    fn puncturing_degrades_gracefully() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 128, 42);
        let mut rng = Xoshiro256::seeded(78);
        let n = 60_000usize;
        let mut bers = Vec::new();
        for preset in ["r1/2", "r3/4"] {
            let p = PuncturePattern::preset(preset).unwrap();
            let bits: Vec<u8> = (0..n).map(|_| rng.next_bit()).collect();
            let mut enc = ConvEncoder::new(&t);
            let coded = enc.encode(&bits);
            let tx_bits = p.puncture(&coded);
            let mut ch = AwgnChannel::new(4.0, p.effective_rate(), &mut rng);
            let soft = ch.transmit(&tx_bits);
            let rx = Quantizer::new(8).quantize(&soft);
            let llr = p.depuncture(&rx, n).unwrap();
            let out = dec.decode_stream(&llr);
            let errors = out.iter().zip(&bits).filter(|(a, b)| a != b).count();
            bers.push(errors as f64 / n as f64);
        }
        assert!(
            bers[1] > bers[0],
            "r3/4 BER {} should exceed r1/2 BER {}",
            bers[1],
            bers[0]
        );
    }
}
