//! Trellis construction and group classification for (R,1,K)
//! convolutional codes — the Rust twin of `python/compile/trellis.py`.
//!
//! Implements the paper's Sec. III-B: butterfly structure, the
//! alpha-classification theorem (eqs. (3)-(6)) that bounds branch-metric
//! work at `2^{R+2}` per stage, the Fig.-3 survivor-path word packing,
//! and the Table-I/Table-II derivations.
//!
//! Conventions are identical to the Python side (state MSB = newest bit,
//! generator MSB = input tap, codeword MSB = first output filter); the
//! integration test `trellis_cross_validation.rs` checks the two
//! implementations table-for-table through the JSON export.

use crate::json::Json;
use anyhow::{anyhow, bail, Context, Result};

/// Preset registry: name -> (K, generator polynomials, octal, MSB-first).
/// Must stay in sync with `python/compile/trellis.py::CODES`.
pub const PRESETS: &[(&str, u32, &[u64])] = &[
    ("ccsds_k7", 7, &[0o171, 0o133]),
    ("k5", 5, &[0o23, 0o35]),
    ("k9", 9, &[0o561, 0o753]),
    ("r3_k7", 7, &[0o133, 0o145, 0o175]),
    ("k3", 3, &[0o7, 0o5]),
];

/// All decode-time tables for one (R,1,K) code.
#[derive(Clone, Debug)]
pub struct Trellis {
    pub name: String,
    pub k: u32,
    pub polys: Vec<u64>,
    pub r: usize,
    pub v: u32,
    pub n_states: usize,
    pub n_groups: usize,
    /// next_state[state][input]
    pub next_state: Vec<[u32; 2]>,
    /// output[state][input] — codeword as integer, filter 1 = MSB
    pub output: Vec<[u32; 2]>,
    /// group id per butterfly j
    pub bfly_group: Vec<u32>,
    /// alpha per group
    pub group_alpha: Vec<u32>,
    /// butterflies per group, ascending
    pub group_bflys: Vec<Vec<u32>>,
    /// [alpha, beta, gamma, theta] per group
    pub group_labels: Vec<[u32; 4]>,
    /// per-butterfly BM labels for vectorized ACS
    pub cw_top0: Vec<u32>,
    pub cw_top1: Vec<u32>,
    pub cw_bot0: Vec<u32>,
    pub cw_bot1: Vec<u32>,
    /// survivor-path packing (Fig. 3)
    pub words_per_group: usize,
    pub n_sp_words: usize,
    pub sp_word: Vec<u32>,
    pub sp_bit: Vec<u32>,
}

#[inline]
pub fn parity(x: u64) -> u32 {
    (x.count_ones() & 1) as u32
}

/// Eq. (2): encoder output (as codeword integer) for `x` at `state`.
pub fn encoder_output(polys: &[u64], k: u32, state: u64, x: u64) -> u32 {
    let reg = (x << (k - 1)) | state;
    let mut cw = 0u32;
    for &p in polys {
        cw = (cw << 1) | parity(reg & p);
    }
    cw
}

impl Trellis {
    /// Build a preset code by name.
    pub fn preset(name: &str) -> Result<Trellis> {
        let (_, k, polys) = PRESETS
            .iter()
            .find(|(n, _, _)| *n == name)
            .ok_or_else(|| anyhow!("unknown code preset {name:?}"))?;
        Trellis::build(name, *k, polys)
    }

    /// Build from arbitrary generator polynomials (MSB = input tap).
    pub fn build(name: &str, k: u32, polys: &[u64]) -> Result<Trellis> {
        if k < 2 || k > 16 {
            bail!("constraint length K={k} out of range (2..=16)");
        }
        if polys.is_empty() || polys.len() > 8 {
            bail!("need 1..=8 generator polynomials, got {}", polys.len());
        }
        for &p in polys {
            if p == 0 || p >= (1 << k) {
                bail!("polynomial {p:#o} out of range for K={k}");
            }
        }
        let r = polys.len();
        let v = k - 1;
        let n = 1usize << v;
        let half = n / 2;

        let mut next_state = vec![[0u32; 2]; n];
        let mut output = vec![[0u32; 2]; n];
        for d in 0..n {
            for x in 0..2u64 {
                next_state[d][x as usize] =
                    ((x << (v - 1)) | (d as u64 >> 1)) as u32;
                output[d][x as usize] = encoder_output(polys, k, d as u64, x);
            }
        }

        // Butterfly classification by alpha (first-occurrence numbering,
        // reproducing Table II exactly).
        let mut bfly_group = vec![0u32; half];
        let mut group_alpha: Vec<u32> = Vec::new();
        let mut group_bflys: Vec<Vec<u32>> = Vec::new();
        for j in 0..half {
            let a = output[2 * j][0];
            let w = match group_alpha.iter().position(|&g| g == a) {
                Some(w) => w,
                None => {
                    group_alpha.push(a);
                    group_bflys.push(Vec::new());
                    group_alpha.len() - 1
                }
            };
            bfly_group[j] = w as u32;
            group_bflys[w].push(j as u32);
        }
        let n_groups = group_alpha.len();

        // Label quadruples per group (eqs. (4)-(6)).
        let mut msb = 0u32;
        let mut lsb = 0u32;
        for &p in polys {
            msb = (msb << 1) | (((p >> (k - 1)) & 1) as u32);
            lsb = (lsb << 1) | ((p & 1) as u32);
        }
        let group_labels: Vec<[u32; 4]> = group_alpha
            .iter()
            .map(|&a| [a, a ^ msb, a ^ lsb, a ^ msb ^ lsb])
            .collect();

        let cw_top0: Vec<u32> = (0..half).map(|j| output[2 * j][0]).collect();
        let cw_top1: Vec<u32> = (0..half).map(|j| output[2 * j + 1][0]).collect();
        let cw_bot0: Vec<u32> = (0..half).map(|j| output[2 * j][1]).collect();
        let cw_bot1: Vec<u32> = (0..half).map(|j| output[2 * j + 1][1]).collect();

        // Verify the classification theorem held (it must, by eq. (3)-(6)).
        for j in 0..half {
            let w = bfly_group[j] as usize;
            debug_assert_eq!(cw_top0[j], group_labels[w][0]);
            debug_assert_eq!(cw_bot0[j], group_labels[w][1]);
            debug_assert_eq!(cw_top1[j], group_labels[w][2]);
            debug_assert_eq!(cw_bot1[j], group_labels[w][3]);
        }

        // Survivor-path word packing (Fig. 3).
        let bits_per_group = 2 * group_bflys.iter().map(Vec::len).max().unwrap();
        let words_per_group = bits_per_group.div_ceil(32);
        let n_sp_words = n_groups * words_per_group;
        let mut sp_word = vec![u32::MAX; n];
        let mut sp_bit = vec![u32::MAX; n];
        for (w, bflys) in group_bflys.iter().enumerate() {
            for (kk, &j) in bflys.iter().enumerate() {
                for (xhat, tgt) in [(0usize, j as usize), (1, j as usize + half)] {
                    let logical = 2 * kk + xhat;
                    sp_word[tgt] = (w * words_per_group + logical / 32) as u32;
                    sp_bit[tgt] = (logical % 32) as u32;
                }
            }
        }
        debug_assert!(sp_word.iter().all(|&w| w != u32::MAX));

        Ok(Trellis {
            name: name.to_string(),
            k,
            polys: polys.to_vec(),
            r,
            v,
            n_states: n,
            n_groups,
            next_state,
            output,
            bfly_group,
            group_alpha,
            group_bflys,
            group_labels,
            cw_top0,
            cw_top1,
            cw_bot0,
            cw_bot1,
            words_per_group,
            n_sp_words,
            sp_word,
            sp_bit,
        })
    }

    /// Codeword bit r (filter r, 1-indexed in the paper; 0-indexed here).
    #[inline]
    pub fn codeword_bit(&self, cw: u32, r: usize) -> u32 {
        (cw >> (self.r - 1 - r)) & 1
    }

    /// The paper's Table II: one row per group.
    pub fn table2(&self) -> Vec<Table2Row> {
        (0..self.n_groups)
            .map(|w| {
                let mut states: Vec<usize> = self.group_bflys[w]
                    .iter()
                    .flat_map(|&j| [2 * j as usize, 2 * j as usize + 1])
                    .collect();
                states.sort_unstable();
                Table2Row {
                    group: w,
                    labels: self.group_labels[w],
                    states,
                }
            })
            .collect()
    }

    /// The paper's Table I: thread geometry for `n_bl` "threadblocks".
    /// Kept as a faithful derivation of the CUDA geometry (the Rust
    /// coordinator reports its own lane geometry next to it).
    pub fn table1(&self, n_bl: usize) -> Table1 {
        let nc = self.n_groups;
        Table1 {
            k1_block_dim: n_bl,
            k1_thread_dim: 32 * nc,
            k2_block_dim: n_bl.div_ceil(nc),
            k2_thread_dim: 32 * nc,
            inter_frame: 32 * n_bl,
            k1_intra_frame: nc,
            k2_intra_frame: 1,
            n_parallel_blocks: 32 * n_bl,
        }
    }

    /// Per-stage branch-metric computation counts (the Sec. III-B claim):
    /// (group-based, state-based) = (2^{R+2}, 2^K).
    pub fn bm_ops_per_stage(&self) -> (usize, usize) {
        (1 << (self.r + 2), 1usize << self.k)
    }

    // ------------------------------------------------------------------
    // JSON import (cross-validation against the Python export).
    // ------------------------------------------------------------------

    /// Parse `artifacts/trellis_<code>.json` (written by aot.py) and
    /// verify it against this trellis, field by field.
    pub fn validate_against_json(&self, json_text: &str) -> Result<()> {
        let j = Json::parse(json_text).context("parsing trellis json")?;
        let get_usize = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("missing field {k}"))
        };
        if get_usize("n_states")? != self.n_states {
            bail!("n_states mismatch");
        }
        if get_usize("n_groups")? != self.n_groups {
            bail!("n_groups mismatch");
        }
        if get_usize("n_sp_words")? != self.n_sp_words {
            bail!("n_sp_words mismatch");
        }
        let next = j
            .get("next_state")
            .and_then(Json::as_i64_mat)
            .ok_or_else(|| anyhow!("missing next_state"))?;
        for (d, row) in next.iter().enumerate() {
            for x in 0..2 {
                if row[x] as u32 != self.next_state[d][x] {
                    bail!("next_state[{d}][{x}] mismatch");
                }
            }
        }
        let output = j
            .get("output")
            .and_then(Json::as_i64_mat)
            .ok_or_else(|| anyhow!("missing output"))?;
        for (d, row) in output.iter().enumerate() {
            for x in 0..2 {
                if row[x] as u32 != self.output[d][x] {
                    bail!("output[{d}][{x}] mismatch");
                }
            }
        }
        let bg = j
            .get("bfly_group")
            .and_then(Json::as_i64_vec)
            .ok_or_else(|| anyhow!("missing bfly_group"))?;
        if bg.iter().map(|&x| x as u32).ne(self.bfly_group.iter().copied()) {
            bail!("bfly_group mismatch");
        }
        let spw = j
            .get("sp_word")
            .and_then(Json::as_i64_vec)
            .ok_or_else(|| anyhow!("missing sp_word"))?;
        let spb = j
            .get("sp_bit")
            .and_then(Json::as_i64_vec)
            .ok_or_else(|| anyhow!("missing sp_bit"))?;
        if spw.iter().map(|&x| x as u32).ne(self.sp_word.iter().copied())
            || spb.iter().map(|&x| x as u32).ne(self.sp_bit.iter().copied())
        {
            bail!("survivor-path packing mismatch");
        }
        Ok(())
    }
}

/// One row of the paper's Table II.
#[derive(Clone, Debug, PartialEq)]
pub struct Table2Row {
    pub group: usize,
    /// [alpha, beta, gamma, theta] as codeword integers
    pub labels: [u32; 4],
    /// sorted source states (both states of every butterfly in the group)
    pub states: Vec<usize>,
}

impl Table2Row {
    pub fn label_str(&self, idx: usize, r: usize) -> String {
        format!("{:0width$b}", self.labels[idx], width = r)
    }
}

/// The paper's Table I (thread dimensions & parallelism).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Table1 {
    pub k1_block_dim: usize,
    pub k1_thread_dim: usize,
    pub k2_block_dim: usize,
    pub k2_thread_dim: usize,
    pub inter_frame: usize,
    pub k1_intra_frame: usize,
    pub k2_intra_frame: usize,
    pub n_parallel_blocks: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ccsds_matches_paper_table2() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        assert_eq!(t.n_states, 64);
        assert_eq!(t.n_groups, 4);
        let rows = t.table2();
        let expected: [(&str, &str, &str, &str, &[usize]); 4] = [
            ("00", "11", "11", "00",
             &[0, 1, 4, 5, 24, 25, 28, 29, 42, 43, 46, 47, 50, 51, 54, 55]),
            ("01", "10", "10", "01",
             &[2, 3, 6, 7, 26, 27, 30, 31, 40, 41, 44, 45, 48, 49, 52, 53]),
            ("11", "00", "00", "11",
             &[8, 9, 12, 13, 16, 17, 20, 21, 34, 35, 38, 39, 58, 59, 62, 63]),
            ("10", "01", "01", "10",
             &[10, 11, 14, 15, 18, 19, 22, 23, 32, 33, 36, 37, 56, 57, 60, 61]),
        ];
        for (row, (a, b, g, th, states)) in rows.iter().zip(expected.iter()) {
            assert_eq!(row.label_str(0, 2), *a);
            assert_eq!(row.label_str(1, 2), *b);
            assert_eq!(row.label_str(2, 2), *g);
            assert_eq!(row.label_str(3, 2), *th);
            assert_eq!(row.states, *states);
        }
    }

    #[test]
    fn butterfly_targets() {
        for (name, _, _) in PRESETS {
            let t = Trellis::preset(name).unwrap();
            let n = t.n_states as u32;
            for j in 0..t.n_states / 2 {
                assert_eq!(t.next_state[2 * j][0], j as u32);
                assert_eq!(t.next_state[2 * j + 1][0], j as u32);
                assert_eq!(t.next_state[2 * j][1], j as u32 + n / 2);
                assert_eq!(t.next_state[2 * j + 1][1], j as u32 + n / 2);
            }
        }
    }

    #[test]
    fn group_count_bound() {
        for (name, _, _) in PRESETS {
            let t = Trellis::preset(name).unwrap();
            assert!(t.n_groups <= 1 << t.r, "{name}");
            let (grouped, statebased) = t.bm_ops_per_stage();
            // the paper's Sec. III-B speedup condition for its codes
            if t.name == "ccsds_k7" {
                assert!(grouped < statebased);
                assert_eq!(grouped, 16);
                assert_eq!(statebased, 128);
            }
        }
    }

    #[test]
    fn sp_packing_bijective() {
        for (name, _, _) in PRESETS {
            let t = Trellis::preset(name).unwrap();
            let mut seen = std::collections::HashSet::new();
            for s in 0..t.n_states {
                let slot = (t.sp_word[s], t.sp_bit[s]);
                assert!(t.sp_bit[s] < 32);
                assert!((t.sp_word[s] as usize) < t.n_sp_words);
                assert!(seen.insert(slot), "{name}: duplicate slot {slot:?}");
            }
        }
    }

    #[test]
    fn k9_needs_two_words_per_group() {
        // (2,1,9): N = 256, N_c = 4 -> 64 bits per group -> 2 u32 words.
        let t = Trellis::preset("k9").unwrap();
        assert_eq!(t.n_states, 256);
        assert_eq!(t.n_groups, 4);
        assert_eq!(t.words_per_group, 2);
        assert_eq!(t.n_sp_words, 8);
    }

    #[test]
    fn table1_matches_paper_formulas() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let g = t.table1(64);
        assert_eq!(g.k1_thread_dim, 128); // 32 * N_c
        assert_eq!(g.k2_block_dim, 16);   // N_bl / N_c
        assert_eq!(g.inter_frame, 2048);  // 32 * N_bl
        assert_eq!(g.k1_intra_frame, 4);
        assert_eq!(g.k2_intra_frame, 1);
    }

    #[test]
    fn encode_known_vector_k3() {
        // textbook vector for (2,1,3) [7,5]: 1011 -> 11 10 00 01
        let t = Trellis::preset("k3").unwrap();
        let mut state = 0u32;
        let mut out = Vec::new();
        for x in [1u64, 0, 1, 1] {
            let cw = t.output[state as usize][x as usize];
            out.push(cw);
            state = t.next_state[state as usize][x as usize];
        }
        assert_eq!(out, vec![0b11, 0b10, 0b00, 0b01]);
    }

    #[test]
    fn rejects_bad_parameters() {
        assert!(Trellis::build("x", 1, &[1]).is_err());
        assert!(Trellis::build("x", 7, &[]).is_err());
        assert!(Trellis::build("x", 3, &[0o17]).is_err()); // poly too wide
        assert!(Trellis::build("x", 3, &[0]).is_err());
        assert!(Trellis::preset("nope").is_err());
    }

    #[test]
    fn label_quadruple_sharing_random_codes() {
        // Property: butterflies with equal alpha share the whole quadruple.
        let mut rng = crate::rng::Xoshiro256::seeded(77);
        for _ in 0..30 {
            let k = 3 + (rng.next_below(6) as u32); // 3..=8
            let r = 2 + (rng.next_below(2) as usize);
            let polys: Vec<u64> = (0..r)
                .map(|_| 1 + rng.next_below((1 << k) - 1))
                .collect();
            let t = match Trellis::build("rand", k, &polys) {
                Ok(t) => t,
                Err(_) => continue,
            };
            for j in 0..t.n_states / 2 {
                let w = t.bfly_group[j] as usize;
                assert_eq!(t.group_labels[w][0], t.cw_top0[j]);
                assert_eq!(t.group_labels[w][1], t.cw_bot0[j]);
                assert_eq!(t.group_labels[w][2], t.cw_top1[j]);
                assert_eq!(t.group_labels[w][3], t.cw_bot1[j]);
            }
        }
    }
}
