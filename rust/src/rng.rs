//! Deterministic PRNGs and Gaussian sampling (no external `rand` crate
//! in this offline environment — built from scratch per DESIGN.md §3).
//!
//! * [`SplitMix64`] — seed expander (Steele et al. 2014).
//! * [`Xoshiro256`] — xoshiro256** general-purpose generator
//!   (Blackman & Vigna 2018); passes BigCrush, tiny state, jumpable.
//! * [`Normal`] — Box–Muller transform over `Xoshiro256`.

/// SplitMix64: used to expand a 64-bit seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 (never produces the all-zero state).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Unbiased uniform integer in `[0, n)` (Lemire rejection).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Random bit (0/1).
    #[inline]
    pub fn next_bit(&mut self) -> u8 {
        (self.next_u64() >> 63) as u8
    }

    /// Jump: equivalent to 2^128 next_u64 calls — decorrelated parallel
    /// streams for the multi-threaded BER harness.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180EC6D33CFD0ABA,
            0xD5A61266F0C9392C,
            0xA9582618E03FC9AA,
            0x39ABDC4529B1661C,
        ];
        let mut s = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    s[0] ^= self.s[0];
                    s[1] ^= self.s[1];
                    s[2] ^= self.s[2];
                    s[3] ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = s;
    }

    /// A decorrelated child stream (jump-ahead clone).
    pub fn split(&mut self) -> Self {
        let child = self.clone();
        self.jump();
        child
    }
}

/// Gaussian sampler: polar Box–Muller with caching of the second deviate.
#[derive(Clone, Debug)]
pub struct Normal {
    cached: Option<f64>,
}

impl Normal {
    pub fn new() -> Self {
        Self { cached: None }
    }

    /// Standard normal deviate.
    pub fn sample(&mut self, rng: &mut Xoshiro256) -> f64 {
        if let Some(z) = self.cached.take() {
            return z;
        }
        loop {
            let u = 2.0 * rng.next_f64() - 1.0;
            let v = 2.0 * rng.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.cached = Some(v * f);
                return u * f;
            }
        }
    }
}

impl Default for Normal {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference sequence for seed 1234567 (from the public-domain
        // splitmix64.c reference implementation).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn xoshiro_deterministic_and_nonzero() {
        let mut r1 = Xoshiro256::seeded(99);
        let mut r2 = Xoshiro256::seeded(99);
        for _ in 0..1000 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
        assert_ne!(Xoshiro256::seeded(1).next_u64(), Xoshiro256::seeded(2).next_u64());
    }

    #[test]
    fn uniform_unit_interval() {
        let mut r = Xoshiro256::seeded(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Xoshiro256::seeded(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.next_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::seeded(11);
        let mut n = Normal::new();
        let count = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..count {
            let z = n.sample(&mut r);
            sum += z;
            sq += z * z;
        }
        let mean = sum / count as f64;
        let var = sq / count as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.02, "var = {var}");
    }

    #[test]
    fn jump_decorrelates() {
        let mut base = Xoshiro256::seeded(5);
        let child = base.split();
        let mut child = child;
        let mut base_next = Xoshiro256::seeded(5);
        // child stream equals the original pre-jump stream
        assert_eq!(child.next_u64(), base_next.next_u64());
        // parent after jump differs from child
        assert_ne!(base.next_u64(), child.next_u64());
    }
}
