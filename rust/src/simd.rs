//! Lane-interleaved SIMD butterfly-ACS backend — `Metric::LANES`
//! parallel blocks advance through every trellis stage in lockstep.
//!
//! The paper's Gb/s numbers come from mapping all trellis states *and*
//! many parallel blocks (PBs) onto GPU threads at once; the scalar
//! [`ButterflyAcs`](crate::par::ButterflyAcs) kernel steps one PB at a
//! time, leaving the whole SIMD width of each CPU core idle.  This
//! module restructures the data instead of adding threads (the same
//! lesson as the memory-efficient and tensor-core parallel Viterbi
//! decoders, arXiv:2011.09337 / arXiv:2011.13579 — and, like the
//! tensor-core follow-up, it treats *metric precision* itself as a
//! throughput lever):
//!
//! * [`LaneInterleavedAcs<M>`] — path metrics stored block-interleaved
//!   (structure-of-arrays, `[state][lane]`), generic over the
//!   [`Metric`] storage width: **u32 × 8 lanes** or **u16 × 16 lanes**
//!   per 256-bit vector.  The butterfly inner loop is `M::LANES`
//!   contiguous adds/mins that LLVM autovectorizes.  Decision bits
//!   come out word-parallel: one lane-mask word ([`Metric::Sel`]: u8
//!   or u16) per target state per stage instead of per-state bit
//!   pokes into shared `u64` rows.  Per-lane branch-metric tables are
//!   filled in one interleaved Gray-code pass reusing the scalar
//!   kernel's antisymmetry trick (`BM(~c) = -BM(c)`).
//! * **u16 saturation-safety bound** — the u16 kernel uses *saturating*
//!   adds, and [`metric_spread_bound`] proves per preset/quantizer
//!   that saturation can never actually fire (so u16 decisions are
//!   bit-identical to u32 and to the golden model); combinations that
//!   exceed the bound fall back to u32 at engine construction.
//! * A per-arch **ACS backend seam** ([`backend`]): the stage kernel
//!   exists as a scalar reference loop, a portable 128-bit lane-chunk
//!   path (autovectorized anywhere), an explicit AVX2 path per width
//!   (`_mm256_add_epi32` / `_mm256_min_epu32` for u32,
//!   `_mm256_adds_epu16` / `_mm256_min_epu16` for u16) and an explicit
//!   NEON path (`vaddq_u32` / `vminq_u32`, `vqaddq_u16` / `vminq_u16`
//!   on 128-bit half-vectors).  Intrinsics backends sit behind the
//!   `simd-intrinsics` cargo feature and are runtime-selected per arch
//!   ([`AcsBackend::detect`]), forceable via CLI
//!   `--simd-backend {auto,scalar,portable,avx2,neon}` or the
//!   `PBVD_SIMD_BACKEND` env var.  All backends issue the identical
//!   adds / unsigned mins / tie-breaks, so decisions stay
//!   bit-identical across backends.
//! * [`SimdCpuEngine`] — a [`DecodeEngine`] that **autotunes the lane
//!   width** at construction (a short calibration decode per code,
//!   the pick recorded in [`WorkerPoolStats`](crate::metrics::WorkerPoolStats) and forceable via
//!   [`MetricWidth`] / CLI `--metric-width`), then shards
//!   *lane-groups* across the shared
//!   [`WorkerPool`](crate::pool::WorkerPool), with a ragged-tail
//!   fallback to the scalar `ButterflyAcs` for the
//!   `batch % lane_width` leftover blocks and exact per-lane-group
//!   worker attribution in [`BatchTimings::per_worker`].
//!
//! Decisions are **bit-identical** to
//! [`CpuPbvdDecoder`](crate::viterbi::CpuPbvdDecoder) in every width:
//! the kernel uses the same `bm_offset(R, q)`-shifted branch metrics
//! and the same per-stage min-normalization as the scalar butterfly
//! kernel, per lane.  The property tests in
//! `rust/tests/simd_engine.rs` and `rust/tests/overflow_guard.rs` pin
//! this across all code presets, both widths, and full-range i8 LLRs.
//!
//! ```text
//! path-metric memory order ([state][lane], one 256-bit vector/state):
//!
//!   u32 mode:  lane 0   lane 1   ...  lane 7     <-  8 parallel blocks
//!   state 0  | pm[0]  | pm[1]  | ... | pm[7]  |
//!   u16 mode:  lane 0   lane 1   ...  lane 15    <- 16 parallel blocks
//!   state 0  | pm[0]  | pm[1]  | ... | pm[15] |      (2x ACS / vector)
//! ```
//!
//! Why u16 is safe (the spread-bound argument): branch metrics are
//! shifted into `[0, 2 * R * 2^(q-1)]`, and after each stage's
//! min-normalization the metric spread is at most `(K-1)` stages of
//! maximal branch metric (any state is reachable from the minimum
//! state within the constraint length), so the largest value formed
//! before the next normalization is under
//! `K * 2 * R * 2^(q-1) <= ` [`metric_spread_bound`]`(R, K, q)` `=
//! 2 * K * R * 2^q`.  Every preset at q = 8 stays far below
//! `u16::MAX`, so the saturating adds are exact.

pub mod backend;

use crate::channel::pack_bits;
use crate::coordinator::{BatchTimings, DecodeEngine};
use crate::metrics::WorkerSnapshot;
use crate::par::{bm_offset, gray_walk, ButterflyAcs};
use crate::pool::{DecodeShard, WorkerPool};
use crate::rng::Xoshiro256;
use crate::trellis::Trellis;
use anyhow::{bail, Result};
pub use backend::{AcsBackend, BackendChoice, ALL_BACKENDS};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Minimum lane-group width (the u32 kernel's 8 lanes): the batch size
/// at which [`EngineKind::Auto`](crate::config::EngineKind::Auto)
/// starts auto-selecting the SIMD engine.
pub const LANES: usize = 8;

/// Lane width of the narrow-metric u16 kernel (16 per 256-bit vector).
pub const LANES_U16: usize = 16;

/// Upper bound used to keep the stage kernels' fixed-size scratch
/// arrays allocation-free per stage.
pub(crate) const MAX_LANES: usize = 16;

// ---------------------------------------------------------------------------
// Metric-width abstraction.
// ---------------------------------------------------------------------------

/// Per-state lane-mask decision word: bit `l` is the survivor input of
/// the state in lane `l`.  u8 for the 8-lane u32 kernel, u16 for the
/// 16-lane u16 kernel.
pub trait SelMask: Copy + Default + Send + Sync + std::fmt::Debug + 'static {
    fn from_mask(m: u32) -> Self;
    fn lane_bit(self, lane: usize) -> usize;
    /// The full lane mask widened back to u32 (inverse of
    /// [`from_mask`](SelMask::from_mask)) — the cross-backend
    /// tie-break tests compare decision words through this.
    fn to_mask(self) -> u32;
}

impl SelMask for u8 {
    #[inline(always)]
    fn from_mask(m: u32) -> u8 {
        m as u8
    }
    #[inline(always)]
    fn lane_bit(self, lane: usize) -> usize {
        ((self >> lane) & 1) as usize
    }
    #[inline(always)]
    fn to_mask(self) -> u32 {
        u32::from(self)
    }
}

impl SelMask for u16 {
    #[inline(always)]
    fn from_mask(m: u32) -> u16 {
        m as u16
    }
    #[inline(always)]
    fn lane_bit(self, lane: usize) -> usize {
        ((self >> lane) & 1) as usize
    }
    #[inline(always)]
    fn to_mask(self) -> u32 {
        u32::from(self)
    }
}

/// Path-metric storage width of the lane-interleaved kernel.
///
/// Two implementations exist: `u32` (8 lanes per 256-bit vector, plain
/// adds — the spread fits with orders of magnitude to spare) and `u16`
/// (16 lanes, *saturating* adds — [`metric_spread_bound`] proves the
/// saturation never fires for admissible preset/quantizer
/// combinations, so decisions are identical).  Both orderings are
/// unsigned, so compare-selects and tie-breaks agree lane-for-lane
/// with the scalar kernel.
pub trait Metric:
    Copy + Default + Eq + Ord + Send + Sync + Into<u64> + std::fmt::Debug + 'static
{
    /// Lanes of this width in one 256-bit vector (8 or 16).
    const LANES: usize;
    /// Lanes per 128-bit half-vector (4 or 16/2 = 8) — the chunk width
    /// of the portable backend and the NEON register width.
    const HALF: usize;
    /// Storage width in bits (32 or 16).
    const BITS: u32;
    /// Identity of the per-lane running minimum.
    const MAX: Self;
    /// Lane-mask decision word paired with this width.
    type Sel: SelMask;
    /// Convert a shifted branch-metric entry (known non-negative and
    /// within the spread bound for admissible configurations).
    fn from_bm(v: i32) -> Self;
    /// `pm + bm` — plain for u32, saturating for u16 (the bound keeps
    /// the saturating add exact; saturation is the graceful-degrade
    /// backstop, never the expected path).
    fn add_metric(self, bm: Self) -> Self;
    /// Min-normalization subtraction (`self >= min` per lane).
    fn sub_norm(self, min: Self) -> Self;
    /// One ACS stage with explicit AVX2 intrinsics for this width.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support and pass `[state][lane]`
    /// buffers of `n_states * Self::LANES` entries.
    #[cfg(all(target_arch = "x86_64", feature = "simd-intrinsics"))]
    unsafe fn acs_stage_avx2(
        t: &Trellis,
        pm: &[Self],
        new_pm: &mut [Self],
        bm: &[Self],
        dw_row: &mut [Self::Sel],
    );
    /// One ACS stage with explicit NEON intrinsics for this width
    /// (two 128-bit half-vectors per state row).
    ///
    /// # Safety
    /// Caller must have verified NEON support and pass `[state][lane]`
    /// buffers of `n_states * Self::LANES` entries.
    #[cfg(all(target_arch = "aarch64", feature = "simd-intrinsics"))]
    unsafe fn acs_stage_neon(
        t: &Trellis,
        pm: &[Self],
        new_pm: &mut [Self],
        bm: &[Self],
        dw_row: &mut [Self::Sel],
    );
}

impl Metric for u32 {
    const LANES: usize = 8;
    const HALF: usize = 4;
    const BITS: u32 = 32;
    const MAX: u32 = u32::MAX;
    type Sel = u8;
    #[inline(always)]
    fn from_bm(v: i32) -> u32 {
        v as u32
    }
    #[inline(always)]
    fn add_metric(self, bm: u32) -> u32 {
        self + bm
    }
    #[inline(always)]
    fn sub_norm(self, min: u32) -> u32 {
        self - min
    }
    #[cfg(all(target_arch = "x86_64", feature = "simd-intrinsics"))]
    unsafe fn acs_stage_avx2(
        t: &Trellis,
        pm: &[u32],
        new_pm: &mut [u32],
        bm: &[u32],
        dw_row: &mut [u8],
    ) {
        backend::avx2::acs_stage_u32(t, pm, new_pm, bm, dw_row)
    }
    #[cfg(all(target_arch = "aarch64", feature = "simd-intrinsics"))]
    unsafe fn acs_stage_neon(
        t: &Trellis,
        pm: &[u32],
        new_pm: &mut [u32],
        bm: &[u32],
        dw_row: &mut [u8],
    ) {
        backend::neon::acs_stage_u32(t, pm, new_pm, bm, dw_row)
    }
}

impl Metric for u16 {
    const LANES: usize = 16;
    const HALF: usize = 8;
    const BITS: u32 = 16;
    const MAX: u16 = u16::MAX;
    type Sel = u16;
    #[inline(always)]
    fn from_bm(v: i32) -> u16 {
        debug_assert!(
            (0..=u16::MAX as i32).contains(&v),
            "BM entry {v} outside u16 — preset/quantizer not admissible"
        );
        v as u16
    }
    #[inline(always)]
    fn add_metric(self, bm: u16) -> u16 {
        self.saturating_add(bm)
    }
    #[inline(always)]
    fn sub_norm(self, min: u16) -> u16 {
        self - min
    }
    #[cfg(all(target_arch = "x86_64", feature = "simd-intrinsics"))]
    unsafe fn acs_stage_avx2(
        t: &Trellis,
        pm: &[u16],
        new_pm: &mut [u16],
        bm: &[u16],
        dw_row: &mut [u16],
    ) {
        backend::avx2::acs_stage_u16(t, pm, new_pm, bm, dw_row)
    }
    #[cfg(all(target_arch = "aarch64", feature = "simd-intrinsics"))]
    unsafe fn acs_stage_neon(
        t: &Trellis,
        pm: &[u16],
        new_pm: &mut [u16],
        bm: &[u16],
        dw_row: &mut [u16],
    ) {
        backend::neon::acs_stage_u16(t, pm, new_pm, bm, dw_row)
    }
}

/// Worst-case peak any path metric can reach between two consecutive
/// min-normalizations, for an `R`-filter, constraint-length-`K` code
/// fed by a `q`-bit quantizer: `2 * K * R * 2^q`.
///
/// Derivation: shifted branch metrics live in `[0, 2 * R * 2^(q-1)]`
/// (= `[0, R * 2^q]`, see [`bm_offset`]).  After a normalization the
/// spread is at most `(K-1) * R * 2^q` — the minimum-metric state
/// reaches any other state within `K-1` trellis steps, each adding at
/// most one maximal branch metric, while the running minimum never
/// decreases (metrics are non-negative).  One more ACS stage before
/// the next normalization adds at most another `R * 2^q`, so the peak
/// is under `K * R * 2^q`; the bound doubles that for slack.  When it
/// fits in the metric type, saturating arithmetic is exact and the
/// narrow kernel's decisions are bit-identical to u32 and the golden
/// model.
pub fn metric_spread_bound(r: usize, k: u32, q: u32) -> u64 {
    2 * (k as u64) * (r as u64) * (1u64 << q)
}

/// Whether the u16 lane-interleaved kernel is exact for this
/// code/quantizer combination ([`metric_spread_bound`] fits in u16).
/// Every built-in preset passes at q = 8 (worst case `r3_k7`:
/// `2 * 7 * 3 * 256 = 10752`); the predicate exists for synthetic
/// codes and wider quantizers, which fall back to u32.
pub fn u16_metric_admissible(trellis: &Trellis, q: u32) -> bool {
    metric_spread_bound(trellis.r, trellis.k, q) <= u16::MAX as u64
}

/// Whether a u16 width request would actually run the u16 kernel for
/// this engine geometry: the spread bound must admit the
/// code/quantizer AND the batch must fill at least one 16-lane group
/// (otherwise every PB would take the ragged-tail path and the u16
/// kernel never executes).  The single source of this policy — used
/// by the engine's width resolution, the autotuner's gate and the
/// bench ladder's rung selection.
pub fn u16_width_eligible(trellis: &Trellis, batch: usize, q: u32) -> bool {
    u16_metric_admissible(trellis, q) && batch >= LANES_U16
}

/// Requested path-metric width for [`SimdCpuEngine`] (CLI
/// `--metric-width {auto,16,32}`).
///
/// `W16` falls back to u32 when the spread bound does not admit u16
/// for the code/quantizer (the *checked fallback* — the engine never
/// runs a width it cannot prove exact), or when the batch cannot fill
/// a single 16-lane group (the u16 kernel would never execute; every
/// PB would go through the scalar tail).  The width actually running
/// is visible in [`SimdCpuEngine::metric_bits`], the engine name and
/// the pool stats.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricWidth {
    /// Calibration decode at construction picks u16 or u32.
    Auto,
    /// Force the 16-lane u16 kernel (if admissible).
    W16,
    /// Force the 8-lane u32 kernel.
    W32,
}

impl MetricWidth {
    /// Parse the CLI form: `auto`, `16` or `32`.
    pub fn parse(s: &str) -> Option<MetricWidth> {
        match s {
            "auto" => Some(MetricWidth::Auto),
            "16" => Some(MetricWidth::W16),
            "32" => Some(MetricWidth::W32),
            _ => None,
        }
    }
}

impl std::fmt::Display for MetricWidth {
    /// The CLI form (`auto` / `16` / `32`); round-trip stable with
    /// [`FromStr`](std::str::FromStr).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            MetricWidth::Auto => "auto",
            MetricWidth::W16 => "16",
            MetricWidth::W32 => "32",
        })
    }
}

impl std::str::FromStr for MetricWidth {
    type Err = crate::config::ConfigError;

    /// Strict CLI parsing (`--metric-width`), with the error message
    /// the CLI used to hand-roll.
    fn from_str(s: &str) -> Result<MetricWidth, Self::Err> {
        MetricWidth::parse(s).ok_or_else(|| {
            crate::config::ConfigError::new(format!(
                "invalid metric width {s:?} (expected auto, 16 or 32)"
            ))
        })
    }
}

/// The lane-interleaved engine's execution-tuning knobs, bundled so
/// [`SimdCpuEngine::with_config`] stays a short signature as axes
/// accumulate (metric width in PR 3, ACS backend in PR 4, ...).  The
/// canonical carrier is [`DecoderConfig`](crate::config::DecoderConfig),
/// whose factory fills this from its own fields.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SimdTuning {
    /// Path-metric width request (checked fallback to u32).
    pub width: MetricWidth,
    /// Quantizer bit width the BM offset is derived from (`2..=8`).
    pub q: u32,
    /// ACS stage-kernel backend request (checked fallback to the
    /// detected backend).
    pub backend: BackendChoice,
}

impl Default for SimdTuning {
    /// Autotuned width, 8-bit quantizer, auto-detected backend.
    fn default() -> SimdTuning {
        SimdTuning {
            width: MetricWidth::Auto,
            q: 8,
            backend: BackendChoice::Auto,
        }
    }
}

// ---------------------------------------------------------------------------
// Lane-interleaved branch-metric fill.
// ---------------------------------------------------------------------------

/// Interleaved branch-metric fill for one stage of `M::LANES` blocks.
///
/// `stage_vals` is the stage's per-lane LLRs transposed to `[R][lane]`
/// (i32-widened); `bm` is the `[codeword][lane]` table.  Walks the same
/// Gray-code sequence as the scalar `fill_bm` ([`gray_walk`]) so each
/// table row costs one add/sub per lane, and derives the upper half by
/// the antisymmetry reflection.  Entries carry the scalar kernel's
/// uniform `off` = [`bm_offset`]`(R, q)` shift, so every lane's table
/// is entry-for-entry identical to what `ButterflyAcs` computes for
/// that lane's block.
fn fill_bm_lanes<M: Metric>(bm: &mut [M], stage_vals: &[i32], r: usize, off: i32) {
    let l = M::LANES;
    debug_assert!(
        stage_vals[..r * l].iter().all(|&y| {
            let b = off / r as i32; // 2^(q-1)
            (-b..b).contains(&y)
        }),
        "LLR outside the q-bit range the BM offset was built for"
    );
    let mask = bm.len() / l - 1;
    // codeword 0 (all bits clear): corr = -Σ llr, per lane
    let mut acc = [0i32; MAX_LANES];
    for ri in 0..r {
        let sv = &stage_vals[ri * l..(ri + 1) * l];
        for lane in 0..l {
            acc[lane] -= sv[lane];
        }
    }
    for lane in 0..l {
        bm[lane] = M::from_bm(off + acc[lane]);
        bm[mask * l + lane] = M::from_bm(off - acc[lane]);
    }
    for (g, ri, set) in gray_walk(r) {
        let sv = &stage_vals[ri * l..(ri + 1) * l];
        if set {
            for lane in 0..l {
                acc[lane] += 2 * sv[lane];
            }
        } else {
            for lane in 0..l {
                acc[lane] -= 2 * sv[lane];
            }
        }
        let lo = g * l;
        let hi = (mask ^ g) * l;
        for lane in 0..l {
            bm[lo + lane] = M::from_bm(off + acc[lane]);
            bm[hi + lane] = M::from_bm(off - acc[lane]);
        }
    }
}

// ---------------------------------------------------------------------------
// The lane-interleaved kernel.
// ---------------------------------------------------------------------------

/// Lockstep forward/traceback kernel over `M::LANES` parallel blocks
/// with reusable scratch, generic over the [`Metric`] storage width.
/// One instance per worker thread; geometry is fixed at construction
/// (`block` = D payload bits, `depth` = L, T = D + 2L), exactly like
/// the scalar `ButterflyAcs`.
pub struct LaneInterleavedAcs<M: Metric> {
    trellis: Trellis,
    pub block: usize,
    pub depth: usize,
    /// `[state][lane]` path metrics (SoA, min-normalized).
    pm: Vec<M>,
    new_pm: Vec<M>,
    /// `[codeword][lane]` branch metrics for the current stage.
    bm: Vec<M>,
    /// `[R][lane]` i32-widened LLRs of the current stage (fill scratch).
    stage_vals: Vec<i32>,
    /// Depth-windowed `[ring_stages][state]` lane-mask decision ring:
    /// stage `s` lives at row `s % ring`, and bit `l` of the entry for
    /// state `st` is the survivor input of that state in lane `l`.
    /// Only the traceback window (stages `depth..T`) is retained; the
    /// forward pass overwrites rows older than the horizon.
    dw: Vec<M::Sel>,
    /// Survivor-ring capacity in stages (`D + L < T = D + 2L`).
    ring: usize,
    /// Uniform per-stage BM shift ([`bm_offset`] of the quantizer).
    bm_off: i32,
    /// Resolved ACS stage-kernel backend (always available on this
    /// host — see [`BackendChoice::resolve`]).
    backend: AcsBackend,
}

/// The 8-lane u32 kernel (PR-2 baseline).
pub type LaneAcs32 = LaneInterleavedAcs<u32>;
/// The 16-lane narrow-metric u16 kernel.
pub type LaneAcs16 = LaneInterleavedAcs<u16>;

impl<M: Metric> LaneInterleavedAcs<M> {
    /// Kernel for the default 8-bit quantizer (i8 full range).
    pub fn new(trellis: &Trellis, block: usize, depth: usize) -> LaneInterleavedAcs<M> {
        LaneInterleavedAcs::with_quantizer(trellis, block, depth, 8)
    }

    /// Kernel for a `q`-bit quantizer (`2 <= q <= 8`): the BM shift
    /// shrinks to `R * 2^(q-1)`, widening the u16 headroom.  For the
    /// u16 width the caller must have checked
    /// [`u16_metric_admissible`] (debug-asserted in the fill).  The
    /// ACS backend is auto-detected (honoring `PBVD_SIMD_BACKEND`).
    pub fn with_quantizer(
        trellis: &Trellis,
        block: usize,
        depth: usize,
        q: u32,
    ) -> LaneInterleavedAcs<M> {
        LaneInterleavedAcs::with_config(trellis, block, depth, q, BackendChoice::Auto.resolve())
    }

    /// Full-control constructor: `backend` selects the ACS stage
    /// kernel (the caller passes a *resolved* backend — engines
    /// resolve a [`BackendChoice`] once and share the pick with every
    /// worker kernel).
    pub fn with_config(
        trellis: &Trellis,
        block: usize,
        depth: usize,
        q: u32,
        backend: AcsBackend,
    ) -> LaneInterleavedAcs<M> {
        assert!(block > 0 && depth > 0);
        assert!((2..=8).contains(&q), "q={q} out of range for i8 input");
        assert!(
            backend.is_available(),
            "backend {backend:?} not available on this host (resolve a BackendChoice first)"
        );
        let n = trellis.n_states;
        let ring = block + depth;
        LaneInterleavedAcs {
            trellis: trellis.clone(),
            block,
            depth,
            pm: vec![M::default(); n * M::LANES],
            new_pm: vec![M::default(); n * M::LANES],
            bm: vec![M::default(); (1 << trellis.r) * M::LANES],
            stage_vals: vec![0i32; trellis.r * M::LANES],
            dw: vec![M::Sel::default(); ring * n],
            ring,
            bm_off: bm_offset(trellis.r, q),
            backend,
        }
    }

    /// Stages per parallel block (T = D + 2L).
    pub fn total(&self) -> usize {
        self.block + 2 * self.depth
    }

    /// Survivor-ring capacity in stages (`D + L < T`).
    pub fn ring_stages(&self) -> usize {
        self.ring
    }

    /// Lane-mask words per retained forward pass (`ring_stages *
    /// n_states`), i.e. the length of
    /// [`decision_ring`](Self::decision_ring).
    pub fn ring_len(&self) -> usize {
        self.ring * self.trellis.n_states
    }

    /// Bytes of survivor storage this kernel retains per lane-group
    /// with the depth-windowed ring.
    pub fn survivor_ring_bytes(&self) -> usize {
        self.ring_len() * std::mem::size_of::<M::Sel>()
    }

    /// Bytes a full-length `[T][n_states]` lane-mask buffer would cost
    /// (the pre-ring layout; kept for the bench report's before/after).
    pub fn survivor_full_bytes(&self) -> usize {
        self.total() * self.trellis.n_states * std::mem::size_of::<M::Sel>()
    }

    /// The lane-mask decision ring of the last forward pass (row `s %
    /// ring_stages` holds stage `s`; only stages `L..T` are retained).
    pub fn decision_ring(&self) -> &[M::Sel] {
        &self.dw
    }

    pub fn trellis(&self) -> &Trellis {
        &self.trellis
    }

    /// Parallel blocks per lane-group (8 for u32, 16 for u16).
    pub fn lanes(&self) -> usize {
        M::LANES
    }

    /// Name of the ACS backend this kernel runs (`"scalar"`,
    /// `"portable"`, `"avx2"` or `"neon"`).
    pub fn backend(&self) -> &'static str {
        self.backend.name()
    }

    /// The resolved ACS backend itself.
    pub fn acs_backend(&self) -> AcsBackend {
        self.backend
    }

    /// Lane-mask decision word of (`stage`, `state`): bit `l` is the
    /// survivor input of the state in lane `l` (`0` = even
    /// predecessor — the tie-break winner).  Exposed so the
    /// conformance suites can pin tie-break semantics bit-for-bit
    /// across backends (`rust/tests/backend_conformance.rs`).
    ///
    /// Valid only for the retained traceback window (`depth <= stage <
    /// T`): the survivor ring overwrites rows older than the horizon,
    /// so an earlier stage's row already holds a later stage's words.
    pub fn decision_mask(&self, stage: usize, state: usize) -> u32 {
        self.dw[(stage % self.ring) * self.trellis.n_states + state].to_mask()
    }

    /// Final normalized `[state][lane]` path metrics of the last
    /// forward pass; lane `l`'s column is bit-identical to
    /// `ButterflyAcs::path_metrics` for that lane's block.
    pub fn path_metrics(&self) -> &[M] {
        &self.pm
    }

    /// Confidence margin of `lane`'s block in the last forward pass:
    /// the runner-up final path metric of that lane's column (the
    /// winner is 0 after min-normalization).  Tracebacks never touch
    /// `pm`, so this stays valid after
    /// [`decode_group_into`](Self::decode_group_into); u16 metrics
    /// widen losslessly, so the value is bit-identical to
    /// [`ForwardResult::margin`](crate::viterbi::ForwardResult::margin)
    /// in every width and backend.
    pub fn lane_margin(&self, lane: usize) -> u32 {
        assert!(lane < M::LANES);
        let n = self.trellis.n_states;
        crate::viterbi::second_min_margin((0..n).map(|st| {
            let v: u64 = self.pm[st * M::LANES + lane].into();
            v as u32
        }))
    }

    /// Lockstep forward pass over `M::LANES` parallel blocks.  `llr`
    /// holds the lane blocks back to back (`LANES * T * R` i8 values,
    /// stage-major `[T][R]` within each lane; lane `l` starts at
    /// `l * T * R`).  Fills the lane-mask decision buffer.
    pub fn forward(&mut self, llr: &[i8]) {
        let l = M::LANES;
        let r = self.trellis.r;
        let tt = self.total();
        let per_pb = tt * r;
        assert_eq!(llr.len(), l * per_pb, "LLR length != LANES * T * R");
        let n = self.trellis.n_states;
        let acs_backend = self.backend;
        let off = self.bm_off;
        let ring = self.ring;
        let Self {
            trellis,
            pm,
            new_pm,
            bm,
            stage_vals,
            dw,
            ..
        } = &mut *self;
        pm.fill(M::default());
        for s in 0..tt {
            // transpose this stage's per-lane LLRs to [R][lane] so the
            // Gray-code fill below reads contiguous lane vectors
            for ri in 0..r {
                for lane in 0..l {
                    stage_vals[ri * l + lane] = llr[lane * per_pb + s * r + ri] as i32;
                }
            }
            fill_bm_lanes(bm, stage_vals, r, off);
            // ring slot; every backend assigns each state's word, so
            // reused rows need no clearing
            let slot = s % ring;
            let dw_row = &mut dw[slot * n..(slot + 1) * n];
            backend::acs_stage(acs_backend, trellis, pm, new_pm, bm, dw_row);
            std::mem::swap(pm, new_pm);
        }
    }

    /// Algorithm-1 traceback for one lane over the shared lane-mask
    /// decision ring; writes the D payload bits into `out`.
    /// `start_state` is arbitrary (the merge phase absorbs it).
    pub fn traceback_into(&self, lane: usize, start_state: usize, out: &mut [u8]) {
        self.traceback_from(&self.dw, lane, start_state, out);
    }

    /// Algorithm-1 traceback over a detached decision ring (a
    /// [`decision_ring`](Self::decision_ring) copy of matching
    /// geometry) — the per-lane traceback phase of the split ACS /
    /// traceback pipeline runs this on whichever worker picked the
    /// job up.
    pub fn traceback_from(&self, dw: &[M::Sel], lane: usize, start_state: usize, out: &mut [u8]) {
        assert!(lane < M::LANES);
        let (d, l) = (self.block, self.depth);
        let tt = self.total();
        assert_eq!(out.len(), d, "output buffer != D bits");
        assert_eq!(dw.len(), self.ring_len(), "decision ring length");
        let n = self.trellis.n_states;
        let v = self.trellis.v;
        let mask = (1usize << (v - 1)) - 1;
        let ring = self.ring;
        let mut state = start_state;
        for s in (l..tt).rev() {
            if s <= d + l - 1 {
                out[s - l] = ((state >> (v - 1)) & 1) as u8;
            }
            let bit = dw[(s % ring) * n + state].lane_bit(lane);
            state = 2 * (state & mask) + bit;
        }
    }

    /// Decode one full lane group (`M::LANES * T * R` LLRs, blocks
    /// back to back) into `out` (`M::LANES * block` bits, same block
    /// order), reusing every scratch buffer.
    pub fn decode_group_into(&mut self, llr: &[i8], out: &mut [u8]) {
        assert_eq!(
            out.len(),
            M::LANES * self.block,
            "output buffer != LANES * D bits"
        );
        self.forward(llr);
        let d = self.block;
        for (lane, chunk) in out.chunks_exact_mut(d).enumerate() {
            self.traceback_into(lane, 0, chunk);
        }
    }
}

// ---------------------------------------------------------------------------
// Lane-width autotune.
// ---------------------------------------------------------------------------

/// Time `reps` group decodes (after one warmup) and return the best
/// per-PB duration — the calibration primitive of the autotuner.
/// Calibrates the same resolved `backend` the engine will run.
fn calibrate_kernel<M: Metric>(
    t: &Trellis,
    block: usize,
    depth: usize,
    q: u32,
    backend: AcsBackend,
    llr: &[i8],
    reps: usize,
) -> Duration {
    let mut kern = LaneInterleavedAcs::<M>::with_config(t, block, depth, q, backend);
    let per_group = kern.total() * t.r * M::LANES;
    let mut out = vec![0u8; M::LANES * block];
    let mut best = Duration::MAX;
    for rep in 0..=reps {
        let t0 = Instant::now();
        kern.decode_group_into(&llr[..per_group], &mut out);
        let dt = t0.elapsed();
        if rep > 0 {
            best = best.min(dt);
        }
    }
    best / M::LANES as u32
}

/// Pick the lane width for one engine: u32 when
/// [`u16_width_eligible`] rejects the geometry; otherwise a short
/// calibration decode per width (deterministic LLRs in the
/// quantizer's range, geometry capped at D = 128 so construction
/// stays cheap) — whichever decodes a PB faster wins.  `backend` is
/// the *resolved* ACS backend the engine will run (width rankings can
/// differ between, say, AVX2 and the portable path).  Public so
/// benches can log the pick without constructing an engine.
pub fn autotune_metric_width(
    t: &Trellis,
    batch: usize,
    block: usize,
    depth: usize,
    q: u32,
    backend: AcsBackend,
) -> MetricWidth {
    if !u16_width_eligible(t, batch, q) {
        return MetricWidth::W32;
    }
    let cal_block = block.min(128);
    let per_pb = (cal_block + 2 * depth) * t.r;
    let mut rng = Xoshiro256::seeded(0xCA11B7A7E);
    let hi = (1i64 << (q - 1)) - 1;
    let lo = if q == 8 { -128i64 } else { -hi };
    let llr: Vec<i8> = (0..LANES_U16 * per_pb)
        .map(|_| (rng.next_below((hi - lo + 1) as u64) as i64 + lo) as i8)
        .collect();
    let t16 = calibrate_kernel::<u16>(t, cal_block, depth, q, backend, &llr, 2);
    let t32 = calibrate_kernel::<u32>(t, cal_block, depth, q, backend, &llr, 2);
    if t16 <= t32 {
        MetricWidth::W16
    } else {
        MetricWidth::W32
    }
}

// ---------------------------------------------------------------------------
// The lane-group sharded engine.
// ---------------------------------------------------------------------------

/// Per-worker kernel of the SIMD pool at the engine's resolved width.
/// The u16 worker also carries an 8-lane u32 kernel so a ragged tail
/// of 8..16 PBs can peel one u32 lane-group off instead of decoding
/// everything scalar (all widths are bit-identical, so mixing them
/// inside one batch is safe).
enum LaneKernel {
    W16 {
        group: LaneInterleavedAcs<u16>,
        /// Only present when the engine's batch has an 8..16-PB tail.
        mid: Option<LaneInterleavedAcs<u32>>,
    },
    W32(LaneInterleavedAcs<u32>),
}

/// Per-worker state: the lane-group kernel(s), the scalar ragged-tail
/// fallback, and reusable bit scratch.  The engine's batch geometry is
/// fixed at construction, so the u32 peel kernel and the scalar tail
/// kernel are only allocated when the dispatch plan can actually
/// produce such jobs (otherwise every worker would carry dead
/// scratch).
struct SimdWorker {
    kern: LaneKernel,
    tail: Option<ButterflyAcs>,
    group_bits: Vec<u8>,
    bits: Vec<u8>,
    block: usize,
    per_pb: usize,
}

/// The ACS phase's detached survivor artifact for one SIMD shard: a
/// lockstep lane-group's copied decision ring at the width that
/// decoded it, or the scalar tail's consecutive `ButterflyAcs` rings.
/// Handing the rings off is what lets the traceback phase run on
/// whichever worker frees up first while the ACS worker's kernels
/// immediately start the next shard's forward pass.
enum SimdAcsArtifact {
    /// 16-lane u16 group ring (u16 lane-mask words).
    Lanes16(Vec<u16>),
    /// 8-lane u32 group ring (u8 lane-mask words) — the u32 engine's
    /// group kernel or the u16 engine's peeled `mid` kernel.
    Lanes8(Vec<u8>),
    /// `n_pbs` consecutive scalar decision rings (u64 words each).
    Scalar(Vec<u64>),
}

impl SimdWorker {
    fn new(
        t: &Trellis,
        batch: usize,
        block: usize,
        depth: usize,
        q: u32,
        width: MetricWidth,
        backend: AcsBackend,
    ) -> SimdWorker {
        let (kern, lanes, scalar_tail) = match width {
            MetricWidth::W16 => (
                LaneKernel::W16 {
                    group: LaneInterleavedAcs::with_config(t, block, depth, q, backend),
                    // the peeled u32 sub-group only exists for tails of
                    // 8..16 PBs
                    mid: (batch % LANES_U16 >= LANES)
                        .then(|| LaneInterleavedAcs::with_config(t, block, depth, q, backend)),
                },
                LANES_U16,
                batch % LANES,
            ),
            _ => (
                LaneKernel::W32(LaneInterleavedAcs::with_config(t, block, depth, q, backend)),
                LANES,
                batch % LANES,
            ),
        };
        SimdWorker {
            kern,
            tail: (scalar_tail > 0).then(|| ButterflyAcs::with_quantizer(t, block, depth, q)),
            group_bits: vec![0u8; lanes * block],
            bits: vec![0u8; if scalar_tail > 0 { block } else { 0 }],
            block,
            per_pb: (block + 2 * depth) * t.r,
        }
    }

    fn decode(&mut self, n_pbs: usize, llr: &[i8]) -> (Vec<u32>, Vec<u32>) {
        let (block, per_pb) = (self.block, self.per_pb);
        let wpp = block.div_ceil(32);
        let mut words = Vec::with_capacity(n_pbs * wpp);
        let mut margins = Vec::with_capacity(n_pbs);
        // the widest lockstep kernel this job fills exactly; lane
        // margins are read right after the group decode, while the
        // kernel's metric columns still hold this job's forward pass
        let decoded_lockstep = match &mut self.kern {
            LaneKernel::W16 { group, .. } if n_pbs == LANES_U16 => {
                group.decode_group_into(llr, &mut self.group_bits[..LANES_U16 * block]);
                margins.extend((0..LANES_U16).map(|l| group.lane_margin(l)));
                true
            }
            LaneKernel::W16 { mid: Some(mid), .. } if n_pbs == LANES => {
                // peeled u32 sub-group of a 8..16-PB ragged tail
                mid.decode_group_into(llr, &mut self.group_bits[..LANES * block]);
                margins.extend((0..LANES).map(|l| mid.lane_margin(l)));
                true
            }
            LaneKernel::W32(group) if n_pbs == LANES => {
                group.decode_group_into(llr, &mut self.group_bits[..LANES * block]);
                margins.extend((0..LANES).map(|l| group.lane_margin(l)));
                true
            }
            _ => false,
        };
        if decoded_lockstep {
            for chunk in self.group_bits[..n_pbs * block].chunks_exact(block) {
                words.extend(pack_bits(chunk));
            }
        } else {
            // ragged tail below a u32 lane-group: decoded scalar (the
            // dispatch plan only produces such jobs when `tail` exists)
            let tail = self.tail.as_mut().expect("plan produced an unplanned tail job");
            for p in 0..n_pbs {
                tail.decode_block_into(&llr[p * per_pb..(p + 1) * per_pb], &mut self.bits);
                // read before the next PB overwrites the metrics
                margins.push(tail.margin());
                words.extend(pack_bits(&self.bits));
            }
        }
        (words, margins)
    }

    /// Forward-ACS phase of a shard: run the forward pass at the
    /// widest kernel the job fills, capture the per-lane margins while
    /// the metric columns still hold this job's pass, and copy out the
    /// decision ring(s) as the traceback phase's artifact.
    fn acs(&mut self, n_pbs: usize, llr: &[i8]) -> (SimdAcsArtifact, Vec<u32>) {
        let per_pb = self.per_pb;
        let mut margins = Vec::with_capacity(n_pbs);
        let art = match &mut self.kern {
            LaneKernel::W16 { group, .. } if n_pbs == LANES_U16 => {
                group.forward(llr);
                margins.extend((0..LANES_U16).map(|l| group.lane_margin(l)));
                SimdAcsArtifact::Lanes16(group.decision_ring().to_vec())
            }
            LaneKernel::W16 { mid: Some(mid), .. } if n_pbs == LANES => {
                mid.forward(llr);
                margins.extend((0..LANES).map(|l| mid.lane_margin(l)));
                SimdAcsArtifact::Lanes8(mid.decision_ring().to_vec())
            }
            LaneKernel::W32(group) if n_pbs == LANES => {
                group.forward(llr);
                margins.extend((0..LANES).map(|l| group.lane_margin(l)));
                SimdAcsArtifact::Lanes8(group.decision_ring().to_vec())
            }
            _ => {
                let tail = self.tail.as_mut().expect("plan produced an unplanned tail job");
                let ring_len = tail.ring_len();
                let mut rings = Vec::with_capacity(n_pbs * ring_len);
                for p in 0..n_pbs {
                    tail.forward(&llr[p * per_pb..(p + 1) * per_pb]);
                    margins.push(tail.margin());
                    rings.extend_from_slice(tail.decision_ring());
                }
                SimdAcsArtifact::Scalar(rings)
            }
        };
        (art, margins)
    }

    /// Traceback phase of a shard, over the ACS phase's detached
    /// ring(s).  Bit-identical to the fused path: same rings, same
    /// walk — only the worker it runs on may differ.
    fn tb(&mut self, n_pbs: usize, art: SimdAcsArtifact) -> Vec<u32> {
        let block = self.block;
        let wpp = block.div_ceil(32);
        let mut words = Vec::with_capacity(n_pbs * wpp);
        match art {
            SimdAcsArtifact::Lanes16(ring) => {
                let LaneKernel::W16 { group, .. } = &self.kern else {
                    unreachable!("u16 artifact on a u32-width pool");
                };
                for lane in 0..n_pbs {
                    group.traceback_from(&ring, lane, 0, &mut self.group_bits[..block]);
                    words.extend(pack_bits(&self.group_bits[..block]));
                }
            }
            SimdAcsArtifact::Lanes8(ring) => {
                let kern32 = match &self.kern {
                    LaneKernel::W32(group) => group,
                    LaneKernel::W16 { mid: Some(mid), .. } => mid,
                    LaneKernel::W16 { mid: None, .. } => {
                        unreachable!("u32 artifact on a pool whose plan never peels")
                    }
                };
                for lane in 0..n_pbs {
                    kern32.traceback_from(&ring, lane, 0, &mut self.group_bits[..block]);
                    words.extend(pack_bits(&self.group_bits[..block]));
                }
            }
            SimdAcsArtifact::Scalar(rings) => {
                let tail = self.tail.as_ref().expect("plan produced an unplanned tail job");
                let ring_len = tail.ring_len();
                for p in 0..n_pbs {
                    tail.traceback_from(
                        &rings[p * ring_len..(p + 1) * ring_len],
                        0,
                        &mut self.bits,
                    );
                    words.extend(pack_bits(&self.bits));
                }
            }
        }
        words
    }
}

/// Lane-interleaved SIMD CPU engine: each `decode_batch` call cuts the
/// batch into `batch / lane_width` full lane-groups plus ragged-tail
/// jobs (in u16 mode a tail of 8..16 PBs first peels one 8-lane u32
/// group; at most 7 PBs ever decode scalar), dispatches them to a
/// persistent [`WorkerPool`] — one job per lane-group, so attribution
/// and load balancing are lane-group granular — and splices the
/// bit-packed outputs back in batch order.  The lane width (u16 × 16
/// or u32 × 8) is autotuned at construction unless forced; decisions
/// are bit-identical to the scalar engines in either width.  Multiple
/// coordinator lanes may call concurrently.
pub struct SimdCpuEngine {
    trellis: Trellis,
    batch: usize,
    block: usize,
    depth: usize,
    /// Resolved lane-group width (8 u32 lanes or 16 u16 lanes).
    lanes: usize,
    /// Resolved ACS stage-kernel backend, shared by every worker.
    backend: AcsBackend,
    pool: WorkerPool,
}

impl SimdCpuEngine {
    /// Build a pool of `workers` decode workers (`0` = one per
    /// available core) with the default 8-bit quantizer and autotuned
    /// lane width.
    pub fn new(
        trellis: &Trellis,
        batch: usize,
        block: usize,
        depth: usize,
        workers: usize,
    ) -> SimdCpuEngine {
        SimdCpuEngine::with_config(
            trellis,
            batch,
            block,
            depth,
            workers,
            SimdTuning {
                width: MetricWidth::Auto,
                q: 8,
                backend: BackendChoice::Auto,
            },
        )
    }

    /// Full-control constructor: [`SimdTuning::width`] selects the
    /// path-metric storage (with the checked u32 fallback when u16's
    /// spread bound does not hold — see [`MetricWidth`]),
    /// [`SimdTuning::q`] the quantizer width the BM offset is derived
    /// from, and [`SimdTuning::backend`] the ACS stage kernel
    /// (resolved here with the checked fallback of
    /// [`BackendChoice::resolve`]; the pick is visible in the engine
    /// name, [`SimdCpuEngine::backend()`](SimdCpuEngine::backend()) and the pool stats).  Most
    /// callers should go through
    /// [`DecoderConfig::build_engine`](crate::config::DecoderConfig::build_engine)
    /// instead.
    pub fn with_config(
        trellis: &Trellis,
        batch: usize,
        block: usize,
        depth: usize,
        workers: usize,
        tuning: SimdTuning,
    ) -> SimdCpuEngine {
        SimdCpuEngine::with_config_mode(trellis, batch, block, depth, workers, tuning, true)
    }

    /// Fused forward+traceback pool (each shard decoded end-to-end on
    /// one worker) — the reference the split pipeline's equivalence
    /// tests and benches compare against.
    pub fn with_config_fused(
        trellis: &Trellis,
        batch: usize,
        block: usize,
        depth: usize,
        workers: usize,
        tuning: SimdTuning,
    ) -> SimdCpuEngine {
        SimdCpuEngine::with_config_mode(trellis, batch, block, depth, workers, tuning, false)
    }

    fn with_config_mode(
        trellis: &Trellis,
        batch: usize,
        block: usize,
        depth: usize,
        workers: usize,
        tuning: SimdTuning,
        split: bool,
    ) -> SimdCpuEngine {
        let SimdTuning { width, q, backend } = tuning;
        assert!(batch > 0 && block > 0 && depth > 0);
        assert!((2..=8).contains(&q), "q={q} out of range for i8 input");
        let backend = backend.resolve();
        let resolved = match width {
            MetricWidth::W32 => MetricWidth::W32,
            // checked fallback: never run a width the bound can't
            // prove, and never report u16 when the batch can't fill a
            // single 16-lane group (every PB would take the tail
            // path, so the u16 kernel would not actually run)
            MetricWidth::W16 if u16_width_eligible(trellis, batch, q) => MetricWidth::W16,
            MetricWidth::W16 => MetricWidth::W32,
            MetricWidth::Auto => autotune_metric_width(trellis, batch, block, depth, q, backend),
        };
        let (lanes, bits) = match resolved {
            MetricWidth::W16 => (LANES_U16, 16u64),
            _ => (LANES, 32u64),
        };
        let t = trellis.clone();
        let make = move |_wid: usize| SimdWorker::new(&t, batch, block, depth, q, resolved, backend);
        let pool = if split {
            WorkerPool::spawn_split(
                "pbvd-simd",
                workers,
                bits,
                backend.code(),
                make,
                SimdWorker::acs,
                SimdWorker::tb,
            )
        } else {
            WorkerPool::spawn("pbvd-simd", workers, bits, backend.code(), make, SimdWorker::decode)
        };
        // survivor footprint of the lane-group kernel every worker
        // carries (one Sel word per state per ring stage, at the
        // resolved width)
        let sel_bytes = match resolved {
            MetricWidth::W16 => std::mem::size_of::<u16>(),
            _ => std::mem::size_of::<u8>(),
        };
        pool.set_survivor_footprint(
            ((block + depth) * trellis.n_states * sel_bytes) as u64,
            (block + depth) as u64,
            (block + 2 * depth) as u64,
        );
        SimdCpuEngine {
            trellis: trellis.clone(),
            batch,
            block,
            depth,
            lanes,
            backend,
            pool,
        }
    }

    /// Pool sized to the machine (one worker per available core).
    pub fn with_auto_workers(
        trellis: &Trellis,
        batch: usize,
        block: usize,
        depth: usize,
    ) -> SimdCpuEngine {
        SimdCpuEngine::new(trellis, batch, block, depth, 0)
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Resolved lane-group width: 16 (u16 metrics) or 8 (u32 metrics).
    pub fn lane_width(&self) -> usize {
        self.lanes
    }

    /// Resolved ACS stage-kernel backend (the checked-fallback result
    /// of the construction-time [`BackendChoice`]), also recorded in
    /// the engine name and [`WorkerSnapshot::backend`].
    pub fn backend(&self) -> AcsBackend {
        self.backend
    }

    /// Path-metric storage width actually running (16 or 32) — the
    /// autotuner's pick, also recorded in [`WorkerPoolStats`](crate::metrics::WorkerPoolStats) and the
    /// per-call [`WorkerSnapshot::metric_bits`].
    pub fn metric_bits(&self) -> u64 {
        self.pool.metric_bits()
    }

    /// Cumulative pool counters (engine lifetime; diff two snapshots
    /// for a per-stream view).  `jobs` counts lane-groups.
    pub fn pool_stats(&self) -> WorkerSnapshot {
        self.pool.snapshot()
    }

    /// Lane-group dispatch core shared by both [`DecodeEngine`] entry
    /// points; the batch buffer reaches workers as `Arc` clones, never
    /// copied here.
    fn dispatch(&self, llr_i8: &Arc<[i8]>) -> Result<(Vec<u32>, BatchTimings)> {
        let r = self.trellis.r;
        let per_pb = (self.block + 2 * self.depth) * r;
        if llr_i8.len() != self.batch * per_pb {
            bail!(
                "batch size mismatch: got {} LLRs, engine wants {}",
                llr_i8.len(),
                self.batch * per_pb
            );
        }
        let full = self.batch / self.lanes;
        let mut plan = Vec::with_capacity(full + 2);
        for seq in 0..full {
            plan.push(DecodeShard {
                n_pbs: self.lanes,
                lo: seq * self.lanes * per_pb,
                hi: (seq + 1) * self.lanes * per_pb,
            });
        }
        let mut off = full * self.lanes;
        let mut tail = self.batch - off;
        // u16 mode: a tail of 8..16 PBs peels one u32 lane-group off
        // (the worker's `mid` kernel) so at most LANES - 1 blocks ever
        // take the scalar path, in any width
        if self.lanes == LANES_U16 && tail >= LANES {
            plan.push(DecodeShard {
                n_pbs: LANES,
                lo: off * per_pb,
                hi: (off + LANES) * per_pb,
            });
            off += LANES;
            tail -= LANES;
        }
        if tail > 0 {
            plan.push(DecodeShard {
                n_pbs: tail,
                lo: off * per_pb,
                hi: self.batch * per_pb,
            });
        }
        self.pool.dispatch(llr_i8, &plan)
    }
}

impl DecodeEngine for SimdCpuEngine {
    fn decode_batch(&self, llr_i8: &[i8]) -> Result<(Vec<u32>, BatchTimings)> {
        // Borrowed entry point: one copy to get a shareable allocation.
        // Streaming callers go through `decode_batch_shared` and skip it.
        let t0 = Instant::now();
        let shared: Arc<[i8]> = Arc::from(llr_i8);
        let copy = t0.elapsed();
        let (words, mut t) = self.dispatch(&shared)?;
        t.pack += copy;
        Ok((words, t))
    }

    fn decode_batch_shared(&self, llr_i8: &Arc<[i8]>) -> Result<(Vec<u32>, BatchTimings)> {
        self.dispatch(llr_i8)
    }

    fn batch(&self) -> usize {
        self.batch
    }
    fn block(&self) -> usize {
        self.block
    }
    fn depth(&self) -> usize {
        self.depth
    }
    fn r(&self) -> usize {
        self.trellis.r
    }
    fn name(&self) -> String {
        format!(
            "simd-cpu:b{}w{}x{}-{}",
            self.batch,
            self.pool.workers(),
            self.lanes,
            self.backend.name()
        )
    }
    fn worker_snapshot(&self) -> Option<WorkerSnapshot> {
        Some(self.pool.snapshot())
    }
    fn install_fault_plan(&self, plan: Option<Arc<crate::serve::faults::FaultPlan>>) {
        self.pool.install_fault_plan(plan);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CpuEngine;
    use crate::viterbi::CpuPbvdDecoder;

    fn random_i8_llrs(rng: &mut Xoshiro256, n: usize) -> Vec<i8> {
        // full i8 range including -128 (frame_stream clamps to -128)
        (0..n)
            .map(|_| ((rng.next_below(256) as i32) - 128) as i8)
            .collect()
    }

    use crate::testutil::expected_simd_jobs;

    #[test]
    fn interleaved_bm_fill_matches_scalar_table_per_lane() {
        fn check_width<M: Metric>(seed: u64) {
            let l = M::LANES;
            let mut rng = Xoshiro256::seeded(seed);
            for r in [2usize, 3] {
                let n_cw = 1usize << r;
                let mut stage_vals = vec![0i32; r * l];
                let mut lane_llrs = vec![vec![0i8; r]; l];
                for (lane, lane_llr) in lane_llrs.iter_mut().enumerate() {
                    let llr8 = random_i8_llrs(&mut rng, r);
                    for ri in 0..r {
                        stage_vals[ri * l + lane] = llr8[ri] as i32;
                    }
                    *lane_llr = llr8;
                }
                let mut bm_i = vec![M::default(); n_cw * l];
                fill_bm_lanes(&mut bm_i, &stage_vals, r, bm_offset(r, 8));
                let off = (r as i64) * 128;
                for lane in 0..l {
                    for c in 0..n_cw {
                        let mut acc = 0i64;
                        for (ri, &y) in lane_llrs[lane].iter().enumerate() {
                            let bit = ((c >> (r - 1 - ri)) & 1) as i64;
                            acc += (y as i64) * (2 * bit - 1);
                        }
                        let got: u64 = bm_i[c * l + lane].into();
                        assert_eq!(got as i64, off + acc, "r={r} c={c} lane={lane}");
                    }
                }
            }
        }
        check_width::<u32>(0x51D);
        check_width::<u16>(0x51D16);
    }

    fn check_lockstep_matches_reference<M: Metric>() {
        for (name, k, _) in crate::trellis::PRESETS {
            let t = Trellis::preset(name).unwrap();
            let (block, depth) = (40usize, 6 * *k as usize);
            let reference = CpuPbvdDecoder::new(&t, block, depth);
            let mut kern = LaneInterleavedAcs::<M>::new(&t, block, depth);
            let per_pb = kern.total() * t.r;
            let mut rng = Xoshiro256::seeded(0x1A4E5);
            let llr8 = random_i8_llrs(&mut rng, M::LANES * per_pb);
            kern.forward(&llr8);
            let mut bits = vec![0u8; block];
            for lane in 0..M::LANES {
                let lane_llr32: Vec<i32> = llr8[lane * per_pb..(lane + 1) * per_pb]
                    .iter()
                    .map(|&x| x as i32)
                    .collect();
                let fwd = reference.forward(&lane_llr32);
                // path-metric column of this lane agrees exactly
                for st in 0..t.n_states {
                    let got: u64 = kern.path_metrics()[st * M::LANES + lane].into();
                    assert_eq!(
                        got as i64, fwd.pm[st],
                        "{name} u{} lane={lane} state={st}",
                        M::BITS
                    );
                }
                // ... and so does the per-lane confidence margin
                assert_eq!(
                    kern.lane_margin(lane),
                    fwd.margin(),
                    "{name} u{} lane={lane} margin",
                    M::BITS
                );
                for s0 in [0usize, 1, t.n_states - 1] {
                    kern.traceback_into(lane, s0, &mut bits);
                    assert_eq!(
                        bits,
                        reference.traceback(&fwd, s0),
                        "{name} u{} lane={lane} s0={s0}",
                        M::BITS
                    );
                }
            }
        }
    }

    #[test]
    fn lockstep_forward_matches_reference_per_lane_u32() {
        check_lockstep_matches_reference::<u32>();
    }

    #[test]
    fn lockstep_forward_matches_reference_per_lane_u16() {
        check_lockstep_matches_reference::<u16>();
    }

    // (Spread-bound accept/reject facts are pinned once, in
    // rust/tests/overflow_guard.rs, alongside the q-monotonicity and
    // engine-fallback checks.)

    #[test]
    fn metric_width_display_round_trips_every_variant() {
        for w in [MetricWidth::Auto, MetricWidth::W16, MetricWidth::W32] {
            assert_eq!(w.to_string().parse::<MetricWidth>().unwrap(), w);
        }
        assert!("64".parse::<MetricWidth>().is_err());
        assert!("w16".parse::<MetricWidth>().is_err());
    }

    #[test]
    fn forced_widths_match_cpu_engine_with_ragged_tail() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        // batch = 2 full u32 lane-groups + 3-PB ragged tail; for the
        // u16 engine the same batch is 1 full group + 3-PB tail
        let (batch, block, depth) = (2 * LANES + 3, 64usize, 42usize);
        let cpu = CpuEngine::new(&t, batch, block, depth);
        let mut rng = Xoshiro256::seeded(0x51ACE);
        let llr = random_i8_llrs(&mut rng, batch * (block + 2 * depth) * t.r);
        let (want, _) = cpu.decode_batch(&llr).unwrap();
        for width in [MetricWidth::W32, MetricWidth::W16] {
            for workers in [1usize, 3, 8] {
                let simd = SimdCpuEngine::with_config(
                    &t,
                    batch,
                    block,
                    depth,
                    workers,
                    SimdTuning {
                        width,
                        q: 8,
                        backend: BackendChoice::Auto,
                    },
                );
                let (got, timings) = simd.decode_batch(&llr).unwrap();
                assert_eq!(got, want, "{width:?} workers={workers}");
                let pw = timings.per_worker.expect("per-call attribution");
                assert_eq!(pw.total_blocks(), batch as u64, "workers={workers}");
                let want_jobs = expected_simd_jobs(batch, simd.lane_width());
                assert_eq!(pw.total_jobs(), want_jobs, "{width:?} workers={workers}");
                assert_eq!(pw.metric_bits, simd.metric_bits());
            }
        }
    }

    #[test]
    fn simd_engine_all_tail_when_batch_below_lane_width() {
        let t = Trellis::preset("k3").unwrap();
        let (batch, block, depth) = (LANES - 1, 32usize, 15usize);
        let cpu = CpuEngine::new(&t, batch, block, depth);
        let simd = SimdCpuEngine::new(&t, batch, block, depth, 2);
        let mut rng = Xoshiro256::seeded(0x7A11);
        let llr = random_i8_llrs(&mut rng, batch * (block + 2 * depth) * t.r);
        let (want, _) = cpu.decode_batch(&llr).unwrap();
        let (got, timings) = simd.decode_batch(&llr).unwrap();
        assert_eq!(got, want);
        assert_eq!(timings.per_worker.unwrap().total_jobs(), 1);
        // batch < 16 never autotunes into the u16 kernel
        assert_eq!(simd.metric_bits(), 32);
    }

    #[test]
    fn autotune_records_pick_and_stays_bit_identical() {
        let t = Trellis::preset("k5").unwrap();
        let (batch, block, depth) = (2 * LANES_U16, 48usize, 30usize);
        let auto = SimdCpuEngine::new(&t, batch, block, depth, 2);
        let bits = auto.metric_bits();
        assert!(bits == 16 || bits == 32, "autotune must pick a width");
        assert_eq!(auto.pool_stats().metric_bits, bits);
        assert_eq!(
            auto.lane_width(),
            if bits == 16 { LANES_U16 } else { LANES }
        );
        assert!(auto.name().contains(&format!("x{}", auto.lane_width())));
        let cpu = CpuEngine::new(&t, batch, block, depth);
        let mut rng = Xoshiro256::seeded(0x47);
        let llr = random_i8_llrs(&mut rng, batch * (block + 2 * depth) * t.r);
        let (want, _) = cpu.decode_batch(&llr).unwrap();
        let (got, _) = auto.decode_batch(&llr).unwrap();
        assert_eq!(got, want);
    }

    // (The inadmissible-code checked-fallback test lives in
    // rust/tests/overflow_guard.rs, which also covers the Auto path.)

    #[test]
    fn small_batch_forced_u16_falls_back_to_u32() {
        // batch < 16 can never fill a u16 lane-group, so a forced W16
        // must resolve to the u32 kernel rather than report a width
        // that would only ever run the scalar tail path.
        let t = Trellis::preset("k5").unwrap();
        let simd = SimdCpuEngine::with_config(
            &t,
            LANES_U16 - 1,
            32,
            20,
            2,
            SimdTuning {
                width: MetricWidth::W16,
                q: 8,
                backend: BackendChoice::Auto,
            },
        );
        assert_eq!(simd.metric_bits(), 32);
        assert_eq!(simd.lane_width(), LANES);
        assert!(simd.name().contains("x8-"), "{}", simd.name());
    }

    #[test]
    fn every_available_backend_matches_scalar_at_kernel_level() {
        // The in-module seam check: each backend's stage kernel must
        // produce the same path metrics AND the same decision masks as
        // the scalar reference, per width (the engine-level and
        // adversarial-corpus pins live in tests/backend_conformance.rs).
        fn check_width<M: Metric>() {
            let t = Trellis::preset("ccsds_k7").unwrap();
            let (block, depth) = (32usize, 42usize);
            let mut rng = Xoshiro256::seeded(0xBACE2D);
            let per_pb = (block + 2 * depth) * t.r;
            let llr = random_i8_llrs(&mut rng, M::LANES * per_pb);
            let mut reference = LaneInterleavedAcs::<M>::with_config(
                &t, block, depth, 8, AcsBackend::Scalar,
            );
            reference.forward(&llr);
            for b in AcsBackend::available() {
                let mut kern = LaneInterleavedAcs::<M>::with_config(&t, block, depth, 8, b);
                assert_eq!(kern.backend(), b.name());
                kern.forward(&llr);
                assert_eq!(
                    kern.path_metrics(),
                    reference.path_metrics(),
                    "{b:?} u{} path metrics diverged from scalar",
                    M::BITS
                );
                // only the retained traceback window is comparable —
                // the survivor ring has overwritten earlier stages
                for s in depth..block + 2 * depth {
                    for st in 0..t.n_states {
                        assert_eq!(
                            kern.decision_mask(s, st),
                            reference.decision_mask(s, st),
                            "{b:?} u{} stage {s} state {st}",
                            M::BITS
                        );
                    }
                }
            }
        }
        check_width::<u32>();
        check_width::<u16>();
    }

    #[test]
    fn lane_ring_is_depth_windowed_and_detachable() {
        fn check_width<M: Metric>() {
            let t = Trellis::preset("ccsds_k7").unwrap();
            // depth < block and depth >= block (ring wraps repeatedly)
            for (block, depth) in [(48usize, 42usize), (8, 42)] {
                let reference = CpuPbvdDecoder::new(&t, block, depth);
                let mut kern = LaneInterleavedAcs::<M>::new(&t, block, depth);
                assert_eq!(kern.ring_stages(), block + depth);
                assert!(kern.ring_stages() < kern.total());
                assert_eq!(kern.decision_ring().len(), kern.ring_len());
                assert!(kern.survivor_ring_bytes() < kern.survivor_full_bytes());
                let per_pb = kern.total() * t.r;
                let mut rng = Xoshiro256::seeded(0x1A4E);
                let llr8 = random_i8_llrs(&mut rng, M::LANES * per_pb);
                kern.forward(&llr8);
                let detached = kern.decision_ring().to_vec();
                let mut live = vec![0u8; block];
                let mut from = vec![0u8; block];
                for lane in [0usize, M::LANES - 1] {
                    let lane_llr32: Vec<i32> = llr8[lane * per_pb..(lane + 1) * per_pb]
                        .iter()
                        .map(|&x| x as i32)
                        .collect();
                    let fwd = reference.forward(&lane_llr32);
                    for s0 in [0usize, t.n_states - 1] {
                        kern.traceback_into(lane, s0, &mut live);
                        kern.traceback_from(&detached, lane, s0, &mut from);
                        assert_eq!(live, from, "u{} D={block} lane={lane} s0={s0}", M::BITS);
                        assert_eq!(
                            live,
                            reference.traceback(&fwd, s0),
                            "u{} D={block} lane={lane} s0={s0}",
                            M::BITS
                        );
                    }
                }
            }
        }
        check_width::<u32>();
        check_width::<u16>();
    }

    #[test]
    fn split_engine_matches_fused_engine() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        // 2 full u16 groups + a peeled u32 group + a 3-PB scalar tail
        // (for the u32 engine: 4 full groups + the same tail)
        let (batch, block, depth) = (2 * LANES_U16 + LANES + 3, 48usize, 42usize);
        let mut rng = Xoshiro256::seeded(0x5317);
        let llr = random_i8_llrs(&mut rng, batch * (block + 2 * depth) * t.r);
        for width in [MetricWidth::W32, MetricWidth::W16] {
            let tuning = SimdTuning {
                width,
                q: 8,
                backend: BackendChoice::Auto,
            };
            let fused = SimdCpuEngine::with_config_fused(&t, batch, block, depth, 2, tuning);
            let (want, want_t) = fused.decode_batch(&llr).unwrap();
            // the fused pool records no phase split
            let pw = want_t.per_worker.unwrap();
            assert_eq!(pw.total_tb_busy(), std::time::Duration::ZERO);
            for workers in [1usize, 2, 8] {
                let split = SimdCpuEngine::with_config(&t, batch, block, depth, workers, tuning);
                let (got, tm) = split.decode_batch(&llr).unwrap();
                assert_eq!(got, want, "{width:?} workers={workers}");
                assert_eq!(tm.margins, want_t.margins, "{width:?} workers={workers}");
                let pw = tm.per_worker.expect("per-call attribution");
                // phase attribution: all busy time is ACS + traceback
                assert_eq!(pw.total_acs_busy() + pw.total_tb_busy(), pw.total_busy());
                assert!(pw.total_tb_busy() > std::time::Duration::ZERO);
                assert_eq!(pw.total_blocks(), batch as u64);
                assert_eq!(
                    pw.total_jobs(),
                    expected_simd_jobs(batch, split.lane_width())
                );
                // survivor footprint travels with the attribution, at
                // the resolved width's Sel size
                assert_eq!(pw.survivor_ring_stages, (block + depth) as u64);
                assert_eq!(pw.survivor_total_stages, (block + 2 * depth) as u64);
                let sel_bytes = if split.lane_width() == LANES_U16 { 2 } else { 1 };
                assert_eq!(
                    pw.survivor_ring_bytes,
                    ((block + depth) * t.n_states * sel_bytes) as u64
                );
            }
        }
    }

    #[test]
    fn engine_records_resolved_backend() {
        let t = Trellis::preset("k5").unwrap();
        for b in AcsBackend::available() {
            let simd = SimdCpuEngine::with_config(
                &t,
                LANES,
                32,
                20,
                2,
                SimdTuning {
                    width: MetricWidth::W32,
                    q: 8,
                    backend: BackendChoice::Forced(b),
                },
            );
            assert_eq!(simd.backend(), b);
            assert!(simd.name().ends_with(b.name()), "{}", simd.name());
            assert_eq!(simd.pool_stats().backend, b.code());
        }
        // forcing an unavailable backend falls back to the detected one
        let unavailable = [AcsBackend::Avx2, AcsBackend::Neon]
            .into_iter()
            .find(|b| !b.is_available());
        if let Some(missing) = unavailable {
            let simd = SimdCpuEngine::with_config(
                &t,
                LANES,
                32,
                20,
                1,
                SimdTuning {
                    width: MetricWidth::W32,
                    q: 8,
                    backend: BackendChoice::Forced(missing),
                },
            );
            assert_eq!(simd.backend(), AcsBackend::detect());
        }
    }

    #[test]
    fn shared_entry_point_matches_borrowed() {
        let t = Trellis::preset("k5").unwrap();
        let (batch, block, depth) = (LANES + 1, 32usize, 25usize);
        let simd = SimdCpuEngine::new(&t, batch, block, depth, 2);
        let mut rng = Xoshiro256::seeded(0x0C0);
        let llr = random_i8_llrs(&mut rng, batch * (block + 2 * depth) * t.r);
        let (want, _) = simd.decode_batch(&llr).unwrap();
        let shared: Arc<[i8]> = llr.into();
        let (got, _) = simd.decode_batch_shared(&shared).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn simd_engine_rejects_bad_batch_and_reports_stats() {
        let t = Trellis::preset("k5").unwrap();
        let simd = SimdCpuEngine::with_config(
            &t,
            LANES,
            32,
            20,
            3,
            SimdTuning {
                width: MetricWidth::W32,
                q: 8,
                backend: BackendChoice::Auto,
            },
        );
        assert!(simd.decode_batch(&[0i8; 5]).is_err());
        let llr = vec![1i8; LANES * (32 + 40) * t.r];
        let before = simd.pool_stats();
        simd.decode_batch(&llr).unwrap();
        let delta = simd.pool_stats().delta_since(&before);
        assert_eq!(delta.total_blocks(), LANES as u64);
        assert_eq!(delta.total_jobs(), 1);
        assert_eq!(delta.metric_bits, 32);
        assert_eq!(simd.worker_snapshot().unwrap().workers(), 3);
        assert_eq!(simd.workers(), 3);
        assert!(simd.name().contains("w3"));
        assert!(simd.name().starts_with("simd-cpu:"));
    }

    #[test]
    fn pool_shuts_down_cleanly_on_drop() {
        let t = Trellis::preset("k3").unwrap();
        let simd = SimdCpuEngine::new(&t, LANES, 32, 15, 2);
        let llr = vec![0i8; LANES * (32 + 30) * t.r];
        simd.decode_batch(&llr).unwrap();
        drop(simd); // joins workers; must not hang or panic
    }
}
