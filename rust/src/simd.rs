//! Lane-interleaved SIMD butterfly-ACS backend — `LANES` parallel
//! blocks advance through every trellis stage in lockstep.
//!
//! The paper's Gb/s numbers come from mapping all trellis states *and*
//! many parallel blocks (PBs) onto GPU threads at once; the scalar
//! [`ButterflyAcs`](crate::par::ButterflyAcs) kernel steps one PB at a
//! time, leaving the whole SIMD width of each CPU core idle.  This
//! module restructures the data instead of adding threads (the same
//! lesson as the memory-efficient and tensor-core parallel Viterbi
//! decoders, arXiv:2011.09337 / arXiv:2011.13579):
//!
//! * [`LaneInterleavedAcs`] — path metrics stored block-interleaved
//!   (structure-of-arrays, `[state][lane]`, fixed lane width
//!   [`LANES`] = 8 u32 lanes), so the butterfly inner loop is `LANES`
//!   contiguous u32 adds/mins that LLVM autovectorizes.  Decision bits
//!   come out word-parallel: one lane-mask byte per target state per
//!   stage (a single shift/or per lane-group) instead of per-state bit
//!   pokes into shared `u64` rows.  Per-lane branch-metric tables are
//!   filled in one interleaved Gray-code pass reusing the scalar
//!   kernel's antisymmetry trick (`BM(~c) = -BM(c)`).
//! * An explicit AVX2 intrinsics path (`#[cfg(target_arch =
//!   "x86_64")]`, behind the `simd-intrinsics` feature) selected at
//!   runtime via `is_x86_feature_detected!("avx2")`; it performs the
//!   identical adds / unsigned mins / tie-breaks, so decisions stay
//!   bit-identical across backends.
//! * [`SimdCpuEngine`] — a [`DecodeEngine`] that shards *lane-groups*
//!   (not single PBs) across the persistent worker-pool architecture
//!   from `par.rs`, with a ragged-tail fallback to the scalar
//!   `ButterflyAcs` for the `batch % LANES` leftover blocks and exact
//!   per-lane-group worker attribution in
//!   [`BatchTimings::per_worker`].
//!
//! Decisions are **bit-identical** to
//! [`CpuPbvdDecoder`](crate::viterbi::CpuPbvdDecoder): the kernel uses
//! the same `R * 128`-shifted u32 branch metrics and the same per-stage
//! min-normalization as the scalar butterfly kernel, per lane.  The
//! property tests in `rust/tests/simd_engine.rs` pin this across all
//! code presets, lane counts and worker counts.
//!
//! ```text
//! path-metric memory order ([state][lane], u32):
//!
//!             lane 0   lane 1   ...   lane 7     <- 8 parallel blocks
//! state 0   | pm[0]  | pm[1]  | ... | pm[7]  |   <- one 256-bit vector
//! state 1   | pm[8]  | pm[9]  | ... | pm[15] |
//!   ...
//! state N-1 | ...                  | pm[8N-1]|
//! ```

use crate::channel::pack_bits;
use crate::coordinator::{BatchTimings, DecodeEngine};
use crate::metrics::{WorkerPoolStats, WorkerSnapshot};
use crate::par::{gray_walk, ButterflyAcs};
use crate::pipeline::BoundedQueue;
use crate::trellis::Trellis;
use anyhow::{bail, Result};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

/// Fixed lane width: 8 u32 lanes = one 256-bit vector per state.
pub const LANES: usize = 8;

/// Runtime backend selection for the explicit-intrinsics path: only on
/// x86_64, only when the `simd-intrinsics` feature is compiled in, and
/// only if the CPU actually reports AVX2.  The autovectorized portable
/// path is the default everywhere else.
fn avx2_selected() -> bool {
    #[cfg(all(target_arch = "x86_64", feature = "simd-intrinsics"))]
    {
        is_x86_feature_detected!("avx2")
    }
    #[cfg(not(all(target_arch = "x86_64", feature = "simd-intrinsics")))]
    {
        false
    }
}

// ---------------------------------------------------------------------------
// Lane-interleaved branch-metric fill.
// ---------------------------------------------------------------------------

/// Interleaved branch-metric fill for one stage of `LANES` blocks.
///
/// `stage_vals` is the stage's per-lane LLRs transposed to `[R][lane]`
/// (i32-widened); `bm` is the `[codeword][lane]` table.  Walks the same
/// Gray-code sequence as the scalar `fill_bm` ([`gray_walk`]) so each
/// table row costs one add/sub per lane, and derives the upper half by
/// the antisymmetry reflection.  Entries carry the scalar kernel's
/// uniform `R * 128` shift, so every lane's table is entry-for-entry
/// identical to what `ButterflyAcs` computes for that lane's block.
fn fill_bm_lanes(bm: &mut [u32], stage_vals: &[i32], r: usize) {
    let off = (r as i32) * 128;
    let mask = bm.len() / LANES - 1;
    // codeword 0 (all bits clear): corr = -Σ llr, per lane
    let mut acc = [0i32; LANES];
    for ri in 0..r {
        let sv = &stage_vals[ri * LANES..(ri + 1) * LANES];
        for lane in 0..LANES {
            acc[lane] -= sv[lane];
        }
    }
    for lane in 0..LANES {
        bm[lane] = (off + acc[lane]) as u32;
        bm[mask * LANES + lane] = (off - acc[lane]) as u32;
    }
    for (g, ri, set) in gray_walk(r) {
        let sv = &stage_vals[ri * LANES..(ri + 1) * LANES];
        if set {
            for lane in 0..LANES {
                acc[lane] += 2 * sv[lane];
            }
        } else {
            for lane in 0..LANES {
                acc[lane] -= 2 * sv[lane];
            }
        }
        let lo = g * LANES;
        let hi = (mask ^ g) * LANES;
        for lane in 0..LANES {
            bm[lo + lane] = (off + acc[lane]) as u32;
            bm[hi + lane] = (off - acc[lane]) as u32;
        }
    }
}

// ---------------------------------------------------------------------------
// The lockstep ACS stage (portable + AVX2 backends).
// ---------------------------------------------------------------------------

/// One butterfly ACS stage over lane-interleaved metrics, portable
/// path.  The per-lane loops run over `LANES` contiguous u32s with the
/// trellis label lookups hoisted out (one table read serves 8 blocks),
/// which is the shape LLVM autovectorizes; the decision mask for each
/// target state is assembled in a register and stored with a single
/// byte write.
fn acs_stage_autovec(t: &Trellis, pm: &[u32], new_pm: &mut [u32], bm: &[u32], dw_row: &mut [u8]) {
    let half = t.n_states / 2;
    let mut minv = [u32::MAX; LANES];
    let (top, bot) = new_pm.split_at_mut(half * LANES);
    for j in 0..half {
        let pe = &pm[2 * j * LANES..][..LANES];
        let po = &pm[(2 * j + 1) * LANES..][..LANES];
        let b_t0 = &bm[t.cw_top0[j] as usize * LANES..][..LANES];
        let b_t1 = &bm[t.cw_top1[j] as usize * LANES..][..LANES];
        let b_b0 = &bm[t.cw_bot0[j] as usize * LANES..][..LANES];
        let b_b1 = &bm[t.cw_bot1[j] as usize * LANES..][..LANES];
        let out_t = &mut top[j * LANES..][..LANES];
        let mut sel_top = 0u8;
        for lane in 0..LANES {
            let a = pe[lane] + b_t0[lane];
            let b = po[lane] + b_t1[lane];
            let m = a.min(b);
            sel_top |= ((b < a) as u8) << lane;
            out_t[lane] = m;
            minv[lane] = minv[lane].min(m);
        }
        let out_b = &mut bot[j * LANES..][..LANES];
        let mut sel_bot = 0u8;
        for lane in 0..LANES {
            let a2 = pe[lane] + b_b0[lane];
            let b2 = po[lane] + b_b1[lane];
            let m2 = a2.min(b2);
            sel_bot |= ((b2 < a2) as u8) << lane;
            out_b[lane] = m2;
            minv[lane] = minv[lane].min(m2);
        }
        dw_row[j] = sel_top;
        dw_row[j + half] = sel_bot;
    }
    // per-lane min-normalization; lane-contiguous, vectorizes cleanly
    for chunk in new_pm.chunks_exact_mut(LANES) {
        for lane in 0..LANES {
            chunk[lane] -= minv[lane];
        }
    }
}

#[cfg(all(target_arch = "x86_64", feature = "simd-intrinsics"))]
mod avx2 {
    use super::LANES;
    use crate::trellis::Trellis;
    use core::arch::x86_64::*;

    /// One full ACS stage with AVX2: each 256-bit op covers all 8 u32
    /// lanes of one state.  Arithmetic is identical to
    /// `acs_stage_autovec` — same u32 adds, same *unsigned* min, same
    /// tie-break (equal metrics keep the even predecessor, because the
    /// survivor bit is `b < a`) — so decisions are bit-identical.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support
    /// (`is_x86_feature_detected!("avx2")`) and pass `pm`/`new_pm` of
    /// `n_states * LANES` u32s and `bm` covering every codeword label.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn acs_stage(
        t: &Trellis,
        pm: &[u32],
        new_pm: &mut [u32],
        bm: &[u32],
        dw_row: &mut [u8],
    ) {
        debug_assert_eq!(LANES, 8);
        debug_assert_eq!(pm.len(), t.n_states * LANES);
        debug_assert_eq!(new_pm.len(), t.n_states * LANES);
        let half = t.n_states / 2;
        let pmp = pm.as_ptr();
        let bmp = bm.as_ptr();
        let np = new_pm.as_mut_ptr();
        let mut minv = _mm256_set1_epi32(-1); // u32::MAX in every lane
        for j in 0..half {
            let pe = _mm256_loadu_si256(pmp.add(2 * j * LANES) as *const __m256i);
            let po = _mm256_loadu_si256(pmp.add((2 * j + 1) * LANES) as *const __m256i);
            let bt0 =
                _mm256_loadu_si256(bmp.add(t.cw_top0[j] as usize * LANES) as *const __m256i);
            let bt1 =
                _mm256_loadu_si256(bmp.add(t.cw_top1[j] as usize * LANES) as *const __m256i);
            let a = _mm256_add_epi32(pe, bt0);
            let b = _mm256_add_epi32(po, bt1);
            let m = _mm256_min_epu32(a, b);
            // survivor bit per lane: (b < a) == !(min == a); movemask
            // collects the 8 lane sign bits into one byte in one op
            let keep_a = _mm256_movemask_ps(_mm256_castsi256_ps(_mm256_cmpeq_epi32(m, a)));
            _mm256_storeu_si256(np.add(j * LANES) as *mut __m256i, m);
            minv = _mm256_min_epu32(minv, m);
            dw_row[j] = (!keep_a) as u8;

            let bb0 =
                _mm256_loadu_si256(bmp.add(t.cw_bot0[j] as usize * LANES) as *const __m256i);
            let bb1 =
                _mm256_loadu_si256(bmp.add(t.cw_bot1[j] as usize * LANES) as *const __m256i);
            let a2 = _mm256_add_epi32(pe, bb0);
            let b2 = _mm256_add_epi32(po, bb1);
            let m2 = _mm256_min_epu32(a2, b2);
            let keep_a2 = _mm256_movemask_ps(_mm256_castsi256_ps(_mm256_cmpeq_epi32(m2, a2)));
            _mm256_storeu_si256(np.add((j + half) * LANES) as *mut __m256i, m2);
            minv = _mm256_min_epu32(minv, m2);
            dw_row[j + half] = (!keep_a2) as u8;
        }
        // per-lane min-normalization
        for st in 0..2 * half {
            let p = np.add(st * LANES) as *mut __m256i;
            _mm256_storeu_si256(p, _mm256_sub_epi32(_mm256_loadu_si256(p), minv));
        }
    }
}

/// Stage dispatch: the AVX2 path when compiled in and detected at
/// runtime, the portable autovectorized path otherwise.
#[inline]
fn acs_stage(
    t: &Trellis,
    use_avx2: bool,
    pm: &[u32],
    new_pm: &mut [u32],
    bm: &[u32],
    dw_row: &mut [u8],
) {
    #[cfg(all(target_arch = "x86_64", feature = "simd-intrinsics"))]
    if use_avx2 {
        // SAFETY: `use_avx2` is only true after a successful
        // `is_x86_feature_detected!("avx2")`; buffer shapes are fixed
        // at kernel construction.
        unsafe { avx2::acs_stage(t, pm, new_pm, bm, dw_row) };
        return;
    }
    let _ = use_avx2;
    acs_stage_autovec(t, pm, new_pm, bm, dw_row);
}

// ---------------------------------------------------------------------------
// The lane-interleaved kernel.
// ---------------------------------------------------------------------------

/// Lockstep forward/traceback kernel over [`LANES`] parallel blocks
/// with reusable scratch.  One instance per worker thread; geometry is
/// fixed at construction (`block` = D payload bits, `depth` = L,
/// T = D + 2L), exactly like the scalar `ButterflyAcs`.
pub struct LaneInterleavedAcs {
    trellis: Trellis,
    pub block: usize,
    pub depth: usize,
    /// `[state][lane]` path metrics (SoA, u32, min-normalized).
    pm: Vec<u32>,
    new_pm: Vec<u32>,
    /// `[codeword][lane]` branch metrics for the current stage.
    bm: Vec<u32>,
    /// `[R][lane]` i32-widened LLRs of the current stage (fill scratch).
    stage_vals: Vec<i32>,
    /// `[stage][state]` lane-mask decision bytes: bit `l` of
    /// `dw[s * N + st]` is the survivor input of state `st` in lane `l`.
    dw: Vec<u8>,
    use_avx2: bool,
}

impl LaneInterleavedAcs {
    pub fn new(trellis: &Trellis, block: usize, depth: usize) -> LaneInterleavedAcs {
        assert!(block > 0 && depth > 0);
        let n = trellis.n_states;
        let total = block + 2 * depth;
        LaneInterleavedAcs {
            trellis: trellis.clone(),
            block,
            depth,
            pm: vec![0u32; n * LANES],
            new_pm: vec![0u32; n * LANES],
            bm: vec![0u32; (1 << trellis.r) * LANES],
            stage_vals: vec![0i32; trellis.r * LANES],
            dw: vec![0u8; total * n],
            use_avx2: avx2_selected(),
        }
    }

    /// Stages per parallel block (T = D + 2L).
    pub fn total(&self) -> usize {
        self.block + 2 * self.depth
    }

    pub fn trellis(&self) -> &Trellis {
        &self.trellis
    }

    /// Which ACS backend this kernel runs (`"avx2"` or `"autovec"`).
    pub fn backend(&self) -> &'static str {
        if self.use_avx2 {
            "avx2"
        } else {
            "autovec"
        }
    }

    /// Final normalized `[state][lane]` path metrics of the last
    /// forward pass; lane `l`'s column is bit-identical to
    /// `ButterflyAcs::path_metrics` for that lane's block.
    pub fn path_metrics(&self) -> &[u32] {
        &self.pm
    }

    /// Lockstep forward pass over `LANES` parallel blocks.  `llr`
    /// holds the lane blocks back to back (`LANES * T * R` i8 values,
    /// stage-major `[T][R]` within each lane; lane `l` starts at
    /// `l * T * R`).  Fills the lane-mask decision buffer.
    pub fn forward(&mut self, llr: &[i8]) {
        let r = self.trellis.r;
        let tt = self.total();
        let per_pb = tt * r;
        assert_eq!(llr.len(), LANES * per_pb, "LLR length != LANES * T * R");
        let n = self.trellis.n_states;
        let use_avx2 = self.use_avx2;
        let Self {
            trellis,
            pm,
            new_pm,
            bm,
            stage_vals,
            dw,
            ..
        } = &mut *self;
        pm.fill(0);
        for s in 0..tt {
            // transpose this stage's per-lane LLRs to [R][lane] so the
            // Gray-code fill below reads contiguous lane vectors
            for ri in 0..r {
                for lane in 0..LANES {
                    stage_vals[ri * LANES + lane] = llr[lane * per_pb + s * r + ri] as i32;
                }
            }
            fill_bm_lanes(bm, stage_vals, r);
            let dw_row = &mut dw[s * n..(s + 1) * n];
            acs_stage(trellis, use_avx2, pm, new_pm, bm, dw_row);
            std::mem::swap(pm, new_pm);
        }
    }

    /// Algorithm-1 traceback for one lane over the shared lane-mask
    /// decision bytes; writes the D payload bits into `out`.
    /// `start_state` is arbitrary (the merge phase absorbs it).
    pub fn traceback_into(&self, lane: usize, start_state: usize, out: &mut [u8]) {
        assert!(lane < LANES);
        let (d, l) = (self.block, self.depth);
        let tt = self.total();
        assert_eq!(out.len(), d, "output buffer != D bits");
        let n = self.trellis.n_states;
        let v = self.trellis.v;
        let mask = (1usize << (v - 1)) - 1;
        let mut state = start_state;
        for s in (l..tt).rev() {
            if s <= d + l - 1 {
                out[s - l] = ((state >> (v - 1)) & 1) as u8;
            }
            let bit = ((self.dw[s * n + state] >> lane) & 1) as usize;
            state = 2 * (state & mask) + bit;
        }
    }

    /// Decode one full lane group (`LANES * T * R` LLRs, blocks back
    /// to back) into `out` (`LANES * block` bits, same block order),
    /// reusing every scratch buffer.
    pub fn decode_group_into(&mut self, llr: &[i8], out: &mut [u8]) {
        assert_eq!(out.len(), LANES * self.block, "output buffer != LANES * D bits");
        self.forward(llr);
        let d = self.block;
        for (lane, chunk) in out.chunks_exact_mut(d).enumerate() {
            self.traceback_into(lane, 0, chunk);
        }
    }
}

// ---------------------------------------------------------------------------
// The lane-group sharded engine.
// ---------------------------------------------------------------------------

/// One lane-group of a batch (up to [`LANES`] consecutive PBs) plus a
/// reply channel.  Jobs share the caller's batch buffer (`Arc<[i8]>`,
/// zero copies on the `decode_batch_shared` path).
struct GroupJob {
    seq: usize,
    /// `LANES` for full lane groups; `batch % LANES` for the ragged
    /// tail job (decoded by the scalar fallback kernel).
    n_pbs: usize,
    llr: Arc<[i8]>,
    /// Byte offset of this group's first PB within `llr`.
    lo: usize,
    reply: mpsc::Sender<GroupResult>,
}

struct GroupResult {
    seq: usize,
    /// Which worker decoded this lane-group, and for how long — the
    /// per-lane-group attribution that feeds `BatchTimings::per_worker`.
    wid: usize,
    busy: Duration,
    n_pbs: usize,
    /// Bit-packed decoded payload, `n_pbs * ceil(D/32)` words.
    words: Vec<u32>,
}

fn worker_loop(
    wid: usize,
    trellis: Trellis,
    block: usize,
    depth: usize,
    jobs: Arc<BoundedQueue<GroupJob>>,
    stats: Arc<WorkerPoolStats>,
) {
    let mut group_kern = LaneInterleavedAcs::new(&trellis, block, depth);
    // ragged-tail fallback: batch % LANES blocks decoded scalar
    let mut tail_kern = ButterflyAcs::new(&trellis, block, depth);
    let per_pb = group_kern.total() * trellis.r;
    let wpp = block.div_ceil(32);
    let mut group_bits = vec![0u8; LANES * block];
    let mut bits = vec![0u8; block];
    while let Some(job) = jobs.pop() {
        let t0 = Instant::now();
        let mut words = Vec::with_capacity(job.n_pbs * wpp);
        if job.n_pbs == LANES {
            group_kern
                .decode_group_into(&job.llr[job.lo..job.lo + LANES * per_pb], &mut group_bits);
            for chunk in group_bits.chunks_exact(block) {
                words.extend(pack_bits(chunk));
            }
        } else {
            for p in 0..job.n_pbs {
                let off = job.lo + p * per_pb;
                tail_kern.decode_block_into(&job.llr[off..off + per_pb], &mut bits);
                words.extend(pack_bits(&bits));
            }
        }
        let busy = t0.elapsed();
        stats.record(wid, busy, job.n_pbs as u64);
        // receiver may be gone if the caller bailed; job is then moot
        let _ = job.reply.send(GroupResult {
            seq: job.seq,
            wid,
            busy,
            n_pbs: job.n_pbs,
            words,
        });
    }
}

/// Lane-interleaved SIMD CPU engine: each `decode_batch` call cuts the
/// batch into `batch / LANES` full lane-groups (plus one ragged-tail
/// job of `batch % LANES` PBs), dispatches them to a persistent
/// `N_w`-worker pool — one job per lane-group, so attribution and load
/// balancing are lane-group granular — and splices the bit-packed
/// outputs back in batch order.  Decisions are bit-identical to the
/// scalar engines; multiple coordinator lanes may call concurrently.
pub struct SimdCpuEngine {
    trellis: Trellis,
    batch: usize,
    block: usize,
    depth: usize,
    workers: usize,
    jobs: Arc<BoundedQueue<GroupJob>>,
    stats: Arc<WorkerPoolStats>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl SimdCpuEngine {
    /// Build a pool of `workers` decode workers; `0` means one per
    /// available core (same policy as `ParCpuEngine::new`).
    pub fn new(
        trellis: &Trellis,
        batch: usize,
        block: usize,
        depth: usize,
        workers: usize,
    ) -> SimdCpuEngine {
        assert!(batch > 0 && block > 0 && depth > 0);
        let workers = crate::par::resolve_workers(workers);
        let jobs: Arc<BoundedQueue<GroupJob>> = BoundedQueue::new(workers * 4);
        let stats = Arc::new(WorkerPoolStats::new(workers));
        let mut handles = Vec::with_capacity(workers);
        for wid in 0..workers {
            let q = Arc::clone(&jobs);
            let st = Arc::clone(&stats);
            let t = trellis.clone();
            handles.push(
                thread::Builder::new()
                    .name(format!("pbvd-simd-{wid}"))
                    .spawn(move || worker_loop(wid, t, block, depth, q, st))
                    .expect("spawn SIMD decode worker"),
            );
        }
        SimdCpuEngine {
            trellis: trellis.clone(),
            batch,
            block,
            depth,
            workers,
            jobs,
            stats,
            handles,
        }
    }

    /// Pool sized to the machine (one worker per available core).
    pub fn with_auto_workers(
        trellis: &Trellis,
        batch: usize,
        block: usize,
        depth: usize,
    ) -> SimdCpuEngine {
        SimdCpuEngine::new(trellis, batch, block, depth, 0)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Cumulative pool counters (engine lifetime; diff two snapshots
    /// for a per-stream view).  `jobs` counts lane-groups.
    pub fn pool_stats(&self) -> WorkerSnapshot {
        self.stats.snapshot()
    }

    /// Lane-group dispatch core shared by both [`DecodeEngine`] entry
    /// points; the batch buffer reaches workers as `Arc` clones, never
    /// copied here.
    fn dispatch(&self, llr_i8: &Arc<[i8]>) -> Result<(Vec<u32>, BatchTimings)> {
        let mut t = BatchTimings::default();
        let r = self.trellis.r;
        let per_pb = (self.block + 2 * self.depth) * r;
        if llr_i8.len() != self.batch * per_pb {
            bail!(
                "batch size mismatch: got {} LLRs, engine wants {}",
                llr_i8.len(),
                self.batch * per_pb
            );
        }
        let full = self.batch / LANES;
        let tail = self.batch % LANES;
        let n_jobs = full + usize::from(tail > 0);
        let (tx, rx) = mpsc::channel::<GroupResult>();

        let t0 = Instant::now();
        for seq in 0..n_jobs {
            let n_pbs = if seq < full { LANES } else { tail };
            let job = GroupJob {
                seq,
                n_pbs,
                llr: Arc::clone(llr_i8),
                lo: seq * LANES * per_pb,
                reply: tx.clone(),
            };
            if self.jobs.push(job).is_err() {
                bail!("SIMD decode pool already shut down");
            }
        }
        drop(tx);
        t.pack = t0.elapsed(); // dispatch only: zero input copies

        // wall time of the lane-group decode (the batch's kernel phase)
        let t0 = Instant::now();
        let mut parts: Vec<Option<Vec<u32>>> = vec![None; n_jobs];
        let mut pool = WorkerSnapshot {
            busy: vec![Duration::ZERO; self.workers],
            jobs: vec![0; self.workers],
            blocks: vec![0; self.workers],
        };
        for _ in 0..n_jobs {
            match rx.recv() {
                Ok(res) => {
                    pool.busy[res.wid] += res.busy;
                    pool.jobs[res.wid] += 1;
                    pool.blocks[res.wid] += res.n_pbs as u64;
                    parts[res.seq] = Some(res.words);
                }
                Err(_) => bail!("SIMD decode worker exited before replying"),
            }
        }
        t.k1 = t0.elapsed();
        t.per_worker = Some(pool);

        // splice lane-groups back into batch order
        let t0 = Instant::now();
        let wpp = self.block.div_ceil(32);
        let mut out = Vec::with_capacity(self.batch * wpp);
        for p in parts {
            out.extend(p.expect("every lane-group replies exactly once"));
        }
        t.unpack = t0.elapsed();
        Ok((out, t))
    }
}

impl Drop for SimdCpuEngine {
    fn drop(&mut self) {
        self.jobs.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl DecodeEngine for SimdCpuEngine {
    fn decode_batch(&self, llr_i8: &[i8]) -> Result<(Vec<u32>, BatchTimings)> {
        // Borrowed entry point: one copy to get a shareable allocation.
        // Streaming callers go through `decode_batch_shared` and skip it.
        let t0 = Instant::now();
        let shared: Arc<[i8]> = Arc::from(llr_i8);
        let copy = t0.elapsed();
        let (words, mut t) = self.dispatch(&shared)?;
        t.pack += copy;
        Ok((words, t))
    }

    fn decode_batch_shared(&self, llr_i8: &Arc<[i8]>) -> Result<(Vec<u32>, BatchTimings)> {
        self.dispatch(llr_i8)
    }

    fn batch(&self) -> usize {
        self.batch
    }
    fn block(&self) -> usize {
        self.block
    }
    fn depth(&self) -> usize {
        self.depth
    }
    fn r(&self) -> usize {
        self.trellis.r
    }
    fn name(&self) -> String {
        format!("simd-cpu:b{}w{}x{}", self.batch, self.workers, LANES)
    }
    fn worker_snapshot(&self) -> Option<WorkerSnapshot> {
        Some(self.stats.snapshot())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CpuEngine;
    use crate::rng::Xoshiro256;
    use crate::viterbi::CpuPbvdDecoder;

    fn random_i8_llrs(rng: &mut Xoshiro256, n: usize) -> Vec<i8> {
        // full i8 range including -128 (frame_stream clamps to -128)
        (0..n)
            .map(|_| ((rng.next_below(256) as i32) - 128) as i8)
            .collect()
    }

    #[test]
    fn interleaved_bm_fill_matches_scalar_table_per_lane() {
        let mut rng = Xoshiro256::seeded(0x51D);
        for r in [2usize, 3] {
            let n_cw = 1usize << r;
            let mut stage_vals = vec![0i32; r * LANES];
            let mut lane_llrs = vec![vec![0i8; r]; LANES];
            for lane in 0..LANES {
                let llr8 = random_i8_llrs(&mut rng, r);
                for ri in 0..r {
                    stage_vals[ri * LANES + lane] = llr8[ri] as i32;
                }
                lane_llrs[lane] = llr8;
            }
            let mut bm_i = vec![0u32; n_cw * LANES];
            fill_bm_lanes(&mut bm_i, &stage_vals, r);
            let off = (r as i64) * 128;
            for lane in 0..LANES {
                for c in 0..n_cw {
                    let mut acc = 0i64;
                    for (ri, &y) in lane_llrs[lane].iter().enumerate() {
                        let bit = ((c >> (r - 1 - ri)) & 1) as i64;
                        acc += (y as i64) * (2 * bit - 1);
                    }
                    assert_eq!(
                        bm_i[c * LANES + lane] as i64,
                        off + acc,
                        "r={r} c={c} lane={lane}"
                    );
                }
            }
        }
    }

    #[test]
    fn lockstep_forward_matches_reference_per_lane() {
        for (name, k, _) in crate::trellis::PRESETS {
            let t = Trellis::preset(name).unwrap();
            let (block, depth) = (40usize, 6 * *k as usize);
            let reference = CpuPbvdDecoder::new(&t, block, depth);
            let mut kern = LaneInterleavedAcs::new(&t, block, depth);
            let per_pb = kern.total() * t.r;
            let mut rng = Xoshiro256::seeded(0x1A4E5);
            let llr8 = random_i8_llrs(&mut rng, LANES * per_pb);
            kern.forward(&llr8);
            let mut bits = vec![0u8; block];
            for lane in 0..LANES {
                let lane_llr32: Vec<i32> = llr8[lane * per_pb..(lane + 1) * per_pb]
                    .iter()
                    .map(|&x| x as i32)
                    .collect();
                let fwd = reference.forward(&lane_llr32);
                // path-metric column of this lane agrees exactly
                for st in 0..t.n_states {
                    assert_eq!(
                        kern.path_metrics()[st * LANES + lane] as i64,
                        fwd.pm[st],
                        "{name} lane={lane} state={st}"
                    );
                }
                for s0 in [0usize, 1, t.n_states - 1] {
                    kern.traceback_into(lane, s0, &mut bits);
                    assert_eq!(
                        bits,
                        reference.traceback(&fwd, s0),
                        "{name} lane={lane} s0={s0}"
                    );
                }
            }
        }
    }

    #[test]
    fn simd_engine_matches_cpu_engine_with_ragged_tail() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        // batch = 2 full lane-groups + 3-PB ragged tail
        let (batch, block, depth) = (2 * LANES + 3, 64usize, 42usize);
        let cpu = CpuEngine::new(&t, batch, block, depth);
        let mut rng = Xoshiro256::seeded(0x51ACE);
        let llr = random_i8_llrs(&mut rng, batch * (block + 2 * depth) * t.r);
        let (want, _) = cpu.decode_batch(&llr).unwrap();
        for workers in [1usize, 3, 8] {
            let simd = SimdCpuEngine::new(&t, batch, block, depth, workers);
            let (got, timings) = simd.decode_batch(&llr).unwrap();
            assert_eq!(got, want, "workers={workers}");
            let pw = timings.per_worker.expect("per-call attribution");
            assert_eq!(pw.total_blocks(), batch as u64, "workers={workers}");
            // one job per lane-group plus one tail job
            assert_eq!(pw.total_jobs(), 3, "workers={workers}");
        }
    }

    #[test]
    fn simd_engine_all_tail_when_batch_below_lane_width() {
        let t = Trellis::preset("k3").unwrap();
        let (batch, block, depth) = (LANES - 1, 32usize, 15usize);
        let cpu = CpuEngine::new(&t, batch, block, depth);
        let simd = SimdCpuEngine::new(&t, batch, block, depth, 2);
        let mut rng = Xoshiro256::seeded(0x7A11);
        let llr = random_i8_llrs(&mut rng, batch * (block + 2 * depth) * t.r);
        let (want, _) = cpu.decode_batch(&llr).unwrap();
        let (got, timings) = simd.decode_batch(&llr).unwrap();
        assert_eq!(got, want);
        assert_eq!(timings.per_worker.unwrap().total_jobs(), 1);
    }

    #[test]
    fn shared_entry_point_matches_borrowed() {
        let t = Trellis::preset("k5").unwrap();
        let (batch, block, depth) = (LANES + 1, 32usize, 25usize);
        let simd = SimdCpuEngine::new(&t, batch, block, depth, 2);
        let mut rng = Xoshiro256::seeded(0x0C0);
        let llr = random_i8_llrs(&mut rng, batch * (block + 2 * depth) * t.r);
        let (want, _) = simd.decode_batch(&llr).unwrap();
        let shared: Arc<[i8]> = llr.into();
        let (got, _) = simd.decode_batch_shared(&shared).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn simd_engine_rejects_bad_batch_and_reports_stats() {
        let t = Trellis::preset("k5").unwrap();
        let simd = SimdCpuEngine::new(&t, LANES, 32, 20, 3);
        assert!(simd.decode_batch(&[0i8; 5]).is_err());
        let llr = vec![1i8; LANES * (32 + 40) * t.r];
        let before = simd.pool_stats();
        simd.decode_batch(&llr).unwrap();
        let delta = simd.pool_stats().delta_since(&before);
        assert_eq!(delta.total_blocks(), LANES as u64);
        assert_eq!(delta.total_jobs(), 1);
        assert_eq!(simd.worker_snapshot().unwrap().workers(), 3);
        assert_eq!(simd.workers(), 3);
        assert!(simd.name().contains("w3"));
        assert!(simd.name().starts_with("simd-cpu:"));
    }

    #[test]
    fn pool_shuts_down_cleanly_on_drop() {
        let t = Trellis::preset("k3").unwrap();
        let simd = SimdCpuEngine::new(&t, LANES, 32, 15, 2);
        let llr = vec![0i8; LANES * (32 + 30) * t.r];
        simd.decode_batch(&llr).unwrap();
        drop(simd); // joins workers; must not hang or panic
    }
}
